"""Real worker processes + measured hops, end to end.

Deploys MobileNetV2 across 3 OS processes connected by real loopback TCP
(the ``socket`` transport), measures per-hop transfer cost from the
wire's own ``TransferRecord``s, live-migrates the cut vector inside the
running processes, lets the closed adaptive loop re-solve from the
*measured* (not modeled) hop costs, and finally converts the measured
records into a replayable ``LinkTrace`` that seeds the emulator.

    PYTHONPATH=src python examples/socket_pipeline.py

(The ``if __name__ == "__main__"`` guard matters: worker hosts are
spawned processes.)
"""
import jax
import numpy as np


def main():
    from repro.core import scenarios
    from repro.core.devices import DURESS
    from repro.models.cnn import zoo
    from repro.runtime import AdaptiveRuntime, EdgePipeline, record_trace

    m = zoo.get("mobilenetv2")
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))

    # --- a 3-stage pipeline across real processes ---------------------- #
    scen = scenarios.get("pi_pi_gpu")
    with EdgePipeline(m, params, (5, 12), scen, transport="socket") as pipe:
        pipe.warmup(x)
        out, latency, hop_s = pipe.run_one(x)
        ref = np.asarray(m.apply(params, x))
        print(f"3 worker processes over loopback TCP: "
              f"latency {latency * 1e3:.1f} ms, per-hop "
              f"{[f'{h * 1e6:.0f}us' for h in hop_s]}, "
              f"output matches: {np.allclose(ref, out, atol=1e-5)}")

        pipe.migrate((3, 17))
        pipe.warmup(x)           # jit the new block ranges off the clock
        out, latency, _ = pipe.run_one(x)
        print(f"live-migrated to cuts {pipe.cuts} inside the running "
              f"processes: latency {latency * 1e3:.1f} ms, "
              f"still correct: {np.allclose(ref, out, atol=1e-5)}\n")

        # measured records -> a replayable trace for the emulator
        pipe.probe()
        trace = record_trace(pipe.nets[0], name="loopback_recorded",
                             bucket_s=60.0)
    snap = trace.at(0.0)
    print(f"recorded hop 0 as a LinkTrace: rtt={snap.rtt_s * 1e6:.0f}us "
          f"bw={snap.bw_bytes_per_s / 1e6:.0f} MB/s "
          f"(replay with scenario.with_link(0, trace))\n")

    # --- the adaptive loop closing over measured costs ------------------ #
    # plan pessimistically (duress everywhere); the measured wire is a
    # loopback socket, so the loop should discover that and migrate
    duress = (scen.with_link(0, DURESS).with_link(1, DURESS)
              .with_transport("socket"))
    with AdaptiveRuntime(m, params, duress,
                         graph=m.block_graph(input_hw=32), batch=2,
                         policy="throughput", check_every=2,
                         migration_cost_s=0.02, alpha=0.8) as rt:
        rt.run(lambda: x, n_batches=10)
        est = rt.estimators[0]
        print(f"planned under duress (200 ms RTT), measured loopback: "
              f"rtt -> {est.rtt_s * 1e3:.1f} ms, "
              f"bw -> {est.bw_bytes_per_s / 1e6:.0f} MB/s")
        print(f"cut history: {' -> '.join(map(str, rt.cut_history))} "
              f"({len(rt.pipe.migrations)} migration(s) on live processes)")


if __name__ == "__main__":
    main()
