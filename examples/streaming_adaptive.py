"""Adaptive migration *inside* a pipelined stream, end to end.

Deploys MobileNetV2 across the 3-stage pi→pi→gpu chain and opens one
streaming ``Session`` (the runtime's single entrypoint) with an
``AdaptiveController``: batches stay in flight while the first hop
ramps from healthy LAN to the paper's 200 ms / 5 Mbit WAN, the closed
loop — observed wire times → per-hop ``LinkEstimator`` → re-solve →
in-band ``RECONFIG`` under the ``drop`` policy — moves the cut vector
without flushing the pipeline, and the printed per-window throughput
shows the dip around the migration and the recovery after it.

    PYTHONPATH=src python examples/streaming_adaptive.py
"""
import jax

from repro.core import scenarios
from repro.core.autosplit import AdaptiveSplitter
from repro.models.cnn import zoo
from repro.runtime import AdaptiveController, EdgePipeline

m = zoo.get("mobilenetv2")
params = m.init(jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
N_BATCHES, WINDOW = 48, 6

# hop 0 ramps LAN → WAN shortly after the stream starts
scen = scenarios.wan_ramp(scenarios.get("pi_pi_gpu"), hop=0,
                          t_start=0.5, t_end=2.0)
graph = m.block_graph(input_hw=32)
splitter = AdaptiveSplitter(graph, scen, batch=x.shape[0],
                            policy="throughput", hysteresis=0.10,
                            migration_cost_s=0.05, include_io=False,
                            amortize_horizon_s=30.0)
init = splitter.solve()
splitter.current = init
print(f"scenario {scen.name}: {scen.n_stages} stages, "
      f"links {[l.name for l in scen.links]}")
print(f"deployed at cuts {init.partition} (nominal conditions)\n")

pipe = EdgePipeline(m, params, init.partition, scen)
pipe.warmup(x)
pipe.reset_clock()

ctrl = AdaptiveController(splitter, check_every=4)
with pipe.session(ctrl, inflight=4, policy="drop", window=WINDOW) as s:
    for _ in range(N_BATCHES):
        s.submit(x)
    for _ in s.results():
        pass                                  # keep the pipeline draining

print(f"{'window':>8} {'t':>7} {'cuts':>9} {'img/s':>8}")
for w0 in range(0, N_BATCHES, WINDOW):
    recs = s.records[w0:w0 + WINDOW]
    tput = recs[-1].throughput
    mig = "  << migrated" if any(r.migrated and r.migration_cost_s
                                 for r in recs) else ""
    print(f"{w0 // WINDOW:>8} {recs[-1].t_s:6.2f}s {str(recs[-1].cuts):>9} "
          f"{tput:8.1f}{mig}")

migs = [r for r in s.records if r.migration_cost_s > 0]
print(f"\nmigrations: {len(pipe.migrations)}")
for r in migs:
    print(f"  batch {r.batch_idx} at t={r.t_s:.2f}s -> cuts {pipe.cuts}: "
          f"charged {r.migration_cost_s * 1e3:.0f} ms wall, "
          f"{r.migration_cost_j * 1e3:.2f} mJ weight shipment")
g = graph
hist = [r.cuts for r in s.records]
print(f"hop-0 wire bytes/sample: {g.cut_bytes(hist[0][0])}"
      f" -> {g.cut_bytes(hist[-1][0])}")
pipe.close()
