"""Network-aware adaptive re-splitting (the paper's future work,
implemented): a link monitor feeds EWMA estimates to the splitter, which
migrates the partition when the predicted gain clears hysteresis.

    PYTHONPATH=src python examples/adaptive_split.py
"""
from repro.core import AdaptiveSplitter, LinkEstimator, scenarios
from repro.core.devices import DURESS, LAN_PI_PI
from repro.models.cnn import zoo

graph = zoo.get("mobilenetv2").block_graph()
scen = scenarios.get("pi_to_pi")
splitter = AdaptiveSplitter(graph, scen, batch=8, policy="throughput")
est = LinkEstimator(rtt_s=LAN_PI_PI.rtt_s,
                    bw_bytes_per_s=LAN_PI_PI.bw_bytes_per_s, alpha=0.5)

print("phase 1: healthy LAN")
for step in range(3):
    m, migrated = splitter.step(est)
    print(f"  step {step}: split P{m.partition[0]} thr={m.throughput:6.2f}"
          f" img/s {'(migrated)' if migrated else ''}")

print("phase 2: link degrades to 200ms / 5Mbit/s (tc-style)")
for step in range(12):
    # monitor observes slow transfers → estimates collapse
    est.observe(1.0e6, DURESS.transfer_time(1.0e6))
    est.observe(0, DURESS.rtt_s, is_rtt_probe=True)
    m, migrated = splitter.step(est)
    print(f"  step {step}: split P{m.partition[0]} thr={m.throughput:6.2f}"
          f" img/s {'(migrated)' if migrated else ''}")

print("phase 3: link recovers")
for step in range(8):
    est.observe(1.0e6, LAN_PI_PI.transfer_time(1.0e6))
    est.observe(0, LAN_PI_PI.rtt_s, is_rtt_probe=True)
    m, migrated = splitter.step(est)
    print(f"  step {step}: split P{m.partition[0]} thr={m.throughput:6.2f}"
          f" img/s {'(migrated)' if migrated else ''}")
