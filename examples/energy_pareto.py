"""Energy as a third objective, end to end.

1. Sweep MobileNetV2 over the 3-Pi battery chain and print the
   (latency ↓, throughput ↑, energy ↓) Pareto front — the surface a
   2-objective solver cannot see.
2. Re-solve with the exact 3-objective DP and check it agrees.
3. Run the closed adaptive loop under a WAN ramp with an energy budget:
   the splitter discards splits above the budget before picking, so the
   migration chases joules as well as throughput.

    PYTHONPATH=src python examples/energy_pareto.py
"""
import jax

from repro.core import (best_energy, best_throughput, dp_front_kway,
                        knee_point, pareto_front, scenarios, sweep_kway)
from repro.models.cnn import zoo
from repro.runtime.adaptive import AdaptiveRuntime

OBJ3 = ("latency", "throughput", "energy")

m = zoo.get("mobilenetv2")
graph = m.block_graph()
scen = scenarios.get("pi_only3")

pts = sweep_kway(graph, scen.devices, scen.links, batch=8)
front = pareto_front(pts, OBJ3)
print(f"{scen.name}: {len(pts)} partitions, {len(front)} on the 3-D front")
print(f"{'cuts':12s} {'lat ms':>9s} {'img/s':>7s} {'J/batch':>8s}")
for p in front:
    print(f"{str(p.partition):12s} {p.latency_s*1e3:9.1f} "
          f"{p.throughput:7.2f} {p.energy_j:8.2f}")

bt, be, kn = best_throughput(pts), best_energy(pts), knee_point(pts, OBJ3)
print(f"\nthroughput pick {bt.partition}: {bt.throughput:.2f}/s at "
      f"{bt.energy_j:.2f} J — energy pick {be.partition}: "
      f"{be.energy_j:.2f} J at {be.throughput:.2f}/s — 3-D knee "
      f"{kn.partition}")

dp = dp_front_kway(graph, scen.devices, scen.links, batch=8,
                   objectives=OBJ3)
assert {p.partition for p in dp} == {p.partition for p in front}
print(f"3-objective DP front matches the exhaustive sweep "
      f"({len(dp)} points)\n")

# --- the closed loop under an energy budget ------------------------------ #
params = m.init(jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
ramp = scenarios.wan_ramp(scenarios.get("pi_pi_gpu"), hop=0,
                          t_start=0.5, t_end=2.0)
rt = AdaptiveRuntime(m, params, ramp, graph=m.block_graph(input_hw=32),
                     batch=2, policy="throughput", check_every=2,
                     migration_cost_s=0.05, alpha=0.6,
                     energy_budget_j=6.0)
print(f"adaptive loop on {ramp.name} under a 6 J/batch budget:")
for r in rt.run(lambda: x, n_batches=16):
    flag = "  << migrated" if r.migrated and r.migration_cost_s else ""
    print(f"t={r.t_s:6.2f}s batch {r.batch_idx:2d} cuts={r.cuts} "
          f"lat={r.latency_s*1e3:7.1f} ms "
          f"E={r.energy_j:5.2f} J (model {r.predicted_energy_j:5.2f} J)"
          f"{flag}")
print(f"cut history: {' -> '.join(map(str, rt.cut_history))}")
