"""k-stage executable pipeline + closed adaptive loop, end to end.

Deploys MobileNetV2 across the 3-stage pi→pi→gpu chain, streams batches
while the first hop degrades from healthy LAN to the paper's 200 ms /
5 Mbit WAN (a ``LinkTrace`` ramp the emulator samples per transfer), and
lets the closed loop — observed wire times → per-hop ``LinkEstimator`` →
``partitioner.solve`` → live migration — chase the moving optimum.

    PYTHONPATH=src python examples/kway_adaptive.py
"""
import jax

from repro.core import scenarios
from repro.models.cnn import zoo
from repro.runtime.adaptive import AdaptiveRuntime

m = zoo.get("mobilenetv2")
params = m.init(jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))

# hop 0 ramps LAN → WAN; a quick ramp so the demo sees the full collapse
# (the registry's pi_pi_gpu_wan_ramp is the same shape at t=2..6s)
scen = scenarios.wan_ramp(scenarios.get("pi_pi_gpu"), hop=0,
                          t_start=0.5, t_end=2.0)
rt = AdaptiveRuntime(m, params, scen, graph=m.block_graph(input_hw=32),
                     batch=2, policy="throughput",
                     check_every=2, migration_cost_s=0.05, alpha=0.6)
print(f"scenario {scen.name}: {scen.n_stages} stages, "
      f"links {[l.name for l in scen.links]}")
print(f"deployed at cuts {rt.pipe.cuts} (nominal conditions)\n")

for r in rt.run(lambda: x, n_batches=30):
    flag = "  << migrated" if r.migrated and r.migration_cost_s else ""
    print(f"t={r.t_s:6.2f}s batch {r.batch_idx:2d} cuts={r.cuts} "
          f"lat={r.latency_s*1e3:7.1f} ms "
          f"(model: {r.predicted_latency_s*1e3:7.1f} ms){flag}")

print(f"\ncut history: {' -> '.join(map(str, rt.cut_history))}")
g = rt.graph
print(f"hop-0 wire bytes/sample: {g.cut_bytes(rt.cut_history[0][0])}"
      f" -> {g.cut_bytes(rt.cut_history[-1][0])}")
