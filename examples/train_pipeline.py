"""End-to-end training driver: a ~100M-param qwen3-family model for a
few hundred steps on CPU, with checkpointing and deterministic resume.

    PYTHONPATH=src python examples/train_pipeline.py [--steps 300]

(Pass --pods 2 with REPRO_HOST_DEVICES=8 to train through the pod-level
GPipe pipeline with ParetoPipe-chosen cuts.)
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    defaults = ["--arch", "qwen3-1.7b", "--reduced",
                "--d-model", "512", "--n-layers", "8",
                "--steps", "300", "--batch", "4", "--seq", "256",
                "--ckpt-dir", "runs/train_100m", "--ckpt-every", "100"]
    raise SystemExit(main(defaults + argv))
