"""Accuracy as the fourth Pareto axis: per-hop wire codecs end to end.

Three acts on the 3-stage pi→pi→gpu chain:

  1. **Calibrate** — measure what each codec actually does to the model
     output at every cut (top-1 agreement on a held batch), the table
     the solver consumes instead of nominal codec figures.
  2. **Solve** — the joint partition × per-hop-codec search
     (``solve_with_codecs``, 4 objectives) under healthy links and
     under the paper's duress WAN: healthy links don't pay for lossy
     wire, so the front collapses to full fidelity; under duress the
     front becomes an accuracy/latency *staircase* — each accuracy
     floor buys a different latency, and the floor picks the step.
  3. **Stream** — an ``AdaptiveController`` whose splitter searches the
     same codec menu live: the ``congestion_spike`` trace degrades
     hop 0, the controller coarsens the wire codec in-band (charged
     like a migration), and the stream keeps its latency SLO at a
     fidelity the accuracy floor still permits.

    PYTHONPATH=src python examples/codec_pareto.py
"""
import time
from dataclasses import replace

import jax
import numpy as np

from repro.core import scenarios
from repro.core.autosplit import AdaptiveSplitter
from repro.core.codecs import calibrate_codecs, codec_wire_bytes
from repro.core.partitioner import solve_with_codecs
from repro.models.cnn.layers import (Conv2D, Flatten, Linear, Pool, ReLU,
                                     Sequential)
from repro.models.cnn.zoo import CNNModel
from repro.runtime import AdaptiveController, EdgePipeline

BATCH = 2
MENU = ("none", "int8", "fp8", "topk")

m = CNNModel("tinycnn", [
    ("conv0", Sequential([Conv2D(3, 8, 3, 1, 1), ReLU()])),
    ("conv1", Sequential([Conv2D(8, 8, 3, 1, 1), ReLU()])),
    ("pool", Pool("max", 2, 2)),
    ("conv2", Sequential([Conv2D(8, 16, 3, 1, 1), ReLU()])),
    ("head", Sequential([Flatten(), Linear(16 * 16 * 16, 10)])),
], input_hw=32)
params = m.init(jax.random.PRNGKey(0))
graph = m.block_graph(input_hw=32)
x = jax.random.normal(jax.random.PRNGKey(1), (BATCH, 32, 32, 3))

# --- 1. measured degradation per (cut, codec) ------------------------------- #
held = jax.random.normal(jax.random.PRNGKey(7), (8, 32, 32, 3))
cal = calibrate_codecs(m, params, held)
print("measured top-1 agreement per cut (held batch of 8):")
print(f"  {'cut':>4} " + "".join(f"{c:>7}" for c in MENU[1:]))
for cut in range(1, len(m.blocks)):
    row = "".join(f"{cal.accuracy(cut, c):7.3f}" for c in MENU[1:])
    print(f"  {cut:>4} {row}")

# --- 2. the 4-objective front: healthy vs duress ---------------------------- #
base = scenarios.get("pi_pi_gpu")
for scen in (base, scenarios.duress(base)):
    front = solve_with_codecs(graph, scen, codec_choices=MENU, batch=BATCH,
                              include_io=False, objectives=4,
                              calibration=cal)
    print(f"\n4-objective front on {scen.name} "
          f"({len(front)} points; latency-sorted):")
    print(f"  {'cuts':>9} {'codecs':>16} {'lat ms':>8} {'img/s':>7} "
          f"{'mJ':>7} {'acc':>6}")
    for p in sorted(front, key=lambda p: p.latency_s):
        print(f"  {str(p.partition):>9} {'/'.join(p.codecs):>16} "
              f"{p.latency_s * 1e3:8.2f} {p.throughput:7.1f} "
              f"{p.energy_j * 1e3:7.2f} {p.accuracy:6.3f}")

# the staircase: under duress, each accuracy floor buys a latency step.
# The *measured* table above says int8/fp8 are lossless on this tiny
# model (top-1 agreement 1.0), so with calibration the floor never
# bites — good news, but it hides the mechanism.  Run the same sweep on
# the conservative nominal codec figures (what the solver uses when no
# calibration exists: int8 0.99, fp8 0.995, topk 0.97 per coded hop) to
# see each floor buy a different latency step.
duress = scenarios.duress(base)
print(f"\naccuracy/latency staircase on {duress.name} "
      f"(best latency per floor, nominal codec figures):")
for floor in (None, 0.95, 0.99, 0.999, 1.0):
    front = solve_with_codecs(graph, duress, codec_choices=MENU,
                              batch=BATCH, include_io=False, objectives=4,
                              accuracy_floor=floor)
    best = min(front, key=lambda p: p.latency_s)
    tag = "none" if floor is None else f"{floor:.3f}"
    print(f"  floor {tag:>5}: cuts={best.partition} "
          f"codecs={'/'.join(best.codecs):>12}  "
          f"lat={best.latency_s * 1e3:7.2f}ms  acc={best.accuracy:.4f}")

# --- 3. live coarsening through the congestion spike ------------------------ #
scen = scenarios.get("pi_pi_gpu_congestion_spike")
splitter = AdaptiveSplitter(graph, scen, batch=BATCH, policy="latency",
                            include_io=False, hysteresis=0.10,
                            codec_choices=("none", "int8", "topk"),
                            accuracy_floor=0.95, calibration=cal)
# deploy uncoded: on the healthy LAN the packed wire buys too little to
# clear the hysteresis — the spike is what will coarsen it
init = replace(splitter, codec_choices=None).solve()
splitter.current = init
print(f"\nstreaming through {scen.name}: deployed cuts={init.partition} "
      f"codecs=none (floor 0.95 — topk is excluded by calibration)")

ctrl = AdaptiveController(splitter, check_every=2, probe=False)
N, WINDOW = 45, 5
with EdgePipeline(m, params, init.partition, scen) as pipe:
    pipe.warmup(x)
    pipe.reset_clock()
    with pipe.session(ctrl, inflight=2, policy="drop", window=WINDOW) as s:
        for _ in range(N):
            s.submit(x)
            time.sleep(0.1)               # let the trace clock advance
        for _ in s.results():
            pass
    recs = sorted(s.records, key=lambda r: r.t_s)
    print(f"{'t':>7} {'cuts':>9} {'codecs':>12} {'lat ms':>8}")
    for i in range(0, len(recs), WINDOW):
        w = recs[i:i + WINDOW]
        r = w[-1]
        mig = "  << codec switch" if any(
            q.migrated and q.migration_cost_s for q in w) else ""
        lat = float(np.median([q.latency_s for q in w]) * 1e3)
        print(f"{r.t_s:6.2f}s {str(r.cuts):>9} {'/'.join(r.codecs):>12} "
              f"{lat:8.1f}{mig}")
    switched = [r for r in recs if r.migration_cost_s > 0]
    for r in switched:
        print(f"\nswitch at t={r.t_s:.2f}s -> codecs {'/'.join(r.codecs)}: "
              f"charged {r.migration_cost_s * 1e3:.0f} ms "
              f"(RECONFIG + in-band warmup)")
    print(f"hop-0 wire bytes/sample: "
          f"{graph.cut_bytes(recs[0].cuts[0])} -> "
          f"{int(codec_wire_bytes(recs[-1].codecs[0], graph.cut_bytes(recs[-1].cuts[0])))}")
