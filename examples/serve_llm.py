"""Batched LLM serving: prefill a prompt batch, decode new tokens with
KV caches, report the paper's two metrics (latency & throughput).

    PYTHONPATH=src python examples/serve_llm.py [--arch zamba2-7b]
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--arch" not in argv:
        argv = ["--arch", "qwen3-1.7b"] + argv
    raise SystemExit(main(argv + ["--reduced"]))
