"""Quickstart: ParetoPipe in 25 lines — map the latency/throughput
frontier for MobileNetV2 split across two Raspberry Pis.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import scenarios, sweep_2way, pareto_front, knee_point
from repro.models.cnn import zoo

model = zoo.get("mobilenetv2")           # the paper's Table-I model
graph = model.block_graph()              # per-block FLOPs/bytes
scen = scenarios.get("pi_to_pi")         # calibrated testbed

points = sweep_2way(graph, scen.devices, scen.links[0], batch=8)
front = pareto_front(points)

print(f"swept {len(points)} split points; {len(front)} on the front:")
for p in front:
    print(f"  split after block {p.partition[0]:2d}: "
          f"latency {p.latency_s*1e3:7.1f} ms, "
          f"throughput {p.throughput:5.2f} img/s")
knee = knee_point(points)
print(f"balanced pick (knee): P{knee.partition[0]}")
