"""ServeGate end to end: 8 tenants with mixed SLOs through one pipeline.

Eight closed-loop tenants (SLOs from 150 ms to 2 s, the
``octet_mixed_slo`` mix) share the 3-stage pi→pi→gpu chain through one
:class:`~repro.runtime.serve.Gateway` while hop 0 rides the
``congestion_spike`` trace — clean until t=2 s, fully congested (the
paper's 200 ms / 5 Mbit duress) by t=4 s, recovered by t=7 s.

Three control loops are visible in the printed timeline:

  * **micro-batching** — the gateway coalesces up to 8 tenant requests
    per padded micro-batch (occupancy column);
  * **SLO-aware admission** — the congestion dip blows the strict
    tenants' SLOs, the AIMD window halves (throttle), and clean batches
    after recovery grow it back (the ``win`` column);
  * **fleet-level Pareto control** — the :class:`FleetController`
    aggregates per-request QoS into fleet objectives and steers the
    splitter's policy axis (latency-min under tail pressure,
    throughput-max with headroom).

    PYTHONPATH=src python examples/serving_gateway.py
"""
import jax
import numpy as np

from repro.core import scenarios
from repro.core.autosplit import AdaptiveSplitter
from repro.models.cnn import zoo
from repro.runtime import EdgePipeline, FleetController, Gateway, \
    drain_violations

T_END, WINDOW_S = 9.0, 1.0
MAX_BATCH = 8

m = zoo.get("mobilenetv2")
params = m.init(jax.random.PRNGKey(0))
scen = scenarios.with_trace(scenarios.get("pi_pi_gpu"), "congestion_spike")
mix = scenarios.get_tenant_mix("octet_mixed_slo")
print(f"scenario {scen.name}: {scen.n_stages} stages; "
      f"tenants {[f'{t.name}@{t.slo_s * 1e3:.0f}ms' for t in mix.tenants]}")

graph = m.block_graph(input_hw=32)
splitter = AdaptiveSplitter(graph, scen, batch=MAX_BATCH,
                            policy="throughput", hysteresis=0.10,
                            migration_cost_s=0.05, include_io=False,
                            amortize_horizon_s=30.0)
splitter.current = splitter.solve()
ctrl = FleetController(splitter, check_every=8, probe=False)

pipe = EdgePipeline(m, params, splitter.current.partition, scen)
x_row = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3))
pipe.warmup(np.concatenate([np.asarray(x_row)] * MAX_BATCH, 0))
pipe.reset_clock()

xs = {t.name: np.asarray(x_row) + np.float32(i * 1e-3)
      for i, t in enumerate(mix.tenants)}
served, violated = 0, 0
timeline = []

with Gateway(pipe, mix, controller=ctrl, max_batch=MAX_BATCH,
             batch_window_s=0.01, inflight=2) as gw:
    for name in xs:                           # prime: one in flight each
        gw.submit(name, xs[name])
    win_qos, next_edge = [], WINDOW_S
    while pipe.clock() < T_END:
        for tenant, _req_id, _val in gw.poll(block=True):
            served += 1
            gw.submit(tenant, xs[tenant])     # closed loop
        win_qos.extend(gw.drain_qos())
        if pipe.clock() >= next_edge:
            lats = [r.latency_s for r in win_qos] or [0.0]
            vio = sum(r.violated for r in win_qos)
            violated += vio
            timeline.append((next_edge, len(win_qos),
                             float(np.percentile(lats, 99)), vio,
                             gw.inflight_window, splitter.policy,
                             float(np.mean([r.occupancy
                                            for r in win_qos] or [0.0]))))
            win_qos, next_edge = [], next_edge + WINDOW_S
    leftovers = gw.drain()
    served += sum(len(v) for v in leftovers.values())

print(f"\n{'t':>5} {'req/s':>6} {'p99':>8} {'viol':>5} {'win':>4} "
      f"{'policy':>11} {'occup':>6}")
for t, n, p99, vio, win, policy, occ in timeline:
    print(f"{t:4.0f}s {n / WINDOW_S:6.0f} {p99 * 1e3:6.1f}ms {vio:>5} "
          f"{win:>4} {policy:>11} {occ:6.2f}")

print(f"\nserved {served} requests from {len(mix.tenants)} tenants; "
      f"{violated} SLO violations (concentrated in the spike and the "
      f"migration dips)")
print("admission window excursions (t, window):")
print("  " + " -> ".join(f"({t:.2f}s, {w})" for t, w in gw.window_history))
obj = ctrl.fleet_objectives()
if obj is not None:
    print(f"fleet objectives at close: p99 {obj.p99_s * 1e3:.1f} ms vs "
          f"strictest SLO {obj.strictest_slo_s * 1e3:.0f} ms, "
          f"{obj.aggregate_ips:.0f} req/s, {obj.j_per_request:.2f} J/req "
          f"-> policy {obj.policy!r}")
print(f"fleet control decisions: {len(ctrl.fleet_history)}; "
      f"migrations: {len(pipe.migrations)}")
assert drain_violations() == []
pipe.close()
