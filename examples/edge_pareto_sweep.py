"""Full edge-inference Pareto study: all six CNNs × {Pi-Pi, Pi-GPU} ×
{ideal LAN, 200ms/5Mbit duress} — reproduces paper Figs 3-6 with ASCII
frontier plots.

    PYTHONPATH=src python examples/edge_pareto_sweep.py
"""
import sys
sys.path.insert(0, ".")
from benchmarks import paper_tables as P

P.table1_models()
P.fig3_pareto_pi_pi()
P.fig4_pareto_pi_gpu()
P.fig56_duress()
P.table23_breakdown()
