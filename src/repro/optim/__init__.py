from .adamw import (OptConfig, init_opt_state, apply_gradients,
                    cosine_schedule, global_norm)
from .compress import (CompressionConfig, init_error_state,
                       compress_gradients)
