"""AdamW with global-norm clipping and schedules (no external deps).

Moments are fp32 regardless of param dtype; updates are computed in fp32
and cast back — the standard mixed-precision recipe.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def lr_at(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return fn


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    import copy
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_gradients(params, grads, state, cfg: OptConfig):
    """→ (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)) \
        if cfg.clip_norm > 0 else 1.0
    lr = cfg.lr_at(count)
    bc1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        step = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:     # decay matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
