"""Error-feedback int8 gradient compression (1-bit-Adam-family trick).

Scheme: per-leaf symmetric int8 quantization of the gradient with an
error-feedback buffer so the quantization error is re-injected next step
(provably keeps SGD/Adam convergence).  The shared scale is the psum-max
across data-parallel replicas, so every replica quantizes into the same
grid and the reduction is exact over the quantized values.

Honesty note (DESIGN.md §6): XLA does not lower an int8 all-reduce on
TPU, so when running under pjit the compression runs as
quantize→(fp all-reduce of int8-valued tensors)→dequantize — the
*convergence* behaviour is exactly that of the compressed scheme and is
what the tests validate; the wire-byte saving (4×) is credited
analytically in the partitioner's cost model (``Link`` bytes), not in
the compiled HLO.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = False
    bits: int = 8

    @property
    def levels(self) -> int:
        return 2 ** (self.bits - 1) - 1


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _q_leaf(g, err, levels: int):
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / levels
    q = jnp.clip(jnp.round(g / scale), -levels, levels)
    deq = q * scale
    return deq, g - deq


def compress_gradients(grads, err_state, cfg: CompressionConfig):
    """→ (compressed_grads, new_error_state)."""
    if not cfg.enabled:
        return grads, err_state
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    out = [_q_leaf(g, e, cfg.levels) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def compressed_bytes(params, cfg: CompressionConfig) -> int:
    """Wire bytes per gradient exchange under compression (for the
    partitioner's link model).

    Delegates to ``core.codecs.quantized_wire_bytes`` so the analytic
    credit uses the *same* wire layout the runtime's packed codecs ship
    (per-leaf scale header + packed payload) — the figure agrees with
    what ``TransferRecord.wire_bytes`` would record for the transfer."""
    from ..core.codecs import quantized_wire_bytes
    if not cfg.enabled:
        return int(sum(l.size for l in jax.tree.leaves(params)) * 4)
    return int(sum(quantized_wire_bytes(l.size, bits=cfg.bits)
                   for l in jax.tree.leaves(params)))
