"""Training launcher: end-to-end driver with fault tolerance.

Runs any registered architecture (``--arch``, full or ``--reduced``) with
checkpoint/restart, deterministic data resume, optional ParetoPipe
auto-partitioning of the pipeline axis, gradient compression, and
failure injection for the crash-restart integration test.

Examples:
  # CPU-scale end-to-end run (~100M params), a few hundred steps:
  python -m repro.launch.train --arch qwen3-1.7b --reduced --steps 300 \
      --batch 8 --seq 128 --ckpt-dir runs/train_qwen3

  # crash/restart drill (kills itself mid-run, then resume):
  python -m repro.launch.train ... --fail-at-step 120
  python -m repro.launch.train ...            # resumes from step 100

  # multi-pod pipeline on forced host devices with ParetoPipe cuts:
  REPRO_HOST_DEVICES=8 python -m repro.launch.train --arch qwen3-1.7b \
      --reduced --pods 2 --auto-partition --steps 20
"""
import os
if os.environ.get("REPRO_HOST_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_HOST_DEVICES"])

import argparse
import sys
import time


def main(argv=None) -> int:
    import jax
    import jax.numpy as jnp

    from .. import configs
    from ..checkpoint import CheckpointManager
    from ..data.pipeline import DataConfig, SyntheticLM
    from ..models import lm
    from ..models.common import DTYPES, InitBuilder
    from ..optim import CompressionConfig, OptConfig, cosine_schedule
    from ..runtime.pipeline import (PipelineConfig, make_pipeline_train_step,
                                    repack_params, unpack_params)
    from ..runtime.steps import init_train_state, make_train_step
    from ..sharding.api import use_mesh_context
    from .mesh import make_host_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_NAMES))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M-param runs)")
    ap.add_argument("--n-layers", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--fail-at-step", type=int, default=0,
                    help="inject a crash (fault-tolerance drill)")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--auto-partition", action="store_true",
                    help="ParetoPipe chooses the pipeline cuts")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.reduced(args.arch) if args.reduced else configs.get(args.arch)
    over = {}
    if args.d_model:
        over["d_model"] = args.d_model
    if args.n_layers:
        over["n_layers"] = args.n_layers
    if over:
        cfg = cfg.replace(**over)

    mesh = None
    pcfg = None
    if args.pods > 1 or args.data_par * args.model_par > 1:
        mesh = make_host_mesh(args.pods, args.data_par, args.model_par)
    if args.pods > 1:
        if args.auto_partition:
            from ..models.blocks_adapter import choose_pipeline_cuts
            cuts, pick, _ = choose_pipeline_cuts(cfg, args.seq, args.pods,
                                                 batch=args.batch)
            print(f"[paretopipe] cuts={cuts} predicted latency="
                  f"{pick.latency_s*1e3:.2f}ms thr={pick.throughput:.1f}/s")
            pcfg = PipelineConfig(args.pods, args.microbatches, cuts)
        else:
            pcfg = PipelineConfig.even(cfg.n_layers, args.pods,
                                       args.microbatches)

    opt = OptConfig(lr=cosine_schedule(args.lr, args.warmup, args.steps))
    comp = CompressionConfig(enabled=args.compress_grads)

    ctx_mgr = use_mesh_context(mesh) if mesh is not None else None
    if ctx_mgr is not None:
        ctx_mgr.__enter__()
    try:
        state = init_train_state(cfg, jax.random.PRNGKey(args.seed), opt,
                                 comp)
        if pcfg is not None:
            lk = "dec_layers" if cfg.family == "encdec" else "layers"
            state["params"] = {**state["params"],
                               lk: repack_params(state["params"][lk], pcfg,
                                                 cfg.n_layers)}
            zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
            state["opt"] = {**state["opt"],
                            "m": jax.tree.map(zeros, state["params"]),
                            "v": jax.tree.map(zeros, state["params"])}
            step_fn = make_pipeline_train_step(cfg, pcfg, opt, mesh)
        else:
            step_fn = make_train_step(cfg, opt, comp)
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

        data = SyntheticLM(cfg, DataConfig(args.batch, args.seq, args.seed))
        mgr = None
        start = 0
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
            restored, manifest = mgr.restore(specs_tree=None)
            if restored is not None:
                state = jax.tree.map(jnp.asarray, restored)
                start = int(manifest["step"])
                data.load_state_dict(manifest["extra"]["data"])
                print(f"[resume] step {start}")

        t0 = time.time()
        for step in range(start, args.steps):
            if args.fail_at_step and step == args.fail_at_step:
                # crash between async checkpoint writes, not during one:
                # the drill tests restart from a durable checkpoint; a
                # torn in-flight write is a separate failure mode the
                # manager already survives by never restoring *.tmp dirs
                if mgr is not None:
                    mgr.wait()
                print(f"[fault-injection] crashing at step {step}",
                      flush=True)
                os._exit(42)
            batch = data.batch_at(step)
            data.step = step + 1
            state, metrics = step_fn(state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics.get('grad_norm', 0)):.3f} "
                      f"({(time.time()-t0):.1f}s)", flush=True)
            if mgr is not None and mgr.should_save(step + 1):
                mgr.save(state, step + 1,
                         extra={"data": data.state_dict()}, block=False)
        if mgr is not None:
            mgr.save(state, args.steps, extra={"data": data.state_dict()})
        print(f"[done] {args.steps} steps, final loss "
              f"{float(metrics['loss']):.4f}")
        return 0
    finally:
        if ctx_mgr is not None:
            ctx_mgr.__exit__(*sys.exc_info())


if __name__ == "__main__":
    raise SystemExit(main())
