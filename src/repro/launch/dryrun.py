import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this harness
  1. builds the production mesh (16×16 single-pod or 2×16×16 multi-pod),
  2. constructs ShapeDtypeStruct inputs (weak-type-correct, sharded, no
     allocation — params are never materialized),
  3. ``jit(step).lower(...).compile()`` — any sharding mismatch, OOM at
     compile, or unsupported collective fails the cell,
  4. records ``memory_analysis`` / ``cost_analysis`` / the collective
     inventory parsed from optimized HLO, and the roofline terms,
  5. writes a JSON manifest per cell (resumable; EXPERIMENTS.md is
     generated from these).

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh both
  python -m repro.launch.dryrun --all [--force] [--out runs/dryrun]
"""
import argparse
import json
import time
import traceback
from pathlib import Path


GRAD_ACCUM = 4       # §Perf iteration 4: 4× smaller activation working set
TRAIN_ATTN_CHUNK = 1024   # §Perf iteration 3: flash block size for train


def _build_step(cfg, shape, mesh, multi_pod: bool, microbatches: int):
    from ..optim import OptConfig
    from ..runtime import steps as S
    from ..runtime.pipeline import (PipelineConfig,
                                    make_pipeline_decode_step,
                                    make_pipeline_prefill_step,
                                    make_pipeline_train_step)
    if multi_pod:
        n_pods = mesh.devices.shape[0]
        mb = microbatches if shape.kind == "train" else 1
        pcfg = PipelineConfig.even(cfg.n_layers, n_pods, mb)
        if shape.kind == "train":
            return make_pipeline_train_step(cfg, pcfg, OptConfig(), mesh), pcfg
        if shape.kind == "prefill":
            return make_pipeline_prefill_step(cfg, pcfg, mesh), pcfg
        return make_pipeline_decode_step(cfg, pcfg, mesh), pcfg
    if shape.kind == "train":
        return S.make_train_step(cfg, OptConfig(),
                                 grad_accum=GRAD_ACCUM), None
    if shape.kind == "prefill":
        return S.make_prefill_step(cfg), None
    return S.make_decode_step(cfg), None


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             microbatches: int = 8, donate: bool = True) -> dict:
    import jax
    from .. import configs
    from ..sharding.api import MeshContext, use_mesh_context
    from . import specs as SP
    from .hlo_analysis import parse_collectives
    from .mesh import make_production_mesh
    from .roofline import model_flops, roofline_from

    cfg = configs.get(arch)
    shape = SP.SHAPES[shape_name]
    if shape.kind == "train":
        cfg = cfg.replace(attn_chunk=TRAIN_ATTN_CHUNK)
    if multi_pod and cfg.family == "moe":
        # GSPMD's gather partitioner hard-aborts evaluating gather
        # strategies under manual meshes → pipeline mode uses the
        # einsum-only GShard dispatch with expert parallelism.
        cfg = cfg.replace(moe_impl="gshard")
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "family": cfg.family, "kind": shape.kind}

    ok, why = SP.cell_supported(cfg, shape_name)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = mesh.devices.size
        pod_size = (mesh.devices.size // mesh.devices.shape[0]
                    if multi_pod else mesh.devices.size)
        with use_mesh_context(mesh) as ctx:
            step, pcfg = _build_step(cfg, shape, mesh, multi_pod, microbatches)
            cell = SP.input_specs(cfg, shape_name, ctx, pcfg)
            if shape.kind == "train":
                jf = jax.jit(step, donate_argnums=(0,) if donate else ())
                lowered = jf.lower(cell["state"], cell["batch"])
            elif shape.kind == "prefill":
                lowered = jax.jit(step).lower(cell["params"], cell["inputs"])
            else:
                jf = jax.jit(step, donate_argnums=(2,) if donate else ())
                lowered = jf.lower(cell["params"], cell["token"],
                                   cell["cache"])
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # jax<=0.4: one dict per device
            ca = ca[0] if ca else {}
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
        coll = parse_collectives(hlo, pod_size)

        # Analytic executed-cost model (XLA cost_analysis counts while-loop
        # bodies once → useless under scanned layers; see launch/analytic.py)
        from .analytic import cell_cost
        axes = dict(zip(mesh.axis_names, mesh.devices.shape))
        cost = cell_cost(cfg, shape, n_chips=n_chips,
                         dp=axes.get("data", 1), tp=axes.get("model", 1),
                         multi_pod=multi_pod, pcfg=pcfg)
        mflops = model_flops(cfg, shape)
        rl = roofline_from(cost.flops_total / n_chips,
                           cost.hbm_bytes_per_dev,
                           cost.wire_ici_per_dev, cost.wire_dcn_per_dev,
                           mflops, n_chips)
        flops_dev = cost.flops_total / n_chips
        bytes_dev = cost.hbm_bytes_per_dev
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            n_chips=n_chips,
            flops_per_dev=flops_dev, bytes_per_dev=bytes_dev,
            memory={
                "args_mb": ma.argument_size_in_bytes / 1e6,
                "output_mb": ma.output_size_in_bytes / 1e6,
                "temp_mb": ma.temp_size_in_bytes / 1e6,
                "peak_mb": (ma.argument_size_in_bytes
                            + ma.temp_size_in_bytes) / 1e6,
            },
            collectives=coll.by_kind(),
            wire_ici_per_dev=cost.wire_ici_per_dev,
            wire_dcn_per_dev=cost.wire_dcn_per_dev,
            xla_raw={"flops_per_dev": float(ca.get("flops", 0.0)),
                     "bytes_per_dev": float(ca.get("bytes accessed", 0.0)),
                     "wire_ici_parsed": coll.wire_bytes_ici,
                     "wire_dcn_parsed": coll.wire_bytes_dcn,
                     "note": "while-loop bodies counted once by XLA"},
            roofline={
                "compute_s": rl.compute_s, "memory_s": rl.memory_s,
                "collective_s": rl.collective_s, "dominant": rl.dominant,
                "step_bound_s": rl.step_time_s,
                "model_flops_total": mflops,
                "useful_ratio": rl.useful_ratio,
                "mfu_bound": rl.mfu_bound,
            },
        )
    except Exception as e:  # a failure here is a bug in the system
        rec.update(status="failed", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def main() -> int:
    from .. import configs
    from . import specs as SP

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(configs.ARCH_NAMES))
    ap.add_argument("--shape", choices=list(SP.SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--out", default="runs/dryrun")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    archs = list(configs.ARCH_NAMES) if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(SP.SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    cells = [(a, s, m) for a in archs for s in shapes for m in meshes]
    single_cell = len(cells) == 1
    failures = 0
    for arch, shape, multi in cells:
        tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
        path = out / f"{tag}.json"
        if path.exists() and not args.force:
            rec = json.loads(path.read_text())
            print(f"[cached] {tag}: {rec['status']}")
            failures += rec["status"] == "failed"
            continue
        if single_cell:
            rec = run_cell(arch, shape, multi, args.microbatches)
            path.write_text(json.dumps(rec, indent=1))
        else:
            # subprocess isolation: an XLA hard abort (LOG(FATAL)) in one
            # cell must not kill the sweep — straggler/failure handling
            # for the dry-run itself.
            import subprocess
            import sys
            t0 = time.time()
            cp = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun",
                 "--arch", arch, "--shape", shape,
                 "--mesh", "multi" if multi else "single",
                 "--out", str(out)] + (["--force"] if args.force else []),
                capture_output=True, text=True, timeout=3600)
            if not path.exists():
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if multi else "16x16",
                       "status": "failed",
                       "error": "hard crash (XLA abort): "
                                + cp.stderr.strip().splitlines()[0][:200]
                                if cp.stderr.strip() else "hard crash",
                       "wall_s": round(time.time() - t0, 1)}
                path.write_text(json.dumps(rec, indent=1))
            else:
                rec = json.loads(path.read_text())
        line = f"[{rec['status']:7s}] {tag} ({rec.get('wall_s', 0)}s)"
        if rec["status"] == "ok":
            r = rec["roofline"]
            line += (f" dominant={r['dominant']}"
                     f" bound={r['step_bound_s']*1e3:.1f}ms"
                     f" peak={rec['memory']['peak_mb']:.0f}MB/dev")
        elif rec["status"] == "failed":
            failures += 1
            line += " " + rec.get("error", "")[:160]
        print(line, flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
