"""Serving launcher: batched prefill + decode with KV caches.

Runs a registered arch (reduced by default — full configs are dry-run
only on this host), prefems a batch of synthetic prompts, decodes N new
tokens, and reports prefill latency / decode throughput — the paper's
two metrics, on the LM serving path.

  python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --batch 8 --prompt-len 64 --new-tokens 32
"""
import os
if os.environ.get("REPRO_HOST_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_HOST_DEVICES"])

import argparse
import time


def main(argv=None) -> int:
    import jax
    import jax.numpy as jnp

    from .. import configs
    from ..data.pipeline import DataConfig, SyntheticLM
    from ..models import lm
    from ..models.common import DTYPES, InitBuilder
    from ..runtime.steps import make_decode_step, make_prefill_step

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_NAMES))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.reduced(args.arch) if args.reduced else configs.get(args.arch)
    params = lm.build_params(cfg, InitBuilder(jax.random.PRNGKey(args.seed),
                                              DTYPES[cfg.dtype]))
    data = SyntheticLM(cfg, DataConfig(args.batch, args.prompt_len, args.seed))
    inputs = {k: v for k, v in next(data).items() if k != "targets"}

    cache_len = args.prompt_len + args.new_tokens \
        + (cfg.n_patches if cfg.family == "vlm" else 0)
    prefill = jax.jit(make_prefill_step(cfg, cache_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

    tok, cache = prefill(params, inputs)            # warmup+compile
    jax.block_until_ready(tok)
    t0 = time.time()
    tok, cache = prefill(params, inputs)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    toks = [tok]
    tok2, cache = decode(params, tok, cache)        # warmup decode
    t0 = time.time()
    tok = tok2
    for _ in range(args.new_tokens - 1):
        tok, cache = decode(params, tok, cache)
        toks.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    n_dec = args.new_tokens - 1
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill latency: {t_prefill*1e3:.1f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode: {t_decode/n_dec*1e3:.2f} ms/token "
          f"({args.batch*n_dec/t_decode:.0f} tok/s aggregate)")
    out = jnp.concatenate(toks, axis=1)
    print(f"generated shape {out.shape}, finite={bool(jnp.all(out >= 0))}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
