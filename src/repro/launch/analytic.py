"""Analytic executed-FLOPs / HBM-bytes / wire-bytes model per cell.

Why this exists: XLA's ``cost_analysis()`` counts a while-loop body ONCE
— with scanned layers (and chunked attention) it under-counts FLOPs by
~n_layers× and its "bytes accessed" ignores fusion entirely.  Since we
wrote every einsum, we derive executed quantities from first principles
and validate against ``cost_analysis`` on *unrolled* reduced configs in
``tests/test_analytic_vs_xla.py``.  The dry-run manifest carries both
(analytic feeds the roofline; raw XLA numbers are kept for reference).

Conventions:
  * matmul (m,k)×(k,n): 2·m·k·n FLOPs.
  * causal chunked attention computes full (chunk×chunk) diagonal blocks
    → effective context per token = (S + chunk)/2.
  * backward = 2× forward matmul FLOPs; full remat re-runs the trunk
    forward once more (factor 4 on trunk, 3 on embed/logits).
  * HBM model assumes the Pallas-fused attention/scan path (weights and
    activations stream once per pass); validated intent, not measured.
  * wire model: all-reduce ring = 2·T·(s-1)/s, all-gather/reduce-scatter
    = T·(s-1)/s per device, ppermute = T.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..models.config import ArchConfig


def _ar_wire(nbytes: float, s: int) -> float:
    return 2.0 * nbytes * (s - 1) / s if s > 1 else 0.0


def _ag_wire(nbytes: float, s: int) -> float:
    return nbytes * (s - 1) / s if s > 1 else 0.0


@dataclass(frozen=True)
class CellCost:
    flops_total: float           # executed FLOPs, whole step, all chips
    hbm_bytes_per_dev: float
    wire_ici_per_dev: float
    wire_dcn_per_dev: float
    notes: str = ""


# --------------------------------------------------------------------------- #
# Per-layer forward FLOPs for one token
# --------------------------------------------------------------------------- #
def _attn_proj_flops(cfg) -> float:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return 2.0 * D * (H + 2 * KV) * hd + 2.0 * H * hd * D


def _attn_score_flops(cfg, ctx_len: float) -> float:
    """Per token: scores + AV over an effective context."""
    return 2.0 * 2.0 * cfg.n_heads * cfg.hd * ctx_len


def _mlp_flops(cfg, d_ff=None) -> float:
    f = d_ff or cfg.d_ff
    return 2.0 * cfg.d_model * f * (3 if cfg.gated_mlp else 2)


def _moe_flops(cfg) -> float:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    router = 2.0 * D * E
    expert = 2.0 * 3 * D * F * cfg.top_k * cfg.capacity_factor
    return router + expert


def _mamba1_flops(cfg) -> float:
    D, di, N, R, K = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank,
                      cfg.ssm_conv)
    proj = 2.0 * D * 2 * di + 2.0 * di * K + 2.0 * di * (R + 2 * N) \
        + 2.0 * R * di + 2.0 * di * D
    scan = 12.0 * di * N          # assoc-scan elementwise (≈2× sequential)
    return proj + scan


def _mamba2_flops(cfg, chunk: int) -> float:
    D, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    d_in = 2 * di + 2 * N + H
    proj = 2.0 * D * d_in + 2.0 * (di + 2 * N) * cfg.ssm_conv + 2.0 * di * D
    L = chunk
    # per token: CB^T row (2·L·N) + att·dtx (2·L·H·P) + carry in/out
    intra = 2.0 * L * N + 2.0 * L * H * P
    inter = 4.0 * H * P * N
    return proj + intra + inter


def _layer_fwd_flops(cfg, ctx_len: float) -> float:
    fam = cfg.family
    if fam in ("dense", "vlm", "encdec"):
        return (_attn_proj_flops(cfg) + _attn_score_flops(cfg, ctx_len)
                + _mlp_flops(cfg))
    if fam == "moe":
        return (_attn_proj_flops(cfg) + _attn_score_flops(cfg, ctx_len)
                + _moe_flops(cfg))
    if fam == "ssm":
        return _mamba1_flops(cfg)
    if fam == "hybrid":
        return _mamba2_flops(cfg, cfg.ssm_chunk)
    raise ValueError(fam)


def _shared_block_flops(cfg, ctx_len: float) -> float:
    return (_attn_proj_flops(cfg) + _attn_score_flops(cfg, ctx_len)
            + _mlp_flops(cfg))


def trunk_fwd_flops(cfg, tokens: float, ctx_len: float) -> float:
    """Whole trunk, forward, `tokens` total tokens at effective context."""
    per = _layer_fwd_flops(cfg, ctx_len)
    total = cfg.n_layers * per * tokens
    if cfg.family == "hybrid":
        total += cfg.n_attn_apps * _shared_block_flops(cfg, ctx_len) * tokens
    if cfg.family == "encdec":
        # cross attention (full F context) + encoder trunk on frame tokens
        total += cfg.n_layers * tokens * (
            2.0 * cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd
            + _attn_score_flops(cfg, cfg.enc_frames))
        frames_tokens = tokens / max(1, 1) * 0  # added separately below
        del frames_tokens
    return total


def _encoder_flops(cfg, batch: int) -> float:
    if cfg.family != "encdec":
        return 0.0
    ftok = batch * cfg.enc_frames
    per = (_attn_proj_flops(cfg) + _attn_score_flops(cfg, cfg.enc_frames)
           + _mlp_flops(cfg))
    return cfg.n_enc_layers * per * ftok


def _logit_flops(cfg, tokens: float) -> float:
    return 2.0 * cfg.d_model * cfg.vocab * tokens


# --------------------------------------------------------------------------- #
# Cell-level model
# --------------------------------------------------------------------------- #
def cell_cost(cfg: ArchConfig, shape, *, n_chips: int, dp: int, tp: int,
              multi_pod: bool, pcfg=None, microbatches: int = 8,
              grad_accum: int = 2) -> CellCost:
    B, S = shape.batch, shape.seq
    fam = cfg.family
    wbytes_total = cfg.param_count() * 2.0       # bf16 weights

    if shape.kind == "decode":
        T = float(B)                             # one token per sequence
        ctx = float(S)
        fwd = trunk_fwd_flops(cfg, T, ctx) + _logit_flops(cfg, T)
        flops = fwd
        # HBM: weights once + caches read(+write tail)
        cache_bytes = _cache_bytes(cfg, B, S)
        hbm_dev = (wbytes_total / tp + cache_bytes / n_chips * 2.05
                   + 3 * 4 * T * cfg.vocab / n_chips)
        # wire: 2 TP psums per layer of (B/dp,1,D)
        psum = _ar_wire(B / dp * cfg.d_model * 2, tp)
        wire_ici = 2 * cfg.n_layers * psum
        wire_dcn = 0.0
        if multi_pod and pcfg is not None:
            K = pcfg.n_stages
            wire_dcn = K * (B / dp * cfg.d_model * 2 / tp)   # tick ppermutes
        return CellCost(flops, hbm_dev, wire_ici, wire_dcn)

    tokens = float(B) * S
    ctx = (S + cfg.attn_chunk) / 2.0 if S > cfg.attn_chunk else (S + 1) / 2.0
    trunk = trunk_fwd_flops(cfg, tokens, ctx) + _encoder_flops(cfg, B)
    heads = _logit_flops(cfg, tokens)

    if shape.kind == "prefill":
        flops = trunk + heads / S  # only last-position logits
        act_layer = tokens * cfg.d_model * 2.0
        hbm_dev = (wbytes_total / tp
                   + cfg.n_layers * act_layer * 2 / n_chips
                   + _cache_bytes(cfg, B, S) / n_chips)
        psum = _ar_wire(tokens / dp * cfg.d_model * 2, tp)
        wire_ici = 2 * cfg.n_layers * psum
        wire_dcn = 0.0
        if multi_pod and pcfg is not None:
            wire_dcn = pcfg.n_stages * tokens / dp * cfg.d_model * 2 / tp
        return CellCost(flops, hbm_dev, wire_ici, wire_dcn)

    # ---- training ------------------------------------------------------ #
    remat = 1.0 if cfg.remat else 0.0
    waste = 1.0
    bubble = 1.0
    if multi_pod and pcfg is not None:
        K, M = pcfg.n_stages, pcfg.microbatches
        _, _, l_max = pcfg.layout(cfg.n_layers)
        # every pod runs l_max (padded) layers every tick, incl. bubble
        waste = (K * l_max * (M + K - 1)) / (cfg.n_layers * M)
        bubble = (M + K - 1) / M
    flops = trunk * (3.0 + remat) * waste + heads * 3.0 \
        + cfg.param_count() * 12.0               # optimizer
    # HBM/device: weights ×(3+remat) passes + optimizer 22B/param +
    # saved layer inputs (write+read) + logits fp32 ×3.
    # seq_parallel shards saved residuals over 'model' (already counted by
    # /n_chips); without it they'd replicate over model (×tp).
    ga = max(grad_accum, 1) if not multi_pod else 1
    params_dev = cfg.param_count() / tp
    sp = 1.0 if cfg.seq_parallel else float(tp)
    act_saved = cfg.n_layers * tokens * cfg.d_model * 2.0 * 2 / n_chips * sp
    # chunked CE re-streams the head weights once per chunk but bounds the
    # fp32 logits residency; traffic ≈ logits once + head reads
    logits_b = 3.0 * 4.0 * tokens * cfg.vocab / n_chips
    # grad_accum re-streams weights per microbatch and adds an fp32 grad
    # accumulator read/write per microbatch
    hbm_dev = (params_dev * 2 * (3 + remat) * ga + params_dev * 22
               + params_dev * 8 * (ga - 1)
               + act_saved + logits_b)
    # wire: TP psums (≈6/layer incl bwd ×(1+remat/2)) + DP grad all-reduce
    psum = _ar_wire(tokens / dp * cfg.d_model * 2, tp)
    wire_ici = 6 * cfg.n_layers * psum * (1 + 0.5 * remat) \
        + _ar_wire(cfg.param_count() * 2 / tp, dp)
    if fam == "moe":
        # dispatch+combine a2a ×3 passes of the capacity buffer
        buf = tokens * cfg.top_k * cfg.capacity_factor * cfg.d_model * 2
        wire_ici += 3 * _ag_wire(buf / dp, tp) * 2
    wire_dcn = 0.0
    if multi_pod and pcfg is not None:
        K, M = pcfg.n_stages, pcfg.microbatches
        ticks = M + K - 1
        mb_bytes = tokens / M / dp * cfg.d_model * 2 / max(tp // tp, 1)
        wire_dcn = 3.0 * ticks * mb_bytes       # fwd + bwd(2×) ppermutes
    return CellCost(flops, hbm_dev, wire_ici, wire_dcn)


def _cache_bytes(cfg, B, S) -> float:
    if cfg.family in ("dense", "vlm", "moe"):
        return 2.0 * cfg.n_layers * B * S * cfg.n_kv_heads * cfg.hd * 2
    if cfg.family == "encdec":
        return 2.0 * cfg.n_layers * B * (S + cfg.enc_frames) \
            * cfg.n_kv_heads * cfg.hd * 2
    if cfg.family == "ssm":
        return cfg.n_layers * B * (cfg.d_inner * cfg.ssm_state * 4
                                   + (cfg.ssm_conv - 1) * cfg.d_inner * 2)
    if cfg.family == "hybrid":
        ssm = cfg.n_layers * B * (cfg.ssm_heads * cfg.ssm_head_dim
                                  * cfg.ssm_state * 4
                                  + (cfg.ssm_conv - 1)
                                  * (cfg.d_inner + 2 * cfg.ssm_state) * 2)
        attn = 2.0 * cfg.n_attn_apps * B * S * cfg.n_kv_heads * cfg.hd * 2
        return ssm + attn
    raise ValueError(cfg.family)
