"""Production meshes.

``make_production_mesh`` is a function (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
jax import, and everything else must see the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_pods: int = 1, data: int = 2, model: int = 2):
    """Small mesh over forced host devices (tests/examples)."""
    if n_pods > 1:
        return jax.make_mesh((n_pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
