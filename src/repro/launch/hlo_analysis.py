"""Post-optimization HLO analysis: collective inventory and wire bytes.

``compiled.cost_analysis()`` has no collective traffic, so we parse the
optimized HLO text: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, its result bytes, its group size, and
whether the group crosses a pod boundary (DCN) or stays inside (ICI).

Wire-byte model per device (ring/bidirectional algorithms):
  all-gather       T·(s-1)/s        (T = full gathered tensor = result)
  reduce-scatter   T_in·(s-1)/s     (T_in = s · result)
  all-reduce       2·T·(s-1)/s      (RS + AG over the full tensor)
  all-to-all       T·(s-1)/s
  collective-permute  T             (point-to-point)
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\(?[^=]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{([^}]*(?:\},\{[^}]*)*)\}")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    crosses_pod: bool
    wire_bytes: int      # per-device wire traffic


@dataclass
class CollectiveSummary:
    ops: list[CollectiveOp] = field(default_factory=list)

    @property
    def total_bytes(self) -> int:
        return sum(o.result_bytes for o in self.ops)

    @property
    def wire_bytes_ici(self) -> int:
        return sum(o.wire_bytes for o in self.ops if not o.crosses_pod)

    @property
    def wire_bytes_dcn(self) -> int:
        return sum(o.wire_bytes for o in self.ops if o.crosses_pod)

    def by_kind(self) -> dict[str, dict]:
        out: dict[str, dict] = {}
        for o in self.ops:
            d = out.setdefault(o.kind, {"count": 0, "bytes": 0, "wire": 0})
            d["count"] += 1
            d["bytes"] += o.result_bytes
            d["wire"] += o.wire_bytes
        return out


def _group_info(line: str, pod_size: int) -> tuple[int, bool]:
    """→ (group_size, crosses_pod)."""
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        n_groups, gsize, total = map(int, m.groups())
        # iota groups [G,S]<=[N](perm): group g = consecutive-in-permuted
        # order; detect pod crossing via stride: T(1,0) style transposes
        # interleave pods.  Conservative: a group crosses pods iff its
        # span in raw ids can exceed pod_size.
        crosses = gsize > 1 and (total > pod_size) and (
            "T(" in line or gsize * n_groups > pod_size or gsize > pod_size)
        # refine: contiguous groups entirely inside one pod
        if "T(" not in line and gsize <= pod_size and pod_size % gsize == 0:
            crosses = False
        return gsize, crosses
    m = _LIST_GROUPS_RE.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x.strip()]
        pods = {i // pod_size for i in ids}
        return max(len(ids), 1), len(pods) > 1
    m = _PAIRS_RE.search(line)
    if m:
        pairs = re.findall(r"(\d+),(\d+)", m.group(1))
        crosses = any(int(a) // pod_size != int(b) // pod_size
                      for a, b in pairs)
        return 2, crosses
    return 1, False


def _wire_bytes(kind: str, result_bytes: int, s: int) -> int:
    if s <= 1:
        return 0
    if kind == "all-gather":
        return int(result_bytes * (s - 1) / s)
    if kind == "reduce-scatter":
        return int(result_bytes * (s - 1))
    if kind == "all-reduce":
        return int(2 * result_bytes * (s - 1) / s)
    if kind == "all-to-all":
        return int(result_bytes * (s - 1) / s)
    if kind == "collective-permute":
        return result_bytes
    return result_bytes


def parse_collectives(hlo_text: str, pod_size: int) -> CollectiveSummary:
    summ = CollectiveSummary()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if ".done" in line or "-done" in line.split("=")[1][:40]:
            continue   # async pairs: count the -start only
        result_txt, kind = m.group(1), m.group(2)
        rb = _shape_bytes(result_txt)
        if rb == 0:
            continue
        gsize, crosses = _group_info(line, pod_size)
        summ.ops.append(CollectiveOp(
            kind=kind, result_bytes=rb, group_size=gsize,
            crosses_pod=crosses,
            wire_bytes=_wire_bytes(kind, rb, gsize)))
    return summ
