"""Generate EXPERIMENTS.md sections from dry-run manifests.

    PYTHONPATH=src python -m repro.launch.report \
        --runs runs/dryrun --baseline runs/dryrun_baseline_v0
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def load(d: Path) -> dict:
    recs = {}
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_s(x: float) -> str:
    return f"{x*1e3:.1f}ms" if x < 10 else f"{x:.1f}s"


def dryrun_table(recs: dict) -> str:
    lines = ["| arch | shape | mesh | status | compile | peak GB/dev | "
             "collectives (per scan iter) |",
             "|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(recs.items()):
        if r["status"] == "skipped":
            lines.append(f"| {a} | {s} | {m} | SKIP | — | — | "
                         f"{r.get('reason','')[:60]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {a} | {s} | {m} | **FAIL** | — | — | "
                         f"{r.get('error','')[:60]} |")
            continue
        coll = ", ".join(f"{k}×{v['count']}"
                         for k, v in sorted(r["collectives"].items()))
        lines.append(
            f"| {a} | {s} | {m} | ok | {r['compile_s']}s | "
            f"{r['memory']['peak_mb']/1000:.1f} | {coll or '—'} |")
    return "\n".join(lines)


def roofline_table(recs: dict) -> str:
    lines = ["| arch | shape | compute | memory | collective | dominant | "
             "bound | MFU-bound | useful |",
             "|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(recs.items()):
        if m != "16x16" or r["status"] != "ok":
            continue
        rl = r["roofline"]
        lines.append(
            f"| {a} | {s} | {fmt_s(rl['compute_s'])} | "
            f"{fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} | "
            f"**{rl['dominant']}** | {fmt_s(rl['step_bound_s'])} | "
            f"{rl['mfu_bound']*100:.0f}% | {rl['useful_ratio']:.2f} |")
    return "\n".join(lines)


def perf_compare(base: dict, cur: dict) -> str:
    lines = ["| cell | peak GB/dev before → after | bound before → after |",
             "|---|---|---|"]
    for key in sorted(cur):
        b, c = base.get(key), cur[key]
        if not b or b.get("status") != "ok" or c.get("status") != "ok":
            continue
        pb = b["memory"]["peak_mb"] / 1000
        pc = c["memory"]["peak_mb"] / 1000
        if abs(pb - pc) / max(pb, 0.01) < 0.05:
            continue
        lines.append(
            f"| {key[0]} {key[1]} {key[2]} | {pb:.1f} → {pc:.1f} "
            f"({pc/pb-1:+.0%}) | {fmt_s(b['roofline']['step_bound_s'])} → "
            f"{fmt_s(c['roofline']['step_bound_s'])} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", default="runs/dryrun")
    ap.add_argument("--baseline", default="")
    args = ap.parse_args()
    cur = load(Path(args.runs))
    n_ok = sum(r["status"] == "ok" for r in cur.values())
    n_skip = sum(r["status"] == "skipped" for r in cur.values())
    n_fail = len(cur) - n_ok - n_skip
    print(f"### Cells: {len(cur)} total — {n_ok} ok / {n_skip} skipped / "
          f"{n_fail} failed\n")
    print("## §Dry-run\n")
    print(dryrun_table(cur))
    print("\n## §Roofline (single-pod 16×16, per device)\n")
    print(roofline_table(cur))
    if args.baseline:
        base = load(Path(args.baseline))
        print("\n## §Perf: baseline → optimized (cells that moved ≥5%)\n")
        print(perf_compare(base, cur))


if __name__ == "__main__":
    main()
