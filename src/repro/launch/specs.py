"""Cell definitions (arch × input-shape) and ShapeDtypeStruct inputs.

The 4 assigned LM shapes; ``long_500k`` is decode-only and runs only for
sub-quadratic archs (SSM/hybrid) — pure full-attention archs skip it
(DESIGN.md §4).  All specs carry NamedShardings so ``jit(...).lower()``
needs no separate in_shardings.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..data.pipeline import make_batch_specs
from ..models.config import ArchConfig
from ..runtime.pipeline import PipelineConfig, build_pipeline_params
from ..sharding.api import MeshContext
from ..models import lm
from ..models.common import AbstractBuilder, DTYPES


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq: int
    batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, ("skip: pure full-attention arch at 524k context "
                       "(sub-quadratic required; see DESIGN.md §4)")
    return True, ""


# --------------------------------------------------------------------------- #
# Param / optimizer / cache specs
# --------------------------------------------------------------------------- #
def param_specs(cfg: ArchConfig, ctx: MeshContext | None,
                pcfg: PipelineConfig | None = None):
    b = AbstractBuilder(ctx, DTYPES[cfg.dtype])
    if pcfg is not None:
        return build_pipeline_params(cfg, b, pcfg)
    return lm.build_params(cfg, b)


def train_state_specs(cfg: ArchConfig, ctx: MeshContext | None,
                      pcfg: PipelineConfig | None = None):
    from ..sharding.api import zero1_spec
    from jax.sharding import NamedSharding
    params = param_specs(cfg, ctx, pcfg)

    def f32_zero1(s):
        """Optimizer moments: fp32, param sharding + 'data' (ZeRO-1)."""
        sh = getattr(s, "sharding", None)
        if ctx is not None and sh is not None:
            sh = NamedSharding(ctx.mesh, zero1_spec(sh.spec, s.shape))
        return jax.ShapeDtypeStruct(s.shape, jnp.float32, sharding=sh)

    scalar = jax.ShapeDtypeStruct((), jnp.int32,
                                  sharding=None if ctx is None
                                  else ctx.sharding(()))
    return {"params": params,
            "opt": {"m": jax.tree.map(f32_zero1, params),
                    "v": jax.tree.map(f32_zero1, params),
                    "count": scalar},
            "step": scalar}


def _sds(ctx, shape, dtype, axes):
    if ctx is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=ctx.sharding(axes, shape))


def cache_specs(cfg: ArchConfig, B: int, S: int, ctx: MeshContext | None,
                pcfg: PipelineConfig | None = None):
    """Decode-input cache pytree (matches trunk_prefill / pipeline prefill
    output layouts)."""
    dt = DTYPES[cfg.dtype]
    KV, hd = cfg.n_kv_heads, cfg.hd

    if pcfg is None:
        lead, lead_ax = (cfg.n_layers,), ("layers",)
    else:
        _, _, l_max = pcfg.layout(cfg.n_layers)
        lead, lead_ax = (pcfg.n_stages, l_max), ("stage", "layers")

    def kv_axes():
        if ctx is not None and KV and KV % max(ctx.size("model"), 1) != 0:
            return (*lead_ax, "batch", "seq_model", "kv_heads", "head_dim")
        return (*lead_ax, "batch", "seq", "kv_heads", "head_dim")

    pos = _sds(ctx, (), jnp.int32, ())
    if cfg.family in ("dense", "vlm", "moe"):
        kshape = (*lead, B, S, KV, hd)
        return {"k": _sds(ctx, kshape, dt, kv_axes()),
                "v": _sds(ctx, kshape, dt, kv_axes()), "pos": pos}
    if cfg.family == "encdec":
        kshape = (*lead, B, S, KV, hd)
        cshape = (*lead, B, cfg.enc_frames, KV, hd)
        cax = (*lead_ax, "batch", "frames", "kv_heads", "head_dim")
        return {"k": _sds(ctx, kshape, dt, kv_axes()),
                "v": _sds(ctx, kshape, dt, kv_axes()),
                "ck": _sds(ctx, cshape, dt, cax),
                "cv": _sds(ctx, cshape, dt, cax), "pos": pos}
    if cfg.family == "ssm":
        return {"conv": _sds(ctx, (*lead, B, cfg.ssm_conv - 1, cfg.d_inner), dt,
                             (*lead_ax, "batch", "kernel", "d_inner")),
                "h": _sds(ctx, (*lead, B, cfg.d_inner, cfg.ssm_state),
                          jnp.float32,
                          (*lead_ax, "batch", "d_inner", "state")),
                "pos": pos}
    if cfg.family == "hybrid":
        from ..runtime.pipeline import n_attn_slots
        d_xbc = cfg.d_inner + 2 * cfg.ssm_state
        if pcfg is None:
            a_lead, a_lead_ax = (cfg.n_attn_apps,), ("layers",)
        else:
            a_lead = (pcfg.n_stages, n_attn_slots(cfg, lead[-1]))
            a_lead_ax = ("stage", "layers")
        return {"conv": _sds(ctx, (*lead, B, cfg.ssm_conv - 1, d_xbc), dt,
                             (*lead_ax, "batch", "kernel", "conv_dim")),
                "h": _sds(ctx, (*lead, B, cfg.ssm_heads, cfg.ssm_head_dim,
                                cfg.ssm_state), jnp.float32,
                          (*lead_ax, "batch", "ssm_heads", "head_dim", "state")),
                "ak": _sds(ctx, (*a_lead, B, S, KV, hd), dt,
                           (*a_lead_ax, "batch", "seq", "kv_heads", "head_dim")),
                "av": _sds(ctx, (*a_lead, B, S, KV, hd), dt,
                           (*a_lead_ax, "batch", "seq", "kv_heads", "head_dim")),
                "pos": pos}
    raise ValueError(cfg.family)


def input_specs(cfg: ArchConfig, shape_name: str, ctx: MeshContext | None,
                pcfg: PipelineConfig | None = None) -> dict:
    """All jit inputs for the cell's step function, as ShapeDtypeStructs.

    train  → {"state": ..., "batch": ...}
    prefill→ {"params": ..., "inputs": ...}
    decode → {"params": ..., "token": ..., "cache": ...}
    """
    sh = SHAPES[shape_name]
    if sh.kind == "train":
        return {"state": train_state_specs(cfg, ctx, pcfg),
                "batch": make_batch_specs(cfg, sh.batch, sh.seq, ctx, "train")}
    if sh.kind == "prefill":
        return {"params": param_specs(cfg, ctx, pcfg),
                "inputs": make_batch_specs(cfg, sh.batch, sh.seq, ctx,
                                           "prefill")}
    # decode: one new token against a cache of sh.seq
    tok = _sds(ctx, (sh.batch, 1), jnp.int32, ("batch", "seq"))
    return {"params": param_specs(cfg, ctx, pcfg),
            "token": tok,
            "cache": cache_specs(cfg, sh.batch, sh.seq, ctx, pcfg)}
