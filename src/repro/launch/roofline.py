"""Roofline-term computation from dry-run artifacts.

Hardware constants (assignment): TPU v5e — 197 TFLOP/s bf16 per chip,
819 GB/s HBM per chip, ~50 GB/s/link ICI.  DCN egress per chip is not
given; we assume 6.25 GB/s/chip (ICI/8, typical for pod-to-pod fabrics)
and record the assumption here.

All inputs are **per-device** quantities (XLA's cost_analysis and
memory_analysis are per-device programs under SPMD — verified in tests):

  compute term    = flops_per_dev / PEAK_FLOPS
  memory term     = bytes_per_dev / HBM_BW
  collective term = wire_ici_per_dev / ICI_BW + wire_dcn_per_dev / DCN_BW
"""
from __future__ import annotations

from dataclasses import dataclass

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
DCN_BW = 6.25e9              # bytes/s per chip across pods (assumption)


@dataclass(frozen=True)
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_per_dev: float      # 6·N·D (or 2·N·D inference) / chips
    hlo_flops_per_dev: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower bound on step time = max of the three terms (perfect
        overlap assumption)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS — how much compiled compute is 'useful'
        (catches remat/causal-waste/dispatch overheads)."""
        if self.hlo_flops_per_dev == 0:
            return 0.0
        return self.model_flops_per_dev / self.hlo_flops_per_dev

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization *if* the step ran at the roofline bound
        (the score we hillclimb): model_flops / (peak · step_time)."""
        t = self.step_time_s
        if t == 0:
            return 0.0
        return self.model_flops_per_dev / (PEAK_FLOPS * t)


def roofline_from(flops_per_dev: float, bytes_per_dev: float,
                  wire_ici_per_dev: float, wire_dcn_per_dev: float,
                  model_flops_total: float, n_chips: int) -> Roofline:
    return Roofline(
        compute_s=flops_per_dev / PEAK_FLOPS,
        memory_s=bytes_per_dev / HBM_BW,
        collective_s=wire_ici_per_dev / ICI_BW + wire_dcn_per_dev / DCN_BW,
        model_flops_per_dev=model_flops_total / n_chips,
        hlo_flops_per_dev=flops_per_dev,
    )


def model_flops(cfg, shape) -> float:
    """6·N·D for training, 2·N·D for inference forward (N = active params
    for MoE); D = tokens processed by the step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.batch * shape.seq
    if shape.kind == "prefill":
        return 2.0 * n * shape.batch * shape.seq
    return 2.0 * n * shape.batch  # decode: one token per sequence
