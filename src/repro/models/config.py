"""Architecture configuration — one dataclass covers all assigned families.

Families: dense | moe | ssm | hybrid | encdec | vlm.  The per-arch files in
``repro.configs`` instantiate these with the published values.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int | None = None         # default d_model // n_heads
    qk_norm: bool = False               # qwen3
    gated_mlp: bool = True              # SwiGLU (False → GELU 2-matmul, starcoder2/granite)
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 4096          # router/dispatch group (tokens)
    moe_gshard_group: int = 128         # group for the einsum (gshard) path
    moe_impl: str = "sort"              # "sort" (gathers) | "gshard" (einsums)
    # "ep": experts sharded over 'model' (GSPMD gather-partitioned dispatch)
    # "etp": each expert's FFN sharded over 'model' (used in pipeline mode,
    #        where GSPMD's gather partitioner aborts under manual meshes)
    moe_shard: str = "ep"

    # SSM (mamba1: falcon-mamba; mamba2: zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2                 # d_inner = expand * d_model
    ssm_conv: int = 4
    ssm_dt_rank: int = 0                # mamba1; default d_model/16
    ssm_head_dim: int = 64              # mamba2
    ssm_chunk: int = 256                # chunked-scan chunk length

    # hybrid (zamba2): one shared attention block applied every k layers
    shared_attn_every: int = 0

    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_frames: int = 1500              # stub conv frontend output length

    # vlm (phi-3-vision): stub patch embeddings prepended to the sequence
    n_patches: int = 0

    # numerics / training
    dtype: str = "bfloat16"
    remat: bool = True
    # attention implementation: "xla" (chunked pure-jnp; what dry-runs lower)
    # or "pallas" (TPU kernels; validated in interpret mode in tests)
    attn_impl: str = "xla"
    attn_chunk: int = 2048              # kv-chunk for the xla chunked attention
    # §Perf iteration 1/2 (EXPERIMENTS.md): Megatron-style sequence-parallel
    # residual stream + seq-chunked cross-entropy
    seq_parallel: bool = True
    ce_chunk: int = 1024                # tokens per CE chunk (0 = full)

    # ------------------------------------------------------------------ #
    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(self.d_model // 16, 1)

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def n_attn_apps(self) -> int:
        """Hybrid: number of shared-attention applications."""
        if self.shared_attn_every <= 0:
            return 0
        return -(-self.n_layers // self.shared_attn_every)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic (SSM/hybrid) archs run long_500k; pure
        full-attention archs skip it (see DESIGN.md §Arch-applicability)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------ #
    def param_count(self) -> int:
        """Analytic parameter count (exact for our implementation)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        H, KV, hd = self.n_heads, self.n_kv_heads, self.hd

        def attn() -> int:
            qn = 2 * hd if self.qk_norm else 0
            return D * H * hd + 2 * D * KV * hd + H * hd * D + qn

        def mlp_dense(f: int) -> int:
            return (3 if self.gated_mlp else 2) * D * f

        def mamba1() -> int:
            di, N, R = self.d_inner, self.ssm_state, self.dt_rank
            return (D * 2 * di + di * self.ssm_conv + di
                    + di * (R + 2 * N) + R * di + di  # x_proj, dt_proj(+bias)
                    + di * N + di                     # A_log, D
                    + di * D)                         # out_proj
        def mamba2() -> int:
            di, N, Hs = self.d_inner, self.ssm_state, self.ssm_heads
            ng = 1  # single B/C group
            d_xbc = di + 2 * ng * N
            return (D * (2 * di + 2 * ng * N + Hs)    # in_proj → z,x,B,C,dt
                    + d_xbc * self.ssm_conv + d_xbc   # conv
                    + Hs + Hs + Hs                    # A_log, D, dt_bias
                    + di + di * D)                    # gated rmsnorm, out_proj

        emb = V * D
        head = 0 if self.tie_embeddings else D * V
        norms2 = 2 * D   # per layer: 2 pre-norms (attn+mlp families)

        if self.family in ("dense", "vlm"):
            per = attn() + mlp_dense(F) + norms2
            return emb + head + self.n_layers * per + D
        if self.family == "moe":
            per = attn() + self.n_experts * 3 * D * F + D * self.n_experts + norms2
            return emb + head + self.n_layers * per + D
        if self.family == "ssm":
            per = mamba1() + D  # single pre-norm
            return emb + head + self.n_layers * per + D
        if self.family == "hybrid":
            per = mamba2() + D
            shared = attn() + mlp_dense(F) + norms2
            return emb + head + self.n_layers * per + shared + D
        if self.family == "encdec":
            enc_per = attn() + mlp_dense(F) + norms2
            dec_per = 2 * attn() + mlp_dense(F) + 3 * D
            return (emb + head + self.n_enc_layers * enc_per
                    + self.n_layers * dec_per + 2 * D)
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """MoE: params touched per token (for MODEL_FLOPS = 6·N_active·D)."""
        if self.family != "moe":
            return self.param_count()
        D, F = self.d_model, self.d_ff
        per = (self.param_count() - self.n_layers * self.n_experts * 3 * D * F
               ) + self.n_layers * self.top_k * 3 * D * F
        return per
