"""Shared model machinery: param builders, norms, RoPE, embeddings, loss.

**Builder pattern** — every weight is declared exactly once, via a
``Builder`` callback that receives (path, shape, logical_axes, init).
Three builders consume the same declarations:

  * ``InitBuilder``     → real arrays (deterministic per-path keys),
  * ``AbstractBuilder`` → ``jax.ShapeDtypeStruct`` with NamedSharding
                          attached (the dry-run never materializes params),
  * ``SpecBuilder``     → ``PartitionSpec`` pytree (checkpointing, docs).

This guarantees the dry-run, the runtime, and the checkpointer always
agree about shapes and shardings.
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding.api import MeshContext, get_context, shard

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
          "float16": jnp.float16}


class Builder:
    dtype = jnp.bfloat16

    def leaf(self, path: str, shape: tuple[int, ...], axes: tuple, *,
             init: str | Callable = "normal", scale: float | None = None,
             dtype=None):
        raise NotImplementedError


class InitBuilder(Builder):
    def __init__(self, key, dtype=jnp.bfloat16):
        self.key = key
        self.dtype = dtype

    def leaf(self, path, shape, axes, *, init="normal", scale=None, dtype=None):
        dtype = dtype or self.dtype
        k = jax.random.fold_in(self.key, int(np.uint32(hash(path) & 0x7FFFFFFF)))
        if callable(init):
            return init(k, shape, dtype)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "normal":
            if scale is None:
                fan_in = shape[0] if len(shape) >= 2 else shape[-1]
                scale = 1.0 / math.sqrt(max(fan_in, 1))
            return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)
        raise ValueError(init)


class AbstractBuilder(Builder):
    """ShapeDtypeStructs with shardings — feeds ``jit(...).lower()``."""

    def __init__(self, ctx: MeshContext | None, dtype=jnp.bfloat16):
        self.ctx = ctx
        self.dtype = dtype

    def leaf(self, path, shape, axes, *, init="normal", scale=None, dtype=None):
        dtype = dtype or self.dtype
        if self.ctx is None:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=self.ctx.sharding(axes, shape))


class SpecBuilder(Builder):
    def __init__(self, ctx: MeshContext):
        self.ctx = ctx

    def leaf(self, path, shape, axes, *, init="normal", scale=None, dtype=None):
        return self.ctx.spec(axes, shape)


# --------------------------------------------------------------------------- #
# Normalization / activations (fp32 internals, cast back)
# --------------------------------------------------------------------------- #
def rms_norm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x.astype(jnp.float32)).astype(x.dtype)


def gelu(x):
    return jax.nn.gelu(x)


def softplus(x):
    return jax.nn.softplus(x)


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #
def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# Embedding / head / loss
# --------------------------------------------------------------------------- #
def embed_params(b: Builder, cfg, prefix: str = "embed"):
    p = {"table": b.leaf(f"{prefix}.table", (cfg.vocab, cfg.d_model),
                         ("vocab", "embed"), scale=0.02)}
    if not cfg.tie_embeddings:
        p_head = b.leaf("lm_head.w", (cfg.d_model, cfg.vocab),
                        ("embed", "vocab"))
        return p, {"w": p_head}
    return p, None


def embed_lookup(table, tokens):
    y = jnp.take(table, tokens, axis=0)
    return shard(y, "batch", "seq", "embed")


def lm_logits(x, embed, head):
    """x: (B, S, D) → (B, S, V), fp32 for the loss."""
    w = head["w"] if head is not None else embed["table"].T
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return shard(logits, "batch", "seq", "vocab")


def chunked_cross_entropy(x, embed, head, targets, chunk: int):
    """CE without materializing the full (B, S, V) fp32 logits: scan over
    seq chunks; the chunk body is rematerialized in the backward pass so
    peak logits memory is (B, chunk, V) (§Perf iteration 2).

    x: (B, S, D) final hidden; targets: (B, S) → scalar mean loss."""
    import jax

    B, S, D = x.shape
    if chunk <= 0 or S <= chunk or S % chunk != 0:
        return cross_entropy(lm_logits(x, embed, head), targets)
    n = S // chunk
    xs = x.reshape(B, n, chunk, D).swapaxes(0, 1)
    ts = targets.reshape(B, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(acc, xt):
        xc, tc = xt
        logits = lm_logits(xc, embed, head)
        m = jnp.max(logits, axis=-1, keepdims=True)
        lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
        lab = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - lab), None

    import jax.lax as lax
    total, _ = lax.scan(body, jnp.zeros((), jnp.float32), (xs, ts))
    return total / (B * S)


def cross_entropy(logits, labels, mask=None):
    """logits: (B, S, V) fp32 (possibly vocab-sharded); labels: (B, S)."""
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    lab = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - lab
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
