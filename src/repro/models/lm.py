"""Unified LM: dense / MoE / SSM / hybrid / VLM decoders + whisper enc-dec.

Layers are *scanned*: per-layer params are stacked on a leading ``layers``
axis and the forward pass is one ``lax.scan`` whose body is the layer —
HLO size and compile time are O(1) in depth, which is what makes 80-layer
× 512-device dry-runs tractable.  ``cfg.remat`` wraps the scan body in
``jax.checkpoint`` for training.

Caches (serving):
  dense/moe/vlm : {"k","v": (L,B,Smax,KV,hd), "pos"}
  ssm           : {"conv": (L,B,K-1,di), "h": (L,B,di,N), "pos"}
  hybrid        : ssm fields (mamba2 shapes) + {"ak","av": (A,B,Smax,KV,hd)}
  encdec        : dense fields + {"ck","cv": (L,B,F,KV,hd)} cross-attn
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from ..sharding.api import kv_cache_names, shard
from .attention import (attend_decode, attend_prefill, attn_params,
                        cache_update, o_project, qkv_project)
from .common import (Builder, embed_lookup, embed_params, layer_norm,
                     lm_logits, rms_norm)
from .mlp import mlp, mlp_params, moe_mlp, moe_params
from .ssm import mamba1_block, mamba1_params, mamba2_block, mamba2_params


class StackedBuilder(Builder):
    """Prefix every leaf with a ``layers`` axis of size n."""

    def __init__(self, base: Builder, n: int):
        self.base, self.n = base, n
        self.dtype = base.dtype

    def leaf(self, path, shape, axes, *, init="normal", scale=None, dtype=None):
        if init == "normal" and scale is None:
            fan_in = shape[0] if len(shape) >= 2 else shape[-1]
            scale = 1.0 / math.sqrt(max(fan_in, 1))
        if callable(init):
            orig = init
            init = lambda k, s, d: jnp.broadcast_to(orig(k, s[1:], d), s)
        return self.base.leaf(path, (self.n, *shape), ("layers", *axes),
                              init=init, scale=scale, dtype=dtype)


# --------------------------------------------------------------------------- #
# Per-layer param defs
# --------------------------------------------------------------------------- #
def _norm_params(b, prefix, d, bias=False):
    p = {"scale": b.leaf(f"{prefix}.scale", (d,), ("embed",), init="ones")}
    if bias:
        p["bias"] = b.leaf(f"{prefix}.bias", (d,), ("embed",), init="zeros")
    return p


def _attn_block_params(b, cfg, prefix, with_mlp=True, bias_norm=False):
    p = {"ln1": _norm_params(b, f"{prefix}.ln1", cfg.d_model, bias_norm),
         "attn": attn_params(b, cfg, f"{prefix}.attn")}
    if with_mlp:
        p["ln2"] = _norm_params(b, f"{prefix}.ln2", cfg.d_model, bias_norm)
        p["mlp"] = mlp_params(b, cfg, f"{prefix}.mlp")
    return p


def layer_params(cfg, b: Builder) -> dict:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return _attn_block_params(b, cfg, "layer")
    if fam == "moe":
        return {"ln1": _norm_params(b, "layer.ln1", cfg.d_model),
                "attn": attn_params(b, cfg, "layer.attn"),
                "ln2": _norm_params(b, "layer.ln2", cfg.d_model),
                "moe": moe_params(b, cfg, "layer.moe")}
    if fam == "ssm":
        return {"ln": _norm_params(b, "layer.ln", cfg.d_model),
                "mamba": mamba1_params(b, cfg, "layer.mamba")}
    if fam == "hybrid":
        return {"ln": _norm_params(b, "layer.ln", cfg.d_model),
                "mamba": mamba2_params(b, cfg, "layer.mamba")}
    raise ValueError(fam)


def build_params(cfg, b: Builder) -> dict:
    embed, head = embed_params(b, cfg)
    params: dict = {"embed": embed,
                    "final_norm": _norm_params(b, "final_norm", cfg.d_model,
                                               cfg.family == "encdec")}
    if head is not None:
        params["lm_head"] = head

    if cfg.family == "encdec":
        enc = StackedBuilder(b, cfg.n_enc_layers)
        dec = StackedBuilder(b, cfg.n_layers)
        params["enc_layers"] = _attn_block_params(enc, cfg, "enc", bias_norm=True)
        params["dec_layers"] = {
            **_attn_block_params(dec, cfg, "dec", bias_norm=True),
            "ln_x": _norm_params(dec, "dec.ln_x", cfg.d_model, True),
            "xattn": attn_params(dec, cfg, "dec.xattn")}
        params["enc_final_norm"] = _norm_params(b, "enc_final_norm",
                                                cfg.d_model, True)
        return params

    sb = StackedBuilder(b, cfg.n_layers)
    params["layers"] = layer_params(cfg, sb)
    if cfg.family == "hybrid":
        params["shared"] = _attn_block_params(b, cfg, "shared")
    return params


# --------------------------------------------------------------------------- #
# Blocks
# --------------------------------------------------------------------------- #
def _attn_mlp_block(cfg, p, x, positions, *, kv_cache=None, pos=None,
                    bias_norm=False, rope=True):
    """Standard transformer block.  Returns (x, new_kv or (k, v))."""
    norm = (lambda t, q: layer_norm(t, q["scale"], q["bias"], cfg.norm_eps)) \
        if bias_norm else (lambda t, q: rms_norm(t, q["scale"], cfg.norm_eps))
    h = norm(x, p["ln1"])
    q, k, v = qkv_project(cfg, p["attn"], h, positions, rope=rope)
    if kv_cache is not None:
        kc, vc = cache_update(*kv_cache, k, v, pos)
        o = attend_decode(cfg, q, kc, vc, pos)
        new_kv = (kc, vc)
    else:
        o = attend_prefill(cfg, q, k, v, causal=True)
        new_kv = (k, v)
    x = x + o_project(p["attn"], o)
    if "mlp" in p:
        h2 = norm(x, p["ln2"])
        x = x + mlp(cfg, p["mlp"], h2)
    return x, new_kv


def _moe_block(cfg, p, x, positions, *, kv_cache=None, pos=None):
    h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
    q, k, v = qkv_project(cfg, p["attn"], h, positions)
    if kv_cache is not None:
        kc, vc = cache_update(*kv_cache, k, v, pos)
        o = attend_decode(cfg, q, kc, vc, pos)
        new_kv = (kc, vc)
    else:
        o = attend_prefill(cfg, q, k, v, causal=True)
        new_kv = (k, v)
    x = x + o_project(p["attn"], o)
    h2 = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
    from .mlp import moe_mlp_gshard
    moe_fn = moe_mlp_gshard if cfg.moe_impl == "gshard" else moe_mlp
    y, aux = moe_fn(cfg, p["moe"], h2)
    return x + y, new_kv, aux


def _ssm_block(cfg, p, x, cache=None):
    h = rms_norm(x, p["ln"]["scale"], cfg.norm_eps)
    block = mamba1_block if cfg.family == "ssm" else mamba2_block
    y, new_cache = block(cfg, p["mamba"], h, cache)
    return x + y, new_cache


# --------------------------------------------------------------------------- #
# Decoder trunk (scan over layers), one function per execution mode
# --------------------------------------------------------------------------- #
def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat else fn


def _shard_residual(x, cfg):
    """Layer-boundary residual constraint.  With ``cfg.seq_parallel`` the
    saved (remat) activations shard their seq dim over 'model'
    (Megatron-SP) — §Perf iteration 1: cuts checkpointed-activation
    memory by the TP degree and de-duplicates attention compute on archs
    whose head counts don't divide the TP axis."""
    return shard(x, "batch", "seq_sp" if cfg.seq_parallel else "seq",
                 "embed")


def _empty_kv(cfg, B, S):
    KV, hd = cfg.n_kv_heads, cfg.hd
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return (jnp.zeros((B, S, KV, hd), dt), jnp.zeros((B, S, KV, hd), dt))


def trunk_train(cfg, params, x, positions):
    """Returns (hidden, aux_loss)."""
    fam = cfg.family
    layers = params["layers"]

    if fam in ("dense", "vlm"):
        def body(c, p_i):
            c = _shard_residual(c, cfg)
            y, _ = _attn_mlp_block(cfg, p_i, c, positions)
            return _shard_residual(y, cfg), None
        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, layers)
        return x, 0.0

    if fam == "moe":
        def body(c, p_i):
            x, aux_sum = c
            x = _shard_residual(x, cfg)
            y, _, aux = _moe_block(cfg, p_i, x, positions)
            return (_shard_residual(y, cfg), aux_sum + aux), None
        (x, aux), _ = jax.lax.scan(_maybe_remat(body, cfg), (x, 0.0), layers)
        return x, aux / cfg.n_layers

    if fam == "ssm":
        def body(c, p_i):
            c = _shard_residual(c, cfg)
            y, _ = _ssm_block(cfg, p_i, c)
            return _shard_residual(y, cfg), None
        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, layers)
        return x, 0.0

    if fam == "hybrid":
        shared = params["shared"]
        every = cfg.shared_attn_every

        def body(c, xs):
            p_i, i = xs
            c = _shard_residual(c, cfg)
            def with_attn(t):
                y, _ = _attn_mlp_block(cfg, shared, t, positions)
                return y
            c = jax.lax.cond(i % every == 0, with_attn, lambda t: t, c)
            y, _ = _ssm_block(cfg, p_i, c)
            return _shard_residual(y, cfg), None
        idx = jnp.arange(cfg.n_layers)
        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, (layers, idx))
        return x, 0.0

    raise ValueError(fam)


def trunk_prefill(cfg, params, x, positions, cache_len: int):
    """Returns (hidden, cache).  ``cache_len >= S`` (cache pre-padded)."""
    fam = cfg.family
    layers = params["layers"]
    B, S, _ = x.shape
    pad = cache_len - S

    def pad_kv(k, v):
        if pad == 0:
            return k, v
        pk = jnp.zeros((B, pad, *k.shape[2:]), k.dtype)
        return (jnp.concatenate([k, pk], 1), jnp.concatenate([v, pk], 1))

    if fam in ("dense", "vlm", "moe"):
        blk = _attn_mlp_block if fam != "moe" else None

        def body(c, p_i):
            if fam == "moe":
                y, (k, v), _ = _moe_block(cfg, p_i, c, positions)
            else:
                y, (k, v) = _attn_mlp_block(cfg, p_i, c, positions)
            return y, pad_kv(k, v)
        x, (ks, vs) = jax.lax.scan(body, x, layers)
        names = kv_cache_names(cfg.n_kv_heads, cfg.hd)
        cache = {"k": shard(ks, *names), "v": shard(vs, *names),
                 "pos": jnp.int32(S)}
        return x, cache

    if fam == "ssm":
        def body(c, p_i):
            y, nc = _ssm_block(cfg, p_i, c)
            return y, nc
        x, caches = jax.lax.scan(body, x, layers)
        return x, {**caches, "pos": jnp.int32(S)}

    if fam == "hybrid":
        shared = params["shared"]
        every = cfg.shared_attn_every
        A = cfg.n_attn_apps
        ak, av = (jnp.zeros((A, B, cache_len, cfg.n_kv_heads, cfg.hd),
                            x.dtype) for _ in range(2))

        def body(carry, xs):
            c, ak, av = carry
            p_i, i = xs

            def with_attn(args):
                c, ak, av = args
                y, (k, v) = _attn_mlp_block(cfg, shared, c, positions)
                k, v = pad_kv(k, v)
                app = i // every
                ak = jax.lax.dynamic_update_slice(ak, k[None], (app, 0, 0, 0, 0))
                av = jax.lax.dynamic_update_slice(av, v[None], (app, 0, 0, 0, 0))
                return y, ak, av
            c, ak, av = jax.lax.cond(i % every == 0, with_attn,
                                     lambda a: a, (c, ak, av))
            y, nc = _ssm_block(cfg, p_i, c)
            return (y, ak, av), nc
        idx = jnp.arange(cfg.n_layers)
        (x, ak, av), caches = jax.lax.scan(body, (x, ak, av), (layers, idx))
        return x, {**caches, "ak": ak, "av": av, "pos": jnp.int32(S)}

    raise ValueError(fam)


def trunk_decode(cfg, params, x, cache):
    """x: (B,1,D); returns (hidden, new_cache)."""
    fam = cfg.family
    layers = params["layers"]
    pos = cache["pos"]
    positions = pos[None]  # (1,)

    if fam in ("dense", "vlm", "moe"):
        def body(c, xs):
            p_i, k_i, v_i = xs
            if fam == "moe":
                y, (k, v), _ = _moe_block(cfg, p_i, c, positions,
                                          kv_cache=(k_i, v_i), pos=pos)
            else:
                y, (k, v) = _attn_mlp_block(cfg, p_i, c, positions,
                                            kv_cache=(k_i, v_i), pos=pos)
            return y, (k, v)
        x, (ks, vs) = jax.lax.scan(body, x, (layers, cache["k"], cache["v"]))
        return x, {"k": ks, "v": vs, "pos": pos + 1}

    if fam == "ssm":
        def body(c, xs):
            p_i, cc = xs
            y, nc = _ssm_block(cfg, p_i, c, cache=cc)
            return y, nc
        sub = {k: cache[k] for k in ("conv", "h")}
        x, new = jax.lax.scan(body, x, (layers, sub))
        return x, {**new, "pos": pos + 1}

    if fam == "hybrid":
        shared = params["shared"]
        every = cfg.shared_attn_every

        def body(carry, xs):
            c, ak, av = carry
            p_i, cc, i = xs

            def with_attn(args):
                c, ak, av = args
                app = i // every
                k_i = jax.lax.dynamic_index_in_dim(ak, app, 0, keepdims=False)
                v_i = jax.lax.dynamic_index_in_dim(av, app, 0, keepdims=False)
                y, (k, v) = _attn_mlp_block(cfg, shared, c, positions,
                                            kv_cache=(k_i, v_i), pos=pos)
                ak = jax.lax.dynamic_update_slice(ak, k[None], (app, 0, 0, 0, 0))
                av = jax.lax.dynamic_update_slice(av, v[None], (app, 0, 0, 0, 0))
                return y, ak, av
            c, ak, av = jax.lax.cond(i % every == 0, with_attn,
                                     lambda a: a, (c, ak, av))
            y, nc = _ssm_block(cfg, p_i, c, cache=cc)
            return (y, ak, av), nc
        sub = {k: cache[k] for k in ("conv", "h")}
        idx = jnp.arange(cfg.n_layers)
        (x, ak, av), new = jax.lax.scan(body, (x, cache["ak"], cache["av"]),
                                        (layers, sub, idx))
        return x, {**new, "ak": ak, "av": av, "pos": pos + 1}

    raise ValueError(fam)


# --------------------------------------------------------------------------- #
# Embedding entry points (vlm merges patch embeds)
# --------------------------------------------------------------------------- #
def embed_inputs(cfg, params, inputs: dict):
    tok = embed_lookup(params["embed"]["table"], inputs["tokens"])
    if cfg.family == "vlm":
        img = inputs["img"].astype(tok.dtype)           # (B, P, D) stub
        img = shard(img, "batch", "patches", "embed")
        tok = jnp.concatenate([img, tok], axis=1)
    return tok


def final_hidden(cfg, params, x):
    fn = params["final_norm"]
    if cfg.family == "encdec":
        return layer_norm(x, fn["scale"], fn["bias"], cfg.norm_eps)
    return rms_norm(x, fn["scale"], cfg.norm_eps)


# --------------------------------------------------------------------------- #
# Whisper enc-dec
# --------------------------------------------------------------------------- #
def encode(cfg, params, frames):
    """frames: (B, F, D) stub conv-frontend output → encoder hidden."""
    x = frames.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    x = shard(x, "batch", "frames", "embed")
    positions = jnp.arange(x.shape[1])

    def body(c, p_i):
        norm = lambda t, q: layer_norm(t, q["scale"], q["bias"], cfg.norm_eps)
        c = _shard_residual(c, cfg)
        h = norm(c, p_i["ln1"])
        q, k, v = qkv_project(cfg, p_i["attn"], h, positions)
        o = attend_prefill(cfg, q, k, v, causal=False)
        c = c + o_project(p_i["attn"], o)
        h2 = norm(c, p_i["ln2"])
        return _shard_residual(c + mlp(cfg, p_i["mlp"], h2), cfg), None
    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["enc_layers"])
    fn = params["enc_final_norm"]
    return layer_norm(x, fn["scale"], fn["bias"], cfg.norm_eps)


def _dec_layer(cfg, p_i, c, enc_or_ckv, positions, kv_cache=None, pos=None):
    norm = lambda t, q: layer_norm(t, q["scale"], q["bias"], cfg.norm_eps)
    c, new_kv = _attn_mlp_block(
        cfg, {"ln1": p_i["ln1"], "attn": p_i["attn"]}, c, positions,
        kv_cache=kv_cache, pos=pos, bias_norm=True)
    # cross-attention
    h = norm(c, p_i["ln_x"])
    q = jnp.einsum("bsd,dhk->bshk", h, p_i["xattn"]["wq"])
    if isinstance(enc_or_ckv, tuple):                    # cached cross k/v
        ck, cv = enc_or_ckv
    else:
        ck = jnp.einsum("bfd,dhk->bfhk", enc_or_ckv, p_i["xattn"]["wk"])
        cv = jnp.einsum("bfd,dhk->bfhk", enc_or_ckv, p_i["xattn"]["wv"])
    o = attend_prefill(cfg, q, ck, cv, causal=False)
    c = c + o_project(p_i["xattn"], o)
    h2 = norm(c, p_i["ln2"])
    c = c + mlp(cfg, p_i["mlp"], h2)
    return c, new_kv, (ck, cv)


def decoder_train(cfg, params, tokens, enc_hidden):
    x = embed_lookup(params["embed"]["table"], tokens)
    positions = jnp.arange(tokens.shape[1])

    def body(c, p_i):
        c = _shard_residual(c, cfg)
        y, _, _ = _dec_layer(cfg, p_i, c, enc_hidden, positions)
        return _shard_residual(y, cfg), None
    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["dec_layers"])
    return final_hidden(cfg, params, x)


def decoder_prefill(cfg, params, tokens, enc_hidden, cache_len: int):
    B, S = tokens.shape
    x = embed_lookup(params["embed"]["table"], tokens)
    positions = jnp.arange(S)
    pad = cache_len - S

    def body(c, p_i):
        y, (k, v), (ck, cv) = _dec_layer(cfg, p_i, c, enc_hidden, positions)
        if pad:
            z = jnp.zeros((B, pad, *k.shape[2:]), k.dtype)
            k, v = jnp.concatenate([k, z], 1), jnp.concatenate([v, z], 1)
        return y, (k, v, ck, cv)
    x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["dec_layers"])
    cache = {"k": ks, "v": vs, "ck": cks, "cv": cvs, "pos": jnp.int32(S)}
    return final_hidden(cfg, params, x), cache


def decoder_decode(cfg, params, token, cache):
    x = embed_lookup(params["embed"]["table"], token)
    pos = cache["pos"]
    positions = pos[None]

    def body(c, xs):
        p_i, k_i, v_i, ck_i, cv_i = xs
        y, (k, v), _ = _dec_layer(cfg, p_i, c, (ck_i, cv_i), positions,
                                  kv_cache=(k_i, v_i), pos=pos)
        return y, (k, v)
    x, (ks, vs) = jax.lax.scan(body, x, (params["dec_layers"], cache["k"],
                                         cache["v"], cache["ck"], cache["cv"]))
    new = {"k": ks, "v": vs, "ck": cache["ck"], "cv": cache["cv"],
           "pos": pos + 1}
    return final_hidden(cfg, params, x), new


# --------------------------------------------------------------------------- #
# Top-level model entry points
# --------------------------------------------------------------------------- #
def forward_train(cfg, params, inputs: dict):
    """→ (logits fp32, aux_loss)."""
    if cfg.family == "encdec":
        enc = encode(cfg, params, inputs["frames"])
        x = decoder_train(cfg, params, inputs["tokens"], enc)
        return lm_logits(x, params["embed"], params.get("lm_head")), 0.0
    x = embed_inputs(cfg, params, inputs)
    positions = jnp.arange(x.shape[1])
    x, aux = trunk_train(cfg, params, x, positions)
    x = final_hidden(cfg, params, x)
    return lm_logits(x, params["embed"], params.get("lm_head")), aux


def forward_prefill(cfg, params, inputs: dict, cache_len: int | None = None):
    """→ (last-token logits fp32, cache)."""
    if cfg.family == "encdec":
        enc = encode(cfg, params, inputs["frames"])
        S = inputs["tokens"].shape[1]
        x, cache = decoder_prefill(cfg, params, inputs["tokens"], enc,
                                   cache_len or S)
        logits = lm_logits(x[:, -1:], params["embed"], params.get("lm_head"))
        return logits, cache
    x = embed_inputs(cfg, params, inputs)
    S = x.shape[1]
    positions = jnp.arange(S)
    x, cache = trunk_prefill(cfg, params, x, positions, cache_len or S)
    x = final_hidden(cfg, params, x)
    logits = lm_logits(x[:, -1:], params["embed"], params.get("lm_head"))
    return logits, cache


def forward_decode(cfg, params, token, cache):
    """token: (B,1) int32 → (logits fp32 (B,1,V), new cache)."""
    if cfg.family == "encdec":
        x, new = decoder_decode(cfg, params, token, cache)
        return lm_logits(x, params["embed"], params.get("lm_head")), new
    x = embed_lookup(params["embed"]["table"], token)
    x, new = trunk_decode(cfg, params, x, cache)
    x = final_hidden(cfg, params, x)
    return lm_logits(x, params["embed"], params.get("lm_head")), new
