"""The paper's six CNNs (Table I), block-structured like torchvision.

Block boundaries replicate the flattened top-level children of the
torchvision implementations — that is what the paper partitions at, and
it makes our block counts match Table I (MobileNetV2 21, ResNet18 14,
InceptionV3 22, ResNet50 22, AlexNet 21, VGG16 39).

Parameter counts are verified against the canonical torchvision counts
in tests (ResNet18 11,689,512 / ResNet50 25,557,032 / AlexNet 61,100,840
/ VGG16 138,357,544 at 1000 classes; MobileNetV2 2,236,682 at the
paper's 10 classes).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ...core.blocks import Block, BlockGraph
from .layers import (AdaptiveAvgPool, BatchNorm, Conv2D, Dropout, Flatten,
                     Layer, Linear, Parallel, Pool, ReLU, Residual,
                     Sequential, conv_bn_relu)


@dataclass
class CNNModel:
    name: str
    blocks: list[tuple[str, Layer]]
    input_hw: int                  # the paper's operating resolution
    in_channels: int = 3

    # ----------------------------------------------------------------- #
    def init(self, key):
        keys = jax.random.split(key, len(self.blocks))
        return [layer.init(k) for (_, layer), k in zip(self.blocks, keys)]

    def apply(self, params, x):
        for (_, layer), p in zip(self.blocks, params):
            x = layer.apply(p, x)
        return x

    def apply_range(self, params, x, lo: int, hi: int):
        """Run blocks[lo:hi] — the unit a pipeline stage executes."""
        for (_, layer), p in zip(self.blocks[lo:hi], params[lo:hi]):
            x = layer.apply(p, x)
        return x

    def block_fns(self, params) -> tuple[list[str], list[Callable]]:
        names = [n for n, _ in self.blocks]
        fns = [(lambda x, l=layer, p=p: l.apply(p, x))
               for (_, layer), p in zip(self.blocks, params)]
        return names, fns

    def param_count(self) -> int:
        return sum(layer.param_count() for _, layer in self.blocks)

    # ----------------------------------------------------------------- #
    def block_graph(self, input_hw: int | None = None) -> BlockGraph:
        """Analytic per-sample BlockGraph for the partitioner."""
        hw = input_hw or self.input_hw
        s = (1, hw, hw, self.in_channels)
        in_bytes = int(np.prod(s)) * 4
        blocks = []
        for name, layer in self.blocks:
            out = layer.out_shape(s)
            fl = layer.flops(s)
            ef = layer.eff_flops(s)
            blocks.append(Block(
                name=name,
                flops=fl,
                weight_bytes=layer.param_count() * 4,
                out_bytes=int(np.prod(out)) * 4,
                act_bytes=(int(np.prod(s)) + int(np.prod(out))) * 4,
                eff=(fl / ef) if ef > 0 else 1.0,
            ))
            s = out
        return BlockGraph(name=self.name, blocks=tuple(blocks),
                          input_bytes=in_bytes,
                          output_bytes=int(np.prod(s)) * 4)

    def out_shape(self, batch: int, input_hw: int | None = None):
        hw = input_hw or self.input_hw
        s = (batch, hw, hw, self.in_channels)
        for _, layer in self.blocks:
            s = layer.out_shape(s)
        return s


# ========================================================================= #
# MobileNetV2
# ========================================================================= #
def _inverted_residual(inp: int, oup: int, stride: int, expand: int) -> Layer:
    hidden = inp * expand
    layers = []
    if expand != 1:
        layers.append(conv_bn_relu(inp, hidden, 1, relu_cap=6.0))
    layers += [
        conv_bn_relu(hidden, hidden, 3, stride, 1, groups=hidden, relu_cap=6.0),
        Sequential([Conv2D(hidden, oup, 1, bias=False), BatchNorm(oup)]),
    ]
    body = Sequential(layers)
    if stride == 1 and inp == oup:
        return Residual(body, post_relu=False)
    return body


def mobilenet_v2(num_classes: int = 10) -> CNNModel:
    cfg = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
           (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    blocks: list[tuple[str, Layer]] = [
        ("features.0_stem", conv_bn_relu(3, 32, 3, 2, 1, relu_cap=6.0))]
    cin, idx = 32, 1
    for t, c, n, s in cfg:
        for i in range(n):
            blocks.append((f"features.{idx}_ir",
                           _inverted_residual(cin, c, s if i == 0 else 1, t)))
            cin, idx = c, idx + 1
    blocks.append(("features.18_head", conv_bn_relu(cin, 1280, 1, relu_cap=6.0)))
    blocks.append(("avgpool", Sequential([AdaptiveAvgPool(1), Flatten()])))
    blocks.append(("classifier", Sequential([Dropout(0.2),
                                             Linear(1280, num_classes)])))
    return CNNModel("mobilenetv2", blocks, input_hw=224)


# ========================================================================= #
# ResNet 18 / 50
# ========================================================================= #
def _basic_block(cin: int, cout: int, stride: int) -> Layer:
    body = Sequential([
        Conv2D(cin, cout, 3, stride, 1, bias=False), BatchNorm(cout), ReLU(),
        Conv2D(cout, cout, 3, 1, 1, bias=False), BatchNorm(cout),
    ])
    short = None
    if stride != 1 or cin != cout:
        short = Sequential([Conv2D(cin, cout, 1, stride, bias=False),
                            BatchNorm(cout)])
    return Residual(body, short, post_relu=True)


def _bottleneck(cin: int, mid: int, cout: int, stride: int) -> Layer:
    body = Sequential([
        Conv2D(cin, mid, 1, bias=False), BatchNorm(mid), ReLU(),
        Conv2D(mid, mid, 3, stride, 1, bias=False), BatchNorm(mid), ReLU(),
        Conv2D(mid, cout, 1, bias=False), BatchNorm(cout),
    ])
    short = None
    if stride != 1 or cin != cout:
        short = Sequential([Conv2D(cin, cout, 1, stride, bias=False),
                            BatchNorm(cout)])
    return Residual(body, short, post_relu=True)


def _resnet_stem() -> list[tuple[str, Layer]]:
    return [("conv1", Conv2D(3, 64, 7, 2, 3, bias=False)),
            ("bn1", BatchNorm(64)),
            ("relu", ReLU()),
            ("maxpool", Pool("max", 3, 2, 1))]


def resnet18(num_classes: int = 10) -> CNNModel:
    blocks = _resnet_stem()
    plan = [(64, 64, 1), (64, 64, 1), (64, 128, 2), (128, 128, 1),
            (128, 256, 2), (256, 256, 1), (256, 512, 2), (512, 512, 1)]
    for i, (cin, cout, s) in enumerate(plan):
        blocks.append((f"layer_bb{i}", _basic_block(cin, cout, s)))
    blocks.append(("avgpool", Sequential([AdaptiveAvgPool(1), Flatten()])))
    blocks.append(("fc", Linear(512, num_classes)))
    return CNNModel("resnet18", blocks, input_hw=224)


def resnet50(num_classes: int = 10) -> CNNModel:
    blocks = _resnet_stem()
    i = 0
    cin = 64
    for mid, n, stride in [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)]:
        cout = mid * 4
        for j in range(n):
            blocks.append((f"layer_bn{i}",
                           _bottleneck(cin, mid, cout, stride if j == 0 else 1)))
            cin = cout
            i += 1
    blocks.append(("avgpool", Sequential([AdaptiveAvgPool(1), Flatten()])))
    blocks.append(("fc", Linear(2048, num_classes)))
    return CNNModel("resnet50", blocks, input_hw=224)


# ========================================================================= #
# AlexNet
# ========================================================================= #
def alexnet(num_classes: int = 10) -> CNNModel:
    f = [Conv2D(3, 64, 11, 4, 2), ReLU(), Pool("max", 3, 2),
         Conv2D(64, 192, 5, 1, 2), ReLU(), Pool("max", 3, 2),
         Conv2D(192, 384, 3, 1, 1), ReLU(),
         Conv2D(384, 256, 3, 1, 1), ReLU(),
         Conv2D(256, 256, 3, 1, 1), ReLU(), Pool("max", 3, 2)]
    blocks = [(f"features.{i}", l) for i, l in enumerate(f)]
    blocks.append(("avgpool", Sequential([AdaptiveAvgPool(6), Flatten()])))
    c = [Dropout(), Linear(256 * 36, 4096), ReLU(),
         Dropout(), Linear(4096, 4096), ReLU(), Linear(4096, num_classes)]
    blocks += [(f"classifier.{i}", l) for i, l in enumerate(c)]
    return CNNModel("alexnet", blocks, input_hw=224)


# ========================================================================= #
# VGG16
# ========================================================================= #
def vgg16(num_classes: int = 10) -> CNNModel:
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]
    f: list[Layer] = []
    cin = 3
    for v in cfg:
        if v == "M":
            f.append(Pool("max", 2, 2))
        else:
            f += [Conv2D(cin, v, 3, 1, 1), ReLU()]
            cin = v
    blocks = [(f"features.{i}", l) for i, l in enumerate(f)]
    blocks.append(("avgpool", Sequential([AdaptiveAvgPool(7), Flatten()])))
    c = [Linear(512 * 49, 4096), ReLU(), Dropout(),
         Linear(4096, 4096), ReLU(), Dropout(), Linear(4096, num_classes)]
    blocks += [(f"classifier.{i}", l) for i, l in enumerate(c)]
    return CNNModel("vgg16", blocks, input_hw=224)


# ========================================================================= #
# InceptionV3
# ========================================================================= #
def _c(cin, cout, k, s=1, p=0):
    return conv_bn_relu(cin, cout, k, s, p)


def _inception_a(cin: int, pool_features: int) -> Layer:
    return Parallel([
        _c(cin, 64, 1),
        Sequential([_c(cin, 48, 1), _c(48, 64, 5, 1, 2)]),
        Sequential([_c(cin, 64, 1), _c(64, 96, 3, 1, 1), _c(96, 96, 3, 1, 1)]),
        Sequential([Pool("avg", 3, 1, 1), _c(cin, pool_features, 1)]),
    ])


def _inception_b(cin: int) -> Layer:
    return Parallel([
        _c(cin, 384, 3, 2),
        Sequential([_c(cin, 64, 1), _c(64, 96, 3, 1, 1), _c(96, 96, 3, 2)]),
        Pool("max", 3, 2),
    ])


def _inception_c(cin: int, c7: int) -> Layer:
    return Parallel([
        _c(cin, 192, 1),
        Sequential([_c(cin, c7, 1), _c(c7, c7, (1, 7), 1, (0, 3)),
                    _c(c7, 192, (7, 1), 1, (3, 0))]),
        Sequential([_c(cin, c7, 1), _c(c7, c7, (7, 1), 1, (3, 0)),
                    _c(c7, c7, (1, 7), 1, (0, 3)),
                    _c(c7, c7, (7, 1), 1, (3, 0)),
                    _c(c7, 192, (1, 7), 1, (0, 3))]),
        Sequential([Pool("avg", 3, 1, 1), _c(cin, 192, 1)]),
    ])


def _inception_d(cin: int) -> Layer:
    return Parallel([
        Sequential([_c(cin, 192, 1), _c(192, 320, 3, 2)]),
        Sequential([_c(cin, 192, 1), _c(192, 192, (1, 7), 1, (0, 3)),
                    _c(192, 192, (7, 1), 1, (3, 0)), _c(192, 192, 3, 2)]),
        Pool("max", 3, 2),
    ])


def _inception_e(cin: int) -> Layer:
    return Parallel([
        _c(cin, 320, 1),
        Sequential([_c(cin, 384, 1),
                    Parallel([_c(384, 384, (1, 3), 1, (0, 1)),
                              _c(384, 384, (3, 1), 1, (1, 0))])]),
        Sequential([_c(cin, 448, 1), _c(448, 384, 3, 1, 1),
                    Parallel([_c(384, 384, (1, 3), 1, (0, 1)),
                              _c(384, 384, (3, 1), 1, (1, 0))])]),
        Sequential([Pool("avg", 3, 1, 1), _c(cin, 192, 1)]),
    ])


def inception_v3(num_classes: int = 10) -> CNNModel:
    blocks: list[tuple[str, Layer]] = [
        ("Conv2d_1a", _c(3, 32, 3, 2)),
        ("Conv2d_2a", _c(32, 32, 3)),
        ("Conv2d_2b", _c(32, 64, 3, 1, 1)),
        ("maxpool1", Pool("max", 3, 2)),
        ("Conv2d_3b", _c(64, 80, 1)),
        ("Conv2d_4a", _c(80, 192, 3)),
        ("maxpool2", Pool("max", 3, 2)),
        ("Mixed_5b", _inception_a(192, 32)),
        ("Mixed_5c", _inception_a(256, 64)),
        ("Mixed_5d", _inception_a(288, 64)),
        ("Mixed_6a", _inception_b(288)),
        ("Mixed_6b", _inception_c(768, 128)),
        ("Mixed_6c", _inception_c(768, 160)),
        ("Mixed_6d", _inception_c(768, 160)),
        ("Mixed_6e", _inception_c(768, 192)),
        ("Mixed_7a", _inception_d(768)),
        ("Mixed_7b", _inception_e(1280)),
        ("Mixed_7c", _inception_e(2048)),
        ("avgpool", AdaptiveAvgPool(1)),
        ("dropout", Dropout()),
        ("flatten", Flatten()),
        ("fc", Linear(2048, num_classes)),
    ]
    return CNNModel("inceptionv3", blocks, input_hw=299)


# ========================================================================= #
ZOO: dict[str, Callable[..., CNNModel]] = {
    "mobilenetv2": mobilenet_v2,
    "resnet18": resnet18,
    "inceptionv3": inception_v3,
    "resnet50": resnet50,
    "alexnet": alexnet,
    "vgg16": vgg16,
}


def get(name: str, num_classes: int = 10) -> CNNModel:
    try:
        return ZOO[name](num_classes=num_classes)
    except KeyError:
        raise KeyError(f"unknown CNN {name!r}; have {sorted(ZOO)}") from None
