"""Minimal functional CNN layer library with cost accounting.

Every layer knows how to ``init`` parameters, ``apply`` a forward pass
(inference mode — BN uses running stats, dropout is identity, matching
the paper's inference benchmarks), and report its ``flops``/``params``
for a given input shape.  This single source of truth feeds both the
executable block functions and the analytic ``BlockGraph`` used by the
partitioner, so model-driven and measured profiles describe the same
computation.

Layout: NHWC, fp32.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


def _pair(v):
    return (v, v) if isinstance(v, int) else tuple(v)


class Layer:
    """Base: stateless layer description."""

    def init(self, key):
        return {}

    def apply(self, params, x):
        raise NotImplementedError

    def out_shape(self, s):
        return s

    def flops(self, s) -> float:
        return 0.0

    def eff_flops(self, s) -> float:
        """FLOPs weighted by 1/efficiency (depthwise convs run far below
        peak on ARM/PyTorch — calibration of the paper's Fig. 2)."""
        return self.flops(s)

    def param_count(self) -> int:
        return 0


@dataclass
class Conv2D(Layer):
    cin: int
    cout: int
    kernel: int | tuple = 3
    stride: int | tuple = 1
    padding: int | tuple | str = 0
    groups: int = 1
    bias: bool = True

    def _pad(self):
        if isinstance(self.padding, str):
            return self.padding
        ph, pw = _pair(self.padding)
        return ((ph, ph), (pw, pw))

    def init(self, key):
        kh, kw = _pair(self.kernel)
        k1, k2 = jax.random.split(key)
        fan_in = self.cin // self.groups * kh * kw
        w = jax.random.normal(k1, (kh, kw, self.cin // self.groups, self.cout),
                              jnp.float32) * (1.0 / math.sqrt(fan_in))
        p = {"w": w}
        if self.bias:
            p["b"] = jnp.zeros((self.cout,), jnp.float32)
        return p

    def apply(self, params, x):
        y = lax.conv_general_dilated(
            x, params["w"], window_strides=_pair(self.stride),
            padding=self._pad(), feature_group_count=self.groups,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        if self.bias:
            y = y + params["b"]
        return y

    def out_shape(self, s):
        n, h, w, _ = s
        kh, kw = _pair(self.kernel)
        sh, sw = _pair(self.stride)
        if isinstance(self.padding, str) and self.padding.upper() == "SAME":
            ho, wo = -(-h // sh), -(-w // sw)
        else:
            ph, pw = _pair(self.padding)
            ho = (h + 2 * ph - kh) // sh + 1
            wo = (w + 2 * pw - kw) // sw + 1
        return (n, ho, wo, self.cout)

    def flops(self, s):
        n, ho, wo, _ = self.out_shape(s)
        kh, kw = _pair(self.kernel)
        return 2.0 * n * ho * wo * kh * kw * (self.cin // self.groups) * self.cout

    def eff_flops(self, s):
        depthwise = self.groups == self.cin and self.groups > 1
        return self.flops(s) / (0.10 if depthwise else 1.0)

    def param_count(self):
        kh, kw = _pair(self.kernel)
        return kh * kw * (self.cin // self.groups) * self.cout + (self.cout if self.bias else 0)


@dataclass
class BatchNorm(Layer):
    c: int
    eps: float = 1e-5

    def init(self, key):
        return {"scale": jnp.ones((self.c,), jnp.float32),
                "bias": jnp.zeros((self.c,), jnp.float32),
                "mean": jnp.zeros((self.c,), jnp.float32),
                "var": jnp.ones((self.c,), jnp.float32)}

    def apply(self, params, x):
        inv = lax.rsqrt(params["var"] + self.eps) * params["scale"]
        return x * inv + (params["bias"] - params["mean"] * inv)

    def flops(self, s):
        return 2.0 * float(np.prod(s))

    def param_count(self):
        return 2 * self.c  # learnable only (running stats excluded, torch-style)


@dataclass
class ReLU(Layer):
    cap: float | None = None   # 6.0 for ReLU6

    def apply(self, params, x):
        y = jnp.maximum(x, 0)
        return jnp.minimum(y, self.cap) if self.cap is not None else y

    def flops(self, s):
        return float(np.prod(s))


@dataclass
class Pool(Layer):
    kind: str = "max"            # "max" | "avg"
    kernel: int | tuple = 2
    stride: int | tuple | None = None
    padding: int | tuple = 0
    ceil_mode: bool = False

    def _dims(self):
        kh, kw = _pair(self.kernel)
        sh, sw = _pair(self.stride if self.stride is not None else self.kernel)
        ph, pw = _pair(self.padding)
        return kh, kw, sh, sw, ph, pw

    def apply(self, params, x):
        kh, kw, sh, sw, ph, pw = self._dims()
        pad = ((0, 0), (ph, ph), (pw, pw), (0, 0))
        if self.kind == "max":
            return lax.reduce_window(x, -jnp.inf, lax.max,
                                     (1, kh, kw, 1), (1, sh, sw, 1), pad)
        summed = lax.reduce_window(x, 0.0, lax.add,
                                   (1, kh, kw, 1), (1, sh, sw, 1), pad)
        return summed / (kh * kw)

    def out_shape(self, s):
        n, h, w, c = s
        kh, kw, sh, sw, ph, pw = self._dims()
        rnd = math.ceil if self.ceil_mode else math.floor
        ho = rnd((h + 2 * ph - kh) / sh) + 1
        wo = rnd((w + 2 * pw - kw) / sw) + 1
        return (n, ho, wo, c)

    def flops(self, s):
        n, ho, wo, c = self.out_shape(s)
        kh, kw, *_ = self._dims()
        return float(n * ho * wo * c * kh * kw)


@dataclass
class AdaptiveAvgPool(Layer):
    out_hw: int | tuple = 1

    def apply(self, params, x):
        oh, ow = _pair(self.out_hw)
        n, h, w, c = x.shape
        if (oh, ow) == (1, 1):
            return jnp.mean(x, axis=(1, 2), keepdims=True)
        if h % oh == 0 and w % ow == 0:
            kh, kw = h // oh, w // ow
            summed = lax.reduce_window(x, 0.0, lax.add, (1, kh, kw, 1),
                                       (1, kh, kw, 1), "VALID")
            return summed / (kh * kw)
        # torch adaptive semantics (handles upsampling too); oh/ow static & small
        rows = []
        for i in range(oh):
            lo_h, hi_h = (i * h) // oh, -(-((i + 1) * h) // oh)
            strip = x[:, lo_h:hi_h]
            cells = []
            for j in range(ow):
                lo_w, hi_w = (j * w) // ow, -(-((j + 1) * w) // ow)
                cells.append(strip[:, :, lo_w:hi_w].mean(axis=(1, 2), keepdims=True))
            rows.append(jnp.concatenate(cells, axis=2))
        return jnp.concatenate(rows, axis=1)

    def out_shape(self, s):
        oh, ow = _pair(self.out_hw)
        return (s[0], oh, ow, s[3])

    def flops(self, s):
        return float(np.prod(s))


@dataclass
class Flatten(Layer):
    def apply(self, params, x):
        return x.reshape(x.shape[0], -1)

    def out_shape(self, s):
        return (s[0], int(np.prod(s[1:])))


@dataclass
class Linear(Layer):
    fin: int
    fout: int
    bias: bool = True

    def init(self, key):
        w = jax.random.normal(key, (self.fin, self.fout), jnp.float32)
        w = w * (1.0 / math.sqrt(self.fin))
        p = {"w": w}
        if self.bias:
            p["b"] = jnp.zeros((self.fout,), jnp.float32)
        return p

    def apply(self, params, x):
        y = x @ params["w"]
        return y + params["b"] if self.bias else y

    def out_shape(self, s):
        return (*s[:-1], self.fout)

    def flops(self, s):
        return 2.0 * float(np.prod(s[:-1])) * self.fin * self.fout

    def param_count(self):
        return self.fin * self.fout + (self.fout if self.bias else 0)


@dataclass
class Dropout(Layer):
    """Inference mode: identity (kept as a block to match torchvision
    children counts — the paper's block indices include them)."""
    p: float = 0.5

    def apply(self, params, x):
        return x


@dataclass
class Sequential(Layer):
    layers: Sequence[Layer] = field(default_factory=list)

    def init(self, key):
        keys = jax.random.split(key, max(len(self.layers), 1))
        return [l.init(k) for l, k in zip(self.layers, keys)]

    def apply(self, params, x):
        for l, p in zip(self.layers, params):
            x = l.apply(p, x)
        return x

    def out_shape(self, s):
        for l in self.layers:
            s = l.out_shape(s)
        return s

    def flops(self, s):
        t = 0.0
        for l in self.layers:
            t += l.flops(s)
            s = l.out_shape(s)
        return t

    def eff_flops(self, s):
        t = 0.0
        for l in self.layers:
            t += l.eff_flops(s)
            s = l.out_shape(s)
        return t

    def param_count(self):
        return sum(l.param_count() for l in self.layers)


def conv_bn_relu(cin, cout, kernel, stride=1, padding=0, groups=1,
                 relu_cap=None) -> Sequential:
    return Sequential([
        Conv2D(cin, cout, kernel, stride, padding, groups, bias=False),
        BatchNorm(cout),
        ReLU(cap=relu_cap),
    ])


@dataclass
class Residual(Layer):
    """y = body(x) + shortcut(x), optional trailing ReLU (ResNet blocks)."""
    body: Layer
    shortcut: Layer | None = None     # None = identity
    post_relu: bool = True

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {"body": self.body.init(k1),
                "short": self.shortcut.init(k2) if self.shortcut else {}}

    def apply(self, params, x):
        y = self.body.apply(params["body"], x)
        sc = self.shortcut.apply(params["short"], x) if self.shortcut else x
        y = y + sc
        return jnp.maximum(y, 0) if self.post_relu else y

    def out_shape(self, s):
        return self.body.out_shape(s)

    def flops(self, s):
        f = self.body.flops(s) + float(np.prod(self.body.out_shape(s)))
        if self.shortcut:
            f += self.shortcut.flops(s)
        return f

    def eff_flops(self, s):
        f = self.body.eff_flops(s) + float(np.prod(self.body.out_shape(s)))
        if self.shortcut:
            f += self.shortcut.eff_flops(s)
        return f

    def param_count(self):
        return self.body.param_count() + (self.shortcut.param_count() if self.shortcut else 0)


@dataclass
class Parallel(Layer):
    """Concat of branches along channels (Inception mixed blocks)."""
    branches: Sequence[Layer] = field(default_factory=list)

    def init(self, key):
        keys = jax.random.split(key, len(self.branches))
        return [b.init(k) for b, k in zip(self.branches, keys)]

    def apply(self, params, x):
        outs = [b.apply(p, x) for b, p in zip(self.branches, params)]
        return jnp.concatenate(outs, axis=-1)

    def out_shape(self, s):
        shapes = [b.out_shape(s) for b in self.branches]
        c = sum(sh[-1] for sh in shapes)
        return (*shapes[0][:-1], c)

    def flops(self, s):
        return sum(b.flops(s) for b in self.branches)

    def eff_flops(self, s):
        return sum(b.eff_flops(s) for b in self.branches)

    def param_count(self):
        return sum(b.param_count() for b in self.branches)
