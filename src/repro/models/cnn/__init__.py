"""Paper's CNN zoo (Table I) — block-structured JAX implementations."""
from . import layers, zoo
from .zoo import CNNModel, ZOO, get
