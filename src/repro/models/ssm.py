"""Selective state-space blocks: Mamba-1 (falcon-mamba) and Mamba-2 (zamba2).

TPU adaptation (see DESIGN.md): the GPU reference implementations fuse a
sequential scan into a single CUDA kernel with warp-level parallelism.
On TPU we use *chunked* formulations instead:

  * Mamba-1 — per-(channel, state) diagonal decays: within a chunk an
    associative scan (log-depth, elementwise), across chunks a
    sequential ``lax.scan`` carrying the (B, d_inner, N) state.  Peak
    memory is O(B·chunk·d_inner·N), never O(B·S·d_inner·N).
  * Mamba-2 (SSD) — per-head *scalar* decay makes the chunk-local part a
    pair of matmuls (the "attention-like" form), which is exactly what
    the MXU wants; inter-chunk recurrence carries (B, H, P, N) states.

Both have single-token decode steps carrying (conv window, ssm state).
The Pallas kernel (``repro.kernels.ssm_scan``) implements the Mamba-1
chunk step with VMEM tiling; this module is its oracle.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..sharding.api import shard
from .common import Builder, rms_norm, silu, softplus


# --------------------------------------------------------------------------- #
# Causal depthwise conv1d
# --------------------------------------------------------------------------- #
def causal_conv(x, w, b, carry=None):
    """x: (B, S, C); w: (C, K); returns (y, new_carry (B, K-1, C))."""
    B, S, C = x.shape
    K = w.shape[1]
    if carry is None:
        carry = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    y = jax.lax.conv_general_dilated(
        xp, w.T[:, None, :],                          # (K, I=1, O=C) WIO
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=C)
    new_carry = xp[:, -(K - 1):] if K > 1 else carry
    return y + b, new_carry


# --------------------------------------------------------------------------- #
# Mamba-1
# --------------------------------------------------------------------------- #
def mamba1_params(b: Builder, cfg, prefix: str) -> dict:
    D, di, N, R, K = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank,
                      cfg.ssm_conv)

    def a_init(key, shape, dtype):
        a = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (di, 1))
        return jnp.log(a).astype(dtype)

    return {
        "in_proj": b.leaf(f"{prefix}.in_proj", (D, 2 * di), ("embed", "d_inner")),
        "conv_w": b.leaf(f"{prefix}.conv_w", (di, K), ("d_inner", "kernel")),
        "conv_b": b.leaf(f"{prefix}.conv_b", (di,), ("d_inner",), init="zeros"),
        "x_proj": b.leaf(f"{prefix}.x_proj", (di, R + 2 * N), ("d_inner", None)),
        "dt_proj": b.leaf(f"{prefix}.dt_proj", (R, di), ("dt_rank", "d_inner")),
        "dt_bias": b.leaf(f"{prefix}.dt_bias", (di,), ("d_inner",), init="zeros"),
        "A_log": b.leaf(f"{prefix}.A_log", (di, N), ("d_inner", "state"),
                        init=a_init, dtype=jnp.float32),
        "D": b.leaf(f"{prefix}.D", (di,), ("d_inner",), init="ones",
                    dtype=jnp.float32),
        "out_proj": b.leaf(f"{prefix}.out_proj", (di, D), ("d_inner", "embed")),
    }


def _mamba1_inner(cfg, p, xc, z, h0):
    """Scan core.  xc: (B, S, di) post-conv+silu; z: gate; h0: (B, di, N).
    Returns (y (B,S,di), h_final)."""
    B, S, di = xc.shape
    N, R = cfg.ssm_state, cfg.dt_rank
    proj = jnp.einsum("bsc,cr->bsr", xc, p["x_proj"])
    dt_low, B_, C_ = jnp.split(proj, [R, R + N], axis=-1)
    dt = softplus(jnp.einsum("bsr,rc->bsc", dt_low, p["dt_proj"]).astype(jnp.float32)
                  + p["dt_bias"].astype(jnp.float32))           # (B,S,di) fp32
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # (di, N)

    L = min(cfg.ssm_chunk, S)
    if S % L != 0:
        L = S
    nc = S // L

    def chunk_step(h, xs):
        dt_c, B_c, C_c, x_c = xs        # (B,L,di) (B,L,N) (B,L,N) (B,L,di)
        zlog = dt_c[..., None] * A      # (B,L,di,N) ≤ 0
        dBx = dt_c[..., None] * B_c[:, :, None, :].astype(jnp.float32) \
            * x_c[..., None].astype(jnp.float32)

        dA = shard(jnp.exp(zlog), "batch", None, "d_inner", "state")
        dBx = shard(dBx, "batch", None, "d_inner", "state")
        def op(a, b):
            return (a[0] * b[0], a[1] * b[0] + b[1])
        dec, hs = jax.lax.associative_scan(op, (dA, dBx), axis=1)
        # carry-in contribution: exp(cumsum zlog)·h0 == dec·h0
        hs = hs + dec * h[:, None]
        y = jnp.einsum("blcn,bln->blc", hs, C_c.astype(jnp.float32))
        return hs[:, -1], y

    shape5 = lambda t: t.reshape(B, nc, L, *t.shape[2:]).swapaxes(0, 1)
    xs = (shape5(dt), shape5(B_), shape5(C_), shape5(xc))
    h_fin, ys = jax.lax.scan(chunk_step, h0.astype(jnp.float32), xs)
    y = ys.swapaxes(0, 1).reshape(B, S, di)
    y = y + xc.astype(jnp.float32) * p["D"]
    y = (y * silu(z).astype(jnp.float32)).astype(xc.dtype)
    return y, h_fin


def mamba1_block(cfg, p, x, cache=None):
    """x: (B, S, D).  cache: None (train/prefill from scratch) or dict
    {"conv": (B,K-1,di), "h": (B,di,N)} for single-step decode."""
    B, S, D = x.shape
    di, N = cfg.d_inner, cfg.ssm_state
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xz = shard(xz, "batch", "seq", "d_inner")
    xr, z = jnp.split(xz, 2, axis=-1)
    conv_in = cache["conv"] if cache is not None else None
    xc, conv_out = causal_conv(xr, p["conv_w"], p["conv_b"], conv_in)
    xc = silu(xc)
    h0 = cache["h"] if cache is not None else jnp.zeros((B, di, N), jnp.float32)
    if S == 1 and cache is not None:
        # decode: one recurrence step, no scan
        proj = jnp.einsum("bsc,cr->bsr", xc, p["x_proj"])
        dt_low, B_, C_ = jnp.split(proj, [cfg.dt_rank, cfg.dt_rank + N], -1)
        dt = softplus(jnp.einsum("bsr,rc->bsc", dt_low, p["dt_proj"]
                                 ).astype(jnp.float32) + p["dt_bias"])[:, 0]
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        dA = jnp.exp(dt[..., None] * A)
        dBx = dt[..., None] * B_[:, 0, None, :].astype(jnp.float32) \
            * xc[:, 0, :, None].astype(jnp.float32)
        h = dA * h0 + dBx
        y = jnp.einsum("bcn,bn->bc", h, C_[:, 0].astype(jnp.float32))
        y = y + xc[:, 0].astype(jnp.float32) * p["D"]
        y = (y[:, None] * silu(z).astype(jnp.float32)).astype(x.dtype)
        h_fin = h
    else:
        y, h_fin = _mamba1_inner(cfg, p, xc, z, h0)
    out = jnp.einsum("bsc,cd->bsd", y, p["out_proj"])
    out = shard(out, "batch", "seq", "embed")
    new_cache = {"conv": conv_out, "h": h_fin}
    return out, new_cache


# --------------------------------------------------------------------------- #
# Mamba-2 (SSD)
# --------------------------------------------------------------------------- #
def mamba2_params(b: Builder, cfg, prefix: str) -> dict:
    D, di, N, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    H = cfg.ssm_heads
    d_xbc = di + 2 * N
    d_in = 2 * di + 2 * N + H

    def a_init(key, shape, dtype):
        return jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype)

    return {
        "in_proj": b.leaf(f"{prefix}.in_proj", (D, d_in), ("embed", "d_inner")),
        "conv_w": b.leaf(f"{prefix}.conv_w", (d_xbc, K), ("conv_dim", "kernel")),
        "conv_b": b.leaf(f"{prefix}.conv_b", (d_xbc,), ("conv_dim",), init="zeros"),
        "A_log": b.leaf(f"{prefix}.A_log", (H,), ("ssm_heads",), init=a_init,
                        dtype=jnp.float32),
        "D": b.leaf(f"{prefix}.D", (H,), ("ssm_heads",), init="ones",
                    dtype=jnp.float32),
        "dt_bias": b.leaf(f"{prefix}.dt_bias", (H,), ("ssm_heads",), init="zeros",
                          dtype=jnp.float32),
        "norm": b.leaf(f"{prefix}.norm", (di,), ("d_inner",), init="ones"),
        "out_proj": b.leaf(f"{prefix}.out_proj", (di, D), ("d_inner", "embed")),
    }


def _ssd_chunk(cfg, dt, zlog, x, B_, C_, h0):
    """Chunked SSD.  dt: (B,S,H) input scale; zlog = dt·A ≤ 0 decay exponent;
    x: (B,S,H,P); B_,C_: (B,S,N).  Returns (y (B,S,H,P), h_fin (B,H,P,N))."""
    Bb, S, H, P = x.shape
    L = min(cfg.ssm_chunk, S)
    if S % L != 0:
        L = S
    nc = S // L

    def chunk_step(h, xs):
        dt_c, z_c, x_c, B_c, C_c = xs       # (B,L,H) ×2, (B,L,H,P), (B,L,N) ×2
        Scum = jnp.cumsum(z_c, axis=1)      # (B,L,H)
        # intra-chunk: att[b,t,s,h] = exp(S_t - S_s)·(C_t·B_s), s ≤ t
        cb = jnp.einsum("btn,bsn->bts", C_c.astype(jnp.float32),
                        B_c.astype(jnp.float32))
        dec = Scum[:, :, None, :] - Scum[:, None, :, :]      # (B,t,s,H)
        dec = shard(dec, "batch", None, None, "ssm_heads")
        tri = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
        # mask *before* exp: exp of a positive upper-tri entry would inf
        # out and poison the backward pass with inf·0 NaNs.
        w = jnp.exp(jnp.where(tri, dec, -jnp.inf))
        att = cb[..., None] * w                               # (B,t,s,H)
        att = shard(att, "batch", None, None, "ssm_heads")
        dtx = dt_c[..., None] * x_c.astype(jnp.float32)       # (B,L,H,P)
        y = jnp.einsum("btsh,bshp->bthp", att, dtx)
        # carry-in: y_t += exp(S_t)·(C_t · h)
        y = y + jnp.einsum("btn,bhpn->bthp", C_c.astype(jnp.float32),
                           h) * jnp.exp(Scum)[..., None]
        # new carry: h' = exp(S_L)·h + Σ_s exp(S_L - S_s) B_s ⊗ dtx_s
        wL = jnp.exp(Scum[:, -1:, :] - Scum)                  # (B,L,H)
        h_new = h * jnp.exp(Scum[:, -1])[..., None, None] + \
            jnp.einsum("bsn,bshp,bsh->bhpn", B_c.astype(jnp.float32), dtx, wL)
        return h_new, y

    shape5 = lambda t: t.reshape(Bb, nc, L, *t.shape[2:]).swapaxes(0, 1)
    xs = (shape5(dt), shape5(zlog), shape5(x), shape5(B_), shape5(C_))
    h_fin, ys = jax.lax.scan(chunk_step, h0.astype(jnp.float32), xs)
    y = ys.swapaxes(0, 1).reshape(Bb, S, H, P)
    return y, h_fin


def mamba2_block(cfg, p, x, cache=None):
    """x: (B, S, D); cache {"conv": (B,K-1,d_xbc), "h": (B,H,P,N)}."""
    B, S, D = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    zxbcdt = shard(zxbcdt, "batch", "seq", "d_inner")
    z, xBC, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    conv_in = cache["conv"] if cache is not None else None
    xBC, conv_out = causal_conv(xBC, p["conv_w"], p["conv_b"], conv_in)
    xBC = silu(xBC)
    xr, B_, C_ = jnp.split(xBC, [di, di + N], axis=-1)
    xh = xr.reshape(B, S, H, P)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (H,)
    dt = softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    zlog = dt * A                                             # decay exponent
    h0 = cache["h"] if cache is not None else jnp.zeros((B, H, P, N), jnp.float32)

    if S == 1 and cache is not None:
        dA = jnp.exp(zlog[:, 0])                              # (B,H)
        dtx = dt[:, 0, :, None] * xh[:, 0].astype(jnp.float32)
        h = h0 * dA[..., None, None] + \
            jnp.einsum("bn,bhp->bhpn", B_[:, 0].astype(jnp.float32), dtx)
        y = jnp.einsum("bn,bhpn->bhp", C_[:, 0].astype(jnp.float32), h)[:, None]
        h_fin = h
    else:
        y, h_fin = _ssd_chunk(cfg, dt, zlog, xh, B_, C_, h0)
    y = y + xh.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(B, S, di)
    y = rms_norm((y * silu(z).astype(jnp.float32)).astype(x.dtype), p["norm"],
                 cfg.norm_eps)
    out = jnp.einsum("bsc,cd->bsd", y, p["out_proj"])
    out = shard(out, "batch", "seq", "embed")
    return out, {"conv": conv_out, "h": h_fin}
