"""Dense MLP (gated SwiGLU / plain GELU) and capacity-based MoE.

The MoE is the sort-based, capacity-dropped formulation (tokens sorted
by expert id, scattered into an (experts, capacity) buffer, batched
expert matmuls, gathered back) — O(T·k·cf) expert FLOPs like the active
parameter count, no dense all-experts waste, and no O(T·E·C) one-hot
dispatch einsum.  Expert weights are sharded over the ``model`` axis
(expert parallelism); GSPMD inserts the dispatch/combine collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding.api import shard
from .common import Builder, gelu, silu


# --------------------------------------------------------------------------- #
# Dense MLP
# --------------------------------------------------------------------------- #
def mlp_params(b: Builder, cfg, prefix: str, d_ff: int | None = None) -> dict:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    p = {"w_up": b.leaf(f"{prefix}.w_up", (D, F), ("embed", "ff")),
         "w_down": b.leaf(f"{prefix}.w_down", (F, D), ("ff", "embed"))}
    if cfg.gated_mlp:
        p["w_gate"] = b.leaf(f"{prefix}.w_gate", (D, F), ("embed", "ff"))
    return p


def mlp(cfg, p, x):
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    up = shard(up, "batch", "seq", "ff")
    if cfg.gated_mlp:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = silu(gate) * up
    else:
        h = gelu(up)
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return shard(y, "batch", "seq", "embed")


# --------------------------------------------------------------------------- #
# MoE
# --------------------------------------------------------------------------- #
def moe_params(b: Builder, cfg, prefix: str) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    if cfg.moe_shard == "etp":
        # expert-TP: every expert's FFN split over 'model' (F axis);
        # the expert axis itself stays unsharded
        wg_axes = (None, "embed", "ff")
        wd_axes = (None, "ff", "embed")
    else:
        # expert parallelism: experts themselves split over 'model'
        wg_axes = ("experts", "embed", "expert_ff")
        wd_axes = ("experts", "expert_ff", "embed")
    return {
        # router stays replicated (D×E is tiny); sharding its E axis makes
        # GSPMD reduce along a sharded top_k axis, which both costs a
        # collective per layer and trips an SPMD-partitioner abort inside
        # partial-manual shard_map (pipeline mode).
        "router": b.leaf(f"{prefix}.router", (D, E), ("embed", None),
                         dtype=jnp.float32),
        "w_gate": b.leaf(f"{prefix}.w_gate", (E, D, F), wg_axes),
        "w_up": b.leaf(f"{prefix}.w_up", (E, D, F), wg_axes),
        "w_down": b.leaf(f"{prefix}.w_down", (E, F, D), wd_axes),
    }


def _capacity(tokens_per_group: int, cfg) -> int:
    c = int(tokens_per_group * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(-(-c // 4) * 4, 4)


def moe_mlp_gshard(cfg, p, x):
    """GShard-style one-hot dispatch/combine (einsum only — no gather,
    sort, or scatter ops anywhere).

    Used in pipeline mode: XLA's SPMD gather partitioner hard-aborts when
    evaluating gather strategies inside a partial-manual mesh, so the
    sort-based path (cheaper) is unusable there.  Cost: the dispatch and
    combine einsums add ≈2·Tg·E·C·D FLOPs per group (~6–20 % of expert
    FLOPs at the default group size), which the analytic roofline model
    accounts for.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    # dispatch/combine einsums are O(Tg²·k·cf·D) — keep groups small
    Tg = min(cfg.moe_gshard_group, T)
    G = T // Tg
    xg = x.reshape(G, Tg, D)
    xg = shard(xg, "moe_group", "seq", "embed")

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    density = jnp.mean(jax.nn.one_hot(top_e[..., 0], E), axis=1)
    aux = jnp.mean(density * jnp.mean(probs, axis=1)) * E * E

    C = _capacity(Tg, cfg)
    # position of each (token, k) within its expert: running count over
    # the flattened (t, k) choice order — pure cumsum, no sorts.
    onehots = jax.nn.one_hot(top_e, E, dtype=jnp.float32)    # (G, Tg, K, E)
    flat = onehots.reshape(G, Tg * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                    # (G, TgK, E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(G, Tg, K)     # per choice
    keep = pos < C

    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32)       # (G, Tg, K, C)
    disp = jnp.einsum("gtke,gtkc->gtec",
                      onehots * keep[..., None], pos_oh)     # (G,Tg,E,C)
    comb = jnp.einsum("gtk,gtke,gtkc->gtec",
                      top_w * keep, onehots, pos_oh)

    etp = cfg.moe_shard == "etp"
    e_ax, f_ax = (None, "ff") if etp else ("experts", "expert_ff")
    buf = jnp.einsum("gtec,gtd->gecd", disp.astype(x.dtype), xg)
    buf = shard(buf, "moe_group", e_ax, "capacity", "embed")
    gate = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
    up = jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    h = silu(gate) * up
    h = shard(h, "moe_group", e_ax, "capacity", f_ax)
    ybuf = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    ybuf = shard(ybuf, "moe_group", e_ax, "capacity", "embed")
    yg = jnp.einsum("gtec,gecd->gtd", comb.astype(x.dtype), ybuf)
    yg = shard(yg, "moe_group", "seq", "embed")
    return yg.reshape(B, S, D), aux


def moe_mlp(cfg, p, x):
    """x: (B, S, D) → (B, S, D).  Returns (y, aux) with load-balance loss."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    flat = x.reshape(T, D)
    Tg = min(cfg.moe_group_size, T)
    G = T // Tg
    xg = flat.reshape(G, Tg, D)
    xg = shard(xg, "moe_group", "seq", "embed")

    # --- routing -------------------------------------------------------- #
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                 # (G, Tg, K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # load-balance aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(top_e[..., 0], E), axis=1)   # (G, E)
    mean_prob = jnp.mean(probs, axis=1)                            # (G, E)
    aux = jnp.mean(density * mean_prob) * E * E

    # --- dispatch (sort + gather, capacity-dropped; NO scatters) --------- #
    # Scatter-based dispatch makes GSPMD materialize/all-gather the full
    # buffer (and trips an SPMD-partitioner abort under partial-manual
    # shard_map); the gather formulation keeps everything local-gatherable.
    C = _capacity(Tg, cfg)
    TK = Tg * K
    e_flat = top_e.reshape(G, TK)                          # expert per slot
    w_flat = top_w.reshape(G, TK).astype(x.dtype)
    tok_of_slot = jnp.repeat(jnp.arange(Tg), K)[None].repeat(G, 0)

    order = jnp.argsort(e_flat, axis=-1, stable=True)      # (G, TK)
    e_sorted = jnp.take_along_axis(e_flat, order, axis=-1)
    tok_sorted = jnp.take_along_axis(tok_of_slot, order, axis=-1)
    # first sorted index of each expert → (G, E)
    starts = jax.vmap(lambda es: jnp.searchsorted(es, jnp.arange(E)))(e_sorted)

    # buffer slot (e, c) is filled by sorted slot j = starts[e] + c
    j_idx = starts[:, :, None] + jnp.arange(C)[None, None, :]   # (G, E, C)
    nxt = jnp.concatenate([starts[:, 1:], jnp.full((G, 1), TK)], axis=1)
    valid = j_idx < nxt[:, :, None]                             # c < count_e
    j_safe = jnp.minimum(j_idx, TK - 1).reshape(G, E * C)
    tok_src = jnp.take_along_axis(tok_sorted, j_safe, axis=-1)  # (G, E*C)
    buf = jnp.take_along_axis(xg, tok_src[..., None], axis=1)   # (G, E*C, D)
    buf = buf.reshape(G, E, C, D) * valid[..., None].astype(x.dtype)
    etp = cfg.moe_shard == "etp"
    e_ax, f_ax = (None, "ff") if etp else ("experts", "expert_ff")
    buf = shard(buf, "moe_group", e_ax, "capacity", "embed")

    # --- expert compute --------------------------------------------------- #
    gate = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
    up = jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    h = silu(gate) * up
    h = shard(h, "moe_group", e_ax, "capacity", f_ax)
    ybuf = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    ybuf = shard(ybuf, "moe_group", e_ax, "capacity", "embed")

    # --- combine (pure gathers) ------------------------------------------- #
    inv_order = jnp.argsort(order, axis=-1)                    # unsort map
    pos_sorted = jnp.arange(TK)[None] - jnp.take_along_axis(
        starts, e_sorted, axis=-1)                             # (G, TK)
    pos_unsorted = jnp.take_along_axis(pos_sorted, inv_order, axis=-1)
    flat_idx = e_flat * C + pos_unsorted                       # (G, TK)
    kept = pos_unsorted < C
    flat_safe = jnp.where(kept, flat_idx, 0)
    y_slot = jnp.take_along_axis(ybuf.reshape(G, E * C, D),
                                 flat_safe[..., None], axis=1)
    y_slot = y_slot * (kept & True)[..., None].astype(x.dtype) \
        * w_flat[..., None]
    yg = jnp.sum(y_slot.reshape(G, Tg, K, D), axis=2)
    yg = shard(yg, "moe_group", "seq", "embed")
    return yg.reshape(B, S, D), aux
