"""GQA attention: projections, RoPE, chunked prefill, cached decode.

The prefill path is a pure-JAX flash attention: the query axis is
unrolled over chunks (Python loop → static), the kv axis is scanned with
an online-softmax carry, and causal chunks above the diagonal are never
materialized — so HLO FLOPs match the causal-optimal count and working
memory is O(chunk²) instead of O(S²).  This is also the oracle the
Pallas kernel (``repro.kernels.flash_attention``) is validated against;
on TPU the kernel replaces it via ``cfg.attn_impl="pallas"``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..sharding.api import shard
from .common import Builder, apply_rope, rms_norm


# --------------------------------------------------------------------------- #
# Params
# --------------------------------------------------------------------------- #
def attn_params(b: Builder, cfg, prefix: str) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    # Head-count-aware weight sharding: when H divides the TP axis, the
    # classic Megatron column-split over heads applies; otherwise (36H/
    # 24H/12H on 16-way TP) heads would replicate the projections AND
    # their fp32 optimizer moments — shard the contraction dims instead
    # (row-parallel: D for q/k/v, head_dim for o; GSPMD turns the psums
    # into reduce-scatters against the seq-parallel residual).
    from ..sharding.api import get_context
    ctx = get_context()
    tp = ctx.size("model") if ctx is not None else 1
    row_par = tp > 1 and H % tp != 0
    qe = "embed_rp" if row_par else "embed"
    od = "head_dim_rp" if row_par else "head_dim"
    p = {
        "wq": b.leaf(f"{prefix}.wq", (D, H, hd), (qe, "heads", "head_dim")),
        "wk": b.leaf(f"{prefix}.wk", (D, KV, hd), (qe, "kv_heads", "head_dim")),
        "wv": b.leaf(f"{prefix}.wv", (D, KV, hd), (qe, "kv_heads", "head_dim")),
        "wo": b.leaf(f"{prefix}.wo", (H, hd, D), ("heads", od, "embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = b.leaf(f"{prefix}.q_norm", (hd,), ("head_dim",), init="ones")
        p["k_norm"] = b.leaf(f"{prefix}.k_norm", (hd,), ("head_dim",), init="ones")
    return p


def qkv_project(cfg, p, x, positions, *, rope: bool = True):
    """x: (B, S, D) → q (B,S,H,hd), k/v (B,S,KV,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    from ..sharding.api import attn_q_names
    q = shard(q, *attn_q_names(cfg.n_heads))
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def o_project(p, attn_out):
    y = jnp.einsum("bshk,hkd->bsd", attn_out, p["wo"])
    return shard(y, "batch", "seq", "embed")


# --------------------------------------------------------------------------- #
# Chunked prefill attention (flash-style, causal-exact FLOPs)
# --------------------------------------------------------------------------- #
def _block_attn(q, k, v, bias, scale):
    """One (q-chunk × kv-chunk) block. q:(B,c,KV,G,hd) k/v:(B,j,KV,hd)
    → (scores_max, exp_scores@v, exp_sum) in fp32."""
    s = jnp.einsum("bckgd,bjkd->bkgcj", q, k,
                   preferred_element_type=jnp.float32) * scale
    if bias is not None:
        s = s + bias
    m = jnp.max(s, axis=-1)
    e = jnp.exp(s - m[..., None])
    ev = jnp.einsum("bkgcj,bjkd->bckgd", e, v.astype(jnp.float32))
    return m, ev, jnp.sum(e, axis=-1)


def attend_prefill(cfg, q, k, v, *, causal: bool = True):
    """q: (B,S,H,hd); k,v: (B,T,KV,hd) → (B,S,H,hd)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, S, KV, G, hd)

    chunk = cfg.attn_chunk
    if S % chunk != 0 or T % chunk != 0 or S != T and causal:
        chunk = 0
    if chunk == 0 or S <= chunk:
        # single full block
        bias = None
        if causal:
            pos_q = jnp.arange(S)[:, None]
            pos_k = jnp.arange(T)[None, :]
            bias = jnp.where(pos_q >= pos_k, 0.0, -jnp.inf)[None, None, None]
        m, ev, l = _block_attn(qg, k, v, bias, scale)
        out = ev / jnp.moveaxis(l, -1, 1)[..., None]
        return out.reshape(B, S, H, hd).astype(q.dtype)

    nq, nk = S // chunk, T // chunk
    outs = []
    for i in range(nq):
        qi = qg[:, i * chunk:(i + 1) * chunk]
        n_kv = (i + 1) if causal else nk
        ks = k[:, :n_kv * chunk].reshape(B, n_kv, chunk, KV, hd).swapaxes(0, 1)
        vs = v[:, :n_kv * chunk].reshape(B, n_kv, chunk, KV, hd).swapaxes(0, 1)
        js = jnp.arange(n_kv)

        # diagonal-block causal bias (off-diagonal blocks are fully visible)
        pos_q = jnp.arange(chunk)[:, None]
        pos_k = jnp.arange(chunk)[None, :]
        tri = jnp.where(pos_q >= pos_k, 0.0, -jnp.inf)[None, None, None]

        def body(carry, xs, qi=qi, i=i, tri=tri):
            m_run, l_run, acc = carry
            kj, vj, j = xs
            bias = None
            if causal:
                bias = jnp.where(j == i, tri, 0.0)
            m_j, ev_j, l_j = _block_attn(qi, kj, vj, bias, scale)
            m_new = jnp.maximum(m_run, m_j)
            a_run = jnp.exp(m_run - m_new)
            a_j = jnp.exp(m_j - m_new)
            l_new = l_run * a_run + l_j * a_j
            # m/l are (B,KV,G,c); acc is (B,c,KV,G,hd)
            corr = jnp.moveaxis(a_run, -1, 1)[..., None]
            corr_j = jnp.moveaxis(a_j, -1, 1)[..., None]
            acc = acc * corr + ev_j * corr_j
            return (m_new, l_new, acc), None

        init = (jnp.full((B, KV, G, chunk), -jnp.inf, jnp.float32),
                jnp.zeros((B, KV, G, chunk), jnp.float32),
                jnp.zeros((B, chunk, KV, G, hd), jnp.float32))
        (m_f, l_f, acc), _ = jax.lax.scan(body, init, (ks, vs, js))
        out_i = acc / jnp.moveaxis(l_f, -1, 1)[..., None]
        outs.append(out_i)
    out = jnp.concatenate(outs, axis=1)
    return out.reshape(B, S, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------- #
# Decode attention against a KV cache
# --------------------------------------------------------------------------- #
def attend_decode(cfg, q, k_cache, v_cache, pos):
    """q: (B,1,H,hd); caches: (B,Smax,KV,hd); pos: scalar index of the
    current token (cache already contains it)."""
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(k_cache.shape[1]) <= pos
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def cache_update(k_cache, v_cache, k_new, v_new, pos):
    """Insert (B,1,KV,hd) at position ``pos``; caches (B,Smax,KV,hd)."""
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new.astype(k_cache.dtype),
                                           (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new.astype(v_cache.dtype),
                                           (0, pos, 0, 0))
    return k_cache, v_cache
