"""ArchConfig → ParetoPipe BlockGraph.

This is the bridge that makes the paper's partitioner a first-class
feature of the LM framework: every architecture becomes a chain of
blocks (its layers, plus embed/head endpoints) with per-block FLOPs,
weight bytes, and inter-block activation bytes — exactly what
``core.partitioner`` needs to choose pod-level pipeline cuts.

Costs come from the same formulas as the dry-run's analytic model
(``launch.analytic``), so the partitioner and the roofline agree.
"""
from __future__ import annotations

from ..core.blocks import Block, BlockGraph
from ..launch.analytic import (_layer_fwd_flops, _logit_flops,
                               _shared_block_flops)
from .config import ArchConfig


def _layer_weight_bytes(cfg: ArchConfig) -> int:
    n = cfg.param_count()
    head = 0 if cfg.tie_embeddings else cfg.d_model * cfg.vocab
    trunk = n - cfg.vocab * cfg.d_model - head
    if cfg.family == "hybrid":
        trunk -= (2 * cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads)
                  * cfg.hd // 2)  # shared block roughly excluded below
    return int(trunk / cfg.n_layers * 2)


def arch_block_graph(cfg: ArchConfig, seq: int, *, train: bool = False,
                     per_sample: bool = True) -> BlockGraph:
    """Per-sample block graph at sequence length ``seq``.

    Blocks: [embed] + n_layers × [layer] + [head].  For hybrid archs the
    shared attention block is folded into the layers it precedes (with
    ``shared_group`` so its weights are counted once per stage).
    """
    ctx = (seq + cfg.attn_chunk) / 2 if seq > cfg.attn_chunk else (seq + 1) / 2
    act = seq * cfg.d_model * 2              # bf16 inter-layer activation
    mult = 3.0 + (1.0 if (train and cfg.remat) else 0.0) if train else 1.0

    blocks = [Block("embed", flops=seq * cfg.d_model * mult,
                    weight_bytes=cfg.vocab * cfg.d_model * 2,
                    out_bytes=act, act_bytes=act * 2)]
    lw = _layer_weight_bytes(cfg)
    per_layer = _layer_fwd_flops(cfg, ctx) * seq * mult
    shared_extra = 0.0
    if cfg.family == "hybrid":
        shared_extra = _shared_block_flops(cfg, ctx) * seq * mult
    for i in range(cfg.n_layers):
        flops = per_layer
        shared_group = None
        wb = lw
        if cfg.family == "hybrid" and i % cfg.shared_attn_every == 0:
            flops += shared_extra
            shared_group = "shared_attn"
            wb += int((2 * cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads)
                       * cfg.hd + 3 * cfg.d_model * cfg.d_ff) * 2)
        blocks.append(Block(f"layer{i:03d}", flops=flops, weight_bytes=wb,
                            out_bytes=act, act_bytes=act * 4,
                            shared_group=shared_group))
    head_w = 0 if cfg.tie_embeddings else cfg.d_model * cfg.vocab * 2
    blocks.append(Block("head", flops=_logit_flops(cfg, seq) * (3 if train else 1),
                        weight_bytes=head_w,
                        out_bytes=seq * 4,        # predictions
                        act_bytes=seq * cfg.vocab * 4))
    return BlockGraph(name=cfg.name, blocks=tuple(blocks),
                      input_bytes=seq * 4, output_bytes=seq * 4)


def choose_pipeline_cuts(cfg: ArchConfig, seq: int, n_pods: int,
                         chips_per_pod: int = 256, batch: int = 1,
                         train: bool = True,
                         objective: str = "throughput"):
    """ParetoPipe-driven stage assignment: solve the k-way partition over
    the arch's block graph on the pod chain, return layer cut indices
    usable by ``PipelineConfig`` (embed/head pinned to first/last pod)."""
    from ..core import dp_front_kway, best_latency, best_throughput
    from ..core.scenarios import pods

    graph = arch_block_graph(cfg, seq, train=train)
    scen = pods(n_pods, chips_per_pod)
    front = dp_front_kway(graph, scen.devices, scen.links, batch=batch)
    pick = best_throughput(front) if objective == "throughput" \
        else best_latency(front)
    # block index → layer index (block 0 is embed)
    cuts = tuple(min(max(c - 1, 1), cfg.n_layers - 1) for c in pick.partition)
    return cuts, pick, front
