"""Fused RMSNorm Pallas kernel: one VMEM pass (read x, write normed x).

Unfused XLA does mean-of-squares and the scale multiply as separate HBM
round trips unless fusion kicks in; this kernel guarantees the single
pass.  Grid tiles the flattened row axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                   # (rows, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps", "interpret"))
def fused_rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
                  interpret: bool = True):
    """x: (..., d); scale: (d,) → same shape as x."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = int(x.size // d)
    xf = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    if rows % block_rows != 0:
        block_rows = 1
    kernel = functools.partial(_rms_kernel, eps=eps)
    y = pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(xf, scale)
    return y.reshape(orig_shape)
