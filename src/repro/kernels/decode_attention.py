"""Single-token GQA decode attention over a KV cache (Pallas TPU kernel).

Flash-decoding style: the cache's sequence axis is tiled into VMEM
blocks and iterated as the innermost sequential grid dimension with an
online-softmax carry; positions beyond the current ``pos`` are masked.
The current position arrives via scalar prefetch (SMEM), so block index
maps could in principle skip fully-masked tail blocks; we predicate them
with ``pl.when`` (equivalent FLOPs, simpler maps).

Grid: (batch, q_heads, ns).  q: (B, H, hd); caches: (B, Smax, KV, hd).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512
NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, block_s: int):
    j = pl.program_id(2)
    pos = pos_ref[0]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j * block_s <= pos)          # skip blocks fully past `pos`
    def _body():
        q = q_ref[0, 0, :].astype(jnp.float32)            # (hd,)
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # (bs, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.einsum("d,sd->s", q, k) * scale           # (bs,)
        idx = j * block_s + jax.lax.broadcasted_iota(jnp.int32, (block_s,), 0)
        s = jnp.where(idx <= pos, s, NEG_INF)
        s = s[None, :]                                     # (1, bs)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + p @ v
        m_scr[...] = m_new

    ns = pl.num_programs(2)

    @pl.when(j == ns - 1)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, :] = out[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q, k_cache, v_cache, pos, *,
                     block_s: int = DEFAULT_BLOCK_S, interpret: bool = True):
    """q: (B, H, hd); caches: (B, Smax, KV, hd); pos: scalar int32.
    → (B, H, hd)."""
    B, H, hd = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    block_s = min(block_s, Smax)
    assert Smax % block_s == 0
    ns = Smax // block_s
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_decode_kernel, scale=scale, block_s=block_s)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, ns),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, j, pos: (b, h, 0)),
            pl.BlockSpec((1, block_s, 1, hd),
                         lambda b, h, j, pos: (b, j, h // G, 0)),
            pl.BlockSpec((1, block_s, 1, hd),
                         lambda b, h, j, pos: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, h, j, pos: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32)[None], q, k_cache, v_cache)
