"""Pallas pack/unpack kernels for the wire codecs (int8 / fp8 / top-k).

The transport's per-hop codecs (``core/codecs.py``) quantize activation
payloads before they hit the wire.  The elementwise quantize-pack and
dequantize-unpack passes run as Pallas kernels — one VMEM pass each,
grid-tiled over a flattened ``(rows, 128)`` layout — so on TPU the pack
cost is a single fused read/write instead of XLA's round trips, and on
CPU (this container) the same bodies execute under ``interpret=True``.

Scale extraction (a global abs-max) and the top-k index selection are
reductions/sorts, which Pallas has no portable primitive for — those
run as plain XLA (``jnp.max`` / ``jax.lax.top_k``) around the kernels,
mirroring how ``fused_rmsnorm`` keeps only the fusable pass in-kernel.

Wire scale conventions (shared with the analytic byte model):

  * ``int8``: symmetric per-tensor, ``scale = max|x| / 127``;
  * ``fp8``:  e4m3 cast after ``scale = max|x| / 448`` (e4m3 max);
  * ``topk``: keep the ``k`` largest-magnitude entries of the flat
    tensor (indices ascending, fp32 values).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128
_EPS = 1e-12


def _pad_rows(flat, block_rows: int):
    """Flat fp32 vector → zero-padded ``(rows, 128)`` with
    ``rows % block_rows == 0`` (zeros quantize to zeros; the caller
    slices back to the true length)."""
    n = flat.size
    per_block = block_rows * _LANES
    padded = -(-max(n, 1) // per_block) * per_block
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(-1, _LANES), padded // _LANES


def _scale_spec():
    # one (1, 1) fp32 scale broadcast to every grid step
    return pl.BlockSpec((1, 1), lambda i: (0, 0))


def _q8_kernel(x_ref, inv_ref, q_ref):
    q = jnp.round(x_ref[...].astype(jnp.float32) * inv_ref[0, 0])
    q_ref[...] = jnp.clip(q, -127.0, 127.0).astype(jnp.int8)


def _dq8_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0, 0]


def _q8f_kernel(x_ref, inv_ref, q_ref):
    y = x_ref[...].astype(jnp.float32) * inv_ref[0, 0]
    q_ref[...] = y.astype(jnp.float8_e4m3fn)


def _dq8f_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0, 0]


def _elementwise(kernel, x2d, scale, rows: int, block_rows: int,
                 out_dtype, interpret: bool):
    block_rows = min(block_rows, rows)
    if rows % block_rows != 0:
        block_rows = 1
    return pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0)),
                  _scale_spec()],
        out_specs=pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _LANES), out_dtype),
        interpret=interpret,
    )(x2d, scale)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def int8_pack(x, *, block_rows: int = 256, interpret: bool = True):
    """x: any shape/float dtype → (int8 flat[n], fp32 scale scalar)."""
    flat = x.reshape(-1).astype(jnp.float32)
    if flat.size == 0:                         # static shape: trace-time
        return flat.astype(jnp.int8), jnp.float32(_EPS / 127.0)
    scale = jnp.maximum(jnp.max(jnp.abs(flat)), _EPS) / 127.0
    x2d, rows = _pad_rows(flat, block_rows)
    inv = (1.0 / scale).reshape(1, 1)
    q = _elementwise(_q8_kernel, x2d, inv, rows, block_rows,
                     jnp.int8, interpret)
    return q.reshape(-1)[:flat.size], scale


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def int8_unpack(q, scale, *, block_rows: int = 256, interpret: bool = True):
    """(int8 flat[n], scale) → fp32 flat[n]."""
    n = q.size
    if n == 0:
        return q.astype(jnp.float32)
    q2d, rows = _pad_rows(q.astype(jnp.float32), block_rows)
    q2d = q2d.astype(jnp.int8)
    s = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    y = _elementwise(_dq8_kernel, q2d, s, rows, block_rows,
                     jnp.float32, interpret)
    return y.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fp8_pack(x, *, block_rows: int = 256, interpret: bool = True):
    """x: any shape/float dtype → (float8_e4m3fn flat[n], fp32 scale)."""
    flat = x.reshape(-1).astype(jnp.float32)
    if flat.size == 0:
        return flat.astype(jnp.float8_e4m3fn), jnp.float32(_EPS / 448.0)
    scale = jnp.maximum(jnp.max(jnp.abs(flat)), _EPS) / 448.0
    x2d, rows = _pad_rows(flat, block_rows)
    inv = (1.0 / scale).reshape(1, 1)
    q = _elementwise(_q8f_kernel, x2d, inv, rows, block_rows,
                     jnp.float8_e4m3fn, interpret)
    return q.reshape(-1)[:flat.size], scale


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fp8_unpack(q, scale, *, block_rows: int = 256, interpret: bool = True):
    """(float8_e4m3fn flat[n], scale) → fp32 flat[n]."""
    n = q.size
    if n == 0:
        return q.astype(jnp.float32)
    q2d, rows = _pad_rows(q.astype(jnp.float32), block_rows)
    q2d = q2d.astype(jnp.float8_e4m3fn)
    s = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    y = _elementwise(_dq8f_kernel, q2d, s, rows, block_rows,
                     jnp.float32, interpret)
    return y.reshape(-1)[:n]


def _mag_kernel(x_ref, s_ref, o_ref):
    o_ref[...] = jnp.abs(x_ref[...].astype(jnp.float32)) * s_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "interpret"))
def topk_select(x, *, k: int, block_rows: int = 256, interpret: bool = True):
    """Keep the ``k`` largest-|x| entries of the flattened tensor →
    (uint32 indices ascending, fp32 values).  The magnitude pass runs
    in-kernel; the selection itself is ``jax.lax.top_k`` (XLA)."""
    flat = x.reshape(-1).astype(jnp.float32)
    x2d, rows = _pad_rows(flat, block_rows)
    one = jnp.ones((1, 1), jnp.float32)
    mag = _elementwise(_mag_kernel, x2d, one, rows, block_rows,
                       jnp.float32, interpret).reshape(-1)[:flat.size]
    _, idx = jax.lax.top_k(mag, k)
    idx = jnp.sort(idx)
    return idx.astype(jnp.uint32), flat[idx]
