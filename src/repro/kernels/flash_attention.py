"""Flash attention (prefill/train) as a Pallas TPU kernel.

TPU-native design (vs. the CUDA original): the kv axis is the innermost
*sequential* grid dimension, so the online-softmax running state
(m, l, acc) lives in VMEM scratch and is carried across kv steps —
the TPU analogue of a CUDA thread-block loop with shared-memory
accumulators.  Block shapes are MXU-aligned (q/kv tiles of 128 rows by
default); causal blocks above the diagonal are predicated away with
``pl.when`` so they cost no MXU cycles.

Grid: (batch, q_heads, nq, nk).  GQA is expressed in the k/v index maps
(q head h reads kv head ``h // group``), so no head replication is ever
materialized.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  nk: int):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block (sequential innermost)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (j <= i) if causal else (j <= nk)   # causal: skip above diagonal

    @pl.when(run if causal else j >= 0)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32)         # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                          (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                          (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    last = i if causal else nk - 1

    @pl.when(j == last)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True):
    """q: (B, S, H, hd); k, v: (B, T, KV, hd) → (B, S, H, hd).

    S % block_q == 0 and T % block_k == 0 are required (pad upstream);
    for causal use S == T.
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0
    nq, nk = S // block_q, T // block_k

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_k=block_k, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
