"""Mamba-1 selective-scan chunk step as a Pallas TPU kernel.

The CUDA reference fuses the whole sequence scan into one kernel with
warp-parallel prefix products.  The TPU-native shape of the same idea:
tile ``d_inner`` across the grid, keep the (bd, N) state resident in
VMEM, and walk the chunk *sequentially* inside the kernel — every step
is a small VPU-elementwise update on VMEM-resident data, so HBM traffic
is exactly one read of the inputs and one write of the outputs
(bandwidth-optimal; the recurrence itself never touches HBM).

Grid: (batch, d_inner / bd).  Inputs are one chunk: dt/x: (B, L, di),
Bc/Cc: (B, L, N), A: (di, N), h0: (B, di, N) → outputs y: (B, L, di),
h_out: (B, di, N).  The layer loops chunks with ``lax.scan`` carrying
``h`` (see models/ssm.py), so kernel memory is independent of S.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_D = 512


def _ssm_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, h_ref,
                *, L: int):
    h = h0_ref[0].astype(jnp.float32)                    # (bd, N)
    A = a_ref[...].astype(jnp.float32)                   # (bd, N)

    def step(t, h):
        dt = dt_ref[0, t, :].astype(jnp.float32)         # (bd,)
        x = x_ref[0, t, :].astype(jnp.float32)
        Bc = b_ref[0, t, :].astype(jnp.float32)          # (N,)
        Cc = c_ref[0, t, :].astype(jnp.float32)
        dA = jnp.exp(dt[:, None] * A)                    # (bd, N)
        h = dA * h + (dt * x)[:, None] * Bc[None, :]
        y = jnp.sum(h * Cc[None, :], axis=1)             # (bd,)
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, L, step, h)
    h_ref[0] = h.astype(h_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def ssm_scan_chunk(dt, x, Bc, Cc, A, h0, *, block_d: int = DEFAULT_BLOCK_D,
                   interpret: bool = True):
    """One chunk of the Mamba-1 recurrence.

    dt, x: (B, L, di) — dt already softplus'ed; A: (di, N) (negative);
    Bc, Cc: (B, L, N); h0: (B, di, N) fp32.
    → (y: (B, L, di) fp32, h_out: (B, di, N) fp32).
    """
    B, L, di = x.shape
    N = A.shape[1]
    block_d = min(block_d, di)
    assert di % block_d == 0
    nd = di // block_d

    kernel = functools.partial(_ssm_kernel, L=L)
    y, h_out = pl.pallas_call(
        kernel,
        grid=(B, nd),
        in_specs=[
            pl.BlockSpec((1, L, block_d), lambda b, d: (b, 0, d)),   # dt
            pl.BlockSpec((1, L, block_d), lambda b, d: (b, 0, d)),   # x
            pl.BlockSpec((1, L, N), lambda b, d: (b, 0, 0)),         # B
            pl.BlockSpec((1, L, N), lambda b, d: (b, 0, 0)),         # C
            pl.BlockSpec((block_d, N), lambda b, d: (d, 0)),         # A
            pl.BlockSpec((1, block_d, N), lambda b, d: (b, d, 0)),   # h0
        ],
        out_specs=[
            pl.BlockSpec((1, L, block_d), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, block_d, N), lambda b, d: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, di), jnp.float32),
            jax.ShapeDtypeStruct((B, di, N), jnp.float32),
        ],
        interpret=interpret,
    )(dt, x, Bc, Cc, A, h0)
    return y, h_out
