"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Deliberately naive: dense score matrices, full materialization, explicit
sequential scans — slow but unarguable.  Kernel tests sweep shapes and
dtypes and ``assert_allclose`` against these.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B,S,H,hd); k,v: (B,T,KV,hd) → (B,S,H,hd)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", w, vv.astype(jnp.float32))
    return o.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos):
    """q: (B,H,hd); caches: (B,Smax,KV,hd); pos scalar → (B,H,hd)."""
    B, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    kk = jnp.repeat(k_cache, G, axis=2)
    vv = jnp.repeat(v_cache, G, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / math.sqrt(hd)
    mask = jnp.arange(k_cache.shape[1]) <= pos
    s = jnp.where(mask[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhs,bshd->bhd", w, vv.astype(jnp.float32))
    return o.astype(q.dtype)


def ssm_scan_chunk_ref(dt, x, Bc, Cc, A, h0):
    """Sequential Mamba-1 recurrence (fp32).  Shapes as in ssm_scan."""
    def step(h, xs):
        dt_t, x_t, B_t, C_t = xs
        dA = jnp.exp(dt_t[:, :, None] * A[None])             # (B, di, N)
        h = dA * h + (dt_t * x_t)[:, :, None] * B_t[:, None, :]
        y = jnp.sum(h * C_t[:, None, :], axis=-1)            # (B, di)
        return h, y

    xs = (dt.swapaxes(0, 1).astype(jnp.float32),
          x.swapaxes(0, 1).astype(jnp.float32),
          Bc.swapaxes(0, 1).astype(jnp.float32),
          Cc.swapaxes(0, 1).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.swapaxes(0, 1), h


def fused_rmsnorm_ref(x, scale, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def int8_pack_ref(x):
    """Symmetric per-tensor int8 quantize → (int8 flat[n], fp32 scale)."""
    flat = x.reshape(-1).astype(jnp.float32)
    if flat.size == 0:
        return flat.astype(jnp.int8), jnp.float32(1e-12 / 127.0)
    scale = jnp.maximum(jnp.max(jnp.abs(flat)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_unpack_ref(q, scale):
    return q.astype(jnp.float32) * jnp.asarray(scale, jnp.float32)


def fp8_pack_ref(x):
    """Scaled e4m3 cast → (float8_e4m3fn flat[n], fp32 scale)."""
    flat = x.reshape(-1).astype(jnp.float32)
    if flat.size == 0:
        return flat.astype(jnp.float8_e4m3fn), jnp.float32(1e-12 / 448.0)
    scale = jnp.maximum(jnp.max(jnp.abs(flat)), 1e-12) / 448.0
    return (flat / scale).astype(jnp.float8_e4m3fn), scale


def fp8_unpack_ref(q, scale):
    return q.astype(jnp.float32) * jnp.asarray(scale, jnp.float32)


def topk_select_ref(x, *, k: int):
    """k largest-|x| entries of the flat tensor → (uint32 idx asc, fp32)."""
    flat = x.reshape(-1).astype(jnp.float32)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    idx = jnp.sort(idx)
    return idx.astype(jnp.uint32), flat[idx]
