"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container) so the kernels
execute their real bodies via the interpreter; on TPU backends the same
calls compile to Mosaic.  Model code selects kernels with
``cfg.attn_impl == "pallas"``.
"""
from __future__ import annotations

import jax

from .codec_pack import fp8_pack as _fp8_pack
from .codec_pack import fp8_unpack as _fp8_unpack
from .codec_pack import int8_pack as _int8_pack
from .codec_pack import int8_unpack as _int8_unpack
from .codec_pack import topk_select as _topk_select
from .decode_attention import decode_attention as _decode
from .flash_attention import flash_attention as _flash
from .fused_rmsnorm import fused_rmsnorm as _rms
from .ssm_scan import ssm_scan_chunk as _ssm


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128,
                    interpret=None):
    return _flash(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
                  interpret=_default_interpret() if interpret is None else interpret)


def decode_attention(q, k_cache, v_cache, pos, *, block_s=512, interpret=None):
    return _decode(q, k_cache, v_cache, pos, block_s=block_s,
                   interpret=_default_interpret() if interpret is None else interpret)


def ssm_scan_chunk(dt, x, Bc, Cc, A, h0, *, block_d=512, interpret=None):
    return _ssm(dt, x, Bc, Cc, A, h0, block_d=block_d,
                interpret=_default_interpret() if interpret is None else interpret)


def fused_rmsnorm(x, scale, *, eps=1e-6, block_rows=256, interpret=None):
    return _rms(x, scale, eps=eps, block_rows=block_rows,
                interpret=_default_interpret() if interpret is None else interpret)


def int8_pack(x, *, block_rows=256, interpret=None):
    return _int8_pack(x, block_rows=block_rows,
                      interpret=_default_interpret() if interpret is None else interpret)


def int8_unpack(q, scale, *, block_rows=256, interpret=None):
    return _int8_unpack(q, scale, block_rows=block_rows,
                        interpret=_default_interpret() if interpret is None else interpret)


def fp8_pack(x, *, block_rows=256, interpret=None):
    return _fp8_pack(x, block_rows=block_rows,
                     interpret=_default_interpret() if interpret is None else interpret)


def fp8_unpack(q, scale, *, block_rows=256, interpret=None):
    return _fp8_unpack(q, scale, block_rows=block_rows,
                       interpret=_default_interpret() if interpret is None else interpret)


def topk_select(x, *, k, block_rows=256, interpret=None):
    return _topk_select(x, k=k, block_rows=block_rows,
                        interpret=_default_interpret() if interpret is None else interpret)
