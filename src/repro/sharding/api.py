"""Logical-axis sharding: one rules table, applied to weights and activations.

Tensors are annotated with *logical* axis names; a ``MeshContext`` maps
them onto physical mesh axes with a divisibility guard (a dim that does
not divide by the mesh-axis size is replicated rather than unevenly
sharded — keeps HLO clean and the roofline honest).  The same mapping
builds ``in_shardings`` for jit (from the param defs) and
``with_sharding_constraint`` annotations inside the step functions, so
they can never disagree.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axis (None = replicate)
RULES: dict[str, str | None] = {
    "batch": "data",
    "moe_group": "data",
    "stage": "pod",
    # tensor-parallel axes
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "d_inner": "model",
    "conv_dim": "model",
    "ssm_heads": "model",
    # replicated / unsharded
    "embed": None,
    "seq": None,
    "frames": None,
    "head_dim": None,
    "state": None,
    "kernel": None,
    "capacity": None,
    "layers": None,
    "dt_rank": None,
    "patches": None,
    "expert_ff": None,   # ff inside an expert: 'model' is taken by experts

    # fallback sequence sharding (used by cache helpers)
    "seq_model": "model",
    # sequence-parallel residual stream (train/prefill layer boundaries)
    "seq_sp": "model",
    # row-parallel attention projections (archs whose head count does not
    # divide the TP axis): shard the contraction dim instead of heads
    "embed_rp": "model",
    "head_dim_rp": "model",
}


@dataclass
class MeshContext:
    mesh: Mesh

    @property
    def axis_sizes(self) -> dict[str, int]:
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    def size(self, mesh_axis: str) -> int:
        return self.axis_sizes.get(mesh_axis, 1)

    # ------------------------------------------------------------------ #
    def spec(self, logical: tuple[str | None, ...],
             shape: tuple[int, ...] | None = None) -> P:
        """Map logical names to a PartitionSpec, replicating any dim that
        is absent from the mesh or not divisible."""
        out = []
        for i, name in enumerate(logical):
            axis = RULES.get(name) if name else None
            if axis is None or axis not in self.mesh.axis_names:
                out.append(None)
                continue
            if shape is not None and shape[i] % self.size(axis) != 0:
                out.append(None)
                continue
            out.append(axis)
        return P(*out)

    def sharding(self, logical: tuple[str | None, ...],
                 shape: tuple[int, ...] | None = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape))


_tls = threading.local()


def set_context(ctx: MeshContext | None):
    _tls.ctx = ctx


def get_context() -> MeshContext | None:
    return getattr(_tls, "ctx", None)


class use_mesh_context:
    """``with use_mesh_context(mesh): ...`` — enables logical sharding
    annotations (and jax.set_mesh) for everything inside."""

    def __init__(self, mesh: Mesh | None):
        self.mesh = mesh
        self._jax_ctx = None

    def __enter__(self):
        if self.mesh is not None:
            set_context(MeshContext(self.mesh))
            # jax >= 0.6 exposes jax.set_mesh / jax.sharding.use_mesh;
            # on older releases the Mesh object itself is the context
            # manager that installs the global mesh.
            set_mesh = (getattr(jax, "set_mesh", None)
                        or getattr(jax.sharding, "use_mesh", None))
            self._jax_ctx = set_mesh(self.mesh) if set_mesh else self.mesh
            self._jax_ctx.__enter__()
        return get_context()

    def __exit__(self, *exc):
        if self._jax_ctx is not None:
            self._jax_ctx.__exit__(*exc)
        set_context(None)
        return False


def shard(x, *logical: str | None):
    """Annotate an activation with logical axes (no-op outside a mesh)."""
    ctx = get_context()
    if ctx is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"{len(logical)} names for rank-{x.ndim} tensor")
    return jax.lax.with_sharding_constraint(x, ctx.spec(tuple(logical), x.shape))


def zero1_spec(spec: P, shape: tuple[int, ...]) -> P:
    """ZeRO-1: extend a param PartitionSpec with 'data' on the first
    still-unsharded, divisible dim — optimizer moments and gradient
    accumulators shard over data×model instead of replicating over data.
    GSPMD then turns the DP gradient all-reduce into reduce-scatter +
    (at the param update) all-gather, which is exactly ZeRO-1."""
    ctx = get_context()
    if ctx is None or "data" not in ctx.mesh.axis_names:
        return spec
    used = set(a for a in spec if a is not None)
    if "data" in used:
        return spec
    dp = ctx.size("data")
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (ax, dim) in enumerate(zip(parts, shape)):
        if ax is None and dim % dp == 0 and dim >= dp:
            parts[i] = "data"
            return P(*parts)
    return spec


def shard_zero1(x, spec: P):
    """In-jit constraint applying zero1_spec to a gradient/moment leaf."""
    ctx = get_context()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, zero1_spec(spec, x.shape))


def attn_q_names(n_heads: int) -> tuple[str, ...]:
    """q activations: shard heads over 'model' when divisible (classic
    TP); otherwise shard the *query sequence* (context parallelism) so
    replicated-head archs (36H/48H on 16-way TP) don't blow up the
    attention workspace and FLOPs by the TP degree."""
    ctx = get_context()
    if ctx is not None and n_heads % max(ctx.size("model"), 1) != 0:
        return ("batch", "seq_sp", "heads", "head_dim")
    return ("batch", "seq", "heads", "head_dim")


def kv_cache_names(kv_heads: int, hd: int) -> tuple[str, ...]:
    """Cache (layers, batch, seq, kv, hd): shard kv heads over 'model'
    when divisible, else shard the sequence (flash-decoding style) —
    resolved at trace time against the active mesh."""
    ctx = get_context()
    if ctx is not None and kv_heads % max(ctx.size("model"), 1) != 0:
        return ("layers", "batch", "seq_model", "kv_heads", "head_dim")
    return ("layers", "batch", "seq", "kv_heads", "head_dim")
