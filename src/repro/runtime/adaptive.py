"""Closed adaptive loop: measure → estimate → re-solve → migrate.

This wires the three halves of the system together into the loop the
paper leaves as future work:

  1. the executable k-stage pipeline (``runtime.edge.EdgePipeline``)
     records what every hop *actually* did per transfer — the modeled
     delay under the ``emulated`` transport, or the **measured**
     wall-clock cost when the hops are real sockets / shared memory
     between worker processes (``transport="socket"``/``"shmem"``),
  2. those observations feed one ``LinkEstimator`` per hop (RTT /
     per-message overhead / bandwidth fitted from observed (nbytes,
     elapsed) pairs — what a real runtime can see),
  3. ``AdaptiveSplitter`` re-solves the whole chain with the estimated
     links (``partitioner.solve``: 2-way sweep, k-way enumeration, or
     Pareto DP as the problem size demands) and, when the predicted gain
     clears hysteresis (and, with ``amortize_horizon_s`` set, amortizes
     both the redeploy stall *and* the weights-over-the-wire joules
     within the horizon), the pipeline live-migrates to the new cut
     vector.

The loop itself is now a thin shim: ``AdaptiveRuntime.run`` opens a
:class:`~repro.runtime.session.Session` with an ``AdaptiveController``
— the same machinery that drives adaptive *streaming* (batches in
flight during migration).  ``run`` keeps the legacy batch-synchronous
cadence (``inflight=1``); pass ``inflight > 1`` for the pipelined loop,
or use ``EdgePipeline.session`` directly.

Energy rides the same loop: every batch's joules are modeled from the
*measured* per-stage compute times, and an ``energy_budget_j`` makes
the re-solve constrained — a budget breach overrides both hysteresis
and the amortization gate.
"""
from __future__ import annotations

from typing import Callable, Sequence

from ..core.autosplit import AdaptiveSplitter, LinkEstimator, Policy
from ..core.blocks import BlockGraph
from ..core.costmodel import CostTable
from ..core.scenarios import Scenario
from .edge import Backend, EdgePipeline
from .session import AdaptiveController, LoopRecord, MigrationPolicy

__all__ = ["AdaptiveRuntime", "LoopRecord", "AdaptiveController"]


class AdaptiveRuntime:
    """Owns an EdgePipeline + AdaptiveSplitter + per-hop LinkEstimators
    and runs them as one loop (a Session with an AdaptiveController)."""

    def __init__(self, model, params, scenario: Scenario, *,
                 graph: BlockGraph | None = None, batch: int | None = None,
                 policy: Policy = "throughput",
                 backend: Backend | Sequence[Backend] = "lightweight",
                 transport: str | Sequence[str] | None = None,
                 costs: CostTable | None = None, hysteresis: float = 0.10,
                 migration_cost_s: float = 0.25, check_every: int = 4,
                 alpha: float = 0.5, queue_depth: int = 2, seed: int = 0,
                 energy_budget_j: float | None = None,
                 amortize_horizon_s: float | None = None):
        self._model, self._params = model, params
        self.scenario = scenario
        self._deploy_opts = dict(batch=batch, policy=policy, costs=costs,
                                 hysteresis=hysteresis,
                                 migration_cost_s=migration_cost_s,
                                 backend=backend, transport=transport,
                                 queue_depth=queue_depth,
                                 alpha=alpha, seed=seed,
                                 energy_budget_j=energy_budget_j,
                                 amortize_horizon_s=amortize_horizon_s)
        self.check_every = check_every
        self.records: list[LoopRecord] = []
        self.graph: BlockGraph | None = graph
        self.splitter: AdaptiveSplitter | None = None
        self.pipe: EdgePipeline | None = None
        self.estimators: list[LinkEstimator] = []
        # graph and batch must both be known to solve; otherwise deploy
        # lazily at run(), modelling the batches actually served
        if graph is not None and batch is not None:
            self._deploy(graph)

    def _deploy(self, graph: BlockGraph) -> None:
        """Solve under nominal (t=0) conditions — the paper's lab choice —
        and stand the pipeline up at the chosen cuts."""
        o = self._deploy_opts
        self.graph = graph
        # include_io=False: the executable pipeline has no orchestrator
        # dispatch/return hop, so the splitter must optimize the same
        # objective the pipeline actually exhibits
        self.splitter = AdaptiveSplitter(
            graph, self.scenario, batch=o["batch"], policy=o["policy"],
            costs=o["costs"], hysteresis=o["hysteresis"],
            migration_cost_s=o["migration_cost_s"], include_io=False,
            energy_budget_j=o["energy_budget_j"],
            amortize_horizon_s=o["amortize_horizon_s"])
        init = self.splitter.solve()
        self.splitter.current = init
        self.splitter.history.append((init.partition, True))
        self.pipe = EdgePipeline(self._model, self._params, init.partition,
                                 self.scenario, backend=o["backend"],
                                 transport=o["transport"],
                                 queue_depth=o["queue_depth"], seed=o["seed"])
        self.estimators = [LinkEstimator.from_link(l, alpha=o["alpha"])
                           for l in self.scenario.links]

    # ------------------------------------------------------------------ #
    def probe_rtt(self) -> None:
        """Send a header-only message down every hop — the emulated wire
        charges RTT/2, a real socket/shmem hop measures it — giving the
        estimators a compute-free RTT sample."""
        if self.pipe is None:
            raise RuntimeError("pipeline not deployed yet — call run() "
                               "(or pass graph= and batch=) first")
        self.pipe.probe()

    # ------------------------------------------------------------------ #
    def run(self, make_batch: Callable[[], object], n_batches: int,
            probe: bool = True, *, inflight: int = 1,
            migration_policy: MigrationPolicy = "drain") -> list[LoopRecord]:
        """Drive ``n_batches`` through the pipeline, re-solving every
        ``check_every`` batches (each check RTT-probes every hop first
        unless ``probe=False`` — without fresh RTT samples the estimator
        attributes queueing delay to bandwidth).  ``inflight=1`` is the
        legacy batch-synchronous cadence; larger keeps the pipeline full
        while the loop adapts, migrating under ``migration_policy``.
        Returns this call's per-batch records (``self.records``
        accumulates across calls); migrations are also visible in
        ``self.pipe.migrations``."""
        x = make_batch()
        if self.pipe is None:
            # model the batches actually being served: infer resolution
            # and batch size from the first batch unless given explicitly
            if self._deploy_opts["batch"] is None:
                self._deploy_opts["batch"] = x.shape[0]
            self._deploy(self.graph if self.graph is not None
                         else self._model.block_graph(input_hw=x.shape[1]))
        self.pipe.warmup(x)
        self.pipe.reset_clock()
        prev = len(self.records)
        ctrl = AdaptiveController(self.splitter, self.estimators,
                                  check_every=self.check_every, probe=probe,
                                  batch_offset=prev)
        with self.pipe.session(ctrl, inflight=inflight,
                               policy=migration_policy,
                               keep_results=False) as s:
            for _ in range(n_batches):
                s.submit(x)
            s.drain()
            self.records.extend(s.records)
        return self.records[prev:]

    # ------------------------------------------------------------------ #
    def close(self) -> None:
        """Tear down the pipeline (worker processes, channels); no-op
        for thread-backed pipelines or before the first deploy."""
        if self.pipe is not None:
            self.pipe.close()

    def __enter__(self) -> "AdaptiveRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------ #
    @property
    def cut_history(self) -> list[tuple[int, ...]]:
        """Distinct cut vectors in deployment order."""
        out: list[tuple[int, ...]] = []
        for r in self.records:
            if not out or r.cuts != out[-1]:
                out.append(r.cuts)
        return out
