"""Closed adaptive loop: measure → estimate → re-solve → migrate.

This wires the three halves of the system together into the loop the
paper leaves as future work:

  1. the executable k-stage pipeline (``runtime.edge.EdgePipeline``)
     records what every hop *actually* did per transfer — the modeled
     delay under the ``emulated`` transport, or the **measured**
     wall-clock cost when the hops are real sockets / shared memory
     between worker processes (``transport="socket"``/``"shmem"``),
  2. those observations feed one ``LinkEstimator`` per hop (RTT /
     per-message overhead / bandwidth fitted from observed (nbytes,
     elapsed) pairs — what a real runtime can see),
  3. ``AdaptiveSplitter`` re-solves the whole chain with the estimated
     links (``partitioner.solve``: 2-way sweep, k-way enumeration, or
     Pareto DP as the problem size demands) and, when the predicted gain
     clears hysteresis, the pipeline live-migrates to the new cut vector,
     charging ``migration_cost_s`` of wall-clock for the redeploy.

Under a ``LinkTrace`` (WAN ramp, congestion spike) the loop therefore
does exactly what Sec. V-B argues a deployment must: notice the wire
degrading and move the split, while the run is in flight.

Energy rides the same loop: every batch's joules are modeled from the
*measured* per-stage compute times (device active power × exe + idle
power during the wire waits + radio cost × bytes actually sent), and an
``energy_budget_j`` makes the re-solve constrained — splits above the
budget are discarded before the policy picks, so a WAN ramp that makes
the current split energy-hungry triggers a migration even when raw
throughput would not justify one.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

from ..core.autosplit import AdaptiveSplitter, LinkEstimator, Policy
from ..core.blocks import BlockGraph
from ..core.costmodel import CostTable
from ..core.scenarios import Scenario
from .edge import Backend, EdgePipeline


@dataclass(frozen=True)
class LoopRecord:
    """One batch through the adaptive loop."""

    batch_idx: int
    t_s: float                      # pipeline-clock time after the batch
    cuts: tuple[int, ...]           # active cut vector for this batch
    latency_s: float                # measured end-to-end latency
    migrated: bool                  # did this step trigger a migration
    migration_cost_s: float         # redeploy cost charged (0 if none)
    predicted_latency_s: float      # splitter's model of the active cuts
    predicted_throughput: float
    energy_j: float = 0.0           # modeled J for this batch (measured exe)
    predicted_energy_j: float = 0.0  # splitter's model of the active cuts


class AdaptiveRuntime:
    """Owns an EdgePipeline + AdaptiveSplitter + per-hop LinkEstimators
    and runs them as one loop."""

    def __init__(self, model, params, scenario: Scenario, *,
                 graph: BlockGraph | None = None, batch: int | None = None,
                 policy: Policy = "throughput",
                 backend: Backend | Sequence[Backend] = "lightweight",
                 transport: str | Sequence[str] | None = None,
                 costs: CostTable | None = None, hysteresis: float = 0.10,
                 migration_cost_s: float = 0.25, check_every: int = 4,
                 alpha: float = 0.5, queue_depth: int = 2, seed: int = 0,
                 energy_budget_j: float | None = None):
        self._model, self._params = model, params
        self.scenario = scenario
        self._deploy_opts = dict(batch=batch, policy=policy, costs=costs,
                                 hysteresis=hysteresis,
                                 migration_cost_s=migration_cost_s,
                                 backend=backend, transport=transport,
                                 queue_depth=queue_depth,
                                 alpha=alpha, seed=seed,
                                 energy_budget_j=energy_budget_j)
        self.check_every = check_every
        self.records: list[LoopRecord] = []
        self.graph: BlockGraph | None = graph
        self.splitter: AdaptiveSplitter | None = None
        self.pipe: EdgePipeline | None = None
        self.estimators: list[LinkEstimator] = []
        # graph and batch must both be known to solve; otherwise deploy
        # lazily at run(), modelling the batches actually served
        if graph is not None and batch is not None:
            self._deploy(graph)

    def _deploy(self, graph: BlockGraph) -> None:
        """Solve under nominal (t=0) conditions — the paper's lab choice —
        and stand the pipeline up at the chosen cuts."""
        o = self._deploy_opts
        self.graph = graph
        # include_io=False: the executable pipeline has no orchestrator
        # dispatch/return hop, so the splitter must optimize the same
        # objective the pipeline actually exhibits
        self.splitter = AdaptiveSplitter(
            graph, self.scenario, batch=o["batch"], policy=o["policy"],
            costs=o["costs"], hysteresis=o["hysteresis"],
            migration_cost_s=o["migration_cost_s"], include_io=False,
            energy_budget_j=o["energy_budget_j"])
        init = self.splitter.solve()
        self.splitter.current = init
        self.splitter.history.append((init.partition, True))
        self.pipe = EdgePipeline(self._model, self._params, init.partition,
                                 self.scenario, backend=o["backend"],
                                 transport=o["transport"],
                                 queue_depth=o["queue_depth"], seed=o["seed"])
        self.estimators = [LinkEstimator.from_link(l, alpha=o["alpha"])
                           for l in self.scenario.links]

    # ------------------------------------------------------------------ #
    def _ingest_observations(self) -> None:
        """Feed each hop's recorded transfers into its estimator.
        Zero-byte messages are RTT probes (header-only ≈ one-way RTT/2)."""
        for est, net in zip(self.estimators, self.pipe.nets):
            for nbytes, dt, _t in net.drain_observations():
                if nbytes <= 0:
                    est.observe(0, 2.0 * dt, is_rtt_probe=True)
                else:
                    est.observe(nbytes, dt)

    def probe_rtt(self) -> None:
        """Send a header-only message down every hop — the emulated wire
        charges RTT/2, a real socket/shmem hop measures it — giving the
        estimators a compute-free RTT sample."""
        if self.pipe is None:
            raise RuntimeError("pipeline not deployed yet — call run() "
                               "(or pass graph= and batch=) first")
        self.pipe.probe()

    # ------------------------------------------------------------------ #
    def run(self, make_batch: Callable[[], object], n_batches: int,
            probe: bool = True) -> list[LoopRecord]:
        """Drive ``n_batches`` through the pipeline, re-solving every
        ``check_every`` batches.  Each check first RTT-probes every hop
        (unless ``probe=False``) — without fresh RTT samples the
        estimator attributes queueing delay to bandwidth and small
        transfers make the estimate collapse.  Returns this call's
        per-batch records (``self.records`` accumulates across calls);
        migrations are also visible in ``self.pipe.migrations``."""
        x = make_batch()
        if self.pipe is None:
            # model the batches actually being served: infer resolution
            # and batch size from the first batch unless given explicitly
            if self._deploy_opts["batch"] is None:
                self._deploy_opts["batch"] = x.shape[0]
            self._deploy(self.graph if self.graph is not None
                         else self._model.block_graph(input_hw=x.shape[1]))
        self.pipe.warmup(x)
        self.pipe.reset_clock()
        prev = len(self.records)
        for b in range(prev, prev + n_batches):
            active_cuts = self.pipe.cuts
            exe0 = [s.exe_s for s in self.pipe.stage_stats()]
            bytes0 = [net.total_bytes for net in self.pipe.nets]
            _, lat, _hops = self.pipe.run_one(x)
            exe_d = [s.exe_s - e0
                     for s, e0 in zip(self.pipe.stage_stats(), exe0)]
            bytes_d = [net.total_bytes - b0
                       for net, b0 in zip(self.pipe.nets, bytes0)]
            energy, _ = self.pipe.stage_energy_model(exe_d, _hops, bytes_d)
            # the model's view of the cuts this batch actually ran under
            # (captured before any re-solve below replaces it)
            pred = self.splitter.current
            migrated, cost = False, 0.0
            if (b + 1) % self.check_every == 0:
                if probe:
                    self.probe_rtt()
                self._ingest_observations()
                m, migrated = self.splitter.step(self.estimators)
                if migrated and m.partition != self.pipe.cuts:
                    cost = self.splitter.migration_cost_s
                    self.pipe.migrate(m.partition, cost_s=cost)
                    # warm the new placement before cutover (shadow-deploy
                    # style) so jit compile doesn't pollute the next batch
                    self.pipe.warmup(x)
            self.records.append(LoopRecord(
                batch_idx=b, t_s=self.pipe.clock(), cuts=active_cuts,
                latency_s=lat, migrated=migrated, migration_cost_s=cost,
                predicted_latency_s=pred.latency_s,
                predicted_throughput=pred.throughput,
                energy_j=energy, predicted_energy_j=pred.energy_j))
        return self.records[prev:]

    # ------------------------------------------------------------------ #
    def close(self) -> None:
        """Tear down the pipeline (worker processes, channels); no-op
        for thread-backed pipelines or before the first deploy."""
        if self.pipe is not None:
            self.pipe.close()

    def __enter__(self) -> "AdaptiveRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------ #
    @property
    def cut_history(self) -> list[tuple[int, ...]]:
        """Distinct cut vectors in deployment order."""
        out: list[tuple[int, ...]] = []
        for r in self.records:
            if not out or r.cuts != out[-1]:
                out.append(r.cuts)
        return out
