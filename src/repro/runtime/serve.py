"""ServeGate: a multi-tenant serving gateway over one streaming Session.

The paper's Pareto analysis prices a *single* stream; production serves
a workload mix.  This module lifts the split-point story to that
setting: many concurrent :class:`ClientSession`s multiplex onto one
underlying :class:`~repro.runtime.session.Session` pipeline through a
:class:`Gateway` that

* **micro-batches** — shape/dtype-compatible head-of-queue requests
  coalesce round-robin across tenants, up to ``max_batch`` rows within
  a ``batch_window_s`` deadline, and (by default) zero-pad to exactly
  ``max_batch`` rows so every pipeline batch has one fixed shape.  The
  padding is what buys *bit-identical* per-request results: XLA's CPU
  convolutions are not batch-size invariant, so deterministic serving
  must never let the resident batch shape depend on the tenant mix.
  ``deterministic=False`` trades that guarantee for the padded FLOPs.
* **demuxes on the drain** — the session delivers micro-batches in
  submit order, so each request's rows slice back out by offset; no
  wire-format change is needed for tenancy.
* **admits under SLO control** — the effective in-flight window runs
  AIMD (additive increase per ``ai_every`` clean batches,
  multiplicative decrease on an SLO violation, one decrease per
  in-flight window) against per-tenant latency SLOs, applied to the
  session via ``Session.set_inflight``.
* **accounts per tenant** — every request finishes with a
  :class:`QoSRecord` splitting queueing time vs processing latency vs
  estimated wire time, drained like violations/recoveries
  (module-level :func:`drain_qos` or per-gateway ``Gateway.drain_qos``).
* **cancels expired work** — ``Gateway.cancel_inflight`` flushes the
  in-flight window over the ``CANCEL`` token (workers skip compute on
  batches ahead of the fence) with resubmit-or-skip bookkeeping at
  request granularity.

On top sits :class:`FleetController`: an
:class:`~repro.runtime.session.AdaptiveController` that aggregates the
live tenant mix into fleet objectives (p50/p99 latency, aggregate
img/s, joules per request) and steers the existing re-solve/migrate/
codec-switch machinery against them — the Pareto front computed over
the workload instead of the stream.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import TYPE_CHECKING, Iterable, Sequence

import numpy as np

from ..core.scenarios import TenantMix, TenantSpec
from .session import AdaptiveController, PinnedController, Session, \
    _EnergyMeter

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .edge import EdgePipeline

__all__ = [
    "ClientSession", "FleetController", "FleetObjectives", "Gateway",
    "QoSRecord", "drain_qos",
]


# --------------------------------------------------------------------------- #
# per-request accounting
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class QoSRecord:
    """One served request, decomposed the way an SLO postmortem needs:
    how long it *queued* at the gateway, how long the pipeline *served*
    it, and how much of that service was estimated *wire* time."""

    tenant: str
    req_id: int                 # per-tenant request index
    seq: int                    # underlying pipeline micro-batch seq
    t_s: float                  # completion time (pipeline clock)
    queue_s: float              # enqueue -> pipeline submit
    service_s: float            # pipeline submit -> arrival
    wire_s: float               # estimated per-batch hop time share
    latency_s: float            # queue_s + service_s (the SLO quantity)
    rows: int                   # rows this request contributed
    coalesced: int              # requests sharing the micro-batch
    occupancy: float            # real rows / padded batch rows
    energy_j: float             # per-request share of the batch estimate
    slo_s: float
    violated: bool


_QOS: list[tuple[int, QoSRecord]] = []      # (gateway id, record)
_QLOCK = threading.Lock()


def drain_qos() -> list[QoSRecord]:
    """Return-and-clear every gateway's QoS log (the violations /
    recoveries drain idiom, applied to per-request accounting)."""
    with _QLOCK:
        out = [r for _, r in _QOS]
        _QOS.clear()
    return out


def _log_qos(gid: int, rec: QoSRecord) -> None:
    with _QLOCK:
        _QOS.append((gid, rec))


# --------------------------------------------------------------------------- #
# wire-time share of a served batch
# --------------------------------------------------------------------------- #
class _WireMeter:
    """Per-batch wire-time estimate from the pipeline's lifetime hop
    counters (same delta discipline as the energy meter: exact when
    batch-synchronous, a window mean when pipelined, checkpoint-lagged
    under process transports)."""

    def __init__(self, pipe: "EdgePipeline"):
        self.pipe = pipe
        self.wire_per_batch = 0.0
        self._snap()

    def _snap(self) -> None:
        nets = self.pipe.nets
        self._elapsed = sum(n.total_elapsed_s for n in nets)
        self._batches = min((n.total_transfers for n in nets), default=0)

    def update(self) -> float:
        nets = self.pipe.nets
        elapsed = sum(n.total_elapsed_s for n in nets)
        batches = min((n.total_transfers for n in nets), default=0)
        if batches < self._batches:           # migration reset the meters
            self._snap()
            return self.wire_per_batch
        d = batches - self._batches
        if d >= 1:
            self.wire_per_batch = max(elapsed - self._elapsed, 0.0) / d
            self._elapsed, self._batches = elapsed, batches
        return self.wire_per_batch


# --------------------------------------------------------------------------- #
# the gateway
# --------------------------------------------------------------------------- #
class _Req:
    __slots__ = ("req_id", "payload", "rows", "t_enq")

    def __init__(self, req_id: int, payload: np.ndarray, t_enq: float):
        self.req_id = req_id
        self.payload = payload
        self.rows = int(payload.shape[0])
        self.t_enq = t_enq


class _Member:
    """One request's slot inside an admitted micro-batch."""

    __slots__ = ("tenant", "req_id", "row0", "row1", "t_enq", "payload")

    def __init__(self, tenant: str, req: _Req, row0: int):
        self.tenant = tenant
        self.req_id = req.req_id
        self.row0 = row0
        self.row1 = row0 + req.rows
        self.t_enq = req.t_enq
        self.payload = req.payload            # kept for cancel-resubmit


class Gateway:
    """Multiplex many tenants onto one streaming pipeline session.

    Single-threaded and cooperative: admission, pumping, and demux all
    advance inside the caller's ``submit``/``poll``/``results`` calls,
    so ordering is deterministic and no locks guard the data plane.

    ``tenants`` is a :class:`~repro.core.scenarios.TenantMix` or an
    iterable of :class:`~repro.core.scenarios.TenantSpec`.  ``max_batch``
    counts *rows*; a request wider than it is rejected at submit.
    """

    def __init__(self, pipe: "EdgePipeline",
                 tenants: TenantMix | Iterable[TenantSpec], *,
                 controller=None, max_batch: int = 8,
                 batch_window_s: float = 0.002, inflight: int | None = None,
                 policy: str = "drop", deterministic: bool = True,
                 ai_every: int = 4, record_cap: int | None = 1024):
        specs = tuple(tenants.tenants if isinstance(tenants, TenantMix)
                      else tenants)
        if not specs:
            raise ValueError("need at least one tenant")
        names = [t.name for t in specs]
        if len(set(names)) != len(names):
            raise ValueError("duplicate tenant names")
        if max_batch < 1:
            raise ValueError("need max_batch >= 1")
        self.pipe = pipe
        self.tenants: dict[str, TenantSpec] = {t.name: t for t in specs}
        self.max_batch = max_batch
        self.batch_window_s = float(batch_window_s)
        self.deterministic = deterministic
        self.ai_every = max(int(ai_every), 1)
        self.controller = controller if controller is not None \
            else PinnedController()
        if isinstance(self.controller, FleetController):
            self.controller.attach_gateway(self)
        self._session: Session = pipe.session(
            self.controller, inflight=inflight, policy=policy,
            keep_results=True, record_cap=record_cap)
        self._gid = id(self)
        # admission state
        self._order = list(names)             # round-robin tenant order
        self._rr = 0
        self._queues: dict[str, deque[_Req]] = {n: deque() for n in names}
        self._next_req: dict[str, int] = {n: 0 for n in names}
        self._results: dict[str, deque] = {n: deque() for n in names}
        self._dropped: dict[str, set[int]] = {n: set() for n in names}
        self._members: dict[int, list[_Member]] = {}
        self._submit_times: dict[int, float] = {}
        self._inflight_order: deque[int] = deque()   # seqs, submit order
        self._canceled: set[int] = set()
        # arrival notifications: (tenant, req_id) in completion order —
        # values live in the per-tenant result queues, so a request
        # consumed through a ClientSession is never delivered twice
        self._events: deque[tuple[str, int]] = deque()
        # AIMD window, in micro-batches
        self._win_cap = self._session.inflight
        self._win = self._win_cap
        self._clean = 0                       # clean batches since change
        self._md_barrier = -1                 # newest seq at last decrease
        self.window_history: list[tuple[float, int]] = [(pipe.clock(),
                                                         self._win)]
        # meters
        self._emeter = _EnergyMeter(pipe)
        self._wmeter = _WireMeter(pipe)
        self.qos_recent: deque[QoSRecord] = deque(maxlen=256)
        self.closed = False

    # -- client surface ------------------------------------------------- #
    def client(self, name: str) -> "ClientSession":
        if name not in self.tenants:
            raise KeyError(f"unknown tenant {name!r}; "
                           f"have {sorted(self.tenants)}")
        return ClientSession(self, name)

    def submit(self, tenant: str, x) -> int:
        """Enqueue one request for ``tenant``; returns its per-tenant
        request id.  Results come back through ``poll``/``results`` in
        per-tenant submit order."""
        if self.closed:
            raise RuntimeError("gateway is closed")
        spec = self.tenants.get(tenant)
        if spec is None:
            raise KeyError(f"unknown tenant {tenant!r}")
        payload = np.asarray(x)
        if payload.ndim < 1:
            raise ValueError("request payload must be batched (ndim >= 1)")
        if payload.shape[0] > self.max_batch:
            raise ValueError(
                f"request of {payload.shape[0]} rows exceeds "
                f"max_batch={self.max_batch}")
        req = _Req(self._next_req[tenant], payload, time.perf_counter())
        self._next_req[tenant] += 1
        self._queues[tenant].append(req)
        self._admit()
        return req.req_id

    def poll(self, block: bool = True) -> list[tuple[str, int, object]]:
        """Deliver completed requests: ``[(tenant, req_id, value), …]``
        in completion order.  With ``block=True`` waits for at least one
        completion (unless nothing is queued or in flight).  Requests a
        :class:`ClientSession` already claimed are not re-delivered."""
        self._admit()
        if not self._events and block:
            self._advance()
        out = []
        while self._events:
            tenant, req_id = self._events.popleft()
            q = self._results[tenant]
            if q and q[0][0] == req_id:
                out.append((tenant, req_id, q.popleft()[1]))
        return out

    def drain(self) -> dict[str, list[tuple[int, object]]]:
        """Serve everything queued or in flight, then hand back all
        unconsumed results per tenant, in per-tenant submit order."""
        while self._has_work():
            self._advance()
        out = {}
        for name, q in self._results.items():
            out[name] = [(r, v) for r, v in q]
            q.clear()
        self._events.clear()
        return out

    @property
    def pending(self) -> int:
        """Requests accepted but not yet delivered (queued + in flight)."""
        queued = sum(len(q) for q in self._queues.values())
        inflight = sum(len(m) for m in self._members.values())
        return queued + inflight

    @property
    def inflight_window(self) -> int:
        """The AIMD-controlled admission window, in micro-batches."""
        return self._win

    @property
    def session(self) -> Session:
        return self._session

    def drain_qos(self) -> list[QoSRecord]:
        """Return-and-clear this gateway's QoS records."""
        with _QLOCK:
            mine = [r for g, r in _QOS if g == self._gid]
            _QOS[:] = [(g, r) for g, r in _QOS if g != self._gid]
        return mine

    # -- cancellation ---------------------------------------------------- #
    def cancel_inflight(self, action: str = "skip") -> int:
        """Flush the in-flight window over the ``CANCEL`` fence.

        ``action="resubmit"`` re-queues every flushed request at the
        *front* of its tenant's queue in original order (its enqueue
        timestamp — and hence its SLO clock — is preserved);
        ``action="skip"`` drops them (each skipped request surfaces as
        ``(req_id, None)`` so per-tenant ordering stays accountable).
        Returns the number of requests flushed."""
        if action not in ("skip", "resubmit"):
            raise ValueError(f"unknown cancel action {action!r}")
        seqs = self._session.cancel()         # flush-cancel + skip window
        flushed: list[_Member] = []           # submit order across batches
        for seq in sorted(seqs):
            self._canceled.add(seq)
            self._submit_times.pop(seq, None)
            flushed.extend(self._members.pop(seq, []))
        if action == "resubmit":
            # back-to-front appendleft restores original per-tenant
            # order at the *front* of the queues; each request keeps its
            # enqueue timestamp, so its SLO clock keeps running
            for m in reversed(flushed):
                self._queues[m.tenant].appendleft(
                    _Req(m.req_id, m.payload, m.t_enq))
            self._admit()
        else:
            # dropped requests surface as (req_id, None) behind anything
            # already delivered (in-flight ids are higher by FIFO)
            for m in flushed:
                self._dropped[m.tenant].add(m.req_id)
                self._results[m.tenant].append((m.req_id, None))
                self._events.append((m.tenant, m.req_id))
        return len(flushed)

    # -- lifecycle ------------------------------------------------------- #
    def close(self) -> None:
        if self.closed:
            return
        try:
            while self._has_work():
                self._advance()
        finally:
            self.closed = True
            self._session.close()

    def __enter__(self) -> "Gateway":
        return self

    def __exit__(self, *exc) -> None:
        if exc and exc[0] is not None:
            self.closed = True                # don't drain through a wreck
            self._session.__exit__(*exc)
            return
        self.close()

    # -- the data plane --------------------------------------------------- #
    def _has_work(self) -> bool:
        return bool(self._members) or any(self._queues.values())

    def _compat(self, a: np.ndarray, b: np.ndarray) -> bool:
        return a.shape[1:] == b.shape[1:] and a.dtype == b.dtype

    def _gather(self) -> list[tuple[str, _Req]] | None:
        """Round-robin one micro-batch's worth of head requests, or
        None when nothing is queued."""
        n = len(self._order)
        picked: list[tuple[str, _Req]] = []
        rows = 0
        seed: np.ndarray | None = None
        start = self._rr
        for turn in range(2 * n):             # two passes: fill the tail
            name = self._order[(start + turn) % n]
            q = self._queues[name]
            # a tenant's weight is how many head requests one visit may
            # take (>=1); fairness is round-robin over visits
            take = max(int(self.tenants[name].weight), 1)
            while take and q:
                head = q[0]
                if seed is None:
                    seed = head.payload
                elif not self._compat(seed, head.payload):
                    break                     # different shape: next round
                if rows + head.rows > self.max_batch:
                    take = 0
                    break
                picked.append((name, q.popleft()))
                rows += head.rows
                take -= 1
            if rows >= self.max_batch:
                break
        if picked:
            self._rr = (start + 1) % n        # rotate the seed tenant
            return picked
        return None

    def _admit(self, force: bool = False) -> None:
        """Admit ripe micro-batches while the AIMD window has room."""
        while self._session.outstanding < self._win:
            queued = [q for q in self._queues.values() if q]
            if not queued:
                return
            now = time.perf_counter()
            oldest = min(q[0].t_enq for q in queued)
            total_rows = sum(r.rows for q in queued for r in q)
            ripe = (force or total_rows >= self.max_batch
                    or now - oldest >= self.batch_window_s)
            if not ripe:
                return
            picked = self._gather()
            if not picked:
                return
            parts = [r.payload for _, r in picked]
            big = parts[0] if len(parts) == 1 else np.concatenate(parts, 0)
            rows = big.shape[0]
            if self.deterministic and rows < self.max_batch:
                pad = np.zeros((self.max_batch - rows,) + big.shape[1:],
                               big.dtype)
                big = np.concatenate([big, pad], 0)
            t_sub = time.perf_counter()
            seq = self._session.submit(big)
            members, row0 = [], 0
            for name, req in picked:
                m = _Member(name, req, row0)
                row0 = m.row1
                members.append(m)
            self._members[seq] = members
            self._submit_times[seq] = t_sub
            self._inflight_order.append(seq)

    def _advance(self) -> bool:
        """Deliver the next completed micro-batch (blocking); → False
        when there is nothing queued or in flight."""
        # backlog, not outstanding: a controller that pumps re-entrantly
        # (checkpoint inside on_result) can park the last arrival in the
        # ready map with nothing left pending — it still must be emitted
        if not self._session.backlog:
            self._admit(force=True)           # nothing to wait on: flush
            if not self._session.backlog:
                return False
        value = next(self._session.results(), None)
        now = time.perf_counter()
        while self._inflight_order and self._inflight_order[0] \
                in self._canceled:
            self._canceled.discard(self._inflight_order.popleft())
        if value is None:
            # everything still in flight was canceled: pump the session
            # until the flush markers land, then admit what queued up
            if self._session.outstanding:
                self._session.drain()
            if self._has_work():
                self._admit(force=True)
                return True
            return False
        seq = self._inflight_order.popleft()
        members = self._members.pop(seq, [])
        t_sub = self._submit_times.pop(seq, now)
        energy = self._emeter.update()
        wire = self._wmeter.update()
        n = max(len(members), 1)
        pad_rows = self.max_batch if self.deterministic \
            else (members[-1].row1 if members else 1)
        violated_any = False
        for m in members:
            y = np.array(value[m.row0:m.row1])   # detach from the pad
            spec = self.tenants[m.tenant]
            latency = now - m.t_enq
            violated = latency > spec.slo_s
            violated_any = violated_any or violated
            rec = QoSRecord(
                tenant=m.tenant, req_id=m.req_id, seq=seq,
                t_s=self.pipe.clock(),
                queue_s=t_sub - m.t_enq, service_s=now - t_sub,
                wire_s=wire, latency_s=latency,
                rows=m.row1 - m.row0, coalesced=len(members),
                occupancy=(members[-1].row1 / pad_rows) if members else 0.0,
                energy_j=energy / n, slo_s=spec.slo_s, violated=violated)
            _log_qos(self._gid, rec)
            self.qos_recent.append(rec)
            self._results[m.tenant].append((m.req_id, y))
            self._events.append((m.tenant, m.req_id))
        self._aimd(seq, violated_any)
        self._admit()
        return True

    def _aimd(self, seq: int, violated: bool) -> None:
        win0 = self._win
        if violated:
            self._clean = 0
            # one decrease per in-flight window: a violation from a
            # batch submitted before the last decrease is stale signal
            if seq > self._md_barrier:
                self._win = max(self._win // 2, 1)
                self._md_barrier = self._session._next_seq - 1
        else:
            self._clean += 1
            if self._clean >= self.ai_every and self._win < self._win_cap:
                self._win += 1
                self._clean = 0
        if self._win != win0:
            self._session.set_inflight(self._win)
            self.window_history.append((self.pipe.clock(), self._win))


# --------------------------------------------------------------------------- #
# the per-tenant handle
# --------------------------------------------------------------------------- #
class ClientSession:
    """One tenant's view of the gateway: a Session-shaped handle whose
    ``submit``/``results``/``drain`` speak per-tenant request ids.
    Cheap — all state lives in the gateway; make as many as you like."""

    def __init__(self, gateway: Gateway, tenant: str):
        self.gateway = gateway
        self.tenant = tenant
        self._emitted = 0                     # next req_id results() yields

    @property
    def spec(self) -> TenantSpec:
        return self.gateway.tenants[self.tenant]

    def submit(self, x) -> int:
        return self.gateway.submit(self.tenant, x)

    @property
    def pending(self) -> int:
        gw = self.gateway
        queued = len(gw._queues[self.tenant])
        inflight = sum(1 for ms in gw._members.values()
                       for m in ms if m.tenant == self.tenant)
        return queued + inflight

    def results(self):
        """Yield ``(req_id, value)`` in submit order for every request
        submitted so far (skipped/canceled requests yield
        ``(req_id, None)``)."""
        gw = self.gateway
        while self._emitted < gw._next_req[self.tenant]:
            q = gw._results[self.tenant]
            if q and q[0][0] == self._emitted:
                self._emitted += 1
                yield q.popleft()
                continue
            if not gw._advance():
                return                        # nothing left anywhere
        return

    def drain(self) -> list[tuple[int, object]]:
        return list(self.results())


# --------------------------------------------------------------------------- #
# fleet-level Pareto control
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class FleetObjectives:
    """The workload-level Pareto axes at one control decision."""

    t_s: float                  # pipeline clock at aggregation
    n: int                      # requests aggregated
    p50_s: float                # median request latency
    p99_s: float                # tail request latency
    aggregate_ips: float        # served rows / wall-clock second
    j_per_request: float        # energy estimate per request
    violation_rate: float       # SLO-violating fraction
    strictest_slo_s: float      # tightest SLO with live traffic
    policy: str                 # splitter policy chosen for this regime


class FleetController(AdaptiveController):
    """Drive the adaptive split loop by *fleet* objectives.

    Extends :class:`AdaptiveController` (same checkpoint → estimate →
    re-solve → migrate machinery, including codec switches) but, before
    each re-solve, aggregates the gateway's recent per-request QoS into
    :class:`FleetObjectives` and steers the splitter's policy axis:
    tail latency above the strictest live SLO selects the latency-min
    split, headroom selects the throughput-max split.  The existing
    hysteresis/amortization gates still own *whether* a migration is
    worth its cost."""

    def __init__(self, splitter, estimators=None, *,
                 fleet_window: int = 64, **kw):
        super().__init__(splitter, estimators, **kw)
        self.fleet_window = fleet_window
        self.fleet_history: list[FleetObjectives] = []
        self._gw: Gateway | None = None

    def attach_gateway(self, gateway: Gateway) -> None:
        self._gw = gateway

    def fleet_objectives(self) -> FleetObjectives | None:
        gw = self._gw
        if gw is None or not gw.qos_recent:
            return None
        recent = list(gw.qos_recent)[-self.fleet_window:]
        lats = np.asarray([r.latency_s for r in recent])
        t0 = min(r.t_s - r.latency_s for r in recent)
        t1 = max(r.t_s for r in recent)
        rows = sum(r.rows for r in recent)
        strictest = min(gw.tenants[r.tenant].slo_s for r in recent)
        p99 = float(np.percentile(lats, 99))
        policy = "latency" if p99 > strictest else "throughput"
        return FleetObjectives(
            t_s=gw.pipe.clock(), n=len(recent),
            p50_s=float(np.percentile(lats, 50)), p99_s=p99,
            aggregate_ips=rows / max(t1 - t0, 1e-9),
            j_per_request=float(np.mean([r.energy_j for r in recent])),
            violation_rate=float(np.mean([r.violated for r in recent])),
            strictest_slo_s=strictest, policy=policy)

    def on_result(self, session: Session, seq: int, latency_s: float,
                  cuts: tuple[int, ...]):
        # steer before the (possibly re-solving) parent hook runs, so
        # this arrival's re-solve already optimizes the fleet's axis
        if (self._count + 1) % self.check_every == 0:
            obj = self.fleet_objectives()
            if obj is not None:
                self.splitter.policy = obj.policy
                self.fleet_history.append(obj)
        return super().on_result(session, seq, latency_s, cuts)
