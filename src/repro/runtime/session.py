"""Streaming Session API — the one always-pipelined entrypoint.

Everything the runtime used to do through three incompatible entrypoints
(``EdgePipeline.run_one`` for lone batches, ``stream(x, n)`` for a
fixed-count burst, ``AdaptiveRuntime.run`` for the adaptive loop) is the
same execution here: a ``Session`` feeds batches into the pipelined
stage chain (threads under the ``emulated`` transport, worker processes
under ``socket``/``shmem``), keeps at most ``inflight`` of them in
flight, and hands results back **in submit order** —

    with pipe.session(controller=AdaptiveController(splitter)) as s:
        for x in batches:
            s.submit(x)
        for y in s.results():          # ordered, as they complete
            ...

A pluggable ``Controller`` decides what happens around each completed
batch: it builds the per-batch ``LoopRecord`` (latency, windowed
throughput, energy, active cut vector) and may re-solve and migrate.
``PinnedController`` never moves; ``AdaptiveController`` wraps
``AdaptiveSplitter`` + per-hop ``LinkEstimator``s and closes the
measure → estimate → re-solve → migrate loop *while batches are in
flight*.

Migration uses the transports' in-band ``RECONFIG`` token under an
explicit ``MigrationPolicy``:

  * ``"drain"`` — flush every in-flight batch to completion first, then
    reconfigure an empty pipeline (a full pipeline bubble: predictable,
    but throughput dips for ~``inflight`` batch times);
  * ``"drop"`` — drop the flush barrier: the ``RECONFIG`` token is
    injected immediately and chases the in-flight batches down the
    chain.  Batches ahead of the token complete under the outgoing
    placement (every cut vector computes the same function, so results
    stay correct), batches behind it run on the new one.  Admissions
    stall for ``cost_s`` (the weight redeploy) but the pipeline keeps
    draining.

Either way a migration loses, duplicates, and reorders **nothing** —
the in-band token is ordered with the batches around it, and an in-band
``WARMUP`` of the last-seen batch shape follows it so the new placement
is jit-warm before the next real batch arrives.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import TYPE_CHECKING, Literal, Protocol, Sequence, runtime_checkable

import numpy as np

from ..core.autosplit import AdaptiveSplitter, LinkEstimator
from .transport import (BATCH, CANCEL, CLOCK, ERROR, PROBE, RECONFIG, STATS,
                        STOP, WARMUP, TransportError, TransportTimeout)

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .edge import EdgePipeline

MigrationPolicy = Literal["drain", "drop"]


@dataclass(frozen=True)
class LoopRecord:
    """One batch through a session (the controller builds these
    uniformly, whatever the controller and transport)."""

    batch_idx: int
    t_s: float                      # pipeline-clock time after the batch
    cuts: tuple[int, ...]           # cut vector the batch was submitted under
    latency_s: float                # submit→result (includes queueing when
                                    # the pipeline is kept full)
    migrated: bool                  # did this step trigger a migration
    migration_cost_s: float         # redeploy wall-clock charged (0 if none)
    predicted_latency_s: float      # controller's model of the active cuts
    predicted_throughput: float
    energy_j: float = 0.0           # modeled J/batch from measured exe
    predicted_energy_j: float = 0.0
    throughput: float = 0.0         # measured samples/s, sliding window
    migration_cost_j: float = 0.0   # weights-over-the-wire J charged
    codecs: tuple[str, ...] = ()    # per-hop wire codecs the batch ran under


@runtime_checkable
class Controller(Protocol):
    """Decides, per completed batch, what the session does next.

    ``on_result`` is called once per batch **in arrival (= submit)
    order** and returns that batch's ``LoopRecord`` (or None to record
    nothing).  Inside it a controller may call ``session.checkpoint()``
    (flush stats/observations from the workers) and
    ``session.migrate(...)`` — the session keeps records ordered by
    batch even when those calls pump further arrivals re-entrantly.
    """

    def bind(self, session: "Session") -> None: ...

    def on_result(self, session: "Session", seq: int, latency_s: float,
                  cuts: tuple[int, ...]) -> "LoopRecord | None": ...


class _EnergyMeter:
    """Per-batch energy estimate from lifetime stage/hop counters.

    Deltas are taken whenever every stage has completed at least one
    more batch since the last snapshot (exact per-batch attribution when
    the session runs batch-synchronously; a window mean when pipelined).
    Under process transports the counters advance at checkpoint cadence,
    so the estimate lags to the last checkpoint — documented behaviour,
    not drift."""

    def __init__(self, pipe: "EdgePipeline"):
        self.pipe = pipe
        self.energy_per_batch = 0.0
        self._snap()

    def _snap(self) -> None:
        stats = self.pipe.stage_stats()
        nets = self.pipe.nets
        self._calls = [s.calls for s in stats]
        self._exe = [s.exe_s for s in stats]
        self._bytes = [n.total_bytes for n in nets]
        self._wire = [(n.total_transfers, n.total_elapsed_s) for n in nets]

    def update(self) -> float:
        stats = self.pipe.stage_stats()
        nets = self.pipe.nets
        # a migration that rebuilds a worker resets its StageStats (the
        # thread engine's in-band RECONFIG does); a shrunk counter means
        # every cached baseline is stale — resync and keep the last
        # estimate until a full post-migration batch lands
        if any(s.calls < c0 for s, c0 in zip(stats, self._calls)):
            self._snap()
            return self.energy_per_batch
        d = min((s.calls - c0 for s, c0 in zip(stats, self._calls)),
                default=0)
        if d >= 1:
            exe = [(s.exe_s - e0) / d
                   for s, e0 in zip(stats, self._exe)]
            nbytes = [(n.total_bytes - b0) / d
                      for n, b0 in zip(nets, self._bytes)]
            wire = [(n.total_elapsed_s - el0) / d
                    for n, (_, el0) in zip(nets, self._wire)]
            energy, _ = self.pipe.stage_energy_model(exe, wire, nbytes)
            self.energy_per_batch = max(energy, 0.0)
            self._snap()
        return self.energy_per_batch


class PinnedController:
    """The null policy: never re-solves, never migrates — records only.
    ``stats_every`` (batches) inserts an in-band stats checkpoint so
    process-transport meters/energy stay fresh mid-stream (None = no
    checkpoints; thread-backed pipelines have live counters anyway)."""

    probe = False

    def __init__(self, stats_every: int | None = None):
        self.stats_every = stats_every
        self._count = 0
        self._busy = False
        self._meter: _EnergyMeter | None = None

    def bind(self, session: "Session") -> None:
        self._meter = _EnergyMeter(session.pipe)

    def on_result(self, session: "Session", seq: int, latency_s: float,
                  cuts: tuple[int, ...]) -> LoopRecord:
        self._count += 1
        if (self.stats_every and not self._busy
                and self._count % self.stats_every == 0):
            self._busy = True
            try:
                session.checkpoint(probe=False)
            finally:
                self._busy = False
        return LoopRecord(
            batch_idx=seq, t_s=session.pipe.clock(), cuts=cuts,
            latency_s=latency_s, migrated=False, migration_cost_s=0.0,
            predicted_latency_s=0.0, predicted_throughput=0.0,
            energy_j=self._meter.update(),
            throughput=session.window_throughput(),
            codecs=session.pipe.codecs)


class AdaptiveController:
    """The closed loop as a session controller: every ``check_every``
    batches, checkpoint (in-band probe + stats flush), feed the drained
    per-hop observations into the ``LinkEstimator``s, re-solve via the
    wrapped ``AdaptiveSplitter``, and migrate in-stream when the
    splitter says so — charging ``migration_cost_s`` wall-clock and
    ``migration_cost_j`` (weights over the wire) on the batch record
    that triggered the move."""

    def __init__(self, splitter: AdaptiveSplitter,
                 estimators: Sequence[LinkEstimator] | None = None, *,
                 check_every: int = 4, probe: bool = True,
                 batch_offset: int = 0, alpha: float = 0.5):
        self.splitter = splitter
        self.estimators = list(estimators) if estimators is not None else None
        self.check_every = check_every
        self.probe = probe
        self.batch_offset = batch_offset
        self.alpha = alpha
        self._count = 0
        self._checking = False
        self._force_check = False
        self.device_losses: list[tuple[int, int]] = []
        self._meter: _EnergyMeter | None = None

    def on_device_loss(self, session: "Session", stage: int,
                       lane: int) -> None:
        """Supervisor-reported replica eviction: the failed device
        changes the pipeline's capacity, so the next arrival re-probes,
        re-estimates, and re-solves immediately instead of waiting out
        the ``check_every`` stride — failure is just another regime
        change to the Pareto loop."""
        self.device_losses.append((stage, lane))
        self._force_check = True

    def bind(self, session: "Session") -> None:
        if self.estimators is None:
            self.estimators = [
                LinkEstimator.from_link(l, alpha=self.alpha)
                for l in session.pipe.links]
        self._meter = _EnergyMeter(session.pipe)

    def ingest_observations(self, pipe: "EdgePipeline") -> None:
        """Drained transfers → estimators (nbytes=0 records are RTT
        probes: header-only ≈ one-way RTT/2)."""
        for est, net in zip(self.estimators, pipe.nets):
            for rec in net.drain_observations():
                if rec.nbytes <= 0:
                    est.observe(0, 2.0 * rec.elapsed_s, is_rtt_probe=True)
                else:
                    # wire bytes, not raw: the estimator must predict the
                    # transfer time of what actually crosses the hop
                    est.observe(rec.nbytes, rec.elapsed_s)

    def on_result(self, session: "Session", seq: int, latency_s: float,
                  cuts: tuple[int, ...]) -> LoopRecord:
        self._count += 1
        pipe = session.pipe
        energy = self._meter.update()
        # the model's view of the cuts this batch actually ran under
        # (captured before any re-solve below replaces it)
        pred = self.splitter.current
        migrated, cost_s, cost_j = False, 0.0, 0.0
        if ((self._count % self.check_every == 0 or self._force_check)
                and not self._checking):
            self._force_check = False
            self._checking = True       # nested arrivals must not re-check
            try:
                session.checkpoint(probe=self.probe)
                self.ingest_observations(pipe)
                m, migrated = self.splitter.step(self.estimators)
                new_codecs = m.codecs or None
                if migrated and (m.partition != pipe.cuts
                                 or (new_codecs is not None
                                     and new_codecs != pipe.codecs)):
                    cost_s = self.splitter.last_migration_cost_s
                    cost_j = self.splitter.last_migration_cost_j
                    session.migrate(m.partition, cost_s=cost_s,
                                    cost_j=cost_j, codecs=new_codecs)
            finally:
                self._checking = False
        return LoopRecord(
            batch_idx=self.batch_offset + seq, t_s=pipe.clock(), cuts=cuts,
            latency_s=latency_s, migrated=migrated,
            migration_cost_s=cost_s,
            predicted_latency_s=pred.latency_s if pred else 0.0,
            predicted_throughput=pred.throughput if pred else 0.0,
            energy_j=energy,
            predicted_energy_j=pred.energy_j if pred else 0.0,
            throughput=session.window_throughput(),
            migration_cost_j=cost_j,
            codecs=pipe.codecs)


# in-band tokens whose round trip a session tracks (kind -> outstanding)
_TOKEN_KINDS = (PROBE, RECONFIG, STATS, WARMUP, CLOCK, CANCEL)


@dataclass
class CancelRecord:
    """One canceled in-flight batch: the resubmit-or-skip bookkeeping a
    drop-policy gateway needs to account for flushed work.  Mutable —
    ``flushed`` flips when the canceled batch's (discarded) arrival
    drains, ``resubmitted_as`` is stamped when its payload re-enters
    the queue as a fresh seq."""

    seq: int
    action: str                     # "skip" | "resubmit"
    flush: bool                     # part of a flush (cancel-all) window
    t_cancel_s: float
    flushed: bool = False           # its arrival has been discarded
    resubmitted_as: int = -1        # new seq when the payload was re-fed


class Session:
    """A live streaming handle over an ``EdgePipeline``.

    One session may be open per pipeline at a time; the pipeline's
    synchronous entrypoints (``run_one``/``stream``/``measure``/
    ``migrate``/…) are shims that open one internally, so they refuse
    to run while a caller-owned session is active.
    """

    def __init__(self, pipe: "EdgePipeline",
                 controller: Controller | None = None, *,
                 inflight: int | None = None,
                 policy: MigrationPolicy = "drain",
                 window: int = 16, keep_results: bool = True,
                 record_cap: int | None = None):
        if policy not in ("drain", "drop"):
            raise ValueError(f"unknown migration policy {policy!r}")
        self.pipe = pipe
        self.controller = controller if controller is not None \
            else PinnedController()
        self.inflight = (inflight if inflight is not None
                         else max(pipe.queue_depth * pipe.n_stages, 1))
        if self.inflight < 1:
            raise ValueError("need inflight >= 1")
        # submit() only pumps while the window is full, so the window
        # must fit inside the engine's guaranteed-drainable capacity —
        # past it, a process-engine feed send would block with nothing
        # draining the result channel until it hard-timed out
        cap = pipe._engine.max_inflight()
        if cap is not None:
            self.inflight = min(self.inflight, cap)
        self.policy: MigrationPolicy = policy
        self.keep_results = keep_results
        # long-lived serving sessions should cap the record log, or it
        # grows one LoopRecord per batch forever (None = unbounded, the
        # right default for finite measurement runs)
        self.record_cap = record_cap
        self._rec_lo = 0                # lowest seq a record may hold
        self.closed = False
        self._engine = pipe._engine
        # supervised engines replay unacked in-flight batches after a
        # stage restart, so the session retains each pending payload
        # (bounded by ``inflight``) until its result arrives
        self._retain = bool(getattr(self._engine, "supervised", False))
        # pending: seq -> (t_submit, cuts, batch size, retained payload)
        self._pending: dict[
            int, tuple[float, tuple[int, ...], int, object]] = {}
        self._ready: dict[int, object] = {}
        self._records: dict[int, LoopRecord] = {}
        self._next_seq = 0              # next submit id
        self._next_arrival = 0          # next BATCH arrival's id
        self._next_emit = 0             # next id results() hands out
        self._arrivals: deque = deque(maxlen=max(window, 2))
        self._expect = {k: 0 for k in _TOKEN_KINDS}
        self._canceled: set[int] = set()      # seqs results() must skip
        self._cancel_live: dict[int, CancelRecord] = {}   # awaiting flush
        self._cancel_log: list[CancelRecord] = []
        self._exemplar = None
        self._failed = False
        self._migrating = False
        self._engine.session_open()
        try:
            self.controller.bind(self)
        except BaseException:
            # a failed bind must not wedge the pipeline behind a
            # Session nobody holds a handle to
            self._engine.session_close(failed=True)
            raise
        if self._retain:
            self._engine._replay_cb = self._replay_for_recovery
        pipe._session = self

    # ------------------------------------------------------------------ #
    @property
    def records(self) -> list[LoopRecord]:
        """Per-batch LoopRecords in batch order (whatever re-entrant
        pumping order the controller's checkpoints caused)."""
        return [self._records[s] for s in sorted(self._records)]

    def window_throughput(self) -> float:
        """Measured samples/s over the sliding arrival window."""
        if len(self._arrivals) < 2:
            return 0.0
        t0, _ = self._arrivals[0]
        t1, _ = self._arrivals[-1]
        samples = sum(b for _, b in list(self._arrivals)[1:])
        return samples / max(t1 - t0, 1e-9)

    @property
    def outstanding(self) -> int:
        return len(self._pending)

    @property
    def backlog(self) -> int:
        """Submitted batches whose emit slot has not been handed out
        yet: in flight, ready-but-unemitted (a re-entrant controller
        pump can park arrivals in the ready map with nothing left
        pending), or canceled-awaiting-skip."""
        return self._next_seq - self._next_emit

    # ------------------------------------------------------------------ #
    def submit(self, x) -> int:
        """Feed one batch; blocks (pumping results) while ``inflight``
        batches are already in the pipeline.  Returns the batch's seq
        id — results() yields values in seq order."""
        if self.closed:
            raise RuntimeError("session is closed")
        self._check_failed()
        while len(self._pending) >= self.inflight:
            self._pump()
        seq = self._next_seq
        self._next_seq += 1
        self._exemplar = x
        shape = getattr(x, "shape", ())       # no host copy on the hot path
        bsz = int(shape[0]) if shape else 1
        # supervised engines need a host copy (replay outlives the
        # device buffers); otherwise keep the caller's reference — free,
        # and it is what cancel(resubmit=True) re-feeds
        kept = np.asarray(x) if self._retain else x
        self._pending[seq] = (time.perf_counter(), self.pipe.cuts, bsz, kept)
        self._engine.submit(x)
        return seq

    def results(self):
        """Ordered iterator over completed batch outputs; yields until
        every batch submitted so far has been handed out (submitting
        more while iterating extends it)."""
        while self._next_emit < self._next_seq:
            self._check_failed()
            if self._next_emit in self._canceled:
                self._next_emit += 1          # canceled: no value to yield
                continue
            while self._next_emit not in self._ready:
                self._pump()
            seq = self._next_emit
            self._next_emit += 1
            yield self._ready.pop(seq)

    def drain(self) -> list:
        """Pump until nothing is in flight; → the not-yet-emitted
        results, in order."""
        while self._pending:
            self._pump()
        return list(self.results())

    def latency_of(self, seq: int) -> float:
        return self._records[seq].latency_s if seq in self._records else 0.0

    def set_inflight(self, n: int) -> int:
        """Retune the admission window mid-stream (the serving
        gateway's AIMD control plane).  Clamped to [1, engine cap];
        returns the window actually applied.  Shrinking never evicts
        in-flight batches — ``submit`` simply blocks until the window
        drains below the new bound."""
        n = max(int(n), 1)
        cap = self._engine.max_inflight()
        if cap is not None:
            n = min(n, cap)
        self.inflight = n
        return n

    # ------------------------------------------------------------------ #
    def cancel(self, seqs: Sequence[int] | None = None, *,
               resubmit: bool = False) -> list[int]:
        """Cancel in-flight (or ready-but-unemitted) batches.

        ``seqs=None`` cancels the whole in-flight window — a *flush*
        cancel: the engine opens an out-of-band skip window (workers
        short-circuit compute on batches already queued) and an in-band
        ``CANCEL`` fence closes it behind them, so the flush confirms
        without paying for the canceled compute.  Explicit ``seqs``
        cancel selectively: those batches still compute, but their
        arrivals are discarded.

        Canceled seqs never reach ``results()`` or the controller; each
        is logged as a :class:`CancelRecord` (see ``drain_cancels``).
        With ``resubmit=True`` every canceled batch whose payload the
        session still holds is immediately re-submitted at the back of
        the queue (``resubmitted_as`` maps old seq to new).

        Returns the seqs actually canceled (already-emitted or
        already-canceled seqs are skipped silently)."""
        if self.closed:
            raise RuntimeError("session is closed")
        self._check_failed()
        flush = seqs is None
        if flush:
            targets = sorted(s for s in self._pending
                             if s not in self._canceled)
        else:
            targets = []
            for s in {int(s) for s in seqs}:
                if s >= self._next_seq:
                    raise ValueError(f"seq {s} was never submitted")
                if (s in self._canceled or s < self._next_emit
                        or (s not in self._pending
                            and s not in self._ready)):
                    continue
                targets.append(s)
            targets.sort()
        if not targets:
            return []
        now = time.perf_counter()
        action = "resubmit" if resubmit else "skip"
        payloads = {}
        made: dict[int, CancelRecord] = {}
        for s in targets:
            if s in self._pending:
                payloads[s] = self._pending[s][3]
            rec = CancelRecord(seq=s, action=action, flush=flush,
                               t_cancel_s=now)
            if s in self._ready:              # already arrived: flushed now
                self._ready.pop(s)
                rec.flushed = True
            else:
                self._cancel_live[s] = rec
            self._canceled.add(s)
            self._cancel_log.append(rec)
            made[s] = rec
        if flush:
            cancel_flush = getattr(self._engine, "cancel_flush", None)
            if cancel_flush is not None:
                cancel_flush()                # out-of-band: skip compute
        # the in-band fence: a truthy payload marks a flush fence (it
        # closes the skip window at each stage); selective cancels send
        # a non-flush fence purely as a flush-progress marker
        self._engine.submit_token(CANCEL, 1 if flush else None)
        self._expect[CANCEL] += 1
        if resubmit:
            # records are mutable and shared with the log, so stamp via
            # the local reference — submit() pumps while the window is
            # full, and the pump may pop _cancel_live[s] before we read
            for s in targets:
                if s in payloads and payloads[s] is not None:
                    made[s].resubmitted_as = self.submit(payloads[s])
        return targets

    def drain_cancels(self) -> list[CancelRecord]:
        """Return-and-clear the cancel log (records are shared with the
        live flush tracker, so a record drained before its batch has
        flushed will still flip ``flushed`` when it does)."""
        out, self._cancel_log = self._cancel_log, []
        return out

    # ------------------------------------------------------------------ #
    def checkpoint(self, probe: bool = True) -> None:
        """Flush worker-side stats + per-hop observations to the
        orchestrator via an in-band ``STATS`` token (preceded by a
        ``PROBE`` for a compute-free RTT sample on every hop), pumping
        batch results until the token(s) come back."""
        self._check_failed()
        if probe:
            self._engine.submit_token(PROBE)
            self._expect[PROBE] += 1
        self._engine.submit_token(STATS)
        self._expect[STATS] += 1
        self._await_tokens(STATS, *((PROBE,) if probe else ()))

    def migrate(self, new_cuts, cost_s: float = 0.0, cost_j: float = 0.0,
                policy: MigrationPolicy | None = None,
                codecs: Sequence[str] | None = None) -> tuple[int, ...]:
        """In-stream migration to ``new_cuts`` under ``policy`` (the
        session default unless overridden).  ``cost_s`` stalls
        admissions for the redeploy; ``cost_j`` is recorded on the
        pipeline's migration log.  ``codecs`` retunes the per-hop wire
        codecs in the same in-band RECONFIG — a codec-only switch (cuts
        unchanged) still runs the full reconfiguration, including the
        in-band WARMUP that pre-compiles the new codec's kernels, so it
        is charged like a migration.  Nested requests (a controller
        deciding again while a migration's own drain is pumping) are
        dropped — the in-progress move supersedes them."""
        if self._migrating:
            return self.pipe.cuts
        new_cuts = self.pipe._check_cuts(new_cuts)
        if codecs is not None:
            from ..core.codecs import get_codec
            codecs = tuple(get_codec(c).name for c in codecs)
            if len(codecs) != self.pipe.n_stages - 1:
                raise ValueError(f"{len(codecs)} codecs for "
                                 f"{self.pipe.n_stages - 1} hops")
            if codecs == self.pipe.codecs:
                codecs = None               # already active: not a switch
        if new_cuts == self.pipe.cuts and codecs is None:
            return self.pipe.cuts
        policy = policy or self.policy
        if policy not in ("drain", "drop"):
            raise ValueError(f"unknown migration policy {policy!r}")
        self._migrating = True
        try:
            if policy == "drain":
                while self._pending:        # empty the pipeline first
                    self._pump()
            if cost_s > 0.0:
                time.sleep(cost_s)          # weight redeploy: admissions
                                            # stall, in-flight work doesn't
            if codecs is not None:
                self.pipe.codecs = codecs
            self.pipe._note_migration(new_cuts, cost_j=cost_j)
            self._engine.submit_token(RECONFIG, self.pipe.reconfig_payload())
            self._expect[RECONFIG] += 1
            if self._exemplar is not None:  # jit-warm the new placement
                self._engine.submit_token(WARMUP,
                                          np.asarray(self._exemplar))
                self._expect[WARMUP] += 1
            if policy == "drain":           # confirmed before resuming
                self._await_tokens(RECONFIG, WARMUP)
            # drop: confirmations collected opportunistically by later
            # pumps while in-flight batches keep completing
        finally:
            self._migrating = False
        return self.pipe.cuts

    # ------------------------------------------------------------------ #
    def _check_failed(self) -> None:
        if self._failed:
            raise TransportError("session failed; no further submissions "
                                 "(see the original error)")

    def _await_tokens(self, *kinds: int) -> None:
        deadline = time.perf_counter() + self.pipe.timeout_s
        while any(self._expect[k] > 0 for k in kinds):
            if time.perf_counter() > deadline:
                raise TransportError(
                    "timed out waiting for in-band control token(s)")
            self._pump()

    def _pump(self, timeout: float | None = None) -> None:
        """Handle exactly one arrival at the result end."""
        try:
            kind, obj = self._engine.poll(timeout or self.pipe.timeout_s)
        except TransportTimeout:
            self._failed = True
            raise
        except TransportError:
            self._failed = True
            raise
        if kind == ERROR:
            self._failed = True
            if isinstance(obj, BaseException):
                raise obj                     # the stage's own exception
            raise TransportError(str(obj))
        self._drain_device_loss()
        if kind == BATCH:
            seq = self._next_arrival
            self._next_arrival += 1
            if seq in self._canceled:
                # a canceled batch flushing through: discard the arrival
                # — no result, no controller callback, no throughput
                # sample (skip markers complete unrealistically fast)
                self._pending.pop(seq, None)
                crec = self._cancel_live.pop(seq, None)
                if crec is not None:
                    crec.flushed = True
            else:
                t_sub, cuts, bsz, _ = self._pending.pop(seq)
                now = time.perf_counter()
                self._arrivals.append((now, bsz))
                self._ready[seq] = obj if self.keep_results else None
                rec = self.controller.on_result(self, seq, now - t_sub, cuts)
                if rec is not None:
                    self._records[seq] = rec
                    if self.record_cap:         # evict oldest beyond the cap
                        while len(self._records) > self.record_cap:
                            while self._rec_lo not in self._records:
                                self._rec_lo += 1
                            del self._records[self._rec_lo]
                            self._rec_lo += 1
            # a degraded pipeline restaffs to full replica strength at
            # the first quiescent point (nothing in flight to replay)
            if (getattr(self._engine, "_restaff_needed", False)
                    and not self._pending
                    and not any(n > 0 for n in self._expect.values())):
                self._engine.restaff()
            return
        if kind == STOP:                    # only during engine teardown
            return
        if kind == STATS:
            self._engine.harvest()
        if kind in self._expect:            # PROBE/RECONFIG/STATS/WARMUP/CLOCK
            self._expect[kind] = max(self._expect[kind] - 1, 0)
            return
        # every kind the session protocol can produce is handled above;
        # anything else reaching the result drain is a wire-level bug,
        # not something to silently swallow (pipecheck R1)
        self._failed = True
        raise TransportError(
            f"session: unexpected token kind {kind!r} at the result drain")

    def _drain_device_loss(self) -> None:
        """Forward supervisor-evicted (stage, lane) pairs to the
        controller — a device-loss event enters the adaptation loop like
        any other regime change (estimator update → re-solve →
        migrate over the existing RECONFIG path)."""
        drain = getattr(self._engine, "drain_device_loss", None)
        if drain is None:
            return
        for stage, lane in drain():
            cb = getattr(self.controller, "on_device_loss", None)
            if cb is not None:
                cb(self, stage, lane)

    def _replay_for_recovery(self) -> int:
        """Engine-supervisor callback, invoked after a stage restart has
        rebuilt the worker tier and replayed the WARMUP fence: re-send
        every unacked in-flight batch, oldest first.

        Correctness: pending seqs are the contiguous window
        [_next_arrival, _next_seq); the teardown destroyed every
        undelivered result, so re-sending the window in ascending order
        recomputes exactly the missing results in arrival order — zero
        lost, zero duplicated, zero reordered.  The fresh feed ring is
        empty and pending <= inflight <= feed depth, so nothing blocks.
        In-flight control tokens died with the channels: their expect
        counters reset here, and any token the engine's send loop
        retries afterwards is absorbed by _pump's surplus tolerance.
        """
        for k in self._expect:
            self._expect[k] = 0
        for seq in sorted(self._pending):
            self._engine._feed.send(self._pending[seq][3], kind=BATCH)
        return len(self._pending)

    def _flush_failed(self) -> None:
        """Best-effort flush after a failure.  A session aborted by a
        *user* exception leaves healthy workers completing in-flight
        batches into the persistent result channel — unclaimed, they
        would be misattributed as the next session's first arrivals.
        Bounded: after a transport failure there may be nothing alive
        left to drain.  Only process engines need it — a thread
        session's channels die with its stage threads."""
        if not getattr(self._engine, "results_persist", False):
            return
        deadline = time.perf_counter() + min(self.pipe.timeout_s, 10.0)
        while (self._pending
               or any(n > 0 for n in self._expect.values())):
            if time.perf_counter() > deadline:
                break
            try:
                kind, _ = self._engine.poll(1.0)
            except TransportTimeout:
                continue                      # a batch may still be computing
            except TransportError:
                break                         # the pipeline really is gone
            if kind == BATCH and self._pending:
                self._pending.pop(min(self._pending))
            elif kind == STATS:
                try:
                    self._engine.harvest()
                except Exception:
                    pass
                self._expect[STATS] = max(self._expect[STATS] - 1, 0)
            elif kind in self._expect:
                self._expect[kind] = max(self._expect[kind] - 1, 0)
            else:
                # unowned BATCH (pending already empty) or a stray
                # ERROR/STOP: the flush is best-effort by contract, but
                # the drop is explicit, not an accidental fall-through
                pass

    # lifecycle --------------------------------------------------------- #
    def close(self) -> None:
        """Drain (unless already failed) and release the pipeline for
        the next session / synchronous call."""
        if self.closed:
            return
        self.closed = True
        try:
            if not self._failed:
                while self._pending:
                    self._pump()
                outstanding = [k for k, n in self._expect.items() if n > 0]
                if outstanding:
                    self._await_tokens(*outstanding)
            else:
                self._flush_failed()
        finally:
            if getattr(self._engine, "_replay_cb", None) is not None:
                self._engine._replay_cb = None
            try:
                self._engine.session_close(failed=self._failed)
            finally:
                self.pipe._session = None

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        if exc and exc[0] is not None:
            self._failed = True             # don't drain through a wreck
        self.close()
