"""Executable ParetoPipe pipeline — orchestrator + workers (paper Fig. 1 / Alg. 1).

This is the *measured* half of the reproduction: a real partitioned
pipeline running on this host, with

  * two workers (threads standing in for the Pis / the GPU server), each
    executing its contiguous block range,
  * an emulated network between them (``tc``-style: RTT/2 + bytes/bw
    injected as wall-clock delay — exactly what the paper imposes with
    Linux traffic control),
  * **dual communication backends**, mirroring the paper's PyTorch-RPC
    vs. custom-socket study:

      - ``lightweight``: the activation is handed to the next worker as a
        device array, zero-copy, and each stage is one fused jitted
        function (the paper's custom TCP backend with tensor
        serialization only at the wire).
      - ``rpc``: per-*block* call dispatch (module-granularity RPC), with
        a full serialize → byte-buffer → deserialize round trip per hop
        plus a per-call coordination overhead — the structural costs that
        made PyTorch RPC slow in the paper (Sec. V-C).

Steady-state throughput is measured by streaming batches through both
workers concurrently (stage 2 of batch i overlaps stage 1 of batch i+1),
end-to-end latency by timing a lone batch through the empty pipeline —
the paper's two metrics.
"""
from __future__ import annotations

import pickle
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Literal, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.devices import Link

Backend = Literal["lightweight", "rpc"]

# Coordination overhead charged per RPC call (future creation, GIL
# handoff, TensorPipe negotiation ~ O(100us) in the paper's setup).
RPC_PER_CALL_OVERHEAD_S = 200e-6


@dataclass
class EmulatedLink:
    """tc-netem analogue: sleeps RTT/2 + bytes/bw per message."""

    link: Link

    def send(self, nbytes: int) -> float:
        dt = self.link.transfer_time(nbytes)
        time.sleep(dt)
        return dt


class _Serializer:
    """RPC-style full serialize/deserialize round trip."""

    @staticmethod
    def dumps(x: jax.Array) -> bytes:
        host = np.asarray(x)
        return pickle.dumps((host.shape, str(host.dtype), host.tobytes()))

    @staticmethod
    def loads(buf: bytes) -> jax.Array:
        shape, dtype, raw = pickle.loads(buf)
        return jnp.asarray(np.frombuffer(raw, dtype=dtype).reshape(shape))


@dataclass
class StageStats:
    exe_s: float = 0.0
    net_s: float = 0.0
    calls: int = 0
    cpu_pct: float = 0.0
    mem_pct: float = 0.0


class Worker:
    """One pipeline stage: executes blocks[lo:hi] of a CNNModel."""

    def __init__(self, name: str, model, params, lo: int, hi: int,
                 backend: Backend):
        self.name, self.lo, self.hi, self.backend = name, lo, hi, backend
        self.stats = StageStats()
        sub = params[lo:hi]
        layers = [layer for (_, layer) in model.blocks[lo:hi]]
        if backend == "lightweight":
            def fused(x, _layers=tuple(layers), _sub=tuple(sub)):
                for l, p in zip(_layers, _sub):
                    x = l.apply(p, x)
                return x
            self._fns = [jax.jit(fused)]
        else:
            # module-granularity dispatch, one jitted call per block
            self._fns = [jax.jit(lambda x, l=layer, p=p: l.apply(p, x))
                         for layer, p in zip(layers, sub)]

    def warmup(self, x):
        for fn in self._fns:
            x = fn(x)
        jax.block_until_ready(x)
        return x

    def run(self, x):
        t0 = time.perf_counter()
        if self.backend == "rpc":
            for fn in self._fns:
                # serialize/deserialize at every module-call boundary
                x = _Serializer.loads(_Serializer.dumps(x))
                time.sleep(RPC_PER_CALL_OVERHEAD_S)
                x = fn(x)
        else:
            x = self._fns[0](x)
        x = jax.block_until_ready(x)
        self.stats.exe_s += time.perf_counter() - t0
        self.stats.calls += 1
        return x


@dataclass
class PipelineResult:
    backend: Backend
    partition: int
    latency_s: float               # lone-batch end-to-end
    throughput: float              # samples/s steady state
    stage_exe_s: tuple[float, ...]  # mean per-batch exe per stage
    net_s: float                   # mean per-batch wire time
    cpu_pct: tuple[float, ...]
    mem_pct: tuple[float, ...]


class EdgePipeline:
    """Orchestrator (paper Alg. 1): split model at ``p``, deploy to two
    workers, stream batches, measure."""

    def __init__(self, model, params, p: int, link: Link,
                 backend: Backend = "lightweight"):
        n = len(model.blocks)
        if not (1 <= p <= n - 1):
            raise ValueError(f"split {p} out of range 1..{n-1}")
        self.model, self.p, self.backend = model, p, backend
        self.w1 = Worker("worker1", model, params, 0, p, backend)
        self.w2 = Worker("worker2", model, params, p, n, backend)
        self.net = EmulatedLink(link)

    # ------------------------------------------------------------------ #
    def _transfer(self, x) -> tuple[jax.Array, float]:
        nbytes = x.size * x.dtype.itemsize
        if self.backend == "rpc":
            buf = _Serializer.dumps(x)
            dt = self.net.send(len(buf))
            return _Serializer.loads(buf), dt
        dt = self.net.send(nbytes)
        return x, dt

    def run_one(self, x) -> tuple[jax.Array, float, float]:
        """One batch through the empty pipeline → (out, latency, net_s)."""
        t0 = time.perf_counter()
        a = self.w1.run(x)
        a, net = self._transfer(a)
        y = self.w2.run(a)
        return y, time.perf_counter() - t0, net

    def measure(self, make_batch: Callable[[], jax.Array],
                n_batches: int = 10, warmup: int = 1) -> PipelineResult:
        import psutil
        x = make_batch()
        a = self.w1.warmup(x)
        self.w2.warmup(a)
        self.w1.stats = StageStats()
        self.w2.stats = StageStats()

        # --- latency: lone batches ---------------------------------- #
        lat, net_t = [], []
        for _ in range(max(warmup, 1)):
            self.run_one(x)
        for _ in range(max(n_batches // 3, 2)):
            _, l, nt = self.run_one(x)
            lat.append(l)
            net_t.append(nt)

        # --- throughput: streamed, stages overlap -------------------- #
        self.w1.stats = StageStats()
        self.w2.stats = StageStats()
        q: queue.Queue = queue.Queue(maxsize=2)
        done: queue.Queue = queue.Queue()
        psutil.cpu_percent(None)
        p_mem = psutil.virtual_memory().percent

        def stage2():
            while True:
                item = q.get()
                if item is None:
                    return
                done.put(self.w2.run(item))

        t = threading.Thread(target=stage2, daemon=True)
        t.start()
        t0 = time.perf_counter()
        for _ in range(n_batches):
            a = self.w1.run(x)
            a, _ = self._transfer(a)
            q.put(a)
        q.put(None)
        t.join()
        total = time.perf_counter() - t0
        cpu = psutil.cpu_percent(None) * psutil.cpu_count()
        batch = x.shape[0]
        return PipelineResult(
            backend=self.backend, partition=self.p,
            latency_s=float(np.mean(lat)),
            throughput=n_batches * batch / total,
            stage_exe_s=(self.w1.stats.exe_s / self.w1.stats.calls,
                         self.w2.stats.exe_s / self.w2.stats.calls),
            net_s=float(np.mean(net_t)),
            cpu_pct=(cpu, cpu), mem_pct=(p_mem, p_mem),
        )
