"""Executable ParetoPipe pipeline — k-stage orchestrator + workers
(paper Fig. 1 / Alg. 1, generalized past the paper's 2-device testbed).

This is the *measured* half of the reproduction: a real partitioned
pipeline running on this host, with

  * one threaded ``Worker`` per stage (threads standing in for the Pis /
    the GPU server / pods), each executing its contiguous block range
    ``[cuts[i], cuts[i+1])``, bounded queues between stages,
  * an emulated network on every hop (``tc``-style: RTT/2 + bytes/bw
    injected as wall-clock delay — exactly what the paper imposes with
    Linux traffic control).  A hop may carry a static ``Link`` or a
    time-varying ``LinkTrace``, which the emulator samples at the
    pipeline clock on every transfer (WAN ramps, congestion spikes),
  * **dual communication backends per hop**, mirroring the paper's
    PyTorch-RPC vs. custom-socket study:

      - ``lightweight``: the activation is handed to the next worker as a
        device array, zero-copy, and each stage is one fused jitted
        function (the paper's custom TCP backend with tensor
        serialization only at the wire).
      - ``rpc``: per-*block* call dispatch (module-granularity RPC), with
        a full serialize → byte-buffer → deserialize round trip per hop
        plus a per-call coordination overhead — the structural costs that
        made PyTorch RPC slow in the paper (Sec. V-C).

Steady-state throughput is measured by streaming batches through all
stages concurrently (stage i+1 of batch b overlaps stage i of batch b+1),
end-to-end latency by timing a lone batch through the empty pipeline —
the paper's two metrics.  Every emulated transfer is recorded per hop so
a closed adaptive loop (``runtime.adaptive``) can feed *observed* wire
times back into ``LinkEstimator``s, and ``migrate`` re-instantiates the
workers at a new cut vector without tearing the pipeline down.
"""
from __future__ import annotations

import pickle
import queue
import threading
import time
from dataclasses import dataclass
from typing import Callable, Literal, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.devices import AnyLink, Link, LinkTrace
from ..core.scenarios import Scenario

Backend = Literal["lightweight", "rpc"]

# Coordination overhead charged per RPC call (future creation, GIL
# handoff, TensorPipe negotiation ~ O(100us) in the paper's setup).
RPC_PER_CALL_OVERHEAD_S = 200e-6


class EmulatedLink:
    """tc-netem analogue: sleeps RTT/2 + bytes/bw per message.

    ``LinkTrace`` hops are sampled at the pipeline clock on every send
    (with the trace's jitter, seeded deterministically), and every
    transfer is recorded as ``(nbytes, elapsed_s, t_s)`` so the adaptive
    loop can replay what the wire actually did."""

    def __init__(self, link: AnyLink, clock: Callable[[], float] | None = None,
                 seed: int = 0):
        self.link = link
        self._clock = clock or (lambda: 0.0)
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self.observations: list[tuple[int, float, float]] = []
        # lifetime radio accounting (never drained): joules = link radio
        # cost × bytes actually pushed through this hop
        self.total_bytes: int = 0
        self.total_energy_j: float = 0.0

    def send(self, nbytes: int) -> float:
        t = self._clock()
        if isinstance(self.link, LinkTrace):
            dt = self.link.transfer_time(nbytes, t, rng=self._rng)
        else:
            dt = self.link.transfer_time(nbytes)
        time.sleep(dt)
        with self._lock:
            self.observations.append((nbytes, dt, t))
            self.total_bytes += nbytes
            self.total_energy_j += self.link.energy_per_byte_j * nbytes
        return dt

    def drain_observations(self) -> list[tuple[int, float, float]]:
        with self._lock:
            obs, self.observations = self.observations, []
        return obs


class _Serializer:
    """RPC-style full serialize/deserialize round trip."""

    @staticmethod
    def dumps(x: jax.Array) -> bytes:
        host = np.asarray(x)
        return pickle.dumps((host.shape, str(host.dtype), host.tobytes()))

    @staticmethod
    def loads(buf: bytes) -> jax.Array:
        shape, dtype, raw = pickle.loads(buf)
        return jnp.asarray(np.frombuffer(raw, dtype=dtype).reshape(shape))


@dataclass
class StageStats:
    exe_s: float = 0.0
    net_s: float = 0.0
    calls: int = 0
    cpu_pct: float = 0.0
    mem_pct: float = 0.0


class Worker:
    """One pipeline stage: executes blocks[lo:hi] of a CNNModel."""

    def __init__(self, name: str, model, params, lo: int, hi: int,
                 backend: Backend):
        self.name, self.lo, self.hi, self.backend = name, lo, hi, backend
        self.stats = StageStats()
        sub = params[lo:hi]
        layers = [layer for (_, layer) in model.blocks[lo:hi]]
        if backend == "lightweight":
            def fused(x, _layers=tuple(layers), _sub=tuple(sub)):
                for l, p in zip(_layers, _sub):
                    x = l.apply(p, x)
                return x
            self._fns = [jax.jit(fused)]
        else:
            # module-granularity dispatch, one jitted call per block
            self._fns = [jax.jit(lambda x, l=layer, p=p: l.apply(p, x))
                         for layer, p in zip(layers, sub)]

    def warmup(self, x):
        for fn in self._fns:
            x = fn(x)
        jax.block_until_ready(x)
        return x

    def run(self, x):
        t0 = time.perf_counter()
        if self.backend == "rpc":
            for fn in self._fns:
                # serialize/deserialize at every module-call boundary
                x = _Serializer.loads(_Serializer.dumps(x))
                time.sleep(RPC_PER_CALL_OVERHEAD_S)
                x = fn(x)
        else:
            x = self._fns[0](x)
        x = jax.block_until_ready(x)
        self.stats.exe_s += time.perf_counter() - t0
        self.stats.calls += 1
        return x


@dataclass
class PipelineResult:
    backend: str                    # per-stage backends, "+"-joined if mixed
    partition: tuple[int, ...]      # cut vector
    latency_s: float                # lone-batch end-to-end
    throughput: float               # samples/s steady state
    stage_exe_s: tuple[float, ...]  # mean per-batch exe per stage
    net_s: float                    # mean per-batch wire time, all hops
    hop_net_s: tuple[float, ...] = ()   # mean per-batch wire time per hop
    cpu_pct: tuple[float, ...] = ()
    mem_pct: tuple[float, ...] = ()
    # modeled J/batch from *measured* stage times + wire bytes (scenario
    # device power × exe + idle × wire wait + radio × bytes); 0.0 when
    # the pipeline was built from bare links (no device power profile)
    energy_j: float = 0.0
    stage_energy_j: tuple[float, ...] = ()


class EdgePipeline:
    """Orchestrator (paper Alg. 1, k-stage): split the model at a cut
    vector, deploy one worker per scenario device, stream batches through
    per-hop emulated links, measure.

    ``cuts``     — interior cut vector (k-1 ints, strictly increasing),
                   or a single int for the classic 2-stage split.
    ``scenario`` — a ``Scenario`` (device chain + per-hop links), a bare
                   ``Link``/``LinkTrace`` (2-stage convenience), or a
                   sequence of per-hop links.
    ``backend``  — one backend for every stage, or a per-stage sequence.

    The legacy 2-stage keywords ``p=`` and ``link=`` are still accepted.
    """

    def __init__(self, model, params, cuts=None, scenario=None,
                 backend: Backend | Sequence[Backend] = "lightweight",
                 *, p: int | None = None, link: AnyLink | None = None,
                 queue_depth: int = 2, clock: Callable[[], float] | None = None,
                 seed: int = 0):
        if p is not None:
            cuts = p
        if link is not None:
            scenario = link
        if cuts is None:
            raise ValueError("need a cut vector (cuts=... or p=...)")
        if scenario is None:
            raise ValueError("need a Scenario, per-hop links, or link=...")

        if isinstance(scenario, Scenario):
            self.scenario: Scenario | None = scenario
            links: tuple[AnyLink, ...] = tuple(scenario.links)
        elif isinstance(scenario, (Link, LinkTrace)):
            self.scenario = None
            links = (scenario,)
        else:
            self.scenario = None
            links = tuple(scenario)

        self.model, self.params = model, params
        self.n_stages = len(links) + 1
        if isinstance(backend, str):
            self.backends: tuple[Backend, ...] = (backend,) * self.n_stages
        else:
            self.backends = tuple(backend)
            if len(self.backends) != self.n_stages:
                raise ValueError(f"{len(self.backends)} backends for "
                                 f"{self.n_stages} stages")
        self.queue_depth = queue_depth
        self._t0 = time.perf_counter()
        self.clock = clock or (lambda: time.perf_counter() - self._t0)
        self.nets = [EmulatedLink(l, self.clock, seed=seed + i)
                     for i, l in enumerate(links)]
        self.migrations: list[tuple[float, tuple[int, ...], tuple[int, ...]]] = []
        self.cuts = self._check_cuts(cuts)
        self._build_workers()

    # ------------------------------------------------------------------ #
    def _check_cuts(self, cuts) -> tuple[int, ...]:
        n = len(self.model.blocks)
        if isinstance(cuts, int):
            cuts = (cuts,)
        cuts = tuple(int(c) for c in cuts)
        if len(cuts) != self.n_stages - 1:
            raise ValueError(f"{len(cuts)} cuts for {self.n_stages} stages; "
                             f"need {self.n_stages - 1}")
        bounds = (0, *cuts, n)
        for a, b in zip(bounds, bounds[1:]):
            if not (0 <= a < b <= n):
                raise ValueError(f"cuts {cuts} invalid for {n} blocks "
                                 "(stages must be non-empty and ordered)")
        return cuts

    def _build_workers(self, reuse: Sequence[Worker] = ()) -> None:
        """Instantiate stage workers, reusing any existing worker whose
        (block range, backend) is unchanged — its jitted functions stay
        warm across a migration."""
        pool = {(w.lo, w.hi, w.backend): w for w in reuse}
        bounds = (0, *self.cuts, len(self.model.blocks))
        self.workers = [
            pool.get((bounds[i], bounds[i + 1], self.backends[i]))
            or Worker(f"worker{i + 1}", self.model, self.params,
                      bounds[i], bounds[i + 1], self.backends[i])
            for i in range(self.n_stages)]

    # legacy 2-stage accessors ----------------------------------------- #
    @property
    def p(self) -> int:
        return self.cuts[0]

    @property
    def backend(self) -> str:
        return "+".join(sorted(set(self.backends)))

    def reset_clock(self) -> None:
        """Restart the pipeline clock (trace time 0) — call before a run
        that should experience a LinkTrace from its beginning."""
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------------ #
    def migrate(self, new_cuts, cost_s: float = 0.0) -> tuple[int, ...]:
        """Live migration: re-instantiate the workers at ``new_cuts``.

        ``cost_s`` is the one-off redeploy cost (weights moving to their
        new hosts) charged as wall-clock time, i.e. the splitter's
        ``migration_cost_s``.  Link state (clock, traces, observations)
        survives the migration."""
        new_cuts = self._check_cuts(new_cuts)
        if cost_s > 0.0:
            time.sleep(cost_s)
        self.migrations.append((self.clock(), self.cuts, new_cuts))
        self.cuts = new_cuts
        self._build_workers(reuse=self.workers)
        return self.cuts

    # ------------------------------------------------------------------ #
    def _hop(self, i: int, x) -> tuple[jax.Array, float]:
        """Transfer ``x`` over hop i, in the sending stage's wire format."""
        if self.backends[i] == "rpc":
            buf = _Serializer.dumps(x)
            dt = self.nets[i].send(len(buf))
            return _Serializer.loads(buf), dt
        dt = self.nets[i].send(x.size * x.dtype.itemsize)
        return x, dt

    def warmup(self, x):
        for i, w in enumerate(self.workers):
            x = w.warmup(x)
        return x

    def _reset_stats(self) -> None:
        for w in self.workers:
            w.stats = StageStats()

    def run_one(self, x) -> tuple[jax.Array, float, tuple[float, ...]]:
        """One batch through the empty pipeline →
        (out, end-to-end latency, per-hop wire times)."""
        t0 = time.perf_counter()
        hop_net: list[float] = []
        for i, w in enumerate(self.workers):
            x = w.run(x)
            if i < len(self.nets):
                x, dt = self._hop(i, x)
                hop_net.append(dt)
        return x, time.perf_counter() - t0, tuple(hop_net)

    def stream(self, x, n_batches: int) -> float:
        """Push ``n_batches`` copies of ``x`` through all stages
        concurrently (bounded queues) → total wall time."""
        k = self.n_stages
        if k == 1:
            t0 = time.perf_counter()
            for _ in range(n_batches):
                self.workers[0].run(x)      # run() blocks until ready
            return time.perf_counter() - t0

        qs = [queue.Queue(maxsize=self.queue_depth) for _ in range(k - 1)]
        errors: list[BaseException] = []

        def stage(i: int):
            # on failure, keep draining the input queue so upstream
            # producers never block on a full queue, and still forward
            # the shutdown sentinel — a dead stage must not hang the run
            failed = False
            while True:
                item = qs[i - 1].get()
                if item is None:
                    if i < k - 1:
                        qs[i].put(None)
                    return
                if failed:
                    continue
                try:
                    y = self.workers[i].run(item)
                    if i < k - 1:
                        y, _ = self._hop(i, y)
                        qs[i].put(y)
                    # last stage: run() already blocked until ready;
                    # the output is complete and can be dropped
                except BaseException as e:   # noqa: BLE001 — re-raised below
                    errors.append(e)
                    failed = True

        threads = [threading.Thread(target=stage, args=(i,), daemon=True)
                   for i in range(1, k)]
        for t in threads:
            t.start()
        t0 = time.perf_counter()
        try:
            for _ in range(n_batches):
                a = self.workers[0].run(x)
                a, _ = self._hop(0, a)
                qs[0].put(a)
        finally:
            qs[0].put(None)
            for t in threads:
                t.join()
        if errors:
            raise errors[0]
        return time.perf_counter() - t0

    def stage_energy_model(self, stage_exe_s: Sequence[float],
                            hop_net_s: Sequence[float],
                            hop_bytes: Sequence[float],
                            ) -> tuple[float, tuple[float, ...]]:
        """Modeled J/batch from measured per-stage compute times: device
        active power × exe, idle power while its outbound hop drains, and
        each hop's radio cost × bytes.  Needs a Scenario (device power
        profiles); bare-link pipelines report 0."""
        if self.scenario is None:
            return 0.0, ()
        from ..core.costmodel import _stage_energy
        per_stage = tuple(
            _stage_energy(dev, stage_exe_s[i],
                          hop_net_s[i] if i < len(hop_net_s) else 0.0,
                          hop_bytes[i] if i < len(hop_bytes) else 0.0,
                          self.nets[i].link if i < len(self.nets) else None)
            for i, dev in enumerate(self.scenario.devices))
        return sum(per_stage), per_stage

    # ------------------------------------------------------------------ #
    def measure(self, make_batch: Callable[[], jax.Array],
                n_batches: int = 10, warmup: int = 1) -> PipelineResult:
        import psutil
        x = make_batch()
        self.warmup(x)
        self._reset_stats()
        # jit warmup can take seconds — restart trace time so a LinkTrace
        # scenario is measured from its beginning, not mid-ramp
        self.reset_clock()

        # --- latency: lone batches ---------------------------------- #
        lat: list[float] = []
        hop_t: list[tuple[float, ...]] = []
        for _ in range(max(warmup, 1)):
            self.run_one(x)
        bytes0 = [net.total_bytes for net in self.nets]
        for _ in range(max(n_batches // 3, 2)):
            _, l, hops = self.run_one(x)
            lat.append(l)
            hop_t.append(hops)
        hop_bytes = [(net.total_bytes - b0) / len(lat)
                     for net, b0 in zip(self.nets, bytes0)]

        # --- throughput: streamed, stages overlap -------------------- #
        self._reset_stats()
        # the latency phase advanced trace time (degraded lone batches
        # sleep); restart so both metrics sample the trace from t=0
        self.reset_clock()
        psutil.cpu_percent(None)
        p_mem = psutil.virtual_memory().percent
        total = self.stream(x, n_batches)
        cpu = psutil.cpu_percent(None) * psutil.cpu_count()
        batch = x.shape[0]
        hop_net = tuple(float(np.mean([h[i] for h in hop_t]))
                        for i in range(len(self.nets)))
        stage_exe = tuple(w.stats.exe_s / max(w.stats.calls, 1)
                          for w in self.workers)
        energy, stage_energy = self.stage_energy_model(stage_exe, hop_net,
                                                       hop_bytes)
        return PipelineResult(
            backend=self.backend, partition=self.cuts,
            latency_s=float(np.mean(lat)),
            throughput=n_batches * batch / total,
            stage_exe_s=stage_exe,
            net_s=float(sum(hop_net)),
            hop_net_s=hop_net,
            cpu_pct=(cpu,) * self.n_stages,
            mem_pct=(p_mem,) * self.n_stages,
            energy_j=energy,
            stage_energy_j=stage_energy,
        )
