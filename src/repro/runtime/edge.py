"""Executable ParetoPipe pipeline — k-stage orchestrator + workers
(paper Fig. 1 / Alg. 1, generalized past the paper's 2-device testbed).

This is the *measured* half of the reproduction: a real partitioned
pipeline running on this host, one worker per stage executing its
contiguous block range ``[cuts[i], cuts[i+1])``, with the hop layer
behind the pluggable Transport API (``runtime.transport``):

  * ``emulated`` — stages are threads, every hop an ``EmulatedChannel``
    (tc-style: RTT/2 + bytes/bw injected as wall-clock delay, static
    ``Link`` or time-varying ``LinkTrace`` sampled at the pipeline clock
    per transfer).  Backend cost is *modeled*.
  * ``socket`` — stages are OS processes (``multiprocessing`` spawn),
    every hop real TCP on loopback with the paper's lightweight wire
    format.  Backend cost is *measured* per transfer.
  * ``shmem`` — stages are processes, hops a shared-memory ring
    (zero-copy local case).  Measured.

Orthogonally, **dual communication backends per stage** mirror the
paper's PyTorch-RPC vs. custom-socket study:

  - ``lightweight``: one fused jitted function per stage, activations
    cross the hop as raw tensor bytes (header + payload only).
  - ``rpc``: per-*block* call dispatch with a full serialize →
    byte-buffer → deserialize round trip per hop plus a per-call
    coordination overhead — the structural costs that made PyTorch RPC
    slow in the paper (Sec. V-C).

Execution is always pipelined: the streaming ``Session`` API
(``EdgePipeline.session``, ``runtime.session``) feeds batches into the
concurrent stage chain and hands results back in order, with pluggable
controllers deciding when to re-solve and migrate mid-stream.
``run_one`` (the paper's lone-batch latency metric) and ``stream``
(steady-state throughput) are thin shims over one-deep / full-window
sessions.  Every transfer is recorded per hop (modeled delay under
``emulated``, measured wall-clock under ``socket``/``shmem``) so the
closed adaptive loop (``runtime.adaptive``) feeds *observed* wire times
into its ``LinkEstimator``s, and migration re-deploys a new cut vector
without tearing the pipeline down — across threads or live worker
processes, with batches in flight.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from dataclasses import dataclass
from typing import Callable, Literal, Sequence

import jax
import numpy as np

from ..core.devices import AnyLink, Link, LinkTrace
from ..core.scenarios import Scenario
from . import transport as T
from .sanitizer import maybe_sanitize, sanitize_enabled
from .transport import (BATCH, CANCEL, CLOCK, ERROR, PROBE, RECONFIG, STATS,
                        STOP, WARMUP, Channel, HopMeter, HopSpec,
                        TransferRecord, TransportError, TransportTimeout,
                        _Serializer, get_transport)

Backend = Literal["lightweight", "rpc"]

# Coordination overhead charged per RPC call (future creation, GIL
# handoff, TensorPipe negotiation ~ O(100us) in the paper's setup).
RPC_PER_CALL_OVERHEAD_S = 200e-6


@dataclass
class StageStats:
    exe_s: float = 0.0
    net_s: float = 0.0
    calls: int = 0
    cpu_s: float = 0.0              # worker CPU time (thread/process clock)
    cpu_pct: float = 0.0
    mem_pct: float = 0.0


class Worker:
    """One pipeline stage: executes blocks[lo:hi] of a CNNModel.

    ``cpu_clock`` attributes CPU time to this worker (default
    ``process_time`` — XLA:CPU executes on an internal pool, which a
    per-thread clock cannot see).  Attribution is exact when the worker
    owns its process; under threads it is exact whenever stages run
    sequentially (the latency phase), which is where ``measure`` reads
    it — per-stage numbers either way, instead of one host-wide reading
    broadcast to every stage."""

    def __init__(self, name: str, model, params, lo: int, hi: int,
                 backend: Backend, cpu_clock: Callable[[], float] | None = None,
                 pace_s: float = 0.0):
        self.name, self.lo, self.hi, self.backend = name, lo, hi, backend
        self.stats = StageStats()
        self._cpu_clock = cpu_clock or time.process_time
        # per-batch floor on this stage's wall time — device-speed
        # emulation on a host faster than the scenario's hardware, the
        # compute-side twin of EmulatedChannel's link pacing.  The paced
        # remainder is a sleep, so replicated stages genuinely overlap
        # even on a single-core host.
        self.pace_s = pace_s
        sub = params[lo:hi]
        layers = [layer for (_, layer) in model.blocks[lo:hi]]
        if backend == "lightweight":
            def fused(x, _layers=tuple(layers), _sub=tuple(sub)):
                for l, p in zip(_layers, _sub):
                    x = l.apply(p, x)
                return x
            self._fns = [jax.jit(fused)]
        else:
            # module-granularity dispatch, one jitted call per block
            self._fns = [jax.jit(lambda x, l=layer, p=p: l.apply(p, x))
                         for layer, p in zip(layers, sub)]

    def warmup(self, x):
        for fn in self._fns:
            x = fn(x)
        jax.block_until_ready(x)
        return x

    def run(self, x):
        t0 = time.perf_counter()
        c0 = self._cpu_clock()
        if self.backend == "rpc":
            for fn in self._fns:
                # serialize/deserialize at every module-call boundary
                x = _Serializer.loads(_Serializer.dumps(x))
                time.sleep(RPC_PER_CALL_OVERHEAD_S)
                x = fn(x)
        else:
            x = self._fns[0](x)
        x = jax.block_until_ready(x)
        if self.pace_s > 0.0:
            rem = self.pace_s - (time.perf_counter() - t0)
            if rem > 0:
                time.sleep(rem)
        self.stats.exe_s += time.perf_counter() - t0
        self.stats.cpu_s += self._cpu_clock() - c0
        self.stats.calls += 1
        return x


@dataclass
class PipelineResult:
    backend: str                    # per-stage backends, "+"-joined if mixed
    partition: tuple[int, ...]      # cut vector
    latency_s: float                # lone-batch end-to-end
    throughput: float               # samples/s steady state
    stage_exe_s: tuple[float, ...]  # mean per-batch exe per stage
    net_s: float                    # mean per-batch wire time, all hops
    hop_net_s: tuple[float, ...] = ()   # mean per-batch wire time per hop
    cpu_pct: tuple[float, ...] = ()     # per-worker CPU util while executing
    mem_pct: tuple[float, ...] = ()     # per-worker-host RSS share
    # modeled J/batch from *measured* stage times + wire bytes (scenario
    # device power × exe + idle × wire wait + radio × bytes); 0.0 when
    # the pipeline was built from bare links (no device power profile)
    energy_j: float = 0.0
    stage_energy_j: tuple[float, ...] = ()
    transport: str = "emulated"     # per-hop transports, "+"-joined if mixed
    replicas: tuple[int, ...] = ()  # per-stage replica counts ((): all 1)


# --------------------------------------------------------------------------- #
# Engines: where the workers live and how batches cross hops
# --------------------------------------------------------------------------- #
class _QueueChan:
    """A ``queue.Queue`` behind the Channel send/recv surface, so the
    thread engine's feed/result ends compose with the replica fan
    wrappers exactly like real channels do."""

    hop = HopSpec(index=-1, scenario_hop=False)

    def __init__(self):
        self._q: queue.Queue = queue.Queue()
        self.epoch = 0.0

    def send(self, payload=None, kind: int = BATCH):
        self._q.put((kind, payload))

    def recv(self, timeout: float | None = None):
        try:
            return self._q.get(timeout=timeout)
        except queue.Empty:
            raise TransportTimeout("session: no result arrived") from None

    def set_codec(self, name: str) -> None:
        pass

    def drain_records(self):
        return []

    def close(self) -> None:
        pass

    def reap(self) -> None:
        pass


class _LaneGroupObs:
    """One per-hop observation surface over a replicated hop's lanes —
    what ``pipe.nets`` exposes when a thread-engine hop has several
    emulated lanes (process hops aggregate into ``HopMeter``s at
    harvest time instead)."""

    def __init__(self, lanes: Sequence[T.EmulatedChannel]):
        self.lanes = list(lanes)

    @property
    def link(self):
        return self.lanes[0].link

    def drain_observations(self) -> list[TransferRecord]:
        out: list[TransferRecord] = []
        for lane in self.lanes:
            out.extend(lane.drain_observations())
        out.sort(key=lambda r: r.t_s)
        return out

    drain_records = drain_observations

    def _sum(self, attr: str):
        return sum(getattr(l, attr) for l in self.lanes)

    @property
    def observations(self):
        return [r for lane in self.lanes for r in lane.observations]

    @property
    def total_bytes(self):
        return self._sum("total_bytes")

    @property
    def total_raw_bytes(self):
        return self._sum("total_raw_bytes")

    @property
    def total_energy_j(self):
        return self._sum("total_energy_j")

    @property
    def total_transfers(self):
        return self._sum("total_transfers")

    @property
    def total_elapsed_s(self):
        return self._sum("total_elapsed_s")


class _ThreadEngine:
    """Stages as threads of this process, hops as EmulatedChannels —
    the modeled path (and the only one a LinkTrace can drive).  A stage
    with ``replicas[i] == r`` runs as r session threads over a lane
    group of r channels (see ``transport.FanOutChannel``)."""

    def __init__(self, pipe: "EdgePipeline"):
        self.pipe = pipe
        self.chan_groups: list[list[T.EmulatedChannel]] = self._open_chans()
        self.stage_workers: list[list[Worker]] = []
        self._build_workers()

    def _open_chans(self) -> "list[list[T.EmulatedChannel]]":
        pipe = self.pipe
        r = pipe.replicas
        tr = get_transport("emulated", clock=pipe.clock)
        return [
            [maybe_sanitize(c) for c in
             tr.open_fan(HopSpec(index=i, link=link,
                                 framing=("pickle" if pipe.backends[i] == "rpc"
                                          else "raw"),
                                 depth=pipe.queue_depth, seed=pipe.seed + i,
                                 codec=pipe.codecs[i],
                                 sanitize=pipe.sanitize),
                         max(r[i], r[i + 1]))]
            for i, link in enumerate(pipe.links)]

    @property
    def nets(self):
        return [g[0] if len(g) == 1 else _LaneGroupObs(g)
                for g in self.chan_groups]

    @property
    def workers(self) -> list[Worker]:
        """Flat stage-major worker list (replica-free pipelines see the
        historical one-worker-per-stage shape)."""
        return [w for ws in self.stage_workers for w in ws]

    def _build_workers(self, reuse: Sequence[Worker] = ()) -> None:
        """Instantiate stage workers, reusing any existing worker whose
        (block range, backend) is unchanged — its jitted functions stay
        warm across a migration."""
        pipe = self.pipe
        pool: dict[tuple, list[Worker]] = {}
        for w in reuse:
            pool.setdefault((w.lo, w.hi, w.backend), []).append(w)
        bounds = pipe.bounds()
        self.stage_workers = []
        for i in range(pipe.n_stages):
            key = (bounds[i], bounds[i + 1], pipe.backends[i])
            ws = []
            for m in range(pipe.replicas[i]):
                cached = pool[key].pop() if pool.get(key) else None
                ws.append(cached or Worker(
                    f"worker{i + 1}", pipe.model, pipe.params,
                    bounds[i], bounds[i + 1], pipe.backends[i],
                    pace_s=pipe.stage_pace_s[i]))
            self.stage_workers.append(ws)

    def warmup(self, x):
        for ws in self.stage_workers:
            y = None
            for w in ws:                      # every replica jits its stage
                y = w.warmup(x)
            x = y
        return x

    def migrate(self) -> None:
        self._build_workers(reuse=self.workers)
        for i, group in enumerate(self.chan_groups):
            for chan in group:
                chan.set_codec(self.pipe.codecs[i])

    def probe(self) -> None:
        for group in self.chan_groups:
            for chan in group:
                chan.send(kind=PROBE)         # records the RTT sample …
                chan.recv(timeout=5.0)        # … and consumes the token
                                              # (no session thread to)

    def stage_stats(self) -> list[StageStats]:
        out = []
        for ws in self.stage_workers:
            s = StageStats()
            for w in ws:                      # replicas fold into one
                s.exe_s += w.stats.exe_s      # logical stage
                s.net_s += w.stats.net_s
                s.calls += w.stats.calls
                s.cpu_s += w.stats.cpu_s
                s.mem_pct = max(s.mem_pct, w.stats.mem_pct)
            out.append(s)
        return out

    def reset_stats(self) -> None:
        for w in self.workers:
            w.stats = StageStats()

    def set_epoch(self, _epoch: float) -> None:
        pass                                  # channels read pipe.clock live

    # session primitives: persistent stage threads, in-band tokens ------- #
    def session_open(self) -> None:
        pipe = self.pipe
        k, r = pipe.n_stages, pipe.replicas
        for group in self.chan_groups:        # channels outlive sessions:
            for chan in group:                # STOP is terminal per stream
                if hasattr(chan, "reset_stream"):
                    chan.reset_stream()
        self._feed_lanes = [_QueueChan() for _ in range(r[0])]
        self._out_lanes = [_QueueChan() for _ in range(r[k - 1])]
        self._err: queue.Queue = queue.Queue()
        lanes: list[list] = [self._feed_lanes, *self.chan_groups,
                             self._out_lanes]
        self._feed = (T.FanOutChannel(self._feed_lanes)
                      if len(self._feed_lanes) > 1 else self._feed_lanes[0])
        self._result = (T.FanInChannel(self._out_lanes)
                        if len(self._out_lanes) > 1 else self._out_lanes[0])
        self._cancel_epoch = 0                # flush-cancels this session
        self._sthreads = []
        for i in range(k):
            for m in range(r[i]):
                # replica m owns lane m through a replicated region; a
                # solo stage facing a wider group fans out / merges in
                ingress = (lanes[i][m] if r[i] > 1
                           else T.FanInChannel(lanes[i])
                           if len(lanes[i]) > 1 else lanes[i][0])
                egress = (lanes[i + 1][m] if r[i] > 1
                          else T.FanOutChannel(lanes[i + 1])
                          if len(lanes[i + 1]) > 1 else lanes[i + 1][0])
                t = threading.Thread(
                    target=self._stage_loop, args=(i, m, ingress, egress),
                    daemon=True, name=f"session-stage{i}.{m}")
                self._sthreads.append(t)
        for t in self._sthreads:
            t.start()

    def _stage_loop(self, i: int, m: int, ingress, egress) -> None:
        """One pipeline stage replica as a session thread: recv →
        handle → send, every control token flowing in-band with the
        batches around it (the thread-engine mirror of
        ``transport._worker_main``)."""
        pipe = self.pipe
        last = i == pipe.n_stages - 1
        failed = False
        # flush-cancel skip window: ``cancel_flush`` bumps the shared
        # epoch out-of-band (a plain int read — GIL-atomic), so batches
        # still queued ahead of the in-band CANCEL fence skip compute
        # and travel on as empty None markers.  The fence (truthy
        # payload) closes the window.  See transport._worker_main for
        # the process-engine twin.
        fence_seen = 0
        while True:
            try:
                # bounded wait (pipecheck R6): a wedged upstream must not
                # park this thread beyond the doorbell cadence
                kind, obj = ingress.recv(timeout=1.0)
            except TransportTimeout:
                continue
            if kind == STOP:
                egress.send(None, kind=STOP)
                return
            if failed:                        # drain so upstream never
                continue                      # blocks on a full queue
            try:
                if kind == BATCH:
                    if obj is None or fence_seen < self._cancel_epoch:
                        egress.send(None, kind=BATCH)  # canceled: marker
                    else:
                        egress.send(self.stage_workers[i][m].run(obj),
                                    kind=BATCH)
                elif kind == CANCEL:
                    if obj:
                        fence_seen += 1
                    egress.send(obj, kind=CANCEL)
                elif kind == WARMUP:
                    egress.send(self.stage_workers[i][m].warmup(obj),
                                kind=WARMUP)
                elif kind == RECONFIG:
                    if isinstance(obj, dict):   # {"bounds":…, "codecs":…}
                        bounds = tuple(obj["bounds"])
                        codecs = obj.get("codecs")
                    else:                       # legacy bare bounds tuple
                        bounds, codecs = tuple(obj), None
                    w = self.stage_workers[i][m]
                    if (bounds[i], bounds[i + 1]) != (w.lo, w.hi):
                        self.stage_workers[i][m] = Worker(
                            f"worker{i + 1}", pipe.model, pipe.params,
                            bounds[i], bounds[i + 1], pipe.backends[i],
                            pace_s=pipe.stage_pace_s[i])
                    if codecs is not None and not last:
                        egress.set_codec(codecs[i])
                    egress.send(obj, kind=RECONFIG)
                elif kind == PROBE:
                    egress.send(None, kind=PROBE)  # emulates 0 bytes per hop
                elif kind in (STATS, CLOCK):  # pass-through tokens
                    egress.send(obj, kind=kind)
                else:
                    # ERROR never originates upstream of a thread stage
                    # (errors ride self._err), so any other kind is a
                    # protocol break — fail loudly instead of silently
                    # forwarding (pipecheck R1)
                    raise TransportError(
                        f"stage {i}.{m}: unexpected "
                        f"{T._KIND_NAMES[kind] if 0 <= kind < len(T._KIND_NAMES) else kind} "
                        f"token in session stream")
            except BaseException as e:        # noqa: BLE001 — reported
                failed = True
                # in-process: ship the exception object itself, so the
                # session re-raises the caller's own type with its
                # traceback (process workers can only send strings);
                # a dedicated error queue keeps lane ordering intact
                self._err.put((ERROR, e))

    def submit(self, x) -> None:
        self._feed.send(x, kind=BATCH)

    def submit_token(self, kind: int, obj=None) -> None:
        self._feed.send(obj, kind=kind)

    def cancel_flush(self) -> None:
        """Open a skip window: batches already in flight short-circuit
        compute until the next flush CANCEL fence passes each stage."""
        self._cancel_epoch += 1

    def poll(self, timeout: float):
        deadline = time.perf_counter() + timeout
        while True:
            try:
                return self._err.get_nowait()
            except queue.Empty:
                pass
            try:
                return self._result.recv(timeout=min(timeout, 0.1))
            except TransportTimeout:
                if time.perf_counter() >= deadline:
                    raise TransportTimeout(
                        "session: no result arrived") from None

    def harvest(self) -> None:
        pass                                  # stats/records are live

    def max_inflight(self) -> int | None:
        return None                           # the feed queues are unbounded

    def session_close(self, failed: bool = False) -> None:
        try:
            self._feed.send(None, kind=STOP)  # broadcast across feed lanes
        except Exception:
            pass
        deadline = time.perf_counter() + 5.0
        for t in self._sthreads:
            t.join(max(deadline - time.perf_counter(), 0.05))
        stragglers = any(t.is_alive() for t in self._sthreads)
        self._sthreads = []
        if stragglers:
            # a stage still computing can push its finished batch (and
            # the forwarded STOP) into the channels *after* this close —
            # orphan them so a later session cannot consume leftovers
            # (the straggler blocks or writes into the abandoned queue,
            # which dies with its daemon thread)
            self.chan_groups = self._open_chans()
            return
        # threads are gone: a clean close left the channels empty (STOP
        # reached the result lanes); after a failure, drop what draining
        # left behind
        for group in self.chan_groups:
            for chan in group:
                try:
                    while True:
                        chan._q.get_nowait()
                except queue.Empty:
                    pass

    def host_mem_pct(self) -> float:
        import psutil
        return psutil.Process().memory_percent()

    def close(self) -> None:
        pass


# how long a blocked orchestrator feed send waits before resurfacing as
# TransportTimeout so the engine can re-check worker liveness — the
# cadence of the supervisor's heartbeat on the submit path
_FEED_SEND_CHUNK_S = 0.5


class _ProcessEngine:
    results_persist = True      # the worker loop outlives any session
    """Stages as spawned OS processes (``WorkerHost``s), hops as real
    socket/shmem channels — the measured path.  The orchestrator feeds
    stage 0 and drains stage k-1 over extra (non-scenario) channels and
    harvests per-stage stats + per-hop TransferRecords over control
    pipes whenever a STATS token traverses the chain."""

    def __init__(self, pipe: "EdgePipeline"):
        import multiprocessing as mp
        from .faults import BackoffPolicy
        self.pipe = pipe
        self._ctx = mp.get_context("spawn")
        self._stop = self._ctx.Event()
        k = pipe.n_stages
        self._meters = [HopMeter(l) for l in pipe.links]
        self._stats = [StageStats() for _ in range(k)]
        self._procs: list = []
        self._ctrls: list = []
        self._ctrl_stage: list[int] = []      # worker w -> its logical stage
        self._proc_slot: list[tuple[int, int]] = []   # worker w -> (stage, lane)
        self._pairs: list = []                # flat (tx, rx) per lane
        self._groups: list[list] = []         # pairs grouped per channel j
        self._feed = None                     # Channel or FanOutChannel
        self._result = None                   # Channel or FanInChannel
        self._closed = False
        # -- supervisor state (active when pipe.supervise) -------------- #
        self.supervised = bool(getattr(pipe, "supervise", False))
        self._backoff = BackoffPolicy()
        self._down: dict[int, int] = {}       # stage -> evicted lane count
        self._restaff_needed = False
        self._device_loss: list[tuple[int, int]] = []  # undrained (stage, lane)
        self._replay_cb: Callable[[], int] | None = None
        self._recovering = False
        self._recover_count = 0
        self._batch_seq = 0                   # global batches fed (kills key)
        plan = getattr(pipe, "fault_plan", None)
        self._kills = plan.kill_events() if plan is not None else {}
        self._chaos_fired: set = set()        # events already executed
        self._last_alive = time.perf_counter()
        try:
            self._start(k)
        except BaseException:
            # partial standup must not leak live worker processes,
            # sockets, or shmem segments — the caller gets no pipe
            # object to close()
            self.close()
            raise

    def _r_eff(self) -> tuple[int, ...]:
        """Replica counts net of supervisor-evicted lanes (never < 1):
        the staffing the next (re)build runs at until ``restaff``."""
        return tuple(max(r - self._down.get(i, 0), 1)
                     for i, r in enumerate(self.pipe.replicas))

    def _start(self, k: int) -> None:
        from .faults import maybe_chaos
        pipe = self.pipe
        r = self._r_eff()
        # channel j carries stage j-1 -> stage j; j=0 is the orchestrator
        # feed, j=k the result drain (neither is a scenario hop).  A
        # channel touching a replicated stage becomes a lane *group*:
        # max(r_left, r_right) SPSC lanes opened together (one shared
        # control segment under shmem)
        chan_names = ([pipe.transports[0], *pipe.transports,
                       pipe.transports[-1]] if k > 1
                      else [pipe.transport_names[0]] * 2)
        trs = {n: get_transport(n, ctx=self._ctx) if n == "shmem"
               else get_transport(n) for n in set(chan_names)}
        for j in range(k + 1):
            internal = 0 < j < k
            framing = ("pickle" if 0 < j and pipe.backends[j - 1] == "rpc"
                       else "raw")
            n_lanes = max(r[j - 1] if j > 0 else 1, r[j] if j < k else 1)
            spec = HopSpec(
                index=j - 1,
                link=pipe.links[j - 1] if internal else None,
                framing=framing,
                # the feed must hold a full stream window, or the
                # orchestrator's send blocks where no liveness check runs
                depth=(pipe.queue_depth if internal
                       else max(pipe.queue_depth * k, 1)),
                seed=pipe.seed + j, epoch=pipe.epoch,
                scenario_hop=internal,
                # the feed send's bound doubles as the orchestrator's
                # liveness cadence: a blocked submit resurfaces every
                # chunk so the engine can poll worker health instead of
                # wedging on a dead peer (the old edge.py liveness hole)
                send_timeout_s=(_FEED_SEND_CHUNK_S if j == 0
                                else pipe.timeout_s),
                codec=pipe.codecs[j - 1] if internal else "none",
                # every hop whose receiver is a worker loop may hand out
                # transport-owned views; the result drain hands arrays
                # back to user code, so it pays the one defensive copy
                zero_copy=(j != k),
                sanitize=pipe.sanitize,
                faults=getattr(pipe, "fault_plan", None))
            # chaos wraps *outside* the sanitizer: honest traffic stays
            # ledgered while injected wire damage enters below the
            # observation point (see runtime.faults.ChaosChannel)
            group = [maybe_chaos(maybe_sanitize(c), self._chaos_fired).split()
                     for c in trs[chan_names[j]].open_fan(spec, n_lanes)]
            self._groups.append(group)
            self._pairs.extend(group)
        g0, gk = self._groups[0], self._groups[k]
        # the fan dispatch/merge is itself sanitized (when enabled): the
        # merge-level wrapper is what catches a broadcast token returned
        # once per lane instead of once per group
        self._feed = (maybe_sanitize(T.FanOutChannel([p[0] for p in g0]))
                      if len(g0) > 1 else g0[0][0])
        self._result = (maybe_sanitize(T.FanInChannel([p[1] for p in gk]))
                        if len(gk) > 1 else gk[0][1])

        params_np = jax.tree.map(np.asarray, pipe.params)
        child_ctrls = []
        for i in range(k):
            for m in range(r[i]):
                parent_c, child_c = self._ctx.Pipe()
                self._ctrls.append(parent_c)
                self._ctrl_stage.append(i)
                self._proc_slot.append((i, m))
                child_ctrls.append(child_c)
                ing = self._groups[i]
                egr = self._groups[i + 1]
                # replica m owns lane m through a replicated region; a
                # solo stage facing a wider group merges in / fans out
                ingress = (ing[m][1] if r[i] > 1
                           else maybe_sanitize(
                               T.FanInChannel([p[1] for p in ing]))
                           if len(ing) > 1 else ing[0][1])
                egress = (egr[m][0] if r[i] > 1
                          else maybe_sanitize(
                              T.FanOutChannel([p[0] for p in egr]))
                          if len(egr) > 1 else egr[0][0])
                spec = {"stage": i, "n_stages": k, "model": pipe.model,
                        "params": params_np, "bounds": pipe.bounds(),
                        "backend": pipe.backends[i],
                        "ingress": ingress, "egress": egress,
                        "ctrl": child_c, "stop": self._stop,
                        "epoch": pipe.epoch,
                        "pace_s": pipe.stage_pace_s[i]}
                name = (f"edge-worker{i}.{m}" if r[i] > 1
                        else f"edge-worker{i}")
                p = self._ctx.Process(target=T._worker_main, args=(spec,),
                                      daemon=True, name=name)
                p.start()
                self._procs.append(p)
        # parent's copies of shipped endpoints must go away, or a dead
        # worker's socket never reads as closed downstream
        for c in child_ctrls:
            c.close()
        for j in range(k + 1):
            for pair in self._groups[j]:
                if j != 0:
                    pair[0].close()
                if j != k:
                    pair[1].close()
        for w in range(len(self._procs)):
            msg = self._ctrl_recv(w)
            if msg[0] != "ready":
                raise TransportError(
                    f"worker {self._ctrl_stage[w]} failed to start: {msg}")

    # ------------------------------------------------------------------ #
    @property
    def nets(self):
        return self._meters

    def _dead_workers(self) -> list[int]:
        dead = [w for w, p in enumerate(self._procs) if not p.is_alive()]
        if not dead:
            self._last_alive = time.perf_counter()
        return dead

    def _raise_dead(self, w: int) -> None:
        raise TransportError(
            f"worker process {w} died (exitcode {self._procs[w].exitcode})")

    def _check_alive(self) -> None:
        dead = self._dead_workers()
        if dead:
            self._raise_dead(dead[0])

    def _ctrl_recv(self, i: int, timeout: float | None = None):
        deadline = time.perf_counter() + (timeout or self.pipe.timeout_s)
        while True:
            if self._ctrls[i].poll(0.05):
                msg = self._ctrls[i].recv()
                if msg[0] == "error":
                    raise TransportError(msg[2])
                return msg
            self._check_alive()
            if time.perf_counter() > deadline:
                raise TransportError(f"worker {i}: control channel timeout")

    def _await(self, expected: int):
        deadline = time.perf_counter() + self.pipe.timeout_s
        while True:
            try:
                kind, obj = self._result.recv(timeout=0.25)
            except TransportTimeout:
                self._check_alive()
                if time.perf_counter() > deadline:
                    raise TransportError(
                        f"timed out waiting for "
                        f"{T._KIND_NAMES[expected]}") from None
                continue
            if kind == ERROR:
                raise TransportError(str(obj))
            if kind == expected:
                return obj
            raise TransportError(
                f"protocol error: got {T._KIND_NAMES[kind]} while waiting "
                f"for {T._KIND_NAMES[expected]}")

    def sync(self) -> dict[int, list[TransferRecord]]:
        """Flush every stage's stats + ingress records to the
        orchestrator; → {hop index: new records} for the scenario hops."""
        self._feed.send(kind=STATS)
        self._await(STATS)
        return self.harvest()

    def harvest(self) -> dict[int, list[TransferRecord]]:
        """The control-pipe half of ``sync``: collect the per-worker
        flushes a ``STATS`` token (already seen at the result end)
        caused.  Every worker — each replica separately — sends its
        control message *before* forwarding the token, so all
        ``sum(replicas)`` messages are in flight by the time the token
        exits the chain.  Replica flushes fold into their logical
        stage's counters and their ingress hop's meter."""
        new: dict[int, list[TransferRecord]] = {}
        for w in range(len(self._ctrls)):
            _, stage, d, mem_pct, records = self._ctrl_recv(w)
            acc = self._stats[stage]
            acc.exe_s += d["exe_s"]
            acc.calls += d["calls"]
            acc.cpu_s += d["cpu_s"]
            acc.mem_pct = max(acc.mem_pct, mem_pct)
            if stage > 0:                     # stage i's ingress = hop i-1
                self._meters[stage - 1].extend(records)
                new.setdefault(stage - 1, []).extend(
                    TransferRecord(*r) for r in records)
        return new

    # session primitives: the worker loop is already persistent --------- #
    def session_open(self) -> None:
        pass

    def submit(self, x) -> None:
        seq = self._batch_seq
        self._batch_seq += 1
        self._send(np.asarray(x), kind=BATCH)
        # scripted worker-kill faults fire the moment their trigger batch
        # has been fed (pop: each fires exactly once — replays go through
        # _feed.send directly and never re-trigger)
        for ev in self._kills.pop(seq, ()):
            self._inject_kill(ev)

    def _inject_kill(self, ev) -> None:
        for w, (stage, lane) in enumerate(self._proc_slot):
            if (stage, lane) == (ev.stage, ev.lane) and self._procs[w].is_alive():
                self._procs[w].kill()         # SIGKILL: no cleanup runs
                return

    def submit_token(self, kind: int, obj=None) -> None:
        self._send(obj, kind=kind)

    def cancel_flush(self) -> None:
        """Out-of-band skip command: a ("cancel",) ctrl message to every
        live worker opens its skip window (batches ahead of the next
        flush CANCEL fence short-circuit compute and travel as empty
        markers).  Best-effort — a worker that misses it just computes
        results the session will drop anyway."""
        for w, c in enumerate(self._ctrls):
            try:
                if self._procs[w].is_alive():
                    c.send(("cancel",))
            except (OSError, ValueError):
                pass                          # dying worker: skip is moot

    def _send(self, payload, kind: int) -> None:
        """Feed send with the liveness loop the seed lacked: a blocked
        send resurfaces every ``_FEED_SEND_CHUNK_S`` as TransportTimeout
        (nothing committed — retryable), the engine checks worker health,
        and — when supervised — recovers instead of raising."""
        deadline = time.perf_counter() + self.pipe.timeout_s
        rev = self._recover_count
        attempts = 0
        while True:
            if (self.supervised and kind == BATCH
                    and self._recover_count != rev):
                # a recovery replayed the session's whole pending window,
                # this batch included — re-sending would duplicate it
                return
            err = None
            try:
                self._feed.send(payload, kind=kind)
                return
            except TransportTimeout:
                pass
            except TransportError as e:
                if not self.supervised:
                    raise
                err = e
            dead = self._dead_workers()
            if not self.supervised:
                if dead:
                    self._raise_dead(dead[0])
                if time.perf_counter() > deadline:
                    raise TransportError(
                        f"feed send blocked for {self.pipe.timeout_s:.0f}s "
                        f"with all workers alive (pipeline wedged)")
                continue
            if dead or err is not None:
                if attempts >= self._backoff.retries:
                    raise err or TransportError(
                        "feed send: recovery retries exhausted")
                time.sleep(self._backoff.delay(attempts))
                attempts += 1
                self._recover(dead, reason="worker-death" if dead
                              else "feed-break")
                continue
            if time.perf_counter() > deadline:
                raise TransportError(
                    f"feed send blocked for {self.pipe.timeout_s:.0f}s "
                    f"with all workers alive (pipeline wedged)")

    def poll(self, timeout: float):
        deadline = time.perf_counter() + timeout
        if not self.supervised:
            while True:
                try:
                    return self._result.recv(timeout=0.25)
                except TransportTimeout:
                    self._check_alive()
                    if time.perf_counter() > deadline:
                        raise
        # supervised: worker death, a worker-reported ERROR, or a stream
        # stalled past the stall window all trigger recovery (bounded by
        # the backoff policy's retry cap) instead of failing the session
        stall = self._stall_window()
        quiet0 = time.perf_counter()
        attempts = 0
        while True:
            failure = None
            try:
                kind, obj = self._result.recv(timeout=0.25)
                if kind != ERROR:
                    return kind, obj
                failure = TransportError(str(obj))
            except TransportTimeout:
                pass
            except TransportError as e:
                failure = e
            dead = self._dead_workers()
            now = time.perf_counter()
            if dead or failure is not None or now - quiet0 >= stall:
                if attempts >= self._backoff.retries:
                    raise failure or TransportError(
                        f"stream stalled past {stall:.1f}s and recovery "
                        f"retries are exhausted")
                time.sleep(self._backoff.delay(attempts))
                attempts += 1
                self._recover(dead,
                              reason=("worker-death" if dead else
                                      "worker-error" if failure else "stall"))
                quiet0 = time.perf_counter()
                continue
            if now > deadline:
                raise TransportTimeout("session: no result arrived")

    def _stall_window(self) -> float:
        w = getattr(self.pipe, "stall_timeout_s", None)
        return w if w is not None else min(self.pipe.timeout_s / 3.0, 10.0)

    def max_inflight(self) -> int | None:
        # the feed channel's depth is what the orchestrator can always
        # stuff without blocking, whatever the workers are doing; a
        # submit window beyond it could park the feed send with the
        # result channel full and nobody pumping
        return max(self.pipe.queue_depth * self.pipe.n_stages, 1)

    def session_close(self, failed: bool = False) -> None:
        pass

    # -- supervised recovery -------------------------------------------- #
    def _recover(self, dead: list[int], reason: str = "worker-death") -> None:
        """Stage restart / replica failover: tear the worker tier down,
        rebuild it (at r−1 on the failed stage when survivors exist),
        replay the WARMUP fence, then let the Session replay its unacked
        in-flight batches.  Emits one RecoveryRecord per recovery."""
        from .faults import RecoveryRecord, note_recovery
        if self._recovering:
            raise TransportError(
                f"recovery failed while already recovering ({reason})")
        if self._stop.is_set() or self._closed:
            raise TransportError(f"engine closing; {reason} not recovered")
        detect_s = time.perf_counter() - self._last_alive
        self._recovering = True
        try:
            kind, stage, lane = "restart", -1, -1
            if len(dead) == 1:
                stage, lane = self._proc_slot[dead[0]]
                if (self.pipe.replicas[stage]
                        - self._down.get(stage, 0)) > 1:
                    # a replicated stage lost one lane: continue degraded
                    # at r−1, restaff in the background, and tell the
                    # controller a device is gone
                    kind = "failover"
                    self._down[stage] = self._down.get(stage, 0) + 1
                    self._restaff_needed = True
                    self._device_loss.append((stage, lane))
            t0 = time.perf_counter()
            self._teardown_workers()
            self._start(self.pipe.n_stages)
            if getattr(self, "_warm_x", None) is not None:
                self._feed.send(self._warm_x, kind=WARMUP)
                self._await(WARMUP)
            restart_s = time.perf_counter() - t0
            t1 = time.perf_counter()
            replayed = self._replay_cb() if self._replay_cb is not None else 0
            replay_s = time.perf_counter() - t1
        finally:
            self._recovering = False
        self._recover_count += 1
        self._last_alive = time.perf_counter()
        eff = self._r_eff()
        note_recovery(RecoveryRecord(
            kind=kind, stage=stage, lane=lane, reason=reason,
            detect_s=detect_s, restart_s=restart_s, replay_s=replay_s,
            batches_replayed=replayed,
            degraded_capacity=min(e / r for e, r
                                  in zip(eff, self.pipe.replicas))))

    def restaff(self) -> None:
        """Return a degraded pipeline to full replica strength — called
        by the Session at a quiescent point (no batches or tokens in
        flight), so the rebuild needs no replay."""
        from .faults import RecoveryRecord, note_recovery
        if not self._restaff_needed or self._recovering or self._closed:
            return
        self._restaff_needed = False
        self._down.clear()
        t0 = time.perf_counter()
        self._recovering = True
        try:
            self._teardown_workers()
            self._start(self.pipe.n_stages)
            if getattr(self, "_warm_x", None) is not None:
                self._feed.send(self._warm_x, kind=WARMUP)
                self._await(WARMUP)
        finally:
            self._recovering = False
        self._recover_count += 1
        self._last_alive = time.perf_counter()
        note_recovery(RecoveryRecord(
            kind="restaff", stage=-1, lane=-1, reason="restaff",
            detect_s=0.0, restart_s=time.perf_counter() - t0,
            replay_s=0.0, batches_replayed=0, degraded_capacity=1.0))

    def drain_device_loss(self) -> list[tuple[int, int]]:
        """(stage, lane) pairs evicted since the last drain — the
        Session forwards them to the controller as device-loss events."""
        out, self._device_loss = self._device_loss, []
        return out

    # ------------------------------------------------------------------ #
    def warmup(self, x):
        self._warm_x = np.asarray(x)          # exemplar for migrate's fence
        self._feed.send(self._warm_x, kind=WARMUP)
        return self._await(WARMUP)

    def migrate(self) -> None:
        self._feed.send(self.pipe.reconfig_payload(), kind=RECONFIG)
        self._await(RECONFIG)
        # the migration protocol's recompile fence: a WARMUP must reach
        # every (re)built stage before the next BATCH, so the quiescent
        # path replays the last warmup exemplar in-band — exactly what
        # Session.migrate does for the in-flight path.  Without one the
        # first post-migrate batch pays the jit compile inside its
        # latency (and trips the sanitizer's warmup-skipped rule).
        if getattr(self, "_warm_x", None) is not None:
            self._feed.send(self._warm_x, kind=WARMUP)
            self._await(WARMUP)

    def probe(self) -> None:
        self._feed.send(kind=PROBE)
        self._await(PROBE)
        self.sync()

    def stage_stats(self) -> list[StageStats]:
        return [dataclasses.replace(s) for s in self._stats]

    def reset_stats(self) -> None:
        self.sync()                           # flush children first
        self._stats = [StageStats() for _ in range(self.pipe.n_stages)]

    def set_epoch(self, epoch: float) -> None:
        self._feed.send(epoch, kind=CLOCK)
        self._await(CLOCK)
        self._feed.epoch = self._result.epoch = epoch

    def host_mem_pct(self) -> float:
        import psutil
        return psutil.Process().memory_percent()

    def _teardown_workers(self) -> None:
        """Tear the whole worker tier down — processes, channel pairs,
        shmem segments, control pipes — leaving the engine ready for a
        fresh ``_start``.  Every step is exception-safe and the state
        lists are cleared, so calling it twice (failed recovery, then
        close) is harmless."""
        for p in self._procs:
            if p.is_alive():
                p.terminate()
        deadline = time.perf_counter() + 3.0
        for p in self._procs:
            p.join(max(deadline - time.perf_counter(), 0.1))
        for p in self._procs:
            if p.is_alive():                  # terminate ignored: escalate
                p.kill()
                p.join(1.0)
        for pair in self._pairs:              # idempotent; includes feed
            for end in pair:                  # and result ends
                try:
                    end.close()
                except Exception:
                    pass
        for pair in self._pairs:              # workers are gone: reclaim
            try:                              # segments a killed worker
                pair[0].reap()                # never cleaned up
            except Exception:
                pass
        for c in self._ctrls:
            try:
                c.close()
            except Exception:
                pass
        self._procs, self._ctrls = [], []
        self._ctrl_stage, self._proc_slot = [], []
        self._pairs, self._groups = [], []
        self._feed = self._result = None

    def close(self) -> None:
        if getattr(self, "_closed", False):   # idempotent: double close,
            return                            # close after failed recovery
        self._closed = True
        self._stop.set()
        if self._feed is not None:
            try:
                self._feed.send(kind=STOP)
            except Exception:
                pass
            deadline = time.perf_counter() + 3.0
            for p in self._procs:             # graceful drain first
                p.join(max(deadline - time.perf_counter(), 0.1))
        self._teardown_workers()


# --------------------------------------------------------------------------- #
class EdgePipeline:
    """Orchestrator (paper Alg. 1, k-stage): split the model at a cut
    vector, deploy one worker per scenario device, stream batches
    through per-hop channels, measure.

    ``cuts``      — interior cut vector (k-1 ints, strictly increasing),
                    or a single int for the classic 2-stage split.
    ``scenario``  — a ``Scenario`` (device chain + per-hop links), a bare
                    ``Link``/``LinkTrace`` (2-stage convenience), or a
                    sequence of per-hop links.
    ``backend``   — one backend for every stage, or a per-stage sequence.
    ``transport`` — hop transport: ``"emulated"`` (threads, modeled
                    wire), ``"socket"``/``"shmem"`` (worker processes,
                    measured wire), or a per-hop sequence; defaults to
                    the scenario's ``transports`` else ``"emulated"``.
                    ``"emulated"`` cannot mix with process transports.

    The legacy 2-stage keywords ``p=`` and ``link=`` are still accepted.
    Process-backed pipelines hold OS resources — ``close()`` them (or
    use the pipeline as a context manager).
    """

    def __init__(self, model, params, cuts=None, scenario=None,
                 backend: Backend | Sequence[Backend] = "lightweight",
                 transport: str | Sequence[str] | None = None,
                 codec: str | Sequence[str] | None = None,
                 *, p: int | None = None, link: AnyLink | None = None,
                 queue_depth: int = 2, clock: Callable[[], float] | None = None,
                 seed: int = 0, timeout_s: float = 180.0,
                 replicas: Sequence[int] | None = None,
                 stage_pace_s: "float | Sequence[float] | None" = None,
                 sanitize: bool | None = None,
                 fault_plan=None, supervise: bool | None = None,
                 stall_timeout_s: float | None = None):
        if p is not None:
            cuts = p
        if link is not None:
            scenario = link
        if cuts is None:
            raise ValueError("need a cut vector (cuts=... or p=...)")
        if scenario is None:
            raise ValueError("need a Scenario, per-hop links, or link=...")

        if isinstance(scenario, Scenario):
            self.scenario: Scenario | None = scenario
            links: tuple[AnyLink, ...] = tuple(scenario.links)
        elif isinstance(scenario, (Link, LinkTrace)):
            self.scenario = None
            links = (scenario,)
        else:
            self.scenario = None
            links = tuple(scenario)

        self.model, self.params = model, params
        self.links = links
        self.n_stages = len(links) + 1
        if isinstance(backend, str):
            self.backends: tuple[Backend, ...] = (backend,) * self.n_stages
        else:
            self.backends = tuple(backend)
            if len(self.backends) != self.n_stages:
                raise ValueError(f"{len(self.backends)} backends for "
                                 f"{self.n_stages} stages")

        # per-hop transports: explicit arg > scenario.transports > emulated
        if transport is None:
            transport = (self.scenario.transports
                         if self.scenario is not None
                         and self.scenario.transports is not None
                         else "emulated")
        n_hops = max(self.n_stages - 1, 1)
        if isinstance(transport, str):
            names = (transport,) * n_hops
        else:
            names = tuple(transport)
            if len(names) != n_hops:
                raise ValueError(f"{len(names)} transports for {n_hops} hops")
        process_based = {n: get_transport(n).process_based for n in set(names)}
        if len(set(process_based.values())) > 1:
            raise ValueError(
                f"cannot mix the in-process 'emulated' transport with "
                f"process transports in one pipeline: {names}")
        if any(process_based.values()):
            # a measured channel cannot follow a schedule; silently
            # ignoring the trace would mislabel results as degraded
            traced = [l.name for l in links if isinstance(l, LinkTrace)]
            if traced:
                raise ValueError(
                    f"LinkTrace hops {traced} need the 'emulated' "
                    f"transport — real {sorted(set(names))} channels "
                    f"measure the wire, they cannot replay a schedule")
        self.transport_names = names
        self.transports = names[:self.n_stages - 1]   # () for k == 1

        # per-hop wire codecs: explicit arg > scenario.codecs > "none"
        if codec is None:
            codec = (self.scenario.codecs
                     if self.scenario is not None
                     and self.scenario.codecs is not None
                     else "none")
        n_real_hops = self.n_stages - 1
        if isinstance(codec, str):
            codecs = (codec,) * n_real_hops
        else:
            codecs = tuple(codec)
            if len(codecs) != n_real_hops:
                raise ValueError(f"{len(codecs)} codecs for "
                                 f"{n_real_hops} hops")
        from ..core.codecs import get_codec as _get_codec
        self.codecs = tuple(_get_codec(c).name for c in codecs)

        # per-stage replica counts: stage i runs as replicas[i] workers,
        # batches striped round-robin across them (the runtime half of
        # the solver's ``replicas`` label).  Fixed for the pipeline's
        # lifetime — migration re-cuts stages, it never re-staffs them.
        k = self.n_stages
        if replicas is None:
            self.replicas: tuple[int, ...] = (1,) * k
        else:
            self.replicas = tuple(int(x) for x in replicas)
            if len(self.replicas) != k:
                raise ValueError(f"{len(self.replicas)} replica counts for "
                                 f"{k} stages")
            if any(x < 1 for x in self.replicas):
                raise ValueError(f"replica counts must be >= 1: "
                                 f"{self.replicas}")
        for a, b in zip(self.replicas, self.replicas[1:]):
            if a != b and min(a, b) != 1:
                raise ValueError(
                    f"adjacent replicated stages need equal counts (r "
                    f"parallel lanes) or a solo stage between fan-out "
                    f"and fan-in: {self.replicas}")

        # per-stage wall-time floor (device-speed emulation; see Worker)
        if stage_pace_s is None:
            self.stage_pace_s: tuple[float, ...] = (0.0,) * k
        elif isinstance(stage_pace_s, (int, float)):
            self.stage_pace_s = (float(stage_pace_s),) * k
        else:
            self.stage_pace_s = tuple(float(t) for t in stage_pace_s)
            if len(self.stage_pace_s) != k:
                raise ValueError(f"{len(self.stage_pace_s)} stage paces "
                                 f"for {k} stages")

        self.queue_depth = queue_depth
        self.timeout_s = timeout_s
        self.seed = seed
        # protocol sanitizer (runtime.sanitizer): explicit arg wins,
        # REPRO_SANITIZE=1 turns it on fleet-wide (e.g. for a CI tier)
        self.sanitize = sanitize_enabled(sanitize)
        # fault tolerance (runtime.faults): a FaultPlan scripts injected
        # failures; supervise turns on the _ProcessEngine supervisor
        # (liveness heartbeats, bounded-backoff retry, stage restart,
        # replica failover) — on by default whenever a plan is given
        self.fault_plan = fault_plan
        self.supervise = (bool(supervise) if supervise is not None
                          else fault_plan is not None)
        self.stall_timeout_s = stall_timeout_s
        if ((self.fault_plan is not None or self.supervise)
                and not any(process_based.values())):
            raise ValueError(
                "fault injection / supervised recovery need a process "
                "transport (socket or shmem) — the emulated transport "
                "has no worker processes to kill or restart")
        self._t0 = time.perf_counter()
        self.epoch = self._t0
        self.clock = clock or (lambda: time.perf_counter() - self._t0)
        self.migrations: list[tuple[float, tuple[int, ...], tuple[int, ...]]] = []
        self.migration_costs_j: list[float] = []   # parallel to migrations
        self._session = None                  # the live Session, if any
        self.cuts = self._check_cuts(cuts)
        self._engine = (_ProcessEngine(self)
                        if any(process_based.values()) else
                        _ThreadEngine(self))

    # ------------------------------------------------------------------ #
    def _check_cuts(self, cuts) -> tuple[int, ...]:
        n = len(self.model.blocks)
        if isinstance(cuts, int):
            cuts = (cuts,)
        cuts = tuple(int(c) for c in cuts)
        if len(cuts) != self.n_stages - 1:
            raise ValueError(f"{len(cuts)} cuts for {self.n_stages} stages; "
                             f"need {self.n_stages - 1}")
        bounds = (0, *cuts, n)
        for a, b in zip(bounds, bounds[1:]):
            if not (0 <= a < b <= n):
                raise ValueError(f"cuts {cuts} invalid for {n} blocks "
                                 "(stages must be non-empty and ordered)")
        return cuts

    def bounds(self) -> tuple[int, ...]:
        return (0, *self.cuts, len(self.model.blocks))

    def reconfig_payload(self) -> dict:
        """The in-band RECONFIG message: stage bounds plus the per-hop
        codec vector (workers re-split on the former and retune their
        egress codec from the latter)."""
        return {"bounds": self.bounds(), "codecs": self.codecs}

    # observation surface + legacy accessors ---------------------------- #
    @property
    def nets(self):
        """Per-hop observation surface: live ``EmulatedChannel``s under
        threads, harvested ``HopMeter``s under worker processes — either
        way one object per hop with ``.link``/``drain_observations()``/
        ``total_bytes``/``total_energy_j``."""
        return self._engine.nets

    @property
    def workers(self) -> list[Worker]:
        if not isinstance(self._engine, _ThreadEngine):
            raise AttributeError("workers live in their own processes under "
                                 f"transport={self.transport!r}")
        return self._engine.workers

    @property
    def p(self) -> int:
        return self.cuts[0]

    @property
    def backend(self) -> str:
        return "+".join(sorted(set(self.backends)))

    @property
    def transport(self) -> str:
        return "+".join(sorted(set(self.transport_names)))

    def reset_clock(self) -> None:
        """Restart the pipeline clock (trace time 0) — call before a run
        that should experience a LinkTrace from its beginning."""
        self._assert_idle("reset_clock")
        self._t0 = time.perf_counter()
        self.epoch = self._t0
        self._engine.set_epoch(self._t0)

    def _assert_idle(self, what: str) -> None:
        if self._session is not None and not self._session.closed:
            raise RuntimeError(
                f"{what}() needs the pipeline to itself, but a Session is "
                f"open — drive the stream through the session (or close "
                f"it) instead")

    # the streaming entrypoint ------------------------------------------ #
    def session(self, controller=None, *, inflight: int | None = None,
                policy: str = "drain", window: int = 16,
                keep_results: bool = True, record_cap: int | None = None):
        """Open a streaming :class:`~repro.runtime.session.Session` —
        the one always-pipelined entrypoint ``run_one``/``stream``/
        ``AdaptiveRuntime.run`` are now shims over.

        ``controller`` — a ``Controller`` (default ``PinnedController``:
        record, never migrate); ``inflight`` — max batches in the
        pipeline at once (default ``queue_depth × n_stages``);
        ``policy`` — mid-stream migration policy, ``"drain"`` (flush
        first) or ``"drop"`` (in-band ``RECONFIG`` chases the in-flight
        batches); ``keep_results=False`` discards outputs (throughput
        runs)."""
        self._assert_idle("session")
        from .session import Session
        return Session(self, controller, inflight=inflight, policy=policy,
                       window=window, keep_results=keep_results,
                       record_cap=record_cap)

    def _note_migration(self, new_cuts: tuple[int, ...],
                        cost_j: float = 0.0) -> None:
        """Shared migration bookkeeping (sessions reconfigure in-band
        and only need the log + cut flip)."""
        self.migrations.append((self.clock(), self.cuts, new_cuts))
        self.cuts = new_cuts
        self.migration_costs_j.append(cost_j)

    # lifecycle --------------------------------------------------------- #
    def close(self) -> None:
        """Tear down worker hosts and channels (no-op for threads)."""
        if self._session is not None and not self._session.closed:
            try:
                self._session.close()
            except Exception:
                pass
        self._engine.close()

    def __enter__(self) -> "EdgePipeline":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------ #
    def migrate(self, new_cuts, cost_s: float = 0.0,
                codecs: Sequence[str] | None = None) -> tuple[int, ...]:
        """Live migration: re-deploy the workers at ``new_cuts``.

        ``cost_s`` is the one-off redeploy cost (weights moving to their
        new hosts) charged as wall-clock time, i.e. the splitter's
        ``migration_cost_s``.  ``codecs`` optionally retunes the per-hop
        wire codecs in the same reconfiguration (the controller's
        congestion → coarser-codec move).  Hop state (clock, traces,
        observations) survives the migration; under process transports
        each worker host rebuilds its stage in place from a RECONFIG
        token.

        This is the *quiescent* path; mid-stream migration (batches in
        flight) goes through ``Session.migrate`` with an explicit
        drain-vs-drop policy."""
        self._assert_idle("migrate")
        new_cuts = self._check_cuts(new_cuts)
        if codecs is not None:
            from ..core.codecs import get_codec as _get_codec
            codecs = tuple(_get_codec(c).name for c in codecs)
            if len(codecs) != self.n_stages - 1:
                raise ValueError(f"{len(codecs)} codecs for "
                                 f"{self.n_stages - 1} hops")
            self.codecs = codecs
        if cost_s > 0.0:
            time.sleep(cost_s)
        self._note_migration(new_cuts)
        self._engine.migrate()
        return self.cuts

    # ------------------------------------------------------------------ #
    def warmup(self, x):
        self._assert_idle("warmup")
        return self._engine.warmup(x)

    def probe(self) -> None:
        """Send a header-only message down every hop: emulated hops
        charge RTT/2, real hops measure it — either way the estimators
        get a compute-free RTT sample (an nbytes=0 observation)."""
        self._assert_idle("probe")
        self._engine.probe()

    def stage_stats(self) -> list[StageStats]:
        """Per-stage compute counters (snapshot), wherever the workers
        live."""
        return self._engine.stage_stats()

    def _reset_stats(self) -> None:
        self._engine.reset_stats()

    def run_one(self, x) -> tuple[jax.Array, float, tuple[float, ...]]:
        """One batch through the empty pipeline →
        (out, end-to-end latency, per-hop wire times).

        Compatibility shim: a lone batch is a one-deep Session."""
        self._assert_idle("run_one")
        wire0 = [(n.total_transfers, n.total_elapsed_s) for n in self.nets]
        with self.session(inflight=1) as s:
            seq = s.submit(x)
            (y,) = s.drain()
            s.checkpoint(probe=False)         # process hops: flush records
            latency = s.latency_of(seq)
        hop_net = tuple(
            (n.total_elapsed_s - e0) / max(n.total_transfers - t0, 1)
            for n, (t0, e0) in zip(self.nets, wire0))
        return y, latency, hop_net

    def stream(self, x, n_batches: int) -> float:
        """Push ``n_batches`` copies of ``x`` through all stages
        concurrently (bounded in-flight window) → total wall time.

        Compatibility shim over :meth:`session` (deprecated for new
        code: open a session and ``submit``/``results`` directly)."""
        self._assert_idle("stream")
        with self.session(keep_results=False) as s:
            t0 = time.perf_counter()
            for _ in range(n_batches):
                s.submit(x)
            s.drain()
            total = time.perf_counter() - t0
            s.checkpoint(probe=False)         # flush stats for measure()
        return total

    def stage_energy_model(self, stage_exe_s: Sequence[float],
                            hop_net_s: Sequence[float],
                            hop_bytes: Sequence[float],
                            ) -> tuple[float, tuple[float, ...]]:
        """Modeled J/batch from measured per-stage compute times: device
        active power × exe, idle power while its outbound hop drains, and
        each hop's radio cost × bytes.  Needs a Scenario (device power
        profiles); bare-link pipelines report 0."""
        if self.scenario is None:
            return 0.0, ()
        from ..core.costmodel import _stage_energy
        nets = self.nets
        per_stage = tuple(
            _stage_energy(dev, stage_exe_s[i],
                          hop_net_s[i] if i < len(hop_net_s) else 0.0,
                          hop_bytes[i] if i < len(hop_bytes) else 0.0,
                          nets[i].link if i < len(nets) else None)
            for i, dev in enumerate(self.scenario.devices))
        return sum(per_stage), per_stage

    # ------------------------------------------------------------------ #
    def measure(self, make_batch: Callable[[], jax.Array],
                n_batches: int = 10, warmup: int = 1) -> PipelineResult:
        self._assert_idle("measure")
        x = make_batch()
        self.warmup(x)
        self._reset_stats()
        # jit warmup can take seconds — restart trace time so a LinkTrace
        # scenario is measured from its beginning, not mid-ramp
        self.reset_clock()

        # --- latency: lone batches ---------------------------------- #
        lat: list[float] = []
        hop_t: list[tuple[float, ...]] = []
        for _ in range(max(warmup, 1)):
            self.run_one(x)
        bytes0 = [net.total_bytes for net in self.nets]
        for _ in range(max(n_batches // 3, 2)):
            _, l, hops = self.run_one(x)
            lat.append(l)
            hop_t.append(hops)
        hop_bytes = [(net.total_bytes - b0) / len(lat)
                     for net, b0 in zip(self.nets, bytes0)]
        # per-worker CPU utilisation while executing (process clock per
        # worker; lone batches run stages one at a time, so attribution
        # is exact even when the workers are threads of this process) —
        # can exceed 100% when a stage's kernels use several cores
        lat_stats = self.stage_stats()
        cpu_pct = tuple(100.0 * s.cpu_s / max(s.exe_s, 1e-9)
                        for s in lat_stats)

        # --- throughput: streamed, stages overlap -------------------- #
        self._reset_stats()
        # the latency phase advanced trace time (degraded lone batches
        # sleep); restart so both metrics sample the trace from t=0
        self.reset_clock()
        total = self.stream(x, n_batches)
        stats = self.stage_stats()
        batch = x.shape[0]
        hop_net = tuple(float(np.mean([h[i] for h in hop_t]))
                        for i in range(len(self.nets)))
        stage_exe = tuple(s.exe_s / max(s.calls, 1) for s in stats)
        host_mem = self._engine.host_mem_pct()
        mem_pct = tuple(s.mem_pct if s.mem_pct > 0 else host_mem
                        for s in stats)
        energy, stage_energy = self.stage_energy_model(stage_exe, hop_net,
                                                       hop_bytes)
        return PipelineResult(
            backend=self.backend, partition=self.cuts,
            latency_s=float(np.mean(lat)),
            throughput=n_batches * batch / total,
            stage_exe_s=stage_exe,
            net_s=float(sum(hop_net)),
            hop_net_s=hop_net,
            cpu_pct=cpu_pct,
            mem_pct=mem_pct,
            energy_j=energy,
            stage_energy_j=stage_energy,
            transport=self.transport,
            replicas=(self.replicas if any(r > 1 for r in self.replicas)
                      else ()),
        )
