"""Pluggable hop transports — the Transport/Channel API under EdgePipeline.

The paper's headline toolchain contribution is *dual communication
backends* whose overheads are measured, not modeled.  This module makes
the hop layer first-class so backend cost can be either:

  * **modeled** — ``emulated``: today's tc-netem analogue (sleep
    RTT/2 + bytes/bw per message, ``LinkTrace`` sampling, jitter), with
    stages as threads in this process; or
  * **measured** — ``socket``: real TCP between ``multiprocessing``
    worker processes on loopback, with the paper's lightweight wire
    format (fixed header + raw tensor bytes); and ``shmem``: a
    shared-memory ring between processes for the zero-copy local case.

Every hop is a ``Channel`` (``send(payload, kind)`` / ``recv()`` /
``close()`` / ``drain_records()``); a ``Transport`` opens one channel
per hop (``open(hop) -> Channel``) and ``Channel.split()`` yields the
(sender, receiver) ends to place in the two worker hosts.  Channels
record every data transfer as a ``TransferRecord`` — emulated channels
record the *injected* delay, socket/shmem channels record the
*wall-clock* cost seen by the receiver (send-start timestamp rides in
the message header; ``time.perf_counter`` is the system-wide monotonic
clock on Linux, so sender/receiver stamps are comparable across
processes).  Records feed the same ``LinkEstimator`` path either way,
which is what lets the adaptive loop close over *observed* socket costs.

Messages are typed (``BATCH``/``WARMUP``/``PROBE``/``RECONFIG``/
``STATS``/``STOP``/``ERROR``/``CLOCK``) and control tokens flow in-band
through the stage chain, so they stay ordered with the batches around
them.  ``_worker_main`` is the per-stage process body: recv from the
ingress channel, execute the stage's block range, send downstream,
and flush stats/observations to the orchestrator over a control pipe
when a ``STATS`` token passes through.

``record_trace`` turns drained records from a *measured* channel into a
replayable ``LinkTrace``, so real runs can seed the emulator.
"""
from __future__ import annotations

import pickle
import queue
import socket as socketlib
import struct
import threading
import time
from abc import ABC, abstractmethod
from dataclasses import dataclass
from typing import Callable, NamedTuple, Sequence

import numpy as np

from ..core.devices import (AnyLink, Link, LinkTrace, attribute_bandwidth,
                            fit_link_params)

# message kinds (in-band, ordered with the batches around them)
BATCH, WARMUP, PROBE, RECONFIG, STATS, STOP, ERROR, CLOCK = range(8)

_KIND_NAMES = ("BATCH", "WARMUP", "PROBE", "RECONFIG", "STATS", "STOP",
               "ERROR", "CLOCK")


class TransportError(RuntimeError):
    """A hop or worker host failed (peer closed, worker died, timeout)."""


class TransportTimeout(TransportError):
    """No message arrived within the requested window (retryable)."""


class TransferRecord(NamedTuple):
    """One observed transfer on a hop.  Tuple-compatible with the legacy
    ``(nbytes, elapsed_s, t_s)`` observation triple."""

    nbytes: int
    elapsed_s: float
    t_s: float


@dataclass(frozen=True)
class HopSpec:
    """Static description of one hop, consumed by ``Transport.open``."""

    index: int                      # hop number (-1 = orchestrator feed)
    link: AnyLink | None = None     # the scenario link this hop models/labels
    framing: str = "raw"            # "raw" (lightweight) | "pickle" (rpc)
    depth: int = 2                  # bounded in-flight messages
    seed: int = 0                   # jitter RNG seed (emulated)
    epoch: float = 0.0              # perf_counter value at pipeline t=0
    # False for the orchestrator's feed/result plumbing: those channels
    # skip TransferRecord logging (nobody drains them, and they are not
    # hops of the scenario being measured)
    scenario_hop: bool = True
    send_timeout_s: float = 180.0   # bound on blocking sends (shmem ring)


# --------------------------------------------------------------------------- #
# Wire framing
# --------------------------------------------------------------------------- #
class _Serializer:
    """RPC-style full serialize/deserialize round trip."""

    @staticmethod
    def dumps(x) -> bytes:
        host = np.asarray(x)
        return pickle.dumps((host.shape, str(host.dtype), host.tobytes()))

    @staticmethod
    def loads(buf: bytes) -> np.ndarray:
        shape, dtype, raw = pickle.loads(buf)
        return np.frombuffer(raw, dtype=dtype).reshape(shape)


def _encode(payload, framing: str) -> tuple[tuple, bytes]:
    """→ (meta, wire bytes).  Arrays go as raw tensor bytes under the
    lightweight framing, or through a full pickle round trip under the
    rpc framing; non-array control payloads ride in the (small) meta."""
    if payload is None:
        return ("O", None), b""
    if isinstance(payload, np.ndarray) or hasattr(payload, "dtype"):
        if framing == "pickle":
            return ("P",), _Serializer.dumps(payload)
        host = np.ascontiguousarray(np.asarray(payload))
        return ("R", host.shape, str(host.dtype)), host.tobytes()
    return ("O", payload), b""


def _decode(meta: tuple, payload: bytes):
    tag = meta[0]
    if tag == "R":
        return np.frombuffer(payload, dtype=meta[2]).reshape(meta[1])
    if tag == "P":
        return _Serializer.loads(payload)
    return meta[1]


# --------------------------------------------------------------------------- #
# Observation bookkeeping (shared by live channels and orchestrator meters)
# --------------------------------------------------------------------------- #
class HopObservations:
    """Per-hop transfer log + lifetime radio accounting."""

    def __init__(self, link: AnyLink | None = None):
        self.link = link
        self._lock = threading.Lock()
        self.observations: list[TransferRecord] = []
        self.total_bytes: int = 0
        self.total_energy_j: float = 0.0

    def record(self, nbytes: int, elapsed_s: float, t_s: float) -> TransferRecord:
        rec = TransferRecord(int(nbytes), float(elapsed_s), float(t_s))
        with self._lock:
            self.observations.append(rec)
            self.total_bytes += rec.nbytes
            if self.link is not None:
                self.total_energy_j += self.link.energy_per_byte_j * rec.nbytes
        return rec

    def extend(self, records: Sequence[tuple]) -> None:
        for r in records:
            self.record(*r)

    def drain_observations(self) -> list[TransferRecord]:
        with self._lock:
            obs, self.observations = self.observations, []
        return obs

    # the Channel-API name for the same drain
    drain_records = drain_observations

    # channels cross process boundaries at spawn; runtime state stays home
    def __getstate__(self):
        state = dict(self.__dict__)
        state.pop("_lock", None)
        state["observations"] = []
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._lock = threading.Lock()
        self.observations = []


class HopMeter(HopObservations):
    """Orchestrator-side mirror of a process hop: harvested records land
    here so ``pipe.nets`` has one observation surface per hop no matter
    where the channel endpoints live."""


# --------------------------------------------------------------------------- #
# Channel interface + the three backends
# --------------------------------------------------------------------------- #
class Channel(HopObservations, ABC):
    """One hop's message pipe.  ``measured`` says whether records are
    wall-clock truth (socket/shmem) or modeled delay (emulated)."""

    measured: bool = False

    def __init__(self, hop: HopSpec):
        super().__init__(hop.link)
        self.hop = hop
        self.epoch = hop.epoch

    def now(self) -> float:
        return time.perf_counter() - self.epoch

    @abstractmethod
    def send(self, payload=None, kind: int = BATCH) -> TransferRecord | None:
        """Ship ``payload`` downstream; returns the TransferRecord when
        the sending end is the one that measures (emulated), else None."""

    @abstractmethod
    def recv(self, timeout: float | None = None) -> tuple[int, object]:
        """→ (kind, payload).  Raises TransportTimeout if nothing starts
        arriving within ``timeout``; TransportError if the peer is gone."""

    def split(self) -> "tuple[Channel, Channel]":
        """→ (sender end, receiver end) for placement in two hosts.
        In-process channels are their own other half."""
        return self, self

    def close(self) -> None:  # pragma: no cover - overridden where needed
        pass


class EmulatedChannel(Channel):
    """tc-netem analogue (the former ``EmulatedLink``): sleeps
    RTT/2 + bytes/bw per message, samples ``LinkTrace`` hops at the
    pipeline clock, and hands arrays to the next thread through a
    bounded queue — zero-copy under the lightweight framing, a full
    serialize/deserialize round trip under the rpc framing."""

    measured = False

    def __init__(self, hop: HopSpec, clock: Callable[[], float] | None = None):
        super().__init__(hop)
        if hop.link is None:
            raise ValueError("emulated transport needs a Link/LinkTrace per hop")
        self._clock = clock or (lambda: 0.0)
        self._rng = np.random.default_rng(hop.seed)
        self._q: queue.Queue = queue.Queue(maxsize=max(hop.depth, 1))

    def emulate(self, nbytes: int) -> float:
        """Inject the modeled wire delay for ``nbytes`` and record it."""
        t = self._clock()
        if isinstance(self.link, LinkTrace):
            dt = self.link.transfer_time(nbytes, t, rng=self._rng)
        else:
            dt = self.link.transfer_time(nbytes)
        time.sleep(dt)
        self.record(nbytes, dt, t)
        return dt

    def send(self, payload=None, kind: int = BATCH):
        if kind in (BATCH, WARMUP):
            if self.hop.framing == "pickle":
                buf = _Serializer.dumps(payload)
                nbytes, out = len(buf), _Serializer.loads(buf)
            else:
                host = np.asarray(payload)
                nbytes, out = host.size * host.dtype.itemsize, payload
            dt = self.emulate(nbytes)
            self._q.put((kind, out))
            return TransferRecord(nbytes, dt, self._clock())
        if kind == PROBE:
            # header-only message: charges RTT/2 (+ per-message overhead),
            # recorded as an nbytes=0 observation; nothing to enqueue
            dt = self.emulate(0)
            return TransferRecord(0, dt, self._clock())
        self._q.put((kind, payload))
        return None

    def recv(self, timeout: float | None = None):
        try:
            return self._q.get(timeout=timeout)
        except queue.Empty:
            raise TransportTimeout(f"hop {self.hop.index}: recv timed out") \
                from None


_HDR = struct.Struct("!BdI Q")        # kind, t_send, meta_len, payload_len


class SocketChannel(Channel):
    """Real TCP on loopback with the paper's lightweight wire format:
    one fixed header (kind, send-start stamp, lengths) + small pickled
    meta + raw tensor bytes.  The receiving end measures each data
    transfer as wall-clock from the sender's send-start stamp through
    full deserialization — serialization cost is *in* the number, which
    is exactly the rpc-vs-lightweight difference the paper measures."""

    measured = True

    def __init__(self, hop: HopSpec, sock: socketlib.socket | None = None,
                 _pair: tuple | None = None):
        super().__init__(hop)
        if sock is not None:
            self._tx = self._rx = sock
        elif _pair is not None:
            self._tx, self._rx = _pair
        else:
            lst = socketlib.socket()
            lst.bind(("127.0.0.1", 0))
            lst.listen(1)
            tx = socketlib.create_connection(lst.getsockname())
            rx, _ = lst.accept()
            lst.close()
            self._tx, self._rx = tx, rx
        for s in {self._tx, self._rx} - {None}:
            s.setsockopt(socketlib.IPPROTO_TCP, socketlib.TCP_NODELAY, 1)

    def split(self):
        tx = SocketChannel(self.hop, _pair=(self._tx, None))
        rx = SocketChannel(self.hop, _pair=(None, self._rx))
        return tx, rx

    def send(self, payload=None, kind: int = BATCH):
        if self._tx is None:
            raise TransportError(f"hop {self.hop.index}: receive-only end")
        t0 = time.perf_counter()              # serialization counts
        meta, data = _encode(payload, self.hop.framing)
        mbuf = pickle.dumps(meta)
        hdr = _HDR.pack(kind, t0, len(mbuf), len(data))
        try:
            self._tx.sendall(hdr + mbuf)
            if data:
                self._tx.sendall(data)
        except OSError as e:
            raise TransportError(
                f"hop {self.hop.index}: peer gone ({e})") from e
        return None

    def _read_exact(self, n: int, timeout: float | None) -> bytes:
        buf = bytearray()
        self._rx.settimeout(timeout)
        while len(buf) < n:
            try:
                chunk = self._rx.recv(min(n - len(buf), 1 << 20))
            except socketlib.timeout:
                if not buf:
                    raise TransportTimeout(
                        f"hop {self.hop.index}: recv timed out") from None
                continue                      # mid-message: keep reading
            except OSError as e:
                raise TransportError(
                    f"hop {self.hop.index}: peer gone ({e})") from e
            if not chunk:
                raise TransportError(f"hop {self.hop.index}: peer closed")
            buf += chunk
        return bytes(buf)

    def recv(self, timeout: float | None = None):
        if self._rx is None:
            raise TransportError(f"hop {self.hop.index}: send-only end")
        hdr = self._read_exact(_HDR.size, timeout)
        kind, t0, mlen, plen = _HDR.unpack(hdr)
        meta = pickle.loads(self._read_exact(mlen, None)) if mlen else ("O", None)
        data = self._read_exact(plen, None) if plen else b""
        payload = _decode(meta, data)
        if kind in (BATCH, PROBE) and self.hop.scenario_hop:
            self.record(plen, time.perf_counter() - t0,
                        t0 - self.epoch)
        return kind, payload

    def close(self) -> None:
        for s in (self._tx, self._rx):
            if s is not None:
                try:
                    s.close()
                except OSError:
                    pass
        self._tx = self._rx = None


class ShmemChannel(Channel):
    """Shared-memory ring between processes for the zero-copy local
    case: payload bytes land in reusable ``SharedMemory`` slots, a
    metadata queue carries (kind, meta, slot, nbytes, t_send), and a
    free-slot queue provides ``depth``-bounded backpressure.  Slots grow
    on demand (the sender replaces a too-small freed slot)."""

    measured = True

    def __init__(self, hop: HopSpec, ctx=None):
        super().__init__(hop)
        if ctx is None:
            import multiprocessing as mp
            ctx = mp.get_context("spawn")
        self._meta_q = ctx.Queue()
        self._free_q = ctx.Queue()
        for _ in range(max(hop.depth, 1)):
            self._free_q.put(None)            # tokens; None = no slot yet
        self._pool: dict = {}                 # sender: name -> SharedMemory
        self._attached: dict = {}             # receiver: name -> SharedMemory
        self._role = "both"

    def __getstate__(self):
        state = super().__getstate__()
        state["_pool"] = {}
        state["_attached"] = {}
        return state

    def split(self):
        import copy
        tx, rx = copy.copy(self), copy.copy(self)
        tx.__setstate__(tx.__getstate__())    # fresh caches/locks per end
        rx.__setstate__(rx.__getstate__())
        tx._role, rx._role = "send", "recv"
        return tx, rx

    def _get_slot(self, nbytes: int):
        from multiprocessing import shared_memory
        # depth-bounded backpressure, but never an unbounded block: a
        # dead receiver returns no tokens, and a sender stuck here can
        # hang an orchestrator whose liveness checks live on the recv
        # path — so give up loudly after send_timeout_s
        deadline = time.perf_counter() + self.hop.send_timeout_s
        while True:
            try:
                token = self._free_q.get(timeout=0.5)
                break
            except queue.Empty:
                if time.perf_counter() > deadline:
                    raise TransportError(
                        f"hop {self.hop.index}: no free shmem slot for "
                        f"{self.hop.send_timeout_s:.0f}s (receiver gone?)"
                    ) from None
        if token is not None:
            shm = self._pool.get(token)
            if shm is not None and shm.size >= nbytes:
                return token
            if shm is not None:               # outgrown: replace the slot
                shm.close()
                try:
                    shm.unlink()
                except FileNotFoundError:
                    pass
                del self._pool[token]
        shm = shared_memory.SharedMemory(create=True,
                                         size=max(nbytes, 1 << 16))
        self._pool[shm.name] = shm
        return shm.name

    def send(self, payload=None, kind: int = BATCH):
        t0 = time.perf_counter()              # serialization + copy count
        meta, data = _encode(payload, self.hop.framing)
        name = None
        if data:
            name = self._get_slot(len(data))
            self._pool[name].buf[:len(data)] = data
        self._meta_q.put((kind, meta, name, len(data), t0))
        return None

    def _attach(self, name: str):
        from multiprocessing import shared_memory
        shm = self._attached.get(name)
        if shm is None:
            # NB: attaching re-registers the segment with the resource
            # tracker, but worker hosts inherit the orchestrator's
            # tracker, so the set-add is idempotent and the creator's
            # unlink still unregisters exactly once
            shm = shared_memory.SharedMemory(name=name)
            self._attached[name] = shm
        return shm

    def recv(self, timeout: float | None = None):
        try:
            item = self._meta_q.get(timeout=timeout)
        except queue.Empty:
            raise TransportTimeout(
                f"hop {self.hop.index}: recv timed out") from None
        kind, meta, name, nbytes, t0 = item
        data = b""
        if name is not None:
            shm = self._attach(name)
            data = bytes(shm.buf[:nbytes])
            self._free_q.put(name)
        payload = _decode(meta, data)
        if kind in (BATCH, PROBE) and self.hop.scenario_hop:
            self.record(nbytes, time.perf_counter() - t0, t0 - self.epoch)
        return kind, payload

    def close(self) -> None:
        for shm in self._attached.values():
            try:
                shm.close()
            except Exception:
                pass
        for shm in self._pool.values():
            try:
                shm.close()
                shm.unlink()
            except Exception:
                pass
        self._pool.clear()
        self._attached.clear()
        for q in (self._meta_q, self._free_q):
            try:
                q.cancel_join_thread()
            except Exception:
                pass


# --------------------------------------------------------------------------- #
# Transport registry
# --------------------------------------------------------------------------- #
class Transport(ABC):
    """A way to realize hops: opens one ``Channel`` per ``HopSpec``.
    ``process_based`` says whether stages must live in worker processes
    (socket/shmem) or threads of this process (emulated)."""

    name: str = "?"
    process_based: bool = False

    @abstractmethod
    def open(self, hop: HopSpec) -> Channel:
        ...


class EmulatedTransport(Transport):
    name = "emulated"
    process_based = False

    def __init__(self, clock: Callable[[], float] | None = None):
        self._clock = clock

    def open(self, hop: HopSpec) -> Channel:
        return EmulatedChannel(hop, clock=self._clock)


class SocketTransport(Transport):
    name = "socket"
    process_based = True

    def open(self, hop: HopSpec) -> Channel:
        return SocketChannel(hop)


class ShmemTransport(Transport):
    name = "shmem"
    process_based = True

    def __init__(self, ctx=None):
        self._ctx = ctx

    def open(self, hop: HopSpec) -> Channel:
        return ShmemChannel(hop, ctx=self._ctx)


TRANSPORTS: dict[str, Callable[..., Transport]] = {
    "emulated": EmulatedTransport,
    "socket": SocketTransport,
    "shmem": ShmemTransport,
}


def register_transport(name: str, factory: Callable[..., Transport]) -> None:
    """Register a backend so scenarios/pipelines can name it."""
    TRANSPORTS[name] = factory


def get_transport(name: str, **kwargs) -> Transport:
    try:
        factory = TRANSPORTS[name]
    except KeyError:
        raise KeyError(f"unknown transport {name!r}; have "
                       f"{sorted(TRANSPORTS)}") from None
    return factory(**kwargs)


# --------------------------------------------------------------------------- #
# Worker host process body
# --------------------------------------------------------------------------- #
def _flush_stats(stage: int, worker, ingress: Channel):
    """Drain this stage's compute stats + ingress observations into one
    picklable control message, resetting both (delta semantics)."""
    import psutil
    from .edge import StageStats
    s = worker.stats
    worker.stats = StageStats()
    records = [tuple(r) for r in ingress.drain_records()]
    mem_pct = psutil.Process().memory_percent()
    return ("stats", stage,
            {"exe_s": s.exe_s, "calls": s.calls, "cpu_s": s.cpu_s},
            mem_pct, records)


def _worker_main(spec: dict) -> None:
    """One pipeline stage as an OS process: recv → compute → send."""
    from .edge import Worker

    stage: int = spec["stage"]
    ctrl = spec["ctrl"]
    stop = spec["stop"]
    ingress: Channel = spec["ingress"]
    egress: Channel = spec["egress"]
    bounds = tuple(spec["bounds"])
    backend = spec["backend"]

    def build(bounds):
        return Worker(f"worker{stage + 1}", spec["model"], spec["params"],
                      bounds[stage], bounds[stage + 1], backend,
                      cpu_clock=time.process_time)

    try:
        worker = build(bounds)
        ctrl.send(("ready", stage))
        while not stop.is_set():
            try:
                kind, obj = ingress.recv(timeout=0.25)
            except TransportTimeout:
                continue
            if kind == STOP:
                egress.send(None, kind=STOP)
                break
            elif kind == BATCH:
                egress.send(np.asarray(worker.run(obj)), kind=BATCH)
            elif kind == WARMUP:
                egress.send(np.asarray(worker.warmup(obj)), kind=WARMUP)
            elif kind == PROBE:
                egress.send(None, kind=PROBE)
            elif kind == RECONFIG:
                bounds = tuple(obj)
                if (bounds[stage], bounds[stage + 1]) != (worker.lo, worker.hi):
                    worker = build(bounds)
                egress.send(obj, kind=RECONFIG)
            elif kind == STATS:
                ctrl.send(_flush_stats(stage, worker, ingress))
                egress.send(obj, kind=STATS)
            elif kind == CLOCK:
                ingress.epoch = egress.epoch = float(obj)
                egress.send(obj, kind=CLOCK)
            elif kind == ERROR:               # propagate towards the sink
                egress.send(obj, kind=ERROR)
    except BaseException as e:  # noqa: BLE001 — reported, then the host exits
        msg = f"stage {stage} ({type(e).__name__}): {e}"
        for report in (lambda: ctrl.send(("error", stage, msg)),
                       lambda: egress.send(msg, kind=ERROR)):
            try:
                report()
            except Exception:
                pass
    finally:
        ingress.close()
        egress.close()


# --------------------------------------------------------------------------- #
# Trace recorder: measured records → replayable LinkTrace
# --------------------------------------------------------------------------- #
def record_trace(source, *, name: str = "recorded", bucket_s: float = 0.5,
                 fallback: Link | None = None) -> LinkTrace:
    """Convert drained ``TransferRecord``s from a real (measured)
    channel into a replayable ``LinkTrace`` — measured runs seeding the
    emulator.

    Records are grouped into ``bucket_s`` windows of hop time; per
    bucket the RTT comes from header-only probes (nbytes=0: elapsed ≈
    one-way, so RTT = 2×mean) and the bandwidth from a least-squares
    fit of elapsed = rtt/2 + overhead + nbytes/bw over the bucket's
    data transfers (single-size buckets fall back to per-record
    attribution).  Buckets inherit missing values from their
    predecessor / the ``fallback`` link.

    ``source`` is a Channel/HopObservations (drained) or an iterable of
    ``(nbytes, elapsed_s, t_s)`` records.
    """
    if isinstance(source, HopObservations):
        records = source.drain_records()
        if fallback is None and isinstance(source.link, Link):
            fallback = source.link
    else:
        records = [TransferRecord(*r) for r in source]
    if not records:
        raise ValueError("record_trace: no records to convert")
    records = sorted(records, key=lambda r: r.t_s)

    rtt = fallback.rtt_s if fallback is not None else None
    overhead = fallback.per_msg_overhead_s if fallback is not None else 0.0
    bw = fallback.bw_bytes_per_s if fallback is not None else None

    knots: list[tuple[float, float, float]] = []
    t0, t_end = records[0].t_s, records[-1].t_s
    n_buckets = max(int((t_end - t0) / bucket_s) + 1, 1)
    for b in range(n_buckets):
        lo, hi = t0 + b * bucket_s, t0 + (b + 1) * bucket_s
        group = [r for r in records
                 if lo <= r.t_s < hi or (b == n_buckets - 1 and r.t_s == hi)]
        if not group:
            continue
        probes = [r.elapsed_s for r in group if r.nbytes <= 0]
        if probes:
            rtt = 2.0 * float(np.mean(probes))
        data = [r for r in group if r.nbytes > 0]
        if data:
            fit = fit_link_params([r.nbytes for r in data],
                                  [r.elapsed_s for r in data], rtt or 0.0)
            if fit is not None:               # joint fit: slope → 1/bw
                bw, overhead = fit
            else:                             # degenerate bucket: attribute
                bw = float(np.mean([
                    attribute_bandwidth(r.nbytes, r.elapsed_s, rtt or 0.0,
                                        overhead) for r in data]))
        if rtt is not None and bw is not None and bw > 0:
            knots.append(((lo + min(hi, t_end)) / 2.0, float(rtt), float(bw)))
    if not knots:
        raise ValueError("record_trace: no bucket yielded both an RTT and "
                         "a bandwidth estimate (need probes or a fallback "
                         "link for the RTT)")
    return LinkTrace(
        name=name, schedule=tuple(knots),
        per_msg_overhead_s=float(overhead),
        energy_per_byte_j=(fallback.energy_per_byte_j
                           if fallback is not None else 0.0),
    )
