"""Pluggable hop transports — the Transport/Channel API under EdgePipeline.

The paper's headline toolchain contribution is *dual communication
backends* whose overheads are measured, not modeled.  This module makes
the hop layer first-class so backend cost can be either:

  * **modeled** — ``emulated``: today's tc-netem analogue (sleep
    RTT/2 + bytes/bw per message, ``LinkTrace`` sampling, jitter), with
    stages as threads in this process; or
  * **measured** — ``socket``: real TCP between ``multiprocessing``
    worker processes on loopback, with the paper's lightweight wire
    format (one packed ``struct`` header + raw tensor bytes, vectored
    ``sendmsg``, reusable receive buffer); and ``shmem``: a doorbell
    ring in shared memory for the zero-copy local case (packed
    metadata records + seq-counter publish + socketpair doorbell, slot
    segments that grow on demand, ``np.frombuffer`` receive views).
    Pickle never touches the hot path on either backend — it survives
    only as the escape hatch for exotic metadata and as the
    deliberately heavyweight ``rpc`` framing under study.

Every hop is a ``Channel`` (``send(payload, kind)`` / ``recv()`` /
``close()`` / ``drain_records()``); a ``Transport`` opens one channel
per hop (``open(hop) -> Channel``) and ``Channel.split()`` yields the
(sender, receiver) ends to place in the two worker hosts.  Channels
record every data transfer as a ``TransferRecord`` — emulated channels
record the *injected* delay, socket/shmem channels record the
*wall-clock* cost seen by the receiver (send-start timestamp rides in
the message header; ``time.perf_counter`` is the system-wide monotonic
clock on Linux, so sender/receiver stamps are comparable across
processes).  Records feed the same ``LinkEstimator`` path either way,
which is what lets the adaptive loop close over *observed* socket costs.

Messages are typed (``BATCH``/``WARMUP``/``PROBE``/``RECONFIG``/
``STATS``/``STOP``/``ERROR``/``CLOCK``) and control tokens flow in-band
through the stage chain, so they stay ordered with the batches around
them.  ``_worker_main`` is the per-stage process body: recv from the
ingress channel, execute the stage's block range, send downstream,
and flush stats/observations to the orchestrator over a control pipe
when a ``STATS`` token passes through.

``record_trace`` turns drained records from a *measured* channel into a
replayable ``LinkTrace``, so real runs can seed the emulator.
"""
from __future__ import annotations

import os
import pickle
import queue
import select
import socket as socketlib
import struct
import threading
import time
from abc import ABC, abstractmethod
from dataclasses import dataclass
from typing import Callable, NamedTuple, Sequence

import numpy as np

from ..core.devices import (AnyLink, Link, LinkTrace, attribute_bandwidth,
                            fit_link_params)

# message kinds (in-band, ordered with the batches around them).
# CANCEL is the flush fence: submitted behind canceled in-flight
# batches, forwarded stage to stage, and — when its payload is truthy
# (a flush-cancel) — it closes the out-of-band skip window the engine
# opened, so workers stop short-circuiting compute.
BATCH, WARMUP, PROBE, RECONFIG, STATS, STOP, ERROR, CLOCK, CANCEL = range(9)

_KIND_NAMES = ("BATCH", "WARMUP", "PROBE", "RECONFIG", "STATS", "STOP",
               "ERROR", "CLOCK", "CANCEL")


class TransportError(RuntimeError):
    """A hop or worker host failed (peer closed, worker died, timeout)."""


class TransportTimeout(TransportError):
    """No message arrived within the requested window (retryable)."""


class TransferRecord(NamedTuple):
    """One observed transfer on a hop.  Tuple-compatible with the legacy
    ``(nbytes, elapsed_s, t_s)`` observation triple.

    ``nbytes`` is what crossed the wire (the codec-packed payload when a
    hop codec is active) — the number link estimators fit bandwidth
    against and radio energy charges for.  ``raw_bytes`` is the
    pre-codec tensor size (-1 in unpacked legacy tuples; ``record``
    normalizes it to ``nbytes``)."""

    nbytes: int
    elapsed_s: float
    t_s: float
    raw_bytes: int = -1

    @property
    def wire_bytes(self) -> int:
        return self.nbytes


@dataclass(frozen=True)
class HopSpec:
    """Static description of one hop, consumed by ``Transport.open``."""

    index: int                      # hop number (-1 = orchestrator feed)
    link: AnyLink | None = None     # the scenario link this hop models/labels
    framing: str = "raw"            # "raw" (lightweight) | "pickle" (rpc)
    depth: int = 2                  # bounded in-flight messages
    seed: int = 0                   # jitter RNG seed (emulated)
    epoch: float = 0.0              # perf_counter value at pipeline t=0
    # False for the orchestrator's feed/result plumbing: those channels
    # skip TransferRecord logging (nobody drains them, and they are not
    # hops of the scenario being measured)
    scenario_hop: bool = True
    send_timeout_s: float = 180.0   # bound on blocking sends (shmem ring)
    # zero-copy receive: the array handed out by recv() may be a view
    # over transport-owned memory (a shmem slot / the reusable socket
    # buffer) that is only valid until the *next* recv() on the channel.
    # True for hops whose receiver consumes the batch immediately (the
    # worker loop: run → block_until_ready → send precedes the next
    # recv); False where the payload outlives the call (the result drain
    # handing arrays back to user code), which buys one defensive copy.
    zero_copy: bool = True
    # shmem busy-poll window (µs) before a waiter parks on the doorbell.
    # The default keeps idle waiters cheap; latency microbenches widen
    # it so back-to-back transfers stay on the spin path instead of
    # paying a scheduler wakeup per message.
    spin_us: float = 80.0
    # shmem doorbell flavor: "eventfd" (one kernel counter, the futex-
    # style wake — ~¼ the wake cost of a socketpair byte at tiny
    # payloads), "socketpair" (the portable fallback), or "auto" (eventfd
    # where the platform has it)
    bell: str = "auto"
    # wire codec applied to float tensor payloads on this hop (a name
    # from ``core.codecs.CODECS``); the sender packs, the receiver
    # decodes off the per-frame codec byte, so a mid-stream RECONFIG
    # can switch codecs without coordinating the two ends
    codec: str = "none"
    # WAN-shape a *real* (socket/shmem) hop: the sender injects
    # ``pace_link.transfer_time(wire_bytes)`` before each data message,
    # so receiver-measured records carry the modeled WAN cost on top of
    # true loopback/serialization cost — the duress-WAN study path
    pace_link: AnyLink | None = None
    # wrap the opened channel in runtime.sanitizer.SanitizedChannel: the
    # live protocol state machine (WARMUP-after-RECONFIG, STOP terminal,
    # token dedup through fan-in, lease canaries) is checked per message
    # and violations raise SanitizerError.  Engines set this from
    # EdgePipeline(sanitize=...) / the REPRO_SANITIZE env var.
    sanitize: bool = False
    # deterministic fault script for this pipeline (runtime.faults
    # .FaultPlan); engines wrap send ends whose hop has frame-level
    # events in runtime.faults.ChaosChannel and execute worker-kill
    # events from the supervisor.  None = no fault injection.
    faults: object | None = None


# --------------------------------------------------------------------------- #
# Wire framing
# --------------------------------------------------------------------------- #
# Wire-layout version: bump when _FHDR/_RREC change shape, and record
# the new format strings in repro.analysis.manifest.WIRE_LAYOUTS —
# tools/pipecheck.py (rule R5) fails the tree otherwise.  The version is
# deliberately *not* framed per message: both ends of a hop come from
# one checkout, the constant exists so layout edits are conscious.
WIRE_LAYOUT_VERSION = 2   # v2: per-frame wire seq for duplicate suppression



class _Serializer:
    """RPC-style full serialize/deserialize round trip."""

    @staticmethod
    def dumps(x) -> bytes:
        host = np.asarray(x)
        return pickle.dumps((host.shape, str(host.dtype), host.tobytes()))

    @staticmethod
    def loads(buf: bytes) -> np.ndarray:
        shape, dtype, raw = pickle.loads(buf)
        return np.frombuffer(raw, dtype=dtype).reshape(shape)


def _decode(meta: tuple, payload: bytes):
    tag = meta[0]
    if tag == "R":
        return np.frombuffer(payload, dtype=meta[2]).reshape(meta[1])
    if tag == "P":
        return _Serializer.loads(payload)
    return meta[1]


# --------------------------------------------------------------------------- #
# Packed framing — the zero-pickle fast path for the process transports.
#
# The common case (a contiguous tensor of a registered dtype, ≤ 8 dims)
# travels as one fixed ``struct``-packed header plus the raw payload
# bytes; ``pickle`` survives only as the escape hatch for exotic
# metadata (unregistered dtypes, > 8 dims, the rpc framing's full
# serialize round trip) and for non-array control payloads.
# --------------------------------------------------------------------------- #
_F_EMPTY, _F_RAW, _F_OBJ, _F_PICKLE = range(4)

# dtypes the packed header can name by code; anything else escapes to
# the pickled-meta path (order is wire format — append only)
_DTYPES = ("float32", "float64", "float16", "bfloat16",
           "int8", "int16", "int32", "int64",
           "uint8", "uint16", "uint32", "uint64",
           "bool", "complex64", "complex128")
_DTYPE_CODE = {n: i for i, n in enumerate(_DTYPES)}
_MAX_NDIM = 8


def _dtype_of(code: int) -> np.dtype:
    """Resolve a wire dtype code.  Extension dtypes (``bfloat16``) only
    parse once ``ml_dtypes`` has registered them with numpy — a sender
    that imported jax frames them as ``_F_RAW``, so a receiver that has
    not must pull in the registration rather than fail the decode."""
    name = _DTYPES[code]
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # noqa: F401 — import registers the dtype
        return np.dtype(name)


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length()


def _frame(payload, framing: str,
           codec=None) -> tuple[int, int, tuple, object, bytes, int]:
    """→ (ftype, dtype code, shape, payload buffer, pickled meta,
    codec wire code).

    The payload buffer is a ``memoryview`` over the source array where
    possible, so socket sends can scatter-gather straight out of it and
    shmem sends copy exactly once (into the slot).  When a (non-identity)
    ``codec`` applies — float tensor, non-empty, raw framing — the
    buffer is the codec-packed bytes instead and the codec's wire code
    rides in the frame so the receiver can decode statelessly."""
    if payload is None:
        return _F_EMPTY, 0, (), b"", b"", 0
    if isinstance(payload, np.ndarray) or hasattr(payload, "dtype"):
        if framing == "pickle":
            return _F_PICKLE, 0, (), _Serializer.dumps(payload), \
                pickle.dumps(("P",)), 0
        host = np.asarray(payload)
        if not host.flags.c_contiguous:       # NB: ascontiguousarray would
            host = np.ascontiguousarray(host)  # flatten 0-d shapes
        code = _DTYPE_CODE.get(host.dtype.name, -1)
        if code >= 0 and host.ndim <= _MAX_NDIM:
            if (codec is not None and codec.code and host.size
                    and codec.supports(host.dtype)):
                return (_F_RAW, code, host.shape, codec.encode(host), b"",
                        codec.code)
            data = host.data.cast("B") if host.size else b""
            return _F_RAW, code, host.shape, data, b"", 0
        return _F_PICKLE, 0, (), host.tobytes(), \
            pickle.dumps(("R", host.shape, str(host.dtype))), 0
    return _F_OBJ, 0, (), pickle.dumps(payload), b"", 0


def _unframe(ftype: int, code: int, shape: tuple, buf, meta_buf,
             ccode: int = 0):
    """Inverse of ``_frame`` over received buffers.  For uncoded
    ``_F_RAW`` the result is a zero-copy ``np.frombuffer`` view over
    ``buf`` — the caller decides whether that view may outlive the
    buffer.  Codec-packed frames decode into fresh arrays (never views),
    so no lease/copy discipline applies to them."""
    if ftype == _F_EMPTY:
        return None
    if ftype == _F_RAW:
        if ccode:
            from ..core.codecs import codec_for_code
            return codec_for_code(ccode).decode(buf, shape, _dtype_of(code))
        return np.frombuffer(buf, dtype=_dtype_of(code)).reshape(shape)
    if ftype == _F_OBJ:
        return pickle.loads(buf)
    return _decode(pickle.loads(meta_buf), bytes(buf))


def _raw_payload_bytes(ftype: int, code: int, shape, plen: int,
                       ccode: int) -> int:
    """Pre-codec tensor bytes for a received frame (== ``plen`` unless
    a codec packed the payload); feeds ``TransferRecord.raw_bytes``."""
    if ftype != _F_RAW or not ccode:
        return plen
    n = 1
    for s in shape:
        n *= int(s)
    return n * _dtype_of(code).itemsize


def as_jax(x):
    """Ingest a (possibly transport-owned) numpy view into jax via
    dlpack where available — the device put aliases host memory on the
    CPU backend instead of copying.  Safe under the zero-copy lease
    because the worker loop calls ``block_until_ready`` before the next
    recv() releases the buffer.  Falls back to handing jax the ndarray
    (one host copy at dispatch)."""
    if isinstance(x, np.ndarray) and x.size:
        try:
            import jax.dlpack
            return jax.dlpack.from_dlpack(x)
        except Exception:
            return x
    return x


# --------------------------------------------------------------------------- #
# Observation bookkeeping (shared by live channels and orchestrator meters)
# --------------------------------------------------------------------------- #
class HopObservations:
    """Per-hop transfer log + lifetime radio accounting."""

    def __init__(self, link: AnyLink | None = None):
        self.link = link
        self._lock = threading.Lock()
        self.observations: list[TransferRecord] = []
        self.total_bytes: int = 0
        self.total_energy_j: float = 0.0
        # lifetime data-transfer counters (nbytes > 0 only): deltas give
        # mean per-transfer wire time over any window *without* draining
        # the observation log out from under the estimators
        self.total_transfers: int = 0
        self.total_elapsed_s: float = 0.0
        # pre-codec bytes (== total_bytes on uncoded hops): the
        # raw-vs-wire gap is the codec's realized saving
        self.total_raw_bytes: int = 0

    def record(self, nbytes: int, elapsed_s: float, t_s: float,
               raw_bytes: int = -1) -> TransferRecord:
        rec = TransferRecord(int(nbytes), float(elapsed_s), float(t_s),
                             int(raw_bytes) if raw_bytes >= 0 else int(nbytes))
        with self._lock:
            self.observations.append(rec)
            self.total_bytes += rec.nbytes
            self.total_raw_bytes += rec.raw_bytes
            if rec.nbytes > 0:
                self.total_transfers += 1
                self.total_elapsed_s += rec.elapsed_s
            if self.link is not None:
                self.total_energy_j += self.link.energy_per_byte_j * rec.nbytes
        return rec

    def extend(self, records: Sequence[tuple]) -> None:
        for r in records:
            self.record(*r)

    def drain_observations(self) -> list[TransferRecord]:
        with self._lock:
            obs, self.observations = self.observations, []
        return obs

    # the Channel-API name for the same drain
    drain_records = drain_observations

    # channels cross process boundaries at spawn; runtime state stays home
    def __getstate__(self):
        state = dict(self.__dict__)
        state.pop("_lock", None)
        state["observations"] = []
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._lock = threading.Lock()
        self.observations = []


class HopMeter(HopObservations):
    """Orchestrator-side mirror of a process hop: harvested records land
    here so ``pipe.nets`` has one observation surface per hop no matter
    where the channel endpoints live."""


# --------------------------------------------------------------------------- #
# Channel interface + the three backends
# --------------------------------------------------------------------------- #
class Channel(HopObservations, ABC):
    """One hop's message pipe.  ``measured`` says whether records are
    wall-clock truth (socket/shmem) or modeled delay (emulated)."""

    measured: bool = False

    def __init__(self, hop: HopSpec):
        super().__init__(hop.link)
        self.hop = hop
        self.epoch = hop.epoch
        self._codec = None                    # resolved lazily from hop.codec

    def now(self) -> float:
        return time.perf_counter() - self.epoch

    @property
    def codec(self):
        """The hop's wire codec object (resolved lazily so channels can
        pickle before the codec registry — and jax, behind its kernels —
        loads in the worker process)."""
        c = self._codec
        if c is None or c.name != self.hop.codec:
            from ..core.codecs import get_codec
            c = self._codec = get_codec(self.hop.codec)
        return c

    def set_codec(self, name: str) -> None:
        """Point this end at a different wire codec (RECONFIG path).
        Senders start packing with it on the next message; receivers
        need no call at all — they decode off the per-frame codec byte."""
        import dataclasses
        self.hop = dataclasses.replace(self.hop, codec=name)
        self._codec = None

    def _send_codec(self, kind: int):
        """Codec to apply for a message of ``kind`` — data and warmup
        exemplars pack; control tokens always travel uncoded."""
        return self.codec if kind in (BATCH, WARMUP) else None

    def _pace(self, nbytes: int, kind: int) -> None:
        """Inject the hop's modeled WAN serialization delay (socket/
        shmem duress studies).  Runs after framing — the delay scales
        with *wire* bytes, which is exactly the codec's win — and after
        the send stamp, so receiver-measured elapsed includes it."""
        link = self.hop.pace_link
        if link is None or kind not in (BATCH, WARMUP, PROBE):
            return
        if isinstance(link, LinkTrace):
            dt = link.transfer_time(nbytes, self.now())
        else:
            dt = link.transfer_time(nbytes)
        time.sleep(dt)

    @abstractmethod
    def send(self, payload=None, kind: int = BATCH) -> TransferRecord | None:
        """Ship ``payload`` downstream; returns the TransferRecord when
        the sending end is the one that measures (emulated), else None."""

    @abstractmethod
    def recv(self, timeout: float | None = None) -> tuple[int, object]:
        """→ (kind, payload).  Raises TransportTimeout if nothing starts
        arriving within ``timeout``; TransportError if the peer is gone."""

    def split(self) -> "tuple[Channel, Channel]":
        """→ (sender end, receiver end) for placement in two hosts.
        In-process channels are their own other half."""
        return self, self

    def close(self) -> None:  # pragma: no cover - overridden where needed
        pass

    def reap(self) -> None:
        """Force-release any OS resources this hop may have left behind
        even in *other* (possibly killed) processes — called by the
        orchestrator after worker processes are joined.  No-op for
        in-process channels."""


class EmulatedChannel(Channel):
    """tc-netem analogue (the former ``EmulatedLink``): sleeps
    RTT/2 + bytes/bw per message, samples ``LinkTrace`` hops at the
    pipeline clock, and hands arrays to the next thread through a
    bounded queue — zero-copy under the lightweight framing, a full
    serialize/deserialize round trip under the rpc framing."""

    measured = False

    def __init__(self, hop: HopSpec, clock: Callable[[], float] | None = None):
        super().__init__(hop)
        if hop.link is None:
            raise ValueError("emulated transport needs a Link/LinkTrace per hop")
        self._clock = clock or (lambda: 0.0)
        self._rng = np.random.default_rng(hop.seed)
        self._q: queue.Queue = queue.Queue(maxsize=max(hop.depth, 1))

    def emulate(self, nbytes: int, raw_bytes: int = -1) -> float:
        """Inject the modeled wire delay for ``nbytes`` and record it."""
        t = self._clock()
        if isinstance(self.link, LinkTrace):
            dt = self.link.transfer_time(nbytes, t, rng=self._rng)
        else:
            dt = self.link.transfer_time(nbytes)
        time.sleep(dt)
        self.record(nbytes, dt, t, raw_bytes=raw_bytes)
        return dt

    def _roundtrip(self, payload):
        """Apply the hop codec's exact wire transform in place of real
        packing: the next stage computes on the degraded tensor, so
        emulated runs carry the codec's accuracy cost end to end.
        → (wire bytes, raw bytes, decoded payload)."""
        host = np.asarray(payload)
        raw = host.size * host.dtype.itemsize
        codec = self.codec
        if not (codec.code and host.size and codec.supports(host.dtype)):
            return raw, raw, payload
        if not host.flags.c_contiguous:
            host = np.ascontiguousarray(host)
        buf = codec.encode(host)
        return len(buf), raw, codec.decode(buf, host.shape, host.dtype)

    def send(self, payload=None, kind: int = BATCH):
        if kind == BATCH:
            if self.hop.framing == "pickle":
                buf = _Serializer.dumps(payload)
                nbytes, raw, out = len(buf), len(buf), _Serializer.loads(buf)
            else:
                nbytes, raw, out = self._roundtrip(payload)
            dt = self.emulate(nbytes, raw_bytes=raw)
            self._q.put((kind, out))
            return TransferRecord(nbytes, dt, self._clock(), raw)
        if (kind == WARMUP and self.hop.framing != "pickle"
                and (isinstance(payload, np.ndarray)
                     or hasattr(payload, "dtype"))):
            # round-trip (no delay): warms the codec's jitted kernels and
            # hands downstream a representative degraded exemplar
            _, _, payload = self._roundtrip(payload)
            self._q.put((kind, payload))
            return None
        if kind == PROBE:
            # header-only message: charges RTT/2 (+ per-message overhead),
            # recorded as an nbytes=0 observation; the token traverses
            # in-band so a streaming session can forward it hop by hop
            dt = self.emulate(0)
            self._q.put((PROBE, None))
            return TransferRecord(0, dt, self._clock())
        self._q.put((kind, payload))
        return None

    def recv(self, timeout: float | None = None):
        try:
            return self._q.get(timeout=timeout)
        except queue.Empty:
            raise TransportTimeout(f"hop {self.hop.index}: recv timed out") \
                from None


# packed socket frame: ftype, kind, dtype code, ndim, codec code,
# meta_len, t_send, payload_len, wire seq, shape[8] — everything the
# common tensor case needs in one fixed-size read, no pickled metadata
# on the wire (mlen = 0); codec code 0 = uncoded payload bytes.  The
# wire seq (layout v2) stamps every frame from a per-end counter so the
# receiver can drop an already-delivered BATCH — duplicate suppression
# for chaos-duplicated and recovery-replayed frames.
_FHDR = struct.Struct("!BBbBB I d Q Q 8q")


class SocketChannel(Channel):
    """Real TCP on loopback with the paper's lightweight wire format:
    one fixed ``struct``-packed header + raw tensor bytes (pickled meta
    only on the escape path), vectored header+payload writes via
    ``sendmsg``, and a reusable preallocated receive buffer.  The
    receiving end measures each data transfer as wall-clock from the
    sender's send-start stamp through full deserialization —
    serialization cost is *in* the number, which is exactly the
    rpc-vs-lightweight difference the paper measures."""

    measured = True

    def __init__(self, hop: HopSpec, sock: socketlib.socket | None = None,
                 _pair: tuple | None = None):
        super().__init__(hop)
        if sock is not None:
            self._tx = self._rx = sock
        elif _pair is not None:
            self._tx, self._rx = _pair
        else:
            lst = socketlib.socket()
            lst.bind(("127.0.0.1", 0))
            lst.listen(1)
            tx = socketlib.create_connection(lst.getsockname())
            rx, _ = lst.accept()
            lst.close()
            self._tx, self._rx = tx, rx
        for s in {self._tx, self._rx} - {None}:
            s.setsockopt(socketlib.IPPROTO_TCP, socketlib.TCP_NODELAY, 1)
        self._init_bufs()
        self._tx_seq = 0                      # frames sent from this end
        self._rx_seen = -1                    # highest wire seq delivered

    def _init_bufs(self) -> None:
        self._hbuf = bytearray(_FHDR.size)
        self._rbuf = bytearray(1 << 16)       # reusable payload buffer

    def __setstate__(self, state):
        super().__setstate__(state)
        self._init_bufs()

    def __getstate__(self):
        state = super().__getstate__()
        state.pop("_hbuf", None)
        state.pop("_rbuf", None)
        return state

    def split(self):
        tx = SocketChannel(self.hop, _pair=(self._tx, None))
        rx = SocketChannel(self.hop, _pair=(None, self._rx))
        return tx, rx

    def send(self, payload=None, kind: int = BATCH, _dup: bool = False):
        if self._tx is None:
            raise TransportError(f"hop {self.hop.index}: receive-only end")
        t0 = time.perf_counter()              # serialization counts
        ftype, code, shape, data, meta, ccode = _frame(
            payload, self.hop.framing, self._send_codec(kind))
        if _dup:                              # chaos re-send: same wire seq
            seq = self._tx_seq - 1
        else:
            seq = self._tx_seq
            self._tx_seq += 1
        hdr = _FHDR.pack(ftype, kind, code, len(shape), ccode, len(meta),
                         t0, len(data), seq, *shape,
                         *((0,) * (8 - len(shape))))
        self._pace(len(data) + len(meta), kind)
        bufs = [memoryview(hdr)]
        if meta:
            bufs.append(memoryview(meta))
        if len(data):
            bufs.append(memoryview(data))
        # The bounded send is the liveness half of the wire protocol: a
        # peer that stops draining surfaces as TransportTimeout once zero
        # bytes of this frame moved for send_timeout_s (nothing committed
        # — retryable, mirroring recv's first-byte rule), and as
        # TransportError if the stall hits mid-frame.
        sent_any = False
        self._tx.settimeout(self.hop.send_timeout_s)
        try:
            while bufs:
                try:
                    n = self._tx.sendmsg(bufs)  # vectored: no concat copy
                except socketlib.timeout:
                    if not sent_any:
                        raise TransportTimeout(
                            f"hop {self.hop.index}: send timed out after "
                            f"{self.hop.send_timeout_s:.0f}s "
                            f"(peer not draining)") from None
                    raise TransportError(
                        f"hop {self.hop.index}: send stalled mid-frame for "
                        f"{self.hop.send_timeout_s:.0f}s") from None
                except OSError as e:
                    raise TransportError(
                        f"hop {self.hop.index}: peer gone ({e})") from e
                if n:
                    sent_any = True
                while bufs and n >= len(bufs[0]):
                    n -= len(bufs.pop(0))
                if bufs and n:
                    bufs[0] = bufs[0][n:]
        finally:
            if self._tx is not None:
                try:
                    self._tx.settimeout(None)
                except OSError:
                    pass
        return None

    def _read_into(self, view: memoryview, timeout: float | None) -> None:
        """Fill ``view`` exactly; the timeout bounds only the wait for
        the first byte (mid-message reads keep going)."""
        got, n = 0, len(view)
        self._rx.settimeout(timeout)
        while got < n:
            try:
                k = self._rx.recv_into(view[got:])
            except socketlib.timeout:
                if not got:
                    raise TransportTimeout(
                        f"hop {self.hop.index}: recv timed out") from None
                continue                      # mid-message: keep reading
            except OSError as e:
                raise TransportError(
                    f"hop {self.hop.index}: peer gone ({e})") from e
            if not k:
                raise TransportError(f"hop {self.hop.index}: peer closed")
            got += k
            if got < n and self._rx.gettimeout() is not None:
                self._rx.settimeout(None)     # header started arriving

    def recv(self, timeout: float | None = None):
        if self._rx is None:
            raise TransportError(f"hop {self.hop.index}: send-only end")
        while True:
            self._read_into(memoryview(self._hbuf), timeout)
            (ftype, kind, code, ndim, ccode, mlen, t0, plen, seq,
             *shape) = _FHDR.unpack(self._hbuf)
            meta = b""
            if mlen:
                meta = bytearray(mlen)
                self._read_into(memoryview(meta), None)
            if plen > len(self._rbuf):
                self._rbuf = bytearray(_next_pow2(plen))
            view = memoryview(self._rbuf)[:plen]
            if plen:
                self._read_into(view, None)
            if kind == BATCH and seq <= self._rx_seen:
                continue                      # duplicate frame: drop it
            if seq > self._rx_seen + 1:
                raise TransportError(
                    f"hop {self.hop.index}: wire gap — frame(s) lost "
                    f"(seq {seq} after {self._rx_seen})")
            if not 0 <= kind <= CANCEL:
                raise TransportError(
                    f"hop {self.hop.index}: corrupt frame header "
                    f"(kind=0x{kind:02x})")
            self._rx_seen = seq
            break
        payload = _unframe(ftype, code, tuple(shape[:ndim]), view, meta,
                           ccode)
        if (ftype == _F_RAW and not ccode and not self.hop.zero_copy
                and isinstance(payload, np.ndarray)):
            payload = payload.copy()          # outlives the reusable buffer
        if kind in (BATCH, PROBE) and self.hop.scenario_hop:
            self.record(plen, time.perf_counter() - t0, t0 - self.epoch,
                        raw_bytes=_raw_payload_bytes(
                            ftype, code, shape[:ndim], plen, ccode))
        return kind, payload

    def close(self) -> None:
        for s in (self._tx, self._rx):
            if s is not None:
                try:
                    s.close()
                except OSError:
                    pass
        self._tx = self._rx = None


# --------------------------------------------------------------------------- #
# Doorbells — the park/wake primitive under the shmem ring.
#
# A doorbell is rung after a counter publish and parked on by the other
# end; wakeup state must *persist* (ring-before-park cannot lose the
# wake), which both flavors guarantee: the eventfd counter accumulates
# until read, and socketpair bytes sit in the kernel buffer until
# recv'd.  Multi-producer safe either way — any number of processes may
# ring the same bell (eventfd adds are atomic; concurrent socket sends
# just coalesce), which is what lets r replica producers share one
# consumer doorbell.
# --------------------------------------------------------------------------- #
def _rebuild_eventfd_bell(dupfd):
    return _EventFdBell(fd=dupfd.detach())


class _EventFdBell:
    """Futex-style doorbell on a Linux ``eventfd``: ring = one atomic
    8-byte counter add (no socket stack, no per-ring allocation), wait =
    poll + drain.  Both ends are the same kernel object — copies dup the
    fd across process boundaries (``multiprocessing.reduction.DupFd``)."""

    def __init__(self, fd: int | None = None):
        self._fd = os.eventfd(0, os.EFD_NONBLOCK) if fd is None else fd

    def ring(self) -> None:
        try:
            os.eventfd_write(self._fd, 1)
        except (BlockingIOError, InterruptedError):
            pass                              # counter saturated: wake pending

    def wait(self, timeout_s: float) -> None:
        try:
            r, _, _ = select.select([self._fd], [], [], timeout_s)
        except ValueError as e:               # fd closed under us
            raise OSError(str(e)) from None
        if r:
            try:
                os.eventfd_read(self._fd)     # drain coalesced rings
            except (BlockingIOError, InterruptedError):
                pass

    def close(self) -> None:
        fd, self._fd = self._fd, -1
        if fd >= 0:
            try:
                os.close(fd)
            except OSError:
                pass

    def __reduce__(self):
        from multiprocessing.reduction import DupFd
        if self._fd < 0:
            raise TransportError("cannot ship a closed doorbell")
        return (_rebuild_eventfd_bell, (DupFd(self._fd),))

    @classmethod
    def pair(cls) -> "tuple[_EventFdBell, _EventFdBell]":
        # both ends reference the same eventfd counter, but each end owns
        # its own descriptor: closing one (e.g. the parent's copy of a
        # shipped end) must not silence the other
        a = cls()
        return a, cls(fd=os.dup(a._fd))


class _SocketPairBell:
    """One end of a socketpair doorbell — the portable fallback (wakeup
    bytes persist in the kernel buffer, so publish-then-ring cannot lose
    a wake).  Sockets cross process boundaries via multiprocessing's
    standard socket reduction."""

    def __init__(self, sock: socketlib.socket):
        self._s = sock

    def ring(self) -> None:
        try:
            self._s.send(b"\0")
        except (BlockingIOError, OSError):
            pass                              # buffered bytes already pending

    def wait(self, timeout_s: float) -> None:
        try:
            self._s.settimeout(timeout_s)
            self._s.recv(4096)                # drain coalesced rings too
        except (socketlib.timeout, BlockingIOError):
            pass

    def close(self) -> None:
        try:
            self._s.close()
        except OSError:
            pass

    @classmethod
    def pair(cls) -> "tuple[_SocketPairBell, _SocketPairBell]":
        ring_end, wait_end = socketlib.socketpair()
        ring_end.setblocking(False)
        return cls(ring_end), cls(wait_end)


def _bell_pair(flavor: str):
    """→ (ring end, wait end) for a HopSpec ``bell`` declaration."""
    if flavor == "auto":
        flavor = "eventfd" if hasattr(os, "eventfd") else "socketpair"
    if flavor == "eventfd":
        return _EventFdBell.pair()
    if flavor == "socketpair":
        return _SocketPairBell.pair()
    raise ValueError(f"unknown doorbell flavor {flavor!r}; "
                     f"have 'eventfd', 'socketpair', 'auto'")


# shmem control ring: fixed-stride metadata records packed directly into
# the shared control segment — ftype, kind, dtype code, ndim, codec
# code, slot index (-1 = inline/none), meta_len, inline_len, t_send,
# nbytes, wire seq, shape[8]; the rest of the stride is the inline area
# (pickled meta + small payloads ride in the record itself, no slot
# round trip).  The wire seq (layout v2) mirrors the socket header's:
# per-end send counter, receiver-side BATCH dedup.
_RREC = struct.Struct("<BBbBB i I I d Q Q 8q")
_STRIDE = 256
_INLINE = _STRIDE - _RREC.size
_BELL_CHUNK_S = 0.05    # re-check cadence while parked on the doorbell


def _ctl_layout(depth: int) -> tuple[int, int, int, int, int, int, int]:
    """Single-lane control layout for ``depth`` in-flight messages →
    (n_slots, cap, fcap, tab_off, free_off, rec_off, size); offsets are
    lane-relative so several lanes can pack into one segment."""
    n_slots = depth + 1                       # +1 backs the zero-copy lease
    cap = _next_pow2(depth + 8)               # data ring: depth + control slack
    fcap = _next_pow2(n_slots)
    tab_off = 256
    free_off = tab_off + 32 * n_slots
    rec_off = -(-(free_off + 8 * fcap) // 64) * 64
    return (n_slots, cap, fcap, tab_off, free_off, rec_off,
            rec_off + _STRIDE * cap)


def _lane_stride(depth: int) -> int:
    """Page-aligned per-lane footprint inside a multi-producer segment."""
    return -(-_ctl_layout(depth)[-1] // 4096) * 4096

# shmem mappings that could not unmap at close() because user-held
# zero-copy views still export their buffer — kept alive to silence
# SharedMemory.__del__; the OS reclaims the pages at process exit
_PINNED_MAPPINGS: list = []


class ShmemChannel(Channel):
    """Shared-memory ring between processes for the zero-copy local
    case.  One control segment carries everything that used to ride two
    ``mp.Queue``s (pickle + pipe + feeder thread per transfer):

      * a single-producer/single-consumer **data ring** of packed
        ``_RREC`` metadata records, published by bumping a seq counter
        (write the record, then the counter — a lock-free doorbell);
      * a **free ring** of slot indices flowing back from receiver to
        sender (``depth``-bounded backpressure, slot reuse);
      * a **slot name table** so payload slots can grow on demand (the
        sender replaces a too-small slot and republishes its name).

    Payload bytes land in per-slot ``SharedMemory`` segments (small
    payloads inline in the record itself) and the receive path is
    zero-copy: ``recv`` returns an ``np.frombuffer`` view over the
    mapped slot, which stays leased — excluded from the free ring —
    until the *next* ``recv`` (one extra slot backs the lease so the
    ring keeps its nominal depth).  Waiters spin for ``hop.spin_us`` and
    then park on a socketpair doorbell (the portable futex stand-in:
    wakeup bytes persist, so the publish-then-ring protocol cannot lose
    a wakeup), re-checking the counters every ``_BELL_CHUNK_S`` as a
    liveness backstop."""

    measured = True

    # control-segment offsets: the four seq counters live on their own
    # cache lines, then the slot name table, free ring, and data ring
    _DH, _DT, _FH, _FT = 0, 64, 128, 192

    def __init__(self, hop: HopSpec, ctx=None,  # ctx kept for API compat
                 _shared: tuple | None = None):
        from multiprocessing import shared_memory
        super().__init__(hop)
        if _shared is None:
            # solo lane: own control segment starting at offset 0
            self._base, self._n_lanes, self._lane_size = 0, 1, 0
            self._layout(max(hop.depth, 1))
            self._lane_size = self._ctl_size
            self._ctl = shared_memory.SharedMemory(create=True,
                                                   size=self._ctl_size)
        else:
            # one lane of a multi-producer segment (ShmemTransport.open_fan):
            # SPSC rings at self._base inside a segment shared by n lanes
            self._ctl, self._base, self._n_lanes, self._lane_size = _shared
            self._layout(max(hop.depth, 1))
        self._ctl_name = self._ctl.name
        self._ctl_owner = True                # double unlink is tolerated
        # doorbells: (data send, data recv) + (free send, free recv)
        self._bell_ds, self._bell_dr = _bell_pair(hop.bell)
        self._bell_fs, self._bell_fr = _bell_pair(hop.bell)
        self._pool: dict = {}                 # sender: slot idx -> SharedMemory
        self._attached: dict = {}             # receiver: idx -> (name, shm)
        self._lease: int | None = None        # slot behind the last recv view
        self._role = "both"
        self._tx_seq = 0                      # frames sent from this end
        self._rx_seen = -1                    # highest wire seq delivered
        for i in range(self._n_slots):        # all slots start free (no
            self._push_free(i, ring=False)    # segment until first use)

    def _layout(self, depth: int) -> None:
        self._depth = depth
        self._spin_s = self.hop.spin_us * 1e-6
        base = getattr(self, "_base", 0)
        (self._n_slots, self._cap, self._fcap,
         tab_off, free_off, rec_off, self._ctl_size) = _ctl_layout(depth)
        # absolute offsets for this lane (counters keep their own cache
        # lines); self._ctl_size stays the lane-relative footprint
        self._DH, self._DT = base + 0, base + 64
        self._FH, self._FT = base + 128, base + 192
        self._tab_off = base + tab_off
        self._free_off = base + free_off
        self._rec_off = base + rec_off

    # -- counters + doorbells ------------------------------------------- #
    def _ld(self, off: int) -> int:
        return struct.unpack_from("<Q", self._ctl.buf, off)[0]

    def _st(self, off: int, v: int) -> None:
        struct.pack_into("<Q", self._ctl.buf, off, v)

    @staticmethod
    def _ring(bell) -> None:
        bell.ring()

    def _wait(self, ready, bell, timeout: float | None, what: str,
              err=TransportTimeout) -> None:
        """Spin briefly, then park on the doorbell until ``ready()``."""
        if ready():
            return
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        spin_until = time.perf_counter() + self._spin_s
        while True:
            if ready():
                return
            now = time.perf_counter()
            if now < spin_until:
                continue
            if deadline is not None and now >= deadline:
                raise err(f"hop {self.hop.index}: {what}")
            chunk = (_BELL_CHUNK_S if deadline is None
                     else min(deadline - now, _BELL_CHUNK_S))
            try:
                bell.wait(chunk)              # drains coalesced rings too
            except OSError as e:
                raise TransportError(
                    f"hop {self.hop.index}: doorbell gone ({e})") from e

    # -- free ring (receiver -> sender) --------------------------------- #
    def _push_free(self, idx: int, ring: bool = True) -> None:
        fh = self._ld(self._FH)
        struct.pack_into("<Q", self._ctl.buf,
                         self._free_off + (fh % self._fcap) * 8, idx)
        self._st(self._FH, fh + 1)
        if ring:
            self._ring(self._bell_fs)

    def _pop_free(self) -> int:
        def ready():
            avail = self._ld(self._FH) - self._ld(self._FT)
            return 0 < avail <= self._n_slots  # clamp guards a torn read
        self._wait(ready, self._bell_fr, self.hop.send_timeout_s,
                   f"no free shmem slot for {self.hop.send_timeout_s:.0f}s "
                   f"(receiver not draining)", err=TransportTimeout)
        ft = self._ld(self._FT)
        idx = struct.unpack_from(
            "<Q", self._ctl.buf, self._free_off + (ft % self._fcap) * 8)[0]
        self._st(self._FT, ft + 1)
        return int(idx)

    # -- payload slots --------------------------------------------------- #
    def _tab_name(self, idx: int) -> str:
        off = self._tab_off + 32 * idx
        return bytes(self._ctl.buf[off:off + 32]).rstrip(b"\0").decode()

    def _get_slot(self, nbytes: int) -> tuple[int, memoryview]:
        from multiprocessing import shared_memory
        idx = self._pop_free()
        shm = self._pool.get(idx)
        if shm is None and (name := self._tab_name(idx)):
            # a pre-split sender populated this slot; adopt it
            try:
                shm = self._pool[idx] = shared_memory.SharedMemory(name=name)
            except FileNotFoundError:
                shm = None
        if shm is None or shm.size < nbytes:
            if shm is not None:               # outgrown: replace the slot
                shm.close()
                try:
                    shm.unlink()
                except FileNotFoundError:
                    pass
            shm = shared_memory.SharedMemory(
                create=True, size=_next_pow2(max(nbytes, 1 << 16)))
            self._pool[idx] = shm
            off = self._tab_off + 32 * idx    # republish before the record
            name = shm.name.encode()
            self._ctl.buf[off:off + 32] = name + b"\0" * (32 - len(name))
        return idx, shm.buf

    def _slot_view(self, idx: int, nbytes: int) -> memoryview:
        from multiprocessing import shared_memory
        name = self._tab_name(idx)
        cached = self._attached.get(idx)
        if cached is None or cached[0] != name:
            if cached is not None:            # stale: the sender grew the slot
                try:
                    cached[1].close()
                except BufferError:           # an older view still pins it
                    _PINNED_MAPPINGS.append(cached[1])
            try:
                # NB: attaching re-registers the segment with the
                # resource tracker, but worker hosts inherit the
                # orchestrator's tracker, so the set-add is idempotent
                # and the creator's unlink still unregisters exactly once
                shm = shared_memory.SharedMemory(name=name)
            except FileNotFoundError:
                raise TransportError(
                    f"hop {self.hop.index}: shmem slot {name!r} gone "
                    f"(peer closed)") from None
            cached = self._attached[idx] = (name, shm)
        return cached[1].buf[:nbytes]

    # -- lifecycle across processes / split ------------------------------ #
    def __getstate__(self):
        state = super().__getstate__()
        state.pop("_ctl", None)
        state["_pool"] = {}
        state["_attached"] = {}
        state["_lease"] = None
        # the shipped copy inherits unlink duty for the control segment;
        # this (parent) copy relinquishes it, so the parent closing its
        # handles on shipped ends cannot yank the segment from under a
        # worker that has not attached yet (double unlink is tolerated)
        state["_ctl_owner"] = True
        self._ctl_owner = False
        return state

    def __setstate__(self, state):
        from multiprocessing import shared_memory
        super().__setstate__(state)
        self._layout(self._depth)
        self._ctl = shared_memory.SharedMemory(name=self._ctl_name)

    def split(self):
        import copy
        tx, rx = copy.copy(self), copy.copy(self)
        tx.__setstate__(tx.__getstate__())    # fresh caches/locks per end
        rx.__setstate__(rx.__getstate__())
        tx._role, rx._role = "send", "recv"
        # each end keeps only its own doorbell fds, so closing one end
        # (e.g. the parent's copy of a shipped end) cannot silence the
        # other's bells
        tx._bell_dr = tx._bell_fs = None
        rx._bell_ds = rx._bell_fr = None
        return tx, rx

    # -- hot path --------------------------------------------------------- #
    def send(self, payload=None, kind: int = BATCH, _dup: bool = False):
        t0 = time.perf_counter()              # serialization + copy count
        ftype, code, shape, data, meta, ccode = _frame(
            payload, self.hop.framing, self._send_codec(kind))
        nbytes, mlen = len(data), len(meta)
        self._pace(nbytes + mlen, kind)
        if mlen > _INLINE:
            raise TransportError(
                f"hop {self.hop.index}: {mlen} B of pickled metadata "
                f"exceeds the {_INLINE} B inline area")
        # Reserve ring space *before* claiming a payload slot, so a
        # TransportTimeout here (the retryable liveness signal — receiver
        # not draining) leaves no sender state mutated and the caller can
        # simply re-send.  Space never shrinks once seen: the receiver
        # only consumes records.  0 <= used: a torn read of the receiver-
        # written tail counter must block the publish, never overwrite an
        # unconsumed record.
        self._wait(lambda: 0 <= self._ld(self._DH) - self._ld(self._DT)
                   < self._cap,
                   self._bell_fr, self.hop.send_timeout_s,
                   f"control ring full for {self.hop.send_timeout_s:.0f}s "
                   f"(receiver not draining)", err=TransportTimeout)
        slot, ilen = -1, 0
        if nbytes:
            if mlen + nbytes <= _INLINE:
                ilen = nbytes                 # small payload: ride inline
            else:
                slot, buf = self._get_slot(nbytes)
                buf[:nbytes] = memoryview(data)
        if _dup:                              # chaos re-send: same wire seq
            seq = self._tx_seq - 1
        else:
            seq = self._tx_seq
            self._tx_seq += 1
        head = self._ld(self._DH)
        base = self._rec_off + (head % self._cap) * _STRIDE
        _RREC.pack_into(self._ctl.buf, base, ftype, kind, code, len(shape),
                        ccode, slot, mlen, ilen, t0, nbytes, seq,
                        *shape, *((0,) * (8 - len(shape))))
        inl = base + _RREC.size
        if mlen:
            self._ctl.buf[inl:inl + mlen] = meta
        if ilen:
            self._ctl.buf[inl + mlen:inl + mlen + ilen] = memoryview(data)
        self._st(self._DH, head + 1)          # publish, then ring
        self._ring(self._bell_ds)
        return None

    def recv(self, timeout: float | None = None):
        if self._lease is not None:           # the handed-out view's slot
            self._push_free(self._lease)      # is only reclaimed now
            self._lease = None

        def ready():
            avail = self._ld(self._DH) - self._ld(self._DT)
            return 0 < avail <= self._cap     # clamp guards a torn read
        while True:
            self._wait(ready, self._bell_dr, timeout, "recv timed out")
            tail = self._ld(self._DT)
            base = self._rec_off + (tail % self._cap) * _STRIDE
            (ftype, kind, code, ndim, ccode, slot, mlen, ilen, t0, nbytes,
             seq, *shape) = _RREC.unpack_from(self._ctl.buf, base)
            if kind == BATCH and seq <= self._rx_seen:
                # duplicate frame: recycle its slot, consume the record
                if slot >= 0:
                    self._push_free(slot)
                was_full = self._ld(self._DH) - tail >= self._cap
                self._st(self._DT, tail + 1)
                if was_full:
                    self._ring(self._bell_fs)
                continue
            if seq > self._rx_seen + 1:
                raise TransportError(
                    f"hop {self.hop.index}: wire gap — frame(s) lost "
                    f"(seq {seq} after {self._rx_seen})")
            if not 0 <= kind <= CANCEL:
                raise TransportError(
                    f"hop {self.hop.index}: corrupt frame header "
                    f"(kind=0x{kind:02x})")
            break
        self._rx_seen = seq
        inl = base + _RREC.size
        meta = bytes(self._ctl.buf[inl:inl + mlen]) if mlen else b""
        if slot >= 0:
            view = self._slot_view(slot, nbytes)
            payload = _unframe(ftype, code, tuple(shape[:ndim]), view, meta,
                               ccode)
            if ftype == _F_RAW and not ccode and self.hop.zero_copy:
                self._lease = slot            # view stays valid until next recv
            else:
                # codec-decoded payloads are fresh arrays, no lease needed
                if (ftype == _F_RAW and not ccode
                        and isinstance(payload, np.ndarray)):
                    payload = payload.copy()  # outlives the slot
                self._push_free(slot)
        else:
            # inline payloads are copied out — the ring record is reused
            # after one wraparound, sooner than any lease could track
            buf = bytes(self._ctl.buf[inl + mlen:inl + mlen + ilen])
            payload = _unframe(ftype, code, tuple(shape[:ndim]), buf, meta,
                               ccode)
        was_full = self._ld(self._DH) - tail >= self._cap
        self._st(self._DT, tail + 1)
        if was_full:                          # unblock a ring-full sender
            self._ring(self._bell_fs)
        if kind in (BATCH, PROBE) and self.hop.scenario_hop:
            self.record(nbytes, time.perf_counter() - t0, t0 - self.epoch,
                        raw_bytes=_raw_payload_bytes(
                            ftype, code, shape[:ndim], nbytes, ccode))
        return kind, payload

    def close(self) -> None:
        if self._lease is not None:
            try:
                self._push_free(self._lease)
            except Exception:
                pass
            self._lease = None
        for _, shm in self._attached.values():
            try:
                shm.close()
            except BufferError:
                # a zero-copy view handed out by recv() still pins this
                # mapping; park the object so its __del__ never runs (the
                # pages are reclaimed at process exit — unlink already
                # removed the name)
                _PINNED_MAPPINGS.append(shm)
            except Exception:
                pass
        for shm in self._pool.values():
            try:
                shm.unlink()                  # before close: a pinned
            except Exception:                 # mapping must not skip it
                pass
            try:
                shm.close()
            except BufferError:
                _PINNED_MAPPINGS.append(shm)
            except Exception:
                pass
        self._pool.clear()
        self._attached.clear()
        ctl = getattr(self, "_ctl", None)
        if ctl is not None:
            try:
                ctl.close()
            except Exception:
                pass
            if self._ctl_owner:
                try:
                    ctl.unlink()
                except FileNotFoundError:
                    pass
                except Exception:
                    pass
            self._ctl = None
        for bell in (self._bell_ds, self._bell_dr,
                     self._bell_fs, self._bell_fr):
            if bell is not None:
                try:
                    bell.close()
                except OSError:
                    pass

    def reap(self) -> None:
        """Unlink the control segment and every slot named in its table
        regardless of ownership or close() state — a SIGKILL'd worker
        never ran close(), and its segments must not outlive the
        pipeline.  Reattaches by name, so it works on any end."""
        from multiprocessing import shared_memory
        try:
            ctl = shared_memory.SharedMemory(name=self._ctl_name)
        except (FileNotFoundError, OSError):
            return                            # already fully torn down
        # every lane of a shared fan segment names slots in its own table;
        # whichever lane reaps first must sweep them all
        for lane in range(self._n_lanes):
            tab = lane * self._lane_size + (self._tab_off - self._base)
            for i in range(self._n_slots):
                off = tab + 32 * i
                name = bytes(ctl.buf[off:off + 32]).rstrip(b"\0").decode()
                if not name:
                    continue
                try:
                    shm = shared_memory.SharedMemory(name=name)
                    shm.close()
                    shm.unlink()
                except Exception:
                    pass
        ctl.close()
        try:
            ctl.unlink()
        except Exception:
            pass


# --------------------------------------------------------------------------- #
# Replica fan-out / fan-in
# --------------------------------------------------------------------------- #
# A stage placed on r devices turns its hop into a *lane group*: r SPSC
# channels, one per replica.  The dispatcher stripes data round-robin by
# sequence number and broadcasts control tokens to every lane; the merge
# consumes lanes in the same round-robin order, so results come back in
# submit order with no reorder buffer, and a token showing up on the
# current lane implies every other lane's next message is that same
# token (tokens are injected at a single upstream point and each lane
# is FIFO).  Both wrappers present the single-channel surface
# ``_worker_main`` and the engines already speak: hop/epoch/set_codec/
# drain_records/close/reap plus send or recv.
class _FanBase:
    def __init__(self, lanes: "Sequence[Channel]"):
        if not lanes:
            raise ValueError("replica fan needs at least one lane")
        self.lanes = list(lanes)

    @property
    def hop(self) -> HopSpec:
        return self.lanes[0].hop

    @property
    def epoch(self) -> float:
        return self.lanes[0].epoch

    @epoch.setter
    def epoch(self, value: float) -> None:
        for ch in self.lanes:
            ch.epoch = value

    def set_codec(self, name: str) -> None:
        for ch in self.lanes:
            ch.set_codec(name)

    def drain_records(self):
        records = []
        for ch in self.lanes:
            records.extend(ch.drain_records())
        return records

    def close(self) -> None:
        for ch in self.lanes:
            ch.close()

    def reap(self) -> None:
        for ch in self.lanes:
            ch.reap()


class FanOutChannel(_FanBase):
    """Dispatcher end of a replica lane group: batches (and probes —
    they ride the data stripe so both sides' round-robin counters stay
    aligned) go to lane ``seq % r``; every other kind is a control
    token, broadcast to all lanes in lane order."""

    def __init__(self, lanes: "Sequence[Channel]"):
        super().__init__(lanes)
        self._seq = 0

    def send(self, payload=None, kind: int = BATCH):
        if kind in (BATCH, PROBE):
            ch = self.lanes[self._seq % len(self.lanes)]
            self._seq += 1
            return ch.send(payload, kind)
        rec = None
        for ch in self.lanes:
            rec = ch.send(payload, kind)
        return rec

    def evict_lane(self, m: int) -> None:
        """Drop a dead lane from the stripe map; later batches stripe
        round-robin over the survivors, restarting at lane 0.  Only
        valid at quiescence (no data in flight on the group) and must be
        mirrored by ``FanInChannel.evict_lane`` on the same lane so both
        cursors stay aligned."""
        if len(self.lanes) <= 1:
            raise ValueError("cannot evict the last lane of a replica fan")
        if not 0 <= m < len(self.lanes):
            raise IndexError(f"lane {m} of {len(self.lanes)}")
        del self.lanes[m]
        self._seq = 0


class FanInChannel(_FanBase):
    """Merge end of a replica lane group: data is consumed strictly in
    the dispatcher's stripe order (lane ``_next``), so ordering needs no
    seq numbers or reorder buffer.  A broadcast token is returned
    exactly once — after collecting every other lane's copy, so no lane
    can run a token ahead of the merge.  A ``TransportTimeout`` while
    collecting leaves the merge state intact: the next ``recv`` resumes
    the collection."""

    def __init__(self, lanes: "Sequence[Channel]"):
        super().__init__(lanes)
        self._next = 0                        # lane owing the next message
        self._tok: tuple | None = None        # pending broadcast token
        self._owed: list[int] = []            # lanes still owing their copy

    def recv(self, timeout: float | None = None):
        if self._tok is not None:
            return self._collect(timeout)
        kind, payload = self.lanes[self._next].recv(timeout)
        if kind in (BATCH, PROBE):
            self._next = (self._next + 1) % len(self.lanes)
            return kind, payload
        if kind == ERROR:
            return kind, payload              # fail fast, skip collection
        self._tok = (kind, payload)
        self._owed = [m for m in range(len(self.lanes)) if m != self._next]
        return self._collect(timeout)

    def _collect(self, timeout: float | None):
        kind, payload = self._tok
        while self._owed:
            k, p = self.lanes[self._owed[0]].recv(timeout)
            if k == ERROR:
                return k, p
            if k != kind:
                raise TransportError(
                    f"hop {self.hop.index}: replica fan-in protocol error "
                    f"— lane {self._owed[0]} sent kind {k} while collecting "
                    f"a broadcast token of kind {kind}")
            self._owed.pop(0)
        self._tok = None                      # _next unchanged: the stripe
        return kind, payload                  # resumes where it left off

    def evict_lane(self, m: int) -> None:
        """Drop a dead lane from the merge, mirroring
        ``FanOutChannel.evict_lane``: the stripe cursor restarts at lane
        0 and any pending-token bookkeeping forgets the evicted lane.
        Only valid at quiescence on the group."""
        if len(self.lanes) <= 1:
            raise ValueError("cannot evict the last lane of a replica fan")
        if not 0 <= m < len(self.lanes):
            raise IndexError(f"lane {m} of {len(self.lanes)}")
        del self.lanes[m]
        self._owed = [x - 1 if x > m else x for x in self._owed if x != m]
        self._next = 0


# --------------------------------------------------------------------------- #
# Transport registry
# --------------------------------------------------------------------------- #
class Transport(ABC):
    """A way to realize hops: opens one ``Channel`` per ``HopSpec``.
    ``process_based`` says whether stages must live in worker processes
    (socket/shmem) or threads of this process (emulated)."""

    name: str = "?"
    process_based: bool = False

    @abstractmethod
    def open(self, hop: HopSpec) -> Channel:
        ...

    def open_fan(self, hop: HopSpec, n: int) -> list[Channel]:
        """``n`` independent lanes of the same hop — the channel group a
        replicated stage's fan-out/fan-in rides (one SPSC lane per
        replica, batches striped round-robin by seq).  Default: ``n``
        separate :meth:`open` calls; shmem overrides this to pack the
        lanes into one shared control segment."""
        return [self.open(hop) for _ in range(n)]


class EmulatedTransport(Transport):
    name = "emulated"
    process_based = False

    def __init__(self, clock: Callable[[], float] | None = None):
        self._clock = clock

    def open(self, hop: HopSpec) -> Channel:
        return EmulatedChannel(hop, clock=self._clock)


class SocketTransport(Transport):
    name = "socket"
    process_based = True

    def open(self, hop: HopSpec) -> Channel:
        return SocketChannel(hop)


class ShmemTransport(Transport):
    name = "shmem"
    process_based = True

    def __init__(self, ctx=None):
        self._ctx = ctx

    def open(self, hop: HopSpec) -> Channel:
        return ShmemChannel(hop, ctx=self._ctx)

    def open_fan(self, hop: HopSpec, n: int) -> list[Channel]:
        if n <= 1:
            return [self.open(hop)]
        from multiprocessing import shared_memory
        # one segment, n page-aligned SPSC lanes: r producers share the
        # ingress mapping without r separate control segments
        stride = _lane_stride(max(hop.depth, 1))
        ctl = shared_memory.SharedMemory(create=True, size=stride * n)
        return [ShmemChannel(hop, ctx=self._ctx,
                             _shared=(ctl, m * stride, n, stride))
                for m in range(n)]


TRANSPORTS: dict[str, Callable[..., Transport]] = {
    "emulated": EmulatedTransport,
    "socket": SocketTransport,
    "shmem": ShmemTransport,
}


def register_transport(name: str, factory: Callable[..., Transport]) -> None:
    """Register a backend so scenarios/pipelines can name it."""
    TRANSPORTS[name] = factory


def get_transport(name: str, **kwargs) -> Transport:
    try:
        factory = TRANSPORTS[name]
    except KeyError:
        raise KeyError(f"unknown transport {name!r}; have "
                       f"{sorted(TRANSPORTS)}") from None
    return factory(**kwargs)


# --------------------------------------------------------------------------- #
# Worker host process body
# --------------------------------------------------------------------------- #
def _flush_stats(stage: int, worker, ingress: Channel):
    """Drain this stage's compute stats + ingress observations into one
    picklable control message, resetting both (delta semantics)."""
    import psutil
    from .edge import StageStats
    s = worker.stats
    worker.stats = StageStats()
    records = [tuple(r) for r in ingress.drain_records()]
    mem_pct = psutil.Process().memory_percent()
    return ("stats", stage,
            {"exe_s": s.exe_s, "calls": s.calls, "cpu_s": s.cpu_s},
            mem_pct, records)


def _worker_main(spec: dict) -> None:
    """One pipeline stage as an OS process: recv → compute → send."""
    from .edge import Worker

    stage: int = spec["stage"]
    ctrl = spec["ctrl"]
    stop = spec["stop"]
    ingress: Channel = spec["ingress"]
    egress: Channel = spec["egress"]
    bounds = tuple(spec["bounds"])
    backend = spec["backend"]

    def build(bounds):
        return Worker(f"worker{stage + 1}", spec["model"], spec["params"],
                      bounds[stage], bounds[stage + 1], backend,
                      cpu_clock=time.process_time,
                      pace_s=spec.get("pace_s", 0.0))

    try:
        worker = build(bounds)
        ctrl.send(("ready", stage))
        # flush-cancel skip window: the parent's out-of-band ("cancel",)
        # ctrl message overtakes the in-band stream, so batches already
        # queued ahead of the CANCEL fence skip compute and travel as
        # empty None markers (preserving arrival accounting).  The fence
        # itself (a truthy CANCEL payload) closes the window.  Purely an
        # optimization: the session drops canceled arrivals either way.
        cancel_target = fence_seen = 0
        while not stop.is_set():
            while ctrl.poll(0):
                msg = ctrl.recv()
                if isinstance(msg, tuple) and msg and msg[0] == "cancel":
                    cancel_target += 1
            try:
                kind, obj = ingress.recv(timeout=0.25)
            except TransportTimeout:
                continue
            if kind == STOP:
                egress.send(None, kind=STOP)
                break
            elif kind == BATCH:
                if obj is None or fence_seen < cancel_target:
                    egress.send(None, kind=BATCH)   # canceled: flush marker
                else:
                    # as_jax: dlpack-alias the (possibly shmem-slot-backed)
                    # view straight into jax; run() blocks until ready, so
                    # the compute is done before the next recv releases it
                    egress.send(np.asarray(worker.run(as_jax(obj))),
                                kind=BATCH)
            elif kind == CANCEL:
                if obj:
                    fence_seen += 1
                egress.send(obj, kind=CANCEL)
            elif kind == WARMUP:
                egress.send(np.asarray(worker.warmup(as_jax(obj))),
                            kind=WARMUP)
            elif kind == PROBE:
                egress.send(None, kind=PROBE)
            elif kind == RECONFIG:
                # payload: legacy bounds tuple, or a dict carrying the
                # bounds plus a per-hop codec vector to switch to
                if isinstance(obj, dict):
                    bounds, codecs = tuple(obj["bounds"]), obj.get("codecs")
                else:
                    bounds, codecs = tuple(obj), None
                if (bounds[stage], bounds[stage + 1]) != (worker.lo, worker.hi):
                    worker = build(bounds)
                if (codecs is not None and egress.hop.scenario_hop
                        and 0 <= egress.hop.index < len(codecs)):
                    egress.set_codec(codecs[egress.hop.index])
                egress.send(obj, kind=RECONFIG)
            elif kind == STATS:
                ctrl.send(_flush_stats(stage, worker, ingress))
                egress.send(obj, kind=STATS)
            elif kind == CLOCK:
                ingress.epoch = egress.epoch = float(obj)
                egress.send(obj, kind=CLOCK)
            elif kind == ERROR:               # propagate towards the sink
                egress.send(obj, kind=ERROR)
    except BaseException as e:  # noqa: BLE001 — reported, then the host exits
        msg = f"stage {stage} ({type(e).__name__}): {e}"
        for report in (lambda: ctrl.send(("error", stage, msg)),
                       lambda: egress.send(msg, kind=ERROR)):
            try:
                report()
            except Exception:
                pass
    finally:
        ingress.close()
        egress.close()


# --------------------------------------------------------------------------- #
# Single-hop microbenchmark: one spawned sink process, receiver-measured
# records — the payload-size sweep under ``benchmarks.transport_bench``
# and the shmem-vs-socket regression guards in the test suite.
# --------------------------------------------------------------------------- #
def _sink_main(spec: dict) -> None:
    """Receive-only host: drain a channel, flush its TransferRecords to
    the parent over a control pipe on STATS, exit on STOP."""
    chan: Channel = spec["chan"]
    ctrl = spec["ctrl"]
    try:
        ctrl.send(("ready",))
        while True:
            try:
                kind, _ = chan.recv(timeout=0.25)
            except TransportTimeout:
                continue
            if kind == STOP:
                break
            if kind == STATS:
                ctrl.send([tuple(r) for r in chan.drain_records()])
            elif kind in (BATCH, WARMUP):
                ctrl.send(0)                  # credit back to the sender
            else:
                # PROBE/RECONFIG/CLOCK/ERROR are not part of the
                # microbench protocol; a stray one means the driver and
                # sink disagree about the wire — fail loudly (R1)
                raise TransportError(
                    f"sink: unexpected {_KIND_NAMES[kind]} token")
    finally:
        chan.close()
        ctrl.close()


def measure_hop(transport: str, sizes: Sequence[int], n_per_size: int = 20,
                warmup: int | None = None, depth: int = 4,
                framing: str = "raw", timeout_s: float = 60.0,
                spin_us: float = 500.0, codec: str = "none",
                pace_link: AnyLink | None = None,
                full: bool = False, bell: str = "auto",
                sanitize: bool | None = None) -> dict[int, list]:
    """Stream float32 payloads of each size in ``sizes`` over one real
    hop to a spawned sink process → {nbytes: receiver-measured elapsed
    seconds per transfer}.  The sink credits each message back over a
    control pipe and the sender waits for the credit, so every transfer
    measures true per-hop cost — without the credit, a fast sender
    queues messages in the transport and later transfers absorb the
    queueing delay of everything ahead of them.  Sizes run
    smallest-first over one channel, so the sweep also exercises shmem
    slot growth in place."""
    import multiprocessing as mp
    if warmup is None:
        # every shmem slot must be grown *and* first-touched at each
        # size before timing starts, or the timed window carries
        # hundreds of µs of page faults per cold slot
        warmup = depth + 3
    from .sanitizer import maybe_sanitize, sanitize_enabled
    ctx = mp.get_context("spawn")
    chan = get_transport(transport).open(
        HopSpec(index=0, framing=framing, depth=depth,
                send_timeout_s=timeout_s,
                # wide spin window: the credit round trip must land in
                # it, or the per-hop number degenerates into a
                # scheduler-wakeup benchmark (bimodal under load)
                spin_us=spin_us, codec=codec, pace_link=pace_link,
                bell=bell, sanitize=sanitize_enabled(sanitize)))
    tx, rx = maybe_sanitize(chan).split()
    parent_c, child_c = ctx.Pipe()
    proc = ctx.Process(target=_sink_main, args=({"chan": rx, "ctrl": child_c},),
                       daemon=True, name=f"hop-sink-{transport}")
    proc.start()
    child_c.close()
    out: dict[int, list[float]] = {}
    try:
        rx.close()                            # parent's copy of the far end
        if not parent_c.poll(timeout_s):
            raise TransportError(f"{transport} sink failed to start")
        parent_c.recv()
        for nbytes in sorted(sizes):
            x = np.zeros(max(nbytes // 4, 1), dtype=np.float32)
            for i in range(warmup + n_per_size):
                tx.send(x, kind=WARMUP if i < warmup else BATCH)
                if not parent_c.poll(timeout_s):
                    raise TransportError(f"{transport} sink stalled")
                parent_c.recv()
            tx.send(kind=STATS)
            if not parent_c.poll(timeout_s):
                raise TransportError(f"{transport} sink stopped responding")
            recs = [TransferRecord(*r) for r in parent_c.recv()]
            recs = [r for r in recs if r.raw_bytes == x.nbytes]
            out[nbytes] = recs if full else [r.elapsed_s for r in recs]
    finally:
        try:
            tx.send(kind=STOP)
        except Exception:
            pass
        proc.join(5.0)
        if proc.is_alive():
            proc.terminate()
            proc.join(1.0)
        tx.close()
        tx.reap()
        parent_c.close()
    return out


# --------------------------------------------------------------------------- #
# Trace recorder: measured records → replayable LinkTrace
# --------------------------------------------------------------------------- #
def record_trace(source, *, name: str = "recorded", bucket_s: float = 0.5,
                 fallback: Link | None = None) -> LinkTrace:
    """Convert drained ``TransferRecord``s from a real (measured)
    channel into a replayable ``LinkTrace`` — measured runs seeding the
    emulator.

    Records are grouped into ``bucket_s`` windows of hop time; per
    bucket the RTT comes from header-only probes (nbytes=0: elapsed ≈
    one-way, so RTT = 2×mean) and the bandwidth from a least-squares
    fit of elapsed = rtt/2 + overhead + nbytes/bw over the bucket's
    data transfers (single-size buckets fall back to per-record
    attribution).  Buckets inherit missing values from their
    predecessor / the ``fallback`` link.

    ``source`` is a Channel/HopObservations (drained) or an iterable of
    ``(nbytes, elapsed_s, t_s)`` records.
    """
    # duck-typed: a SanitizedChannel wrapper delegates drain_records()
    # and link without subclassing HopObservations
    if isinstance(source, HopObservations) or hasattr(source, "drain_records"):
        records = source.drain_records()
        if fallback is None and isinstance(getattr(source, "link", None), Link):
            fallback = source.link
    else:
        records = [TransferRecord(*r) for r in source]
    if not records:
        raise ValueError("record_trace: no records to convert")
    records = sorted(records, key=lambda r: r.t_s)

    rtt = fallback.rtt_s if fallback is not None else None
    overhead = fallback.per_msg_overhead_s if fallback is not None else 0.0
    bw = fallback.bw_bytes_per_s if fallback is not None else None

    knots: list[tuple[float, float, float]] = []
    t0, t_end = records[0].t_s, records[-1].t_s
    n_buckets = max(int((t_end - t0) / bucket_s) + 1, 1)
    for b in range(n_buckets):
        lo, hi = t0 + b * bucket_s, t0 + (b + 1) * bucket_s
        group = [r for r in records
                 if lo <= r.t_s < hi or (b == n_buckets - 1 and r.t_s == hi)]
        if not group:
            continue
        probes = [r.elapsed_s for r in group if r.nbytes <= 0]
        if probes:
            rtt = 2.0 * float(np.mean(probes))
        data = [r for r in group if r.nbytes > 0]
        if data:
            fit = fit_link_params([r.nbytes for r in data],
                                  [r.elapsed_s for r in data], rtt or 0.0)
            if fit is not None:               # joint fit: slope → 1/bw
                bw, overhead = fit
            else:                             # degenerate bucket: attribute
                bw = float(np.mean([
                    attribute_bandwidth(r.nbytes, r.elapsed_s, rtt or 0.0,
                                        overhead) for r in data]))
        if rtt is not None and bw is not None and bw > 0:
            knots.append(((lo + min(hi, t_end)) / 2.0, float(rtt), float(bw)))
    if not knots:
        raise ValueError("record_trace: no bucket yielded both an RTT and "
                         "a bandwidth estimate (need probes or a fallback "
                         "link for the RTT)")
    return LinkTrace(
        name=name, schedule=tuple(knots),
        per_msg_overhead_s=float(overhead),
        energy_per_byte_j=(fallback.energy_per_byte_j
                           if fallback is not None else 0.0),
    )
