"""Layer 2 of PipeCheck: the live transport-protocol sanitizer.

``SanitizedChannel`` wraps any :class:`~repro.runtime.transport.Channel`
and validates the in-band token state machine per message, on both the
send and the receive side of the hop:

* **WARMUP-after-RECONFIG** — once a hop has carried a BATCH, every
  RECONFIG must be followed by a WARMUP before the next BATCH (the
  migration protocol's recompile fence).  Quiescent reconfigs on a hop
  that never saw traffic are exempt.
* **STOP is terminal** — nothing may follow a STOP in either direction
  (repeated STOPs are tolerated: engine teardown is idempotent).
* **RECONFIG payloads are well-formed** — a ``{bounds, codecs}`` dict
  (or the legacy bare bounds tuple) with strictly-increasing integer
  bounds and codec names drawn from the registry.
* **exactly-once token delivery** — the same RECONFIG delivered twice
  back-to-back means a fan-in merge returned a broadcast token once
  per lane instead of once per group.
* **per-lane content order** — while both ends of a hop live in one
  process (thread engine, pre-spawn), batch payload fingerprints are
  queued at ``send`` and matched at ``recv``; a swap or corruption
  surfaces as a ``seq-order`` violation.  The ledger is dropped when an
  end crosses a process boundary (fingerprints cannot ride the wire
  without changing the frame layout — a cross-host follow-on).
* **zero-copy lease discipline** — a ``recv`` that hands out a view
  over transport-owned memory (shmem slot, reusable socket buffer)
  leases it until the *next* ``recv``.  The sanitizer stamps a canary
  (CRC of head+tail bytes) on the leased view and re-checks it at the
  next ``recv`` entry: a sender that wrote into the leased slot — or a
  stale view mutated after handoff — raises instead of silently
  corrupting a tensor.

Violations are appended to a process-global report *and* raised as
:class:`SanitizerError` (a ``TransportError``, so engine error paths
propagate them like any transport failure).  ``drain_violations()``
empties the report; matrix tests assert it stays empty.

Enable per hop with ``HopSpec(sanitize=True)``, per pipeline with
``EdgePipeline(..., sanitize=True)``, or globally with
``REPRO_SANITIZE=1`` in the environment.

Layering with fault injection: :class:`~repro.runtime.faults.ChaosChannel`
wraps *outside* this sanitizer (``maybe_chaos(maybe_sanitize(chan))``)
and injects wire damage through the raw transport *below* it, so the
chaos layer doubles as the sanitizer's adversarial test harness — a
supervised pipeline that recovers from an injected fault must still
drain zero violations.
"""
from __future__ import annotations

import os
import threading
import zlib
from dataclasses import dataclass
from typing import Optional

import numpy as np

from .transport import (
    BATCH, RECONFIG, STOP, WARMUP, _KIND_NAMES, TransportError,
)

__all__ = [
    "SanitizerError", "Violation", "SanitizedChannel",
    "deep_enabled", "drain_violations", "maybe_sanitize", "sanitize_enabled",
]


class SanitizerError(TransportError):
    """A live protocol invariant was violated on a sanitized hop."""


@dataclass(frozen=True)
class Violation:
    """One protocol violation: which rule, on which hop, at which point
    of the stream (seq = messages of that direction seen so far)."""

    rule: str
    hop: int
    seq: int
    kind: int
    message: str

    def render(self) -> str:
        kind = (_KIND_NAMES[self.kind]
                if 0 <= self.kind < len(_KIND_NAMES) else str(self.kind))
        return (f"[{self.rule}] hop {self.hop} seq {self.seq} "
                f"kind {kind}: {self.message}")


_VIOLATIONS: list[Violation] = []
_VLOCK = threading.Lock()


def drain_violations() -> list[Violation]:
    """Return and clear every violation collected in this process."""
    with _VLOCK:
        out = list(_VIOLATIONS)
        _VIOLATIONS.clear()
    return out


def sanitize_enabled(explicit: Optional[bool] = None) -> bool:
    """Resolve a sanitize knob: an explicit True/False wins, otherwise
    the ``REPRO_SANITIZE`` env var ("" / "0" = off)."""
    if explicit is not None:
        return bool(explicit)
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


def maybe_sanitize(chan):
    """Wrap ``chan`` in a SanitizedChannel iff its hop asks for it."""
    if getattr(chan.hop, "sanitize", False) \
            and not isinstance(chan, SanitizedChannel):
        return SanitizedChannel(chan)
    return chan


# --------------------------------------------------------------------------- #
# payload fingerprints
# --------------------------------------------------------------------------- #
_SAMPLE = 16  # elements hashed from each end of a batch


def deep_enabled() -> bool:
    """``REPRO_SANITIZE_DEEP=1``: hash the *full* payload instead of a
    head/tail sample.  Read per call so a test can flip it; the cost is
    one crc32 pass over every batch on every sanitized hop, which is
    why it is the slow-tier CI setting and not the default."""
    return os.environ.get("REPRO_SANITIZE_DEEP", "") not in ("", "0")


def _content_crc(arr: np.ndarray) -> int:
    if deep_enabled():
        return zlib.crc32(np.ascontiguousarray(arr).tobytes())
    flat = arr.ravel()  # view for contiguous payloads (the common case)
    return zlib.crc32(flat[:_SAMPLE].tobytes() + flat[-_SAMPLE:].tobytes())


def _fingerprint(payload, content: bool) -> tuple:
    """(tag, shape, dtype, crc|None) identity of a batch payload.

    ``content=False`` (a coded hop: the codec legitimately rewrites the
    bytes in flight) keeps only the structural identity.
    """
    if isinstance(payload, np.ndarray) or hasattr(payload, "dtype"):
        arr = np.asarray(payload)
        if not content or arr.size == 0:
            return ("nd", arr.shape, str(arr.dtype), None)
        return ("nd", arr.shape, str(arr.dtype), _content_crc(arr))
    return ("obj", repr(payload)[:200], None, None)


class _Ledger:
    """Send→recv fingerprint queue shared by the two wrapped ends of a
    hop while both live in the creating process.  Bounded so a
    recv-less drain (e.g. a closed pipeline) cannot grow it forever."""

    __slots__ = ("fps",)
    _MAX = 4096

    def __init__(self):
        from collections import deque
        self.fps = deque(maxlen=self._MAX)


# --------------------------------------------------------------------------- #
# the wrapper
# --------------------------------------------------------------------------- #
class SanitizedChannel:
    """Protocol-checking wrapper around a concrete Channel.

    Composition, not inheritance: every Channel attribute (``hop``,
    ``link``, observation counters, transport internals) delegates to
    the wrapped instance, so the wrapper is state-free apart from the
    checker itself and can front any transport."""

    def __init__(self, inner, _ledger: Optional[_Ledger] = None):
        self._inner = inner
        self._ledger = _ledger if _ledger is not None else _Ledger()
        # direction-local protocol state
        self._tx_seq = 0
        self._rx_seq = 0
        self._tx_batches = 0
        self._rx_batches = 0
        self._tx_stopped = False
        self._rx_stopped = False
        self._tx_need_warmup = False
        self._rx_need_warmup = False
        self._last_rx_token: Optional[tuple] = None
        self._lease: Optional[tuple] = None  # (crc, view, seq)

    # -- violation plumbing -------------------------------------------------
    def _violate(self, rule: str, seq: int, kind: int, message: str) -> None:
        v = Violation(rule, getattr(self.hop, "index", -1), seq, kind, message)
        with _VLOCK:
            _VIOLATIONS.append(v)
        raise SanitizerError(v.render())

    def _check_kind(self, kind, seq: int) -> None:
        if not isinstance(kind, int) or not 0 <= kind < len(_KIND_NAMES):
            self._violate("kind-range", seq, -1,
                          f"token kind {kind!r} outside the "
                          f"{len(_KIND_NAMES)}-kind protocol")

    def _content_checked(self) -> bool:
        # a coded hop rewrites payload bytes in flight; only structural
        # identity survives the wire
        return getattr(self.hop, "codec", "none") == "none"

    @staticmethod
    def _reconfig_error(payload) -> Optional[str]:
        if isinstance(payload, dict):
            if "bounds" not in payload:
                return "RECONFIG dict carries no 'bounds'"
            bounds, codecs = payload["bounds"], payload.get("codecs")
        elif isinstance(payload, (tuple, list)):
            bounds, codecs = payload, None
        else:
            return (f"RECONFIG payload must be a {{bounds, codecs}} dict or "
                    f"a bounds tuple, got {type(payload).__name__}")
        try:
            b = tuple(int(x) for x in bounds)
        except (TypeError, ValueError):
            return f"bounds is not an integer sequence: {bounds!r}"
        if len(b) < 2 or any(x >= y for x, y in zip(b, b[1:])):
            return f"bounds must be strictly increasing with >=2 edges: {b}"
        if codecs is not None:
            from ..core.codecs import CODECS
            try:
                bad = [c for c in codecs if c not in CODECS]
            except TypeError:
                return f"codecs is not a sequence of names: {codecs!r}"
            if bad:
                return f"unknown codec name(s) {bad} (registry: " \
                       f"{sorted(CODECS)})"
        return None

    # -- the checked surface ------------------------------------------------
    def send(self, payload=None, kind: int = BATCH):
        seq = self._tx_seq
        self._tx_seq += 1
        self._check_kind(kind, seq)
        if self._tx_stopped and kind != STOP:
            self._violate("stop-terminal", seq, kind,
                          "message sent after STOP (STOP is terminal)")
        if kind == STOP:
            self._tx_stopped = True
        elif kind == RECONFIG:
            err = self._reconfig_error(payload)
            if err is not None:
                self._violate("reconfig-payload", seq, kind, err)
            if self._tx_batches:
                self._tx_need_warmup = True
        elif kind == WARMUP:
            self._tx_need_warmup = False
        elif kind == BATCH:
            if self._tx_need_warmup:
                self._violate(
                    "warmup-skipped", seq, kind,
                    "BATCH sent after RECONFIG with no WARMUP fence between")
            self._tx_batches += 1
            if self._ledger is not None:
                self._ledger.fps.append(
                    _fingerprint(payload, self._content_checked()))
        return self._inner.send(payload, kind=kind)

    def recv(self, timeout: Optional[float] = None):
        self._check_lease()
        seq = self._rx_seq
        try:
            kind, payload = self._inner.recv(timeout)
        except TransportError:
            raise
        except Exception as exc:
            # a decode failure (unknown codec byte, mangled frame) comes
            # out of the framer as KeyError/ValueError/struct.error —
            # report it as a frame violation with hop context
            self._violate("frame-decode", seq, -1,
                          f"{type(exc).__name__}: {exc}")
        self._rx_seq += 1
        self._check_kind(kind, seq)
        if self._rx_stopped and kind != STOP:
            self._violate("stop-terminal", seq, kind,
                          "message received after STOP (STOP is terminal)")
        token_id: Optional[tuple] = None
        if kind == STOP:
            self._rx_stopped = True
        elif kind == RECONFIG:
            err = self._reconfig_error(payload)
            if err is not None:
                self._violate("reconfig-payload", seq, kind, err)
            token_id = ("RECONFIG", repr(payload)[:200])
            if token_id == self._last_rx_token:
                self._violate(
                    "token-dup", seq, kind,
                    "identical RECONFIG delivered twice back-to-back — a "
                    "fan-in merge must return each broadcast token exactly "
                    "once per lane group")
            if self._rx_batches:
                self._rx_need_warmup = True
        elif kind == WARMUP:
            self._rx_need_warmup = False
        elif kind == BATCH:
            if self._rx_need_warmup:
                self._violate(
                    "warmup-skipped", seq, kind,
                    "BATCH received after RECONFIG with no WARMUP fence "
                    "between")
            if self._ledger is not None and self._ledger.fps:
                expected = self._ledger.fps.popleft()
                got = _fingerprint(payload, expected[3] is not None)
                if got != expected:
                    self._violate(
                        "seq-order", seq, kind,
                        f"batch out of order or corrupted in flight: "
                        f"expected fingerprint {expected}, got {got}")
            self._rx_batches += 1
            self._arm_lease(payload)
        self._last_rx_token = token_id
        return kind, payload

    # -- zero-copy lease canaries -------------------------------------------
    def _arm_lease(self, payload) -> None:
        self._lease = None
        if not getattr(self.hop, "zero_copy", True):
            return
        if (
            isinstance(payload, np.ndarray)
            and payload.base is not None      # a view over transport memory
            and payload.size
        ):
            self._lease = (_content_crc(payload), payload, self._rx_batches)

    def _check_lease(self) -> None:
        lease, self._lease = self._lease, None
        if lease is None:
            return
        crc0, view, seq = lease
        try:
            crc = _content_crc(view)
        except Exception:
            return  # buffer already unmapped: nothing left to corrupt
        if crc != crc0:
            self._violate(
                "lease", seq, BATCH,
                "zero-copy view of the previous batch changed under its "
                "lease — a sender wrote into a leased slot (or user code "
                "mutated a stale view); copy before the next recv")

    # -- delegation ---------------------------------------------------------
    @property
    def hop(self):
        return self._inner.hop

    @property
    def epoch(self) -> float:
        return self._inner.epoch

    @epoch.setter
    def epoch(self, value: float) -> None:
        self._inner.epoch = value

    def reset_stream(self) -> None:
        """Start a fresh stream over a reused channel.

        STOP is terminal *per stream*, not per channel: the thread
        engine keeps its inter-stage channels across sessions (a clean
        close leaves them empty), so each ``session_open`` resets the
        protocol state machine.  Cumulative seq counters survive — a
        violation report should still locate the message in the
        channel's lifetime."""
        self._tx_batches = 0
        self._rx_batches = 0
        self._tx_stopped = False
        self._rx_stopped = False
        self._tx_need_warmup = False
        self._rx_need_warmup = False
        self._last_rx_token = None
        self._lease = None
        if self._ledger is not None:
            self._ledger.fps.clear()

    def split(self):
        tx, rx = self._inner.split()
        ledger = _Ledger()
        wrapped_tx = SanitizedChannel(tx, _ledger=ledger)
        if rx is tx:  # in-process pair: one shared end (emulated)
            return wrapped_tx, wrapped_tx
        return wrapped_tx, SanitizedChannel(rx, _ledger=ledger)

    def set_codec(self, name: str) -> None:
        self._inner.set_codec(name)

    def close(self) -> None:
        # drop any leased view before the transport unmaps its buffers
        # (a held export would make SharedMemory.close() fail)
        self._lease = None
        self._inner.close()

    def reap(self) -> None:
        self._inner.reap()

    def drain_records(self):
        return self._inner.drain_records()

    def drain_observations(self):
        return self._inner.drain_observations()

    def __getattr__(self, name: str):
        inner = self.__dict__.get("_inner")
        if inner is None:  # mid-unpickle: nothing to delegate to yet
            raise AttributeError(name)
        return getattr(inner, name)

    # crossing a process boundary drops the in-process-only state (the
    # fingerprint ledger and any armed lease canary); the token state
    # machine itself travels with the end
    def __getstate__(self):
        state = dict(self.__dict__)
        state["_ledger"] = None
        state["_lease"] = None
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)

    def __repr__(self) -> str:
        return f"SanitizedChannel({self._inner!r})"
