"""Executable runtime: the measured half of the reproduction.

Public API:
    EdgePipeline, PipelineResult      — k-stage executable pipeline over
                                        pluggable hop transports
    AdaptiveRuntime, LoopRecord       — closed measure→estimate→re-solve→
                                        migrate loop
    Transport, Channel, TransferRecord,
    register_transport, get_transport — the hop transport API
                                        ("emulated" | "socket" | "shmem")
    record_trace                      — measured records → replayable
                                        LinkTrace (seed the emulator)
"""
from .adaptive import AdaptiveRuntime, LoopRecord
from .edge import EdgePipeline, PipelineResult, StageStats, Worker
from .transport import (Channel, HopSpec, TransferRecord, Transport,
                        TransportError, TransportTimeout, get_transport,
                        record_trace, register_transport)

__all__ = [
    "AdaptiveRuntime", "LoopRecord",
    "EdgePipeline", "PipelineResult", "StageStats", "Worker",
    "Channel", "HopSpec", "TransferRecord", "Transport", "TransportError",
    "TransportTimeout", "get_transport", "record_trace", "register_transport",
]
