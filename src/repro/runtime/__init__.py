"""Executable runtime: the measured half of the reproduction.

Public API:
    EdgePipeline, PipelineResult      — k-stage executable pipeline over
                                        pluggable hop transports
    Session, Controller,
    PinnedController,
    AdaptiveController, LoopRecord,
    MigrationPolicy                   — the streaming Session API: one
                                        always-pipelined entrypoint
                                        (``EdgePipeline.session``) with
                                        pluggable controllers and
                                        in-flight drain/drop migration
    AdaptiveRuntime                   — closed measure→estimate→re-solve→
                                        migrate loop (a Session shim)
    Transport, Channel, TransferRecord,
    register_transport, get_transport — the hop transport API
                                        ("emulated" | "socket" | "shmem")
    record_trace                      — measured records → replayable
                                        LinkTrace (seed the emulator)
    SanitizedChannel, SanitizerError,
    Violation, drain_violations       — the live protocol sanitizer
                                        (``HopSpec(sanitize=True)`` /
                                        ``REPRO_SANITIZE=1``)
    FaultPlan, FaultEvent,
    ChaosChannel, BackoffPolicy,
    RecoveryRecord, drain_recoveries,
    drain_injections                  — deterministic fault injection
                                        (``EdgePipeline(fault_plan=...)``)
                                        and the supervised-recovery
                                        records it produces
    Gateway, ClientSession,
    QoSRecord, drain_qos,
    FleetController, FleetObjectives,
    CancelRecord                      — the multi-tenant serving gateway
                                        (micro-batching, SLO-aware AIMD
                                        admission, per-request QoS,
                                        CANCEL-fence flush) and the
                                        fleet-objective controller
"""
from .adaptive import AdaptiveRuntime
from .edge import EdgePipeline, PipelineResult, StageStats, Worker
from .faults import (BackoffPolicy, ChaosChannel, FaultEvent, FaultPlan,
                     RecoveryRecord, drain_injections, drain_recoveries)
from .sanitizer import (SanitizedChannel, SanitizerError, Violation,
                        drain_violations)
from .serve import (ClientSession, FleetController, FleetObjectives, Gateway,
                    QoSRecord, drain_qos)
from .session import (AdaptiveController, CancelRecord, Controller,
                      LoopRecord, MigrationPolicy, PinnedController, Session)
from .transport import (Channel, HopSpec, TransferRecord, Transport,
                        TransportError, TransportTimeout, get_transport,
                        record_trace, register_transport)

__all__ = [
    "AdaptiveRuntime", "LoopRecord",
    "Session", "Controller", "PinnedController", "AdaptiveController",
    "MigrationPolicy", "CancelRecord",
    "EdgePipeline", "PipelineResult", "StageStats", "Worker",
    "Channel", "HopSpec", "TransferRecord", "Transport", "TransportError",
    "TransportTimeout", "get_transport", "record_trace", "register_transport",
    "SanitizedChannel", "SanitizerError", "Violation", "drain_violations",
    "FaultPlan", "FaultEvent", "ChaosChannel", "BackoffPolicy",
    "RecoveryRecord", "drain_recoveries", "drain_injections",
    "Gateway", "ClientSession", "QoSRecord", "drain_qos",
    "FleetController", "FleetObjectives",
]
