"""Step functions: train / prefill / decode — the units jit compiles.

These are what the dry-run lowers, what the launcher runs, and what the
pipeline runtime wraps, for every architecture family.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..models import lm
from ..models.common import cross_entropy
from ..optim import (CompressionConfig, OptConfig, apply_gradients,
                     compress_gradients)


def loss_fn(cfg, params, batch):
    """CE via the seq-chunked head (logits never fully materialized —
    §Perf iteration 2); falls back to dense logits for tiny S."""
    from ..models.common import chunked_cross_entropy
    inputs = {k: v for k, v in batch.items() if k != "targets"}
    if cfg.family == "encdec":
        enc = lm.encode(cfg, params, inputs["frames"])
        x = lm.decoder_train(cfg, params, inputs["tokens"], enc)
        aux = 0.0
    else:
        x = lm.embed_inputs(cfg, params, inputs)
        positions = jnp.arange(x.shape[1])
        x, aux = lm.trunk_train(cfg, params, x, positions)
        x = lm.final_hidden(cfg, params, x)
    ce = chunked_cross_entropy(x, params["embed"], params.get("lm_head"),
                               batch["targets"], cfg.ce_chunk)
    return ce + 1e-2 * aux, {"ce": ce, "aux": aux}


def make_train_step(cfg, opt: OptConfig,
                    comp: CompressionConfig | None = None,
                    grad_accum: int = 1):
    """``grad_accum`` > 1 scans microbatches with fp32 gradient
    accumulation — activation working set shrinks by the factor at the
    cost of re-streaming weights per microbatch (§Perf iteration 4)."""
    comp = comp or CompressionConfig()

    # ZeRO-1 (§Perf iteration 5): the fp32 gradient accumulator shards
    # over data×model like the optimizer moments — otherwise it would
    # replicate a full fp32 gradient per data shard.
    from ..sharding.api import get_context, shard_zero1
    from ..models.common import SpecBuilder
    _specs = None
    if get_context() is not None:
        _specs = lm.build_params(cfg, SpecBuilder(get_context()))

    def _z1(tree):
        if _specs is None:
            return tree
        return jax.tree.map(lambda g, sp: shard_zero1(g, sp), tree, _specs)

    def _grads(params, batch):
        if grad_accum <= 1:
            return jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        mbs = jax.tree.map(
            lambda a: a.reshape(grad_accum, a.shape[0] // grad_accum,
                                *a.shape[1:]), batch)

        def body(carry, mb):
            gsum, lsum = carry
            (l, parts), g = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, mb), has_aux=True)(params)
            # constrain the raw per-microbatch gradient too: its DP
            # reduction then lowers to reduce-scatter instead of
            # materializing a full unsharded gradient + all-reduce
            g = _z1(jax.tree.map(lambda b: b.astype(jnp.float32), g))
            gsum = _z1(jax.tree.map(jnp.add, gsum, g))
            return (gsum, lsum + l), parts

        g0 = _z1(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params))
        (gsum, lsum), parts = jax.lax.scan(body, (g0, 0.0), mbs)
        grads = jax.tree.map(lambda g: g / grad_accum, gsum)
        parts = jax.tree.map(lambda a: a[-1], parts)
        return (lsum / grad_accum, parts), grads

    def train_step(state, batch):
        params = state["params"]
        (loss, parts), grads = _grads(params, batch)
        if comp.enabled:
            grads, err = compress_gradients(grads, state["err"], comp)
        new_params, opt_state, om = apply_gradients(params, grads,
                                                    state["opt"], opt)
        new_state = {"params": new_params, "opt": opt_state,
                     "step": state["step"] + 1}
        if comp.enabled:
            new_state["err"] = err
        metrics = {"loss": loss, **parts, **om}
        return new_state, metrics

    return train_step


def make_prefill_step(cfg, cache_len: int | None = None):
    def prefill_step(params, inputs):
        logits, cache = lm.forward_prefill(cfg, params, inputs, cache_len)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache
    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, token, cache):
        logits, cache = lm.forward_decode(cfg, params, token, cache)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache
    return decode_step


def init_train_state(cfg, key, opt: OptConfig,
                     comp: CompressionConfig | None = None,
                     dtype=None):
    from ..models.common import DTYPES, InitBuilder
    from ..optim import init_error_state, init_opt_state
    b = InitBuilder(key, dtype or DTYPES[cfg.dtype])
    params = lm.build_params(cfg, b)
    state = {"params": params, "opt": init_opt_state(params),
             "step": jnp.zeros((), jnp.int32)}
    if comp is not None and comp.enabled:
        state["err"] = init_error_state(params)
    return state
