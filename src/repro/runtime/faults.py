"""Chaos transport and recovery bookkeeping for fault-tolerant pipelines.

The paper's testbed is real hardware: workers get OOM-killed, links stall
and flap, frames arrive mangled.  This module supplies the two halves the
runtime needs to survive that world deterministically:

* **Fault injection** — a :class:`FaultPlan` is a seeded, picklable script
  of :class:`FaultEvent`\\ s ("kill stage 1 at batch 3", "stall the feed hop
  for 300 ms at batch 2").  Frame-level events are applied by
  :class:`ChaosChannel`, a send-side composition wrapper in the
  ``SanitizedChannel`` style: it wraps any channel whose ``hop`` carries a
  plan (``HopSpec(faults=...)``) and perturbs the wire *below* the
  sanitizer, so a sanitized stream that recovers cleanly also drains zero
  violations.  Worker-kill events are executed by the engine supervisor
  (``_ProcessEngine``), which SIGKILLs the scripted process the moment the
  triggering batch has been fed.

* **Recovery bookkeeping** — every supervised recovery (stage restart,
  replica failover, background restaff) emits a :class:`RecoveryRecord`
  into a module-level buffer drained with :func:`drain_recoveries`, the
  same contract ``sanitizer.drain_violations`` uses.  :class:`BackoffPolicy`
  pins the bounded exponential retry schedule the supervisor follows
  between recovery attempts.

Determinism: a plan holds *batch sequence numbers*, not wall-clock times.
The feed hop is addressed as hop ``-1``; its seq counter is the global
batch index, so "drop batch 2" means the same thing on every run and every
transport.  Faults fire exactly once — a replayed batch after recovery is
a fresh send on fresh channels and is not re-perturbed.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, replace

from .transport import BATCH

__all__ = [
    "FAULT_KINDS",
    "FaultEvent",
    "FaultPlan",
    "BackoffPolicy",
    "RecoveryRecord",
    "Injection",
    "ChaosChannel",
    "maybe_chaos",
    "note_recovery",
    "drain_recoveries",
    "drain_injections",
]

# The feed hop (orchestrator -> stage 0) in FaultPlan addressing.  Its seq
# counter is the global batch index, which makes feed-side plans portable
# across cut placements.
FEED_HOP = -1

# Frame kind used by header corruption: outside the 0..8 token range, so a
# sanitized receiver flags it (kind-range violation in the worker, which
# the supervisor turns into a recovery) and an unsanitized worker's
# dispatch ladder silently drops it (stall detection recovers instead).
CORRUPT_KIND = 0x6B

FAULT_KINDS = (
    "worker-kill",    # SIGKILL a (stage, lane) worker after batch seq N is fed
    "frame-stall",    # hold the frame for arg seconds before sending
    "frame-drop",     # swallow the frame (never reaches the wire)
    "frame-dup",      # send the frame twice with the same wire seq
    "link-flap",      # link down for arg seconds starting at this frame
    "header-corrupt", # replace the frame's kind byte with CORRUPT_KIND
)


@dataclass(frozen=True)
class FaultEvent:
    """One scripted fault.

    ``seq`` is the 0-based BATCH count on the addressed channel end
    (``hop == FEED_HOP`` → global batch index).  ``stage``/``lane`` are
    only meaningful for ``worker-kill``; ``arg`` holds the duration in
    seconds for ``frame-stall`` / ``link-flap``.
    """

    kind: str
    hop: int = FEED_HOP
    seq: int = 0
    stage: int = -1
    lane: int = 0
    arg: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")


@dataclass(frozen=True)
class FaultPlan:
    """A deterministic, picklable script of faults.

    Builder methods return a *new* plan (the dataclass is frozen), so
    plans compose fluently::

        plan = (FaultPlan(seed=7)
                .stall(hop=-1, at_seq=2, for_s=0.3)
                .kill_worker(stage=1, at_seq=4))

    The plan travels inside each ``HopSpec`` to worker processes, so it
    must stay tuples-of-frozen-dataclasses all the way down.
    """

    seed: int = 0
    events: tuple = ()

    def _with(self, ev: FaultEvent) -> "FaultPlan":
        return replace(self, events=self.events + (ev,))

    def kill_worker(self, stage: int, at_seq: int, lane: int = 0) -> "FaultPlan":
        return self._with(FaultEvent("worker-kill", seq=at_seq,
                                     stage=stage, lane=lane))

    def stall(self, hop: int, at_seq: int, for_s: float) -> "FaultPlan":
        return self._with(FaultEvent("frame-stall", hop=hop, seq=at_seq,
                                     arg=float(for_s)))

    def drop(self, hop: int, at_seq: int) -> "FaultPlan":
        return self._with(FaultEvent("frame-drop", hop=hop, seq=at_seq))

    def duplicate(self, hop: int, at_seq: int) -> "FaultPlan":
        return self._with(FaultEvent("frame-dup", hop=hop, seq=at_seq))

    def flap(self, hop: int, at_seq: int, down_s: float) -> "FaultPlan":
        return self._with(FaultEvent("link-flap", hop=hop, seq=at_seq,
                                     arg=float(down_s)))

    def corrupt(self, hop: int, at_seq: int) -> "FaultPlan":
        return self._with(FaultEvent("header-corrupt", hop=hop, seq=at_seq))

    # -- views used by the chaos wrapper and the supervisor ----------------
    def channel_events(self, hop: int) -> dict:
        """seq -> [events] for frame-level faults on one hop."""
        out: dict = {}
        for ev in self.events:
            if ev.kind != "worker-kill" and ev.hop == hop:
                out.setdefault(ev.seq, []).append(ev)
        return out

    def kill_events(self) -> dict:
        """global batch seq -> [worker-kill events]."""
        out: dict = {}
        for ev in self.events:
            if ev.kind == "worker-kill":
                out.setdefault(ev.seq, []).append(ev)
        return out


@dataclass(frozen=True)
class BackoffPolicy:
    """Bounded exponential backoff between supervisor recovery attempts.

    ``delay(a) = min(base_s * factor**a, cap_s)`` for attempt ``a`` in
    ``0..retries-1``; after ``retries`` failed attempts the supervisor
    gives up and surfaces the underlying ``TransportError``.
    """

    base_s: float = 0.05
    factor: float = 2.0
    cap_s: float = 2.0
    retries: int = 5

    def delay(self, attempt: int) -> float:
        return min(self.base_s * self.factor ** attempt, self.cap_s)

    def schedule(self) -> tuple:
        return tuple(self.delay(a) for a in range(self.retries))


# --------------------------------------------------------------------------- #
# Recovery records — drained like sanitizer violations
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class RecoveryRecord:
    """One completed recovery, with the timings the paper's robustness
    story needs: how fast was the failure *detected*, how long did the
    *restart* (respawn + channel rebuild + WARMUP fence) take, how long
    did the in-flight *replay* take, and at what capacity fraction does
    the pipeline run until restaffed.
    """

    kind: str              # "restart" | "failover" | "restaff"
    stage: int             # failed stage (-1 if unknown / whole-pipeline)
    lane: int              # failed replica lane (-1 if not replicated)
    reason: str            # "worker-death" | "worker-error" | "stall" | ...
    detect_s: float        # last-known-alive -> failure detected
    restart_s: float       # teardown + respawn + warmup fence
    replay_s: float        # resubmit of unacked in-flight batches
    batches_replayed: int
    degraded_capacity: float  # min_i r_eff[i]/r[i] after this recovery

    def render(self) -> str:
        return (f"[{self.kind}] stage={self.stage} lane={self.lane} "
                f"({self.reason}): detect={self.detect_s * 1e3:.0f}ms "
                f"restart={self.restart_s * 1e3:.0f}ms "
                f"replay={self.replay_s * 1e3:.0f}ms "
                f"({self.batches_replayed} batches) "
                f"capacity={self.degraded_capacity:.2f}")


_RECOVERIES: list = []
_RLOCK = threading.Lock()


def note_recovery(rec: RecoveryRecord) -> None:
    with _RLOCK:
        _RECOVERIES.append(rec)


def drain_recoveries() -> list:
    """Return and clear all recoveries since the last drain (orchestrator
    process only — recoveries are executed and recorded by the parent).
    """
    with _RLOCK:
        out = list(_RECOVERIES)
        _RECOVERIES.clear()
    return out


@dataclass(frozen=True)
class Injection:
    """A fault that actually fired, for tests asserting the chaos layer
    did its job (visible only in the process that executed the send)."""

    kind: str
    hop: int
    seq: int


_INJECTIONS: list = []
_ILOCK = threading.Lock()


def _note_injection(kind: str, hop: int, seq: int) -> None:
    with _ILOCK:
        _INJECTIONS.append(Injection(kind, hop, seq))


def drain_injections() -> list:
    with _ILOCK:
        out = list(_INJECTIONS)
        _INJECTIONS.clear()
    return out


# --------------------------------------------------------------------------- #
# ChaosChannel — send-side fault injection by composition
# --------------------------------------------------------------------------- #
class ChaosChannel:
    """Wraps a channel's send side and applies its hop's scripted faults.

    Layering: the engine wraps ``maybe_chaos(maybe_sanitize(chan))`` — the
    chaos wrapper sits *outside* the sanitizer so honest traffic is still
    ledgered, while injected wire damage (duplicate frames, corrupt
    headers) goes through ``_raw`` — the innermost transport — bypassing
    the sanitizer's tx checks.  That models a fault below the observation
    point: the *receiver* (wire-seq dedup, kind-range check) has to cope,
    and a clean recovery leaves ``drain_violations()`` empty on the
    orchestrator.

    Only BATCH frames advance the fault seq counter, so plans target batch
    indices regardless of interleaved control tokens.
    """

    def __init__(self, inner, fired: set | None = None):
        self._inner = inner
        self._events = inner.hop.faults.channel_events(inner.hop.index)
        # events that already fired: shared across channel rebuilds (the
        # engine passes one set per pipeline), so a recovery's replayed
        # batches are never re-perturbed by the fault that killed them
        self._fired = fired if fired is not None else set()
        self._seq = 0              # BATCH frames sent through this end
        self._down_until = 0.0     # link-flap outage window (monotonic)

    # -- identity ----------------------------------------------------------
    @property
    def hop(self):
        return self._inner.hop

    @property
    def epoch(self):
        return self._inner.epoch

    @epoch.setter
    def epoch(self, value):
        self._inner.epoch = value

    @property
    def _raw(self):
        """The innermost transport channel (below any sanitizer)."""
        return getattr(self._inner, "_inner", self._inner)

    # -- the perturbed surface --------------------------------------------
    def send(self, payload=None, kind=BATCH):
        now = time.perf_counter()
        if self._down_until > now:          # link still down from a flap
            time.sleep(self._down_until - now)
        if kind != BATCH:
            return self._inner.send(payload, kind=kind)
        seq = self._seq
        self._seq += 1
        events = [ev for ev in self._events.get(seq, ())
                  if ev not in self._fired]
        self._fired.update(events)
        for ev in events:
            _note_injection(ev.kind, ev.hop, seq)
            if ev.kind == "frame-stall":
                time.sleep(ev.arg)
            elif ev.kind == "link-flap":
                self._down_until = time.perf_counter() + ev.arg
                time.sleep(ev.arg)
            elif ev.kind == "frame-drop":
                # The frame "left" the sender but never arrives: burn its
                # wire seq so the receiver sees a gap and fails fast
                # instead of silently misattributing later batches.
                raw = self._raw
                if hasattr(raw, "_tx_seq"):
                    raw._tx_seq += 1
                return None
            elif ev.kind == "header-corrupt":
                # Replace the frame: same payload, out-of-range kind byte.
                return self._send_raw(payload, CORRUPT_KIND)
        out = self._inner.send(payload, kind=kind)
        for ev in events:
            if ev.kind == "frame-dup":
                # Re-send below the sanitizer with the *same* wire seq so
                # the receiver's dedup — not the ledger — has to absorb it.
                self._send_raw(payload, kind, dup=True)
        return out

    def _send_raw(self, payload, kind, dup=False):
        raw = self._raw
        try:
            return raw.send(payload, kind=kind, _dup=dup)
        except TypeError:
            # Transport without wire-seq support (emulated/queue): plain
            # resend — the receiver sees a genuine duplicate.
            return raw.send(payload, kind=kind)

    def recv(self, timeout=None):
        return self._inner.recv(timeout)

    # -- delegated surface (mirrors SanitizedChannel) ----------------------
    def split(self):
        tx, rx = self._inner.split()
        out = ChaosChannel(tx, fired=self._fired)
        out._seq = self._seq
        return out, rx

    def reset_stream(self):
        self._inner.reset_stream()

    def set_codec(self, codec) -> None:
        self._inner.set_codec(codec)

    def close(self) -> None:
        self._inner.close()

    def reap(self) -> None:
        self._inner.reap()

    def drain_records(self):
        return self._inner.drain_records()

    def drain_observations(self):
        return self._inner.drain_observations()

    def __getattr__(self, name):
        inner = self.__dict__.get("_inner")
        if inner is None:
            raise AttributeError(name)
        return getattr(inner, name)

    def __getstate__(self):
        return dict(self.__dict__)

    def __setstate__(self, state):
        self.__dict__.update(state)

    def __repr__(self):
        return f"ChaosChannel({self._inner!r})"


def maybe_chaos(chan, fired: set | None = None):
    """Wrap ``chan`` in a :class:`ChaosChannel` iff its hop carries a
    fault plan with frame-level events for that hop.  Worker-kill events
    are the supervisor's job and never cause wrapping.  ``fired`` is the
    engine's per-pipeline set of already-executed events; sharing it
    across channel rebuilds keeps recovery replays unperturbed.
    """
    plan = getattr(chan.hop, "faults", None)
    if plan is None or isinstance(chan, ChaosChannel):
        return chan
    if not plan.channel_events(chan.hop.index):
        return chan
    return ChaosChannel(chan, fired=fired)
