"""Multi-pod pipeline parallelism — ParetoPipe's split, scaled to pods.

The ``pod`` mesh axis is the pipeline axis: the partitioner
(``repro.core``) assigns a contiguous layer range to each pod (cuts may
be *uneven* — that is the paper's entire point), activations cross pods
over DCN via ``lax.ppermute`` inside a partial-manual ``shard_map``
(manual over ``pod``; ``data``/``model`` stay GSPMD-auto inside each
stage), and training uses GPipe microbatching so the per-step bubble is
(K-1)/(M+K-1).

Uneven stages: per-stage layer stacks are padded to the max stage depth;
pad layers compute-then-passthrough (``where(li < count, y, x)``) so the
program stays SPMD-uniform.  The same repacking implements *elastic*
re-splits: checkpoints store the canonical (L, ...) stacked layout and
``repack_params`` reshapes to any cut vector on load.

Schedule (train, K stages, M microbatches, T = M+K-1 ticks):
  tick t: every pod applies its stage to its buffer; results ppermute to
  the next pod; pod 0 injects microbatch t+1.  Output microbatches are
  collected from the last pod (out_specs P('pod') + host-side slice) —
  exactly Alg. 1's worker→orchestrator return, at pod scale.

Relation to the hop Transport API (``runtime.transport``): here the
"transport" is the ``ppermute`` collective itself — XLA owns the wire,
so per-hop cost is modeled by the DCN ``Link`` in the pod scenarios
rather than recorded per transfer.  Folding these collectives in as a
registered transport (so pod hops emit ``TransferRecord``s too) is the
ROADMAP's "DCN at pod scale" follow-on.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..models import lm
from ..models.common import Builder, cross_entropy, embed_lookup, lm_logits
from ..sharding.api import shard
from ..optim import OptConfig, apply_gradients


def _shard_map(f, mesh, in_specs, out_specs, axis_names):
    """Partial-manual shard_map across jax versions: jax>=0.6 spells it
    jax.shard_map(axis_names=..., check_vma=...); older releases have
    jax.experimental.shard_map with auto=<complement> / check_rep=."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=set(axis_names),
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    auto = frozenset(mesh.axis_names) - set(axis_names)
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False, auto=auto)


# --------------------------------------------------------------------------- #
# Stage layout / param repacking
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class PipelineConfig:
    n_stages: int
    microbatches: int
    cuts: tuple[int, ...]            # interior layer cuts, len = n_stages-1

    @staticmethod
    def even(n_layers: int, n_stages: int, microbatches: int) -> "PipelineConfig":
        base = n_layers // n_stages
        rem = n_layers % n_stages
        counts = [base + (1 if i < rem else 0) for i in range(n_stages)]
        cuts = tuple(np.cumsum(counts)[:-1].tolist())
        return PipelineConfig(n_stages, microbatches, cuts)

    def layout(self, n_layers: int):
        """→ (starts (K,), counts (K,), l_max)."""
        bounds = (0, *self.cuts, n_layers)
        starts = np.array(bounds[:-1])
        counts = np.diff(bounds)
        if (counts < 0).any():
            raise ValueError(f"bad cuts {self.cuts}")
        return starts, counts, int(counts.max())


class PipelineBuilder(Builder):
    """Declares layer leaves in (n_stages, l_max, ...) layout."""

    def __init__(self, base: Builder, pcfg: PipelineConfig, n_layers: int):
        self.base, self.pcfg = base, pcfg
        _, _, self.l_max = pcfg.layout(n_layers)
        self.dtype = base.dtype

    def leaf(self, path, shape, axes, *, init="normal", scale=None, dtype=None):
        import math
        if init == "normal" and scale is None:
            fan_in = shape[0] if len(shape) >= 2 else shape[-1]
            scale = 1.0 / math.sqrt(max(fan_in, 1))
        if callable(init):
            orig = init
            init = lambda k, s, d: jnp.broadcast_to(orig(k, s[2:], d), s)
        return self.base.leaf(path, (self.pcfg.n_stages, self.l_max, *shape),
                              ("stage", "layers", *axes), init=init,
                              scale=scale, dtype=dtype)


def build_pipeline_params(cfg, b: Builder, pcfg: PipelineConfig) -> dict:
    """Same structure as lm.build_params but layers in pipeline layout."""
    from ..models.common import embed_params
    from ..models.lm import _attn_block_params, _norm_params, layer_params
    embed, head = embed_params(b, cfg)
    params: dict = {"embed": embed,
                    "final_norm": _norm_params(b, "final_norm", cfg.d_model,
                                               cfg.family == "encdec")}
    if head is not None:
        params["lm_head"] = head
    if cfg.family == "encdec":
        # encoder stays replicated (small); decoder layers are pipelined
        from ..models.lm import StackedBuilder
        enc = StackedBuilder(b, cfg.n_enc_layers)
        params["enc_layers"] = _attn_block_params(enc, cfg, "enc",
                                                  bias_norm=True)
        params["enc_final_norm"] = _norm_params(b, "enc_final_norm",
                                                cfg.d_model, True)
        from ..models.attention import attn_params
        pb = PipelineBuilder(b, pcfg, cfg.n_layers)
        params["dec_layers"] = {
            **_attn_block_params(pb, cfg, "dec", bias_norm=True),
            "ln_x": _norm_params(pb, "dec.ln_x", cfg.d_model, True),
            "xattn": attn_params(pb, cfg, "dec.xattn")}
        return params
    pb = PipelineBuilder(b, pcfg, cfg.n_layers)
    params["layers"] = layer_params(cfg, pb)
    if cfg.family == "hybrid":
        params["shared"] = _attn_block_params(b, cfg, "shared")
    return params


def repack_params(stacked_layers, pcfg: PipelineConfig, n_layers: int):
    """(L, ...) canonical → (K, l_max, ...) pipeline layout (zero-padded)."""
    starts, counts, l_max = pcfg.layout(n_layers)

    def repack(leaf):
        out = jnp.zeros((pcfg.n_stages, l_max, *leaf.shape[1:]), leaf.dtype)
        for s in range(pcfg.n_stages):
            blk = leaf[starts[s]:starts[s] + counts[s]]
            out = out.at[s, :counts[s]].set(blk)
        return out
    return jax.tree.map(repack, stacked_layers)


def unpack_params(pipeline_layers, pcfg: PipelineConfig, n_layers: int):
    """Inverse of repack_params (for elastic resharding / checkpoints)."""
    starts, counts, _ = pcfg.layout(n_layers)

    def unpack(leaf):
        parts = [leaf[s, :counts[s]] for s in range(pcfg.n_stages)]
        return jnp.concatenate(parts, axis=0)
    return jax.tree.map(unpack, pipeline_layers)


# --------------------------------------------------------------------------- #
# Per-stage layer application (generic across families)
# --------------------------------------------------------------------------- #
def _layer_fn_train(cfg, p_i, x, positions, gidx, shared, enc_hidden):
    from ..models.lm import _attn_mlp_block, _moe_block, _ssm_block, _dec_layer
    if cfg.family in ("dense", "vlm"):
        y, _ = _attn_mlp_block(cfg, p_i, x, positions)
        return y
    if cfg.family == "moe":
        y, _, _ = _moe_block(cfg, p_i, x, positions)   # aux dropped (note)
        return y
    if cfg.family == "ssm":
        y, _ = _ssm_block(cfg, p_i, x)
        return y
    if cfg.family == "hybrid":
        def with_attn(t):
            y, _ = _attn_mlp_block(cfg, shared, t, positions)
            return y
        x = jax.lax.cond(gidx % cfg.shared_attn_every == 0, with_attn,
                         lambda t: t, x)
        y, _ = _ssm_block(cfg, p_i, x)
        return y
    if cfg.family == "encdec":
        y, _, _ = _dec_layer(cfg, p_i, x, enc_hidden, positions)
        return y
    raise ValueError(cfg.family)


def _stage_apply(cfg, stage_layers, x, positions, start, count, shared,
                 enc_hidden, l_max):
    """Run this pod's layer slice (padded to l_max) on x."""
    from ..models.lm import _shard_residual

    def body(c, xs):
        p_i, li = xs
        c = _shard_residual(c, cfg)
        y = _layer_fn_train(cfg, p_i, c, positions, start + li, shared,
                            enc_hidden)
        c = jnp.where(li < count, y, c)
        return _shard_residual(c, cfg), None
    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn, x, (stage_layers, jnp.arange(l_max)))
    return x


# --------------------------------------------------------------------------- #
# Pipelined train step
# --------------------------------------------------------------------------- #
def make_pipeline_train_step(cfg, pcfg: PipelineConfig, opt: OptConfig,
                             mesh):
    K, M = pcfg.n_stages, pcfg.microbatches
    starts_np, counts_np, l_max = pcfg.layout(cfg.n_layers)
    T = M + K - 1
    perm = [(p, p + 1) for p in range(K - 1)]

    def loss_fn(params, batch):
        # ---- embedding / frontend (replicated across pods, cheap) ----- #
        inputs = {k: v for k, v in batch.items() if k != "targets"}
        enc_hidden = None
        if cfg.family == "encdec":
            enc_hidden = lm.encode(cfg, params, inputs["frames"])
            x = embed_lookup(params["embed"]["table"], inputs["tokens"])
        else:
            x = lm.embed_inputs(cfg, params, inputs)
        B, S, D = x.shape
        assert B % M == 0, f"batch {B} % microbatches {M}"
        mb = B // M
        positions = jnp.arange(S)
        x_mb = x.reshape(M, mb, S, D)

        layers = params["dec_layers"] if cfg.family == "encdec" \
            else params["layers"]
        starts = jnp.asarray(starts_np)
        counts = jnp.asarray(counts_np)
        dtype = x.dtype

        # Pod-replicated tensors enter the shard_map as fp32: JAX psums
        # their cotangents over the manual 'pod' axis in the boundary
        # dtype, and a bf16 all-reduce trips an XLA:CPU AllReducePromotion
        # crash (add+copy reduction).  fp32 at the boundary sidesteps it
        # and is also numerically safer for gradient accumulation.
        x_mb32 = x_mb.astype(jnp.float32)
        enc_mb32 = None
        if enc_hidden is not None:
            F = enc_hidden.shape[1]
            enc_mb32 = enc_hidden.reshape(M, mb, F, D).astype(jnp.float32)
        shared32 = jax.tree.map(
            lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
            params.get("shared"))

        def pipeline(stage_layers, x_mb32, enc_mb32, shared32):
            sid = jax.lax.axis_index("pod")
            stage_layers = jax.tree.map(lambda l: l[0], stage_layers)
            start, count = starts[sid], counts[sid]
            x_mb = x_mb32.astype(dtype)
            shared = jax.tree.map(
                lambda a: a.astype(dtype) if a.dtype == jnp.float32
                and dtype != jnp.float32 else a, shared32) \
                if shared32 is not None else None

            def tick(buf, t):
                buf = shard(buf, "batch", "seq", "embed")
                enc_i = None
                if enc_mb32 is not None:
                    # pod `sid` is processing microbatch (t - sid)
                    mi = jnp.clip(t - sid, 0, M - 1)
                    enc_i = jax.lax.dynamic_index_in_dim(
                        enc_mb32, mi, 0, keepdims=False).astype(dtype)
                y = _stage_apply(cfg, stage_layers, buf, positions, start,
                                 count, shared, enc_i, l_max)
                nxt = jax.lax.ppermute(y, "pod", perm) if K > 1 else y
                idx = jnp.minimum(t + 1, M - 1)
                inj = jax.lax.dynamic_index_in_dim(x_mb, idx, 0,
                                                   keepdims=False)
                buf = jnp.where(sid == 0, inj, nxt)
                return buf, y

            buf0 = jnp.where(sid == 0, x_mb[0], jnp.zeros((mb, S, D), dtype))
            _, ys = jax.lax.scan(tick, buf0, jnp.arange(T))
            return ys[None]                       # (1, T, mb, S, D) per pod

        ys = _shard_map(
            pipeline, mesh=mesh,
            in_specs=(P("pod"), P(), P(), P()), out_specs=P("pod"),
            axis_names={"pod"},
        )(layers, x_mb32, enc_mb32, shared32)
        # finished microbatches come off the last pod at ticks K-1 .. T-1
        out = ys[K - 1][K - 1:]                    # (M, mb, S, D)
        h = out.reshape(B, S, D)
        h = lm.final_hidden(cfg, params, h)
        from ..models.common import chunked_cross_entropy
        ce = chunked_cross_entropy(h, params["embed"],
                                   params.get("lm_head"),
                                   batch["targets"], cfg.ce_chunk)
        return ce, {"ce": ce}

    def train_step(state, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch), has_aux=True)(state["params"])
        new_params, opt_state, om = apply_gradients(state["params"], grads,
                                                    state["opt"], opt)
        return ({"params": new_params, "opt": opt_state,
                 "step": state["step"] + 1},
                {"loss": loss, **parts, **om})

    return train_step


# --------------------------------------------------------------------------- #
# Pipelined serving steps (prefill / decode)
# --------------------------------------------------------------------------- #
def make_pipeline_prefill_step(cfg, pcfg: PipelineConfig, mesh,
                               cache_len: int | None = None):
    """Single-shot prefill: the request batch flows stage→stage (K ticks);
    each stage fills its local KV/SSM cache slice.  Returns the pipeline-
    layout cache: leaves (K, l_max, B, ...)."""
    K = pcfg.n_stages
    starts_np, counts_np, l_max = pcfg.layout(cfg.n_layers)
    perm = [(p, p + 1) for p in range(K - 1)]

    def prefill(params, inputs):
        enc_hidden = None
        if cfg.family == "encdec":
            enc_hidden = lm.encode(cfg, params, inputs["frames"])
            x = embed_lookup(params["embed"]["table"], inputs["tokens"])
        else:
            x = lm.embed_inputs(cfg, params, inputs)
        B, S, D = x.shape
        positions = jnp.arange(S)
        clen = cache_len or S
        layers = params["dec_layers"] if cfg.family == "encdec" \
            else params["layers"]
        shared = params.get("shared")
        starts = jnp.asarray(starts_np)
        counts = jnp.asarray(counts_np)

        def pipeline(stage_layers, x):
            sid = jax.lax.axis_index("pod")
            stage_layers = jax.tree.map(lambda l: l[0], stage_layers)
            start, count = starts[sid], counts[sid]

            def tick(carry, t):
                buf, cache = carry
                y, new_cache = _stage_prefill(cfg, stage_layers, buf,
                                              positions, start, count,
                                              shared, enc_hidden, l_max, clen)
                # commit this stage's cache only on its own tick (the tick
                # when its buffer holds real data: t == stage id)
                cache = jax.tree.map(
                    lambda old, new: jnp.where(t == sid, new, old),
                    cache, new_cache)
                nxt = jax.lax.ppermute(y, "pod", perm) if K > 1 else y
                return (nxt, cache), y

            cache0 = _empty_stage_cache(cfg, l_max, B, clen, x.dtype)
            (_, cache), ys = jax.lax.scan(tick, (x, cache0), jnp.arange(K))
            last = ys[K - 1]
            return jax.tree.map(lambda c: c[None], (last, cache))

        last, cache = _shard_map(
            pipeline, mesh=mesh, in_specs=(P("pod"), P()),
            out_specs=P("pod"), axis_names={"pod"},
        )(layers, x)
        h = lm.final_hidden(cfg, params, last[K - 1])
        logits = lm_logits(h[:, -1:], params["embed"], params.get("lm_head"))
        cache = dict(cache, pos=jnp.int32(S))
        return jnp.argmax(logits, -1).astype(jnp.int32), cache
    return prefill


def make_pipeline_decode_step(cfg, pcfg: PipelineConfig, mesh):
    """One decode tick through the pod pipeline: the (B,1) token embeds on
    pod 0, flows K stages, logits emerge from the last pod.  Cache leaves
    are pipeline-layout (K, l_max, B, ...) sharded P('pod')."""
    K = pcfg.n_stages
    starts_np, counts_np, l_max = pcfg.layout(cfg.n_layers)
    perm = [(p, p + 1) for p in range(K - 1)]

    def decode(params, token, cache):
        pos = cache["pos"]
        x = embed_lookup(params["embed"]["table"], token)
        layers = params["dec_layers"] if cfg.family == "encdec" \
            else params["layers"]
        shared = params.get("shared")
        starts = jnp.asarray(starts_np)
        counts = jnp.asarray(counts_np)
        kv = {k: v for k, v in cache.items() if k != "pos"}

        def pipeline(stage_layers, kv, x):
            sid = jax.lax.axis_index("pod")
            stage_layers = jax.tree.map(lambda l: l[0], stage_layers)
            kv = jax.tree.map(lambda l: l[0], kv)
            start, count = starts[sid], counts[sid]

            def tick(carry, t):
                buf, cache = carry
                y, new_cache = _stage_decode(cfg, stage_layers, buf, cache,
                                             pos, start, count, shared, l_max)
                cache = jax.tree.map(
                    lambda old, new: jnp.where(t == sid, new, old),
                    cache, new_cache)
                nxt = jax.lax.ppermute(y, "pod", perm) if K > 1 else y
                return (nxt, cache), y

            (_, kv), ys = jax.lax.scan(tick, (x, kv), jnp.arange(K))
            return jax.tree.map(lambda c: c[None], (ys[K - 1], kv))

        last, kv = _shard_map(
            pipeline, mesh=mesh, in_specs=(P("pod"), P("pod"), P()),
            out_specs=P("pod"), axis_names={"pod"},
        )(layers, kv, x)
        h = lm.final_hidden(cfg, params, last[K - 1])
        logits = lm_logits(h, params["embed"], params.get("lm_head"))
        new_cache = dict(kv, pos=pos + 1)
        return jnp.argmax(logits, -1).astype(jnp.int32), new_cache
    return decode


def _stage_decode(cfg, stage_layers, x, cache, pos, start, count, shared,
                  l_max):
    from ..models.lm import _attn_mlp_block, _moe_block, _ssm_block
    positions = pos[None]

    def body(c, xs):
        p_i, cc, li = xs
        gidx = start + li
        if cfg.family in ("dense", "vlm", "moe", "encdec"):
            kvp = (cc["k"], cc["v"])
            if cfg.family == "moe":
                y, (k, v), _ = _moe_block(cfg, p_i, c, positions,
                                          kv_cache=kvp, pos=pos)
            elif cfg.family == "encdec":
                from ..models.lm import _dec_layer
                y, (k, v), _ = _dec_layer(cfg, p_i, c, (cc["ck"], cc["cv"]),
                                          positions, kv_cache=kvp, pos=pos)
            else:
                y, (k, v) = _attn_mlp_block(cfg, p_i, c, positions,
                                            kv_cache=kvp, pos=pos)
            cache_i = dict(cc, k=k, v=v)
        elif cfg.family == "ssm":
            y, nc = _ssm_block(cfg, p_i, c, cache=cc)
            cache_i = nc
        else:
            raise ValueError(cfg.family)
        y = jnp.where(li < count, y, c)
        # pad layers must not clobber their (zero) cache rows — harmless
        return y, cache_i

    if cfg.family == "hybrid":
        return _stage_decode_hybrid(cfg, stage_layers, x, cache, pos, start,
                                    count, shared, l_max)
    x, caches = jax.lax.scan(body, x, (stage_layers, cache,
                                       jnp.arange(l_max)))
    return x, caches


def _stage_decode_hybrid(cfg, stage_layers, x, cache, pos, start, count,
                         shared, l_max):
    from ..models.lm import _attn_mlp_block, _ssm_block
    positions = pos[None]
    every = cfg.shared_attn_every
    ak, av = cache["ak"], cache["av"]
    ssm_cache = {k: cache[k] for k in ("conv", "h")}

    def body(carry, xs):
        c, ak, av = carry
        p_i, cc, li = xs
        gidx = start + li
        slot = gidx // every - start // every

        def with_attn(args):
            c, ak, av = args
            k_i = jax.lax.dynamic_index_in_dim(ak, slot, 0, keepdims=False)
            v_i = jax.lax.dynamic_index_in_dim(av, slot, 0, keepdims=False)
            y, (k, v) = _attn_mlp_block(cfg, shared, c, positions,
                                        kv_cache=(k_i, v_i), pos=pos)
            ak = jax.lax.dynamic_update_slice(ak, k[None], (slot, 0, 0, 0, 0))
            av = jax.lax.dynamic_update_slice(av, v[None], (slot, 0, 0, 0, 0))
            return y, ak, av
        c2, ak, av = jax.lax.cond((gidx % every == 0) & (li < count),
                                  with_attn, lambda a: a, (c, ak, av))
        y, nc = _ssm_block(cfg, p_i, c2, cache=cc)
        y = jnp.where(li < count, y, c)
        return (y, ak, av), nc

    (x, ak, av), new_ssm = jax.lax.scan(body, (x, ak, av),
                                        (stage_layers, ssm_cache,
                                         jnp.arange(l_max)))
    return x, {**new_ssm, "ak": ak, "av": av}


def _empty_stage_cache(cfg, l_max, B, clen, dtype):
    KVh, hd = cfg.n_kv_heads, cfg.hd
    if cfg.family == "encdec":
        z = jnp.zeros((l_max, B, clen, KVh, hd), dtype)
        zc = jnp.zeros((l_max, B, cfg.enc_frames, KVh, hd), dtype)
        return {"k": z, "v": z, "ck": zc, "cv": zc}
    if cfg.family in ("dense", "vlm", "moe"):
        z = jnp.zeros((l_max, B, clen, KVh, hd), dtype)
        return {"k": z, "v": z}
    if cfg.family == "ssm":
        return {"conv": jnp.zeros((l_max, B, cfg.ssm_conv - 1, cfg.d_inner), dtype),
                "h": jnp.zeros((l_max, B, cfg.d_inner, cfg.ssm_state), jnp.float32)}
    if cfg.family == "hybrid":
        d_xbc = cfg.d_inner + 2 * cfg.ssm_state
        ns = n_attn_slots(cfg, l_max)
        return {"conv": jnp.zeros((l_max, B, cfg.ssm_conv - 1, d_xbc), dtype),
                "h": jnp.zeros((l_max, B, cfg.ssm_heads, cfg.ssm_head_dim,
                                cfg.ssm_state), jnp.float32),
                "ak": jnp.zeros((ns, B, clen, KVh, hd), dtype),
                "av": jnp.zeros((ns, B, clen, KVh, hd), dtype)}
    raise ValueError(cfg.family)


def n_attn_slots(cfg, l_max: int) -> int:
    """Shared-attention KV slots per pipeline stage (slot-compressed: one
    per application site, not one per layer)."""
    return l_max // cfg.shared_attn_every + 2


def _stage_prefill(cfg, stage_layers, x, positions, start, count, shared,
                   enc_hidden, l_max, clen):
    """Apply the stage's layers, returning per-layer caches (padded)."""
    from ..models.lm import _attn_mlp_block, _moe_block, _ssm_block, _dec_layer
    B, S, D = x.shape

    def pad_kv(k, v):
        pad = clen - S
        if pad:
            z = jnp.zeros((B, pad, *k.shape[2:]), k.dtype)
            k, v = jnp.concatenate([k, z], 1), jnp.concatenate([v, z], 1)
        return k, v

    def body(c, xs):
        p_i, li = xs
        gidx = start + li
        if cfg.family in ("dense", "vlm"):
            y, (k, v) = _attn_mlp_block(cfg, p_i, c, positions)
            cache_i = dict(zip(("k", "v"), pad_kv(k, v)))
        elif cfg.family == "moe":
            y, (k, v), _ = _moe_block(cfg, p_i, c, positions)
            cache_i = dict(zip(("k", "v"), pad_kv(k, v)))
        elif cfg.family == "encdec":
            y, (k, v), (ck, cv) = _dec_layer(cfg, p_i, c, enc_hidden, positions)
            k, v = pad_kv(k, v)
            cache_i = {"k": k, "v": v, "ck": ck, "cv": cv}
        elif cfg.family == "ssm":
            y, cc = _ssm_block(cfg, p_i, c)
            cache_i = cc
        else:
            raise ValueError(cfg.family)
        y = jnp.where(li < count, y, c)
        return y, cache_i

    if cfg.family == "hybrid":
        return _stage_prefill_hybrid(cfg, stage_layers, x, positions, start,
                                     count, shared, l_max, clen)
    x, caches = jax.lax.scan(body, x, (stage_layers, jnp.arange(l_max)))
    return x, caches


def _stage_prefill_hybrid(cfg, stage_layers, x, positions, start, count,
                          shared, l_max, clen):
    """Hybrid stage prefill with slot-compressed shared-attention caches:
    ak/av hold one (B, clen, KV, hd) slot per application site in this
    stage; ssm caches stay per-layer via scan ys."""
    from ..models.lm import _attn_mlp_block, _ssm_block
    B, S, D = x.shape
    ns = n_attn_slots(cfg, l_max)
    every = cfg.shared_attn_every
    pad = clen - S
    ak = jnp.zeros((ns, B, clen, cfg.n_kv_heads, cfg.hd), x.dtype)
    av = jnp.zeros_like(ak)

    def body(carry, xs):
        c, ak, av = carry
        p_i, li = xs
        gidx = start + li
        slot = gidx // every - start // every

        def with_attn(args):
            c, ak, av = args
            y, (k, v) = _attn_mlp_block(cfg, shared, c, positions)
            if pad:
                z = jnp.zeros((B, pad, *k.shape[2:]), k.dtype)
                k = jnp.concatenate([k, z], 1)
                v = jnp.concatenate([v, z], 1)
            ak = jax.lax.dynamic_update_slice(ak, k[None], (slot, 0, 0, 0, 0))
            av = jax.lax.dynamic_update_slice(av, v[None], (slot, 0, 0, 0, 0))
            return y, ak, av
        c2, ak, av = jax.lax.cond((gidx % every == 0) & (li < count),
                                  with_attn, lambda a: a, (c, ak, av))
        y, cc = _ssm_block(cfg, p_i, c2)
        y = jnp.where(li < count, y, c)
        return (y, ak, av), cc

    (x, ak, av), caches = jax.lax.scan(body, (x, ak, av),
                                       (stage_layers, jnp.arange(l_max)))
    return x, {**caches, "ak": ak, "av": av}
