"""Deterministic synthetic data pipeline with O(1) resumable state.

Every batch is a pure function of (seed, step) via ``jax.random.fold_in``
— the iterator state checkpointed for restart is a single integer, and a
restarted run consumes the *identical* token stream (the crash-restart
integration test asserts bit-equal losses).  On a real multi-host fleet
each host generates only its data-shard (same fold_in, host-offset
stream); here the full batch is generated and device_put with the batch
sharding.

Also provides the ShapeDtypeStruct ``input_specs`` used by the dry-run —
built from the same shape logic, so the dry-run and the real pipeline
can never diverge.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..models.config import ArchConfig
from ..sharding.api import MeshContext


@dataclass(frozen=True)
class DataConfig:
    batch: int
    seq: int
    seed: int = 0


def _token_shapes(cfg: ArchConfig, batch: int, seq: int) -> dict:
    """Logical input shapes for one *training/prefill* batch."""
    shapes: dict[str, tuple] = {}
    if cfg.family == "vlm":
        P = cfg.n_patches
        shapes["tokens"] = (batch, seq - P)
        shapes["img"] = (batch, P, cfg.d_model)
    elif cfg.family == "encdec":
        shapes["tokens"] = (batch, seq)
        shapes["frames"] = (batch, cfg.enc_frames, cfg.d_model)
    else:
        shapes["tokens"] = (batch, seq)
    return shapes


def _axes_for(name: str) -> tuple:
    return {"tokens": ("batch", "seq"),
            "targets": ("batch", "seq"),
            "img": ("batch", "patches", "embed"),
            "frames": ("batch", "frames", "embed")}[name]


class SyntheticLM:
    """Synthetic next-token data; batches are functions of the step."""

    def __init__(self, cfg: ArchConfig, data: DataConfig,
                 ctx: MeshContext | None = None):
        self.cfg, self.data, self.ctx = cfg, data, ctx
        self.step = 0

    # -- checkpointable state ------------------------------------------- #
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.data.seed}

    def load_state_dict(self, d: dict):
        self.step = int(d["step"])

    # ------------------------------------------------------------------- #
    def batch_at(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.data.seed), step)
        shapes = _token_shapes(self.cfg, self.data.batch, self.data.seq)
        out = {}
        for i, (name, shape) in enumerate(sorted(shapes.items())):
            k = jax.random.fold_in(key, i)
            if name in ("img", "frames"):
                out[name] = jax.random.normal(k, shape, jnp.float32) * 0.02
            else:
                out[name] = jax.random.randint(k, shape, 0, self.cfg.vocab,
                                               jnp.int32)
        # next-token targets over the full logits sequence
        tgt_key = jax.random.fold_in(key, 100)
        out["targets"] = jax.random.randint(
            tgt_key, (self.data.batch, self.data.seq), 0, self.cfg.vocab,
            jnp.int32)
        if self.cfg.family != "vlm":
            # make it a real LM task: targets = tokens shifted left
            t = out["tokens"]
            out["targets"] = jnp.concatenate(
                [t[:, 1:], out["targets"][:, :1]], axis=1)
        if self.ctx is not None:
            out = {k: jax.device_put(v, self.ctx.sharding(_axes_for(k), v.shape))
                   for k, v in out.items()}
        return out

    def __next__(self) -> dict:
        b = self.batch_at(self.step)
        self.step += 1
        return b


# --------------------------------------------------------------------------- #
# Dry-run input specs
# --------------------------------------------------------------------------- #
def make_batch_specs(cfg: ArchConfig, batch: int, seq: int,
                     ctx: MeshContext | None, kind: str = "train") -> dict:
    """ShapeDtypeStructs for a train/prefill batch (decode cache specs
    live in ``repro.launch.specs``)."""
    shapes = dict(_token_shapes(cfg, batch, seq))
    if kind == "train":
        shapes["targets"] = (batch, seq)
    out = {}
    for name, shape in shapes.items():
        dtype = jnp.float32 if name in ("img", "frames") else jnp.int32
        if ctx is None:
            out[name] = jax.ShapeDtypeStruct(shape, dtype)
        else:
            out[name] = jax.ShapeDtypeStruct(
                shape, dtype, sharding=ctx.sharding(_axes_for(name), shape))
    return out
