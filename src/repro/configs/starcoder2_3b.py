"""starcoder2-3b [dense] — GQA kv=2, RoPE, GELU MLP. [arXiv:2402.19173]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab=49152, head_dim=128,
    gated_mlp=False, rope_theta=1e5,
)
