"""Architecture registry: the 10 assigned archs + reduced smoke variants
+ the paper-scale pipeline demo config.

``get(name)`` returns the published full config (dry-run only — params
are never materialized at full scale on this host); ``reduced(name)``
returns a small same-family config for CPU smoke tests and examples.
"""
from __future__ import annotations

import dataclasses

from ..models.config import ArchConfig
from . import (falcon_mamba_7b, granite_20b, phi3_5_moe_42b_a6_6b,
               phi_3_vision_4_2b, qwen3_1_7b, qwen3_moe_30b_a3b,
               starcoder2_3b, starcoder2_7b, whisper_small, zamba2_7b)

REGISTRY: dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG for m in [
    phi_3_vision_4_2b, falcon_mamba_7b, starcoder2_3b, qwen3_1_7b,
    granite_20b, starcoder2_7b, whisper_small, qwen3_moe_30b_a3b,
    phi3_5_moe_42b_a6_6b, zamba2_7b,
]}

ARCH_NAMES = tuple(REGISTRY)


def get(name: str) -> ArchConfig:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}") from None


def reduced(name: str) -> ArchConfig:
    """Tiny same-family config: same code paths, laptop-scale shapes."""
    c = get(name)
    kw = dict(
        name=c.name + "-reduced", n_layers=2, d_model=64, vocab=256,
        d_ff=128 if c.d_ff else 0, head_dim=16, moe_group_size=64,
        attn_chunk=32, ssm_chunk=16, dtype="float32", remat=False,
    )
    if c.family == "ssm":
        kw.update(n_heads=0, n_kv_heads=0, ssm_state=8)
    elif c.family == "hybrid":
        kw.update(n_heads=4, n_kv_heads=4, ssm_state=8, ssm_head_dim=16,
                  shared_attn_every=2, n_layers=4)
    elif c.family == "moe":
        kw.update(n_heads=4, n_kv_heads=2, n_experts=4, top_k=2)
    elif c.family == "encdec":
        kw.update(n_heads=4, n_kv_heads=4, n_enc_layers=2, enc_frames=24)
    elif c.family == "vlm":
        kw.update(n_heads=4, n_kv_heads=4, n_patches=8)
    else:
        kw.update(n_heads=4, n_kv_heads=max(1, min(c.n_kv_heads, 2)))
    return c.replace(**kw)
