"""zamba2-7b [hybrid] — Mamba-2 backbone + one shared attention+MLP block
applied every 6 layers (weights shared across applications; the
published per-application LoRA deltas are omitted — DESIGN.md §4).
[arXiv:2411.15242]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, head_dim=112,
    ssm_state=64, ssm_expand=2, ssm_conv=4, ssm_head_dim=64,
    shared_attn_every=6, gated_mlp=True,
)
