"""whisper-small [audio] — enc-dec backbone; conv frontend is a STUB
(input_specs provide precomputed frame embeddings). [arXiv:2212.04356]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, gated_mlp=False, enc_frames=1500,
    tie_embeddings=True,   # whisper ties decoder embedding ↔ output head
)
