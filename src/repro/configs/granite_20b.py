"""granite-20b [dense] — MQA (kv=1), GELU MLP (GPT-BigCode-style widths
give the published 20B total). [arXiv:2405.04324]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152, head_dim=128,
    gated_mlp=False, rope_theta=1e4,
)
