"""Shard-aware, elastic checkpointing.

Layout (one directory per step):
    step_000042/
      manifest.json     — step, flat tree spec (path → shape/dtype),
                          mesh shape, data-iterator state, pipeline cuts
      arrays.npz        — flat path → host array

On a real multi-host fleet each host writes only its addressable shards
and the manifest records the global sharding (the npz would be one file
per host); on this single-process testbed arrays are gathered to host.
What we *do* implement fully is the part that matters for elasticity:
``load_checkpoint`` reshards every leaf onto the *current* mesh (any
mesh), and canonical (L, …)-stacked layer storage means a run can come
back with a different pipeline cut vector or pod count
(``repack_params``/``unpack_params`` convert layouts on save/load).

Async: ``save_async`` snapshots to host then writes on a background
thread — training continues during the disk write.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


def save_checkpoint(path: str | Path, state, step: int,
                    extra: dict | None = None) -> Path:
    path = Path(path)
    tmp = path.with_name(path.name + ".tmp")
    tmp.mkdir(parents=True, exist_ok=True)
    flat = _flatten(state)
    host = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(tmp / "arrays.npz", **{k.replace("/", "|"): v
                                    for k, v in host.items()})
    manifest = {
        "step": int(step),
        "time": time.time(),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in host.items()},
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if path.exists():
        shutil.rmtree(path)
    tmp.rename(path)                      # atomic publish
    return path


def reshard_tree(tree, specs_tree):
    """device_put every leaf with the sharding carried by ``specs_tree``
    (ShapeDtypeStructs from the builder) — elastic restore onto any mesh."""
    flat_t = _flatten(tree)
    flat_s = _flatten(specs_tree)
    out = {}
    for k, v in flat_t.items():
        spec = flat_s.get(k)
        arr = np.asarray(v)
        if spec is not None and getattr(spec, "sharding", None) is not None:
            out[k] = jax.device_put(arr.astype(spec.dtype), spec.sharding)
        else:
            out[k] = jax.numpy.asarray(arr)
    return _unflatten(out)


def load_checkpoint(path: str | Path, specs_tree=None):
    """→ (state, manifest).  With ``specs_tree`` the state is resharded
    onto the current mesh (and cast to the spec dtypes)."""
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / "arrays.npz") as z:
        flat = {k.replace("|", "/"): z[k] for k in z.files}
    state = _unflatten(flat)
    if specs_tree is not None:
        state = reshard_tree(state, specs_tree)
    return state, manifest


class CheckpointManager:
    """Cadence + retention + async writes + latest-checkpoint discovery."""

    def __init__(self, root: str | Path, every: int = 50, keep: int = 3):
        self.root = Path(root)
        self.every, self.keep = every, keep
        self.root.mkdir(parents=True, exist_ok=True)
        self._thread: threading.Thread | None = None

    def _dir(self, step: int) -> Path:
        return self.root / f"step_{step:08d}"

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.every == 0

    def save(self, state, step: int, extra: dict | None = None,
             block: bool = True):
        self.wait()                               # one writer at a time
        if self._dir(step).exists():
            return                                # already checkpointed
        host = jax.tree.map(np.asarray, state)   # snapshot before async
        def write():
            save_checkpoint(self._dir(step), host, step, extra)
            self._gc()
        if block:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _complete(self) -> list[Path]:
        """Published checkpoints only — a crash mid-write leaves a
        ``step_*.tmp`` dir (no manifest) that must never be restored."""
        return sorted(p for p in self.root.glob("step_*")
                      if not p.name.endswith(".tmp")
                      and (p / "manifest.json").exists())

    def _gc(self):
        for old in self._complete()[:-self.keep]:
            shutil.rmtree(old, ignore_errors=True)
        # torn writes are never restorable; don't let crash/restart
        # cycles hoard them (one writer at a time, and the current
        # write's tmp dir was renamed before _gc runs, so every
        # remaining *.tmp is an orphan)
        for tmp in self.root.glob("step_*.tmp"):
            shutil.rmtree(tmp, ignore_errors=True)

    def latest(self) -> Path | None:
        self.wait()
        ckpts = self._complete()
        return ckpts[-1] if ckpts else None

    def restore(self, specs_tree=None):
        p = self.latest()
        if p is None:
            return None, None
        return load_checkpoint(p, specs_tree)
