from .store import (CheckpointManager, load_checkpoint, save_checkpoint,
                    reshard_tree)
