"""Pareto-front machinery over d-dimensional objective vectors.

Pure functions; used by the partitioner, the benchmarks, and the
scheduler.  An :class:`Objective` names one axis (``latency``,
``throughput``, ``energy``, …) with a per-axis sense (``min``/``max``)
and knows how to read its value off a point.  Points are either

  * objects exposing the objective's attribute (``PipelineMetrics``
    qualifies: ``latency_s``, ``throughput``, ``energy_j``), or
  * plain tuples/lists, read positionally in the order of the active
    objective set — so the legacy ``(lat, thr)`` tuples keep working
    under the default ``(LATENCY, THROUGHPUT)`` pair, and d=3 tests can
    pass ``(lat, thr, energy)``.

Every public function takes ``objectives=None`` meaning the legacy
bi-objective (latency ↓, throughput ↑) pair, so all existing callers
run unchanged; pass ``("latency", "throughput", "energy")`` (names or
``Objective`` instances) for the 3-D front.

Complexity: ``pareto_front`` is the O(n log n) sort-sweep for d=2, a
lexicographic sweep with a staircase (the classic divide-and-conquer
maxima structure flattened into one bisect-maintained envelope) for
d=3, and pairwise O(d·n²) beyond.  ``hypervolume`` is the exact sweep
for d=2 and recursive slicing (HSO) for d≥3.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass
from typing import Callable, Iterable, Sequence, TypeVar, Union

T = TypeVar("T")


# --------------------------------------------------------------------------- #
# Objective protocol
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class Objective:
    """One axis of the objective vector.

    ``attr`` is the attribute read off metric objects; plain tuples are
    read positionally (position in the active objective set).  ``getter``
    overrides attribute access for custom point types.
    """

    name: str
    sense: str                          # "min" | "max"
    attr: str
    getter: Callable | None = None

    def __post_init__(self):
        if self.sense not in ("min", "max"):
            raise ValueError(f"objective {self.name!r}: sense must be "
                             f"'min' or 'max', got {self.sense!r}")

    def value(self, p, position: int | None = None) -> float:
        if isinstance(p, (tuple, list)):
            if position is None:
                raise ValueError("positional read needs the objective's "
                                 "position in the active set")
            return float(p[position])
        if self.getter is not None:
            return float(self.getter(p))
        return float(getattr(p, self.attr))


LATENCY = Objective("latency", "min", "latency_s")
THROUGHPUT = Objective("throughput", "max", "throughput")
ENERGY = Objective("energy", "min", "energy_j")
# the codec axis: predicted end-task fidelity under the partition's
# per-hop wire codecs (product of per-cut top-1 agreements; 1.0 when
# every hop ships uncoded)
ACCURACY = Objective("accuracy", "max", "accuracy")

OBJECTIVES: dict[str, Objective] = {
    o.name: o for o in (LATENCY, THROUGHPUT, ENERGY, ACCURACY)}

#: The paper's original bi-objective pair — the default everywhere.
DEFAULT_OBJECTIVES: tuple[Objective, ...] = (LATENCY, THROUGHPUT)

#: Widening order: ``objectives=d`` (an int) takes the first d axes.
CANONICAL_ORDER: tuple[Objective, ...] = (LATENCY, THROUGHPUT, ENERGY,
                                          ACCURACY)

ObjectiveLike = Union[str, Objective]


def resolve_objectives(
    objectives: Sequence[ObjectiveLike] | int | None = None,
) -> tuple[Objective, ...]:
    """Normalize names/instances to a tuple of Objectives (None = legacy
    (latency, throughput) pair).  An int d selects the first d axes of
    the canonical (latency, throughput, energy, accuracy) order — so
    ``objectives=4`` is the full codec-aware front."""
    if objectives is None:
        return DEFAULT_OBJECTIVES
    if isinstance(objectives, int):
        if not 1 <= objectives <= len(CANONICAL_ORDER):
            raise ValueError(f"objectives={objectives}: int form selects "
                             f"1..{len(CANONICAL_ORDER)} canonical axes")
        return CANONICAL_ORDER[:objectives]
    out: list[Objective] = []
    for o in objectives:
        if isinstance(o, Objective):
            out.append(o)
        elif o in OBJECTIVES:
            out.append(OBJECTIVES[o])
        else:
            raise ValueError(f"unknown objective {o!r}; "
                             f"have {sorted(OBJECTIVES)}")
    if not out:
        raise ValueError("need at least one objective")
    return tuple(out)


def vector(p, objectives: Sequence[ObjectiveLike] | None = None
           ) -> tuple[float, ...]:
    """The point's raw objective vector, in objective order."""
    objs = resolve_objectives(objectives)
    return tuple(o.value(p, i) for i, o in enumerate(objs))


def _key(p, objs: tuple[Objective, ...]) -> tuple[float, ...]:
    """Minimization-convention vector (max axes negated): componentwise
    ``<=`` on keys means 'no worse' on every objective."""
    return tuple(o.value(p, i) if o.sense == "min" else -o.value(p, i)
                 for i, o in enumerate(objs))


def _dominates_key(ka: tuple[float, ...], kb: tuple[float, ...]) -> bool:
    return all(a <= b for a, b in zip(ka, kb)) and \
        any(a < b for a, b in zip(ka, kb))


# --------------------------------------------------------------------------- #
# Dominance and fronts
# --------------------------------------------------------------------------- #
def dominates(a, b, objectives: Sequence[ObjectiveLike] | None = None) -> bool:
    """a dominates b: no worse on every objective, strictly better on one."""
    objs = resolve_objectives(objectives)
    return _dominates_key(_key(a, objs), _key(b, objs))


def pareto_front(points: Sequence[T],
                 objectives: Sequence[ObjectiveLike] | None = None) -> list[T]:
    """Non-dominated subset, sorted by the first objective (best first).

    Duplicate objective vectors keep one representative.  d=2 is the
    O(n log n) sort-sweep; d=3 a lexicographic sweep with a staircase
    envelope (also O(n log n)); higher d falls back to pairwise checks.
    """
    objs = resolve_objectives(objectives)
    if not points:
        return []
    return min_front([(_key(p, objs), p) for p in points])


def min_front(keyed: list[tuple[tuple[float, ...], T]]) -> list[T]:
    """Non-dominated payloads under componentwise-minimization vectors,
    sorted by vector; duplicate vectors keep one payload.  This is the
    kernel shared by ``pareto_front`` and the partitioner's DP label
    pruning (labels are already min-convention vectors there)."""
    if not keyed:
        return []
    keyed = sorted(keyed, key=lambda kp: kp[0])
    d = len(keyed[0][0])
    if d == 1:
        return [keyed[0][1]]
    if d == 2:
        front: list[T] = []
        best1 = float("inf")
        for k, p in keyed:
            if k[1] < best1:
                front.append(p)
                best1 = k[1]
        return front
    if d == 3:
        return _front_3d(keyed)
    return _front_nd(keyed)


def _front_3d(keyed: list[tuple[tuple[float, ...], T]]) -> list[T]:
    """Lexicographic sweep: with points sorted by k0, a point is dominated
    iff some earlier point is ≤ on (k1, k2) — a 2-D staircase query."""
    front: list[T] = []
    stair1: list[float] = []          # k1, ascending
    stair2: list[float] = []          # matching k2, strictly descending
    prev_key: tuple[float, ...] | None = None
    for k, p in keyed:
        if k == prev_key:             # duplicate vector: keep first
            continue
        prev_key = k
        _, k1, k2 = k
        i = bisect.bisect_right(stair1, k1) - 1
        if i >= 0 and stair2[i] <= k2:
            continue                  # dominated (or duplicate cross-k0)
        front.append(p)
        # insert (k1, k2), dropping staircase entries it covers
        j = bisect.bisect_left(stair1, k1)
        hi = j
        while hi < len(stair1) and stair2[hi] >= k2:
            hi += 1
        stair1[j:hi] = [k1]
        stair2[j:hi] = [k2]
    return front


def _front_nd(keyed: list[tuple[tuple[float, ...], T]]) -> list[T]:
    front: list[T] = []
    front_keys: list[tuple[float, ...]] = []
    seen: set[tuple[float, ...]] = set()
    for k, p in keyed:
        if k in seen:
            continue
        seen.add(k)
        # sorted order: only already-accepted points can dominate k
        if any(_dominates_key(fk, k) for fk in front_keys):
            continue
        front.append(p)
        front_keys.append(k)
    return front


def is_on_front(p, points: Iterable,
                objectives: Sequence[ObjectiveLike] | None = None) -> bool:
    objs = resolve_objectives(objectives)
    kp = _key(p, objs)
    return not any(_dominates_key(_key(q, objs), kp) for q in points)


# --------------------------------------------------------------------------- #
# Hypervolume
# --------------------------------------------------------------------------- #
def hypervolume(points: Sequence,
                ref: float | Sequence[float] | None = None,
                objectives: Sequence[ObjectiveLike] | None = None,
                *, ref_latency: float | None = None,
                ref_throughput: float = 0.0) -> float:
    """Hypervolume dominated w.r.t. a reference point — higher is better.

    ``ref`` is the reference vector in objective order (worse than the
    interesting region on every axis: above on min axes, below on max
    axes).  The legacy 2-D-only signature
    ``hypervolume(points, ref_latency, ref_throughput=0.0)`` it replaces
    is still accepted: a scalar ``ref`` (or the ``ref_latency=`` keyword)
    means (latency ↓, throughput ↑) with the throughput reference
    defaulting to 0.

    Raises ``ValueError`` for an invalid reference box: one that no
    point lies strictly inside (e.g. every point's latency above the
    latency reference, or the throughput reference at/above every
    point's throughput — a reference that is not worse than the cloud
    on a max axis).  Individual points outside a valid box still
    contribute nothing.  Empty ``points`` returns 0.0.

    Exact: sort-sweep for d=2, recursive slicing (HSO) for d≥3.
    """
    if ref_latency is not None:
        if ref is not None:
            raise ValueError("pass either ref or ref_latency, not both")
        ref = (ref_latency, ref_throughput)
    elif isinstance(ref, (int, float)):
        # legacy positional forms: (points, ref_lat) and (points, ref_lat,
        # ref_thr) — in the latter the old third positional lands in
        # ``objectives``
        if isinstance(objectives, (int, float)):
            ref = (float(ref), float(objectives))
            objectives = None
        else:
            ref = (float(ref), ref_throughput)
    objs = resolve_objectives(objectives)
    if ref is None or len(ref) != len(objs):
        raise ValueError(f"need a {len(objs)}-dim reference vector")
    if not points:
        return 0.0
    kref = tuple(r if o.sense == "min" else -r for r, o in zip(ref, objs))
    inside = [k for k in (_key(p, objs) for p in points)
              if all(ki < ri for ki, ri in zip(k, kref))]
    if not inside:
        raise ValueError(
            f"invalid reference box {tuple(ref)!r}: no point lies strictly "
            "inside it (the reference must be worse than at least one "
            "point on every objective)")
    # reduce to the non-dominated subset before slicing
    front_keys = _front_nd(sorted((k, k) for k in inside))
    return _hv_min(front_keys, kref)


def _hv_min(keys: list[tuple[float, ...]], ref: tuple[float, ...]) -> float:
    """Exact hypervolume of minimization vectors strictly inside ref."""
    d = len(ref)
    if not keys:
        return 0.0
    if d == 1:
        return ref[0] - min(k[0] for k in keys)
    if d == 2:
        # non-dominated staircase (inputs may be raw projections from the
        # slicing recursion), then sum strips from worst to best k0
        stairs: list[tuple[float, ...]] = []
        best1 = float("inf")
        for k in sorted(set(keys)):
            if k[1] < best1:
                stairs.append(k)
                best1 = k[1]
        hv = 0.0
        prev0 = ref[0]
        for k0, k1 in reversed(stairs):
            hv += (prev0 - k0) * (ref[1] - k1)
            prev0 = k0
        return hv
    # slice on the last axis: between consecutive levels the cross-section
    # is the (d-1)-dim hypervolume of everything at or below the level
    order = sorted(keys, key=lambda k: k[-1])
    hv = 0.0
    for i, k in enumerate(order):
        z_lo = k[-1]
        z_hi = order[i + 1][-1] if i + 1 < len(order) else ref[-1]
        if z_hi > z_lo:
            hv += (z_hi - z_lo) * _hv_min([u[:-1] for u in order[:i + 1]],
                                          ref[:-1])
    return hv


# --------------------------------------------------------------------------- #
# Knee point
# --------------------------------------------------------------------------- #
def knee_point(points: Sequence[T],
               objectives: Sequence[ObjectiveLike] | None = None) -> T | None:
    """The front point with the max normalized Manhattan improvement —
    a pragmatic 'balanced' pick for practitioners (paper Sec. V-A asks
    which split balances the objectives); generalizes to any d by
    summing each axis's normalized goodness over the front's span."""
    objs = resolve_objectives(objectives)
    front = pareto_front(points, objs)
    if not front:
        return None
    cols = list(zip(*(_key(p, objs) for p in front)))
    los = [min(c) for c in cols]
    spans = [(max(c) - lo) or 1.0 for c, lo in zip(cols, los)]

    def score(p) -> float:
        k = _key(p, objs)
        return sum((lo + span - v) / span
                   for v, lo, span in zip(k, los, spans))

    return max(front, key=score)
