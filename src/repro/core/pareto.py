"""Pareto-front machinery over (latency ↓, throughput ↑) points.

Pure functions; used by the partitioner, the benchmarks, and the
scheduler.  Points are any objects exposing ``latency_s`` and
``throughput`` (PipelineMetrics qualifies) or plain ``(lat, thr)``
tuples via the key functions.
"""
from __future__ import annotations

from typing import Callable, Iterable, Sequence, TypeVar

T = TypeVar("T")


def _lat(p) -> float:
    return p[0] if isinstance(p, tuple) else p.latency_s


def _thr(p) -> float:
    return p[1] if isinstance(p, tuple) else p.throughput


def dominates(a, b) -> bool:
    """a dominates b: no worse on both objectives, strictly better on one."""
    la, ta, lb, tb = _lat(a), _thr(a), _lat(b), _thr(b)
    return (la <= lb and ta >= tb) and (la < lb or ta > tb)


def pareto_front(points: Sequence[T]) -> list[T]:
    """Non-dominated subset, sorted by latency ascending.

    O(n log n): sort by (latency asc, throughput desc) then sweep keeping
    points whose throughput strictly exceeds the best seen so far.
    Duplicate (lat, thr) pairs keep one representative.
    """
    if not points:
        return []
    order = sorted(points, key=lambda p: (_lat(p), -_thr(p)))
    front: list[T] = []
    best_thr = float("-inf")
    for p in order:
        if _thr(p) > best_thr:
            front.append(p)
            best_thr = _thr(p)
    return front


def is_on_front(p, points: Iterable) -> bool:
    return not any(dominates(q, p) for q in points)


def hypervolume(points: Sequence, ref_latency: float, ref_throughput: float = 0.0) -> float:
    """2-D hypervolume dominated w.r.t. reference point
    (ref_latency, ref_throughput) — higher is better.  Points with
    latency above the reference contribute nothing."""
    front = pareto_front(points)
    hv = 0.0
    prev_lat = ref_latency
    for p in sorted(front, key=_lat, reverse=True):
        lat, thr = _lat(p), _thr(p)
        if lat >= prev_lat or thr <= ref_throughput:
            continue
        hv += (prev_lat - lat) * (thr - ref_throughput)
        prev_lat = lat
    return hv


def knee_point(points: Sequence[T]) -> T | None:
    """The front point with the max normalized Manhattan improvement —
    a pragmatic 'balanced' pick for practitioners (paper Sec. V-A asks
    which split balances the objectives)."""
    front = pareto_front(points)
    if not front:
        return None
    lats = [_lat(p) for p in front]
    thrs = [_thr(p) for p in front]
    lo_l, hi_l = min(lats), max(lats)
    lo_t, hi_t = min(thrs), max(thrs)
    dl = (hi_l - lo_l) or 1.0
    dt = (hi_t - lo_t) or 1.0

    def score(p) -> float:
        return (hi_l - _lat(p)) / dl + (_thr(p) - lo_t) / dt

    return max(front, key=score)
