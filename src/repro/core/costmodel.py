"""Pipeline performance model — the analytical heart of ParetoPipe.

Given a ``BlockGraph``, an assignment of contiguous block ranges to
devices, and the links between consecutive devices, predict:

  * **end-to-end latency per batch** — one batch flowing through the
    whole pipeline: input dispatch + every stage's compute + every
    inter-stage transfer + result return (paper Sec. IV-C measures
    exactly this),
  * **steady-state throughput** — successive batches pipeline, so the
    bottleneck is the slowest stage *cycle* (its compute plus its
    non-overlapped sends),
  * **energy per batch** — per stage, device active power × compute time
    plus idle power × its outbound wire wait plus the link's radio cost ×
    bytes sent (Kreß et al., arXiv:2406.19913 treat exactly this
    compute+radio decomposition as the edge partitioning energy model);
    with ``include_io`` the dispatch/return hops add their radio cost.
    The sum is additive over stages, which is what lets ``dp_front_kway``
    carry it as a third monotone DP label,
  * per-stage breakdowns and memory feasibility.

Validation against the paper (Table II, MobileNetV2 P3, batch 8):
  exe 0.969 s + 0.941 s + net 0.048 s → latency ≈ 1.96 s and throughput
  ≈ 8/(0.969+0.048) ≈ 7.9 img/s — the paper reports 7.8 img/s, i.e. the
  bottleneck-cycle model (compute + outbound transfer) is the right one.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Sequence

from .blocks import BlockGraph
from .codecs import CodecCalibration, codec_wire_bytes, get_codec
from .devices import DeviceProfile, Link
from .pareto import ObjectiveLike, vector as objective_vector


class CostTable:
    """Measured per-(device, block) execution times in seconds (per batch).

    Overrides the analytic flops/eff_flops model where present — this is
    the paper's block-wise profiling (Fig. 2) feeding the partitioner."""

    def __init__(self, entries: Mapping[tuple[str, str], float] | None = None):
        self._t: dict[tuple[str, str], float] = dict(entries or {})

    def set(self, device: str, block: str, seconds: float) -> None:
        self._t[(device, block)] = seconds

    def get(self, device: str, block: str) -> float | None:
        return self._t.get((device, block))

    def __len__(self) -> int:
        return len(self._t)


@dataclass(frozen=True)
class StageMetrics:
    device: str
    blocks: tuple[int, int]        # [lo, hi) block range
    compute_s: float
    send_s: float                  # outbound transfer time (0 for last stage)
    weight_bytes: int
    mem_ok: bool
    energy_j: float = 0.0          # active×compute + idle×send + radio×bytes
    send_wire_bytes: float = 0.0   # codec-packed bytes on the outbound hop
    replicas: int = 1              # devices running this stage in parallel


@dataclass(frozen=True)
class PipelineMetrics:
    partition: tuple[int, ...]     # cut points; stage i = blocks[cuts[i]:cuts[i+1]]
    latency_s: float               # end-to-end per batch
    throughput: float              # samples / s, steady state
    stages: tuple[StageMetrics, ...]
    net_s: float                   # total wire time per batch
    feasible: bool                 # all stages fit in device memory
    energy_j: float = 0.0          # joules per batch, all stages + IO radio
    # the fourth Pareto axis: predicted end-task fidelity under this
    # partition's per-hop wire codecs (product of per-cut top-1
    # agreements from the calibration; 1.0 = every hop uncoded)
    accuracy: float = 1.0
    codecs: tuple[str, ...] = ()   # per-hop codec names ((): all "none")
    replicas: tuple[int, ...] = ()  # per-stage replica counts ((): all 1)

    @property
    def bottleneck_s(self) -> float:
        # a stage on r devices drains r batches per cycle: its share of
        # the steady-state period is (compute + send) / r
        return max((s.compute_s + s.send_s) / s.replicas
                   for s in self.stages)

    def objectives(self, objectives: Sequence[ObjectiveLike] | None = None
                   ) -> tuple[float, ...]:
        """This partition's objective vector (default: latency, throughput)."""
        return objective_vector(self, objectives)


def _stage_time(graph: BlockGraph, lo: int, hi: int, dev: DeviceProfile,
                batch: int, costs: CostTable | None) -> float:
    """Batch execution time of blocks[lo:hi] on ``dev``."""
    t = 0.0
    analytic_flops = 0.0
    for b in graph.blocks[lo:hi]:
        m = costs.get(dev.name, b.name) if costs is not None else None
        if m is not None:
            t += m
        else:
            analytic_flops += b.flops * batch / max(b.eff, 1e-6)
    if analytic_flops > 0:
        t += analytic_flops / dev.flops_per_s
    if hi > lo:
        t += dev.stage_overhead_s
    return t


def _stage_energy(dev: DeviceProfile, compute_s: float, send_s: float,
                  send_bytes: float, link: Link | None) -> float:
    """Joules one stage spends per batch: busy while computing, idle
    while its outbound transfer drains, radio cost per byte on the wire."""
    e = dev.active_w * compute_s + dev.idle_w * send_s
    if link is not None and send_bytes > 0:
        e += link.transfer_energy(send_bytes)
    return e


def evaluate_pipeline(
    graph: BlockGraph,
    cuts: Sequence[int],
    devices: Sequence[DeviceProfile],
    links: Sequence[Link],
    batch: int = 1,
    costs: CostTable | None = None,
    dispatch_link: Link | None = None,
    include_io: bool = True,
    codecs: Sequence[str] | None = None,
    calibration: CodecCalibration | None = None,
    replicas: Sequence[int] | None = None,
) -> PipelineMetrics:
    """Evaluate one partition.

    ``cuts`` are the interior cut points: stage i runs blocks
    [cuts[i], cuts[i+1]) with implicit cuts[-1]=0 and cuts[k]=n.
    ``len(devices) == len(cuts) + 1`` and ``len(links) == len(cuts)``.
    ``dispatch_link`` models orchestrator→worker1 input dispatch and
    workerN→orchestrator result return (paper Alg. 1 lines 5–9); defaults
    to the first link.

    ``codecs`` names the per-hop wire codec for each of the
    ``len(cuts)`` inter-stage hops (None = all ``none``): hop bytes
    become the codec's analytic packed size — exactly what the runtime
    ships (``TransferRecord.wire_bytes``) — and the predicted
    ``accuracy`` is the product of per-cut degradations from
    ``calibration`` (falling back to each codec's nominal figure).
    Dispatch/return IO is orchestrator plumbing and ships uncoded.

    ``replicas`` gives the per-stage replica count (None = all 1): a
    stage placed on ``r`` identical devices drains ``r`` batches per
    cycle, so it contributes ``(compute + send) / r`` to the
    steady-state bottleneck while one batch's *latency* through it is
    unchanged (a single batch still traverses exactly one replica) —
    the latency/throughput tension replication buys. Energy charges the
    extra ``r - 1`` devices idle power over the stage's per-batch
    period on top of the usual active/idle/radio terms, so replication
    always costs joules while (only sometimes) buying throughput.
    """
    n = graph.n_blocks
    full = (0, *cuts, n)
    n_stages = len(devices)
    if len(cuts) != n_stages - 1 or len(links) != n_stages - 1:
        raise ValueError("need len(devices)-1 cuts and links")
    if codecs is not None and len(codecs) != n_stages - 1:
        raise ValueError(f"need {n_stages - 1} per-hop codecs, "
                         f"got {len(codecs)}")
    if replicas is not None:
        if len(replicas) != n_stages:
            raise ValueError(f"need {n_stages} per-stage replica counts, "
                             f"got {len(replicas)}")
        if any(r < 1 for r in replicas):
            raise ValueError(f"replica counts must be >= 1: {replicas!r}")
    reps = tuple(replicas) if replicas is not None else (1,) * n_stages
    for a, b in zip(full, full[1:]):
        if not (0 <= a <= b <= n):
            raise ValueError(f"bad cuts {cuts!r} for {n} blocks")

    dlink = dispatch_link or (links[0] if links else None)

    stages: list[StageMetrics] = []
    latency = 0.0
    net_total = 0.0
    energy = 0.0
    feasible = True

    if include_io and dlink is not None:
        in_bytes = graph.cut_bytes(0) * batch
        t_in = dlink.transfer_time(in_bytes)
        latency += t_in
        net_total += t_in
        energy += dlink.transfer_energy(in_bytes)

    accuracy = 1.0
    cycle_times: list[float] = []
    for i in range(n_stages):
        lo, hi = full[i], full[i + 1]
        dev = devices[i]
        comp = _stage_time(graph, lo, hi, dev, batch, costs)
        send = 0.0
        send_bytes = 0.0
        link = None
        if i < n_stages - 1:
            link = links[i]
            send_bytes = graph.cut_bytes(hi) * batch
            if codecs is not None:
                codec = get_codec(codecs[i])
                send_bytes = codec_wire_bytes(codec, send_bytes)
                accuracy *= (calibration.accuracy(hi, codec)
                             if calibration is not None
                             else codec.nominal_accuracy)
            send = link.transfer_time(send_bytes)
        r = reps[i]
        e = _stage_energy(dev, comp, send, send_bytes, link)
        # the r-1 extra replicas burn idle power across the stage's
        # per-batch period — replication is never free in joules
        e += (r - 1) * dev.idle_w * (comp + send) / r
        wbytes = graph.segment_weight_bytes(lo, hi)
        abytes = max((b.act_bytes * batch for b in graph.blocks[lo:hi]), default=0)
        ok = wbytes + abytes <= dev.mem_bytes   # per replica: each holds a copy
        feasible &= ok
        stages.append(StageMetrics(device=dev.name, blocks=(lo, hi),
                                   compute_s=comp, send_s=send,
                                   weight_bytes=wbytes, mem_ok=ok,
                                   energy_j=e, send_wire_bytes=send_bytes,
                                   replicas=r))
        latency += comp + send
        net_total += send
        energy += e
        cycle_times.append((comp + send) / r)

    if include_io and dlink is not None:
        out_bytes = graph.output_bytes * batch
        t_out = dlink.transfer_time(out_bytes)
        latency += t_out
        net_total += t_out
        energy += dlink.transfer_energy(out_bytes)
        cycle_times[-1] += t_out

    bottleneck = max(cycle_times)
    throughput = batch / bottleneck if bottleneck > 0 else float("inf")
    return PipelineMetrics(partition=tuple(cuts), latency_s=latency,
                           throughput=throughput, stages=tuple(stages),
                           net_s=net_total, feasible=feasible,
                           energy_j=energy, accuracy=accuracy,
                           codecs=(tuple(get_codec(c).name for c in codecs)
                                   if codecs is not None else ()),
                           replicas=(reps if replicas is not None else ()))
