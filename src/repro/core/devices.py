"""Device and link models.

Two kinds of cost sources coexist (mirroring the paper's methodology):

  * **Analytic** — a ``DeviceProfile`` with an *effective* FLOP rate and a
    fixed per-stage-invocation overhead; block time = flops / eff_flops +
    overhead share.  Effective rates for the paper's testbed are
    back-solved from the paper's own Tables II/III (see calibration notes
    below) — the point is to land in the same *regime* (GPU 2–3 orders of
    magnitude faster than a Pi; seconds-scale CNN batches), so frontier
    *structure* reproduces.
  * **Measured** — a ``CostTable`` filled by wall-clock profiling
    (``core.profiler``) or by compiled-HLO cost analysis (the dry-run
    path).  When a CostTable has an entry it overrides the analytic model.

Calibration notes (paper Tables II/III, batch 8; 224²/299² inputs — the
only reading consistent with the reported seconds-scale batch times):
  * Pi 4B: AlexNet full ≈0.83 s/batch over 11.4 GFLOP and VGG16
    ≈13 s over 248 GFLOP → ~10–19 effective GFLOP/s on dense convs; we
    use 10.  MobileNetV2's 1.9 s over 5 GFLOP (~1.3 GFLOP/s) reflects
    depthwise-conv inefficiency, modelled per-block via ``Block.eff``.
  * RTX 4090: AlexNet ≈9 ms/batch → ~1.3 effective TFLOP/s at batch 8
    (launch-bound).  We use 1.5 + 5 ms per-stage overhead.
  * TPU v5e (the scale target): 197 TFLOP/s bf16 peak, 819 GB/s HBM,
    ~50 GB/s/link ICI; DCN between pods ~25 GB/s per host pair.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class DeviceProfile:
    name: str
    flops_per_s: float            # effective achievable FLOP/s
    mem_bytes: int                # usable memory for weights + activations
    mem_bw: float = 0.0           # bytes/s (used by roofline-style costs)
    stage_overhead_s: float = 0.0  # fixed cost per stage invocation (framework)

    def compute_time(self, flops: float, bytes_moved: float = 0.0) -> float:
        """Roofline-ish time: max of compute and memory terms + overhead."""
        t = flops / self.flops_per_s
        if self.mem_bw > 0 and bytes_moved > 0:
            t = max(t, bytes_moved / self.mem_bw)
        return t + self.stage_overhead_s


@dataclass(frozen=True)
class Link:
    """Point-to-point link: latency + bandwidth + per-message overhead."""

    name: str
    rtt_s: float                  # round-trip time
    bw_bytes_per_s: float
    per_msg_overhead_s: float = 0.0   # serialization / syscall / RPC overhead

    def transfer_time(self, nbytes: float) -> float:
        return self.rtt_s / 2.0 + self.per_msg_overhead_s + nbytes / self.bw_bytes_per_s


# --------------------------------------------------------------------------- #
# The paper's testbed (calibrated) and the TPU target.
# --------------------------------------------------------------------------- #
GiB = 1024 ** 3

# Calibrated against Tables II/III at the paper's operating point
# (CIFAR-10 upscaled to 224²/299² — the only reading consistent with the
# reported seconds-scale batch times): PyTorch-on-A72 sustains ~10 GFLOP/s
# on dense convs; depthwise convs run at ~10% of that (captured per-block
# via Block.eff, not here).
PI_4B = DeviceProfile(
    name="pi4b", flops_per_s=10e9, mem_bytes=4 * GiB, mem_bw=4e9,
    stage_overhead_s=5e-3,
)

RTX_4090 = DeviceProfile(
    name="rtx4090", flops_per_s=1.5e12, mem_bytes=24 * GiB, mem_bw=1008e9,
    stage_overhead_s=5e-3,
)

# One TPU v5e chip (peak specs; roofline constants of the assignment).
TPU_V5E_CHIP = DeviceProfile(
    name="tpu_v5e", flops_per_s=197e12, mem_bytes=16 * GiB, mem_bw=819e9,
    stage_overhead_s=2e-6,
)


def tpu_pod(n_chips: int = 256, name: str | None = None) -> DeviceProfile:
    """A whole pod as one pipeline 'device' (chips cooperate via TP/DP
    inside the stage; the partitioner places layer ranges on pods)."""
    return DeviceProfile(
        name=name or f"v5e_pod{n_chips}",
        flops_per_s=TPU_V5E_CHIP.flops_per_s * n_chips,
        mem_bytes=TPU_V5E_CHIP.mem_bytes * n_chips,
        mem_bw=TPU_V5E_CHIP.mem_bw * n_chips,
        stage_overhead_s=5e-6,
    )


# Links -------------------------------------------------------------------- #
Mbit = 1e6 / 8
Gbit = 1e9 / 8

LAN_PI_PI = Link("lan_pi_pi", rtt_s=0.201e-3, bw_bytes_per_s=1 * Gbit,
                 per_msg_overhead_s=0.5e-3)
LAN_PI_GPU = Link("lan_pi_gpu", rtt_s=0.383e-3, bw_bytes_per_s=1 * Gbit,
                  per_msg_overhead_s=0.5e-3)
# Paper Sec. V-B: tc netem 200 ms RTT + 5 Mbit/s.
DURESS = Link("duress", rtt_s=200e-3, bw_bytes_per_s=5 * Mbit,
              per_msg_overhead_s=0.5e-3)

ICI_V5E = Link("ici_v5e", rtt_s=2e-6, bw_bytes_per_s=50e9,
               per_msg_overhead_s=1e-6)
# Cross-pod data-center network, aggregated per pod boundary.
DCN = Link("dcn", rtt_s=20e-6, bw_bytes_per_s=25e9, per_msg_overhead_s=5e-6)
DCN_CONGESTED = Link("dcn_congested", rtt_s=2e-3, bw_bytes_per_s=2.5e9,
                     per_msg_overhead_s=5e-6)
