"""Device and link models.

Two kinds of cost sources coexist (mirroring the paper's methodology):

  * **Analytic** — a ``DeviceProfile`` with an *effective* FLOP rate and a
    fixed per-stage-invocation overhead; block time = flops / eff_flops +
    overhead share.  Effective rates for the paper's testbed are
    back-solved from the paper's own Tables II/III (see calibration notes
    below) — the point is to land in the same *regime* (GPU 2–3 orders of
    magnitude faster than a Pi; seconds-scale CNN batches), so frontier
    *structure* reproduces.
  * **Measured** — a ``CostTable`` filled by wall-clock profiling
    (``core.profiler``) or by compiled-HLO cost analysis (the dry-run
    path).  When a CostTable has an entry it overrides the analytic model.

Calibration notes (paper Tables II/III, batch 8; 224²/299² inputs — the
only reading consistent with the reported seconds-scale batch times):
  * Pi 4B: AlexNet full ≈0.83 s/batch over 11.4 GFLOP and VGG16
    ≈13 s over 248 GFLOP → ~10–19 effective GFLOP/s on dense convs; we
    use 10.  MobileNetV2's 1.9 s over 5 GFLOP (~1.3 GFLOP/s) reflects
    depthwise-conv inefficiency, modelled per-block via ``Block.eff``.
  * RTX 4090: AlexNet ≈9 ms/batch → ~1.3 effective TFLOP/s at batch 8
    (launch-bound).  We use 1.5 + 5 ms per-stage overhead.
  * TPU v5e (the scale target): 197 TFLOP/s bf16 peak, 819 GB/s HBM,
    ~50 GB/s/link ICI; DCN between pods ~25 GB/s per host pair.
"""
from __future__ import annotations

import bisect
import math
from dataclasses import dataclass, field
from typing import Union


@dataclass(frozen=True)
class DeviceProfile:
    name: str
    flops_per_s: float            # effective achievable FLOP/s
    mem_bytes: int                # usable memory for weights + activations
    mem_bw: float = 0.0           # bytes/s (used by roofline-style costs)
    stage_overhead_s: float = 0.0  # fixed cost per stage invocation (framework)
    idle_w: float = 0.0           # power draw while waiting (W)
    active_w: float = 0.0         # power draw while computing (W)

    def compute_time(self, flops: float, bytes_moved: float = 0.0) -> float:
        """Roofline-ish time: max of compute and memory terms + overhead."""
        t = flops / self.flops_per_s
        if self.mem_bw > 0 and bytes_moved > 0:
            t = max(t, bytes_moved / self.mem_bw)
        return t + self.stage_overhead_s

    def compute_energy(self, compute_s: float, idle_s: float = 0.0) -> float:
        """Joules for ``compute_s`` seconds busy (+ optional idle tail)."""
        return self.active_w * compute_s + self.idle_w * idle_s


@dataclass(frozen=True)
class Link:
    """Point-to-point link: latency + bandwidth + per-message overhead."""

    name: str
    rtt_s: float                  # round-trip time
    bw_bytes_per_s: float
    per_msg_overhead_s: float = 0.0   # serialization / syscall / RPC overhead
    energy_per_byte_j: float = 0.0    # radio/NIC joules per byte on the wire

    def transfer_time(self, nbytes: float) -> float:
        return self.rtt_s / 2.0 + self.per_msg_overhead_s + nbytes / self.bw_bytes_per_s

    def transfer_energy(self, nbytes: float) -> float:
        """Radio joules to move ``nbytes`` (sender + receiver NICs)."""
        return self.energy_per_byte_j * nbytes


# --------------------------------------------------------------------------- #
# Time-varying links
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class LinkTrace:
    """A link whose RTT/bandwidth follow a piecewise (t, rtt, bw) schedule.

    This is the paper's Sec. V-B duress experiment generalized from a
    step to an arbitrary time profile: the emulator samples the trace at
    every transfer, so a WAN ramp, a congestion spike, or a recovery can
    all play out *while a pipeline is streaming*.

      * ``schedule`` — ascending ``(t_s, rtt_s, bw_bytes_per_s)`` knots.
        Between knots values are linearly interpolated (``interp="linear"``)
        or held at the previous knot (``interp="hold"``); before the first
        / after the last knot the boundary values apply.
      * ``jitter`` — optional relative noise: a caller-supplied RNG draws a
        lognormal factor ``exp(N(0, jitter))`` per transfer, so emulated
        times wobble the way real WANs do while staying positive.
    """

    name: str
    schedule: tuple[tuple[float, float, float], ...]
    per_msg_overhead_s: float = 0.0
    jitter: float = 0.0
    interp: str = "linear"            # "linear" | "hold"
    energy_per_byte_j: float = 0.0    # radio cost is a link property, not
                                      # time-varying: congestion changes
                                      # rtt/bw, not joules per byte sent

    def __post_init__(self):
        if not self.schedule:
            raise ValueError(f"LinkTrace {self.name!r}: empty schedule")
        ts = [k[0] for k in self.schedule]
        if ts != sorted(ts):
            raise ValueError(f"LinkTrace {self.name!r}: knots must be "
                             f"sorted by time, got {ts}")
        if self.interp not in ("linear", "hold"):
            raise ValueError(f"unknown interp {self.interp!r}")

    def _sample(self, t: float) -> tuple[float, float]:
        knots = self.schedule
        if t <= knots[0][0]:
            return knots[0][1], knots[0][2]
        if t >= knots[-1][0]:
            return knots[-1][1], knots[-1][2]
        i = bisect.bisect_right([k[0] for k in knots], t)
        t0, r0, b0 = knots[i - 1]
        t1, r1, b1 = knots[i]
        if self.interp == "hold" or t1 == t0:
            return r0, b0
        w = (t - t0) / (t1 - t0)
        return r0 + w * (r1 - r0), b0 + w * (b1 - b0)

    def at(self, t: float) -> Link:
        """Static snapshot of the link at trace time ``t`` (no jitter)."""
        rtt, bw = self._sample(t)
        return Link(f"{self.name}@{t:.3g}s", rtt_s=rtt, bw_bytes_per_s=bw,
                    per_msg_overhead_s=self.per_msg_overhead_s,
                    energy_per_byte_j=self.energy_per_byte_j)

    def transfer_time(self, nbytes: float, t: float = 0.0, rng=None) -> float:
        """Transfer time at trace time ``t``; with ``rng`` applies jitter.

        ``t`` defaults to 0 so a LinkTrace is a drop-in Link for analytic
        callers that only look at the trace's starting conditions."""
        dt = self.at(t).transfer_time(nbytes)
        if self.jitter > 0.0 and rng is not None:
            dt *= math.exp(rng.normal(0.0, self.jitter))
        return dt

    def transfer_energy(self, nbytes: float) -> float:
        return self.energy_per_byte_j * nbytes


AnyLink = Union[Link, LinkTrace]


def link_at(link: AnyLink, t: float = 0.0) -> Link:
    """Resolve a possibly time-varying link to a static Link at time t."""
    return link.at(t) if isinstance(link, LinkTrace) else link


# --------------------------------------------------------------------------- #
# Fitting the link model to observed transfers.  One home for the
# ``elapsed = rtt/2 + overhead + nbytes/bw`` inversion, shared by the
# runtime estimator (core.autosplit.LinkEstimator) and the trace
# recorder (runtime.transport.record_trace).
# --------------------------------------------------------------------------- #
def fit_link_params(nbytes_list, elapsed_list,
                    rtt_s: float) -> tuple[float, float] | None:
    """Joint least-squares of (bw, overhead) from (nbytes, elapsed)
    pairs: slope → 1/bw, intercept − rtt/2 → per-message overhead.
    Returns None when the sample is degenerate (a single message size
    makes the slope unidentifiable; a non-positive slope means noise
    dominates) — callers fall back to ``attribute_bandwidth``."""
    import numpy as np
    xs = np.asarray(nbytes_list, dtype=float)
    ys = np.asarray(elapsed_list, dtype=float)
    if xs.max() - xs.min() < 1e-9 * max(xs.max(), 1.0):
        return None
    slope, intercept = np.polyfit(xs, ys, 1)
    if slope <= 0.0:
        return None
    return 1.0 / float(slope), max(float(intercept) - rtt_s / 2.0, 0.0)


def fit_link_params_robust(nbytes_list, elapsed_list, rtt_s: float,
                           n_iter: int = 3, k_mad: float = 4.0
                           ) -> tuple[float, float] | None:
    """Outlier-robust variant of ``fit_link_params`` for heavy-tailed
    *measured* records (real socket/shmem transfers pick up scheduler
    preemption and allocator hiccups that a plain least-squares fit
    chases).  MAD-gated: fit, drop samples whose residual exceeds
    ``k_mad`` × 1.4826 × MAD of the window's residuals, refit on the
    survivors; repeat until stable.  A clean window has zero residual
    spread, drops nothing, and degrades exactly to the plain fit."""
    import numpy as np
    xs = np.asarray(nbytes_list, dtype=float)
    ys = np.asarray(elapsed_list, dtype=float)
    fit = fit_link_params(xs, ys, rtt_s)
    if fit is None:
        return None
    for _ in range(n_iter):
        bw, overhead = fit
        resid = ys - (rtt_s / 2.0 + overhead + xs / bw)
        med = float(np.median(resid))
        width = k_mad * 1.4826 * float(np.median(np.abs(resid - med)))
        if width <= 0.0:
            break                              # clean window: nothing to gate
        keep = np.abs(resid - med) <= width
        # never gate the window into degeneracy: the fit needs several
        # samples across more than one message size
        if keep.all() or keep.sum() < 4 or len(np.unique(xs[keep])) < 2:
            break
        refit = fit_link_params(xs[keep], ys[keep], rtt_s)
        if refit is None:
            break
        fit = refit
    return fit


def attribute_bandwidth(nbytes: float, elapsed_s: float, rtt_s: float,
                        overhead_s: float = 0.0) -> float:
    """Single-transfer bandwidth attribution: serviceable time is
    elapsed minus the fixed costs, floored at a fraction of elapsed so
    a jittery small transfer arriving "before" the estimated RTT cannot
    imply near-infinite bandwidth."""
    serv = max(elapsed_s - rtt_s / 2.0 - overhead_s, 0.05 * elapsed_s, 1e-9)
    return nbytes / serv


def ramp_trace(name: str, start: Link, end: Link, t_start: float,
               t_end: float, jitter: float = 0.0) -> LinkTrace:
    """A trace that holds ``start`` until ``t_start``, degrades (or
    recovers) linearly to ``end`` by ``t_end``, then holds ``end``.

    Schedule knots carry (t, rtt, bw) only, so the trace keeps
    ``start``'s per-message overhead and radio energy throughout; pick
    link pairs with matching overheads (all the edge-side links here use
    0.5 ms)."""
    if t_end <= t_start:
        raise ValueError("need t_end > t_start")
    return LinkTrace(
        name=name,
        schedule=((t_start, start.rtt_s, start.bw_bytes_per_s),
                  (t_end, end.rtt_s, end.bw_bytes_per_s)),
        per_msg_overhead_s=start.per_msg_overhead_s,
        jitter=jitter,
        energy_per_byte_j=start.energy_per_byte_j,
    )


def step_trace(name: str, before: Link, after: Link, t_step: float,
               jitter: float = 0.0) -> LinkTrace:
    """The paper's tc-netem duress switch as a trace: ``before`` until
    ``t_step``, ``after`` from then on.  As with ``ramp_trace``, the
    per-message overhead stays at ``before``'s value throughout."""
    eps = 1e-9
    return LinkTrace(
        name=name,
        schedule=((0.0, before.rtt_s, before.bw_bytes_per_s),
                  (t_step, before.rtt_s, before.bw_bytes_per_s),
                  (t_step + eps, after.rtt_s, after.bw_bytes_per_s)),
        per_msg_overhead_s=before.per_msg_overhead_s,
        jitter=jitter,
        interp="hold",
        energy_per_byte_j=before.energy_per_byte_j,
    )


def sawtooth_trace(name: str, good: Link, bad: Link, period_s: float,
                   n_periods: int = 4, duty: float = 0.6,
                   jitter: float = 0.0) -> LinkTrace:
    """LTE-like sawtooth: each period ramps from ``good`` down to ``bad``
    over ``duty`` of the period, then snaps back — the cell-handover /
    scheduler-rotation pattern measured WAN traces show.  Keeps
    ``good``'s per-message overhead and radio energy throughout."""
    if period_s <= 0 or not (0.0 < duty < 1.0):
        raise ValueError("need period_s > 0 and 0 < duty < 1")
    eps = 1e-9
    knots: list[tuple[float, float, float]] = []
    for p in range(n_periods):
        t0 = p * period_s
        knots.append((t0, good.rtt_s, good.bw_bytes_per_s))
        knots.append((t0 + duty * period_s, bad.rtt_s, bad.bw_bytes_per_s))
        knots.append((t0 + duty * period_s + eps,
                      good.rtt_s, good.bw_bytes_per_s))
    knots.append((n_periods * period_s, good.rtt_s, good.bw_bytes_per_s))
    return LinkTrace(name=name, schedule=tuple(knots),
                     per_msg_overhead_s=good.per_msg_overhead_s,
                     jitter=jitter,
                     energy_per_byte_j=good.energy_per_byte_j)


def spike_trace(name: str, base: Link, spike: Link, t_start: float,
                t_peak: float, t_end: float,
                jitter: float = 0.0) -> LinkTrace:
    """Congestion ramp-and-recover: ``base`` until ``t_start``, degrades
    linearly to ``spike`` at ``t_peak``, recovers linearly back to
    ``base`` by ``t_end``, then holds ``base`` — one congestion event
    the adaptive loop should enter *and leave* (migrate out, migrate
    back)."""
    if not (t_start < t_peak < t_end):
        raise ValueError("need t_start < t_peak < t_end")
    return LinkTrace(
        name=name,
        schedule=((t_start, base.rtt_s, base.bw_bytes_per_s),
                  (t_peak, spike.rtt_s, spike.bw_bytes_per_s),
                  (t_end, base.rtt_s, base.bw_bytes_per_s)),
        per_msg_overhead_s=base.per_msg_overhead_s,
        jitter=jitter,
        energy_per_byte_j=base.energy_per_byte_j,
    )


# --------------------------------------------------------------------------- #
# The paper's testbed (calibrated) and the TPU target.
# --------------------------------------------------------------------------- #
GiB = 1024 ** 3

# Calibrated against Tables II/III at the paper's operating point
# (CIFAR-10 upscaled to 224²/299² — the only reading consistent with the
# reported seconds-scale batch times): PyTorch-on-A72 sustains ~10 GFLOP/s
# on dense convs; depthwise convs run at ~10% of that (captured per-block
# via Block.eff, not here).
#
# Power calibration (the energy objective): Pi 4B draws ~2.7 W idle and
# ~6.4 W with all four A72 cores busy (widely measured wall figures); an
# RTX 4090 idles around 22 W and sustains ~320 W under inference load
# (below its 450 W TGP — launch-bound small batches never hit it).  TPU
# v5e per-chip power is not published; ~170 W active / ~60 W idle is the
# regime consistent with its 197 TFLOP/s at "2x perf/W over v4".
PI_4B = DeviceProfile(
    name="pi4b", flops_per_s=10e9, mem_bytes=4 * GiB, mem_bw=4e9,
    stage_overhead_s=5e-3, idle_w=2.7, active_w=6.4,
)

RTX_4090 = DeviceProfile(
    name="rtx4090", flops_per_s=1.5e12, mem_bytes=24 * GiB, mem_bw=1008e9,
    stage_overhead_s=5e-3, idle_w=22.0, active_w=320.0,
)

# This host, as one pipeline "device" per worker *process* — the analytic
# stand-in the partitioner plans with when the runtime deploys real local
# processes (scenarios.local_chain); the measured transports then replace
# the link model with observed transfer costs.  Effective rate is the
# same order as the Pi calibration (shared cores, CPU jax); power is the
# package figure of a small desktop CPU.
HOST_CPU = DeviceProfile(
    name="host_cpu", flops_per_s=20e9, mem_bytes=8 * GiB, mem_bw=10e9,
    stage_overhead_s=1e-3, idle_w=10.0, active_w=45.0,
)

# One TPU v5e chip (peak specs; roofline constants of the assignment).
TPU_V5E_CHIP = DeviceProfile(
    name="tpu_v5e", flops_per_s=197e12, mem_bytes=16 * GiB, mem_bw=819e9,
    stage_overhead_s=2e-6, idle_w=60.0, active_w=170.0,
)


def tpu_pod(n_chips: int = 256, name: str | None = None) -> DeviceProfile:
    """A whole pod as one pipeline 'device' (chips cooperate via TP/DP
    inside the stage; the partitioner places layer ranges on pods)."""
    return DeviceProfile(
        name=name or f"v5e_pod{n_chips}",
        flops_per_s=TPU_V5E_CHIP.flops_per_s * n_chips,
        mem_bytes=TPU_V5E_CHIP.mem_bytes * n_chips,
        mem_bw=TPU_V5E_CHIP.mem_bw * n_chips,
        stage_overhead_s=5e-6,
        idle_w=TPU_V5E_CHIP.idle_w * n_chips,
        active_w=TPU_V5E_CHIP.active_w * n_chips,
    )


# Links -------------------------------------------------------------------- #
Mbit = 1e6 / 8
Gbit = 1e9 / 8

# Radio/NIC energy per byte (both endpoints): GbE NICs draw ~1.5 W
# sustained at wire rate (125 MB/s) → ~12 nJ/B for the pair; a
# WAN/cellular egress path is orders of magnitude costlier, ~1 J/MB
# (the low end of measured LTE figures) → 1 µJ/B; ICI/DCN move bytes at
# a few W over tens of GB/s, so their per-byte cost is negligible but
# nonzero.
LAN_PI_PI = Link("lan_pi_pi", rtt_s=0.201e-3, bw_bytes_per_s=1 * Gbit,
                 per_msg_overhead_s=0.5e-3, energy_per_byte_j=12e-9)
LAN_PI_GPU = Link("lan_pi_gpu", rtt_s=0.383e-3, bw_bytes_per_s=1 * Gbit,
                  per_msg_overhead_s=0.5e-3, energy_per_byte_j=12e-9)
# Loopback TCP between processes on one host — the analytic stand-in for
# the *measured* socket/shmem transports (typical: tens of µs RTT, a few
# GB/s effective with serialization; no radio).  Planning numbers only —
# the real transports record what the wire actually did.
LOOPBACK = Link("loopback", rtt_s=60e-6, bw_bytes_per_s=2e9,
                per_msg_overhead_s=30e-6, energy_per_byte_j=0.0)
# Paper Sec. V-B: tc netem 200 ms RTT + 5 Mbit/s.
DURESS = Link("duress", rtt_s=200e-3, bw_bytes_per_s=5 * Mbit,
              per_msg_overhead_s=0.5e-3, energy_per_byte_j=1e-6)

ICI_V5E = Link("ici_v5e", rtt_s=2e-6, bw_bytes_per_s=50e9,
               per_msg_overhead_s=1e-6, energy_per_byte_j=1e-11)
# Cross-pod data-center network, aggregated per pod boundary.
DCN = Link("dcn", rtt_s=20e-6, bw_bytes_per_s=25e9, per_msg_overhead_s=5e-6,
           energy_per_byte_j=5e-11)
DCN_CONGESTED = Link("dcn_congested", rtt_s=2e-3, bw_bytes_per_s=2.5e9,
                     per_msg_overhead_s=5e-6, energy_per_byte_j=5e-11)
