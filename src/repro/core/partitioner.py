"""Partition-point search.

Three engines, in increasing generality:

  * ``sweep_2way``      — the paper's method: exhaustively evaluate every
                          block-boundary split across a 2-device pipeline.
  * ``sweep_kway``      — exhaustive k-way enumeration (exact; fine up to
                          ~C(n_blocks, k-1) ≈ 1e6 combinations).
  * ``dp_front_kway``   — multi-objective label-correcting DP over the
                          chain: exact Pareto front for k stages in
                          O(k·n²·|labels|), used when enumeration blows
                          up (many pods / many blocks).  Labels carry one
                          monotone scalar per active objective — latency,
                          bottleneck cycle (↔ throughput), and energy are
                          each monotone under chain extension, so pruning
                          dominated labels is exact for any subset.

``solve`` is the unified scenario-driven entry point: it picks the right
engine for the problem size, so callers (AdaptiveSplitter, the runtime,
benchmarks) never hard-code a pipeline depth.  Pass
``objectives=("latency", "throughput", "energy")`` to widen the DP front
to the 3-D trade-off surface; the default is the paper's bi-objective
pair, and sweeps always return every evaluated point regardless.

All return ``PipelineMetrics`` lists; compose with ``pareto.pareto_front``.
"""
from __future__ import annotations

import itertools
import math
from collections import Counter
from typing import Sequence

from .blocks import BlockGraph
from .codecs import CodecCalibration, get_codec
from .costmodel import (CostTable, PipelineMetrics, _stage_energy,
                        evaluate_pipeline)
from .devices import DeviceProfile, Link, link_at
from .pareto import (ObjectiveLike, min_front, pareto_front,
                     resolve_objectives)


def _floor_filter(points: list[PipelineMetrics],
                  accuracy_floor: float | None) -> list[PipelineMetrics]:
    """Drop partitions whose predicted accuracy is below the floor."""
    if accuracy_floor is None:
        return points
    return [p for p in points if p.accuracy >= accuracy_floor]


def _check_replicas(replicas, k: int) -> tuple[int, ...] | None:
    """Validate a fixed per-stage replica vector (None = all 1)."""
    if replicas is None:
        return None
    reps = tuple(int(r) for r in replicas)
    if len(reps) != k:
        raise ValueError(f"need {k} per-stage replica counts, got {len(reps)}")
    if any(r < 1 for r in reps):
        raise ValueError(f"replica counts must be >= 1: {reps!r}")
    return reps


def replicas_feasible(replicas: Sequence[int],
                      devices: Sequence[DeviceProfile],
                      spare_devices: Sequence[DeviceProfile]) -> bool:
    """Can this replica vector be staffed from the scenario's spares?

    Stage i placed on ``r`` devices needs ``r - 1`` spares whose profile
    *name* matches the stage's assigned device (replicas are identical
    copies — the cost model charges every copy the same compute)."""
    need: Counter[str] = Counter()
    for r, d in zip(replicas, devices):
        need[d.name] += r - 1
    have = Counter(s.name for s in spare_devices)
    return all(have[name] >= cnt for name, cnt in need.items())


def solve(
    graph: BlockGraph,
    scenario,
    batch: int = 1,
    costs: CostTable | None = None,
    include_io: bool = True,
    at_time: float = 0.0,
    max_enum: int = 50_000,
    objectives: Sequence[ObjectiveLike] | int | None = None,
    codecs: Sequence[str] | None = None,
    calibration: CodecCalibration | None = None,
    accuracy_floor: float | None = None,
    replicas: Sequence[int] | str | None = None,
) -> list[PipelineMetrics]:
    """Scenario-driven partition search — the one entry point.

    Dispatches on problem size: ``sweep_2way`` for 2-device chains (every
    point, the paper's method), ``sweep_kway`` while exhaustive k-way
    enumeration stays under ``max_enum`` combinations, ``dp_front_kway``
    beyond that (returns only the exact Pareto front).  Time-varying
    links are resolved to their state at ``at_time``.  ``objectives``
    selects the active objective set for the DP front (default: the
    paper's (latency, throughput) pair; ``objectives=4`` is the
    canonical latency/throughput/energy/accuracy set).

    ``codecs`` fixes the per-hop wire codecs (default: the scenario's
    ``codecs`` declaration, else uncoded); with a codec in play the
    metrics carry packed hop bytes and the accuracy axis (measured via
    ``calibration`` where supplied).  ``accuracy_floor`` drops every
    point whose predicted accuracy falls below it — the returned front
    respects the floor on all engines.

    ``replicas`` assigns per-stage replica counts: a fixed vector is
    plumbed through every engine unchecked (what-if analysis — the
    caller supplies the hardware), while ``"auto"`` runs a
    ``best_throughput``-driven greedy search that staffs extra replicas
    from the scenario's ``spare_devices`` (matched by profile name) as
    long as each added replica strictly improves the best achievable
    steady-state throughput.  The auto pool contains the unreplicated
    baseline plus every accepted step, so latency/energy-optimal picks
    still see the r=1 points.
    """
    if isinstance(replicas, str):
        if replicas != "auto":
            raise ValueError(f"replicas must be a vector, 'auto' or None, "
                             f"got {replicas!r}")
        return _search_replicas(graph, scenario, batch=batch, costs=costs,
                                include_io=include_io, at_time=at_time,
                                max_enum=max_enum, objectives=objectives,
                                codecs=codecs, calibration=calibration,
                                accuracy_floor=accuracy_floor)
    devices = tuple(scenario.devices)
    links = tuple(link_at(l, at_time) for l in scenario.links)
    k = len(devices)
    if k < 1 or len(links) != k - 1:
        raise ValueError("scenario needs >=1 device and len(devices)-1 links")
    if graph.n_blocks < k:
        raise ValueError(
            f"{k}-stage scenario {getattr(scenario, 'name', '?')!r} needs "
            f">= {k} blocks, graph {graph.name!r} has {graph.n_blocks}")
    if codecs is None:
        codecs = getattr(scenario, "codecs", None)
    reps = _check_replicas(replicas, k)
    if k == 1:
        return [evaluate_pipeline(graph, (), devices, (), batch=batch,
                                  costs=costs, include_io=include_io,
                                  replicas=reps)]
    if k == 2:
        return _floor_filter(
            sweep_2way(graph, devices, links[0], batch=batch, costs=costs,
                       include_io=include_io, codecs=codecs,
                       calibration=calibration, replicas=reps),
            accuracy_floor)
    if math.comb(graph.n_blocks - 1, k - 1) <= max_enum:
        return _floor_filter(
            sweep_kway(graph, devices, links, batch=batch, costs=costs,
                       include_io=include_io, codecs=codecs,
                       calibration=calibration, replicas=reps),
            accuracy_floor)
    return dp_front_kway(graph, devices, links, batch=batch, costs=costs,
                         include_io=include_io, objectives=objectives,
                         codecs=codecs, calibration=calibration,
                         accuracy_floor=accuracy_floor, replicas=reps)


def sweep_2way(
    graph: BlockGraph,
    devices: Sequence[DeviceProfile],
    link: Link,
    batch: int = 1,
    costs: CostTable | None = None,
    include_degenerate: bool = False,
    include_io: bool = True,
    codecs: Sequence[str] | None = None,
    calibration: CodecCalibration | None = None,
    replicas: Sequence[int] | None = None,
) -> list[PipelineMetrics]:
    """Every valid split point of a 2-device pipeline (paper Sec. IV-C)."""
    if len(devices) != 2:
        raise ValueError("sweep_2way needs exactly 2 devices")
    lo = 0 if include_degenerate else 1
    hi = graph.n_blocks + (1 if include_degenerate else 0)
    out = []
    for p in range(lo, hi):
        out.append(evaluate_pipeline(graph, (p,), devices, (link,),
                                     batch=batch, costs=costs,
                                     include_io=include_io, codecs=codecs,
                                     calibration=calibration,
                                     replicas=replicas))
    return out


def sweep_kway(
    graph: BlockGraph,
    devices: Sequence[DeviceProfile],
    links: Sequence[Link],
    batch: int = 1,
    costs: CostTable | None = None,
    allow_empty_stages: bool = False,
    include_io: bool = True,
    max_combos: int = 2_000_000,
    codecs: Sequence[str] | None = None,
    calibration: CodecCalibration | None = None,
    replicas: Sequence[int] | None = None,
) -> list[PipelineMetrics]:
    """Exhaustive enumeration of all k-way contiguous partitions."""
    n, k = graph.n_blocks, len(devices)
    if k - 1 != len(links):
        raise ValueError("need len(devices)-1 links")
    pool = range(0, n + 1) if allow_empty_stages else range(1, n)
    combos = math.comb(len(pool), k - 1) if k > 1 else 1
    if combos > max_combos:
        raise ValueError(f"{combos} combinations; use dp_front_kway instead")
    out = []
    for cuts in itertools.combinations(pool, k - 1):
        out.append(evaluate_pipeline(graph, cuts, devices, links,
                                     batch=batch, costs=costs,
                                     include_io=include_io, codecs=codecs,
                                     calibration=calibration,
                                     replicas=replicas))
    return out


# --------------------------------------------------------------------------- #
# Multi-objective DP
# --------------------------------------------------------------------------- #
#: DP-trackable monotone scalars per objective name: the label component
#: is min-convention and monotone non-decreasing under chain extension.
#: "throughput" is tracked as the bottleneck cycle time (throughput =
#: batch / bottleneck is strictly monotone in it); "accuracy" as the
#: negated product of per-cut codec agreements — each hop multiplies by
#: a factor in (0, 1], so -accuracy is monotone non-decreasing and two
#: labels' order is preserved under any shared completion.
_DP_OBJECTIVES = ("latency", "throughput", "energy", "accuracy")


def _prune(labels: list[tuple[tuple[float, ...], tuple[int, ...]]]):
    """Keep non-dominated (vector, cuts) labels (vectors all-minimized)."""
    return min_front(labels)


def dp_front_kway(
    graph: BlockGraph,
    devices: Sequence[DeviceProfile],
    links: Sequence[Link],
    batch: int = 1,
    costs: CostTable | None = None,
    allow_empty_stages: bool = False,
    include_io: bool = True,
    objectives: Sequence[ObjectiveLike] | int | None = None,
    codecs: Sequence[str] | None = None,
    calibration: CodecCalibration | None = None,
    accuracy_floor: float | None = None,
    replicas: Sequence[int] | None = None,
) -> list[PipelineMetrics]:
    """Exact Pareto front over all k-way partitions via label DP.

    A label at state (i devices used, j blocks placed) carries one
    monotone scalar per active objective — cumulative latency, worst
    stage cycle so far (↔ throughput), cumulative energy, accumulated
    codec accuracy — plus the cut vector.  Every component is monotone
    under extension, so dominated labels can never yield a non-dominated
    completion — pruning is exact for any subset of ``_DP_OBJECTIVES``.

    With ``codecs`` fixed per hop, hop bytes are the codec-packed sizes
    and the accuracy component multiplies per-cut degradations (from
    ``calibration`` where measured).  ``accuracy_floor`` prunes labels —
    exactly, since accuracy only falls under extension — and filters the
    returned front.

    With ``replicas`` (fixed per-stage counts), stage i on ``r`` devices
    contributes ``(compute + send) / r`` to the bottleneck component and
    the extra-replica idle joules to the energy component.  Both remain
    per-stage constants once (i, j, j2) is fixed, so every label stays
    monotone under extension and the same d-dimensional prune is exact.
    """
    from .codecs import codec_wire_bytes
    from .costmodel import _stage_time  # internal reuse

    objs = resolve_objectives(objectives)
    for o in objs:
        if o.name not in _DP_OBJECTIVES:
            raise ValueError(
                f"dp_front_kway cannot track objective {o.name!r}: only "
                f"{_DP_OBJECTIVES} are monotone under chain extension")
    track_lat = any(o.name == "latency" for o in objs)
    track_bot = any(o.name == "throughput" for o in objs)
    track_en = any(o.name == "energy" for o in objs)
    track_acc = any(o.name == "accuracy" for o in objs)

    n, k = graph.n_blocks, len(devices)
    if k - 1 != len(links):
        raise ValueError("need len(devices)-1 links")
    hop_codecs = ([get_codec(c) for c in codecs] if codecs is not None
                  else [get_codec("none")] * (k - 1))
    if len(hop_codecs) != k - 1:
        raise ValueError(f"need {k - 1} per-hop codecs, got {len(codecs)}")
    reps = _check_replicas(replicas, k) or (1,) * k

    def cut_accuracy(hop: int, cut: int) -> float:
        codec = hop_codecs[hop]
        if codec.code == 0:
            return 1.0
        return (calibration.accuracy(cut, codec) if calibration is not None
                else codec.nominal_accuracy)

    dlink = links[0] if (include_io and links) else None
    init_lat = dlink.transfer_time(graph.cut_bytes(0) * batch) if dlink else 0.0
    init_en = dlink.transfer_energy(graph.cut_bytes(0) * batch) if dlink else 0.0

    def label_vec(lat: float, bot: float, en: float,
                  acc: float) -> tuple[float, ...]:
        vec = []
        if track_lat:
            vec.append(lat)
        if track_bot:
            vec.append(bot)
        if track_en:
            vec.append(en)
        if track_acc:
            vec.append(-acc)
        return tuple(vec)

    # labels[j] after i stages: list of ((lat, bot, en, acc), cuts); the
    # full vector rides along so pruning can project to the active subset
    labels: dict[int, list] = {0: [((init_lat, 0.0, init_en, 1.0), ())]}
    for i in range(k):
        nxt: dict[int, list] = {}
        last = i == k - 1
        stages_after = k - i - 1       # stages still to fill after this one
        for j, labs in labels.items():
            if last:
                j2_options: Sequence[int] = (n,) if (allow_empty_stages or n > j) else ()
            else:
                lo = j if allow_empty_stages else j + 1
                hi = n if allow_empty_stages else n - stages_after  # leave ≥1 each
                j2_options = range(lo, hi + 1)
            for j2 in j2_options:
                comp = _stage_time(graph, j, j2, devices[i], batch, costs)
                send_bytes = (codec_wire_bytes(hop_codecs[i],
                                               graph.cut_bytes(j2) * batch)
                              if not last else 0.0)
                send = links[i].transfer_time(send_bytes) if not last else 0.0
                out_t = dlink.transfer_time(graph.output_bytes * batch) if (last and dlink) else 0.0
                out_e = dlink.transfer_energy(graph.output_bytes * batch) if (last and dlink) else 0.0
                r = reps[i]
                e_step = _stage_energy(devices[i], comp, send, send_bytes,
                                       links[i] if not last else None) + out_e
                # extra replicas idle across the stage's per-batch period
                e_step += (r - 1) * devices[i].idle_w * (comp + send) / r
                a_step = cut_accuracy(i, j2) if not last else 1.0
                step = comp + send + out_t
                # r replicas drain r batches per cycle; the shared
                # return hop (out_t) stays serial at the orchestrator
                cyc = (comp + send) / r + out_t
                for (lat, bot, en, acc), cuts in labs:
                    nl = lat + step
                    nb = max(bot, cyc)
                    ne = en + e_step
                    na = acc * a_step
                    if accuracy_floor is not None and na < accuracy_floor:
                        continue       # accuracy only falls: prune exactly
                    nc = cuts if last else cuts + (j2,)
                    nxt.setdefault(j2, []).append(((nl, nb, ne, na), nc))
        labels = {j: _prune([(label_vec(*vec), (vec, cuts))
                             for vec, cuts in v])
                  for j, v in nxt.items()}

    finals = labels.get(n, [])
    out = [evaluate_pipeline(graph, cuts, devices, links, batch=batch,
                             costs=costs, include_io=include_io,
                             codecs=codecs, calibration=calibration,
                             replicas=replicas)
           for _, cuts in finals]
    return pareto_front(_floor_filter(out, accuracy_floor), objs)


# Convenience single-objective picks ---------------------------------------- #
def best_latency(points: Sequence[PipelineMetrics]) -> PipelineMetrics:
    feas = [p for p in points if p.feasible] or list(points)
    return min(feas, key=lambda p: p.latency_s)


def best_throughput(points: Sequence[PipelineMetrics]) -> PipelineMetrics:
    feas = [p for p in points if p.feasible] or list(points)
    return max(feas, key=lambda p: p.throughput)


def best_energy(points: Sequence[PipelineMetrics]) -> PipelineMetrics:
    """Lowest joules/batch — the pick for battery-bound deployments."""
    feas = [p for p in points if p.feasible] or list(points)
    return min(feas, key=lambda p: p.energy_j)


def best_accuracy(points: Sequence[PipelineMetrics]) -> PipelineMetrics:
    """Highest predicted fidelity (latency breaks ties)."""
    feas = [p for p in points if p.feasible] or list(points)
    return min(feas, key=lambda p: (-p.accuracy, p.latency_s))


# --------------------------------------------------------------------------- #
# Replica search: staff the bottleneck from the scenario's spare devices
# --------------------------------------------------------------------------- #
def _search_replicas(graph: BlockGraph, scenario,
                     **solve_kwargs) -> list[PipelineMetrics]:
    """Greedy best-improvement replica search (``solve(replicas="auto")``).

    Starts from the unreplicated chain, then repeatedly tries adding one
    replica to each stage that still has a matching spare (same profile
    name in ``scenario.spare_devices``), re-solving the partition each
    time — replication shifts the bottleneck, so the optimal *cuts* move
    with it.  The single best-improving stage is accepted per round;
    the search stops when no spare strictly improves
    ``best_throughput``.  Returns the accumulated pool: baseline points
    plus every accepted assignment's points."""
    devices = tuple(scenario.devices)
    k = len(devices)
    have = Counter(s.name for s in getattr(scenario, "spare_devices", ())
                   or ())
    pool = solve(graph, scenario, **solve_kwargs)
    if not pool:
        return pool
    best_tp = best_throughput(pool).throughput
    reps = [1] * k
    used: Counter[str] = Counter()
    while True:
        winner = None
        for i, dev in enumerate(devices):
            if used[dev.name] >= have[dev.name]:
                continue
            trial = tuple(reps[:i] + [reps[i] + 1] + reps[i + 1:])
            pts = solve(graph, scenario, replicas=trial, **solve_kwargs)
            if not pts:
                continue
            tp = best_throughput(pts).throughput
            if tp > best_tp and (winner is None or tp > winner[0]):
                winner = (tp, i, pts)
        if winner is None:
            return pool
        best_tp, i, pts = winner
        reps[i] += 1
        used[devices[i].name] += 1
        pool.extend(pts)


def sweep_replicas(graph: BlockGraph, scenario,
                   max_assignments: int = 4096,
                   **solve_kwargs) -> list[PipelineMetrics]:
    """Exhaustive replica-assignment sweep — the ground truth the greedy
    ``solve(replicas="auto")`` is cross-validated against in tests.

    Enumerates every per-stage replica vector staffable from
    ``scenario.spare_devices`` (each stage bounded by the count of
    same-name spares, joint feasibility checked per vector) and solves
    the partition under each.  Cost is |assignments| × one ``solve``;
    guarded by ``max_assignments``."""
    devices = tuple(scenario.devices)
    have = Counter(s.name for s in getattr(scenario, "spare_devices", ())
                   or ())
    per_stage = [range(1, 2 + have[d.name]) for d in devices]
    assignments = [reps for reps in itertools.product(*per_stage)
                   if replicas_feasible(reps, devices,
                                        getattr(scenario, "spare_devices",
                                                ()) or ())]
    if len(assignments) > max_assignments:
        raise ValueError(f"{len(assignments)} replica assignments exceed "
                         f"max_assignments={max_assignments}")
    pool: list[PipelineMetrics] = []
    for reps in assignments:
        pool.extend(solve(graph, scenario, replicas=reps, **solve_kwargs))
    return pool


def solve_with_codecs(
    graph: BlockGraph,
    scenario,
    codec_choices: Sequence[str] = ("none", "int8", "fp8", "topk"),
    batch: int = 1,
    costs: CostTable | None = None,
    include_io: bool = True,
    at_time: float = 0.0,
    objectives: Sequence[ObjectiveLike] | int | None = 4,
    calibration: CodecCalibration | None = None,
    accuracy_floor: float | None = None,
) -> list[PipelineMetrics]:
    """Joint partition × per-hop codec search.

    Enumerates every per-hop codec assignment from ``codec_choices``
    (|choices|^(k-1) ``solve`` calls — fine for the paper's 2–4 device
    chains) and returns the joint Pareto front, each point tagged with
    the codec vector that produced it (``PipelineMetrics.codecs``).
    This is the 4-objective front the wire-codec study plots: coarser
    codecs trade the accuracy axis for latency/throughput/energy.
    """
    k = len(scenario.devices)
    objs = resolve_objectives(objectives)
    pool: list[PipelineMetrics] = []
    for assign in itertools.product(codec_choices, repeat=k - 1):
        pool.extend(solve(graph, scenario, batch=batch, costs=costs,
                          include_io=include_io, at_time=at_time,
                          objectives=objs, codecs=assign,
                          calibration=calibration,
                          accuracy_floor=accuracy_floor))
    return pareto_front(pool, objs)
