"""Block-level model abstraction for partitioning.

ParetoPipe (Sec. IV-C/IV-D of the paper) partitions models at *block*
boundaries — a block is a group of layers that is never split internally
(e.g. an inverted-residual block of MobileNetV2 or a transformer layer).
The partitioner only needs, per block:

  * forward cost (FLOPs, or a measured per-device time — see CostTable),
  * parameter bytes (for the per-device memory-feasibility constraint),
  * the size of the activation it emits (what crosses the wire if we cut
    right after it).

A ``BlockGraph`` is a linear chain of blocks.  Non-chain dependencies that
matter for partitioning (whisper's encoder output feeding every decoder
block) are modelled with ``broadcast_bytes``: bytes that must additionally
be forwarded to every stage placed after this block.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Sequence


@dataclass(frozen=True)
class Block:
    """One indivisible unit of the model."""

    name: str
    flops: float                 # forward FLOPs per *sample*
    weight_bytes: int            # parameter bytes held by this block
    out_bytes: int               # activation bytes emitted per *sample*
    act_bytes: int = 0           # peak intermediate activation bytes (memory model)
    eff: float = 1.0             # achievable fraction of device peak (per-op-type)
    shared_group: str | None = None   # weight-sharing group id (zamba2 shared block)
    broadcast_bytes: int = 0     # bytes every *later* stage needs (enc-dec cross-attn)

    def scaled(self, batch: int) -> "Block":
        return dataclasses.replace(
            self,
            flops=self.flops * batch,
            out_bytes=self.out_bytes * batch,
            act_bytes=self.act_bytes * batch,
            broadcast_bytes=self.broadcast_bytes * batch,
        )


@dataclass(frozen=True)
class BlockGraph:
    """A linear chain of blocks plus the model-input size."""

    name: str
    blocks: tuple[Block, ...]
    input_bytes: int             # bytes of the model input per sample
    output_bytes: int = 0        # bytes of the final prediction per sample

    def __post_init__(self):
        if not self.blocks:
            raise ValueError(f"BlockGraph {self.name!r} has no blocks")

    # ------------------------------------------------------------------ #
    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    @property
    def total_flops(self) -> float:
        return sum(b.flops for b in self.blocks)

    @property
    def total_weight_bytes(self) -> int:
        """Total parameter bytes, counting each shared group once."""
        seen: set[str] = set()
        total = 0
        for b in self.blocks:
            if b.shared_group is not None:
                if b.shared_group in seen:
                    continue
                seen.add(b.shared_group)
            total += b.weight_bytes
        return total

    def segment_flops(self, lo: int, hi: int) -> float:
        """FLOPs of blocks[lo:hi]."""
        return sum(b.flops for b in self.blocks[lo:hi])

    def segment_weight_bytes(self, lo: int, hi: int) -> int:
        """Parameter bytes of blocks[lo:hi]; shared groups counted once
        per segment (each stage that uses a shared block holds one copy)."""
        seen: set[str] = set()
        total = 0
        for b in self.blocks[lo:hi]:
            if b.shared_group is not None:
                if b.shared_group in seen:
                    continue
                seen.add(b.shared_group)
            total += b.weight_bytes
        return total

    def cut_bytes(self, p: int) -> int:
        """Bytes/sample crossing a cut placed after block index ``p-1``
        (i.e. blocks[0:p] on the earlier side).  ``p == 0`` means the raw
        input crosses; ``p == n_blocks`` means only the output crosses.
        Broadcast edges from any block at or before the cut add their
        bytes (they must reach the later stage too)."""
        if p <= 0:
            base = self.input_bytes
        elif p >= self.n_blocks:
            return self.output_bytes
        else:
            base = self.blocks[p - 1].out_bytes
        bcast = sum(b.broadcast_bytes for b in self.blocks[:p])
        return base + bcast

    def scaled(self, batch: int) -> "BlockGraph":
        return BlockGraph(
            name=self.name,
            blocks=tuple(b.scaled(batch) for b in self.blocks),
            input_bytes=self.input_bytes * batch,
            output_bytes=self.output_bytes * batch,
        )


def chain(name: str, blocks: Sequence[Block], input_bytes: int,
          output_bytes: int = 0) -> BlockGraph:
    return BlockGraph(name=name, blocks=tuple(blocks),
                      input_bytes=input_bytes, output_bytes=output_bytes)
