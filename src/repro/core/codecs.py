"""Per-hop wire codecs: registry, analytic byte model, calibration.

A ``Codec`` is a lossy (or identity) transform applied to activation
payloads at a hop, declared per hop the way ``Scenario.transports``
declares backends.  The same object serves three layers:

  * **runtime** — ``encode``/``decode`` run the Pallas pack/unpack
    kernels (``kernels/codec_pack.py``) on host arrays; the transport
    calls them from ``_frame``/``_unframe`` and ships the packed
    payload with the codec's wire code in the frame header;
  * **analytic** — ``wire_bytes`` predicts the packed payload size
    exactly (header + packed elements), so the partitioner's predicted
    hop bytes agree with the measured ``TransferRecord.wire_bytes``;
  * **accuracy** — a calibration pass (``calibrate_codecs``) measures
    per-cut per-codec output degradation (top-1 agreement and
    max-abs-err on a held batch) for the cost model's fourth Pareto
    axis; ``nominal_accuracy`` is the placeholder used when no
    calibration is supplied.

Wire layouts (little-endian, shared by encode/decode/wire_bytes):

  ===========  =====================================================
  ``none``     raw bytes, unchanged (codec byte 0 on the wire)
  ``int8``     4 B fp32 scale + n × int8            (≈4× for fp32)
  ``fp8``      4 B fp32 scale + n × float8_e4m3fn   (≈4× for fp32)
  ``topk``     8 B header (uint32 k, reserved) + k × uint32 index +
               k × fp32 value, k = ⌈n/8⌉            (≈4× for fp32)
  ===========  =====================================================

Codecs apply to float tensors only (``supports``); everything else —
control tokens, integer arrays, empty payloads — passes through
unchanged with codec byte 0, which is also why the ``none`` codec is
bit-exact with pre-codec framing.
"""
from __future__ import annotations

import math
import struct
from dataclasses import dataclass
from typing import Mapping, Sequence

import numpy as np

_FLOAT_NAMES = frozenset({"float16", "float32", "float64", "bfloat16"})
_SCALE = struct.Struct("<f")
_TOPK_HDR = struct.Struct("<II")


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # noqa: F401 — import registers extension dtypes
        return np.dtype(name)


class Codec:
    """Identity codec (``none``): payload bytes untouched."""

    name: str = "none"
    code: int = 0              # wire byte; 0 = uncoded (append-only space)
    # output degradation assumed when no calibration measured it — the
    # identity codec is exact, lossy subclasses override
    nominal_accuracy: float = 1.0

    def supports(self, dtype: np.dtype) -> bool:
        return True

    def wire_bytes(self, n_elems: int, itemsize: int = 4) -> int:
        """Packed payload size for ``n_elems`` elements of ``itemsize``."""
        return int(n_elems) * int(itemsize)

    def encode(self, host: np.ndarray) -> bytes:
        return host.tobytes()

    def decode(self, buf, shape: tuple, dtype: np.dtype) -> np.ndarray:
        return np.frombuffer(buf, dtype=dtype).reshape(shape)


class _LossyCodec(Codec):
    """Shared float-only gate + fp32 staging for the lossy codecs."""

    def supports(self, dtype: np.dtype) -> bool:
        return dtype.name in _FLOAT_NAMES

    @staticmethod
    def _restore(flat: np.ndarray, shape: tuple, dtype: np.dtype):
        out = flat.reshape(shape)
        return out if dtype == out.dtype else out.astype(dtype)


class Int8Codec(_LossyCodec):
    """Symmetric per-tensor int8: 4 B scale header + one byte/element."""

    name = "int8"
    code = 1
    nominal_accuracy = 0.99

    def wire_bytes(self, n_elems: int, itemsize: int = 4) -> int:
        return _SCALE.size + int(n_elems)

    def encode(self, host: np.ndarray) -> bytes:
        from ..kernels import ops
        q, scale = ops.int8_pack(host)
        return _SCALE.pack(float(scale)) + np.asarray(q).tobytes()

    def decode(self, buf, shape: tuple, dtype: np.dtype) -> np.ndarray:
        from ..kernels import ops
        scale = _SCALE.unpack_from(buf)[0]
        q = np.frombuffer(buf, dtype=np.int8, offset=_SCALE.size)
        return self._restore(np.asarray(ops.int8_unpack(q, scale)),
                             shape, dtype)


class Fp8Codec(_LossyCodec):
    """Scaled e4m3 cast: 4 B scale header + one byte/element (~3 bit
    mantissa keeps relative error where int8 keeps absolute error)."""

    name = "fp8"
    code = 2
    nominal_accuracy = 0.995

    def wire_bytes(self, n_elems: int, itemsize: int = 4) -> int:
        return _SCALE.size + int(n_elems)

    def encode(self, host: np.ndarray) -> bytes:
        from ..kernels import ops
        q, scale = ops.fp8_pack(host)
        return _SCALE.pack(float(scale)) + np.asarray(q).tobytes()

    def decode(self, buf, shape: tuple, dtype: np.dtype) -> np.ndarray:
        from ..kernels import ops
        scale = _SCALE.unpack_from(buf)[0]
        q = np.frombuffer(buf, dtype=_np_dtype("float8_e4m3fn"),
                          offset=_SCALE.size)
        return self._restore(np.asarray(ops.fp8_unpack(q, scale)),
                             shape, dtype)


class TopKCodec(_LossyCodec):
    """Magnitude top-k sparsification with packed uint32 indices; the
    dropped (1 - 1/density) tail decodes to zeros."""

    name = "topk"
    code = 3
    nominal_accuracy = 0.97
    density = 8                # keep 1 in `density` elements

    def _k(self, n_elems: int) -> int:
        return max(1, math.ceil(int(n_elems) / self.density))

    def wire_bytes(self, n_elems: int, itemsize: int = 4) -> int:
        return _TOPK_HDR.size + 8 * self._k(n_elems)

    def encode(self, host: np.ndarray) -> bytes:
        from ..kernels import ops
        idx, vals = ops.topk_select(host, k=self._k(host.size))
        return (_TOPK_HDR.pack(self._k(host.size), 0)
                + np.asarray(idx).tobytes() + np.asarray(vals).tobytes())

    def decode(self, buf, shape: tuple, dtype: np.dtype) -> np.ndarray:
        k = _TOPK_HDR.unpack_from(buf)[0]
        off = _TOPK_HDR.size
        idx = np.frombuffer(buf, dtype=np.uint32, offset=off, count=k)
        vals = np.frombuffer(buf, dtype=np.float32, offset=off + 4 * k,
                             count=k)
        flat = np.zeros(int(np.prod(shape, dtype=np.int64)), np.float32)
        flat[idx] = vals
        return self._restore(flat, shape, dtype)


CODECS: dict[str, Codec] = {}
_BY_CODE: dict[int, Codec] = {}


def register_codec(codec: Codec) -> None:
    """Register a codec instance under its ``name`` and wire ``code``.
    Wire codes are append-only protocol space: reusing a live code or
    code 0 (uncoded) would misdecode in-flight frames."""
    if codec.code in _BY_CODE and _BY_CODE[codec.code].name != codec.name:
        raise ValueError(f"wire code {codec.code} already taken by "
                         f"{_BY_CODE[codec.code].name!r}")
    CODECS[codec.name] = codec
    _BY_CODE[codec.code] = codec


for _c in (Codec(), Int8Codec(), Fp8Codec(), TopKCodec()):
    register_codec(_c)


def get_codec(name: str | Codec | None) -> Codec:
    if isinstance(name, Codec):
        return name
    try:
        return CODECS[name or "none"]
    except KeyError:
        raise KeyError(f"unknown codec {name!r}; have "
                       f"{sorted(CODECS)}") from None


def codec_for_code(code: int) -> Codec:
    try:
        return _BY_CODE[code]
    except KeyError:
        raise KeyError(f"unknown codec wire code {code}") from None


def codec_wire_bytes(codec: str | Codec | None, raw_bytes: float,
                     itemsize: int = 4) -> float:
    """Analytic packed size for a raw payload of ``raw_bytes`` — the
    cost model's Link-bytes credit, exact against the runtime framing."""
    c = get_codec(codec)
    if c.code == 0 or raw_bytes <= 0:
        return raw_bytes
    return float(c.wire_bytes(int(raw_bytes) // itemsize, itemsize))


def quantized_wire_bytes(n_elems: int, bits: int = 8) -> int:
    """Wire bytes for one symmetrically-quantized tensor: scale header
    + ceil(n·bits/8) packed element bytes (``optim/compress.py``'s
    gradient credit shares this accounting with the int8 codec)."""
    return _SCALE.size + -(-int(n_elems) * bits // 8)


# --------------------------------------------------------------------------- #
# Accuracy calibration — degradation per (cut, codec) on a held batch
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class CodecAccuracy:
    """Measured output degradation for one (cut, codec) pair."""

    top1_agreement: float      # fraction of held batch keeping its argmax
    max_abs_err: float         # worst output-logit perturbation


@dataclass(frozen=True)
class CodecCalibration:
    """Per-cut per-codec degradation table for one model/input shape.
    ``accuracy`` is what the cost model multiplies per hop; unmeasured
    pairs fall back to the codec's ``nominal_accuracy``."""

    table: Mapping[tuple[int, str], CodecAccuracy]

    def accuracy(self, cut: int, codec: str | Codec | None) -> float:
        c = get_codec(codec)
        if c.code == 0:
            return 1.0
        entry = self.table.get((int(cut), c.name))
        return entry.top1_agreement if entry is not None \
            else c.nominal_accuracy

    def max_abs_err(self, cut: int, codec: str | Codec | None) -> float:
        c = get_codec(codec)
        if c.code == 0:
            return 0.0
        entry = self.table.get((int(cut), c.name))
        return entry.max_abs_err if entry is not None else float("nan")


def nominal_accuracy(codec: str | Codec | None) -> float:
    return get_codec(codec).nominal_accuracy


def roundtrip(codec: str | Codec, host: np.ndarray) -> np.ndarray:
    """Encode→decode through the exact wire transform (calibration and
    tests measure what the transport will actually do to the tensor)."""
    c = get_codec(codec)
    host = np.ascontiguousarray(host)
    if c.code == 0 or not host.size or not c.supports(host.dtype):
        return host
    return c.decode(c.encode(host), host.shape, host.dtype)


def calibrate_codecs(model, params, x,
                     codecs: Sequence[str] = ("int8", "fp8", "topk"),
                     cuts: Sequence[int] | None = None) -> CodecCalibration:
    """Measure per-cut per-codec output degradation on a held batch.

    ``model`` needs the ``CNNModel`` surface: ``apply_range(params, a,
    lo, hi)`` plus ``blocks``.  For every cut the clean activation is
    round-tripped through each codec's wire transform and the remainder
    of the network is re-run; degradation is scored as top-1 agreement
    with the clean output plus the worst output perturbation.
    """
    import jax.numpy as jnp
    n = len(model.blocks)
    cuts = tuple(cuts) if cuts is not None else tuple(range(1, n))
    acts = {0: jnp.asarray(x)}
    a = acts[0]
    for b in range(n):
        a = model.apply_range(params, a, b, b + 1)
        acts[b + 1] = a
    clean = np.asarray(acts[n])
    base = clean.reshape(clean.shape[0], -1).argmax(axis=-1)

    table: dict[tuple[int, str], CodecAccuracy] = {}
    for cut in cuts:
        act = np.asarray(acts[cut])
        for name in codecs:
            c = get_codec(name)
            if c.code == 0:
                table[(cut, c.name)] = CodecAccuracy(1.0, 0.0)
                continue
            deg = roundtrip(c, act)
            out = np.asarray(
                model.apply_range(params, jnp.asarray(deg), cut, n))
            top1 = out.reshape(out.shape[0], -1).argmax(axis=-1)
            table[(cut, c.name)] = CodecAccuracy(
                top1_agreement=float(np.mean(top1 == base)),
                max_abs_err=float(np.max(np.abs(out - clean))),
            )
    return CodecCalibration(table)
