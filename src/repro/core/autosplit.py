"""Adaptive split selection under changing network conditions.

The paper's headline deployment finding (Sec. V-B): a split chosen under
lab conditions becomes wrong when the network degrades, so partitioning
must be *network-aware*.  The paper leaves adaptive selection to future
work; we implement it:

  * ``LinkEstimator`` — EWMA estimates of RTT and bandwidth from observed
    transfers (what a runtime actually sees).
  * ``AdaptiveSplitter`` — re-solves the Pareto front with the estimated
    link, picks a point for the active policy (min-latency /
    max-throughput / knee), and migrates only when the predicted gain
    beats a hysteresis threshold (migration = redeploying weights, which
    has a real cost the splitter accounts for).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal

from .blocks import BlockGraph
from .costmodel import CostTable, PipelineMetrics
from .devices import Link
from .pareto import knee_point, pareto_front
from .partitioner import best_latency, best_throughput, sweep_2way
from .scenarios import Scenario

Policy = Literal["latency", "throughput", "knee"]


@dataclass
class LinkEstimator:
    """EWMA link-condition estimator fed by observed transfers."""

    rtt_s: float
    bw_bytes_per_s: float
    alpha: float = 0.3

    def observe(self, nbytes: float, elapsed_s: float, is_rtt_probe: bool = False):
        if is_rtt_probe:
            self.rtt_s = (1 - self.alpha) * self.rtt_s + self.alpha * elapsed_s
            return
        # attribute elapsed = rtt/2 + bytes/bw
        serv = max(elapsed_s - self.rtt_s / 2.0, 1e-9)
        bw = nbytes / serv
        self.bw_bytes_per_s = (1 - self.alpha) * self.bw_bytes_per_s + self.alpha * bw

    def as_link(self, name: str = "estimated") -> Link:
        return Link(name, rtt_s=self.rtt_s, bw_bytes_per_s=self.bw_bytes_per_s)


@dataclass
class AdaptiveSplitter:
    graph: BlockGraph
    scenario: Scenario
    batch: int = 8
    policy: Policy = "knee"
    costs: CostTable | None = None
    hysteresis: float = 0.10          # required relative improvement
    migration_cost_s: float = 1.0     # one-off cost of moving the split
    current: PipelineMetrics | None = None
    history: list = field(default_factory=list)

    def _pick(self, points) -> PipelineMetrics:
        feas = [p for p in points if p.feasible] or points
        if self.policy == "latency":
            return best_latency(feas)
        if self.policy == "throughput":
            return best_throughput(feas)
        return knee_point(feas) or best_throughput(feas)

    def _objective(self, m: PipelineMetrics) -> float:
        """Lower is better (throughput negated)."""
        return m.latency_s if self.policy == "latency" else -m.throughput

    def solve(self, link: Link | None = None) -> PipelineMetrics:
        scen = self.scenario if link is None else self.scenario.with_link(0, link)
        points = sweep_2way(self.graph, scen.devices, scen.links[0],
                            batch=self.batch, costs=self.costs)
        return self._pick(points)

    def step(self, estimator: LinkEstimator) -> tuple[PipelineMetrics, bool]:
        """Re-evaluate with the current link estimate.  Returns the active
        partition and whether a migration happened."""
        cand = self.solve(estimator.as_link())
        migrated = False
        if self.current is None:
            self.current, migrated = cand, True
        elif cand.partition != self.current.partition:
            # re-price the *current* split under the new conditions
            cur = next(
                p for p in sweep_2way(self.graph, self.scenario.devices,
                                      estimator.as_link(), batch=self.batch,
                                      costs=self.costs)
                if p.partition == self.current.partition)
            old, new = self._objective(cur), self._objective(cand)
            gain = (old - new) / max(abs(old), 1e-12)
            if gain > self.hysteresis:
                self.current, migrated = cand, True
            else:
                self.current = cur
        else:
            self.current = cand
        self.history.append((self.current.partition, migrated))
        return self.current, migrated
