"""Adaptive split selection under changing network conditions.

The paper's headline deployment finding (Sec. V-B): a split chosen under
lab conditions becomes wrong when the network degrades, so partitioning
must be *network-aware*.  The paper leaves adaptive selection to future
work; we implement it:

  * ``LinkEstimator`` — EWMA estimates of RTT and bandwidth from observed
    transfers (what a runtime actually sees).  The executable runtime
    (``runtime.adaptive``) feeds one estimator per hop straight from its
    emulated-wire observations.
  * ``AdaptiveSplitter`` — re-solves the Pareto front for the *whole*
    device chain (any depth, via ``partitioner.solve``) with the
    estimated links, picks a point for the active policy (min-latency /
    max-throughput / min-energy / knee), and migrates only when the
    predicted gain beats a hysteresis threshold (migration = redeploying
    weights, which has a real cost the runtime charges via
    ``migration_cost_s`` — and a *joule* cost, ``migration_energy_j``:
    the moved blocks' weights crossing each hop at its radio price;
    with ``amortize_horizon_s`` set, both must be amortized by the
    predicted per-batch savings within the horizon before the splitter
    will move).  An ``energy_budget_j`` (joules/batch) turns
    any policy into a constrained pick: candidates above the budget are
    dropped before the policy chooses, falling back to the least-energy
    point when nothing fits — a battery-bound Pi deployment re-solving
    under its power envelope.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Literal, Sequence

from .blocks import BlockGraph
from .codecs import CodecCalibration
from .costmodel import CostTable, PipelineMetrics, evaluate_pipeline
from .devices import (Link, LinkTrace, attribute_bandwidth,
                      fit_link_params_robust, link_at)
from .pareto import knee_point
from .partitioner import (best_accuracy, best_energy, best_latency,
                          best_throughput, solve, solve_with_codecs)
from .scenarios import Scenario

Policy = Literal["latency", "throughput", "energy", "knee"]


@dataclass
class LinkEstimator:
    """Link-condition estimator fed by observed transfers.

    The link model is ``elapsed = rtt/2 + overhead + nbytes/bw``.  RTT
    comes from header-only probes (EWMA — probes measure it directly).
    For data transfers the estimator accumulates ``(nbytes, elapsed)``
    pairs in a sliding window and, once the window spans more than one
    message size, fits (overhead, bw) **jointly** by least squares:
    slope → 1/bw, intercept − rtt/2 → per-message overhead.  This fixes
    the classic EWMA failure mode where the fixed per-message cost of
    tiny transfers is mis-attributed to bandwidth.  Until the window is
    informative (too few samples, or all one size) it falls back to the
    bounded per-sample EWMA attribution.
    """

    rtt_s: float
    bw_bytes_per_s: float
    alpha: float = 0.3
    per_msg_overhead_s: float = 0.0
    window: int = 64                  # (nbytes, elapsed) pairs kept for the fit
    min_fit_samples: int = 4
    _nbytes: list = field(default_factory=list, repr=False)
    _elapsed: list = field(default_factory=list, repr=False)

    @classmethod
    def from_link(cls, link, alpha: float = 0.3) -> "LinkEstimator":
        """Seed the estimator with a link's nominal (t=0) conditions."""
        l = link_at(link, 0.0)
        return cls(rtt_s=l.rtt_s, bw_bytes_per_s=l.bw_bytes_per_s, alpha=alpha,
                   per_msg_overhead_s=l.per_msg_overhead_s)

    def observe(self, nbytes: float, elapsed_s: float, is_rtt_probe: bool = False):
        if is_rtt_probe or nbytes <= 0:
            self.rtt_s = (1 - self.alpha) * self.rtt_s + self.alpha * elapsed_s
            return
        self._nbytes.append(float(nbytes))
        self._elapsed.append(float(elapsed_s))
        if len(self._nbytes) > self.window:
            del self._nbytes[0], self._elapsed[0]
        if len(self._nbytes) >= self.min_fit_samples and self._fit():
            return
        # fallback: per-sample attribution of elapsed = rtt/2 + overhead
        # + bytes/bw (bounded, see devices.attribute_bandwidth)
        bw = attribute_bandwidth(nbytes, elapsed_s, self.rtt_s,
                                 self.per_msg_overhead_s)
        self.bw_bytes_per_s = (1 - self.alpha) * self.bw_bytes_per_s + self.alpha * bw

    def _fit(self) -> bool:
        """Joint least-squares of (overhead, bw) over the window,
        MAD-gated (``fit_link_params_robust``) so the heavy-tailed
        records a *real* transport produces (scheduler preemption
        inflating a few transfers) do not drag the slope; False when
        the window is degenerate (single message size / bad slope)."""
        fit = fit_link_params_robust(self._nbytes, self._elapsed, self.rtt_s)
        if fit is None:
            return False                       # keep the EWMA fallback
        bw, overhead = fit
        self.bw_bytes_per_s = ((1 - self.alpha) * self.bw_bytes_per_s
                               + self.alpha * bw)
        self.per_msg_overhead_s = ((1 - self.alpha) * self.per_msg_overhead_s
                                   + self.alpha * overhead)
        return True

    def as_link(self, name: str = "estimated") -> Link:
        return Link(name, rtt_s=self.rtt_s, bw_bytes_per_s=self.bw_bytes_per_s,
                    per_msg_overhead_s=self.per_msg_overhead_s)


@dataclass
class AdaptiveSplitter:
    graph: BlockGraph
    scenario: Scenario
    batch: int = 8
    policy: Policy = "knee"
    costs: CostTable | None = None
    hysteresis: float = 0.10          # required relative improvement
    # one-off wall-clock cost of moving the split.  None (the default)
    # computes it per candidate move: the moved blocks' weight bytes
    # crossing each hop at that hop's *current estimated* transfer time,
    # plus ``migration_overhead_s``.  A float pins the legacy constant.
    migration_cost_s: float | None = None
    # fixed redeploy overhead (process teardown/rebuild, jit re-warm)
    # added on top of the weight-shipping time when the cost is computed;
    # also the full charge of a codec-only switch (no weights move, but
    # the RECONFIG + in-band WARMUP still cost real time)
    migration_overhead_s: float = 0.05
    energy_budget_j: float | None = None   # max joules/batch (None = unbounded)
    # joint partition × per-hop codec search: when set, every step
    # re-solves over this codec menu (``partitioner.solve_with_codecs``)
    # and a migration may change codecs, cuts, or both — congestion
    # coarsens the wire, recovery refines it.  None pins the scenario's
    # declared codecs (or uncoded).
    codec_choices: Sequence[str] | None = None
    # minimum predicted end-task fidelity: candidates below the floor
    # are dropped before the policy picks (mirroring energy_budget_j)
    accuracy_floor: float | None = None
    # measured per-cut per-codec degradation table (core.codecs
    # .calibrate_codecs); None falls back to nominal codec figures
    calibration: CodecCalibration | None = None
    # per-stage replica counts for the re-solve: a fixed vector, or
    # "auto" to let every step run the greedy spare-device search
    # (partitioner._search_replicas).  Ignored by the joint codec search
    # (codec_choices) — replica × codec co-search is a follow-on.
    replicas: "Sequence[int] | str | None" = None
    # energy-aware migration hysteresis: when set, a candidate split must
    # amortize *both* migration currencies within this horizon — the
    # wall-clock redeploy cost (``migration_cost_s``) out of its per-batch
    # time saving, and the joules of shipping the moved weights over the
    # crossed hops (``migration_energy_j``) out of its per-batch energy
    # saving.  None keeps the plain relative-gain hysteresis.
    amortize_horizon_s: float | None = None
    # the charges computed for the last accepted migration; the runtime
    # charges/records them (wall-clock stall + weights-over-wire joules)
    last_migration_cost_s: float = 0.0
    last_migration_cost_j: float = 0.0
    # charge orchestrator dispatch/return IO in the model?  True for the
    # paper's analytic studies; the executable runtime has no dispatch
    # hop, so the closed loop (runtime.adaptive) solves with False to
    # optimize the objective the pipeline actually exhibits.
    include_io: bool = True
    current: PipelineMetrics | None = None
    history: list = field(default_factory=list)

    def _pick(self, points) -> PipelineMetrics:
        feas = [p for p in points if p.feasible] or points
        if self.accuracy_floor is not None:
            within = [p for p in feas if p.accuracy >= self.accuracy_floor]
            # nothing above the floor: degrade to the most-accurate point
            feas = within or [best_accuracy(feas)]
        if self.energy_budget_j is not None:
            within = [p for p in feas if p.energy_j <= self.energy_budget_j]
            # nothing under budget: degrade to the least-energy point
            feas = within or [best_energy(feas)]
        if self.policy == "latency":
            return best_latency(feas)
        if self.policy == "throughput":
            return best_throughput(feas)
        if self.policy == "energy":
            return best_energy(feas)
        return knee_point(feas) or best_throughput(feas)

    def _objective(self, m: PipelineMetrics) -> float:
        """Lower is better (throughput negated)."""
        if self.policy == "latency":
            return m.latency_s
        if self.policy == "energy":
            return m.energy_j
        return -m.throughput

    def _with_links(self, links) -> Scenario:
        """Scenario with hop links overridden.

        ``links`` may be None (nominal scenario), a single Link (hop 0,
        the 2-stage convention), or a per-hop sequence where ``None``
        entries keep the scenario's own link."""
        scen = self.scenario
        if links is None:
            return scen
        if isinstance(links, (Link, LinkTrace)):
            links = (links,)
        for i, l in enumerate(links):
            if l is not None:
                scen = scen.with_link(i, l, name=scen.name)
        return scen

    def solve(self, link: Link | Sequence[Link | None] | None = None
              ) -> PipelineMetrics:
        return self._pick(self._solve_points(self._with_links(link)))

    def _solve_points(self, scen: Scenario):
        if self.codec_choices is not None:
            # joint partition × codec search keeps all four axes so the
            # accuracy trades stay visible to _pick
            return solve_with_codecs(
                self.graph, scen, self.codec_choices, batch=self.batch,
                costs=self.costs, include_io=self.include_io, objectives=4,
                calibration=self.calibration,
                accuracy_floor=self.accuracy_floor)
        # when energy drives the pick (policy or budget), the DP path must
        # keep the energy axis, or energy-optimal splits get pruned as
        # (latency, throughput)-dominated before _pick ever sees them;
        # an accuracy constraint likewise needs the accuracy axis kept
        objectives = (("latency", "throughput", "energy")
                      if self.policy == "energy"
                      or self.energy_budget_j is not None else None)
        if self.accuracy_floor is not None or self.calibration is not None:
            objectives = 4
        return solve(self.graph, scen, batch=self.batch, costs=self.costs,
                     include_io=self.include_io, objectives=objectives,
                     calibration=self.calibration,
                     accuracy_floor=self.accuracy_floor,
                     replicas=self.replicas)

    def _moved_bytes(self, old: tuple[int, ...], new: tuple[int, ...],
                     new_replicas: Sequence[int] | None = None
                     ) -> dict[int, float]:
        """Weight bytes crossing each hop when redeploying ``old`` →
        ``new``: every block that changes stage ships its weights across
        the hops between its old and new host.  A block landing on a
        stage replicated ``r``× ships ``r`` copies over each crossed hop
        (every replica holds the full stage weights; the source keeps
        one copy to ship from, so only the *destination* count
        multiplies).  → {hop index: bytes}."""
        n = len(self.graph.blocks)
        ob, nb = (0, *old, n), (0, *new, n)

        def stage_of(bounds, b):
            for s in range(len(bounds) - 1):
                if bounds[s] <= b < bounds[s + 1]:
                    return s
            raise ValueError(f"block {b} outside bounds {bounds}")

        moved: dict[int, float] = {}
        for b, blk in enumerate(self.graph.blocks):
            s0, s1 = stage_of(ob, b), stage_of(nb, b)
            if s0 == s1:
                continue
            copies = (new_replicas[s1] if new_replicas is not None else 1)
            for hop in range(min(s0, s1), max(s0, s1)):
                moved[hop] = moved.get(hop, 0.0) + blk.weight_bytes * copies
        return moved

    def migration_energy_j(self, old: tuple[int, ...],
                           new: tuple[int, ...],
                           new_replicas: Sequence[int] | None = None
                           ) -> float:
        """Joules to redeploy from cuts ``old`` to ``new``: the moved
        weight bytes at each crossed hop's ``energy_per_byte_j`` (times
        the destination stage's replica count — r copies ship)."""
        links = [link_at(l, 0.0) for l in self.scenario.links]
        return sum(links[hop].energy_per_byte_j * nbytes
                   for hop, nbytes in
                   self._moved_bytes(old, new, new_replicas).items())

    def migration_time_s(self, old: tuple[int, ...], new: tuple[int, ...],
                         links: Sequence[Link] | None = None,
                         new_replicas: Sequence[int] | None = None) -> float:
        """Wall-clock to redeploy ``old`` → ``new``: the moved weight
        bytes crossing each hop at its transfer time under ``links``
        (the step's fitted estimates; defaults to the scenario's nominal
        links), plus the fixed ``migration_overhead_s``.  A configured
        ``migration_cost_s`` constant overrides the computation."""
        if self.migration_cost_s is not None:
            return self.migration_cost_s
        if links is None:
            links = [link_at(l, 0.0) for l in self.scenario.links]
        return self.migration_overhead_s + sum(
            links[hop].transfer_time(nbytes)
            for hop, nbytes in
            self._moved_bytes(old, new, new_replicas).items()
            if nbytes > 0)

    def _amortizes(self, cur: PipelineMetrics, cand: PipelineMetrics,
                   cost_j: float, cost_s: float | None = None) -> bool:
        """Does the candidate pay back both migration currencies within
        ``amortize_horizon_s``?  Batches served in the horizon come from
        the candidate's own throughput (the post-migration rate)."""
        horizon = self.amortize_horizon_s
        if horizon is None:
            return True
        if cost_s is None:
            cost_s = self.migration_time_s(cur.partition, cand.partition)
        batch_time = self.batch / max(cand.throughput, 1e-12)
        n = max(horizon / max(batch_time, 1e-12), 0.0)
        # time currency: per-batch serving-time saving must cover the
        # redeploy stall within the horizon (vacuously true for a free
        # move — an energy-motivated migration may well be time-neutral)
        t_cur = self.batch / max(cur.throughput, 1e-12)
        if cost_s > 0.0 and (t_cur - batch_time) * n < cost_s:
            return False
        # energy currency: per-batch joule saving must cover the weight
        # shipment (vacuously true for a free move)
        if cost_j > 0.0 and (cur.energy_j - cand.energy_j) * n < cost_j:
            return False
        return True

    def _reprice(self, partition: tuple[int, ...], scen: Scenario,
                 codecs: Sequence[str] | None = None,
                 replicas: Sequence[int] | None = None
                 ) -> PipelineMetrics | None:
        """Re-evaluate the *current* cuts (and codecs/replicas) under
        new conditions; None when the cut vector is no longer valid for
        the graph/chain (e.g. the graph or pipeline depth changed
        between steps)."""
        static = scen.at(0.0)
        try:
            return evaluate_pipeline(self.graph, partition, static.devices,
                                     static.links, batch=self.batch,
                                     costs=self.costs,
                                     include_io=self.include_io,
                                     codecs=codecs,
                                     calibration=self.calibration,
                                     replicas=replicas)
        except ValueError:
            return None

    def step(self, estimator: "LinkEstimator | Sequence[LinkEstimator]"
             ) -> tuple[PipelineMetrics, bool]:
        """Re-evaluate with the current link estimate(s).

        ``estimator`` is one LinkEstimator (2-stage convention: hop 0) or
        a per-hop sequence.  Returns the active partition and whether a
        migration happened."""
        ests = ([estimator] if isinstance(estimator, LinkEstimator)
                else list(estimator))
        links = [e.as_link(f"est_hop{i}") for i, e in enumerate(ests)]
        scen = self._with_links(links)
        cand = self._pick(self._solve_points(scen))
        migrated = False
        self.last_migration_cost_s = 0.0
        self.last_migration_cost_j = 0.0
        if self.current is None:
            self.current, migrated = cand, True
        elif (cand.partition != self.current.partition
              or cand.codecs != self.current.codecs):
            cost_j = self.migration_energy_j(self.current.partition,
                                             cand.partition,
                                             new_replicas=cand.replicas
                                             or None)
            # codec-only switches move no weights: cost_s degrades to the
            # fixed overhead (still charged — RECONFIG + WARMUP are real)
            cost_s = self.migration_time_s(self.current.partition,
                                           cand.partition, links,
                                           new_replicas=cand.replicas
                                           or None)
            # re-price the *current* split (and codecs) under the new
            # conditions
            cur = self._reprice(self.current.partition, scen,
                                codecs=self.current.codecs or None,
                                replicas=self.current.replicas or None)
            if cur is None:
                # current cuts are stale/invalid — must migrate
                self.current, migrated = cand, True
            elif (self.energy_budget_j is not None
                  and cur.energy_j > self.energy_budget_j >= cand.energy_j):
                # current split violates the energy budget and the
                # candidate fits: a constraint breach overrides hysteresis
                # (and the amortization gate — staying put keeps burning
                # over-budget joules every batch)
                self.current, migrated = cand, True
            else:
                old, new = self._objective(cur), self._objective(cand)
                gain = (old - new) / max(abs(old), 1e-12)
                if gain > self.hysteresis and self._amortizes(cur, cand,
                                                              cost_j,
                                                              cost_s=cost_s):
                    self.current, migrated = cand, True
                else:
                    self.current = cur
            if migrated:
                self.last_migration_cost_s = cost_s
                self.last_migration_cost_j = cost_j
        else:
            self.current = cand
        self.history.append((self.current.partition, migrated))
        return self.current, migrated
