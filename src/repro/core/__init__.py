"""ParetoPipe core: multi-objective DNN partitioning (the paper's contribution).

Public API:
    Block, BlockGraph, chain          — block-level model abstraction
    DeviceProfile, Link, LinkTrace    — hardware/network models (static +
                                        time-varying links)
    CostTable, evaluate_pipeline      — pipeline performance model
    solve                             — unified scenario-driven search
    sweep_2way, sweep_kway,
    dp_front_kway                     — partition search engines
    Objective, LATENCY, THROUGHPUT,
    ENERGY, resolve_objectives        — the objective-vector protocol
    pareto_front, knee_point,
    hypervolume, dominates            — Pareto machinery (any d, per-axis
                                        min/max sense)
    Scenario, scenarios.get           — named testbeds (paper + TPU pods)
    AdaptiveSplitter, LinkEstimator   — network-aware runtime re-splitting
"""
from .blocks import Block, BlockGraph, chain
from .costmodel import CostTable, PipelineMetrics, StageMetrics, evaluate_pipeline
from .devices import (DeviceProfile, Link, LinkTrace, link_at, ramp_trace,
                      sawtooth_trace, spike_trace, step_trace)
from .pareto import (ENERGY, LATENCY, THROUGHPUT, Objective, dominates,
                     hypervolume, is_on_front, knee_point, pareto_front,
                     resolve_objectives)
from .partitioner import (best_energy, best_latency, best_throughput,
                          dp_front_kway, solve, sweep_2way, sweep_kway)
from .autosplit import AdaptiveSplitter, LinkEstimator
from .scenarios import Scenario
from . import devices, scenarios, profiler

__all__ = [
    "Block", "BlockGraph", "chain",
    "CostTable", "PipelineMetrics", "StageMetrics", "evaluate_pipeline",
    "DeviceProfile", "Link", "LinkTrace", "link_at", "ramp_trace",
    "sawtooth_trace", "spike_trace", "step_trace",
    "Objective", "LATENCY", "THROUGHPUT", "ENERGY", "resolve_objectives",
    "dominates", "hypervolume", "is_on_front", "knee_point", "pareto_front",
    "best_energy", "best_latency", "best_throughput", "dp_front_kway", "solve",
    "sweep_2way", "sweep_kway",
    "AdaptiveSplitter", "LinkEstimator", "Scenario",
    "devices", "scenarios", "profiler",
]
