"""ParetoPipe core: multi-objective DNN partitioning (the paper's contribution).

Public API:
    Block, BlockGraph, chain          — block-level model abstraction
    DeviceProfile, Link, LinkTrace    — hardware/network models (static +
                                        time-varying links)
    CostTable, evaluate_pipeline      — pipeline performance model
    solve                             — unified scenario-driven search
    sweep_2way, sweep_kway,
    dp_front_kway                     — partition search engines
    pareto_front, knee_point,
    hypervolume, dominates            — Pareto machinery
    Scenario, scenarios.get           — named testbeds (paper + TPU pods)
    AdaptiveSplitter, LinkEstimator   — network-aware runtime re-splitting
"""
from .blocks import Block, BlockGraph, chain
from .costmodel import CostTable, PipelineMetrics, StageMetrics, evaluate_pipeline
from .devices import (DeviceProfile, Link, LinkTrace, link_at, ramp_trace,
                      step_trace)
from .pareto import dominates, hypervolume, is_on_front, knee_point, pareto_front
from .partitioner import (best_latency, best_throughput, dp_front_kway, solve,
                          sweep_2way, sweep_kway)
from .autosplit import AdaptiveSplitter, LinkEstimator
from .scenarios import Scenario
from . import devices, scenarios, profiler

__all__ = [
    "Block", "BlockGraph", "chain",
    "CostTable", "PipelineMetrics", "StageMetrics", "evaluate_pipeline",
    "DeviceProfile", "Link", "LinkTrace", "link_at", "ramp_trace", "step_trace",
    "dominates", "hypervolume", "is_on_front", "knee_point", "pareto_front",
    "best_latency", "best_throughput", "dp_front_kway", "solve",
    "sweep_2way", "sweep_kway",
    "AdaptiveSplitter", "LinkEstimator", "Scenario",
    "devices", "scenarios", "profiler",
]
