"""Named deployment scenarios (device chain + links).

The paper's four experimental conditions plus the TPU-scale analogues the
framework actually deploys on.  A ``Scenario`` is what the partitioner
consumes: an ordered device chain with the links between them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from . import devices as D


@dataclass(frozen=True)
class Scenario:
    name: str
    devices: tuple[D.DeviceProfile, ...]
    links: tuple[D.Link, ...]

    def __post_init__(self):
        if len(self.links) != len(self.devices) - 1:
            raise ValueError("need len(devices)-1 links")

    def with_link(self, i: int, link: D.Link, name: str | None = None) -> "Scenario":
        links = list(self.links)
        links[i] = link
        return Scenario(name or f"{self.name}+{link.name}", self.devices, tuple(links))


# --- the paper's testbed ---------------------------------------------------- #
def pi_to_pi() -> Scenario:
    return Scenario("pi_to_pi", (D.PI_4B, D.PI_4B), (D.LAN_PI_PI,))


def pi_to_gpu() -> Scenario:
    return Scenario("pi_to_gpu", (D.PI_4B, D.RTX_4090), (D.LAN_PI_GPU,))


def duress(base: Scenario) -> Scenario:
    """Paper Sec. V-B: tc-imposed 200 ms RTT + 5 Mbit/s on the first hop."""
    return base.with_link(0, D.DURESS, name=f"{base.name}_duress")


# --- TPU-scale analogues ----------------------------------------------------- #
def pods(n_pods: int = 2, chips_per_pod: int = 256,
         link: D.Link = D.DCN) -> Scenario:
    """n pods in a pipeline, DCN links between consecutive pods —
    the multi-pod mesh's ``pod`` axis as a ParetoPipe device chain."""
    devs = tuple(D.tpu_pod(chips_per_pod, name=f"pod{i}") for i in range(n_pods))
    return Scenario(f"pods{n_pods}x{chips_per_pod}", devs, (link,) * (n_pods - 1))


def pods_congested(n_pods: int = 2, chips_per_pod: int = 256) -> Scenario:
    """The duress analogue at datacenter scale: congested DCN."""
    s = pods(n_pods, chips_per_pod, link=D.DCN_CONGESTED)
    return dataclasses.replace(s, name=s.name + "_congested")


def chips_linear(n: int = 4, link: D.Link = D.ICI_V5E) -> Scenario:
    """A few chips in a ring/line over ICI — single-host pipelining."""
    devs = tuple(dataclasses.replace(D.TPU_V5E_CHIP, name=f"chip{i}")
                 for i in range(n))
    return Scenario(f"chips{n}_ici", devs, (link,) * (n - 1))


REGISTRY = {
    "pi_to_pi": pi_to_pi,
    "pi_to_gpu": pi_to_gpu,
    "pi_to_pi_duress": lambda: duress(pi_to_pi()),
    "pi_to_gpu_duress": lambda: duress(pi_to_gpu()),
    "pods2": lambda: pods(2),
    "pods2_congested": lambda: pods_congested(2),
    "pods4": lambda: pods(4),
    "chips4_ici": lambda: chips_linear(4),
}


def get(name: str) -> Scenario:
    try:
        return REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; have {sorted(REGISTRY)}") from None
