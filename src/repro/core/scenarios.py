"""Named deployment scenarios (device chain + links).

The paper's four experimental conditions plus the TPU-scale analogues the
framework actually deploys on.  A ``Scenario`` is what the partitioner
*and* the executable runtime consume: an ordered device chain with the
links between consecutive devices.  Links may be static ``Link``s or
time-varying ``LinkTrace``s — ``Scenario.at(t)`` resolves every trace to
its value at time ``t`` for the analytic side, while the runtime samples
traces per transfer.

Every registry entry carries the measured power calibration needed by
the energy objective: Pi 4B 2.7 W idle / 6.4 W active, RTX 4090 22 W /
320 W, v5e 60 W / 170 W per chip (see ``core.devices``), plus per-byte
radio cost on each link (GbE NIC pair ≈ 12 nJ/B; the duress WAN at
cellular-like 1 µJ/B) — so ``solve(..., objectives=("latency",
"throughput", "energy"))`` works on any scenario out of the box.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from . import devices as D


@dataclass(frozen=True)
class Scenario:
    name: str
    devices: tuple[D.DeviceProfile, ...]
    links: tuple[D.AnyLink, ...]
    # per-hop transport names (see runtime.transport.TRANSPORTS): None
    # defers to the pipeline default ("emulated"); "socket"/"shmem" make
    # the hop a *measured* real channel between worker processes
    transports: tuple[str, ...] | None = None
    # per-hop wire codec names (see core.codecs.CODECS): None defers to
    # the pipeline default ("none" everywhere); declared per hop exactly
    # like transports, consumed by both the cost model (packed bytes +
    # accuracy axis) and the runtime (Pallas pack on the wire)
    codecs: tuple[str, ...] | None = None
    # idle devices available for stage replication: solve(replicas="auto")
    # staffs extra replicas of a stage from spares whose profile *name*
    # matches the stage's assigned device (identical copies — same
    # compute model per replica)
    spare_devices: tuple[D.DeviceProfile, ...] = ()

    def __post_init__(self):
        if len(self.links) != len(self.devices) - 1:
            raise ValueError("need len(devices)-1 links")
        if self.transports is not None and \
                len(self.transports) != len(self.links):
            raise ValueError("need one transport per link")
        if self.codecs is not None and len(self.codecs) != len(self.links):
            raise ValueError("need one codec per link")

    @property
    def n_stages(self) -> int:
        return len(self.devices)

    @property
    def time_varying(self) -> bool:
        return any(isinstance(l, D.LinkTrace) for l in self.links)

    @property
    def active_power_w(self) -> float:
        """Chain power with every device busy — the energy model's upper
        envelope (per-partition joules come from ``PipelineMetrics``)."""
        return sum(d.active_w for d in self.devices)

    def with_link(self, i: int, link: D.AnyLink, name: str | None = None) -> "Scenario":
        links = list(self.links)
        links[i] = link
        return Scenario(name or f"{self.name}+{link.name}", self.devices,
                        tuple(links), self.transports, self.codecs,
                        self.spare_devices)

    def with_transport(self, transport: "str | tuple[str, ...]",
                       name: str | None = None) -> "Scenario":
        """Scenario with every hop (or a per-hop tuple) on ``transport``."""
        if isinstance(transport, str):
            transports = (transport,) * len(self.links)
        else:
            transports = tuple(transport)
        return Scenario(name or self.name, self.devices, self.links,
                        transports, self.codecs, self.spare_devices)

    def with_codec(self, codec: "str | tuple[str, ...]",
                   name: str | None = None) -> "Scenario":
        """Scenario with every hop (or a per-hop tuple) on wire ``codec``."""
        if isinstance(codec, str):
            codecs = (codec,) * len(self.links)
        else:
            codecs = tuple(codec)
        return Scenario(name or self.name, self.devices, self.links,
                        self.transports, codecs, self.spare_devices)

    def at(self, t: float = 0.0) -> "Scenario":
        """Static snapshot: every LinkTrace resolved to its link at ``t``."""
        if not self.time_varying:
            return self
        return Scenario(self.name, self.devices,
                        tuple(D.link_at(l, t) for l in self.links),
                        self.transports, self.codecs, self.spare_devices)


# --- the paper's testbed ---------------------------------------------------- #
def pi_to_pi() -> Scenario:
    return Scenario("pi_to_pi", (D.PI_4B, D.PI_4B), (D.LAN_PI_PI,))


def pi_to_gpu() -> Scenario:
    return Scenario("pi_to_gpu", (D.PI_4B, D.RTX_4090), (D.LAN_PI_GPU,))


def pi_pi_gpu() -> Scenario:
    """Three-stage edge chain: two Pis feeding the GPU server — the
    cluster depth the k-way engines reason about, now executable."""
    return Scenario("pi_pi_gpu", (D.PI_4B, D.PI_4B, D.RTX_4090),
                    (D.LAN_PI_PI, D.LAN_PI_GPU))


def pi_cluster(n_spares: int = 1) -> Scenario:
    """The replication testbed: the 3-stage pi_pi_gpu chain plus
    ``n_spares`` idle Pis.  The chain alone pins throughput to the
    slowest Pi stage while the GPU starves; ``solve(replicas="auto")``
    staffs the bottleneck Pi stage from the spares (Parthasarathy &
    Krishnamachari's throughput-max placement).  ``pi_cluster4`` /
    ``pi_cluster5`` in the registry = 4 / 5 devices total."""
    if n_spares < 1:
        raise ValueError("need n_spares >= 1")
    base = pi_pi_gpu()
    return dataclasses.replace(base, name=f"pi_cluster{3 + n_spares}",
                               spare_devices=(D.PI_4B,) * n_spares)


def pi_chain(k: int = 3) -> Scenario:
    """k-1 Pis in a line feeding a GPU — arbitrary-depth edge cluster."""
    if k < 2:
        raise ValueError("need k >= 2 stages")
    devs = (D.PI_4B,) * (k - 1) + (D.RTX_4090,)
    links = (D.LAN_PI_PI,) * (k - 2) + (D.LAN_PI_GPU,)
    return Scenario(f"pi_chain{k}", devs, links)


def pi_only_chain(k: int = 3) -> Scenario:
    """k Pis, no GPU — the battery-bound deployment the energy objective
    is for: every stage costs the same watts, so the (latency,
    throughput, energy) front is decided by balance vs. bytes moved."""
    if k < 2:
        raise ValueError("need k >= 2 stages")
    return Scenario(f"pi_only{k}", (D.PI_4B,) * k,
                    (D.LAN_PI_PI,) * (k - 1))


def duress(base: Scenario) -> Scenario:
    """Paper Sec. V-B: tc-imposed 200 ms RTT + 5 Mbit/s on the first hop."""
    return base.with_link(0, D.DURESS, name=f"{base.name}_duress")


def wan_ramp(base: Scenario, hop: int = 0, t_start: float = 2.0,
             t_end: float = 6.0, jitter: float = 0.05) -> Scenario:
    """Time-varying duress: hop ``hop`` degrades linearly from its
    healthy value to the paper's 200 ms / 5 Mbit WAN between ``t_start``
    and ``t_end`` (trace time), with mild jitter — the condition the
    adaptive loop is built to survive."""
    healthy = D.link_at(base.links[hop], 0.0)
    trace = D.ramp_trace(f"{healthy.name}_wan_ramp", healthy, D.DURESS,
                         t_start, t_end, jitter=jitter)
    return base.with_link(hop, trace, name=f"{base.name}_wan_ramp")


# --- curated WAN trace mini-library ------------------------------------------ #
# Named, replayable time-varying links for adaptive-under-streaming
# studies: each is a factory so every caller gets a fresh (immutable)
# LinkTrace.  ``traces.get(name)`` / the scenario registry's
# ``pi_pi_gpu_<trace>`` entries put them on hop 0 of the 3-stage chain.
TRACES = {
    # healthy LAN until t=3 s, then the paper's tc-netem duress — the
    # Sec. V-B experiment as a trace (sharpest possible degradation)
    "wan_step_drop": lambda: D.step_trace(
        "wan_step_drop", D.LAN_PI_GPU, D.DURESS, t_step=3.0, jitter=0.03),
    # LTE-like sawtooth: 4 s cells, each ramping LAN→duress over 60 %
    # of the period then snapping back (handover recovery)
    "lte_sawtooth": lambda: D.sawtooth_trace(
        "lte_sawtooth", D.LAN_PI_GPU, D.DURESS, period_s=4.0, n_periods=4,
        duty=0.6, jitter=0.05),
    # one congestion event: clean until t=2 s, fully congested by t=4 s,
    # recovered by t=7 s — the loop should migrate out *and back*
    "congestion_spike": lambda: D.spike_trace(
        "congestion_spike", D.LAN_PI_GPU, D.DURESS, t_start=2.0, t_peak=4.0,
        t_end=7.0, jitter=0.05),
    # slow monotone collapse (the registry wan-ramp shape, jittered)
    "wan_slow_ramp": lambda: D.ramp_trace(
        "wan_slow_ramp", D.LAN_PI_GPU, D.DURESS, t_start=2.0, t_end=8.0,
        jitter=0.05),
}


def get_trace(name: str) -> D.LinkTrace:
    try:
        return TRACES[name]()
    except KeyError:
        raise KeyError(f"unknown trace {name!r}; have "
                       f"{sorted(TRACES)}") from None


def with_trace(base: Scenario, trace_name: str, hop: int = 0) -> Scenario:
    """``base`` with the named curated trace on hop ``hop``."""
    return base.with_link(hop, get_trace(trace_name),
                          name=f"{base.name}_{trace_name}")


# --- the real local testbed (measured transports) ---------------------------- #
def local_chain(k: int = 3, transport: str = "socket") -> Scenario:
    """k worker *processes* on this host, every hop a real measured
    channel (loopback TCP by default, ``transport="shmem"`` for the
    shared-memory ring).  The LOOPBACK link is only the analytic
    stand-in the partitioner plans with — the pipeline measures the
    actual wire."""
    if k < 2:
        raise ValueError("need k >= 2 stages")
    return Scenario(f"local{k}_{transport}", (D.HOST_CPU,) * k,
                    (D.LOOPBACK,) * (k - 1),
                    transports=(transport,) * (k - 1))


# --- TPU-scale analogues ----------------------------------------------------- #
def pods(n_pods: int = 2, chips_per_pod: int = 256,
         link: D.Link = D.DCN) -> Scenario:
    """n pods in a pipeline, DCN links between consecutive pods —
    the multi-pod mesh's ``pod`` axis as a ParetoPipe device chain."""
    devs = tuple(D.tpu_pod(chips_per_pod, name=f"pod{i}") for i in range(n_pods))
    return Scenario(f"pods{n_pods}x{chips_per_pod}", devs, (link,) * (n_pods - 1))


def pods_congested(n_pods: int = 2, chips_per_pod: int = 256) -> Scenario:
    """The duress analogue at datacenter scale: congested DCN."""
    s = pods(n_pods, chips_per_pod, link=D.DCN_CONGESTED)
    return dataclasses.replace(s, name=s.name + "_congested")


def chips_linear(n: int = 4, link: D.Link = D.ICI_V5E) -> Scenario:
    """A few chips in a ring/line over ICI — single-host pipelining."""
    devs = tuple(dataclasses.replace(D.TPU_V5E_CHIP, name=f"chip{i}")
                 for i in range(n))
    return Scenario(f"chips{n}_ici", devs, (link,) * (n - 1))


REGISTRY = {
    "pi_to_pi": pi_to_pi,
    "pi_to_gpu": pi_to_gpu,
    "pi_pi_gpu": pi_pi_gpu,
    "pi_chain4": lambda: pi_chain(4),
    "pi_cluster4": lambda: pi_cluster(1),
    "pi_cluster5": lambda: pi_cluster(2),
    "pi_only3": lambda: pi_only_chain(3),
    "pi_only3_duress": lambda: duress(pi_only_chain(3)),
    "pi_to_pi_duress": lambda: duress(pi_to_pi()),
    "pi_to_gpu_duress": lambda: duress(pi_to_gpu()),
    "pi_to_gpu_wan_ramp": lambda: wan_ramp(pi_to_gpu()),
    "pi_pi_gpu_wan_ramp": lambda: wan_ramp(pi_pi_gpu()),
    "pi_pi_gpu_step_drop": lambda: with_trace(pi_pi_gpu(), "wan_step_drop"),
    "pi_pi_gpu_lte_sawtooth": lambda: with_trace(pi_pi_gpu(), "lte_sawtooth"),
    "pi_pi_gpu_congestion_spike": lambda: with_trace(pi_pi_gpu(),
                                                     "congestion_spike"),
    "local3_socket": lambda: local_chain(3, "socket"),
    "local3_shmem": lambda: local_chain(3, "shmem"),
    "pi_pi_gpu_socket": lambda: pi_pi_gpu().with_transport(
        "socket", name="pi_pi_gpu_socket"),
    "pi_pi_gpu_int8": lambda: pi_pi_gpu().with_codec(
        "int8", name="pi_pi_gpu_int8"),
    "pi_pi_gpu_congestion_spike_int8": lambda: with_trace(
        pi_pi_gpu(), "congestion_spike").with_codec(
        "int8", name="pi_pi_gpu_congestion_spike_int8"),
    "pods2": lambda: pods(2),
    "pods2_congested": lambda: pods_congested(2),
    "pods4": lambda: pods(4),
    "chips4_ici": lambda: chips_linear(4),
}


def get(name: str) -> Scenario:
    try:
        return REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; have {sorted(REGISTRY)}") from None


# --------------------------------------------------------------------------- #
# Named fault plans — chaos scripts for the fault-tolerance matrix.
# Factories import lazily (runtime.faults imports runtime.transport, which
# core must not depend on at module load).  Seqs are global batch indices
# on the feed hop (hop -1); worker kills name (stage, lane).
# --------------------------------------------------------------------------- #
def _plans():
    from ..runtime.faults import FaultPlan
    return {
        # the canonical restart drill: SIGKILL stage 1 mid-stream
        "kill_mid_stream": lambda: FaultPlan(seed=1).kill_worker(
            stage=1, at_seq=3),
        # replica failover: kill one lane of a replicated stage
        "lane_kill": lambda: FaultPlan(seed=2).kill_worker(
            stage=1, at_seq=3, lane=1),
        # WAN under duress: a stall, then a flap, on the feed hop
        "wan_duress": lambda: FaultPlan(seed=3)
            .stall(hop=-1, at_seq=2, for_s=0.3)
            .flap(hop=-1, at_seq=5, down_s=0.5),
        # lossy feed: a dropped and a duplicated frame
        "lossy_feed": lambda: FaultPlan(seed=4)
            .drop(hop=-1, at_seq=2)
            .duplicate(hop=-1, at_seq=5),
        # bit-rot on the wire: one corrupt frame header
        "header_rot": lambda: FaultPlan(seed=5).corrupt(hop=-1, at_seq=2),
    }


FAULT_PLANS = ("kill_mid_stream", "lane_kill", "wan_duress", "lossy_feed",
               "header_rot")


def get_fault_plan(name: str):
    """Build the named :class:`~repro.runtime.faults.FaultPlan`."""
    try:
        return _plans()[name]()
    except KeyError:
        raise KeyError(
            f"unknown fault plan {name!r}; have {sorted(FAULT_PLANS)}") from None


# --------------------------------------------------------------------------- #
# Named tenant mixes — multi-tenant workload specs for the serving
# gateway (runtime/serve.py).  A mix is pure data, like a Scenario: who
# the tenants are, their latency SLOs, and the arrival pattern the
# fairness matrix / bench drive them with.
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant of the serving gateway: its latency SLO and the
    workload shape it contributes to a mix."""

    name: str
    slo_s: float = 0.5              # per-request latency SLO (queue+service)
    weight: float = 1.0             # relative admission share in the mix
    burst: int = 1                  # requests dumped per arrival event

    def __post_init__(self):
        if self.slo_s <= 0:
            raise ValueError(f"tenant {self.name}: need slo_s > 0")
        if self.burst < 1:
            raise ValueError(f"tenant {self.name}: need burst >= 1")


@dataclasses.dataclass(frozen=True)
class TenantMix:
    """A named multi-tenant workload: the tenants plus how their
    requests arrive ("uniform" = one request per tenant per round,
    "bursty" = each tenant dumps its ``burst`` requests per round)."""

    name: str
    tenants: tuple[TenantSpec, ...]
    arrival: str = "uniform"

    def __post_init__(self):
        if self.arrival not in ("uniform", "bursty"):
            raise ValueError(f"unknown arrival pattern {self.arrival!r}")
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"mix {self.name}: duplicate tenant names")

    @property
    def n_tenants(self) -> int:
        return len(self.tenants)

    def spec(self, name: str) -> TenantSpec:
        for t in self.tenants:
            if t.name == name:
                return t
        raise KeyError(f"mix {self.name} has no tenant {name!r}")


def _uniform_tenants(n: int, slo_s: float = 0.5) -> tuple[TenantSpec, ...]:
    return tuple(TenantSpec(f"tenant{i}", slo_s=slo_s) for i in range(n))


def _bursty_tenants(n: int, slo_s: float = 0.5,
                    burst: int = 4) -> tuple[TenantSpec, ...]:
    # alternate steady and bursty tenants so the mix actually mixes
    return tuple(TenantSpec(f"tenant{i}", slo_s=slo_s,
                            burst=burst if i % 2 else 1) for i in range(n))


def _mixed_slo_tenants(n: int = 8) -> tuple[TenantSpec, ...]:
    # SLOs spread over an order of magnitude: strict interactive
    # tenants next to lax batch ones, the fleet controller's worst case
    slos = (0.15, 0.3, 0.5, 0.75, 1.0, 1.25, 1.5, 2.0)
    return tuple(TenantSpec(f"tenant{i}", slo_s=slos[i % len(slos)])
                 for i in range(n))


TENANT_MIXES: dict[str, "TenantMix"] = {
    "duo_uniform": TenantMix("duo_uniform", _uniform_tenants(2)),
    "duo_bursty": TenantMix("duo_bursty", _bursty_tenants(2),
                            arrival="bursty"),
    "octet_uniform": TenantMix("octet_uniform", _uniform_tenants(8)),
    "octet_bursty": TenantMix("octet_bursty", _bursty_tenants(8),
                              arrival="bursty"),
    "octet_mixed_slo": TenantMix("octet_mixed_slo", _mixed_slo_tenants(8)),
}


def get_tenant_mix(name: str) -> TenantMix:
    try:
        return TENANT_MIXES[name]
    except KeyError:
        raise KeyError(f"unknown tenant mix {name!r}; "
                       f"have {sorted(TENANT_MIXES)}") from None
