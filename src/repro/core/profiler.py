"""Block-wise profiling (paper Sec. IV-D / Fig. 2).

Three cost sources, all feeding the same ``CostTable``:

  * ``profile_wallclock`` — run each block's jitted function on this host
    and measure it (the paper's psutil/wall-clock methodology).
  * ``profile_analytic``  — per-block FLOPs / device effective rate.
  * ``costs_from_hlo``    — per-block FLOPs taken from compiled-HLO
    ``cost_analysis`` of the real jitted block (the dry-run-native
    equivalent for the TPU target, where wall-clock is unavailable).
"""
from __future__ import annotations

import time
from typing import Callable, Sequence

import jax

from .blocks import BlockGraph
from .costmodel import CostTable
from .devices import DeviceProfile


def profile_wallclock(
    device_name: str,
    block_fns: Sequence[Callable],
    block_names: Sequence[str],
    make_input: Callable[[int], object],
    repeats: int = 5,
    warmup: int = 1,
    table: CostTable | None = None,
) -> CostTable:
    """Measure each block on the current host.

    ``block_fns[i]`` maps the activation produced by block i-1 to block
    i's output; ``make_input(0)`` builds the model input.  Each config is
    run ``repeats`` times and averaged, mirroring the paper's 5-run mean.
    """
    table = table or CostTable()
    x = make_input(0)
    for name, fn in zip(block_names, block_fns):
        jfn = jax.jit(fn)
        for _ in range(warmup):
            y = jfn(x)
        jax.block_until_ready(y)
        t0 = time.perf_counter()
        for _ in range(repeats):
            y = jfn(x)
        jax.block_until_ready(y)
        dt = (time.perf_counter() - t0) / repeats
        table.set(device_name, name, dt)
        x = y
    return table


def profile_analytic(graph: BlockGraph, device: DeviceProfile, batch: int = 1,
                     table: CostTable | None = None) -> CostTable:
    table = table or CostTable()
    per_block_overhead = device.stage_overhead_s / max(graph.n_blocks, 1)
    for b in graph.blocks:
        table.set(device.name, b.name,
                  b.flops * batch / device.flops_per_s + per_block_overhead)
    return table


def costs_from_hlo(
    device: DeviceProfile,
    block_fns: Sequence[Callable],
    block_names: Sequence[str],
    example_inputs: Sequence,
    table: CostTable | None = None,
) -> CostTable:
    """Per-block cost from XLA's own flop count: lower+compile each block
    (no execution) and convert cost_analysis FLOPs to seconds with the
    device's effective rate, max'ed with the memory-bandwidth term."""
    table = table or CostTable()
    for name, fn, x in zip(block_names, block_fns, example_inputs):
        compiled = jax.jit(fn).lower(x).compile()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # jax<=0.4: one dict per device
            ca = ca[0] if ca else {}
        flops = float(ca.get("flops", 0.0))
        nbytes = float(ca.get("bytes accessed", 0.0))
        table.set(device.name, name, device.compute_time(flops, nbytes))
    return table


def coefficient_of_variation(times: Sequence[float]) -> float:
    """Used to validate Fig 2's finding: block costs are heterogeneous."""
    import math
    n = len(times)
    if n == 0:
        return 0.0
    mu = sum(times) / n
    if mu == 0:
        return 0.0
    var = sum((t - mu) ** 2 for t in times) / n
    return math.sqrt(var) / mu
