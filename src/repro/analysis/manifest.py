"""Pinned wire-protocol facts that PipeCheck holds the tree to.

This module is the *other half* of every protocol constant in the
runtime: the checker (`repro.analysis.pipecheck`) compares what the
source tree declares against what is recorded here, so changing a wire
code, a struct layout, or a token kind requires a matching, conscious
edit in this file.  That friction is the point — protocol drift should
fail `make check`, not a matrix test three PRs later.
"""
from __future__ import annotations

# The in-band token kinds, in wire order (append-only: a kind byte,
# once shipped in a frame header, is never reused or renamed).
# `BATCH…CANCEL = range(9)` in runtime/transport.py must enumerate
# exactly these names.  CANCEL (v10) is the flush fence: the gateway
# submits it behind canceled in-flight batches; workers forward it and,
# for flush-cancels, skip compute on every batch ahead of it.
TOKEN_KINDS: tuple[str, ...] = (
    "BATCH", "WARMUP", "PROBE", "RECONFIG", "STATS", "STOP", "ERROR", "CLOCK",
    "CANCEL",
)

# Codec wire codes are append-only: a code, once shipped in a frame
# header, can never be reused or renamed (stateless decode relies on
# it).  New codecs append the next free code here *and* in
# core/codecs.py; R2 fails on any divergence.
CODEC_WIRE_CODES: dict[int, str] = {
    0: "none",
    1: "int8",
    2: "fp8",
    3: "topk",
}

# Struct layouts per WIRE_LAYOUT_VERSION, whitespace-normalised.  An
# edit to _FHDR/_RREC in runtime/transport.py must bump
# WIRE_LAYOUT_VERSION there and append the new shapes here (R5).
WIRE_LAYOUT_VERSION: int = 2
WIRE_LAYOUTS: dict[int, dict[str, str]] = {
    1: {
        "_FHDR": "!BBbBBIdQ8q",
        "_RREC": "<BBbBBiIIdQ8q",
    },
    # v2: a per-frame wire sequence number (Q) after the payload length,
    # stamped by every sender so receivers can drop already-delivered
    # BATCH frames (chaos duplicates, recovery replays)
    2: {
        "_FHDR": "!BBbBBIdQQ8q",
        "_RREC": "<BBbBBiIIdQQ8q",
    },
}

# The full surface every concrete Channel must implement (R3): the two
# abstract halves plus the concrete contract the engines rely on.
CHANNEL_SURFACE: tuple[str, ...] = (
    "send", "recv", "close", "reap", "split", "set_codec",
)

# Declared pickle escape hatches (R4): (path suffix, qualname prefix)
# pairs inside which `pickle.dumps/loads` is legitimate — the
# `framing="pickle"` serializer and the exotic-meta fallback in the
# packed framer.  Anywhere else under runtime/ is a hot path.
PICKLE_ALLOWED: tuple[tuple[str, str], ...] = (
    ("runtime/transport.py", "_Serializer"),
    ("runtime/transport.py", "_frame"),
    ("runtime/transport.py", "_unframe"),
    ("runtime/transport.py", "_decode"),
)
