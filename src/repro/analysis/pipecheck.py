"""PipeCheck — repo-specific static invariants over the runtime protocol.

An AST pass (no imports of the checked code, no execution) that holds
``src/`` to the transport-protocol invariants the matrix tests only
catch dynamically:

  R1  every ``kind ==`` / ``kind in`` dispatch ladder over transport
      tokens is exhaustive for every manifest kind or ends in an explicit
      default (``else``) / falls through to further handling — silent
      token drops are how protocol bugs hide.
  R2  codec registry wire codes are append-only and collision-free
      against :mod:`repro.analysis.manifest`; every lossy codec
      overrides the analytic ``wire_bytes``/``encode``/``decode``
      surface and every ``ops.<fn>`` it calls has a ``<fn>_ref``
      oracle in ``kernels/ref.py``.
  R3  every concrete ``Channel`` subclass implements the full surface
      (``send``/``recv``/``close``/``reap``/``split``/``set_codec``),
      and observation ``record(...)`` calls on runtime paths carry
      ``raw_bytes`` so wire accounting never silently degrades.
  R4  no ``pickle`` on runtime hot paths outside the declared escape
      hatches (``framing="pickle"`` serializer, exotic-meta fallback).
  R5  ``_FHDR``/``_RREC`` struct layouts match the manifest entry for
      the declared ``WIRE_LAYOUT_VERSION`` — field edits must bump the
      version and append the new shape to the manifest.
  R6  every blocking channel op in ``runtime/`` is timeout-guarded: a
      bare ``recv()`` (no timeout argument) needs a ``poll(...)``
      liveness loop on the same object in the same function, and any
      raw socket ``sendmsg``/``sendall`` needs a ``settimeout``/
      ``setblocking`` in the same function — an unguarded blocking op
      is where a dead peer hangs the pipeline forever.

The pass runs over a ``{relative path: source}`` mapping so the test
suite can pin each rule with fixture files; ``scan_tree`` builds that
mapping from a repo checkout.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from pathlib import Path
from typing import Iterable, Mapping, Optional

from . import manifest

RULES: tuple[str, ...] = ("R1", "R2", "R3", "R4", "R5", "R6")

RULE_DOCS: dict[str, str] = {
    "R1": "token dispatch must be exhaustive or explicitly defaulted",
    "R2": "codec wire codes append-only; lossy codecs need wire_bytes + ref oracle",
    "R3": "concrete Channels implement the full surface; record() carries raw_bytes",
    "R4": "no pickle on runtime hot paths outside declared escape hatches",
    "R5": "_FHDR/_RREC edits must bump WIRE_LAYOUT_VERSION (+ manifest)",
    "R6": "blocking channel ops in runtime/ must be timeout- or liveness-guarded",
}


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

_TOKENS = frozenset(manifest.TOKEN_KINDS)


def _token_name(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Name) and node.id in _TOKENS:
        return node.id
    if isinstance(node, ast.Attribute) and node.attr in _TOKENS:
        return node.attr
    return None


def _token_tuples(tree: ast.Module) -> dict[str, frozenset[str]]:
    """Module-level ``NAME = (BATCH, PROBE, ...)`` tuple constants."""
    out: dict[str, frozenset[str]] = {}
    for stmt in tree.body:
        if (
            isinstance(stmt, ast.Assign)
            and len(stmt.targets) == 1
            and isinstance(stmt.targets[0], ast.Name)
            and isinstance(stmt.value, ast.Tuple)
            and stmt.value.elts
        ):
            names = [_token_name(e) for e in stmt.value.elts]
            if all(names):
                out[stmt.targets[0].id] = frozenset(n for n in names if n)
    return out


def _classify_test(
    test: ast.expr, tuples: Mapping[str, frozenset[str]]
) -> Optional[tuple[str, frozenset[str]]]:
    """(subject key, token kinds) for a token-dispatch branch test."""
    if (
        isinstance(test, ast.Compare)
        and len(test.ops) == 1
        and len(test.comparators) == 1
    ):
        op, comp = test.ops[0], test.comparators[0]
        if isinstance(op, ast.Eq):
            tok = _token_name(comp)
            if tok is not None:
                return ast.dump(test.left), frozenset((tok,))
            tok = _token_name(test.left)
            if tok is not None:
                return ast.dump(comp), frozenset((tok,))
        if isinstance(op, ast.In):
            if isinstance(comp, (ast.Tuple, ast.Set, ast.List)) and comp.elts:
                names = [_token_name(e) for e in comp.elts]
                if all(names):
                    return ast.dump(test.left), frozenset(n for n in names if n)
            if isinstance(comp, ast.Name) and comp.id in tuples:
                return ast.dump(test.left), tuples[comp.id]
    if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.Or):
        parts = [_classify_test(v, tuples) for v in test.values]
        if parts and all(p is not None for p in parts):
            subjects = {p[0] for p in parts if p}
            if len(subjects) == 1:
                kinds: frozenset[str] = frozenset().union(
                    *(p[1] for p in parts if p)
                )
                return parts[0][0], kinds  # type: ignore[index]
    return None


# ---------------------------------------------------------------------------
# R1 — exhaustive token dispatch
# ---------------------------------------------------------------------------

@dataclass
class _Ladder:
    subject: Optional[str]      # None when no token branch found
    kinds: frozenset[str]
    n_token_branches: int
    has_else: bool
    line: int


def _walk_ladder(node: ast.If, tuples: Mapping[str, frozenset[str]]) -> _Ladder:
    subject: Optional[str] = None
    kinds: frozenset[str] = frozenset()
    n_token = 0
    has_else = False
    cur: ast.If = node
    while True:
        c = _classify_test(cur.test, tuples)
        if c is not None and (subject is None or c[0] == subject):
            subject = c[0]
            kinds |= c[1]
            n_token += 1
        orelse = cur.orelse
        if len(orelse) == 1 and isinstance(orelse[0], ast.If):
            cur = orelse[0]
            continue
        has_else = bool(orelse)
        break
    return _Ladder(subject, kinds, n_token, has_else, node.lineno)


def _iter_blocks(tree: ast.AST) -> Iterable[list[ast.stmt]]:
    for node in ast.walk(tree):
        for field in ("body", "orelse", "finalbody"):
            block = getattr(node, field, None)
            if not (isinstance(block, list) and block
                    and isinstance(block[0], ast.stmt)):
                continue
            if (
                field == "orelse"
                and isinstance(node, ast.If)
                and len(block) == 1
                and isinstance(block[0], ast.If)
            ):
                continue  # elif continuation — _walk_ladder covers it
            yield block
        for handler in getattr(node, "handlers", []) or []:
            yield handler.body


def _check_r1(rel: str, tree: ast.Module) -> list[Finding]:
    tuples = _token_tuples(tree)
    findings: list[Finding] = []
    all_kinds = frozenset(manifest.TOKEN_KINDS)
    for block in _iter_blocks(tree):
        i = 0
        while i < len(block):
            stmt = block[i]
            if not isinstance(stmt, ast.If):
                i += 1
                continue
            # Grow a group of consecutive If-ladders testing the same
            # token subject (the `if kind == A: ...` / `if kind == B:`
            # sequential style counts as one dispatch site).
            group: list[_Ladder] = []
            j = i
            while j < len(block) and isinstance(block[j], ast.If):
                ladder = _walk_ladder(block[j], tuples)  # type: ignore[arg-type]
                if ladder.subject is None:
                    break
                if group and ladder.subject != group[0].subject:
                    break
                group.append(ladder)
                j += 1
                if ladder.has_else:
                    break  # an explicit default closes the site
            if not group:
                i += 1
                continue
            covered = frozenset().union(*(g.kinds for g in group))
            n_branches = sum(g.n_token_branches for g in group)
            trailing = j < len(block)  # later statements = default handling
            compliant = (
                group[-1].has_else
                or covered >= all_kinds
                or trailing
            )
            if n_branches >= 2 and not compliant:
                missing = sorted(all_kinds - covered)
                findings.append(Finding(
                    "R1", rel, group[0].line,
                    "non-exhaustive token dispatch: handles "
                    f"{{{', '.join(sorted(covered))}}}, silently drops "
                    f"{{{', '.join(missing)}}}; add an else that raises "
                    f"TransportError or cover all {len(all_kinds)} kinds",
                ))
            i = max(j, i + 1)
    return findings


# ---------------------------------------------------------------------------
# R2 — codec registry
# ---------------------------------------------------------------------------

def _class_const(node: ast.ClassDef, name: str):
    for stmt in node.body:
        target = None
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            target = stmt.targets[0]
        elif isinstance(stmt, ast.AnnAssign):
            target = stmt.target
        if (
            isinstance(target, ast.Name)
            and target.id == name
            and isinstance(getattr(stmt, "value", None), ast.Constant)
        ):
            return stmt.value.value
    return None


def _method_names(node: ast.ClassDef) -> set[str]:
    return {
        s.name for s in node.body
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef))
    }


def _ops_calls(node: ast.ClassDef) -> set[str]:
    out = set()
    for sub in ast.walk(node):
        if (
            isinstance(sub, ast.Call)
            and isinstance(sub.func, ast.Attribute)
            and isinstance(sub.func.value, ast.Name)
            and sub.func.value.id == "ops"
        ):
            out.add(sub.func.attr)
    return out


def _reaches(name: str, bases: Mapping[str, list[str]], target: str) -> bool:
    seen = set()
    stack = [name]
    while stack:
        cur = stack.pop()
        if cur == target:
            return True
        if cur in seen:
            continue
        seen.add(cur)
        stack.extend(bases.get(cur, []))
    return False


def _check_r2(
    rel: str, tree: ast.Module, ref_names: frozenset[str]
) -> list[Finding]:
    findings: list[Finding] = []
    classes = {
        n.name: n for n in tree.body if isinstance(n, ast.ClassDef)
    }
    base_names = {
        name: [b.id for b in node.bases if isinstance(b, ast.Name)]
        for name, node in classes.items()
    }
    codecs: dict[str, tuple[str, int, ast.ClassDef]] = {}
    for name, node in classes.items():
        if name != "Codec" and not _reaches(name, base_names, "Codec"):
            continue
        code = _class_const(node, "code")
        wire_name = _class_const(node, "name")
        if code is None and wire_name is None:
            continue  # abstract intermediate (e.g. a lossy base)
        if not isinstance(code, int) or not isinstance(wire_name, str):
            findings.append(Finding(
                "R2", rel, node.lineno,
                f"codec class {name} must declare literal `name` (str) and "
                "`code` (int) class attributes",
            ))
            continue
        codecs[name] = (wire_name, code, node)

    by_code: dict[int, str] = {}
    for cls, (wire_name, code, node) in sorted(
        codecs.items(), key=lambda kv: kv[1][2].lineno
    ):
        if code in by_code:
            findings.append(Finding(
                "R2", rel, node.lineno,
                f"wire code {code} of codec {cls} collides with codec "
                f"{by_code[code]!r} — wire codes are append-only and unique",
            ))
            continue
        by_code[code] = cls
        pinned = manifest.CODEC_WIRE_CODES.get(code)
        if pinned is None:
            expected = max(manifest.CODEC_WIRE_CODES) + 1
            findings.append(Finding(
                "R2", rel, node.lineno,
                f"codec {wire_name!r} uses wire code {code} not recorded in "
                "analysis/manifest.py CODEC_WIRE_CODES — append it there "
                f"(next free code: {expected})",
            ))
        elif pinned != wire_name:
            findings.append(Finding(
                "R2", rel, node.lineno,
                f"wire code {code} is pinned to codec {pinned!r} in the "
                f"manifest but the tree names it {wire_name!r} — codes are "
                "append-only, never renamed or reused",
            ))
        if code != 0:
            methods = _method_names(node)
            for required in ("wire_bytes", "encode", "decode"):
                if required not in methods:
                    findings.append(Finding(
                        "R2", rel, node.lineno,
                        f"lossy codec {wire_name!r} inherits `{required}` "
                        "instead of overriding it — the identity byte model "
                        "would misaccount the wire",
                    ))
            for op in sorted(_ops_calls(node)):
                if f"{op}_ref" not in ref_names:
                    findings.append(Finding(
                        "R2", rel, node.lineno,
                        f"codec {wire_name!r} calls ops.{op} but "
                        f"kernels/ref.py defines no {op}_ref oracle",
                    ))

    # every manifest code must still exist in the tree (append-only also
    # means no deletions)
    tree_codes = {code for (_, code, _) in codecs.values()}
    for code, pinned in sorted(manifest.CODEC_WIRE_CODES.items()):
        if code not in tree_codes:
            findings.append(Finding(
                "R2", rel, 1,
                f"manifest pins wire code {code} to codec {pinned!r} but no "
                "codec class in the tree declares it — codes may never be "
                "retired",
            ))
    return findings


# ---------------------------------------------------------------------------
# R3 — Channel surface + record() accounting
# ---------------------------------------------------------------------------

@dataclass
class _ClassInfo:
    rel: str
    node: ast.ClassDef
    bases: list[str]
    methods: dict[str, bool]  # name -> is_abstract
    is_abstract_marked: bool


def _is_abstract_def(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> bool:
    for dec in fn.decorator_list:
        name = dec.attr if isinstance(dec, ast.Attribute) else (
            dec.id if isinstance(dec, ast.Name) else None
        )
        if name in ("abstractmethod", "abstractproperty"):
            return True
    return False


def _collect_classes(files: Mapping[str, ast.Module]) -> dict[str, _ClassInfo]:
    table: dict[str, _ClassInfo] = {}
    for rel, tree in files.items():
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            bases = []
            marked = False
            for b in node.bases:
                name = b.id if isinstance(b, ast.Name) else (
                    b.attr if isinstance(b, ast.Attribute) else None
                )
                if name is None:
                    continue
                if name in ("ABC", "ABCMeta"):
                    marked = True
                else:
                    bases.append(name)
            methods = {
                s.name: _is_abstract_def(s)
                for s in node.body
                if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef))
            }
            if any(methods.values()):
                marked = True
            table[node.name] = _ClassInfo(rel, node, bases, methods, marked)
    return table


def _resolve_method(
    cls: str, method: str, table: Mapping[str, _ClassInfo]
) -> Optional[bool]:
    """Is `method` implemented (False) / abstract (True) / missing (None)?"""
    seen = set()
    stack = [cls]
    while stack:
        cur = stack.pop(0)
        if cur in seen or cur not in table:
            continue
        seen.add(cur)
        info = table[cur]
        if method in info.methods:
            if not info.methods[method]:
                return False
            # abstract here — an implementation may still live deeper
            for base in info.bases:
                deeper = _resolve_method(base, method, table)
                if deeper is False:
                    return False
            return True
        stack.extend(info.bases)
    return None


def _check_r3(files: Mapping[str, ast.Module]) -> list[Finding]:
    findings: list[Finding] = []
    table = _collect_classes(files)
    for name, info in sorted(table.items()):
        if name == "Channel" or not _reaches(
            name, {k: v.bases for k, v in table.items()}, "Channel"
        ):
            continue
        if info.is_abstract_marked:
            continue
        for method in manifest.CHANNEL_SURFACE:
            status = _resolve_method(name, method, table)
            if status is not False:
                why = "declares it abstract" if status else "never defines it"
                findings.append(Finding(
                    "R3", info.rel, info.node.lineno,
                    f"concrete Channel subclass {name} {why}: `{method}` — "
                    "the engines require the full surface "
                    f"({'/'.join(manifest.CHANNEL_SURFACE)})",
                ))

    # record() calls on runtime paths must carry raw_bytes (or be
    # explicit zero-byte probes) so TransferRecord wire accounting holds.
    for rel, tree in files.items():
        if "runtime/" not in rel:
            continue
        for node in ast.walk(tree):
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "record"
            ):
                continue
            if any(isinstance(a, ast.Starred) for a in node.args):
                continue
            if any(kw.arg == "raw_bytes" for kw in node.keywords):
                continue
            if len(node.args) >= 4:
                continue
            if node.args and isinstance(node.args[0], ast.Constant) \
                    and node.args[0].value == 0:
                continue  # zero-byte probe: raw == wire == 0
            findings.append(Finding(
                "R3", rel, node.lineno,
                "record() call without raw_bytes — TransferRecord wire "
                "accounting (raw_bytes >= wire bytes) silently degrades; "
                "pass raw_bytes= explicitly",
            ))
    return findings


# ---------------------------------------------------------------------------
# R4 — pickle on hot paths
# ---------------------------------------------------------------------------

_PICKLE_FNS = frozenset(("dumps", "loads", "dump", "load"))


def _check_r4(rel: str, tree: ast.Module) -> list[Finding]:
    if "runtime/" not in rel:
        return []
    allowed_prefixes = tuple(
        qual for suffix, qual in manifest.PICKLE_ALLOWED
        if rel.endswith(suffix)
    )
    findings: list[Finding] = []

    def visit(node: ast.AST, qual: tuple[str, ...]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                visit(child, qual + (child.name,))
                continue
            if (
                isinstance(child, ast.Call)
                and isinstance(child.func, ast.Attribute)
                and isinstance(child.func.value, ast.Name)
                and child.func.value.id == "pickle"
                and child.func.attr in _PICKLE_FNS
            ):
                qualname = ".".join(qual) or "<module>"
                if not any(
                    qualname == p or qualname.startswith(p + ".")
                    for p in allowed_prefixes
                ):
                    findings.append(Finding(
                        "R4", rel, child.lineno,
                        f"pickle.{child.func.attr} in {qualname} — hot-path "
                        "serialization must use the packed framer; declared "
                        "escape hatches live in analysis/manifest.py "
                        "PICKLE_ALLOWED",
                    ))
            visit(child, qual)

    visit(tree, ())
    return findings


# ---------------------------------------------------------------------------
# R5 — struct layout version
# ---------------------------------------------------------------------------

def _struct_fmt(stmt: ast.stmt) -> Optional[tuple[str, str, int]]:
    if not (
        isinstance(stmt, ast.Assign)
        and len(stmt.targets) == 1
        and isinstance(stmt.targets[0], ast.Name)
        and isinstance(stmt.value, ast.Call)
        and isinstance(stmt.value.func, ast.Attribute)
        and stmt.value.func.attr == "Struct"
        and stmt.value.args
        and isinstance(stmt.value.args[0], ast.Constant)
        and isinstance(stmt.value.args[0].value, str)
    ):
        return None
    return stmt.targets[0].id, stmt.value.args[0].value, stmt.lineno


def _check_r5(rel: str, tree: ast.Module) -> list[Finding]:
    findings: list[Finding] = []
    version = None
    version_line = 1
    layouts: dict[str, tuple[str, int]] = {}
    for stmt in tree.body:
        if (
            isinstance(stmt, ast.Assign)
            and len(stmt.targets) == 1
            and isinstance(stmt.targets[0], ast.Name)
            and stmt.targets[0].id == "WIRE_LAYOUT_VERSION"
            and isinstance(stmt.value, ast.Constant)
        ):
            version = stmt.value.value
            version_line = stmt.lineno
        fmt = _struct_fmt(stmt)
        if fmt is not None and fmt[0] in ("_FHDR", "_RREC"):
            layouts[fmt[0]] = (fmt[1].replace(" ", ""), fmt[2])
    if version is None:
        return [Finding(
            "R5", rel, 1,
            "transport module declares no WIRE_LAYOUT_VERSION constant — "
            "_FHDR/_RREC edits cannot be tracked",
        )]
    pinned = manifest.WIRE_LAYOUTS.get(version)
    if pinned is None:
        return [Finding(
            "R5", rel, version_line,
            f"WIRE_LAYOUT_VERSION {version} has no entry in "
            "analysis/manifest.py WIRE_LAYOUTS — record the new layout "
            "shapes when bumping",
        )]
    for name, expected in sorted(pinned.items()):
        got = layouts.get(name)
        if got is None:
            findings.append(Finding(
                "R5", rel, version_line,
                f"layout version {version} pins {name} but the module does "
                "not define it",
            ))
        elif got[0] != expected:
            findings.append(Finding(
                "R5", rel, got[1],
                f"{name} format {got[0]!r} differs from the manifest shape "
                f"{expected!r} for layout version {version} — bump "
                "WIRE_LAYOUT_VERSION and append the new shape to "
                "WIRE_LAYOUTS",
            ))
    return findings


# ---------------------------------------------------------------------------
# R6 — timeout-guarded blocking channel ops
# ---------------------------------------------------------------------------

def _own_nodes(fn: ast.AST) -> Iterable[ast.AST]:
    """Walk a function body without descending into nested ``def``s
    (each nested function is audited as its own scope)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _check_r6(rel: str, tree: ast.Module) -> list[Finding]:
    if "runtime/" not in rel:
        return []
    findings: list[Finding] = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        bare_recvs: list[tuple[int, str]] = []   # (line, dumped base expr)
        polled: set[str] = set()
        raw_sends: list[tuple[int, str]] = []    # sendmsg/sendall sites
        has_timeout_ctl = False
        for node in _own_nodes(fn):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)):
                continue
            attr = node.func.attr
            base = ast.dump(node.func.value)
            if attr == "recv" and not node.args and not node.keywords:
                bare_recvs.append((node.lineno, base))
            elif attr == "poll":
                polled.add(base)
            elif attr in ("sendmsg", "sendall"):
                raw_sends.append((node.lineno, attr))
            elif attr in ("settimeout", "setblocking"):
                has_timeout_ctl = True
        for line, base in bare_recvs:
            if base not in polled:
                findings.append(Finding(
                    "R6", rel, line,
                    f"bare blocking recv() in {fn.name} with no timeout and "
                    "no poll(...) liveness loop on the same object — a dead "
                    "peer hangs this call forever; pass a timeout or guard "
                    "with poll()",
                ))
        if raw_sends and not has_timeout_ctl:
            line, attr = raw_sends[0]
            findings.append(Finding(
                "R6", rel, line,
                f"raw socket {attr}() in {fn.name} without settimeout()/"
                "setblocking() in the same function — a non-draining peer "
                "blocks the send forever; bound it (TransportTimeout "
                "semantics)",
            ))
    return findings


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def run_checks(
    sources: Mapping[str, str], rules: Optional[Iterable[str]] = None
) -> list[Finding]:
    """Run the pass over a ``{relative posix path: source}`` mapping."""
    active = frozenset(rules) if rules is not None else frozenset(RULES)
    trees: dict[str, ast.Module] = {}
    findings: list[Finding] = []
    for rel, text in sorted(sources.items()):
        try:
            trees[rel] = ast.parse(text, filename=rel)
        except SyntaxError as exc:
            findings.append(Finding(
                "R0", rel, exc.lineno or 1, f"syntax error: {exc.msg}"
            ))
    ref_names = frozenset(
        node.name
        for rel, tree in trees.items() if rel.endswith("kernels/ref.py")
        for node in tree.body
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
    )
    for rel, tree in sorted(trees.items()):
        if "R1" in active:
            findings.extend(_check_r1(rel, tree))
        if "R2" in active and rel.endswith("core/codecs.py"):
            findings.extend(_check_r2(rel, tree, ref_names))
        if "R4" in active:
            findings.extend(_check_r4(rel, tree))
        if "R5" in active and rel.endswith("runtime/transport.py"):
            findings.extend(_check_r5(rel, tree))
        if "R6" in active:
            findings.extend(_check_r6(rel, tree))
    if "R3" in active:
        findings.extend(_check_r3(trees))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def scan_tree(
    root: str | Path, rules: Optional[Iterable[str]] = None
) -> list[Finding]:
    """Run the pass over every Python file under ``<root>/src``."""
    root = Path(root)
    sources = {
        p.relative_to(root).as_posix(): p.read_text()
        for p in sorted((root / "src").rglob("*.py"))
    }
    return run_checks(sources, rules)
