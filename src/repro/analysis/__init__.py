"""Static analysis over the repo itself (PipeCheck).

`repro.analysis` is tooling *about* the tree, not part of the serving
path: `pipecheck` holds the runtime to its protocol invariants
(R1–R5), `manifest` pins the wire-protocol facts it checks against.
Run it via ``tools/pipecheck.py`` or ``make check``.
"""
from .pipecheck import Finding, RULE_DOCS, RULES, run_checks, scan_tree

__all__ = ["Finding", "RULES", "RULE_DOCS", "run_checks", "scan_tree"]
