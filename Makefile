# Developer loop for the ParetoPipe reproduction.
#
#   make check           — static gates, <30 s total: PipeCheck (the
#                          protocol invariant checker, tools/pipecheck.py)
#                          always; ruff + mypy when installed (see
#                          ruff.toml / mypy.ini; CI always has them)
#   make fast            — the development tier: static gates + fast
#                          tests + the <30 s 3-objective bench smoke
#                          (BENCH_pareto.json) + the <30 s transport
#                          smoke (BENCH_transport.json)
#   make test-fast       — fast tests only: everything except the
#                          multi-minute train/system drills (marker: slow)
#   make test            — tier-1 verify, the full suite (what CI runs)
#   make bench-quick     — analytic benchmarks only (no wall-clock measuring)
#   make bench-smoke     — 3-objective solver bench on a tiny graph (<30 s)
#   make bench-transport — per-hop overhead + payload-size sweep, emulated
#                          vs real socket/shmem processes (<30 s smoke tier)
#   make bench-transport-check
#                        — fresh smoke measurement diffed against the
#                          committed BENCH_transport.json; fails on a
#                          >25% hop_us regression (the make-fast gate)
#   make bench-stream    — streaming-session bench: pipelined steady state
#                          per transport + mid-stream migration dip
#                          (<30 s smoke tier, writes BENCH_stream.json)
#   make bench-stream-check
#                        — fresh smoke measurement diffed against the
#                          committed BENCH_stream.json; fails on a
#                          steady-state throughput regression (make-fast)
#   make bench-codec     — per-hop wire codec bench: bytes-on-wire /
#                          hop-µs / accuracy per codec per size per
#                          transport + the duress-WAN paced gate + the
#                          adaptive WAN-dip study (writes BENCH_codec.json)
#   make bench-codec-check
#                        — re-measures the codec gate quantities and
#                          fails unless int8 holds ≥3.5× wire reduction
#                          and strictly beats `none` on the paced WAN
#                          hop (the make-fast gate)
#   make bench-replica   — replicated-bottleneck-stage bench: img/s vs
#                          replica count r per process transport over a
#                          paced bottleneck stage (writes
#                          BENCH_replica.json, < 60 s smoke tier)
#   make bench-replica-check
#                        — fresh smoke run gated on the within-run
#                          invariants: r=2 holds >= 1.5x over r=1 and
#                          r=3 does not regress vs r=2, on both
#                          transports (the make-fast gate)
#   make bench-fault     — fault-tolerance drills: worker-kill restart
#                          (detection / restart / replay timings, parity)
#                          per transport + r=2 lane failover at degraded
#                          capacity (writes BENCH_fault.json, < 90 s)
#   make bench-fault-check
#                        — fresh smoke run gated on recovery health:
#                          detection < 3 s, restart+replay < 30 s, exact
#                          parity, failover capacity 0.5 (the make-fast
#                          gate)
#   make bench-serve     — multi-tenant serving-gateway bench: closed-loop
#                          tenants, coalescing gain + p50/p99 tails +
#                          micro-batch occupancy per tenant count
#                          (writes BENCH_serve.json, <30 s smoke tier)
#   make bench-serve-check
#                        — fresh smoke run gated on the within-run
#                          invariants: 8-tenant aggregate >= 3x solo and
#                          8-tenant p99 <= 5x solo p50 (the make-fast
#                          gate)
#   make test-faults     — the fault matrix alone ({socket,shmem} x
#                          {drain,drop} x fault kinds, sanitized)
#   make demo            — k-stage adaptive loop demo under a WAN ramp

PY      ?= python
PYTEST  ?= $(PY) -m pytest
ENV      = PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: check fast test test-fast test-faults bench bench-quick bench-smoke \
        bench-transport bench-transport-check bench-stream \
        bench-stream-check bench-codec bench-codec-check bench-replica \
        bench-replica-check bench-fault bench-fault-check bench-serve \
        bench-serve-check demo

fast: check test-fast bench-smoke bench-transport-check bench-stream-check \
      bench-codec-check bench-replica-check bench-fault-check \
      bench-serve-check

# Static gates (<30 s). PipeCheck is self-contained (stdlib ast only)
# and always runs; ruff/mypy are dev extras — skipped with a notice
# when absent so `make fast` works in the bare runtime container.
check:
	$(ENV) $(PY) tools/pipecheck.py
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tools tests; \
	else echo "check: ruff not installed — skipped (pip install -r requirements-dev.txt)"; fi
	@if command -v mypy >/dev/null 2>&1; then \
		mypy; \
	else echo "check: mypy not installed — skipped (pip install -r requirements-dev.txt)"; fi

test:
	$(ENV) $(PYTEST) -x -q

test-fast:
	$(ENV) $(PYTEST) -q -m "not slow"

test-faults:
	$(ENV) REPRO_SANITIZE=1 $(PYTEST) -q tests/test_faults.py

bench:
	$(ENV) $(PY) -m benchmarks.run

bench-quick:
	$(ENV) $(PY) -m benchmarks.run --quick

bench-smoke:
	$(ENV) $(PY) -m benchmarks.energy_front --smoke

bench-transport:
	$(ENV) $(PY) -m benchmarks.transport_bench --smoke

bench-transport-check:
	$(ENV) $(PY) -m benchmarks.transport_bench --smoke --check

bench-stream:
	$(ENV) $(PY) -m benchmarks.stream_bench --smoke

bench-stream-check:
	$(ENV) $(PY) -m benchmarks.stream_bench --check

bench-codec:
	$(ENV) $(PY) -m benchmarks.codec_bench --smoke

bench-codec-check:
	$(ENV) $(PY) -m benchmarks.codec_bench --check

bench-replica:
	$(ENV) $(PY) -m benchmarks.replica_bench --smoke

bench-replica-check:
	$(ENV) $(PY) -m benchmarks.replica_bench --check

bench-fault:
	$(ENV) $(PY) -m benchmarks.fault_bench --smoke

bench-fault-check:
	$(ENV) $(PY) -m benchmarks.fault_bench --check

bench-serve:
	$(ENV) $(PY) -m benchmarks.serve_bench --smoke

bench-serve-check:
	$(ENV) $(PY) -m benchmarks.serve_bench --check

demo:
	$(ENV) $(PY) examples/kway_adaptive.py
