# Developer loop for the ParetoPipe reproduction.
#
#   make test-fast   — the development tier: everything except the
#                      multi-minute train/system drills (marker: slow)
#   make test        — tier-1 verify, the full suite (what CI runs)
#   make bench-quick — analytic benchmarks only (no wall-clock measuring)
#   make demo        — k-stage adaptive loop demo under a WAN ramp

PY      ?= python
PYTEST  ?= $(PY) -m pytest
ENV      = PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast bench bench-quick demo

test:
	$(ENV) $(PYTEST) -x -q

test-fast:
	$(ENV) $(PYTEST) -q -m "not slow"

bench:
	$(ENV) $(PY) -m benchmarks.run

bench-quick:
	$(ENV) $(PY) -m benchmarks.run --quick

demo:
	$(ENV) $(PY) examples/kway_adaptive.py
