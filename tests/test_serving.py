"""Serving correctness: prefill+decode ≡ full forward; chunked ≡ dense."""
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow          # ~10-17s per arch, 10 archs

import repro.configs as configs
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import lm
from repro.models.common import InitBuilder

TOL = 2e-4


def _setup(name, seq=16, cf=8.0):
    cfg = configs.reduced(name)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=cf)   # drop-free for identity
    if cfg.family == "vlm":
        seq += cfg.n_patches
    params = lm.build_params(cfg, InitBuilder(jax.random.PRNGKey(0),
                                              jnp.float32))
    data = SyntheticLM(cfg, DataConfig(batch=2, seq=seq))
    inputs = {k: v for k, v in next(data).items() if k != "targets"}
    return cfg, params, inputs


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_prefill_decode_matches_full(name):
    cfg, params, inputs = _setup(name)
    logits_full, _ = lm.forward_train(cfg, params, inputs)
    S = inputs["tokens"].shape[1]
    off = cfg.n_patches if cfg.family == "vlm" else 0
    T1 = S // 2
    pre = dict(inputs, tokens=inputs["tokens"][:, :T1])
    _, cache = lm.forward_prefill(cfg, params, pre, cache_len=S + off)
    worst = 0.0
    for t in range(T1, S):
        lg, cache = lm.forward_decode(cfg, params,
                                      inputs["tokens"][:, t:t + 1], cache)
        worst = max(worst, float(jnp.max(jnp.abs(lg[:, 0]
                                                 - logits_full[:, off + t]))))
    assert worst < TOL, worst


@pytest.mark.parametrize("name", ["qwen3-1.7b", "falcon-mamba-7b",
                                  "zamba2-7b", "qwen3-moe-30b-a3b"])
def test_chunked_equals_unchunked(name):
    cfg = configs.reduced(name)
    params = lm.build_params(cfg, InitBuilder(jax.random.PRNGKey(0),
                                              jnp.float32))
    data = SyntheticLM(cfg, DataConfig(batch=2, seq=64))
    inputs = {k: v for k, v in next(data).items() if k != "targets"}
    chunked, _ = lm.forward_train(cfg, params, inputs)
    dense, _ = lm.forward_train(cfg.replace(attn_chunk=4096, ssm_chunk=4096),
                                params, inputs)
    assert float(jnp.max(jnp.abs(chunked - dense))) < TOL


def test_moe_gshard_equals_sort():
    cfg = configs.reduced("qwen3-moe-30b-a3b").replace(
        capacity_factor=8.0, moe_group_size=32, moe_gshard_group=32)
    params = lm.build_params(cfg, InitBuilder(jax.random.PRNGKey(0),
                                              jnp.float32))
    data = SyntheticLM(cfg, DataConfig(batch=2, seq=16))
    inputs = {k: v for k, v in next(data).items() if k != "targets"}
    a, _ = lm.forward_train(cfg, params, inputs)
    b, _ = lm.forward_train(cfg.replace(moe_impl="gshard"), params, inputs)
    assert float(jnp.max(jnp.abs(a - b))) < TOL


def test_moe_matches_dense_reference():
    """moe_mlp vs an all-experts dense loop (no capacity drops)."""
    from repro.models.common import silu
    from repro.models.mlp import moe_mlp, moe_params
    cfg = configs.reduced("qwen3-moe-30b-a3b").replace(capacity_factor=8.0)
    b = InitBuilder(jax.random.PRNGKey(0), jnp.float32)
    p = moe_params(b, cfg, "m")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, _ = moe_mlp(cfg, p, x)
    flat = x.reshape(-1, cfg.d_model)
    logits = flat @ p["router"]
    tw, te = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
    tw = tw / tw.sum(-1, keepdims=True)
    outs = jnp.stack([(silu(flat @ p["w_gate"][e]) * (flat @ p["w_up"][e]))
                      @ p["w_down"][e] for e in range(cfg.n_experts)], 1)
    ref = jnp.einsum("tk,tkd->td", tw,
                     jnp.take_along_axis(outs, te[..., None], 1))
    assert float(jnp.max(jnp.abs(y.reshape(-1, cfg.d_model) - ref))) < 1e-3


def test_capacity_drops_are_bounded():
    """With cf=1.0 and adversarially-skewed routing, dropped tokens get
    only residual (identity) treatment — output must stay finite and the
    layer must not amplify."""
    cfg = configs.reduced("qwen3-moe-30b-a3b").replace(capacity_factor=1.0)
    params = lm.build_params(cfg, InitBuilder(jax.random.PRNGKey(0),
                                              jnp.float32))
    data = SyntheticLM(cfg, DataConfig(batch=2, seq=32))
    inputs = {k: v for k, v in next(data).items() if k != "targets"}
    logits, _ = lm.forward_train(cfg, params, inputs)
    assert bool(jnp.all(jnp.isfinite(logits)))
