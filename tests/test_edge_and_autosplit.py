"""Edge runtime (dual backends, measured) + adaptive splitter."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import AdaptiveSplitter, LinkEstimator, scenarios
from repro.core.devices import DURESS, LAN_PI_PI, Link
from repro.models.cnn import zoo
from repro.runtime.edge import EdgePipeline


@pytest.fixture(scope="module")
def mobilenet():
    m = zoo.get("mobilenetv2")
    return m, m.init(jax.random.PRNGKey(0))


def test_backends_agree_numerically(mobilenet):
    m, params = mobilenet
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64, 3))
    link = Link("l", rtt_s=1e-5, bw_bytes_per_s=1e12)
    outs = {}
    for backend in ("lightweight", "rpc"):
        pipe = EdgePipeline(m, params, p=5, link=link, backend=backend)
        y, _, _ = pipe.run_one(x)
        outs[backend] = y
    assert jnp.allclose(outs["lightweight"], outs["rpc"], atol=1e-5)


def test_lightweight_beats_rpc(mobilenet):
    """Paper Sec. V-C: the custom backend wins on both axes (we assert
    the sign; magnitude depends on the host)."""
    m, params = mobilenet
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 64, 3))
    link = Link("lan", rtt_s=0.2e-3, bw_bytes_per_s=125e6)
    res = {}
    for backend in ("lightweight", "rpc"):
        pipe = EdgePipeline(m, params, p=3, link=link, backend=backend)
        res[backend] = pipe.measure(lambda: x, n_batches=4)
    assert res["lightweight"].latency_s < res["rpc"].latency_s
    assert res["lightweight"].throughput > res["rpc"].throughput


def test_network_emulation_injects_delay(mobilenet):
    m, params = mobilenet
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    slow = Link("slow", rtt_s=100e-3, bw_bytes_per_s=1e9)
    fast = Link("fast", rtt_s=1e-5, bw_bytes_per_s=1e9)
    t_slow = EdgePipeline(m, params, 3, slow).run_one(x)[1]
    t_fast = EdgePipeline(m, params, 3, fast).run_one(x)[1]
    assert t_slow - t_fast > 0.04            # ≈ rtt/2 = 50 ms


def test_adaptive_splitter_migrates_and_hysteresis():
    graph = zoo.get("mobilenetv2").block_graph()
    scen = scenarios.get("pi_to_pi")
    sp = AdaptiveSplitter(graph, scen, batch=8, policy="throughput")
    est = LinkEstimator(LAN_PI_PI.rtt_s, LAN_PI_PI.bw_bytes_per_s, alpha=0.6)
    m0, mig0 = sp.step(est)
    assert mig0                               # first solve always "migrates"
    # healthy link: stable (hysteresis holds)
    for _ in range(3):
        _, mig = sp.step(est)
        assert not mig
    healthy = sp.current.partition
    # degrade hard: estimates converge, split must move toward min-transfer
    for _ in range(25):
        est.observe(1e6, DURESS.transfer_time(1e6))
        est.observe(0, DURESS.rtt_s, is_rtt_probe=True)
        sp.step(est)
    assert sp.current.partition != healthy
    assert graph.cut_bytes(sp.current.partition[0]) <= \
        graph.cut_bytes(healthy[0])


def test_estimator_converges():
    est = LinkEstimator(rtt_s=1e-3, bw_bytes_per_s=1e9, alpha=0.5)
    for _ in range(30):
        est.observe(1e6, DURESS.transfer_time(1e6))
        est.observe(0, DURESS.rtt_s, is_rtt_probe=True)
    assert est.rtt_s == pytest.approx(DURESS.rtt_s, rel=0.05)
    assert est.bw_bytes_per_s < 3 * DURESS.bw_bytes_per_s
