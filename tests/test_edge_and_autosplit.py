"""Edge runtime (dual backends, measured) + adaptive splitter."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import AdaptiveSplitter, LinkEstimator, scenarios
from repro.core.devices import DURESS, LAN_PI_PI, Link
from repro.models.cnn import zoo
from repro.runtime.edge import EdgePipeline


@pytest.fixture(scope="module")
def mobilenet():
    m = zoo.get("mobilenetv2")
    return m, m.init(jax.random.PRNGKey(0))


def test_backends_agree_numerically(mobilenet):
    m, params = mobilenet
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64, 3))
    link = Link("l", rtt_s=1e-5, bw_bytes_per_s=1e12)
    outs = {}
    for backend in ("lightweight", "rpc"):
        pipe = EdgePipeline(m, params, p=5, link=link, backend=backend)
        y, _, _ = pipe.run_one(x)
        outs[backend] = y
    assert jnp.allclose(outs["lightweight"], outs["rpc"], atol=1e-5)


def test_lightweight_beats_rpc(mobilenet):
    """Paper Sec. V-C: the custom backend wins on both axes (we assert
    the sign; magnitude depends on the host).  Min-of-3 latencies and a
    longer stream keep host scheduling noise out of the sign."""
    m, params = mobilenet
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 64, 3))
    link = Link("lan", rtt_s=0.2e-3, bw_bytes_per_s=125e6)
    pipes = {}
    for backend in ("lightweight", "rpc"):
        pipes[backend] = EdgePipeline(m, params, p=3, link=link,
                                      backend=backend)
        pipes[backend].warmup(x)

    for attempt in range(3):      # retries: load spikes can eat the margin
        lat = {b: min(pipes[b].run_one(x)[1] for _ in range(3))
               for b in pipes}
        thr = {b: pipes[b].measure(lambda: x, n_batches=8).throughput
               for b in pipes}
        if (lat["lightweight"] < lat["rpc"]
                and thr["lightweight"] > thr["rpc"]):
            break
    else:
        pytest.fail(f"lightweight never beat rpc on both axes: "
                    f"lat={lat} thr={thr}")


def test_network_emulation_injects_delay(mobilenet):
    """The emulated wire charges rtt/2 + bytes/bw as real wall-clock
    (host compute is too noisy here for an end-to-end A/B latency diff,
    so assert the injected hop time and that latency contains it)."""
    m, params = mobilenet
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    slow = Link("slow", rtt_s=100e-3, bw_bytes_per_s=1e9)
    fast = Link("fast", rtt_s=1e-5, bw_bytes_per_s=1e9)

    def lone(link):
        pipe = EdgePipeline(m, params, 3, link)
        pipe.warmup(x)                       # keep jit compile out of the timing
        _, lat, hops = pipe.run_one(x)
        return lat, sum(hops)

    lat_slow, hop_slow = lone(slow)
    lat_fast, hop_fast = lone(fast)
    assert hop_slow - hop_fast > 0.045       # ≈ rtt/2 = 50 ms on the wire
    assert lat_slow > hop_slow > 0.045       # and the sleep is real wall-clock


def test_adaptive_splitter_migrates_and_hysteresis():
    graph = zoo.get("mobilenetv2").block_graph()
    scen = scenarios.get("pi_to_pi")
    sp = AdaptiveSplitter(graph, scen, batch=8, policy="throughput")
    est = LinkEstimator(LAN_PI_PI.rtt_s, LAN_PI_PI.bw_bytes_per_s, alpha=0.6)
    m0, mig0 = sp.step(est)
    assert mig0                               # first solve always "migrates"
    # healthy link: stable (hysteresis holds)
    for _ in range(3):
        _, mig = sp.step(est)
        assert not mig
    healthy = sp.current.partition
    # degrade hard: estimates converge, split must move toward min-transfer
    for _ in range(25):
        est.observe(1e6, DURESS.transfer_time(1e6))
        est.observe(0, DURESS.rtt_s, is_rtt_probe=True)
        sp.step(est)
    assert sp.current.partition != healthy
    assert graph.cut_bytes(sp.current.partition[0]) <= \
        graph.cut_bytes(healthy[0])


def test_estimator_converges():
    est = LinkEstimator(rtt_s=1e-3, bw_bytes_per_s=1e9, alpha=0.5)
    for _ in range(30):
        est.observe(1e6, DURESS.transfer_time(1e6))
        est.observe(0, DURESS.rtt_s, is_rtt_probe=True)
    assert est.rtt_s == pytest.approx(DURESS.rtt_s, rel=0.05)
    assert est.bw_bytes_per_s < 3 * DURESS.bw_bytes_per_s
