"""End-to-end behaviour of the paper's system.

The paper's pipeline (Alg. 1) at both scales: the edge emulation with
real execution, and the launcher drivers with fault injection —
including the two headline properties: (1) partitioning never changes
model outputs; (2) a crashed run resumes bit-exact.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow          # subprocess train/serve drills

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(args, timeout=600, env_extra=None):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    if env_extra:
        env.update(env_extra)
    return subprocess.run([sys.executable, "-m", *args], env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_end_to_end_paper_pipeline():
    """ParetoPipe, start to finish: profile → sweep → front → deploy the
    chosen split on the executable pipeline → outputs match the
    unpartitioned model."""
    from repro.core import best_throughput, pareto_front, sweep_2way
    from repro.core import scenarios
    from repro.core.devices import Link
    from repro.models.cnn import zoo
    from repro.runtime.edge import EdgePipeline

    m = zoo.get("mobilenetv2")
    params = m.init(jax.random.PRNGKey(0))
    graph = m.block_graph()
    scen = scenarios.get("pi_to_pi")
    pts = sweep_2way(graph, scen.devices, scen.links[0], batch=8)
    front = pareto_front(pts)
    assert 2 <= len(front) <= len(pts)
    pick = best_throughput(pts)

    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 64, 3))
    ref = m.apply(params, x)
    pipe = EdgePipeline(m, params, p=pick.partition[0],
                        link=Link("fast", 1e-5, 1e12))
    y, latency, _ = pipe.run_one(x)
    assert jnp.allclose(ref, y, atol=1e-5)
    assert latency > 0


def test_train_crash_restart_cli(tmp_path):
    """The launcher drill: run with fault injection, resume, finish."""
    ckpt = str(tmp_path / "ck")
    args = ["repro.launch.train", "--arch", "qwen3-1.7b", "--reduced",
            "--steps", "16", "--batch", "2", "--seq", "32",
            "--ckpt-dir", ckpt, "--ckpt-every", "5", "--log-every", "5"]
    crashed = _run(args + ["--fail-at-step", "9"])
    assert crashed.returncode == 42, crashed.stdout + crashed.stderr
    resumed = _run(args)
    assert resumed.returncode == 0, resumed.stdout + resumed.stderr
    assert "[resume] step 5" in resumed.stdout
    assert "[done] 16 steps" in resumed.stdout


def test_serve_cli():
    cp = _run(["repro.launch.serve", "--arch", "qwen3-1.7b", "--reduced",
               "--batch", "2", "--prompt-len", "16", "--new-tokens", "4"])
    assert cp.returncode == 0, cp.stdout + cp.stderr
    assert "decode:" in cp.stdout


@pytest.mark.xfail(
    not hasattr(jax, "shard_map"),
    reason="partial-manual shard_map (axis_names=) requires jax>=0.6; "
           "this jax lowers axis_index to an unpartitionable PartitionId",
    strict=False)
def test_train_pipeline_cli_with_auto_partition():
    """Multi-pod GPipe on forced host devices + ParetoPipe-chosen cuts."""
    cp = _run(["repro.launch.train", "--arch", "qwen3-1.7b", "--reduced",
               "--steps", "3", "--batch", "4", "--seq", "32",
               "--pods", "2", "--data-par", "2", "--model-par", "2",
               "--microbatches", "2", "--auto-partition", "--log-every", "1"],
              env_extra={"REPRO_HOST_DEVICES": "8"})
    assert cp.returncode == 0, cp.stdout + cp.stderr
    assert "[paretopipe] cuts=" in cp.stdout
    assert "step     2" in cp.stdout


def test_loss_decreases_on_learnable_task():
    """Repeated steps on a fixed batch (memorization) — loss must drop
    substantially (end-to-end learning sanity)."""
    import repro.configs as configs
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.optim import OptConfig
    from repro.runtime.steps import init_train_state, make_train_step

    cfg = configs.reduced("qwen3-1.7b").replace(n_layers=2, d_model=64,
                                                vocab=64)
    state = init_train_state(cfg, jax.random.PRNGKey(0), OptConfig())
    data = SyntheticLM(cfg, DataConfig(batch=4, seq=32))
    batch = next(data)                       # memorize one batch
    step = jax.jit(make_train_step(cfg, OptConfig(lr=3e-3)))
    first = None
    for i in range(60):
        state, metrics = step(state, batch)
        if first is None:
            first = float(metrics["loss"])
    last = float(metrics["loss"])
    assert last < first * 0.7, (first, last)
