"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 4, 2, 64),      # GQA
    (1, 128, 8, 1, 128),     # MQA, granite-style head_dim
    (2, 128, 4, 4, 96),      # phi3-vision head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, KV, hd, dtype, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                              interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=causal)
    assert_allclose(np.asarray(out, np.float32), np.asarray(exp, np.float32),
                    **tol(dtype))


@pytest.mark.parametrize("B,H,KV,hd,Smax,pos", [
    (2, 4, 2, 64, 512, 317),
    (1, 8, 1, 128, 256, 0),       # first token
    (2, 4, 4, 96, 256, 255),      # full cache
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, H, KV, hd, Smax, pos, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kc = jax.random.normal(ks[1], (B, Smax, KV, hd), dtype)
    vc = jax.random.normal(ks[2], (B, Smax, KV, hd), dtype)
    out = ops.decode_attention(q, kc, vc, pos, block_s=128, interpret=True)
    exp = ref.decode_attention_ref(q, kc, vc, pos)
    assert_allclose(np.asarray(out, np.float32), np.asarray(exp, np.float32),
                    **tol(dtype))


@pytest.mark.parametrize("B,L,di,N,bd", [
    (2, 64, 128, 16, 64),
    (1, 32, 256, 8, 128),
    (2, 16, 64, 16, 64),
])
def test_ssm_scan_sweep(B, L, di, N, bd):
    ks = jax.random.split(KEY, 6)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, L, di)))
    x = jax.random.normal(ks[1], (B, L, di))
    Bc = jax.random.normal(ks[2], (B, L, N))
    Cc = jax.random.normal(ks[3], (B, L, N))
    A = -jnp.exp(jax.random.normal(ks[4], (di, N)) * 0.5)
    h0 = jax.random.normal(ks[5], (B, di, N))
    y, h = ops.ssm_scan_chunk(dt, x, Bc, Cc, A, h0, block_d=bd,
                              interpret=True)
    ye, he = ref.ssm_scan_chunk_ref(dt, x, Bc, Cc, A, h0)
    assert_allclose(np.asarray(y), np.asarray(ye), rtol=1e-4, atol=1e-4)
    assert_allclose(np.asarray(h), np.asarray(he), rtol=1e-4, atol=1e-4)


def test_ssm_chunk_chaining_matches_long_scan():
    """Two chained kernel chunks ≡ one long reference scan."""
    B, L, di, N = 2, 32, 64, 8
    ks = jax.random.split(KEY, 6)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, 2 * L, di)))
    x = jax.random.normal(ks[1], (B, 2 * L, di))
    Bc = jax.random.normal(ks[2], (B, 2 * L, N))
    Cc = jax.random.normal(ks[3], (B, 2 * L, N))
    A = -jnp.exp(jax.random.normal(ks[4], (di, N)) * 0.5)
    h0 = jnp.zeros((B, di, N))
    y1, h1 = ops.ssm_scan_chunk(dt[:, :L], x[:, :L], Bc[:, :L], Cc[:, :L],
                                A, h0, block_d=64, interpret=True)
    y2, h2 = ops.ssm_scan_chunk(dt[:, L:], x[:, L:], Bc[:, L:], Cc[:, L:],
                                A, h1, block_d=64, interpret=True)
    ye, he = ref.ssm_scan_chunk_ref(dt, x, Bc, Cc, A, h0)
    assert_allclose(np.concatenate([y1, y2], 1), np.asarray(ye),
                    rtol=1e-4, atol=1e-4)
    assert_allclose(np.asarray(h2), np.asarray(he), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(4, 128), (2, 33, 128), (1, 7, 5, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_rmsnorm_sweep(shape, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], shape, dtype)
    sc = jax.random.normal(ks[1], (shape[-1],), jnp.float32)
    out = ops.fused_rmsnorm(x, sc, interpret=True)
    exp = ref.fused_rmsnorm_ref(x, sc)
    assert_allclose(np.asarray(out, np.float32), np.asarray(exp, np.float32),
                    **tol(dtype))


def test_flash_attention_matches_model_attention():
    """The model's chunked-XLA attention and the Pallas kernel agree —
    the kernel can replace the XLA path on TPU."""
    from repro.models.attention import attend_prefill
    import repro.configs as configs
    cfg = configs.reduced("qwen3-1.7b").replace(attn_chunk=64)
    B, S, H, KV, hd = 2, 128, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    a = attend_prefill(cfg, q, k, v, causal=True)
    b = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                            interpret=True)
    assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)
