"""The live protocol sanitizer: every deliberately-broken Channel
double must raise ``SanitizerError`` (and leave a matching entry in the
violation report), a clean token stream must sanitize silently, and the
measured hop-µs overhead of the wrapper must stay small.

The clean migration/replica matrices running sanitized end-to-end live
in test_session.py / test_replicas.py (``sanitize=True`` plus a
zero-violations assert) — this file owns the adversarial doubles.
"""
import numpy as np
import pytest

from repro.runtime.sanitizer import (SanitizedChannel, SanitizerError,
                                     drain_violations)
from repro.runtime.transport import (BATCH, CLOCK, RECONFIG, STATS, STOP,
                                     WARMUP)


# --------------------------------------------------------------------------- #
# Channel doubles
# --------------------------------------------------------------------------- #
class _Hop:
    """Just enough HopSpec surface for the wrapper."""

    def __init__(self, index=0, codec="none", zero_copy=True):
        self.index = index
        self.codec = codec
        self.zero_copy = zero_copy
        self.sanitize = True


class _Loopback:
    """In-process FIFO channel: recv() returns what send() queued.
    ``script`` entries (exceptions or (kind, payload) tuples) are
    served before the queue — the mutation hook."""

    def __init__(self, hop=None, script=None):
        self.hop = hop if hop is not None else _Hop()
        self.q = []
        self.script = list(script or [])

    def send(self, payload=None, kind=BATCH):
        self.q.append((kind, payload))

    def recv(self, timeout=None):
        if self.script:
            item = self.script.pop(0)
            if isinstance(item, BaseException):
                raise item
            return item
        return self.q.pop(0)


class _SwapLoopback(_Loopback):
    """Delivers queued messages newest-first: a reordering transport."""

    def recv(self, timeout=None):
        if self.script:
            return super().recv(timeout)
        return self.q.pop()


def _wrap(inner=None, **hop_kw):
    chan = inner if inner is not None else _Loopback(_Hop(**hop_kw))
    drain_violations()                        # isolate each test
    return SanitizedChannel(chan)


def _assert_raises_with_rule(rule, fn):
    with pytest.raises(SanitizerError):
        fn()
    bad = drain_violations()
    assert [v.rule for v in bad] == [rule], bad


# --------------------------------------------------------------------------- #
# the mutation doubles
# --------------------------------------------------------------------------- #
def test_skipped_warmup_on_send_raises():
    ch = _wrap()
    ch.send(np.ones(4, np.float32), kind=BATCH)
    ch.send({"bounds": (0, 2, 5)}, kind=RECONFIG)
    _assert_raises_with_rule(
        "warmup-skipped",
        lambda: ch.send(np.ones(4, np.float32), kind=BATCH))


def test_skipped_warmup_on_recv_raises():
    x = np.ones(4, np.float32)
    ch = _wrap(_Loopback(script=[
        (BATCH, x),
        (RECONFIG, {"bounds": (0, 2, 5)}),
        (BATCH, x),                           # no WARMUP fence: violation
    ]))
    ch.recv()
    ch.recv()
    _assert_raises_with_rule("warmup-skipped", ch.recv)


def test_warmup_fence_clears_the_obligation():
    ch = _wrap()
    x = np.ones(4, np.float32)
    for kind in (BATCH, RECONFIG, WARMUP, BATCH):
        payload = {"bounds": (0, 2)} if kind == RECONFIG else x
        ch.send(payload, kind=kind)
        ch.recv()
    assert drain_violations() == []


def test_duplicated_fanin_token_raises():
    tok = {"bounds": (0, 2, 5), "codecs": ("none", "none")}
    ch = _wrap(_Loopback(script=[(RECONFIG, tok), (RECONFIG, tok)]))
    ch.recv()
    _assert_raises_with_rule("token-dup", ch.recv)


def test_distinct_reconfigs_are_not_duplicates():
    ch = _wrap(_Loopback(script=[
        (RECONFIG, {"bounds": (0, 2, 5)}),
        (WARMUP, None),
        (RECONFIG, {"bounds": (0, 3, 5)}),    # a different cut: legitimate
    ]))
    ch.recv(), ch.recv(), ch.recv()
    assert drain_violations() == []


def test_reordered_seq_raises():
    ch = _wrap(_SwapLoopback(_Hop()))
    a = np.arange(8, dtype=np.float32)
    b = np.arange(8, dtype=np.float32) * -1.0
    ch.send(a, kind=BATCH)
    ch.send(b, kind=BATCH)                    # transport delivers b first
    _assert_raises_with_rule("seq-order", ch.recv)


def test_write_into_leased_slot_raises():
    slab = np.zeros(64, np.float32)
    view = slab[:32]                          # payload.base is the slab
    assert view.base is not None
    ch = _wrap(_Loopback(script=[(BATCH, view), (BATCH, np.ones(2))]))
    ch.recv()                                 # leases the view
    slab[:4] = 7.0                            # sender scribbles on the slot
    _assert_raises_with_rule("lease", ch.recv)


def test_untouched_lease_is_silent():
    slab = np.zeros(64, np.float32)
    ch = _wrap(_Loopback(script=[(BATCH, slab[:32]), (BATCH, np.ones(2))]))
    ch.recv()
    ch.recv()                                 # canary intact: no violation
    assert drain_violations() == []


def test_bad_codec_byte_raises_frame_decode():
    # an unknown codec wire byte surfaces from the framer as a KeyError
    ch = _wrap(_Loopback(script=[KeyError(9)]))
    _assert_raises_with_rule("frame-decode", ch.recv)


def test_stop_is_terminal_both_directions():
    ch = _wrap()
    ch.send(None, kind=STOP)
    _assert_raises_with_rule(
        "stop-terminal", lambda: ch.send(np.ones(2), kind=BATCH))
    ch2 = _wrap(_Loopback(script=[(STOP, None), (STATS, {})]))
    ch2.recv()
    _assert_raises_with_rule("stop-terminal", ch2.recv)


def test_repeated_stop_is_tolerated():
    ch = _wrap()
    ch.send(None, kind=STOP)
    ch.send(None, kind=STOP)                  # idempotent teardown
    assert drain_violations() == []


def test_malformed_reconfig_payloads_raise():
    for payload in (
        {"codecs": ("none",)},                # no bounds
        {"bounds": (5, 2)},                   # not increasing
        {"bounds": (3,)},                     # too few edges
        {"bounds": (0, 2), "codecs": ("gzip9",)},  # unregistered codec
        "0:5",                                # wrong type entirely
    ):
        ch = _wrap()
        _assert_raises_with_rule(
            "reconfig-payload", lambda: ch.send(payload, kind=RECONFIG))


def test_out_of_range_kind_raises():
    ch = _wrap()
    _assert_raises_with_rule(
        "kind-range", lambda: ch.send(None, kind=42))


def test_coded_hop_checks_structure_not_bytes():
    # an int8 hop rewrites payload bytes in flight: the ledger must only
    # compare structural identity, so a lossy round-trip stays silent
    hop = _Hop(codec="int8")
    inner = _Loopback(hop)
    ch = _wrap(inner)
    x = np.linspace(-1, 1, 32, dtype=np.float32)
    ch.send(x, kind=BATCH)
    inner.q[0] = (BATCH, (x * 0.98).astype(np.float32))  # quantized echo
    ch.recv()
    assert drain_violations() == []


def test_clean_stream_is_silent():
    ch = _wrap()
    x = np.arange(16, dtype=np.float32)
    for kind in (WARMUP, BATCH, BATCH, STATS, CLOCK, STOP):
        ch.send(x if kind in (WARMUP, BATCH) else None, kind=kind)
        ch.recv()
    assert drain_violations() == []


# --------------------------------------------------------------------------- #
# overhead: the wrapper must not tax the hop
# --------------------------------------------------------------------------- #
@pytest.mark.slow
def test_sanitizer_overhead_is_small():
    """Measured hop-µs with and without the wrapper on a real shmem hop
    at 64 KiB.  Target is <10% (documented in the README); the assert is
    deliberately loose (50% + scheduler slack) so a noisy CI box cannot
    flake it — a real regression (per-message deep copies, full-payload
    hashing) shows up as 2-10x, not 1.2x."""
    from repro.runtime.transport import measure_hop
    size = 65536
    base = measure_hop("shmem", [size], n_per_size=40, sanitize=False)[size]
    sani = measure_hop("shmem", [size], n_per_size=40, sanitize=True)[size]
    assert drain_violations() == []
    m_base = float(np.median(base))
    m_sani = float(np.median(sani))
    assert m_sani <= m_base * 1.5 + 100e-6, \
        f"sanitizer overhead too high: {m_base*1e6:.1f}µs -> {m_sani*1e6:.1f}µs"


# --------------------------------------------------------------------------- #
# deep mode: full-payload fingerprints (REPRO_SANITIZE_DEEP=1)
# --------------------------------------------------------------------------- #
def test_shallow_sample_misses_interior_corruption(monkeypatch):
    """The default fingerprint hashes a head/tail sample — corruption
    strictly between the samples passes.  This is the documented gap
    that deep mode exists to close (and the control for the test
    below)."""
    monkeypatch.delenv("REPRO_SANITIZE_DEEP", raising=False)
    inner = _Loopback(_Hop())
    ch = _wrap(inner)
    x = np.arange(64, dtype=np.float32)
    ch.send(x.copy(), kind=BATCH)
    inner.q[0][1][32] = -1.0                  # flip one interior element
    ch.recv()
    assert drain_violations() == []


def test_deep_sanitize_catches_interior_corruption(monkeypatch):
    """``REPRO_SANITIZE_DEEP=1`` crc32s the whole payload, so the same
    interior flip the sampled fingerprint missed above now raises."""
    monkeypatch.setenv("REPRO_SANITIZE_DEEP", "1")
    inner = _Loopback(_Hop())
    ch = _wrap(inner)
    x = np.arange(64, dtype=np.float32)
    ch.send(x.copy(), kind=BATCH)
    inner.q[0][1][32] = -1.0
    _assert_raises_with_rule("seq-order", ch.recv)


def test_deep_enabled_reads_env_per_call(monkeypatch):
    from repro.runtime.sanitizer import deep_enabled
    monkeypatch.delenv("REPRO_SANITIZE_DEEP", raising=False)
    assert not deep_enabled()
    monkeypatch.setenv("REPRO_SANITIZE_DEEP", "0")
    assert not deep_enabled()
    monkeypatch.setenv("REPRO_SANITIZE_DEEP", "1")
    assert deep_enabled()
