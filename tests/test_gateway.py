"""ServeGate: the multi-tenant serving gateway.

The fairness/ordering matrix — {socket, shmem} x {2, 8 tenants} x
{uniform, bursty} — asserts the gateway's core contract: every tenant's
results come back in per-tenant submit order, **bit-identical** to a
solo run of the same requests (the gateway pads every micro-batch to
``max_batch`` rows, which is what makes coalesced compute row-position
invariant), with zero cross-tenant leakage and zero sanitizer
violations.  On top of the matrix: a chaos worker-kill proving
per-tenant replay isolation, the AIMD admission window under SLO
pressure, fleet-objective aggregation, QoS decomposition, cancellation
through the CANCEL fence, and the deep-sanitize tier end to end.
"""
import jax
import numpy as np
import pytest

from repro.core import scenarios
from repro.core.autosplit import AdaptiveSplitter
from repro.core.devices import LAN_PI_GPU
from repro.runtime import (EdgePipeline, FaultPlan, FleetController,
                           Gateway, QoSRecord, drain_qos, drain_recoveries,
                           drain_violations)

MAX_BATCH = 8
N_REQS = 3                                    # requests per tenant
NAMES = [f"tenant{i}" for i in range(8)]


def _tiny_model():
    from repro.models.cnn.layers import (Conv2D, Flatten, Linear, Pool,
                                         ReLU, Sequential)
    from repro.models.cnn.zoo import CNNModel
    blocks = [
        ("conv0", Sequential([Conv2D(3, 8, 3, 1, 1), ReLU()])),
        ("conv1", Sequential([Conv2D(8, 8, 3, 1, 1), ReLU()])),
        ("pool", Pool("max", 2, 2)),
        ("conv2", Sequential([Conv2D(8, 16, 3, 1, 1), ReLU()])),
        ("head", Sequential([Flatten(), Linear(16 * 16 * 16, 10)])),
    ]
    return CNNModel("tinycnn", blocks, input_hw=32)


@pytest.fixture(scope="module")
def tiny():
    m = _tiny_model()
    return m, m.init(jax.random.PRNGKey(0))


def _requests():
    """The same per-tenant request tensors for every run — distinct
    per (tenant, req) so leakage or reordering shows up in the bits."""
    return {n: [np.asarray(jax.random.normal(
                    jax.random.PRNGKey(1000 + 10 * i + j), (1, 32, 32, 3)))
                for j in range(N_REQS)]
            for i, n in enumerate(NAMES)}


@pytest.fixture(scope="module")
def solo_refs(tiny):
    """Each tenant served *alone* through its own gateway (emulated),
    with the same ``max_batch`` padding as every mixed run — the
    bit-identity baseline for the whole matrix."""
    m, params = tiny
    reqs = _requests()
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU], sanitize=True)
    pipe.warmup(reqs[NAMES[0]][0])
    refs = {}
    for n in NAMES:
        with Gateway(pipe, [scenarios.TenantSpec(n)], max_batch=MAX_BATCH,
                     batch_window_s=0.0) as gw:
            c = gw.client(n)
            for x in reqs[n]:
                c.submit(x)
            refs[n] = c.drain()
        assert [r for r, _ in refs[n]] == list(range(N_REQS))
    assert drain_violations() == []
    drain_qos()
    pipe.close()
    return reqs, refs


def _run_mixed(tiny, transport, mix_name, reqs):
    """One mixed run: every tenant in the mix submits its requests
    (interleaved for uniform mixes, per-tenant bursts for bursty ones),
    then the gateway drains.  Returns per-tenant results + QoS."""
    m, params = tiny
    mix = scenarios.get_tenant_mix(mix_name)
    names = [t.name for t in mix.tenants]
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU], transport=transport,
                        sanitize=True, timeout_s=120)
    with pipe:
        pipe.warmup(reqs[names[0]][0])
        with Gateway(pipe, mix, max_batch=MAX_BATCH,
                     batch_window_s=0.005) as gw:
            clients = {n: gw.client(n) for n in names}
            if mix.arrival == "bursty":
                for n in names:               # whole burst back-to-back
                    for x in reqs[n]:
                        clients[n].submit(x)
            else:
                for j in range(N_REQS):       # round-robin interleave
                    for n in names:
                        clients[n].submit(reqs[n][j])
            got = {n: clients[n].drain() for n in names}
            qos = gw.drain_qos()
    assert drain_violations() == []
    return names, got, qos


# --------------------------------------------------------------------------- #
# the fairness/ordering matrix
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("transport", ["socket", "shmem"])
@pytest.mark.parametrize("mix_name", ["duo_uniform", "duo_bursty",
                                      "octet_uniform", "octet_bursty"])
def test_gateway_matrix_bit_identical_to_solo(tiny, solo_refs, transport,
                                              mix_name):
    reqs, refs = solo_refs
    names, got, qos = _run_mixed(tiny, transport, mix_name, reqs)
    for n in names:
        # per-tenant submit order, nothing lost, nothing duplicated
        assert [r for r, _ in got[n]] == list(range(N_REQS))
        # zero leakage: every value bit-identical to the solo run
        for (_, y), (_, ref) in zip(got[n], refs[n]):
            assert np.array_equal(np.asarray(y), np.asarray(ref)), \
                f"tenant {n} leaked or corrupted under {mix_name}"
    # every request is accounted for in QoS, attributed to its tenant
    assert sorted((r.tenant, r.req_id) for r in qos) == \
        sorted((n, j) for n in names for j in range(N_REQS))
    if len(names) == 8:                       # octet: coalescing happened
        assert max(r.coalesced for r in qos) >= 2


# --------------------------------------------------------------------------- #
# chaos: worker kill mid-stream, per-tenant replay isolation
# --------------------------------------------------------------------------- #
def test_gateway_survives_worker_kill_bit_identical(tiny, solo_refs):
    """A SIGKILLed stage mid-stream: supervised recovery replays the
    retained (padded) micro-batches, and every tenant still gets its
    full result stream bit-identical to solo — a fault on a shared
    batch never bleeds across the tenants riding it."""
    reqs, refs = solo_refs
    m, params = tiny
    drain_recoveries()
    mix = scenarios.get_tenant_mix("duo_uniform")
    names = [t.name for t in mix.tenants]
    plan = FaultPlan().kill_worker(stage=1, at_seq=2)
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU], transport="shmem",
                        fault_plan=plan, stall_timeout_s=2.0,
                        timeout_s=120, sanitize=True)
    with pipe:
        pipe.warmup(reqs[names[0]][0])
        with Gateway(pipe, mix, max_batch=MAX_BATCH,
                     batch_window_s=0.0) as gw:
            clients = {n: gw.client(n) for n in names}
            for j in range(N_REQS):
                for n in names:
                    clients[n].submit(reqs[n][j])
            got = {n: clients[n].drain() for n in names}
    assert [r.kind for r in drain_recoveries()] == ["restart"]
    assert drain_violations() == []
    for n in names:
        assert [r for r, _ in got[n]] == list(range(N_REQS))
        for (_, y), (_, ref) in zip(got[n], refs[n]):
            assert np.array_equal(np.asarray(y), np.asarray(ref))


# --------------------------------------------------------------------------- #
# QoS decomposition
# --------------------------------------------------------------------------- #
def test_qos_records_decompose_latency(tiny):
    m, params = tiny
    reqs = _requests()
    drain_qos()
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU], sanitize=True)
    pipe.warmup(reqs[NAMES[0]][0])
    mix = scenarios.get_tenant_mix("duo_uniform")
    with Gateway(pipe, mix, max_batch=MAX_BATCH, batch_window_s=0.0) as gw:
        for j in range(N_REQS):
            for t in mix.tenants:
                gw.submit(t.name, reqs[t.name][j])
        gw.drain()
        qos = gw.drain_qos()
    assert len(qos) == 2 * N_REQS
    for r in qos:
        assert isinstance(r, QoSRecord)
        assert r.queue_s >= 0 and r.service_s > 0
        assert r.latency_s == pytest.approx(r.queue_s + r.service_s)
        assert 0 <= r.wire_s <= r.service_s + 1e-9
        assert r.rows == 1 and 1 <= r.coalesced <= MAX_BATCH
        assert 0 < r.occupancy <= 1
        assert r.slo_s == gw.tenants[r.tenant].slo_s
        assert r.violated == (r.latency_s > r.slo_s)
    # gateway-scoped drain already claimed them: the global log is clean
    assert drain_qos() == []
    assert drain_violations() == []
    pipe.close()


# --------------------------------------------------------------------------- #
# SLO-aware AIMD admission
# --------------------------------------------------------------------------- #
def test_aimd_window_throttles_then_recovers(tiny):
    """An SLO-violating tenant drives multiplicative decrease down to a
    1-batch window; clean traffic afterwards grows it back additively."""
    m, params = tiny
    reqs = _requests()
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU], sanitize=True)
    pipe.warmup(reqs[NAMES[0]][0])
    tenants = [scenarios.TenantSpec("hot", slo_s=1e-9),   # always violates
               scenarios.TenantSpec("cool", slo_s=30.0)]  # never does
    with Gateway(pipe, tenants, max_batch=MAX_BATCH, batch_window_s=0.0,
                 inflight=4, ai_every=1) as gw:
        cap = gw.inflight_window
        assert cap >= 2
        for j in range(N_REQS):               # phase 1: violations
            gw.submit("hot", reqs[NAMES[0]][j])
            gw.drain()
        assert gw.inflight_window == 1        # halved to the floor
        assert gw.session.inflight == 1       # applied to the session
        for j in range(N_REQS * 2):           # phase 2: clean traffic
            gw.submit("cool", reqs[NAMES[1]][j % N_REQS])
            gw.drain()
        assert gw.inflight_window > 1         # additive recovery
        assert gw.inflight_window <= cap
        # history records both directions of the excursion
        wins = [w for _, w in gw.window_history]
        assert min(wins) == 1 and wins[0] == cap and wins[-1] > 1
        qos = gw.drain_qos()
        assert all(r.violated for r in qos if r.tenant == "hot")
        assert not any(r.violated for r in qos if r.tenant == "cool")
    assert drain_violations() == []
    pipe.close()


# --------------------------------------------------------------------------- #
# fleet-level objectives
# --------------------------------------------------------------------------- #
def test_fleet_controller_aggregates_and_steers(tiny):
    m, params = tiny
    reqs = _requests()
    scen = scenarios.get("pi_pi_gpu")
    graph = m.block_graph(input_hw=32)
    # hysteresis ~1: the fleet axis steers the policy, but no migration
    # fires — delivery determinism is owned by the matrix tests above
    splitter = AdaptiveSplitter(graph, scen, batch=MAX_BATCH,
                                policy="throughput", hysteresis=0.99)
    splitter.current = splitter.solve()
    ctrl = FleetController(splitter, check_every=2, probe=False)
    pipe = EdgePipeline(m, params, splitter.current.partition, scen,
                        sanitize=True)
    pipe.warmup(reqs[NAMES[0]][0])
    mix = scenarios.get_tenant_mix("octet_mixed_slo")
    with Gateway(pipe, mix, controller=ctrl, max_batch=MAX_BATCH,
                 batch_window_s=0.005) as gw:
        for j in range(N_REQS):
            for t in mix.tenants:
                gw.submit(t.name, reqs[t.name][j])
        gw.drain()
        obj = ctrl.fleet_objectives()
        assert obj is not None
        assert obj.n == len(gw.qos_recent)
        assert obj.p99_s >= obj.p50_s > 0
        assert obj.aggregate_ips > 0
        assert obj.j_per_request >= 0
        assert 0 <= obj.violation_rate <= 1
        assert obj.strictest_slo_s == min(t.slo_s for t in mix.tenants)
        assert obj.policy in ("latency", "throughput")
        assert obj.policy == splitter.policy  # the steer was applied
        assert ctrl.fleet_history             # one per control decision
        gw.drain_qos()
    assert drain_violations() == []
    pipe.close()


# --------------------------------------------------------------------------- #
# cancellation through the gateway
# --------------------------------------------------------------------------- #
def test_gateway_cancel_resubmit_and_skip(tiny, solo_refs):
    reqs, refs = solo_refs
    m, params = tiny
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU], sanitize=True)
    pipe.warmup(reqs[NAMES[0]][0])
    mix = scenarios.get_tenant_mix("duo_uniform")
    names = [t.name for t in mix.tenants]
    with Gateway(pipe, mix, max_batch=4, batch_window_s=0.0) as gw:
        clients = {n: gw.client(n) for n in names}
        for j in range(N_REQS):
            for n in names:
                clients[n].submit(reqs[n][j])
        flushed = gw.cancel_inflight(action="resubmit")
        got = {n: clients[n].drain() for n in names}
        # every flushed request redelivered, in order, bit-identical
        for n in names:
            assert [r for r, _ in got[n]] == list(range(N_REQS))
            for (_, y), (_, ref) in zip(got[n], refs[n]):
                assert np.array_equal(np.asarray(y), np.asarray(ref))
        # skip: flushed requests surface as (req_id, None) placeholders
        for n in names:
            clients[n].submit(reqs[n][0])
        flushed2 = gw.cancel_inflight(action="skip")
        got2 = {n: clients[n].drain() for n in names}
        skipped = [rv for n in names for rv in got2[n] if rv[1] is None]
        assert len(skipped) == flushed2
        assert flushed >= 0 and flushed2 >= 0
        # the fence is async: pump the discarded arrivals home, then
        # every CancelRecord must show its batch flushed
        gw.session.drain()
        cancels = gw.session.drain_cancels()
        assert all(c.flushed for c in cancels)
    assert drain_violations() == []
    pipe.close()


# --------------------------------------------------------------------------- #
# deep sanitize tier, end to end
# --------------------------------------------------------------------------- #
def test_gateway_clean_under_deep_sanitize(tiny, solo_refs, monkeypatch):
    """``REPRO_SANITIZE_DEEP=1``: full-payload crc32 fingerprints on
    every sanitized hop.  A clean mixed run must stay silent — and still
    be bit-identical to solo."""
    reqs, refs = solo_refs
    m, params = tiny
    monkeypatch.setenv("REPRO_SANITIZE_DEEP", "1")
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU], sanitize=True)
    pipe.warmup(reqs[NAMES[0]][0])
    mix = scenarios.get_tenant_mix("duo_uniform")
    names = [t.name for t in mix.tenants]
    with Gateway(pipe, mix, max_batch=MAX_BATCH, batch_window_s=0.0) as gw:
        clients = {n: gw.client(n) for n in names}
        for j in range(N_REQS):
            for n in names:
                clients[n].submit(reqs[n][j])
        got = {n: clients[n].drain() for n in names}
    for n in names:
        for (_, y), (_, ref) in zip(got[n], refs[n]):
            assert np.array_equal(np.asarray(y), np.asarray(ref))
    assert drain_violations() == []
    pipe.close()


# --------------------------------------------------------------------------- #
# tenant-mix specs
# --------------------------------------------------------------------------- #
def test_tenant_mix_registry_and_validation():
    for name in ("duo_uniform", "duo_bursty", "octet_uniform",
                 "octet_bursty", "octet_mixed_slo"):
        mix = scenarios.get_tenant_mix(name)
        assert mix.n_tenants in (2, 8)
        assert len({t.name for t in mix.tenants}) == mix.n_tenants
        assert all(t.slo_s > 0 and t.weight > 0 and t.burst >= 1
                   for t in mix.tenants)
    with pytest.raises(KeyError):
        scenarios.get_tenant_mix("nope")
    with pytest.raises(ValueError):
        scenarios.TenantSpec("t", slo_s=-1.0)
    mix = scenarios.get_tenant_mix("octet_mixed_slo")
    assert mix.spec("tenant0").slo_s != mix.spec("tenant7").slo_s


def test_gateway_rejects_bad_requests(tiny):
    m, params = tiny
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU])
    with Gateway(pipe, [scenarios.TenantSpec("a")], max_batch=2) as gw:
        with pytest.raises(KeyError, match="unknown tenant"):
            gw.submit("nope", np.zeros((1, 32, 32, 3), np.float32))
        with pytest.raises(ValueError, match="exceeds"):
            gw.submit("a", np.zeros((3, 32, 32, 3), np.float32))
        with pytest.raises(ValueError, match="batched"):
            gw.submit("a", np.float32(1.0))
    with pytest.raises(ValueError, match="at least one tenant"):
        Gateway(pipe, [])
    pipe.close()
