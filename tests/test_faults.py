"""Fault-tolerant pipelines: the chaos/fault-injection layer, the
supervised recovery machinery, and replica failover.

Three tiers:

* unit — :class:`FaultPlan` builders/views, the pinned
  :class:`BackoffPolicy` schedule, fan-lane eviction, chaos fire-once
  semantics, and transport ``TransportTimeout`` send bounds (no
  processes).
* liveness — the historical hole: an orchestrator blocked in a channel
  op while every worker is dead must fail fast, not hang (satellite of
  the supervisor work).
* matrix — {socket, shmem} x {drain, drop} x {worker-kill, frame-stall,
  link-flap, lane-kill at r=2}, injected mid-stream: every cell must
  recover without operator intervention, produce bit-identical ordered
  results (zero lost / duplicated / reordered batches), and drain zero
  sanitizer violations.
"""
import os

import jax
import numpy as np
import pytest

from repro.core import scenarios
from repro.core.devices import LAN_PI_GPU
from repro.runtime import (BackoffPolicy, EdgePipeline, FaultPlan,
                           TransportError, TransportTimeout,
                           drain_injections, drain_recoveries,
                           drain_violations, get_transport)
from repro.runtime.faults import FaultEvent
from repro.runtime.transport import BATCH, HopSpec


def _tiny_model():
    """Same 5-block CNN the session tests use — recovery is the thing
    under test, not the compute."""
    from repro.models.cnn.layers import (Conv2D, Flatten, Linear, Pool,
                                         ReLU, Sequential)
    from repro.models.cnn.zoo import CNNModel
    blocks = [
        ("conv0", Sequential([Conv2D(3, 8, 3, 1, 1), ReLU()])),
        ("conv1", Sequential([Conv2D(8, 8, 3, 1, 1), ReLU()])),
        ("pool", Pool("max", 2, 2)),
        ("conv2", Sequential([Conv2D(8, 16, 3, 1, 1), ReLU()])),
        ("head", Sequential([Flatten(), Linear(16 * 16 * 16, 10)])),
    ]
    return CNNModel("tinycnn", blocks, input_hw=32)


@pytest.fixture(scope="module")
def tiny():
    m = _tiny_model()
    return m, m.init(jax.random.PRNGKey(0))


def _batches(n, batch=2, hw=32):
    return [np.asarray(jax.random.normal(jax.random.PRNGKey(100 + i),
                                         (batch, hw, hw, 3)))
            for i in range(n)]


def _run_with_plan(tiny, transport, plan, replicas=None, policy="drain",
                   n=8):
    """Stream ``n`` batches through a supervised 2-stage pipeline under
    ``plan``; return (ordered outputs, references)."""
    m, params = tiny
    xs = _batches(n)
    refs = [np.asarray(m.apply(params, x)) for x in xs]
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU], transport=transport,
                        replicas=replicas, fault_plan=plan,
                        stall_timeout_s=2.0, timeout_s=120, sanitize=True)
    with pipe:
        pipe.warmup(xs[0])
        with pipe.session(policy=policy) as s:
            for x in xs:
                s.submit(x)
            outs = s.drain()
    return [np.asarray(y) for y in outs], refs


# --------------------------------------------------------------------------- #
# FaultPlan / FaultEvent units
# --------------------------------------------------------------------------- #
def test_fault_plan_builders_compose_and_views_split():
    plan = (FaultPlan(seed=7)
            .kill_worker(stage=1, at_seq=4, lane=1)
            .stall(hop=-1, at_seq=2, for_s=0.3)
            .drop(hop=0, at_seq=5)
            .duplicate(hop=-1, at_seq=2)
            .flap(hop=-1, at_seq=6, down_s=0.5)
            .corrupt(hop=0, at_seq=1))
    assert len(plan.events) == 6
    feed = plan.channel_events(-1)
    assert sorted(feed) == [2, 6]
    assert [e.kind for e in feed[2]] == ["frame-stall", "frame-dup"]
    hop0 = plan.channel_events(0)
    assert sorted(hop0) == [1, 5]
    kills = plan.kill_events()
    assert list(kills) == [4]
    assert (kills[4][0].stage, kills[4][0].lane) == (1, 1)
    # builders are pure: the intermediate plans are untouched
    assert FaultPlan(seed=7).events == ()


def test_fault_plan_is_picklable_and_frozen():
    import pickle
    plan = FaultPlan(seed=3).drop(hop=-1, at_seq=2)
    clone = pickle.loads(pickle.dumps(plan))
    assert clone == plan
    with pytest.raises(Exception):
        plan.seed = 9                         # frozen dataclass


def test_fault_event_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent("brownout")


def test_named_fault_plans_registry():
    for name in scenarios.FAULT_PLANS:
        plan = scenarios.get_fault_plan(name)
        assert isinstance(plan, FaultPlan) and plan.events
    with pytest.raises(KeyError, match="unknown fault plan"):
        scenarios.get_fault_plan("nope")


# --------------------------------------------------------------------------- #
# BackoffPolicy: the pinned retry schedule and caps
# --------------------------------------------------------------------------- #
def test_backoff_schedule_is_pinned():
    p = BackoffPolicy()
    assert p.schedule() == (0.05, 0.1, 0.2, 0.4, 0.8)
    assert p.retries == 5                     # the supervisor's retry cap
    assert p.delay(10) == p.cap_s == 2.0      # bounded, never unbounded
    assert len(p.schedule()) == p.retries


# --------------------------------------------------------------------------- #
# Fan-lane eviction (in-process units)
# --------------------------------------------------------------------------- #
def _queue_lanes(n):
    from repro.runtime.edge import _QueueChan
    return [_QueueChan() for _ in range(n)]


def test_fanout_evict_lane_restripes_survivors():
    from repro.runtime.transport import FanOutChannel
    lanes = _queue_lanes(3)
    out = FanOutChannel(lanes)
    out.evict_lane(1)
    for i in range(4):
        out.send(i, kind=BATCH)
    assert [v for _, v in _drain_lane(lanes[0])] == [0, 2]
    assert [v for _, v in _drain_lane(lanes[2])] == [1, 3]
    assert _drain_lane(lanes[1]) == []        # dead lane gets nothing


def test_fanin_evict_lane_preserves_merge_order():
    from repro.runtime.transport import FanInChannel, FanOutChannel
    lanes = _queue_lanes(2)
    out, inn = FanOutChannel(lanes), FanInChannel(lanes)
    out.evict_lane(1)
    inn.evict_lane(1)
    for i in range(4):
        out.send(i, kind=BATCH)
    got = [inn.recv(timeout=1.0)[1] for _ in range(4)]
    assert got == [0, 1, 2, 3]


def test_evict_last_lane_is_refused():
    from repro.runtime.transport import FanOutChannel
    out = FanOutChannel(_queue_lanes(1))
    with pytest.raises(ValueError):
        out.evict_lane(0)
    with pytest.raises(IndexError):
        FanOutChannel(_queue_lanes(2)).evict_lane(5)


def _drain_lane(lane):
    got = []
    while True:
        try:
            got.append(lane.recv(timeout=0.01))
        except Exception:
            return got


# --------------------------------------------------------------------------- #
# Chaos fire-once semantics (in-process)
# --------------------------------------------------------------------------- #
def test_chaos_events_fire_exactly_once_across_rebuilds():
    from repro.runtime.edge import _QueueChan
    from repro.runtime.faults import ChaosChannel
    drain_injections()
    plan = FaultPlan().drop(hop=0, at_seq=1)
    fired: set = set()
    for rebuild in range(2):                  # same fired set, fresh chan
        inner = _QueueChan()
        inner.hop = HopSpec(index=0, faults=plan)
        chaos = ChaosChannel(inner, fired=fired)
        for i in range(3):
            chaos.send(i, kind=BATCH)
        got = [v for _, v in _drain_lane(inner)]
        if rebuild == 0:
            assert got == [0, 2]              # seq 1 swallowed
        else:
            assert got == [0, 1, 2]           # replay: not re-perturbed
    assert [i.kind for i in drain_injections()] == ["frame-drop"]


# --------------------------------------------------------------------------- #
# TransportTimeout send bounds (no peer draining)
# --------------------------------------------------------------------------- #
def test_shmem_send_times_out_when_receiver_not_draining():
    chan = get_transport("shmem").open(
        HopSpec(index=0, depth=1, send_timeout_s=0.2))
    try:
        with pytest.raises(TransportTimeout, match="not draining"):
            # payloads big enough to claim real slots (not inlined):
            # depth+1 slots are never recycled without a receiver
            for _ in range(8):
                chan.send(np.zeros(100_000, np.float32), kind=BATCH)
    finally:
        chan.close()


def test_socket_send_is_bounded_when_peer_not_draining():
    chan = get_transport("socket").open(
        HopSpec(index=0, send_timeout_s=0.2))
    try:
        # far larger than loopback socket buffers: the vectored send
        # cannot complete without a reader, and must not hang
        with pytest.raises(TransportError):
            chan.send(np.zeros(16 << 20, np.uint8), kind=BATCH)
    finally:
        chan.close()


# --------------------------------------------------------------------------- #
# Liveness: dead workers must fail fast, not hang (the edge.py hole)
# --------------------------------------------------------------------------- #
def test_unsupervised_submit_fails_fast_when_workers_die(tiny):
    m, params = tiny
    xs = _batches(2)
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU], transport="shmem",
                        timeout_s=60)
    with pipe:
        pipe.warmup(xs[0])
        eng = pipe._engine
        for p in eng._procs:
            p.kill()
        for p in eng._procs:
            p.join(10)
        import time
        t0 = time.perf_counter()
        with pytest.raises(TransportError, match="died"):
            for _ in range(64):               # ring fills; send must not hang
                eng.submit(xs[0])
        assert time.perf_counter() - t0 < 30  # bounded by liveness polling


# --------------------------------------------------------------------------- #
# Teardown idempotence and shmem hygiene after SIGKILL
# --------------------------------------------------------------------------- #
def test_close_is_idempotent_after_recovery(tiny):
    drain_recoveries()
    outs, refs = _run_with_plan(
        tiny, "shmem", FaultPlan().kill_worker(stage=1, at_seq=2), n=4)
    for r, y in zip(refs, outs):
        assert np.allclose(r, y, atol=1e-5)
    assert [r.kind for r in drain_recoveries()] == ["restart"]


def test_double_close_and_close_with_inflight(tiny):
    m, params = tiny
    xs = _batches(3)
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU], transport="shmem",
                        supervise=True, timeout_s=60)
    pipe.warmup(xs[0])
    eng = pipe._engine
    for x in xs:
        eng.submit(x)                         # abandon in-flight batches
    pipe.close()
    pipe.close()                              # second close is a no-op
    eng.close()                               # engine close too
    assert eng._procs == []


def test_sigkilled_replicated_stage_leaves_no_shmem_leaks(tiny):
    if not os.path.isdir("/dev/shm"):
        pytest.skip("no /dev/shm on this platform")
    drain_recoveries()
    before = set(os.listdir("/dev/shm"))
    outs, refs = _run_with_plan(
        tiny, "shmem", FaultPlan().kill_worker(stage=1, at_seq=2, lane=1),
        replicas=(1, 2), n=6)
    for r, y in zip(refs, outs):
        assert np.allclose(r, y, atol=1e-5)
    kinds = [r.kind for r in drain_recoveries()]
    assert kinds[0] == "failover"             # degraded to r-1 first
    assert "restaff" in kinds                 # restaffed at quiescence
    # mp.Event/Lock semaphores are freed with their (parent-held) Python
    # objects; collect them so the diff shows only true segment leaks
    import gc
    gc.collect()
    assert set(os.listdir("/dev/shm")) - before == set()


# --------------------------------------------------------------------------- #
# The fault matrix (mid-stream injection, recovery, exactness)
# --------------------------------------------------------------------------- #
_FAULTS = {
    "worker-kill": (lambda: FaultPlan().kill_worker(stage=1, at_seq=3),
                    None, ["restart"]),
    "frame-stall": (lambda: FaultPlan().stall(hop=-1, at_seq=2, for_s=0.3),
                    None, []),
    "link-flap": (lambda: FaultPlan().flap(hop=-1, at_seq=2, down_s=0.5),
                  None, []),
    "lane-kill": (lambda: FaultPlan().kill_worker(stage=1, at_seq=3, lane=1),
                  (1, 2), None),              # failover path varies by
                                              # transport death reporting
}


@pytest.mark.slow
@pytest.mark.parametrize("transport", ["socket", "shmem"])
@pytest.mark.parametrize("policy", ["drain", "drop"])
@pytest.mark.parametrize("fault", sorted(_FAULTS))
def test_fault_matrix_recovers_exactly(tiny, transport, policy, fault):
    build, replicas, expect_kinds = _FAULTS[fault]
    drain_recoveries()
    drain_violations()
    outs, refs = _run_with_plan(tiny, transport, build(),
                                replicas=replicas, policy=policy)
    assert len(outs) == len(refs)             # zero lost / duplicated
    for r, y in zip(refs, outs):              # zero reordered, bit-exact
        assert np.allclose(r, y, atol=1e-5)
    kinds = [r.kind for r in drain_recoveries()]
    if expect_kinds is not None:
        assert kinds == expect_kinds
    else:
        assert kinds                          # some recovery happened
    assert drain_violations() == []           # sanitized end to end


@pytest.mark.slow
@pytest.mark.parametrize("transport", ["socket", "shmem"])
@pytest.mark.parametrize("fault", ["drop", "dup", "corrupt"])
def test_wire_damage_recovers_exactly(tiny, transport, fault):
    plan = {
        "drop": FaultPlan().drop(hop=-1, at_seq=2),
        "dup": FaultPlan().duplicate(hop=-1, at_seq=2),
        "corrupt": FaultPlan().corrupt(hop=-1, at_seq=2),
    }[fault]
    drain_recoveries()
    drain_violations()
    drain_injections()
    outs, refs = _run_with_plan(tiny, transport, plan)
    for r, y in zip(refs, outs):
        assert np.allclose(r, y, atol=1e-5)
    assert [i.kind for i in drain_injections()]
    if fault == "dup":
        # receiver-side wire-seq dedup absorbs it: no recovery needed
        assert drain_recoveries() == []
    else:
        # a gap / corrupt header is detected at the receiver and healed
        # by restart + replay
        assert [r.kind for r in drain_recoveries()] == ["restart"]
    assert drain_violations() == []


@pytest.mark.slow
def test_recovery_records_carry_timings(tiny):
    drain_recoveries()
    _run_with_plan(tiny, "shmem",
                   FaultPlan().kill_worker(stage=1, at_seq=3), n=6)
    (rec,) = drain_recoveries()
    assert rec.kind == "restart" and rec.stage >= -1
    assert rec.detect_s >= 0 and rec.restart_s > 0 and rec.replay_s >= 0
    assert rec.batches_replayed >= 1          # in-flight window resubmitted
    assert rec.degraded_capacity == 1.0       # full restart, no degradation
    assert "restart=" in rec.render() and "replay=" in rec.render()
