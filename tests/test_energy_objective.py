"""The N-dimensional objective system + energy as the third objective.

Deterministic (no hypothesis needed — the property suite in
test_core_pareto.py adds randomized d-dim coverage when hypothesis is
installed).  Covers: the objective-vector protocol, d∈{1,2,3,4} fronts
against brute force, d=3 hypervolume, the energy cost model, the
3-objective DP cross-validated against exhaustive enumeration (the PR's
acceptance criterion), and the energy-aware adaptive layer.
"""
import math
import random

import pytest

from repro.core import (Block, BlockGraph, CostTable, ENERGY, LATENCY,
                        THROUGHPUT, AdaptiveSplitter, Objective, best_energy,
                        dominates, dp_front_kway, evaluate_pipeline,
                        hypervolume, is_on_front, knee_point, pareto_front,
                        resolve_objectives, scenarios, solve, sweep_kway)
from repro.core.devices import DeviceProfile, Link
from repro.core.pareto import vector
from repro.core.scenarios import Scenario

OBJ3 = ("latency", "throughput", "energy")


# --------------------------------------------------------------------------- #
# Objective protocol
# --------------------------------------------------------------------------- #
def test_resolve_objectives_names_instances_and_default():
    assert resolve_objectives() == (LATENCY, THROUGHPUT)
    assert resolve_objectives(OBJ3) == (LATENCY, THROUGHPUT, ENERGY)
    assert resolve_objectives((ENERGY, "latency")) == (ENERGY, LATENCY)
    with pytest.raises(ValueError, match="unknown objective"):
        resolve_objectives(("latency", "carbon"))
    with pytest.raises(ValueError, match="at least one"):
        resolve_objectives(())


def test_objective_sense_validated():
    with pytest.raises(ValueError, match="sense"):
        Objective("x", "maximize", "x")


def test_vector_reads_tuples_positionally_and_objects_by_attr():
    assert vector((1.0, 2.0)) == (1.0, 2.0)
    assert vector((1.0, 2.0, 3.0), OBJ3) == (1.0, 2.0, 3.0)

    class M:
        latency_s, throughput, energy_j = 0.5, 8.0, 2.5
    assert vector(M(), OBJ3) == (0.5, 8.0, 2.5)


# --------------------------------------------------------------------------- #
# d-dimensional dominance / fronts
# --------------------------------------------------------------------------- #
def test_dominates_3d_basics_and_antisymmetry():
    a, b = (1.0, 10.0, 2.0), (2.0, 5.0, 3.0)
    assert dominates(a, b, OBJ3)
    assert not dominates(b, a, OBJ3)            # antisymmetry
    # equal vectors never dominate
    assert not dominates(a, a, OBJ3)
    # better on two axes, worse on energy: incomparable
    c = (0.5, 20.0, 5.0)
    assert not dominates(c, a, OBJ3) and not dominates(a, c, OBJ3)


def _naive_front(pts, objs):
    seen, out = set(), []
    for p in pts:
        if p in seen:
            continue
        seen.add(p)
        if not any(dominates(q, p, objs) for q in pts):
            out.append(p)
    return out


@pytest.mark.parametrize("d", [1, 2, 3, 4])
def test_front_matches_brute_force_every_dimension(d):
    names = ["latency", "throughput", "energy"]
    objs = tuple((names[i] if i < 3 else Objective(f"o{i}", "min", f"o{i}"))
                 for i in range(d))
    rnd = random.Random(42 + d)
    for _ in range(40):
        pts = [tuple(rnd.choice([rnd.uniform(0, 5), float(rnd.randint(1, 3))])
                     for _ in range(d))
               for _ in range(rnd.randint(1, 50))]
        front = pareto_front(pts, objs)
        assert sorted(set(front)) == sorted(set(_naive_front(pts, objs)))
        assert len(front) == len(set(front))            # dedup
        for p in front:
            assert is_on_front(p, pts, objs)


def test_front_3d_never_drops_energy_distinct_ties():
    # identical (lat, thr), different energy: 2-D front keeps one, the
    # 3-D front keeps exactly the lower-energy point
    pts = [(1.0, 5.0, 9.0), (1.0, 5.0, 2.0), (2.0, 6.0, 1.0)]
    f3 = pareto_front(pts, OBJ3)
    assert (1.0, 5.0, 2.0) in f3 and (1.0, 5.0, 9.0) not in f3
    assert (2.0, 6.0, 1.0) in f3


def test_legacy_2d_callers_unchanged():
    # the exact cases of the original bi-objective suite
    pts = [(1, 1), (2, 5), (3, 6), (10, 6.5)]
    assert pareto_front(pts) == [(1, 1), (2, 5), (3, 6), (10, 6.5)]
    k = knee_point(pts)
    assert k in ((2, 5), (3, 6))
    assert dominates((1.0, 10.0), (2.0, 5.0))
    assert hypervolume([(1.0, 1.0), (2.0, 2.0)], 3.0) == pytest.approx(3.0)


def test_knee_point_3d_on_front():
    pts = [(1, 1, 10), (2, 5, 5), (3, 6, 4), (10, 6.5, 1)]
    k = knee_point(pts, OBJ3)
    assert k is not None and is_on_front(k, pts, OBJ3)


# --------------------------------------------------------------------------- #
# Hypervolume
# --------------------------------------------------------------------------- #
def test_hypervolume_3d_known_value():
    # single point: box (3-1) × (4-2) × (5-2) = 12
    assert hypervolume([(1.0, 4.0, 2.0)], (3.0, 2.0, 5.0), OBJ3) \
        == pytest.approx(12.0)
    # second, dominated point adds nothing
    assert hypervolume([(1.0, 4.0, 2.0), (2.0, 3.0, 3.0)],
                       (3.0, 2.0, 5.0), OBJ3) == pytest.approx(12.0)
    # disjoint contribution: (1,4,2) and a better-energy, worse-latency pt
    hv = hypervolume([(1.0, 4.0, 2.0), (2.0, 4.0, 1.0)],
                     (3.0, 2.0, 5.0), OBJ3)
    # union = 12 + (3-2)*(4-2)*(2-1) extra slab below energy 2
    assert hv == pytest.approx(12.0 + 2.0)


def test_hypervolume_3d_invalid_reference_raises():
    with pytest.raises(ValueError, match="invalid reference box"):
        hypervolume([(1.0, 4.0, 2.0)], (3.0, 2.0, 1.0), OBJ3)


def test_hypervolume_vector_ref_dimension_checked():
    with pytest.raises(ValueError, match="reference"):
        hypervolume([(1.0, 4.0, 2.0)], (3.0, 2.0), OBJ3)
    with pytest.raises(ValueError, match="either ref or ref_latency"):
        hypervolume([(1.0, 4.0)], 3.0, ref_latency=2.0)


# --------------------------------------------------------------------------- #
# Energy cost model
# --------------------------------------------------------------------------- #
def _two_stage():
    g = BlockGraph("g", (Block("a", 1e9, 10, out_bytes=1000),
                         Block("b", 2e9, 10, out_bytes=10)),
                   input_bytes=100, output_bytes=10)
    d0 = DeviceProfile("d0", flops_per_s=1e9, mem_bytes=10**9,
                       idle_w=2.0, active_w=10.0)
    d1 = DeviceProfile("d1", flops_per_s=2e9, mem_bytes=10**9,
                       idle_w=3.0, active_w=30.0)
    link = Link("l", rtt_s=0.2, bw_bytes_per_s=1e4, energy_per_byte_j=1e-3)
    return g, (d0, d1), (link,)


def test_evaluate_pipeline_energy_hand_computed():
    g, devs, links = _two_stage()
    m = evaluate_pipeline(g, (1,), devs, links, batch=1, include_io=False)
    # stage0: 1e9/1e9 = 1 s busy at 10 W; send 1000 B: 0.1 s rtt/2 +
    # 1000/1e4 = 0.2 s wait at 2 W idle; radio 1000 × 1e-3 = 1 J
    send_s = 0.1 + 1000 / 1e4
    e0 = 10.0 * 1.0 + 2.0 * send_s + 1.0
    # stage1: 2e9/2e9 = 1 s at 30 W, no send
    e1 = 30.0 * 1.0
    assert m.stages[0].energy_j == pytest.approx(e0)
    assert m.stages[1].energy_j == pytest.approx(e1)
    assert m.energy_j == pytest.approx(e0 + e1)


def test_evaluate_pipeline_io_radio_charged():
    g, devs, links = _two_stage()
    no_io = evaluate_pipeline(g, (1,), devs, links, batch=1, include_io=False)
    io = evaluate_pipeline(g, (1,), devs, links, batch=1, include_io=True)
    # dispatch 100 B + return 10 B over the default dispatch link
    assert io.energy_j - no_io.energy_j == pytest.approx(110 * 1e-3)


def test_objectives_accessor_and_batch_scaling():
    g, devs, links = _two_stage()
    m = evaluate_pipeline(g, (1,), devs, links, batch=1, include_io=False)
    assert m.objectives() == (m.latency_s, m.throughput)
    assert m.objectives(OBJ3) == (m.latency_s, m.throughput, m.energy_j)
    m4 = evaluate_pipeline(g, (1,), devs, links, batch=4, include_io=False)
    assert m4.energy_j > m.energy_j         # more samples, more joules


def test_registry_scenarios_carry_power_specs():
    for name in ("pi_to_pi", "pi_to_gpu", "pi_pi_gpu", "pi_only3", "pods2"):
        scen = scenarios.get(name)
        assert all(d.active_w > 0 for d in scen.devices), name
        assert scen.active_power_w > 0
        from repro.core.devices import link_at
        assert all(link_at(l, 0.0).energy_per_byte_j > 0
                   for l in scen.links), name


# --------------------------------------------------------------------------- #
# 3-objective DP — the acceptance criterion
# --------------------------------------------------------------------------- #
def _rand_case(rnd, k):
    n = rnd.randint(k + 1, 9)
    blocks = tuple(Block(f"b{i}", flops=rnd.uniform(1e5, 1e9),
                         weight_bytes=rnd.randint(100, 10**6),
                         out_bytes=rnd.randint(100, 10**6))
                   for i in range(n))
    g = BlockGraph("g", blocks, input_bytes=1000, output_bytes=100)
    devs = tuple(DeviceProfile(f"d{i}", flops_per_s=1e9 * (i + 1),
                               mem_bytes=10**12, idle_w=1.0 + i,
                               active_w=5.0 + 7 * i) for i in range(k))
    links = tuple(Link(f"l{i}", rtt_s=1e-3, bw_bytes_per_s=1e8,
                       energy_per_byte_j=rnd.choice([1e-8, 1e-6]))
                  for i in range(k - 1))
    return g, devs, links


def _key3(p):
    return (round(p.latency_s, 10), round(p.throughput, 6),
            round(p.energy_j, 9))


@pytest.mark.parametrize("k", [2, 3, 4])
def test_dp_3obj_matches_brute_force(k):
    """dp_front_kway with 3 objectives returns the exact (latency,
    throughput, energy) Pareto front — cross-validated against
    sweep_kway + d=3 pareto_front on brute-force-checkable graphs."""
    rnd = random.Random(100 + k)
    for _ in range(8):
        g, devs, links = _rand_case(rnd, k)
        ex = pareto_front(sweep_kway(g, devs, links, batch=4), OBJ3)
        dp = dp_front_kway(g, devs, links, batch=4, objectives=OBJ3)
        assert sorted(map(_key3, ex)) == sorted(map(_key3, dp))


def test_dp_legacy_2obj_unchanged():
    rnd = random.Random(7)
    for _ in range(8):
        g, devs, links = _rand_case(rnd, 3)
        ex = pareto_front(sweep_kway(g, devs, links, batch=4))
        dp = dp_front_kway(g, devs, links, batch=4)
        key = lambda p: (round(p.latency_s, 10), round(p.throughput, 6))
        assert sorted(map(key, ex)) == sorted(map(key, dp))


def test_dp_single_objective_and_unknown_rejected():
    rnd = random.Random(11)
    g, devs, links = _rand_case(rnd, 3)
    lat_only = dp_front_kway(g, devs, links, batch=4,
                             objectives=("latency",))
    assert len(lat_only) == 1
    all_pts = sweep_kway(g, devs, links, batch=4)
    assert lat_only[0].latency_s == pytest.approx(
        min(p.latency_s for p in all_pts))
    with pytest.raises(ValueError, match="unknown objective"):
        dp_front_kway(g, devs, links, objectives=("energy", "net_s"))
    # a registered-looking custom objective the DP has no monotone label for
    with pytest.raises(ValueError, match="cannot track"):
        dp_front_kway(g, devs, links,
                      objectives=(Objective("net", "min", "net_s"),))


def test_solve_passes_objectives_to_dp():
    # force the DP path with max_enum=0 and check the 3-D front arrives
    rnd = random.Random(13)
    g, devs, links = _rand_case(rnd, 3)
    scen = Scenario("t", devs, links)
    dp = solve(g, scen, batch=4, max_enum=0, objectives=OBJ3)
    ex = pareto_front(sweep_kway(g, devs, links, batch=4), OBJ3)
    assert sorted(map(_key3, dp)) == sorted(map(_key3, ex))


# --------------------------------------------------------------------------- #
# Energy-aware adaptive layer
# --------------------------------------------------------------------------- #
def test_best_energy_and_energy_policy():
    from repro.models.cnn import zoo
    g = zoo.get("mobilenetv2").block_graph()
    scen = scenarios.get("pi_only3")
    pts = solve(g, scen, batch=8)
    be = best_energy(pts)
    assert be.energy_j == pytest.approx(min(p.energy_j for p in pts))
    sp = AdaptiveSplitter(g, scen, batch=8, policy="energy")
    assert sp.solve().partition == be.partition


def test_splitter_requests_energy_axis_when_energy_drives_pick(monkeypatch):
    """On the DP path a 2-objective front prunes energy-optimal splits
    before the policy sees them — the splitter must ask for the energy
    axis whenever policy or budget involves energy."""
    import repro.core.autosplit as A
    from repro.models.cnn import zoo
    g = zoo.get("mobilenetv2").block_graph()
    scen = scenarios.get("pi_only3")
    seen = []
    real_solve = A.solve
    monkeypatch.setattr(
        A, "solve",
        lambda *a, **kw: seen.append(kw.get("objectives")) or
        real_solve(*a, **kw))
    AdaptiveSplitter(g, scen, batch=8, policy="energy").solve()
    AdaptiveSplitter(g, scen, batch=8, policy="throughput",
                     energy_budget_j=10.0).solve()
    AdaptiveSplitter(g, scen, batch=8, policy="throughput").solve()
    assert seen == [("latency", "throughput", "energy"),
                    ("latency", "throughput", "energy"), None]


def test_energy_budget_constrains_pick():
    from repro.models.cnn import zoo
    g = zoo.get("mobilenetv2").block_graph()
    scen = scenarios.get("pi_only3")
    pts = solve(g, scen, batch=8)
    unconstrained = AdaptiveSplitter(g, scen, batch=8,
                                     policy="throughput").solve()
    # budget below the throughput pick's joules forces a cheaper split
    budget = unconstrained.energy_j - 1e-3
    sp = AdaptiveSplitter(g, scen, batch=8, policy="throughput",
                          energy_budget_j=budget)
    pick = sp.solve()
    assert pick.energy_j <= budget
    assert pick.throughput <= unconstrained.throughput
    # impossible budget: degrade to the least-energy split, not crash
    sp0 = AdaptiveSplitter(g, scen, batch=8, policy="throughput",
                           energy_budget_j=0.0)
    assert sp0.solve().partition == best_energy(pts).partition
