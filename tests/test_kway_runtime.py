"""k-stage scenario-driven runtime, time-varying links, closed loop.

The tentpole's acceptance surface: a >=3-stage scenario from the
registry runs end-to-end with per-hop links, predicted latency ordering
(``dp_front_kway``) survives contact with the measured pipeline, and the
adaptive loop migrates the cut vector while a ``LinkTrace`` degrades the
first hop mid-run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Block, BlockGraph, LinkTrace, Scenario,
                        dp_front_kway, evaluate_pipeline, link_at,
                        pareto_front, ramp_trace, scenarios, solve,
                        step_trace, sweep_2way, sweep_kway)
from repro.core.autosplit import AdaptiveSplitter, LinkEstimator
from repro.core.devices import DURESS, LAN_PI_PI, DeviceProfile, Link
from repro.core.profiler import profile_wallclock
from repro.models.cnn import zoo
from repro.runtime.adaptive import AdaptiveRuntime
from repro.runtime.edge import EdgePipeline


@pytest.fixture(scope="module")
def mobilenet():
    m = zoo.get("mobilenetv2")
    return m, m.init(jax.random.PRNGKey(0))


def _x(batch=2, hw=32):
    return jax.random.normal(jax.random.PRNGKey(1), (batch, hw, hw, 3))


# --------------------------------------------------------------------------- #
# LinkTrace
# --------------------------------------------------------------------------- #
def test_linktrace_linear_interpolation():
    tr = LinkTrace("t", schedule=((0.0, 0.0, 1e6), (10.0, 0.1, 1e5)))
    assert link_at(tr, -1.0).rtt_s == 0.0
    assert link_at(tr, 5.0).rtt_s == pytest.approx(0.05)
    assert link_at(tr, 5.0).bw_bytes_per_s == pytest.approx(5.5e5)
    assert link_at(tr, 99.0).bw_bytes_per_s == pytest.approx(1e5)
    # drop-in Link behaviour: transfer_time defaults to the t=0 state
    assert tr.transfer_time(1e6) == pytest.approx(1e6 / 1e6)


def test_linktrace_hold_interpolation():
    tr = LinkTrace("t", schedule=((0.0, 0.0, 1e6), (10.0, 0.1, 1e5)),
                   interp="hold")
    assert tr.at(9.99).rtt_s == 0.0
    assert tr.at(10.0).rtt_s == pytest.approx(0.1)


def test_linktrace_validation():
    with pytest.raises(ValueError):
        LinkTrace("t", schedule=())
    with pytest.raises(ValueError):
        LinkTrace("t", schedule=((1.0, 0, 1e6), (0.0, 0, 1e6)))


def test_ramp_and_step_traces():
    r = ramp_trace("r", LAN_PI_PI, DURESS, t_start=1.0, t_end=3.0)
    assert r.at(0.0).rtt_s == LAN_PI_PI.rtt_s
    assert r.at(2.0).rtt_s == pytest.approx(
        (LAN_PI_PI.rtt_s + DURESS.rtt_s) / 2)
    assert r.at(10.0).bw_bytes_per_s == DURESS.bw_bytes_per_s
    s = step_trace("s", LAN_PI_PI, DURESS, t_step=1.0)
    assert s.at(0.999).rtt_s == LAN_PI_PI.rtt_s
    assert s.at(1.001).rtt_s == DURESS.rtt_s


def test_linktrace_jitter_seeded():
    tr = LinkTrace("t", schedule=((0.0, 0.01, 1e6),), jitter=0.2)
    rng1, rng2 = np.random.default_rng(7), np.random.default_rng(7)
    a = [tr.transfer_time(1e5, 0.0, rng=rng1) for _ in range(5)]
    b = [tr.transfer_time(1e5, 0.0, rng=rng2) for _ in range(5)]
    assert a == b                                  # deterministic per seed
    assert len(set(a)) > 1                         # but actually jittery
    assert all(t > 0 for t in a)                   # lognormal stays positive


def test_scenario_at_resolves_traces():
    scen = scenarios.get("pi_pi_gpu_wan_ramp")
    assert scen.time_varying
    snap = scen.at(1e9)
    assert not snap.time_varying
    assert snap.links[0].rtt_s == pytest.approx(DURESS.rtt_s)
    healthy = scen.at(0.0)
    assert healthy.links[0].rtt_s == pytest.approx(LAN_PI_PI.rtt_s)


# --------------------------------------------------------------------------- #
# partitioner.solve dispatch
# --------------------------------------------------------------------------- #
def _toy_graph(n=6):
    blocks = tuple(Block(f"b{i}", flops=1e7 * (i + 1), weight_bytes=1000,
                         out_bytes=10_000 * (n - i)) for i in range(n))
    return BlockGraph("toy", blocks, input_bytes=50_000, output_bytes=100)


def _generic_scenario(k):
    devs = tuple(DeviceProfile(f"d{i}", flops_per_s=1e9, mem_bytes=10**12)
                 for i in range(k))
    links = tuple(Link(f"l{i}", rtt_s=1e-3, bw_bytes_per_s=1e8)
                  for i in range(k - 1))
    return Scenario(f"generic{k}", devs, links)


def test_solve_dispatches_by_depth():
    g = _toy_graph(6)
    s2 = _generic_scenario(2)
    pts2 = solve(g, s2, batch=4)
    ref2 = sweep_2way(g, s2.devices, s2.links[0], batch=4)
    assert [p.partition for p in pts2] == [p.partition for p in ref2]

    s3 = _generic_scenario(3)
    pts3 = solve(g, s3, batch=4)
    assert len(pts3) == 10                    # C(5, 2) enumerated
    ref3 = sweep_kway(g, s3.devices, s3.links, batch=4)
    assert {p.partition for p in pts3} == {p.partition for p in ref3}


def test_solve_falls_back_to_dp_front():
    g = _toy_graph(6)
    s3 = _generic_scenario(3)
    full = pareto_front(solve(g, s3, batch=4))
    dp = solve(g, s3, batch=4, max_enum=3)    # force the DP engine
    assert {p.partition for p in dp} == {p.partition for p in full}


def test_solve_single_device():
    g = _toy_graph(4)
    pts = solve(g, _generic_scenario(1), batch=2)
    assert len(pts) == 1 and pts[0].partition == ()


def test_solve_rejects_more_stages_than_blocks():
    with pytest.raises(ValueError, match="blocks"):
        solve(_toy_graph(3), _generic_scenario(5), batch=2)


# --------------------------------------------------------------------------- #
# k-stage executable pipeline
# --------------------------------------------------------------------------- #
def test_three_stage_registry_scenario_end_to_end(mobilenet):
    """A >=3-stage scenario from the registry, per-hop links, output
    bit-equivalent to the unpartitioned model."""
    m, params = mobilenet
    scen = scenarios.get("pi_pi_gpu")
    assert scen.n_stages == 3
    x = _x()
    ref = m.apply(params, x)
    pipe = EdgePipeline(m, params, (5, 12), scen)
    assert len(pipe.nets) == 2 and len(pipe.workers) == 3
    y, latency, hop_net = pipe.run_one(x)
    assert jnp.allclose(ref, y, atol=1e-5)
    assert latency > 0 and len(hop_net) == 2
    res = pipe.measure(lambda: x, n_batches=4)
    assert res.partition == (5, 12)
    assert len(res.stage_exe_s) == 3 and len(res.hop_net_s) == 2
    assert res.throughput > 0
    # modeled-from-measured energy: scenario devices carry power specs
    assert res.energy_j > 0 and len(res.stage_energy_j) == 3
    assert res.energy_j == pytest.approx(sum(res.stage_energy_j))


def test_four_stage_and_mixed_backends(mobilenet):
    m, params = mobilenet
    scen = scenarios.get("pi_chain4")
    x = _x()
    ref = m.apply(params, x)
    pipe = EdgePipeline(m, params, (4, 9, 14), scen,
                        backend=("lightweight", "rpc", "rpc", "lightweight"))
    y, _, hop_net = pipe.run_one(x)
    assert jnp.allclose(ref, y, atol=1e-5)
    assert len(hop_net) == 3
    assert pipe.backend == "lightweight+rpc"


def test_legacy_two_stage_api(mobilenet):
    m, params = mobilenet
    x = _x()
    ref = m.apply(params, x)
    pipe = EdgePipeline(m, params, p=5, link=Link("l", 1e-5, 1e12))
    y, _, _ = pipe.run_one(x)
    assert jnp.allclose(ref, y, atol=1e-5)
    assert pipe.p == 5 and pipe.cuts == (5,)


def test_cut_validation(mobilenet):
    m, params = mobilenet
    scen = scenarios.get("pi_pi_gpu")
    with pytest.raises(ValueError):
        EdgePipeline(m, params, (5,), scen)          # 1 cut, 3 stages
    with pytest.raises(ValueError):
        EdgePipeline(m, params, (12, 5), scen)       # not increasing
    with pytest.raises(ValueError):
        EdgePipeline(m, params, (0, 5), scen)        # empty first stage


def test_migrate_rebuilds_workers(mobilenet):
    m, params = mobilenet
    scen = scenarios.get("pi_pi_gpu")
    x = _x()
    ref = m.apply(params, x)
    pipe = EdgePipeline(m, params, (5, 12), scen)
    pipe.run_one(x)
    pipe.migrate((3, 17), cost_s=0.0)
    assert pipe.cuts == (3, 17)
    assert [(w.lo, w.hi) for w in pipe.workers] == [(0, 3), (3, 17), (17, 21)]
    y, _, _ = pipe.run_one(x)
    assert jnp.allclose(ref, y, atol=1e-5)
    assert len(pipe.migrations) == 1


def test_stream_surfaces_stage_failure(mobilenet):
    """A stage dying mid-stream must raise, not hang the pipeline."""
    m, params = mobilenet
    pipe = EdgePipeline(m, params, (5, 12), scenarios.get("pi_pi_gpu"))
    x = _x()
    pipe.warmup(x)

    def boom(_):
        raise RuntimeError("stage 2 died")

    pipe.workers[1].run = boom
    with pytest.raises(RuntimeError, match="stage 2 died"):
        pipe.stream(x, n_batches=6)


def test_adaptive_run_returns_only_new_records(mobilenet):
    m, params = mobilenet
    x = _x()
    rt = AdaptiveRuntime(m, params, scenarios.get("pi_pi_gpu"),
                         graph=m.block_graph(input_hw=32),
                         batch=x.shape[0], check_every=2)
    first = rt.run(lambda: x, n_batches=3)
    second = rt.run(lambda: x, n_batches=3)
    assert len(first) == 3 and len(second) == 3
    assert len(rt.records) == 6
    assert [r.batch_idx for r in rt.records] == list(range(6))


def test_per_hop_observations_recorded(mobilenet):
    m, params = mobilenet
    scen = scenarios.get("pi_pi_gpu")
    pipe = EdgePipeline(m, params, (5, 12), scen)
    pipe.run_one(_x())
    for net in pipe.nets:
        obs = net.drain_observations()
        assert len(obs) == 1
        nbytes, dt, t, raw = obs[0]
        assert nbytes > 0 and dt > 0 and t >= 0
        assert raw == nbytes                         # uncoded: wire == raw
        assert net.drain_observations() == []        # drained
        # radio accounting survives the drain (lifetime counters)
        assert net.total_bytes == nbytes
        assert net.total_energy_j == pytest.approx(
            nbytes * net.link.energy_per_byte_j)


def test_bare_link_pipeline_reports_zero_energy(mobilenet):
    """No Scenario = no device power profile: energy must be 0, not junk."""
    m, params = mobilenet
    x = _x()
    pipe = EdgePipeline(m, params, p=5, link=Link("l", 1e-5, 1e12))
    res = pipe.measure(lambda: x, n_batches=2)
    assert res.energy_j == 0.0 and res.stage_energy_j == ()


def test_adaptive_records_carry_energy(mobilenet):
    m, params = mobilenet
    x = _x()
    rt = AdaptiveRuntime(m, params, scenarios.get("pi_pi_gpu"),
                         graph=m.block_graph(input_hw=32),
                         batch=x.shape[0], check_every=2,
                         energy_budget_j=1e6)
    recs = rt.run(lambda: x, n_batches=3)
    for r in recs:
        assert r.energy_j > 0              # measured-exe modeled joules
        assert r.predicted_energy_j > 0    # the splitter's model view
    assert rt.splitter.energy_budget_j == 1e6


# --------------------------------------------------------------------------- #
# predicted vs measured (3-stage)
# --------------------------------------------------------------------------- #
def test_measured_latency_ordering_matches_dp_prediction(mobilenet):
    """Calibrate the analytic model to this host (block-wise wall-clock
    profile, the paper's Sec. IV-D methodology), slow the links down so
    the wire matters, and check the measured pipeline sorts dp_front_kway
    front points the way the model predicts."""
    m, params = mobilenet
    x = _x()
    graph = m.block_graph(input_hw=32)
    base = scenarios.get("pi_pi_gpu")
    scen = base.with_link(0, Link("slow0", rtt_s=80e-3, bw_bytes_per_s=4e6))
    scen = scen.with_link(1, Link("slow1", rtt_s=20e-3, bw_bytes_per_s=2e7))

    names, fns = m.block_fns(params)
    costs = profile_wallclock(scen.devices[0].name, fns, names,
                              make_input=lambda _: x, repeats=2)
    for dev in scen.devices[1:]:
        for blk in names:
            costs.set(dev.name, blk, costs.get(scen.devices[0].name, blk))

    front = dp_front_kway(graph, scen.devices, scen.links, batch=x.shape[0],
                          costs=costs, include_io=False)
    assert len(front) >= 2
    # min-latency, a middle point, max-latency of the predicted front
    picks = sorted({0, len(front) // 2, len(front) - 1})
    pts = [front[i] for i in picks]

    measured = []
    for pt in pts:
        pipe = EdgePipeline(m, params, pt.partition, scen)
        pipe.warmup(x)
        measured.append(float(np.median([pipe.run_one(x)[1]
                                         for _ in range(3)])))
    for i in range(len(pts)):
        for j in range(i + 1, len(pts)):
            pi, pj = pts[i].latency_s, pts[j].latency_s
            if abs(pi - pj) / max(pi, pj) < 0.25:
                continue                       # too close to call reliably
            assert (pi < pj) == (measured[i] < measured[j]), (
                f"predicted {pi:.3f} vs {pj:.3f}, "
                f"measured {measured[i]:.3f} vs {measured[j]:.3f}")


# --------------------------------------------------------------------------- #
# closed adaptive loop
# --------------------------------------------------------------------------- #
def test_adaptive_splitter_kway_step():
    graph = zoo.get("mobilenetv2").block_graph()
    scen = scenarios.get("pi_pi_gpu")
    sp = AdaptiveSplitter(graph, scen, batch=8, policy="throughput")
    ests = [LinkEstimator.from_link(l) for l in scen.links]
    m0, mig0 = sp.step(ests)
    assert mig0 and len(m0.partition) == 2
    for _ in range(25):                       # degrade hop 0 only
        ests[0].observe(1e6, DURESS.transfer_time(1e6))
        ests[0].observe(0, DURESS.rtt_s, is_rtt_probe=True)
        sp.step(ests)
    assert sp.current.partition != m0.partition
    assert graph.cut_bytes(sp.current.partition[0]) <= \
        graph.cut_bytes(m0.partition[0])


def test_adaptive_splitter_solve_accepts_trace():
    """A LinkTrace is a drop-in link for the splitter (t=0 state)."""
    graph = zoo.get("mobilenetv2").block_graph()
    sp = AdaptiveSplitter(graph, scenarios.get("pi_to_pi"), batch=8)
    tr = ramp_trace("r", LAN_PI_PI, DURESS, t_start=1.0, t_end=3.0)
    m = sp.solve(tr)
    assert m.partition == sp.solve(LAN_PI_PI).partition


def test_adaptive_splitter_handles_stale_partition():
    """Re-pricing a cut vector the sweep no longer contains must not
    raise (the old code's bare StopIteration crash path)."""
    graph = zoo.get("mobilenetv2").block_graph()
    scen = scenarios.get("pi_to_pi")
    sp = AdaptiveSplitter(graph, scen, batch=8, policy="throughput")
    est = LinkEstimator.from_link(scen.links[0])
    m0, _ = sp.step(est)
    # simulate a graph/depth change leaving current cuts invalid
    sp.current = dataclasses.replace(sp.current, partition=(999,))
    est2 = LinkEstimator(rtt_s=DURESS.rtt_s,
                         bw_bytes_per_s=DURESS.bw_bytes_per_s)
    m1, migrated = sp.step(est2)
    assert migrated                           # stale cuts force migration
    assert m1.partition != (999,)


def test_adaptive_loop_migrates_when_trace_degrades(mobilenet):
    """The acceptance loop: a LinkTrace degrades hop 0 mid-run, the
    closed loop (observed transfers -> estimators -> solve -> migrate)
    moves the pipeline to a cheaper-wire cut vector, live."""
    m, params = mobilenet
    x = _x()
    base = scenarios.get("pi_pi_gpu")
    # the ramp starts almost immediately: once it bites, the emulated
    # RTT sleeps pace the loop into the degraded regime, so the test
    # does not depend on how fast this host runs the compute
    scen = scenarios.wan_ramp(base, hop=0, t_start=0.05, t_end=0.4,
                              jitter=0.05)
    rt = AdaptiveRuntime(m, params, scen, batch=x.shape[0],
                         policy="throughput", check_every=2,
                         migration_cost_s=0.02, alpha=0.6)
    recs = rt.run(lambda: x, n_batches=12)
    assert len(recs) == 12
    assert len(rt.pipe.migrations) >= 1
    start, final = recs[0].cuts, rt.pipe.cuts
    assert final != start
    graph = rt.graph
    # no graph was passed: the loop must model the served resolution
    assert graph.input_bytes == x.size // x.shape[0] * 4   # bytes/sample
    # the split moved toward less wire on the degraded hop
    assert graph.cut_bytes(final[0]) <= graph.cut_bytes(start[0])
    # migration cost was charged and recorded
    assert any(r.migration_cost_s > 0 for r in recs)
    # records track the cuts that were active batch by batch; the
    # migration log is the authoritative trail (a migration triggered at
    # the very last check never serves a batch, so don't assert on
    # cut_history length)
    assert rt.cut_history[0] == start
    assert rt.pipe.migrations[0][1] == start
    assert rt.pipe.migrations[-1][2] == final


def test_evaluate_pipeline_three_stage_consistency():
    """Analytic sanity on the 3-stage chain: k-way evaluation equals the
    sum of its per-stage parts."""
    g = _toy_graph(8)
    scen = _generic_scenario(3)
    pm = evaluate_pipeline(g, (2, 5), scen.devices, scen.links, batch=2,
                           include_io=False)
    assert pm.latency_s == pytest.approx(
        sum(s.compute_s + s.send_s for s in pm.stages))
    assert pm.throughput == pytest.approx(2 / pm.bottleneck_s)
    assert pm.energy_j == pytest.approx(sum(s.energy_j for s in pm.stages))
