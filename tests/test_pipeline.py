"""Multi-pod pipeline: numerical equivalence to the plain model, uneven
ParetoPipe cuts, repack/unpack roundtrip, pipelined serving."""
import os

import pytest

pytestmark = [
    pytest.mark.skipif("XLA_FLAGS" in os.environ,
                       reason="needs default device config"),
    pytest.mark.slow,                  # multi-pod GPipe drills, ~40s
]

import jax  # noqa: E402

if jax.device_count() == 1:
    # a tiny in-process multi-device mesh via the CPU collectives path is
    # unavailable once jax is initialized with 1 device; these tests run
    # in a subprocess with forced host devices instead.
    import subprocess
    import sys

    def _run_sub(code: str):
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=8",
                   PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
        cp = subprocess.run([sys.executable, "-c", code], env=env,
                            capture_output=True, text=True, timeout=900)
        assert cp.returncode == 0, cp.stdout + "\n" + cp.stderr

    # partial-manual shard_map (manual over 'pod', auto elsewhere) only
    # lowers on jax >= 0.6; jax 0.4's SPMD partitioner rejects the
    # axis_index → PartitionId op inside an auto-axes shard_map
    _needs_new_shard_map = pytest.mark.xfail(
        not hasattr(jax, "shard_map"),
        reason="partial-manual shard_map (axis_names=) requires jax>=0.6; "
               "this jax lowers axis_index to an unpartitionable PartitionId",
        strict=False)

    @_needs_new_shard_map
    def test_pipeline_train_matches_plain():
        _run_sub("""
import jax, jax.numpy as jnp
import repro.configs as configs
from repro.models import lm
from repro.models.common import InitBuilder, cross_entropy
from repro.data.pipeline import SyntheticLM, DataConfig
from repro.runtime.pipeline import PipelineConfig, repack_params, make_pipeline_train_step
from repro.optim import OptConfig, init_opt_state
from repro.sharding.api import use_mesh_context

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
for name in ["qwen3-1.7b", "zamba2-7b", "qwen3-moe-30b-a3b", "whisper-small", "falcon-mamba-7b"]:
    cfg = configs.reduced(name)
    params = lm.build_params(cfg, InitBuilder(jax.random.PRNGKey(0), jnp.float32))
    data = SyntheticLM(cfg, DataConfig(batch=4, seq=32))
    batch = next(data)
    logits, _ = lm.forward_train(cfg, params, {k: v for k, v in batch.items() if k != "targets"})
    ref_ce = float(cross_entropy(logits, batch["targets"]))
    pcfg = PipelineConfig.even(cfg.n_layers, 2, 2)
    key = "dec_layers" if cfg.family == "encdec" else "layers"
    pparams = dict(params); pparams[key] = repack_params(params[key], pcfg, cfg.n_layers)
    with use_mesh_context(mesh):
        state = {"params": pparams, "opt": init_opt_state(pparams), "step": jnp.int32(0)}
        step = jax.jit(make_pipeline_train_step(cfg, pcfg, OptConfig(lr=1e-3), mesh))
        state, m = step(state, batch)
    diff = abs(float(m["ce"]) - ref_ce)
    assert diff < 5e-4, (name, diff)
print("OK")
""")

    @_needs_new_shard_map
    def test_pipeline_uneven_cuts_and_serving():
        _run_sub("""
import jax, jax.numpy as jnp
import repro.configs as configs
from repro.models import lm
from repro.models.common import InitBuilder
from repro.data.pipeline import SyntheticLM, DataConfig
from repro.runtime.pipeline import (PipelineConfig, repack_params,
                                    make_pipeline_prefill_step, make_pipeline_decode_step)
from repro.sharding.api import use_mesh_context

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = configs.reduced("qwen3-1.7b").replace(n_layers=5)   # odd → uneven
params = lm.build_params(cfg, InitBuilder(jax.random.PRNGKey(0), jnp.float32))
data = SyntheticLM(cfg, DataConfig(batch=4, seq=16))
inputs = {k: v for k, v in next(data).items() if k != "targets"}
_, ref_cache = lm.forward_prefill(cfg, params, inputs, cache_len=18)
nxt = inputs["tokens"][:, :1]
ref_lg, _ = lm.forward_decode(cfg, params, nxt, ref_cache)
for cuts in [(2,), (1,), (4,)]:               # ParetoPipe uneven splits
    pcfg = PipelineConfig(2, 2, cuts)
    pparams = dict(params)
    pparams["layers"] = repack_params(params["layers"], pcfg, cfg.n_layers)
    with use_mesh_context(mesh):
        pre = jax.jit(make_pipeline_prefill_step(cfg, pcfg, mesh, cache_len=18))
        dec = jax.jit(make_pipeline_decode_step(cfg, pcfg, mesh))
        tok, cache = pre(pparams, inputs)
        tok2, cache = dec(pparams, nxt, cache)
    assert bool(jnp.array_equal(tok2[:, 0], jnp.argmax(ref_lg[:, 0], -1))), cuts
print("OK")
""")


def test_repack_unpack_roundtrip():
    import jax.numpy as jnp
    import numpy as np
    from repro.runtime.pipeline import (PipelineConfig, repack_params,
                                        unpack_params)
    tree = {"w": jnp.arange(7 * 3 * 2, dtype=jnp.float32).reshape(7, 3, 2),
            "b": jnp.arange(7, dtype=jnp.float32)}
    for cuts in [(3,), (2,), (5,), (1, 4)]:
        pcfg = PipelineConfig(len(cuts) + 1, 2, cuts)
        packed = repack_params(tree, pcfg, 7)
        back = unpack_params(packed, pcfg, 7)
        for k in tree:
            assert np.array_equal(np.asarray(tree[k]), np.asarray(back[k])), \
                (k, cuts)


def test_stage_layout():
    from repro.runtime.pipeline import PipelineConfig
    pcfg = PipelineConfig.even(81, 2, 8)
    starts, counts, l_max = pcfg.layout(81)
    assert counts.sum() == 81 and l_max == 41
    pcfg = PipelineConfig(2, 4, (10,))        # uneven ParetoPipe cut
    starts, counts, l_max = pcfg.layout(81)
    assert list(counts) == [10, 71] and l_max == 71
