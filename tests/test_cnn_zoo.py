"""CNN zoo: exact param counts (Table I), block counts, partition identity."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.cnn import zoo

# canonical torchvision counts @1000 classes
TORCHVISION_COUNTS = {
    "mobilenetv2": 3_504_872,
    "resnet18": 11_689_512,
    "resnet50": 25_557_032,
    "alexnet": 61_100_840,
    "vgg16": 138_357_544,
}

# paper Table I block counts
PAPER_BLOCKS = {"mobilenetv2": 21, "resnet18": 14, "inceptionv3": 22,
                "resnet50": 22, "alexnet": 21, "vgg16": 39}


@pytest.mark.parametrize("name,count", sorted(TORCHVISION_COUNTS.items()))
def test_param_counts_exact(name, count):
    assert zoo.get(name, num_classes=1000).param_count() == count


def test_paper_mobilenet_count_10_classes():
    # paper Table I reports the CIFAR-10 head for MobileNetV2
    assert zoo.get("mobilenetv2", num_classes=10).param_count() == 2_236_682


@pytest.mark.parametrize("name", sorted(PAPER_BLOCKS))
def test_block_counts_match_table1(name):
    assert len(zoo.get(name).blocks) == PAPER_BLOCKS[name]


@pytest.mark.parametrize("name,hw", [("mobilenetv2", 64), ("resnet18", 64),
                                     ("alexnet", 224)])
def test_every_partition_bit_identical(name, hw):
    """The property Table I's accuracy column stands in for: splitting
    never changes the math (checked at every block boundary)."""
    m = zoo.get(name)
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, hw, hw, 3))
    ref = m.apply(params, x)
    n = len(m.blocks)
    for p in range(1, n, max(n // 6, 1)):
        a = m.apply_range(params, x, 0, p)
        y = m.apply_range(params, a, p, n)
        assert jnp.array_equal(ref, y), f"split at {p} changed outputs"
    assert not bool(jnp.any(jnp.isnan(ref)))


def test_block_graph_flops_match_published_macs():
    """Sanity: FLOPs ≈ 2× published MACs at 224²/299²."""
    expect = {"mobilenetv2": 0.60, "resnet18": 3.6, "resnet50": 8.2,
              "alexnet": 1.4, "vgg16": 31.0, "inceptionv3": 11.4}
    for name, gf in expect.items():
        got = zoo.get(name).block_graph().total_flops / 1e9
        assert abs(got - gf) / gf < 0.15, (name, got)


def test_weight_sizes_match_table1_mb():
    """Table I 'Size (MB)' column (fp32 weights)."""
    expect = {"mobilenetv2": 8.8, "resnet18": 43, "resnet50": 91,
              "alexnet": 234, "vgg16": 528}
    for name, mb in expect.items():
        got = zoo.get(name).block_graph().total_weight_bytes / 1e6
        assert abs(got - mb) / mb < 0.12, (name, got)
