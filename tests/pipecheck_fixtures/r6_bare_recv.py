"""R6 fixture: unguarded blocking channel ops a dead peer hangs forever.

``drain_one`` calls a bare ``recv()`` with neither a timeout argument
nor a ``poll(...)`` liveness loop on the same object, and
``push_frame`` drives a raw socket ``sendmsg`` without bounding it via
``settimeout``/``setblocking``.  ``drain_guarded`` shows the compliant
shape (poll-then-recv) and must NOT fire.
"""


def drain_one(ctrl):
    msg = ctrl.recv()                         # R6: bare blocking recv
    return msg


def drain_guarded(ctrl, deadline):
    while True:
        if ctrl.poll(0.05):
            return ctrl.recv()                # guarded: poll on same object
        if deadline():
            raise TimeoutError


def push_frame(sock, bufs):
    sent = 0
    while bufs:
        sent += sock.sendmsg(bufs)            # R6: unbounded raw send
    return sent
