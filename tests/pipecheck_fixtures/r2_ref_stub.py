"""R2 fixture support: a kernels/ref.py stand-in carrying oracles for
the four registered codecs only (nothing for wavelet/gzip)."""


def int8_pack_ref(x):
    return x


def int8_unpack_ref(b, shape, dtype):
    return b


def fp8_pack_ref(x):
    return x


def fp8_unpack_ref(b, shape, dtype):
    return b


def topk_select_ref(x):
    return x
