"""R2 fixture: a codec registry that breaks the append-only contract
three ways — a wire-code collision, a lossy codec inheriting the
identity byte model, and a packed op with no kernels/ref.py oracle.
Checked under the path ``src/repro/core/codecs.py``."""


class Codec:
    name: str = "none"
    code: int = 0

    def wire_bytes(self, n_elems, itemsize=4):
        return n_elems * itemsize

    def encode(self, host):
        return host.tobytes()

    def decode(self, buf, shape, dtype):
        return buf


class Int8Codec(Codec):
    name = "int8"
    code = 1

    def wire_bytes(self, n_elems, itemsize=4):
        return n_elems + 4

    def encode(self, host):
        return ops.int8_pack(host)

    def decode(self, buf, shape, dtype):
        return ops.int8_unpack(buf, shape, dtype)


class Fp8Codec(Codec):
    name = "fp8"
    code = 2

    def wire_bytes(self, n_elems, itemsize=4):
        return n_elems

    def encode(self, host):
        return ops.fp8_pack(host)

    def decode(self, buf, shape, dtype):
        return ops.fp8_unpack(buf, shape, dtype)


class TopKCodec(Codec):
    name = "topk"
    code = 3

    def wire_bytes(self, n_elems, itemsize=4):
        return n_elems // 10

    def encode(self, host):
        return ops.topk_select(host)

    def decode(self, buf, shape, dtype):
        return buf


class WaveletCodec(Codec):
    """Collides with topk's wire code."""

    name = "wavelet"
    code = 3

    def wire_bytes(self, n_elems, itemsize=4):
        return n_elems // 2

    def encode(self, host):
        return host

    def decode(self, buf, shape, dtype):
        return buf


class GzipCodec(Codec):
    """Unregistered wire code, inherits the identity encode/decode, and
    calls a packed op with no kernels/ref.py oracle."""

    name = "gzip"
    code = 9

    def wire_bytes(self, n_elems, itemsize=4):
        return ops.gzip_pack(n_elems)
