"""R5 fixture: the _FHDR frame header grew a field (8q -> 9q) without a
WIRE_LAYOUT_VERSION bump.  Checked under the path
``src/repro/runtime/transport.py``."""
import struct

WIRE_LAYOUT_VERSION = 1

_FHDR = struct.Struct("!BBbBB I d Q 9q")      # drifted from the manifest
_RREC = struct.Struct("<BBbBB i I I d Q 8q")
