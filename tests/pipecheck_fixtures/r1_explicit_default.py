"""R1 fixture (clean): the same dispatch shape made compliant three
ways — an else that raises, full 9-kind coverage, and a trailing
default statement."""
BATCH, WARMUP, PROBE, RECONFIG, STATS, STOP, ERROR, CLOCK, CANCEL = range(9)

_TOKENS = (PROBE, RECONFIG, STATS, WARMUP, CLOCK, CANCEL)


def pump_with_else(chan):
    while True:
        kind, obj = chan.recv(timeout=0.25)
        if kind == STOP:
            break
        elif kind == BATCH:
            chan.send(obj, kind=BATCH)
        else:
            raise RuntimeError(f"unexpected kind {kind}")


def pump_covering_all(chan):
    while True:
        kind, obj = chan.recv(timeout=0.25)
        if kind == STOP:
            break
        elif kind in (BATCH, WARMUP):
            chan.send(obj, kind=kind)
        elif kind in _TOKENS:
            chan.send(obj, kind=kind)
        elif kind == ERROR:
            raise RuntimeError(str(obj))


def pump_with_trailing_default(chan):
    while True:
        kind, obj = chan.recv(timeout=0.25)
        if kind == STOP:
            break
        if kind == BATCH:
            chan.send(obj, kind=BATCH)
        chan.ack(kind)                        # every other kind lands here
