"""R3 fixture: a concrete Channel subclass missing half the surface
(recv stays abstract, reap/set_codec never defined anywhere), plus a
record() call that forgets raw_bytes.  Checked under a
``src/repro/runtime/`` path."""
from abc import ABC, abstractmethod


class Channel(ABC):
    @abstractmethod
    def send(self, payload=None, kind=0):
        ...

    @abstractmethod
    def recv(self, timeout=None):
        ...

    def close(self):
        pass

    def split(self):
        return self, self


class HalfChannel(Channel):
    def send(self, payload=None, kind=0):
        nbytes = 128
        self.record(nbytes, 0.001, 0.0)       # no raw_bytes: R3
        return None
