"""R4 fixture: pickle on a runtime hot path outside the declared
escape hatches.  Checked under a ``src/repro/runtime/`` path."""
import pickle


def frame_fast(payload):
    return pickle.dumps(payload)              # hot path: R4


class _Serializer:
    """Same qualname as the real escape hatch, but in the wrong file —
    the allowlist is (path, qualname) pairs, so this still fires when
    the fixture is checked under a non-transport.py path."""

    @staticmethod
    def dumps(x):
        return pickle.dumps(x)
