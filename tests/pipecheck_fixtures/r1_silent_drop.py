"""R1 fixture: a worker loop that dispatches on token kinds but
silently drops everything it does not name (no else, no coverage of
all manifest kinds, nothing after the ladder)."""
BATCH, WARMUP, PROBE, RECONFIG, STATS, STOP, ERROR, CLOCK = range(8)


def pump(chan):
    while True:
        kind, obj = chan.recv(timeout=0.25)
        if kind == STOP:
            break
        elif kind == BATCH:
            chan.send(obj, kind=BATCH)
        elif kind == PROBE:
            chan.send(None, kind=PROBE)
