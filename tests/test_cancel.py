"""CANCEL protocol: flush fences, selective cancels, resubmit-or-skip
bookkeeping, and cancel-mid-flight over the process transports.

CANCEL (token kind 8) is the ninth wire kind: a flush cancel opens an
out-of-band skip window at every stage (workers short-circuit compute
on batches already queued) and the in-band CANCEL fence closes it; a
selective cancel still computes but its arrival is discarded by the
session.  Either way the canceled seq never reaches ``results()`` and
is logged as a :class:`CancelRecord`.
"""
import jax
import numpy as np
import pytest

from repro.core.devices import LAN_PI_GPU
from repro.runtime import CancelRecord, EdgePipeline, drain_violations


def _tiny_model():
    from repro.models.cnn.layers import (Conv2D, Flatten, Linear, Pool,
                                         ReLU, Sequential)
    from repro.models.cnn.zoo import CNNModel
    blocks = [
        ("conv0", Sequential([Conv2D(3, 8, 3, 1, 1), ReLU()])),
        ("conv1", Sequential([Conv2D(8, 8, 3, 1, 1), ReLU()])),
        ("pool", Pool("max", 2, 2)),
        ("conv2", Sequential([Conv2D(8, 16, 3, 1, 1), ReLU()])),
        ("head", Sequential([Flatten(), Linear(16 * 16 * 16, 10)])),
    ]
    return CNNModel("tinycnn", blocks, input_hw=32)


@pytest.fixture(scope="module")
def tiny():
    m = _tiny_model()
    return m, m.init(jax.random.PRNGKey(0))


def _batches(n, batch=2, hw=32):
    return [np.asarray(jax.random.normal(jax.random.PRNGKey(100 + i),
                                         (batch, hw, hw, 3)))
            for i in range(n)]


# --------------------------------------------------------------------------- #
# manifest: CANCEL is an append-only extension
# --------------------------------------------------------------------------- #
def test_cancel_kind_appended_to_manifest():
    """CANCEL rides as kind 8 — appended after CLOCK, never renumbering
    the existing kinds (old captures must replay against new code)."""
    from repro.analysis.manifest import TOKEN_KINDS
    from repro.runtime import transport as T
    assert TOKEN_KINDS[-1] == "CANCEL"
    assert TOKEN_KINDS.index("CANCEL") == T.CANCEL == 8
    assert TOKEN_KINDS[:8] == ("BATCH", "WARMUP", "PROBE", "RECONFIG",
                               "STATS", "STOP", "ERROR", "CLOCK")
    assert len(T._KIND_NAMES) == len(TOKEN_KINDS)


# --------------------------------------------------------------------------- #
# thread engine (emulated): semantics
# --------------------------------------------------------------------------- #
def test_cancel_flush_and_selective_emulated(tiny):
    m, params = tiny
    xs = _batches(8)
    refs = [np.asarray(m.apply(params, x)) for x in xs]
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU], sanitize=True)
    pipe.warmup(xs[0])
    with pipe.session(inflight=4) as s:
        for i in range(4):
            s.submit(xs[i])
        canceled = s.cancel()                 # flush the whole window
        assert canceled == [0, 1, 2, 3]
        s4, s5 = s.submit(xs[4]), s.submit(xs[5])
        sel = s.cancel([s5])                  # selective: still computes
        assert sel == [s5]
        # double-cancel and out-of-range seqs
        assert s.cancel([s5]) == []           # already canceled: silent
        with pytest.raises(ValueError, match="never submitted"):
            s.cancel([99])
        out = s.drain()
        recs = s.drain_cancels()
    # only the one surviving batch reaches results(), bit-exact
    assert len(out) == 1
    assert np.array_equal(np.asarray(out[0]), refs[4])
    # five records, every flushed arrival accounted for
    assert [r.seq for r in recs] == [0, 1, 2, 3, s5]
    assert all(isinstance(r, CancelRecord) and r.flushed for r in recs)
    assert all(r.flush for r in recs[:4]) and not recs[4].flush
    assert all(r.action == "skip" and r.resubmitted_as == -1 for r in recs)
    assert s.drain_cancels() == []            # return-and-clear
    assert drain_violations() == []
    pipe.close()


def test_cancel_resubmit_redelivers_bit_identical(tiny):
    m, params = tiny
    xs = _batches(4)
    refs = [np.asarray(m.apply(params, x)) for x in xs]
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU], sanitize=True)
    pipe.warmup(xs[0])
    with pipe.session(inflight=4) as s:
        for x in xs:
            s.submit(x)
        canceled = s.cancel(resubmit=True)
        assert canceled == [0, 1, 2, 3]
        out = s.drain()
        recs = s.drain_cancels()
    # every payload re-fed at the back of the queue, in order, bit-exact
    assert len(out) == 4
    for ref, y in zip(refs, out):
        assert np.array_equal(np.asarray(y), ref)
    assert [r.resubmitted_as for r in recs] == [4, 5, 6, 7]
    assert all(r.action == "resubmit" and r.flushed for r in recs)
    assert drain_violations() == []
    pipe.close()


def test_cancel_skips_already_emitted(tiny):
    m, params = tiny
    xs = _batches(3)
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU], sanitize=True)
    pipe.warmup(xs[0])
    with pipe.session(inflight=3) as s:
        for x in xs:
            s.submit(x)
        it = s.results()
        next(it)                              # seq 0 emitted
        assert s.cancel([0]) == []            # emitted: silently skipped
        assert s.cancel([1]) == [1]
        rest = list(it)
    assert len(rest) == 1                     # seq 2 only
    assert drain_violations() == []
    pipe.close()


def test_set_inflight_clamps_and_applies(tiny):
    m, params = tiny
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU])
    with pipe.session(inflight=4) as s:
        assert s.set_inflight(2) == 2
        assert s.inflight == 2
        assert s.set_inflight(0) == 1         # floor
        cap = pipe._engine.max_inflight()
        if cap is not None:
            assert s.set_inflight(10 ** 6) == cap
    pipe.close()


# --------------------------------------------------------------------------- #
# process engines: cancel mid-flight over real transports
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("transport", ["socket", "shmem"])
def test_cancel_mid_flight_process(tiny, transport):
    """Flush-cancel while batches are genuinely in flight inside worker
    processes: the ctrl-pipe skip window plus the in-band fence must
    flush every pending batch, and the one uncanceled batch afterwards
    must come back bit-identical — all under the live sanitizer."""
    m, params = tiny
    xs = _batches(6)
    ref5 = np.asarray(m.apply(params, xs[5]))
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU], transport=transport,
                        sanitize=True, timeout_s=120)
    with pipe:
        pipe.warmup(xs[0])
        with pipe.session(inflight=4) as s:
            for i in range(4):
                s.submit(xs[i])
            canceled = s.cancel()             # mid-flight flush
            s4, s5 = s.submit(xs[4]), s.submit(xs[5])
            sel = s.cancel([s4])
            out = s.drain()
            recs = s.drain_cancels()
        assert canceled == [0, 1, 2, 3] and sel == [s4]
        assert len(out) == 1
        assert np.array_equal(np.asarray(out[0]), ref5)
        assert all(r.flushed for r in recs)
    assert drain_violations() == []
