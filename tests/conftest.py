import os
import sys

# tests must see the real single device — the 512-device override is
# exclusively for launch/dryrun.py (assignment requirement).
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "do not run tests with the dry-run XLA_FLAGS set"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
