"""Property + unit tests for the ParetoPipe core (the paper's algorithm)."""
import math

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (Block, BlockGraph, chain, dominates, hypervolume,
                        is_on_front, knee_point, pareto_front)

points = st.lists(
    st.tuples(st.floats(0.01, 100, allow_nan=False),
              st.floats(0.01, 100, allow_nan=False)),
    min_size=1, max_size=60)

# d=3 clouds (latency ↓, throughput ↑, energy ↓); a coarse grid mixed in
# so duplicate coordinates (the staircase's hard case) actually occur
_coord = st.one_of(st.floats(0.01, 100, allow_nan=False),
                   st.integers(1, 5).map(float))
points3 = st.lists(st.tuples(_coord, _coord, _coord),
                   min_size=1, max_size=60)
OBJ3 = ("latency", "throughput", "energy")


@given(points)
@settings(max_examples=200, deadline=None)
def test_front_is_nondominated(pts):
    front = pareto_front(pts)
    for p in front:
        assert not any(dominates(q, p) for q in pts)


@given(points)
@settings(max_examples=200, deadline=None)
def test_every_point_dominated_or_on_front(pts):
    front = set(map(tuple, pareto_front(pts)))
    for p in pts:
        assert tuple(p) in front or any(dominates(q, p) for q in front)


@given(points)
@settings(max_examples=200, deadline=None)
def test_front_monotone(pts):
    """Sorted by latency ascending, throughput must strictly increase."""
    front = pareto_front(pts)
    for a, b in zip(front, front[1:]):
        assert a[0] < b[0] and a[1] < b[1]


@given(points)
@settings(max_examples=100, deadline=None)
def test_front_idempotent(pts):
    f1 = pareto_front(pts)
    assert pareto_front(f1) == f1


@given(points, st.tuples(st.floats(0.01, 100), st.floats(0.01, 100)))
@settings(max_examples=100, deadline=None)
def test_adding_dominated_point_keeps_front(pts, extra):
    front = pareto_front(pts)
    if any(dominates(q, extra) for q in front):
        assert set(map(tuple, pareto_front(pts + [extra]))) \
            == set(map(tuple, front))


@given(points)
@settings(max_examples=100, deadline=None)
def test_hypervolume_nonneg_and_front_invariant(pts):
    ref = max(p[0] for p in pts) * 1.1
    hv_all = hypervolume(pts, ref)
    hv_front = hypervolume(pareto_front(pts), ref)
    assert hv_all >= 0
    assert math.isclose(hv_all, hv_front, rel_tol=1e-9, abs_tol=1e-12)


# ---- d-dimensional properties (the objective-vector protocol) ------------- #
@given(points3)
@settings(max_examples=200, deadline=None)
def test_front3_is_nondominated(pts):
    front = pareto_front(pts, OBJ3)
    for p in front:
        assert not any(dominates(q, p, OBJ3) for q in pts)


@given(points3)
@settings(max_examples=200, deadline=None)
def test_front3_covers_every_point(pts):
    front = set(pareto_front(pts, OBJ3))
    for p in pts:
        assert p in front or any(dominates(q, p, OBJ3) for q in front)


@given(points3)
@settings(max_examples=200, deadline=None)
def test_dominates3_antisymmetric_and_irreflexive(pts):
    for p in pts:
        assert not dominates(p, p, OBJ3)
    for a in pts[:10]:
        for b in pts[:10]:
            assert not (dominates(a, b, OBJ3) and dominates(b, a, OBJ3))


@given(points3)
@settings(max_examples=100, deadline=None)
def test_front3_idempotent(pts):
    f1 = pareto_front(pts, OBJ3)
    assert pareto_front(f1, OBJ3) == f1


@given(points)
@settings(max_examples=200, deadline=None)
def test_d2_path_agrees_with_legacy_sweep(pts):
    """The generalized front must reproduce the original bi-objective
    sort-sweep output exactly (order included)."""
    order = sorted(pts, key=lambda p: (p[0], -p[1]))
    legacy, best_thr = [], float("-inf")
    for p in order:
        if p[1] > best_thr:
            legacy.append(p)
            best_thr = p[1]
    assert pareto_front(pts) == legacy


@given(points3)
@settings(max_examples=100, deadline=None)
def test_hypervolume3_nonneg_front_invariant_and_monotone(pts):
    ref = (max(p[0] for p in pts) * 1.1, min(p[1] for p in pts) * 0.9,
           max(p[2] for p in pts) * 1.1)
    hv_all = hypervolume(pts, ref, OBJ3)
    hv_front = hypervolume(pareto_front(pts, OBJ3), ref, OBJ3)
    assert hv_all >= 0
    assert math.isclose(hv_all, hv_front, rel_tol=1e-9, abs_tol=1e-12)
    # an extra clearly-dominating point can only grow the volume
    better = (0.005, 200.0, 0.005)
    assert hypervolume(pts + [better], ref, OBJ3) >= hv_all - 1e-12


def test_dominates_basic():
    assert dominates((1.0, 10.0), (2.0, 5.0))
    assert not dominates((1.0, 5.0), (2.0, 10.0))
    assert not dominates((1.0, 5.0), (1.0, 5.0))  # equal: no strict improve


def test_knee_on_front():
    pts = [(1, 1), (2, 5), (3, 6), (10, 6.5)]
    k = knee_point(pts)
    assert is_on_front(k, pts)
    assert k in ((2, 5), (3, 6))  # a balanced pick, not an extreme
    assert k != (1, 1) and k != (10, 6.5)


def test_blockgraph_cut_bytes_and_shared_groups():
    blocks = (
        Block("a", 1e6, 100, out_bytes=10),
        Block("b", 1e6, 200, out_bytes=20, shared_group="s"),
        Block("c", 1e6, 200, out_bytes=30, shared_group="s"),
        Block("d", 1e6, 50, out_bytes=40, broadcast_bytes=7),
        Block("e", 1e6, 60, out_bytes=50),
    )
    g = BlockGraph("t", blocks, input_bytes=5, output_bytes=3)
    assert g.cut_bytes(0) == 5
    assert g.cut_bytes(2) == 20
    assert g.cut_bytes(5) == 3
    assert g.cut_bytes(5 - 1) == 40 + 7  # broadcast edge crosses later cuts...
    # shared group counted once globally and once per segment
    assert g.total_weight_bytes == 100 + 200 + 50 + 60
    assert g.segment_weight_bytes(1, 3) == 200
    assert g.segment_weight_bytes(0, 5) == g.total_weight_bytes
