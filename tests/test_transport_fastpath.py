"""Zero-copy fast path for the process transports: packed socket
framing, the shmem doorbell ring, slot growth/teardown, the zero-copy
lease, the outlier-robust estimator fit, and the shmem-vs-socket
regression guard.

Bit-level parity is the acceptance surface: every message kind and both
framings must cross the packed wire formats unchanged, through ring
wraparound and slot growth, with ``TransferRecord`` semantics identical
to the pickled formats they replace.
"""
import os

import numpy as np
import pytest

from repro.core.autosplit import LinkEstimator
from repro.core.devices import (Link, fit_link_params,
                                fit_link_params_robust)
from repro.runtime.transport import (BATCH, CLOCK, ERROR, PROBE, RECONFIG,
                                     STATS, STOP, WARMUP, HopSpec,
                                     get_transport, measure_hop)


def _payload_cases():
    return [
        ("f32", np.arange(24, dtype=np.float32).reshape(2, 3, 4)),
        ("f64", np.linspace(0, 1, 7, dtype=np.float64)),
        ("i64", np.arange(-4, 4, dtype=np.int64).reshape(2, 4)),
        ("u8", np.frombuffer(bytes(range(256)), dtype=np.uint8).copy()),
        ("bool", np.array([[True, False], [False, True]])),
        ("scalar0d", np.float32(3.5) * np.ones(())),
        ("big", np.arange(1 << 16, dtype=np.float32)),      # slot path
        ("tiny", np.ones(3, dtype=np.float32)),             # inline path
        ("empty", np.zeros((0, 4), dtype=np.float32)),
        ("ndim9", np.arange(8, dtype=np.float32).reshape((1,) * 8 + (8,))),
    ]


# --------------------------------------------------------------------------- #
# Packed-wire parity: every kind, every framing, both process backends
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["socket", "shmem"])
@pytest.mark.parametrize("framing", ["raw", "pickle"])
def test_packed_framing_bit_parity(name, framing):
    chan = get_transport(name).open(HopSpec(index=0, framing=framing))
    try:
        for label, x in _payload_cases():
            chan.send(x, kind=BATCH)
            kind, y = chan.recv(timeout=5.0)
            assert kind == BATCH
            assert y.dtype == x.dtype, (label, y.dtype)
            assert y.shape == x.shape, (label, y.shape)
            # bit-identical, not just allclose
            assert np.asarray(y).tobytes() == x.tobytes(), label
    finally:
        chan.close()


@pytest.mark.parametrize("name", ["socket", "shmem"])
def test_control_tokens_roundtrip(name):
    """Non-array control payloads ride the same packed records."""
    cases = [(STOP, None), (PROBE, None), (CLOCK, 123.456),
             (RECONFIG, (0, 5, 12, 17)), (STATS, None),
             (ERROR, "stage 1 (ValueError): boom " + "x" * 500),
             (WARMUP, np.ones((2, 2), dtype=np.float32))]
    chan = get_transport(name).open(HopSpec(index=0))
    try:
        for kind, payload in cases:
            chan.send(payload, kind=kind)
            k, got = chan.recv(timeout=5.0)
            assert k == kind
            if isinstance(payload, np.ndarray):
                assert np.array_equal(got, payload)
            else:
                assert got == payload
        # only BATCH/PROBE transfers are recorded, as before
        recs = chan.drain_records()
        assert len(recs) == 1 and recs[0].nbytes == 0
    finally:
        chan.close()


def test_ring_wraparound():
    """Many more messages than the control ring holds: seq counters keep
    running past the ring capacity and every payload survives."""
    chan = get_transport("shmem").open(HopSpec(index=0, depth=2))
    try:
        n = 4 * chan._cap + 3
        for i in range(n):
            x = np.full(5 + (i % 7), i, dtype=np.int32)
            chan.send(x, kind=BATCH)
            _, y = chan.recv(timeout=5.0)
            assert np.array_equal(x, y), i
        assert len(chan.drain_records()) == n
    finally:
        chan.close()


def test_slot_growth_under_backpressure():
    """Fill every slot with growing payloads before draining any: each
    send pops a freed slot, outgrows it, and replaces it in the name
    table — the drain must still see every payload bit-exact."""
    depth = 3
    chan = get_transport("shmem").open(HopSpec(index=0, depth=depth))
    try:
        xs = [np.arange((1 << 14) << i, dtype=np.uint8) for i in range(depth)]
        for x in xs:                          # no recv in between
            chan.send(x, kind=BATCH)
        for x in xs:
            _, y = chan.recv(timeout=5.0)
            assert np.array_equal(x, y)
        # second wave reuses (some grown) slots
        for x in reversed(xs):
            chan.send(x, kind=BATCH)
            _, y = chan.recv(timeout=5.0)
            assert np.array_equal(x, y)
    finally:
        chan.close()


def test_zero_copy_view_vs_copy_mode():
    """Default recv hands out a view over the mapped slot (zero-copy);
    ``zero_copy=False`` buys an owning copy that survives the next
    recv."""
    x = np.arange(1 << 12, dtype=np.float32)  # big enough for the slot path
    chan = get_transport("shmem").open(HopSpec(index=0))
    try:
        chan.send(x, kind=BATCH)
        _, view = chan.recv(timeout=5.0)
        assert not view.flags.owndata         # np.frombuffer over the slot
        assert np.array_equal(view, x)
    finally:
        chan.close()
    chan = get_transport("shmem").open(HopSpec(index=0, zero_copy=False))
    try:
        chan.send(x, kind=BATCH)
        _, own = chan.recv(timeout=5.0)
        assert own.flags.owndata
        chan.send(np.zeros_like(x), kind=BATCH)   # reuses the slot
        chan.recv(timeout=5.0)
        assert np.array_equal(own, x)         # copy was defensive
    finally:
        chan.close()


def test_held_view_survives_slot_replacement():
    """A zero-copy view handed out earlier must stay valid (its mapping
    pinned) even after the sender outgrows and replaces that slot."""
    chan = get_transport("shmem").open(HopSpec(index=0, depth=1))
    try:
        a = np.arange(1 << 12, dtype=np.uint8)
        chan.send(a, kind=BATCH)
        _, va = chan.recv(timeout=5.0)        # leases the slot
        held = []
        for i in range(4):                    # grow the same slots repeatedly
            big = np.full(1 << (16 + i), i, dtype=np.uint8)
            chan.send(big, kind=BATCH)
            _, vb = chan.recv(timeout=5.0)
            held.append(vb)
        assert np.array_equal(va, a)          # old view still readable
        for i, vb in enumerate(held[:-1]):
            assert vb[0] == i
    finally:
        chan.close()


def test_shmem_teardown_unlinks_all_segments():
    """close() must unlink the control segment and every pooled slot —
    no /dev/shm leaks across runs (incl. slots replaced by growth)."""
    if not os.path.isdir("/dev/shm"):
        pytest.skip("no /dev/shm on this platform")
    before = set(os.listdir("/dev/shm"))
    chan = get_transport("shmem").open(HopSpec(index=0, depth=3))
    sizes = [1 << 12, 1 << 18, 1 << 12, 1 << 20, 1 << 14]
    for n in sizes:                           # growth replaces segments
        chan.send(np.zeros(n, dtype=np.uint8), kind=BATCH)
        chan.recv(timeout=5.0)
    assert len(set(os.listdir("/dev/shm")) - before) >= 1   # live segments
    chan.close()
    assert set(os.listdir("/dev/shm")) - before == set()


def test_shmem_fan_teardown_reaps_every_lane():
    """open_fan lanes pack into one control segment: close() on every
    lane end plus one reap() must unlink the shared segment and all
    per-lane payload slots — the supervisor's rebuild path after a
    SIGKILL'd replica depends on this not leaking."""
    if not os.path.isdir("/dev/shm"):
        pytest.skip("no /dev/shm on this platform")
    before = set(os.listdir("/dev/shm"))
    lanes = get_transport("shmem").open_fan(HopSpec(index=0, depth=3), 2)
    for m, lane in enumerate(lanes):          # slot traffic on every lane
        lane.send(np.full(1 << 16, m, dtype=np.uint8), kind=BATCH)
        lane.recv(timeout=5.0)
    assert len(set(os.listdir("/dev/shm")) - before) >= 1   # live segments
    for lane in lanes:
        lane.close()
    lanes[0].reap()                           # idempotent vs close()
    assert set(os.listdir("/dev/shm")) - before == set()


def test_socket_vectored_send_large_payload():
    """8 MiB through sendmsg: the partial-write loop must hold up well
    past the kernel socket buffers (needs a concurrent reader)."""
    import threading
    chan = get_transport("socket").open(HopSpec(index=0))
    x = np.arange(2 << 20, dtype=np.float32)  # 8 MiB
    out = {}

    def reader():
        out["msg"] = chan.recv(timeout=30.0)
    try:
        t = threading.Thread(target=reader)
        t.start()
        chan.send(x, kind=BATCH)
        t.join(30.0)
        kind, y = out["msg"]
        assert kind == BATCH and np.array_equal(x, y)
    finally:
        chan.close()


# --------------------------------------------------------------------------- #
# Outlier-robust LinkEstimator fit (heavy-tailed measured records)
# --------------------------------------------------------------------------- #
def test_robust_fit_resists_heavy_tail():
    truth = Link("truth", rtt_s=10e-3, bw_bytes_per_s=1e8,
                 per_msg_overhead_s=1e-3)
    rng = np.random.default_rng(0)
    sizes = np.tile([1e4, 1e5, 1e6], 12)
    elapsed = np.array([truth.transfer_time(n) for n in sizes])
    dirty = elapsed.copy()
    spikes = rng.choice(len(dirty), size=5, replace=False)
    dirty[spikes] *= 25.0                     # scheduler-preemption tail
    plain = fit_link_params(sizes, dirty, truth.rtt_s)
    robust = fit_link_params_robust(sizes, dirty, truth.rtt_s)
    assert robust is not None and plain is not None
    bw_r, _ = robust
    bw_p, _ = plain
    assert abs(bw_r - truth.bw_bytes_per_s) < abs(bw_p - truth.bw_bytes_per_s)
    assert bw_r == pytest.approx(truth.bw_bytes_per_s, rel=0.15)
    # clean window: robust degrades exactly to the plain fit
    assert fit_link_params_robust(sizes, elapsed, truth.rtt_s) == \
        pytest.approx(fit_link_params(sizes, elapsed, truth.rtt_s))


def test_estimator_observe_api_with_outliers():
    truth = Link("truth", rtt_s=10e-3, bw_bytes_per_s=1e8,
                 per_msg_overhead_s=1e-3)
    est = LinkEstimator(rtt_s=truth.rtt_s, bw_bytes_per_s=1e9, alpha=0.5)
    for i in range(12):
        for n in (1e4, 1e5, 1e6):
            t = truth.transfer_time(n)
            est.observe(n, t * (20.0 if (i % 5 == 0 and n == 1e5) else 1.0))
    # the EWMA carries early (small, outlier-contaminated) windows in
    # its history, so the bar is "sane despite 20x spikes", not exact
    assert est.bw_bytes_per_s == pytest.approx(truth.bw_bytes_per_s, rel=0.5)
    assert est.per_msg_overhead_s < 10 * truth.per_msg_overhead_s


# --------------------------------------------------------------------------- #
# Regression guard: the whole point of the doorbell ring
# --------------------------------------------------------------------------- #
@pytest.mark.slow
def test_shmem_beats_socket_at_1mib():
    """Cross-process, credit-paced, receiver-measured: the shmem ring's
    median per-hop cost at 1 MiB must not exceed loopback TCP's — the
    regression the doorbell redesign exists to prevent."""
    n = 1 << 20
    shmem = measure_hop("shmem", [n], n_per_size=30)[n]
    sock = measure_hop("socket", [n], n_per_size=30)[n]
    assert shmem and sock
    med_m, med_s = float(np.median(shmem)), float(np.median(sock))
    assert med_m <= med_s, \
        f"shmem {med_m * 1e6:.0f}us > socket {med_s * 1e6:.0f}us at 1 MiB"
