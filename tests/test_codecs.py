"""Per-hop wire codecs: Pallas pack kernels vs refs, bounded round-trip
error over dtypes/shapes (hypothesis where installed, a seeded sweep
otherwise), ``none`` bit-parity with uncoded framing, the codec byte
surviving socket and shmem framing cross-process, and the 4-objective
DP front cross-validated against the exhaustive sweep.
"""
import math
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codecs as C
from repro.core.blocks import Block, BlockGraph
from repro.core.devices import DeviceProfile, Link
from repro.core.pareto import pareto_front, resolve_objectives
from repro.core.partitioner import (best_accuracy, dp_front_kway,
                                    solve_with_codecs, sweep_kway)
from repro.kernels import ops, ref

RNG = np.random.default_rng(11)

# shapes every codec must survive: 0-d, empty, 1-d, odd sizes that do
# not fill a Pallas lane, multi-dim
SHAPES = [(), (0,), (1,), (7,), (127,), (128,), (129,), (3, 5, 7), (2, 1000)]
FLOAT_DTYPES = [np.float16, np.float32, np.float64]


def _sample(shape, dtype, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(size=shape or ()).astype(dtype)
    return np.asarray(x * 3.0, dtype=dtype)


# --------------------------------------------------------------------------- #
# Pallas kernels vs pure-jnp refs
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("shape", SHAPES)
def test_int8_pack_pallas_matches_ref(shape):
    x = jnp.asarray(_sample(shape, np.float32, seed=1))
    q, s = ops.int8_pack(x, interpret=True)
    qr, sr = ref.int8_pack_ref(x)
    assert np.array_equal(np.asarray(q), np.asarray(qr))
    assert np.asarray(s) == pytest.approx(np.asarray(sr), rel=1e-6)
    y = ops.int8_unpack(q, s, interpret=True)
    yr = ref.int8_unpack_ref(qr, sr)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
def test_fp8_pack_pallas_matches_ref(shape):
    x = jnp.asarray(_sample(shape, np.float32, seed=2))
    q, s = ops.fp8_pack(x, interpret=True)
    qr, sr = ref.fp8_pack_ref(x)
    assert np.array_equal(np.asarray(q).view(np.uint8),
                          np.asarray(qr).view(np.uint8))
    y = ops.fp8_unpack(q, s, interpret=True)
    yr = ref.fp8_unpack_ref(qr, sr)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-6)


@pytest.mark.parametrize("shape,k", [((128,), 16), ((7,), 1), ((3, 5, 7), 13),
                                     ((2, 1000), 250)])
def test_topk_select_pallas_matches_ref(shape, k):
    x = jnp.asarray(_sample(shape, np.float32, seed=3))
    idx, vals = ops.topk_select(x, k=k, interpret=True)
    idx_r, vals_r = ref.topk_select_ref(x, k=k)
    assert np.array_equal(np.asarray(idx), np.asarray(idx_r))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(vals_r))
    # indices ascending, values = flat[idx]
    assert np.all(np.diff(np.asarray(idx)) > 0) or k == 1
    flat = np.asarray(x).reshape(-1)
    np.testing.assert_allclose(flat[np.asarray(idx)], np.asarray(vals))


# --------------------------------------------------------------------------- #
# Round-trip properties: bit-parity for none, bounded error for lossy
# --------------------------------------------------------------------------- #
def _roundtrip_bounds(codec_name, x):
    """Assert the codec's wire round trip respects its error contract."""
    c = C.get_codec(codec_name)
    host = np.ascontiguousarray(x)
    if not c.supports(host.dtype) or host.size == 0:
        assert np.array_equal(C.roundtrip(c, host), host)
        return
    buf = c.encode(host)
    assert len(buf) == c.wire_bytes(host.size, host.dtype.itemsize)
    y = c.decode(buf, host.shape, host.dtype)
    assert y.shape == host.shape and y.dtype == host.dtype
    amax = float(np.max(np.abs(host.astype(np.float64))))
    err = float(np.max(np.abs(host.astype(np.float64) -
                              y.astype(np.float64))))
    # restoring to the original dtype re-rounds: allow its own epsilon
    dt_eps = amax * float(np.finfo(host.dtype).eps)
    if codec_name == "int8":
        scale = max(amax, 1e-12) / 127.0
        assert err <= 0.5 * scale * 1.01 + dt_eps + 1e-6
    elif codec_name == "fp8":
        # e4m3: 3 mantissa bits -> 2^-4 relative, plus the denormal floor
        scale = max(amax, 1e-12) / 448.0
        assert err <= amax * 0.0625 * 1.01 + scale + dt_eps + 1e-6
    elif codec_name == "topk":
        k = c._k(host.size)
        nz = np.count_nonzero(y)
        assert nz <= k
        # survivors are exact
        mask = y.reshape(-1) != 0
        np.testing.assert_allclose(y.reshape(-1)[mask],
                                   host.reshape(-1).astype(y.dtype)[mask])
        assert err <= amax + 1e-6


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                            # container has no hypothesis:
    HAVE_HYPOTHESIS = False                    # the seeded sweep below covers


if HAVE_HYPOTHESIS:
    @given(st.sampled_from(["int8", "fp8", "topk"]),
           st.sampled_from(SHAPES),
           st.sampled_from(FLOAT_DTYPES),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=60, deadline=None)
    def test_lossy_roundtrip_bounded_property(codec, shape, dtype, seed):
        _roundtrip_bounds(codec, _sample(shape, dtype, seed=seed))
else:
    @pytest.mark.parametrize("codec", ["int8", "fp8", "topk"])
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", FLOAT_DTYPES)
    def test_lossy_roundtrip_bounded_sweep(codec, shape, dtype):
        for seed in (0, 1, 2):
            _roundtrip_bounds(codec, _sample(shape, dtype, seed=seed))


@pytest.mark.parametrize("dtype", [np.float32, np.int32, np.uint8])
@pytest.mark.parametrize("shape", SHAPES)
def test_none_codec_is_bitexact(dtype, shape):
    c = C.get_codec("none")
    x = np.ascontiguousarray((RNG.standard_normal(size=shape or ()) * 100)
                             .astype(dtype))
    buf = c.encode(x)
    assert buf == x.tobytes()                  # the pre-codec wire layout
    y = c.decode(buf, x.shape, x.dtype)
    assert np.array_equal(y, x) and y.dtype == x.dtype


def test_lossy_codec_skips_unsupported_dtypes():
    for name in ("int8", "fp8", "topk"):
        c = C.get_codec(name)
        assert c.supports(np.dtype(np.float32))
        assert not c.supports(np.dtype(np.int32))
        assert not c.supports(np.dtype(np.uint8))


def test_registry_and_wire_codes_are_stable():
    # wire codes are append-only protocol constants
    assert [C.get_codec(n).code for n in ("none", "int8", "fp8", "topk")] \
        == [0, 1, 2, 3]
    for n in ("none", "int8", "fp8", "topk"):
        assert C.codec_for_code(C.get_codec(n).code).name == n
    with pytest.raises(KeyError):
        C.get_codec("lzma")

    class Pretender(C.Codec):                  # claims int8's wire code
        name, code = "pretender", 1
    with pytest.raises(ValueError):
        C.register_codec(Pretender())
    assert "pretender" not in C.CODECS


def test_codec_wire_bytes_analytic_matches_encode():
    for name in ("none", "int8", "fp8", "topk"):
        c = C.get_codec(name)
        for n in (1, 7, 128, 4096):
            x = np.asarray(RNG.standard_normal(n), np.float32)
            assert C.codec_wire_bytes(c, x.nbytes) == len(c.encode(x))
    # int8 hits the acceptance ratio on >=64 KiB fp32 payloads
    raw = 64 * 1024
    assert raw / C.codec_wire_bytes(C.get_codec("int8"), raw) >= 3.5


def test_compressed_bytes_agrees_with_codec_wire_layout():
    from repro.optim.compress import CompressionConfig, compressed_bytes
    params = {"a": np.zeros((32, 32), np.float32),
              "b": np.zeros((100,), np.float32)}
    on = compressed_bytes(params, CompressionConfig(enabled=True, bits=8))
    off = compressed_bytes(params, CompressionConfig(enabled=False))
    assert off == (32 * 32 + 100) * 4
    # per-leaf scale header + 1 byte/elem: the int8 codec's wire layout
    assert on == sum(C.quantized_wire_bytes(v.size, bits=8)
                     for v in params.values())
    assert on == (32 * 32 + 100) + 2 * 4


# --------------------------------------------------------------------------- #
# Framing: codec byte in _FHDR/_RREC, none bit-parity, raw+wire records
# --------------------------------------------------------------------------- #
def test_frame_none_matches_uncoded_frame():
    """With the none codec the framed payload is byte-identical to an
    uncoded frame — the `codec byte 0` path IS the pre-codec layout."""
    from repro.runtime import transport as T
    x = np.asarray(RNG.standard_normal((4, 32)), np.float32)
    uncoded = T._frame(x, "raw", None)
    noned = T._frame(x, "raw", C.get_codec("none"))
    assert uncoded == noned
    ftype, code, shape, data, meta, ccode = noned
    assert ccode == 0 and bytes(data) == x.tobytes()
    y = T._unframe(ftype, code, shape, data, meta, ccode)
    assert np.array_equal(np.asarray(y), x)


def test_frame_codec_packs_and_unframe_restores():
    from repro.runtime import transport as T
    x = np.asarray(RNG.standard_normal((8, 64)), np.float32)
    ftype, code, shape, data, meta, ccode = T._frame(
        x, "raw", C.get_codec("int8"))
    assert ccode == 1 and len(data) == 4 + x.size
    y = T._unframe(ftype, code, shape, data, meta, ccode)
    scale = np.max(np.abs(x)) / 127.0
    assert float(np.max(np.abs(np.asarray(y) - x))) <= 0.5 * scale * 1.01
    # non-float payloads ship uncoded whatever the hop codec says
    xi = np.arange(64, dtype=np.int32)
    *_, data_i, _, ccode_i = T._frame(xi, "raw", C.get_codec("int8"))
    assert ccode_i == 0 and bytes(data_i) == xi.tobytes()


@pytest.mark.parametrize("transport", ["socket", "shmem"])
def test_codec_byte_survives_framing_cross_process(transport):
    """A coded hop to a spawned sink: receiver-side records carry both
    the raw payload size (decoded from the codec byte + shape) and the
    packed wire size."""
    from repro.runtime.transport import measure_hop
    nbytes = 64 * 1024
    out = measure_hop(transport, [nbytes], n_per_size=4, codec="int8",
                      full=True)
    recs = out[nbytes]
    assert recs, "sink returned no matching records"
    for r in recs:
        assert r.raw_bytes == nbytes
        assert r.nbytes == 4 + nbytes // 4     # scale header + int8 payload
        assert r.wire_bytes == r.nbytes
        assert r.raw_bytes / r.nbytes >= 3.5   # acceptance ratio on the wire


def test_uncoded_measure_hop_records_raw_equals_wire():
    from repro.runtime.transport import measure_hop
    out = measure_hop("socket", [4096], n_per_size=3, codec="none", full=True)
    for r in out[4096]:
        assert r.raw_bytes == r.nbytes == 4096


# --------------------------------------------------------------------------- #
# Emulated end-to-end: real degradation + codec switch mid-stream
# --------------------------------------------------------------------------- #
def _tiny_model():
    from repro.models.cnn.layers import (Conv2D, Flatten, Linear, Pool,
                                         ReLU, Sequential)
    from repro.models.cnn.zoo import CNNModel
    blocks = [
        ("conv0", Sequential([Conv2D(3, 8, 3, 1, 1), ReLU()])),
        ("conv1", Sequential([Conv2D(8, 8, 3, 1, 1), ReLU()])),
        ("pool", Pool("max", 2, 2)),
        ("conv2", Sequential([Conv2D(8, 16, 3, 1, 1), ReLU()])),
        ("head", Sequential([Flatten(), Linear(16 * 16 * 16, 10)])),
    ]
    return CNNModel("tinycnn", blocks, input_hw=32)


def test_emulated_pipeline_codec_roundtrip_and_switch():
    from repro.core.devices import LAN_PI_GPU
    from repro.runtime.edge import EdgePipeline
    m = _tiny_model()
    params = m.init(jax.random.PRNGKey(0))
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3)),
                   np.float32)
    ref_y = np.asarray(m.apply(params, x))
    pipe = EdgePipeline(m, params, cuts=(2, 4),
                        scenario=[LAN_PI_GPU, LAN_PI_GPU], codec="int8")
    try:
        pipe.warmup(x)
        y, _, _ = pipe.run_one(x)
        err = float(np.max(np.abs(np.asarray(y) - ref_y)))
        assert 0 < err < 0.1                   # real int8 degradation
        recs = [r for r in pipe.nets[0].observations if r.nbytes > 0]
        assert recs and all(r.raw_bytes / r.nbytes > 3.5 for r in recs)
        # quiescent codec-only migrate back to bit-exact
        pipe.migrate(pipe.cuts, codecs=("none", "none"))
        assert pipe.codecs == ("none", "none")
        y2, _, _ = pipe.run_one(x)
        assert np.array_equal(np.asarray(y2), ref_y)
    finally:
        pipe.close()


def test_session_codec_only_switch_is_a_migration():
    """A codec retune with unchanged cuts still runs the in-band
    RECONFIG + WARMUP — charged like a migration."""
    from repro.core.devices import LAN_PI_GPU
    from repro.runtime.edge import EdgePipeline
    m = _tiny_model()
    params = m.init(jax.random.PRNGKey(0))
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3)),
                   np.float32)
    pipe = EdgePipeline(m, params, cuts=(2, 4),
                        scenario=[LAN_PI_GPU, LAN_PI_GPU])
    try:
        pipe.warmup(x)
        n_migrations = len(pipe.migrations)
        with pipe.session(inflight=1) as s:
            s.submit(x)
            list(s.results())
            s.migrate(pipe.cuts, codecs=("fp8", "fp8"))
            s.submit(x)
            list(s.results())
        assert pipe.codecs == ("fp8", "fp8")
        assert len(pipe.migrations) == n_migrations + 1
        # re-issuing the active codecs is a no-op, not another migration
        with pipe.session(inflight=1) as s:
            s.migrate(pipe.cuts, codecs=("fp8", "fp8"))
        assert len(pipe.migrations) == n_migrations + 1
    finally:
        pipe.close()


# --------------------------------------------------------------------------- #
# Calibration + 4-objective solve
# --------------------------------------------------------------------------- #
def test_calibration_measures_degradation():
    m = _tiny_model()
    params = m.init(jax.random.PRNGKey(0))
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (8, 32, 32, 3)),
                   np.float32)
    cal = C.calibrate_codecs(m, params, x, codecs=("int8",), cuts=(2, 4))
    for cut in (2, 4):
        acc = cal.table[(cut, "int8")]
        assert 0.0 <= acc.top1_agreement <= 1.0
        assert acc.max_abs_err > 0.0           # lossy really is lossy
        assert cal.accuracy(cut, C.get_codec("int8")) == acc.top1_agreement
    # unmeasured (cut, codec) falls back to the codec's nominal figure
    assert cal.accuracy(3, C.get_codec("fp8")) == \
        C.get_codec("fp8").nominal_accuracy
    assert cal.accuracy(2, C.get_codec("none")) == 1.0


def _toy_graph(n=8, seed=5):
    rng = np.random.default_rng(seed)
    blocks = tuple(
        Block(name=f"b{i}", flops=float(rng.integers(1, 9)) * 1e8,
              out_bytes=int(rng.integers(1, 5)) * 4096,
              weight_bytes=int(rng.integers(1, 9)) * 8192,
              act_bytes=4096)
        for i in range(n))
    return BlockGraph("toy", blocks, input_bytes=4096)


def _chain3():
    dev = DeviceProfile("d", flops_per_s=1e9, mem_bytes=1 << 30,
                        active_w=4.0, idle_w=1.0)
    link = Link("l", rtt_s=20e-3, bw_bytes_per_s=2e6,
                energy_per_byte_j=3e-7)
    return (dev, dev, dev), (link, link)


@pytest.mark.parametrize("codecs,floor", [
    (("int8", "int8"), None),
    (("int8", "topk"), None),
    (("topk", "topk"), 0.95),
    (("int8", "none"), 0.98),
])
def test_dp_front_4d_matches_exhaustive_sweep(codecs, floor):
    g = _toy_graph()
    devices, links = _chain3()
    objs = resolve_objectives(4)
    dp = dp_front_kway(g, devices, links, objectives=4, codecs=codecs,
                       accuracy_floor=floor)
    sweep = sweep_kway(g, devices, links, codecs=codecs)
    if floor is not None:
        sweep = [p for p in sweep if p.accuracy >= floor]
    expect = pareto_front(sweep, objs)
    assert sorted(p.partition for p in dp) == \
        sorted(p.partition for p in expect)
    for p in dp:
        assert p.codecs == tuple(C.get_codec(c).name for c in codecs)
        if floor is not None:
            assert p.accuracy >= floor


def test_dp_front_accuracy_floor_can_empty_the_front():
    g = _toy_graph()
    devices, links = _chain3()
    # two topk hops: nominal 0.97**2 < 0.95 — nothing survives
    assert dp_front_kway(g, devices, links, objectives=4,
                         codecs=("topk", "topk"), accuracy_floor=0.95) == []


def test_solve_with_codecs_joint_front_and_floor():
    from repro.core.scenarios import Scenario
    g = _toy_graph()
    devices, links = _chain3()
    scen = Scenario("toy3", devices, links)
    front = solve_with_codecs(g, scen, codec_choices=("none", "int8"),
                              accuracy_floor=0.97)
    assert front
    assert all(p.accuracy >= 0.97 for p in front)
    # the uncoded assignment is always accuracy-optimal
    assert best_accuracy(front).codecs == ("none", "none")
    # coarser codecs must appear on the front: they strictly shrink hop
    # bytes, so they win the latency axis on a bandwidth-bound chain
    assert any("int8" in p.codecs for p in front)
    accs = {p.codecs: p.accuracy for p in front}
    assert all(a >= 0.97 for a in accs.values())


def test_scenario_codecs_flow_through_solve():
    from repro.core.partitioner import solve
    from repro.core.scenarios import get
    g = _toy_graph()
    scen = get("pi_pi_gpu_int8")
    pts = solve(g, scen, objectives=4)
    assert pts and all(p.codecs == ("int8", "int8") for p in pts)
    nominal = C.get_codec("int8").nominal_accuracy
    assert all(p.accuracy == pytest.approx(nominal ** 2) for p in pts)
    # and the packed bytes shrink the modeled wire time vs uncoded
    pts_none = solve(g, scen, objectives=4, codecs=("none", "none"))
    by_cut = {p.partition: p for p in pts_none}
    for p in pts:
        if p.partition in by_cut:
            assert p.net_s <= by_cut[p.partition].net_s


# --------------------------------------------------------------------------- #
# Satellite: computed migration cost
# --------------------------------------------------------------------------- #
def test_migration_time_computed_from_moved_bytes():
    from repro.core.autosplit import AdaptiveSplitter
    from repro.core.scenarios import Scenario
    g = _toy_graph()
    devices, links = _chain3()
    scen = Scenario("toy3", devices, links)
    sp = AdaptiveSplitter(g, scen, batch=2)    # migration_cost_s=None
    # moving cut (2, 4) -> (4, 4): blocks 2 and 3 cross hop 0
    moved = g.blocks[2].weight_bytes + g.blocks[3].weight_bytes
    expect = sp.migration_overhead_s + links[0].transfer_time(moved)
    assert sp.migration_time_s((2, 4), (4, 4)) == pytest.approx(expect)
    # no move: just the fixed overhead (the codec-only switch charge)
    assert sp.migration_time_s((2, 4), (2, 4)) == \
        pytest.approx(sp.migration_overhead_s)
    # the legacy constant still overrides
    sp.migration_cost_s = 0.75
    assert sp.migration_time_s((2, 4), (4, 4)) == 0.75
