"""Streaming Session API: ordered pipelined results, in-flight
migration over every transport under both drain/drop policies, failure
propagation from ``results()``, controller records, the energy-aware
migration amortization gate, and the curated WAN trace library.
"""
import time

import jax
import numpy as np
import pytest

from repro.core import Block, BlockGraph, Scenario, scenarios
from repro.core.autosplit import AdaptiveSplitter, LinkEstimator
from repro.core.costmodel import PipelineMetrics
from repro.core.devices import DURESS, LAN_PI_GPU, DeviceProfile, Link
from repro.models.cnn import zoo
from repro.runtime import (AdaptiveController, AdaptiveRuntime, EdgePipeline,
                           LoopRecord, PinnedController, TransportError,
                           record_trace)


def _tiny_model():
    """A 5-block CNN that jit-compiles in a blink — sessions and
    migrations are the thing under test, not the compute."""
    from repro.models.cnn.layers import (Conv2D, Flatten, Linear, Pool,
                                         ReLU, Sequential)
    from repro.models.cnn.zoo import CNNModel
    blocks = [
        ("conv0", Sequential([Conv2D(3, 8, 3, 1, 1), ReLU()])),
        ("conv1", Sequential([Conv2D(8, 8, 3, 1, 1), ReLU()])),
        ("pool", Pool("max", 2, 2)),
        ("conv2", Sequential([Conv2D(8, 16, 3, 1, 1), ReLU()])),
        ("head", Sequential([Flatten(), Linear(16 * 16 * 16, 10)])),
    ]
    return CNNModel("tinycnn", blocks, input_hw=32)


@pytest.fixture(scope="module")
def tiny():
    m = _tiny_model()
    return m, m.init(jax.random.PRNGKey(0))


def _batches(n, batch=2, hw=32):
    """n distinct inputs — distinctness is what makes loss/duplication/
    reordering detectable at the output."""
    return [jax.random.normal(jax.random.PRNGKey(100 + i), (batch, hw, hw, 3))
            for i in range(n)]


# --------------------------------------------------------------------------- #
# Session basics (emulated)
# --------------------------------------------------------------------------- #
def test_session_ordered_results_and_interleaving(tiny):
    m, params = tiny
    xs = _batches(6)
    refs = [np.asarray(m.apply(params, x)) for x in xs]
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU])
    pipe.warmup(xs[0])
    with pipe.session(inflight=3) as s:
        it = s.results()
        for i in range(3):
            s.submit(xs[i])
        got = [next(it)]                      # consume mid-stream …
        for i in range(3, 6):
            s.submit(xs[i])                   # … and keep submitting
        got += list(it)
    assert len(got) == 6
    for ref, y in zip(refs, got):
        assert np.allclose(ref, y, atol=1e-5)
    # one LoopRecord per batch, in batch order, from the controller
    assert [r.batch_idx for r in s.records] == list(range(6))
    assert all(isinstance(r, LoopRecord) and r.latency_s > 0
               for r in s.records)
    assert s.records[-1].throughput > 0       # windowed, measured


def test_session_refuses_sync_calls_while_open(tiny):
    m, params = tiny
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU])
    x = _batches(1)[0]
    pipe.warmup(x)
    with pipe.session() as s:
        s.submit(x)
        with pytest.raises(RuntimeError, match="Session is open"):
            pipe.run_one(x)
        with pytest.raises(RuntimeError, match="Session is open"):
            pipe.migrate(3)
        s.drain()
    # released: synchronous entrypoints work again
    y, _, _ = pipe.run_one(x)
    assert y is not None


def test_session_rejects_bad_policy_and_nesting(tiny):
    m, params = tiny
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU])
    with pytest.raises(ValueError, match="policy"):
        pipe.session(policy="teleport")
    with pipe.session() as s:
        with pytest.raises(RuntimeError, match="Session is open"):
            pipe.session()
        # the per-call override is validated too — a typo must not
        # silently fall through to drop semantics
        with pytest.raises(ValueError, match="policy"):
            s.migrate(3, policy="flush")


def test_stage_exception_type_survives_the_session(tiny):
    """A stage raising under the thread engine must surface as the
    *original* exception type (legacy run_one/stream behaviour), not a
    flattened TransportError string."""
    m, params = tiny
    x = _batches(1)[0]
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU])
    pipe.warmup(x)

    def boom(_):
        raise ZeroDivisionError("stage blew up")

    pipe._engine.workers[1].run = boom
    with pytest.raises(ZeroDivisionError, match="stage blew up"):
        with pipe.session() as s:
            s.submit(x)
            s.drain()


# --------------------------------------------------------------------------- #
# In-flight migration matrix: transports × policies
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("transport", ["emulated", "socket", "shmem"])
@pytest.mark.parametrize("policy", ["drain", "drop"])
def test_migration_mid_stream_loses_nothing(tiny, transport, policy):
    """The acceptance matrix: migrate() firing with batches in flight
    must lose, duplicate, and reorder nothing, on modeled threads and
    real worker processes alike, under both the flush-first and the
    in-band-token policy.  The whole matrix runs with the protocol
    sanitizer armed — a violation here means the runtime broke the
    token contract even if the outputs happen to come back right."""
    from repro.runtime import drain_violations
    m, params = tiny
    xs = _batches(10)
    refs = [np.asarray(m.apply(params, x)) for x in xs]
    drain_violations()                        # shed any stale reports
    with EdgePipeline(m, params, 2, [LAN_PI_GPU],
                      transport=transport, sanitize=True) as pipe:
        pipe.warmup(xs[0])
        with pipe.session(inflight=4, policy=policy) as s:
            for x in xs[:4]:
                s.submit(x)                   # fill the pipeline …
            s.migrate(3, cost_s=0.0)          # … then move the cut
            for x in xs[4:]:
                s.submit(x)
            got = s.drain()
        assert pipe.cuts == (3,)
        assert len(pipe.migrations) == 1
    assert len(got) == len(xs)                # nothing lost or duplicated
    for i, (ref, y) in enumerate(zip(refs, got)):
        assert np.allclose(ref, y, atol=1e-5), \
            f"batch {i} wrong under {transport}/{policy} (reordered?)"
    bad = drain_violations()
    assert bad == [], "\n".join(v.render() for v in bad)


@pytest.mark.parametrize("transport", ["socket", "shmem"])
def test_worker_death_mid_stream_raises_from_results(tiny, transport):
    """A worker process dying with batches in flight must surface as
    TransportError from the session (submit backpressure or results()),
    not hang."""
    m, params = tiny
    x = _batches(1)[0]
    pipe = EdgePipeline(m, params, (2, 3), scenarios.get("pi_pi_gpu"),
                        transport=transport)
    try:
        pipe.warmup(x)
        t0 = time.perf_counter()
        with pytest.raises(TransportError, match="died|closed|gone"):
            with pipe.session(inflight=4) as s:
                s.submit(x)
                list(s.results())             # healthy round first
                pipe._engine._procs[1].terminate()
                pipe._engine._procs[1].join(5.0)
                for _ in range(8):
                    s.submit(x)
                list(s.results())
        assert time.perf_counter() - t0 < 30.0
    finally:
        pipe.close()


# --------------------------------------------------------------------------- #
# Adaptive under streaming
# --------------------------------------------------------------------------- #
def test_adaptive_controller_migrates_with_batches_in_flight():
    """The tentpole behaviour: the closed loop runs *inside* the
    pipelined stream (inflight > 1) and still chases a degrading
    LinkTrace to a cheaper-wire cut vector."""
    m = zoo.get("mobilenetv2")
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    scen = scenarios.wan_ramp(scenarios.get("pi_pi_gpu"), hop=0,
                              t_start=0.05, t_end=0.4, jitter=0.05)
    with AdaptiveRuntime(m, params, scen, batch=x.shape[0],
                         policy="throughput", check_every=3,
                         migration_cost_s=0.02, alpha=0.6) as rt:
        recs = rt.run(lambda: x, n_batches=12, inflight=3,
                      migration_policy="drop")
        assert len(recs) == 12
        assert [r.batch_idx for r in recs] == list(range(12))
        assert len(rt.pipe.migrations) >= 1
        start, final = recs[0].cuts, rt.pipe.cuts
        assert final != start
        assert rt.graph.cut_bytes(final[0]) <= rt.graph.cut_bytes(start[0])
        # in-stream migration charged both currencies on its record
        mig = [r for r in recs if r.migration_cost_s > 0]
        assert mig and all(r.migration_cost_j >= 0 for r in mig)
        # pipelined records carry a measured windowed throughput
        assert any(r.throughput > 0 for r in recs)


def test_pinned_controller_never_migrates(tiny):
    m, params = tiny
    xs = _batches(8)
    pipe = EdgePipeline(m, params, 2, [LAN_PI_GPU])
    pipe.warmup(xs[0])
    with pipe.session(PinnedController(), inflight=4) as s:
        for x in xs:
            s.submit(x)
        s.drain()
    assert pipe.migrations == []
    assert len(s.records) == 8
    assert all(not r.migrated and r.migration_cost_s == 0 for r in s.records)


# --------------------------------------------------------------------------- #
# Energy-aware migration hysteresis (amortization gate)
# --------------------------------------------------------------------------- #
def _graph_and_scenario():
    # activation bytes shrink with depth, so a degraded wire pushes the
    # optimal cut later while a healthy one balances compute
    blocks = tuple(Block(f"b{i}", flops=1e7, weight_bytes=1_000_000,
                         out_bytes=50_000 * (6 - i)) for i in range(6))
    g = BlockGraph("toy", blocks, input_bytes=300_000, output_bytes=100)
    devs = (DeviceProfile("d0", flops_per_s=1e9, mem_bytes=10**12,
                          idle_w=1.0, active_w=5.0),) * 2
    link = Link("l0", rtt_s=1e-3, bw_bytes_per_s=1e8,
                energy_per_byte_j=1e-6)
    return g, Scenario("toy2", devs, (link,))


def test_migration_energy_is_weights_over_crossed_hops():
    g, scen = _graph_and_scenario()
    sp = AdaptiveSplitter(g, scen, batch=2)
    # moving the cut 2 -> 4 ships blocks 2 and 3 across hop 0
    expect = 2 * 1_000_000 * 1e-6
    assert sp.migration_energy_j((2,), (4,)) == pytest.approx(expect)
    assert sp.migration_energy_j((4,), (2,)) == pytest.approx(expect)
    assert sp.migration_energy_j((3,), (3,)) == 0.0


def _metrics(partition, latency_s, throughput, energy_j):
    return PipelineMetrics(partition=partition, latency_s=latency_s,
                           throughput=throughput, stages=(), net_s=0.0,
                           feasible=True, energy_j=energy_j)


def test_amortization_gate_blocks_and_admits():
    g, scen = _graph_and_scenario()
    sp = AdaptiveSplitter(g, scen, batch=2, migration_cost_s=1.0,
                          amortize_horizon_s=10.0)
    cur = _metrics((2,), 1.0, 1.0, 10.0)      # 2 s/batch at batch=2
    cand = _metrics((4,), 0.5, 4.0, 9.0)      # 0.5 s/batch, saves 1 J/batch
    # horizon serves ~20 batches: 1.5 s/batch time saving >> 1 s cost,
    # 1 J/batch energy saving >> 2 J weight shipment
    assert sp._amortizes(cur, cand, cost_j=2.0)
    # an enormous weight shipment cannot be amortized in 10 s
    assert not sp._amortizes(cur, cand, cost_j=100.0)
    # nor can the redeploy stall when the horizon is tiny
    sp.amortize_horizon_s = 1e-3
    assert not sp._amortizes(cur, cand, cost_j=0.0)
    # no horizon = no gate (legacy behaviour)
    sp.amortize_horizon_s = None
    assert sp._amortizes(cur, cand, cost_j=1e9)


def test_step_respects_amortization_and_charges_cost_j():
    """An attractive candidate must be rejected while its weight
    shipment cannot pay back, and accepted (with last_migration_cost_j
    set) when the gate is off."""
    g, scen = _graph_and_scenario()
    degraded = Link("bad", rtt_s=0.2, bw_bytes_per_s=1e5,
                    energy_per_byte_j=1e-6)

    def run_once(horizon):
        sp = AdaptiveSplitter(g, scen, batch=2, policy="throughput",
                              hysteresis=0.01, migration_cost_s=0.0,
                              amortize_horizon_s=horizon)
        est = LinkEstimator.from_link(degraded)   # start under duress
        sp.step(est)
        start = sp.current.partition
        est2 = LinkEstimator.from_link(scen.links[0])  # wire recovered
        m, migrated = sp.step(est2)
        return sp, start, migrated

    sp, start, migrated = run_once(horizon=None)
    assert migrated and sp.current.partition != start
    assert sp.last_migration_cost_j > 0       # weights crossed the hop
    # an absurdly short horizon blocks the same move
    sp2, start2, migrated2 = run_once(horizon=1e-9)
    assert not migrated2 and sp2.current.partition == start2
    assert sp2.last_migration_cost_j == 0.0


# --------------------------------------------------------------------------- #
# Curated WAN trace mini-library
# --------------------------------------------------------------------------- #
def test_trace_registry_entries():
    for name in ("wan_step_drop", "lte_sawtooth", "congestion_spike",
                 "wan_slow_ramp"):
        tr = scenarios.get_trace(name)
        assert tr.name == name
        assert tr.transfer_time(1e5) > 0
    with pytest.raises(KeyError, match="unknown trace"):
        scenarios.get_trace("carrier-pigeon")
    for sname in ("pi_pi_gpu_step_drop", "pi_pi_gpu_lte_sawtooth",
                  "pi_pi_gpu_congestion_spike"):
        scen = scenarios.get(sname)
        assert scen.time_varying and scen.n_stages == 3


def test_trace_shapes():
    saw = scenarios.get_trace("lte_sawtooth")
    # within each 4 s period: healthy at the start, degraded at 60 %
    assert saw.at(0.0).bw_bytes_per_s == pytest.approx(
        LAN_PI_GPU.bw_bytes_per_s)
    assert saw.at(2.4).bw_bytes_per_s == pytest.approx(
        DURESS.bw_bytes_per_s, rel=0.01)
    assert saw.at(4.0).bw_bytes_per_s == pytest.approx(
        LAN_PI_GPU.bw_bytes_per_s, rel=0.01)
    spike = scenarios.get_trace("congestion_spike")
    assert spike.at(0.0).rtt_s == pytest.approx(LAN_PI_GPU.rtt_s)
    assert spike.at(4.0).rtt_s == pytest.approx(DURESS.rtt_s)
    assert spike.at(10.0).rtt_s == pytest.approx(LAN_PI_GPU.rtt_s)


def _synth_records(trace, t0, t1, n=40):
    """Sample a trace the way a measured channel would record it."""
    recs, sizes = [], [1e4, 1e5, 1e6]
    for i in range(n):
        t = t0 + (t1 - t0) * i / max(n - 1, 1)
        if i % 4 == 0:
            recs.append((0, trace.at(t).rtt_s / 2.0, t))
        else:
            nb = sizes[i % len(sizes)]
            recs.append((int(nb), trace.at(t).transfer_time(nb), t))
    return recs


def test_record_trace_roundtrip_on_curated_traces():
    """Records synthesized from a curated trace, fed through
    ``record_trace``, must reproduce the trace's regimes — measured
    runs can seed the emulator with any library shape."""
    tr = scenarios.get_trace("wan_step_drop")       # step at t=3
    recs = _synth_records(tr, 0.0, 2.8) + _synth_records(tr, 3.2, 8.0)
    rt = record_trace(recs, name="rt", bucket_s=1.0)
    assert rt.at(0.5).rtt_s == pytest.approx(LAN_PI_GPU.rtt_s, rel=0.15)
    assert rt.at(7.0).rtt_s == pytest.approx(DURESS.rtt_s, rel=0.15)
    assert rt.at(7.0).bw_bytes_per_s == pytest.approx(
        DURESS.bw_bytes_per_s, rel=0.3)
    spike = scenarios.get_trace("congestion_spike")  # peak at t=4
    recs = _synth_records(spike, 0.0, 10.0, n=120)
    rs = record_trace(recs, name="rs", bucket_s=1.0)
    assert rs.at(4.0).rtt_s > 5 * rs.at(0.5).rtt_s   # the event is there
    assert rs.at(9.5).rtt_s < rs.at(4.0).rtt_s / 5   # and it recovers
