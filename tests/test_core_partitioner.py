"""Partitioner: DP-vs-exhaustive equivalence, cost-model calibration."""
import math

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (Block, BlockGraph, CostTable, best_latency,
                        best_throughput, dp_front_kway, evaluate_pipeline,
                        pareto_front, sweep_2way, sweep_kway)
from repro.core import scenarios
from repro.core.devices import DeviceProfile, Link


def rand_graph(draw):
    n = draw(st.integers(3, 10))
    blocks = tuple(
        Block(f"b{i}",
              flops=draw(st.floats(1e5, 1e9)),
              weight_bytes=draw(st.integers(100, 10**6)),
              out_bytes=draw(st.integers(100, 10**6)))
        for i in range(n))
    return BlockGraph("g", blocks, input_bytes=1000, output_bytes=100)


graphs = st.composite(rand_graph)()


@given(graphs, st.integers(2, 4))
@settings(max_examples=40, deadline=None)
def test_dp_front_matches_exhaustive(g, k):
    devs = tuple(DeviceProfile(f"d{i}", flops_per_s=1e9 * (i + 1),
                               mem_bytes=10**12) for i in range(k))
    links = tuple(Link(f"l{i}", rtt_s=1e-3, bw_bytes_per_s=1e8)
                  for i in range(k - 1))
    ex = pareto_front(sweep_kway(g, devs, links, batch=4))
    dp = dp_front_kway(g, devs, links, batch=4)
    ex_pts = sorted((round(p.latency_s, 10), round(p.throughput, 6))
                    for p in ex)
    dp_pts = sorted((round(p.latency_s, 10), round(p.throughput, 6))
                    for p in dp)
    assert ex_pts == dp_pts


@given(graphs)
@settings(max_examples=30, deadline=None)
def test_more_bandwidth_never_hurts(g):
    s = scenarios.pi_to_pi()
    slow = Link("slow", rtt_s=1e-3, bw_bytes_per_s=1e6)
    fast = Link("fast", rtt_s=1e-3, bw_bytes_per_s=1e9)
    for p in range(1, g.n_blocks):
        m_slow = evaluate_pipeline(g, (p,), s.devices, (slow,), batch=2)
        m_fast = evaluate_pipeline(g, (p,), s.devices, (fast,), batch=2)
        assert m_fast.latency_s <= m_slow.latency_s + 1e-12
        assert m_fast.throughput >= m_slow.throughput - 1e-9


def test_cost_table_overrides_analytic():
    g = BlockGraph("g", (Block("a", 1e9, 10, 10), Block("b", 1e9, 10, 10)),
                   input_bytes=10)
    s = scenarios.pi_to_pi()
    t = CostTable()
    t.set("pi4b", "a", 0.123)
    m = evaluate_pipeline(g, (1,), s.devices, s.links, batch=1, costs=t,
                          include_io=False)
    # stage 0 = measured; stage 1 = analytic 1e9 / 10e9 = 0.1 s + overhead
    assert math.isclose(m.stages[0].compute_s, 0.123 + 5e-3, rel_tol=1e-6)
    assert math.isclose(m.stages[1].compute_s, 0.1 + 5e-3, rel_tol=1e-6)


def test_paper_calibration_mobilenet_p3():
    """Table II: MobileNetV2 P3 → thr ≈ batch/(pi1_exe + net).  Our model
    must land in the paper's regime (seconds-scale, single-digit img/s)."""
    from repro.models.cnn import zoo
    g = zoo.get("mobilenetv2").block_graph()
    s = scenarios.pi_to_pi()
    pts = sweep_2way(g, s.devices, s.links[0], batch=8)
    thr = best_throughput(pts)
    assert 0.5 < thr.throughput < 50          # paper: 7.8 img/s
    lat = best_latency(pts)
    assert 0.05 < lat.latency_s < 20          # paper: ~2 s
    assert all(p.feasible for p in pts)


def test_duress_shifts_frontier():
    """Sec. V-B: under 200 ms / 5 Mbit/s the frontier must move to higher
    latency & lower throughput, and the min-transfer split must win."""
    from repro.models.cnn import zoo
    g = zoo.get("mobilenetv2").block_graph()
    base = scenarios.pi_to_pi()
    dur = scenarios.duress(base)
    pts_base = sweep_2way(g, base.devices, base.links[0], batch=8)
    pts_dur = sweep_2way(g, dur.devices, dur.links[0], batch=8)
    assert best_latency(pts_dur).latency_s > best_latency(pts_base).latency_s
    assert best_throughput(pts_dur).throughput < \
        best_throughput(pts_base).throughput
    # under duress the optimal split minimizes transferred bytes
    best_dur = best_throughput(pts_dur)
    cut_bytes = g.cut_bytes(best_dur.partition[0])
    median = sorted(g.cut_bytes(p) for p in range(1, g.n_blocks))[
        g.n_blocks // 2]
    assert cut_bytes <= median


def test_pi_to_gpu_offloads_aggressively():
    """Fig. 4: with a GPU as stage 2, the best split offloads early."""
    from repro.models.cnn import zoo
    g = zoo.get("mobilenetv2").block_graph()
    s = scenarios.pi_to_gpu()
    pts = sweep_2way(g, s.devices, s.links[0], batch=8)
    bt = best_throughput(pts)
    assert bt.partition[0] <= 3               # paper: P1
