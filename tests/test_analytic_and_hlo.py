"""Analytic cost model vs XLA ground truth (unrolled), HLO parser, and
roofline math."""
import jax
import jax.numpy as jnp
import pytest

import repro.configs as configs
from repro.launch.analytic import cell_cost, trunk_fwd_flops, _logit_flops
from repro.launch.hlo_analysis import parse_collectives, _shape_bytes
from repro.launch.roofline import (Roofline, model_flops, roofline_from,
                                   PEAK_FLOPS)
from repro.launch.specs import SHAPES, ShapeSpec


def test_analytic_fwd_flops_vs_xla_unrolled():
    """Unrolled 1-layer dense forward: XLA's cost_analysis is exact there;
    analytic must agree within 10% (elementwise conventions differ)."""
    from repro.models import lm
    from repro.models.common import InitBuilder
    cfg = configs.reduced("qwen3-1.7b").replace(
        n_layers=1, d_model=128, d_ff=256, vocab=512, head_dim=32,
        n_heads=4, n_kv_heads=2, attn_chunk=64, remat=False)
    params = lm.build_params(cfg, InitBuilder(jax.random.PRNGKey(0),
                                              jnp.float32))
    B, S = 2, 64
    tokens = jnp.zeros((B, S), jnp.int32)

    def fwd(p, t):
        logits, _ = lm.forward_train(cfg, p, {"tokens": t})
        return logits

    comp = jax.jit(fwd).lower(params, tokens).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, (list, tuple)):   # jax<=0.4: one dict per device
        ca = ca[0]
    xla_flops = float(ca["flops"])
    ctx = (S + 1) / 2  # S <= chunk → exact causal masking in one block,
    # but the single-block path COMPUTES the full S×S scores:
    ctx_computed = S
    analytic = (trunk_fwd_flops(cfg, B * S, ctx_computed)
                + _logit_flops(cfg, B * S))
    assert abs(analytic - xla_flops) / xla_flops < 0.10, \
        (analytic, xla_flops)


def test_model_flops_conventions():
    cfg = configs.get("qwen3-1.7b")
    t = model_flops(cfg, SHAPES["train_4k"])
    assert t == pytest.approx(6 * cfg.param_count() * 256 * 4096, rel=1e-6)
    d = model_flops(cfg, SHAPES["decode_32k"])
    assert d == pytest.approx(2 * cfg.param_count() * 128, rel=1e-6)
    moe = configs.get("qwen3-moe-30b-a3b")
    assert model_flops(moe, SHAPES["train_4k"]) == pytest.approx(
        6 * moe.active_param_count() * 256 * 4096, rel=1e-6)


def test_roofline_terms_and_dominance():
    rl = roofline_from(flops_per_dev=197e12, bytes_per_dev=819e9 / 2,
                       wire_ici_per_dev=0, wire_dcn_per_dev=0,
                       model_flops_total=197e12 * 0.5, n_chips=1)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(0.5)
    assert rl.dominant == "compute"
    assert rl.useful_ratio == pytest.approx(0.5)
    assert rl.mfu_bound == pytest.approx(0.5)


def test_hlo_shape_bytes():
    assert _shape_bytes("bf16[2,3,4]{2,1,0}") == 48
    assert _shape_bytes("(f32[10], bf16[4])") == 48
    assert _shape_bytes("pred[]") == 1          # scalar = one element


def test_hlo_collective_parsing():
    hlo = """
  %all-reduce = f32[1024]{0} all-reduce(%x), replica_groups=[4,2]<=[8], to_apply=%add
  %ag = bf16[8,128]{1,0} all-gather(%p), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %cp = bf16[64]{0} collective-permute(%y), source_target_pairs={{0,4},{1,5}}
"""
    s = parse_collectives(hlo, pod_size=4)
    kinds = s.by_kind()
    assert kinds["all-reduce"]["count"] == 1
    assert kinds["all-gather"]["count"] == 1
    assert kinds["collective-permute"]["count"] == 1
    # the permute pairs cross pods of size 4 → DCN
    assert s.wire_bytes_dcn >= 128
    # all-reduce of 4096 B in groups of 2 → 2·T·(s-1)/s = 4096
    ar = [o for o in s.ops if o.kind == "all-reduce"][0]
    assert ar.wire_bytes == 4096


def test_cell_cost_sane_magnitudes():
    """Napkin cross-checks: granite-20b train_4k ≈ 6·N·D·(4/3) trunk-ish."""
    cfg = configs.get("granite-20b")
    c = cell_cost(cfg, SHAPES["train_4k"], n_chips=256, dp=16, tp=16,
                  multi_pod=False)
    model = 6 * cfg.param_count() * 256 * 4096
    # remat adds ~1/3; attention + CE chunking add more
    assert model < c.flops_total < 2.6 * model
    # decode is memory-bound: per-dev bytes dominated by weights+cache
    d = cell_cost(cfg, SHAPES["decode_32k"], n_chips=256, dp=16, tp=16,
                  multi_pod=False)
    assert d.hbm_bytes_per_dev > cfg.param_count() * 2 / 16


def test_cell_supported_long_context_rules():
    from repro.launch.specs import cell_supported
    ok, _ = cell_supported(configs.get("falcon-mamba-7b"), "long_500k")
    assert ok
    ok, why = cell_supported(configs.get("granite-20b"), "long_500k")
    assert not ok and "sub-quadratic" in why
