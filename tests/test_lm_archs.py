"""Per-architecture smoke tests (assignment requirement): a REDUCED
same-family config runs one forward/train step on CPU with correct
output shapes and no NaNs.  The FULL configs are exercised only via the
dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow          # one jitted train step per arch

import repro.configs as configs
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import lm
from repro.models.common import InitBuilder
from repro.optim import OptConfig
from repro.runtime.steps import init_train_state, make_train_step


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_reduced_forward_shapes_and_finite(name):
    cfg = configs.reduced(name)
    params = lm.build_params(cfg, InitBuilder(jax.random.PRNGKey(0),
                                              jnp.float32))
    data = SyntheticLM(cfg, DataConfig(batch=2, seq=32))
    inputs = {k: v for k, v in next(data).items() if k != "targets"}
    logits, aux = lm.forward_train(cfg, params, inputs)
    S = 32
    assert logits.shape == (2, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_reduced_train_step(name):
    cfg = configs.reduced(name)
    state = init_train_state(cfg, jax.random.PRNGKey(0), OptConfig())
    data = SyntheticLM(cfg, DataConfig(batch=2, seq=32))
    step = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3)))
    state, m = step(state, next(data))
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    assert int(state["step"]) == 1


def test_full_configs_match_published_param_counts():
    """Analytic N vs published totals (±12% — publications round and our
    whisper/zamba variants simplify positional/LoRA details)."""
    published = {
        "phi-3-vision-4.2b": 3.8e9,       # backbone (phi3-mini) only
        "falcon-mamba-7b": 7.3e9,
        "starcoder2-3b": 3.0e9,
        "qwen3-1.7b": 1.7e9,
        "granite-20b": 20e9,
        "starcoder2-7b": 7.2e9,
        "whisper-small": 0.244e9,
        "qwen3-moe-30b-a3b": 30.5e9,
        "phi3.5-moe-42b-a6.6b": 42e9,
        "zamba2-7b": 7.4e9,
    }
    # zamba2 omits the published per-application LoRA deltas (DESIGN.md §4)
    loose = {"zamba2-7b": 0.12, "whisper-small": 0.12}
    for name, target in published.items():
        n = configs.get(name).param_count()
        tol = loose.get(name, 0.07)
        assert abs(n - target) / target < tol, (name, f"{n:,}", target)


def test_moe_active_params():
    qwen = configs.get("qwen3-moe-30b-a3b")
    assert 2.5e9 < qwen.active_param_count() < 4.0e9      # "a3b"
    phi = configs.get("phi3.5-moe-42b-a6.6b")
    assert 5.5e9 < phi.active_param_count() < 7.7e9       # "a6.6b"


def test_long_context_support_flags():
    assert configs.get("falcon-mamba-7b").supports_long_context
    assert configs.get("zamba2-7b").supports_long_context
    for name in ("qwen3-1.7b", "granite-20b", "whisper-small",
                 "phi-3-vision-4.2b", "qwen3-moe-30b-a3b"):
        assert not configs.get(name).supports_long_context
