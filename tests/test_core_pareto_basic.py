"""Pareto machinery edge cases — deterministic, no hypothesis needed
(the property suite in test_core_pareto.py skips when hypothesis is
absent; this file keeps the degenerate paths covered regardless)."""
import pytest

from repro.core import dominates, hypervolume, is_on_front, knee_point, pareto_front


def test_pareto_front_empty():
    assert pareto_front([]) == []
    assert knee_point([]) is None
    assert hypervolume([], ref_latency=10.0) == 0.0


def test_pareto_front_single_point():
    assert pareto_front([(1.0, 2.0)]) == [(1.0, 2.0)]
    assert knee_point([(1.0, 2.0)]) == (1.0, 2.0)


def test_pareto_front_duplicates_keep_one():
    pts = [(1.0, 2.0), (1.0, 2.0), (1.0, 2.0)]
    assert pareto_front(pts) == [(1.0, 2.0)]


def test_knee_point_degenerate_all_equal():
    """All-equal fronts have zero spread on both axes — the knee must
    still return a member, not divide by zero."""
    pts = [(3.0, 5.0)] * 4
    assert knee_point(pts) == (3.0, 5.0)


def test_knee_point_degenerate_one_axis():
    # same latency, varying throughput: front collapses to the best-thr point
    pts = [(1.0, 1.0), (1.0, 2.0), (1.0, 3.0)]
    assert knee_point(pts) == (1.0, 3.0)


def test_hypervolume_invalid_reference_raises():
    # every point outside the reference box = a mis-specified reference;
    # the old behavior silently returned 0.0, now it raises
    with pytest.raises(ValueError, match="invalid reference box"):
        hypervolume([(2.0, 5.0)], ref_latency=1.0)


def test_hypervolume_reference_better_on_max_axis_raises():
    # throughput reference at/above every point = ref not worse on a
    # max-axis: invalid box
    with pytest.raises(ValueError, match="invalid reference box"):
        hypervolume([(0.5, 1.0)], ref_latency=1.0, ref_throughput=2.0)


def test_hypervolume_mixed_inside_outside():
    inside = (0.5, 3.0)          # contributes (1.0-0.5)*(3.0-1.0) = 1.0
    outside = (5.0, 10.0)        # latency past the reference: nothing
    hv = hypervolume([inside, outside], ref_latency=1.0, ref_throughput=1.0)
    assert hv == pytest.approx(1.0)


def test_hypervolume_known_value():
    pts = [(1.0, 1.0), (2.0, 2.0)]
    # sweep from ref 3.0: (3-2)*2 + (2-1)*1 = 3
    assert hypervolume(pts, ref_latency=3.0) == pytest.approx(3.0)


def test_hypervolume_legacy_positional_forms():
    pts = [(1.0, 3.0), (2.0, 4.0)]
    # (points, ref_latency): thr reference defaults to 0
    assert hypervolume(pts, 3.0) == pytest.approx(
        hypervolume(pts, ref_latency=3.0))
    # (points, ref_latency, ref_throughput): the old fully-positional call
    assert hypervolume(pts, 3.0, 1.0) == pytest.approx(
        hypervolume(pts, ref_latency=3.0, ref_throughput=1.0))


def test_dominates_and_is_on_front():
    a, b, c = (1.0, 5.0), (2.0, 4.0), (1.0, 5.0)
    assert dominates(a, b)
    assert not dominates(b, a)
    assert not dominates(a, c)       # equal points never dominate
    assert is_on_front(a, [a, b, c])
    assert not is_on_front(b, [a, b])
