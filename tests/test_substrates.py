"""Substrates: optimizer, data determinism, checkpointing, compression."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# only the error-feedback property test needs hypothesis; the optimizer/
# data/checkpoint tests below are deterministic and must run regardless
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import repro.configs as configs
from repro.checkpoint import (CheckpointManager, load_checkpoint,
                              save_checkpoint)
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import (CompressionConfig, OptConfig, apply_gradients,
                         compress_gradients, cosine_schedule,
                         init_error_state, init_opt_state, global_norm)


# --------------------------------------------------------------------------- #
# Optimizer
# --------------------------------------------------------------------------- #
def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = init_opt_state(params)
    cfg = OptConfig(lr=0.2, weight_decay=0.0, clip_norm=0.0)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, state, _ = apply_gradients(params, g, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clip_caps_update():
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params)
    cfg = OptConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    _, _, m = apply_gradients(params, {"w": jnp.full(4, 100.0)}, state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_cosine_schedule_shape():
    fn = cosine_schedule(1e-3, warmup=10, total=100, floor=0.1)
    assert float(fn(jnp.int32(0))) == 0.0
    assert float(fn(jnp.int32(10))) == pytest.approx(1e-3)
    assert float(fn(jnp.int32(100))) == pytest.approx(1e-4, rel=1e-2)
    assert float(fn(jnp.int32(5))) == pytest.approx(5e-4)


# --------------------------------------------------------------------------- #
# Gradient compression (error feedback)
# --------------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:
    @given(st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_error_feedback_is_lossless_in_sum(seed):
        """Σ_t (compressed_t) + err_T == Σ_t raw_t — error feedback never
        loses mass, only delays it."""
        key = jax.random.PRNGKey(seed)
        cfg = CompressionConfig(enabled=True)
        g_sum = np.zeros(16, np.float64)
        c_sum = np.zeros(16, np.float64)
        err = {"w": jnp.zeros(16)}
        for t in range(5):
            g = {"w": jax.random.normal(jax.random.fold_in(key, t), (16,))}
            g_sum += np.asarray(g["w"], np.float64)
            cg, err = compress_gradients(g, err, cfg)
            c_sum += np.asarray(cg["w"], np.float64)
        np.testing.assert_allclose(c_sum + np.asarray(err["w"], np.float64),
                                   g_sum, rtol=1e-5, atol=1e-5)


def test_compressed_training_converges():
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params)
    err = init_error_state(params)
    ccfg = CompressionConfig(enabled=True)
    ocfg = OptConfig(lr=0.2, weight_decay=0.0, clip_norm=0.0)
    for _ in range(300):
        g = {"w": 2 * params["w"]}
        g, err = compress_gradients(g, err, ccfg)
        params, state, _ = apply_gradients(params, g, state, ocfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 5e-2


# --------------------------------------------------------------------------- #
# Data determinism
# --------------------------------------------------------------------------- #
def test_data_resume_bit_exact():
    cfg = configs.reduced("qwen3-1.7b")
    a = SyntheticLM(cfg, DataConfig(batch=2, seq=16, seed=3))
    batches = [next(a) for _ in range(5)]
    b = SyntheticLM(cfg, DataConfig(batch=2, seq=16, seed=3))
    b.load_state_dict({"step": 3, "seed": 3})
    resumed = next(b)
    for k in batches[3]:
        assert jnp.array_equal(batches[3][k], resumed[k]), k


# --------------------------------------------------------------------------- #
# Checkpointing
# --------------------------------------------------------------------------- #
def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"m": {"w": jnp.ones((2, 3))},
                     "count": jnp.int32(7)},
             "step": jnp.int32(7)}
    save_checkpoint(tmp_path / "c", state, 7, extra={"data": {"step": 7}})
    loaded, manifest = load_checkpoint(tmp_path / "c")
    assert manifest["step"] == 7
    assert manifest["extra"]["data"]["step"] == 7
    np.testing.assert_array_equal(np.asarray(loaded["params"]["w"]),
                                  np.arange(6.0).reshape(2, 3))
    assert int(loaded["opt"]["count"]) == 7


def test_manager_cadence_retention_async(tmp_path):
    mgr = CheckpointManager(tmp_path, every=10, keep=2)
    assert not mgr.should_save(5) and mgr.should_save(10)
    state = {"w": jnp.zeros(4)}
    for step in (10, 20, 30):
        mgr.save(state, step, block=False)
    mgr.wait()
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert kept == ["step_00000020", "step_00000030"]
    restored, manifest = mgr.restore()
    assert manifest["step"] == 30


def test_manager_ignores_and_gcs_torn_tmp_dirs(tmp_path):
    """A crash mid-async-write leaves step_*.tmp (no manifest): restore
    must never pick it — even though it sorts after its own step — and
    the next save's GC must clean it up."""
    mgr = CheckpointManager(tmp_path, every=1, keep=2)
    mgr.save({"w": jnp.zeros(2)}, 5)
    torn = tmp_path / "step_00000005.tmp"
    torn.mkdir()                       # simulated torn write
    assert mgr.latest().name == "step_00000005"
    mgr.save({"w": jnp.ones(2)}, 6)
    assert not torn.exists()
    _, manifest = mgr.restore()
    assert manifest["step"] == 6


def test_elastic_reshard_pipeline_layout(tmp_path):
    """Save canonical (L, ...) layers; restore repacked for a different
    pipeline cut — the elastic path."""
    from repro.runtime.pipeline import (PipelineConfig, repack_params,
                                        unpack_params)
    layers = {"w": jnp.arange(6 * 4, dtype=jnp.float32).reshape(6, 4)}
    save_checkpoint(tmp_path / "c", {"layers": layers}, 1)
    loaded, _ = load_checkpoint(tmp_path / "c")
    for cuts in [(2,), (1, 3)]:
        pcfg = PipelineConfig(len(cuts) + 1, 2, cuts)
        packed = repack_params(loaded["layers"], pcfg, 6)
        back = unpack_params(packed, pcfg, 6)
        np.testing.assert_array_equal(np.asarray(back["w"]),
                                      np.asarray(layers["w"]))


def test_crash_restart_bit_exact(tmp_path):
    """End-to-end: train, checkpoint, 'crash', resume — losses identical
    to an uninterrupted run."""
    from repro.optim import OptConfig
    from repro.runtime.steps import init_train_state, make_train_step
    cfg = configs.reduced("qwen3-1.7b").replace(n_layers=1, d_model=32,
                                                vocab=64, d_ff=64)
    data_cfg = DataConfig(batch=2, seq=16, seed=1)
    opt = OptConfig(lr=1e-3)
    step_fn = jax.jit(make_train_step(cfg, opt))

    def run(n_steps, state=None, start=0):
        if state is None:
            state = init_train_state(cfg, jax.random.PRNGKey(0), opt)
        data = SyntheticLM(cfg, data_cfg)
        losses = []
        for s in range(start, n_steps):
            state, m = step_fn(state, data.batch_at(s))
            losses.append(float(m["loss"]))
        return state, losses

    _, ref_losses = run(8)
    state, _ = run(4)
    save_checkpoint(tmp_path / "c", state, 4, extra={"data": {"step": 4,
                                                              "seed": 1}})
    loaded, manifest = load_checkpoint(tmp_path / "c")
    loaded = jax.tree.map(jnp.asarray, loaded)
    _, resumed_losses = run(8, state=loaded, start=manifest["step"])
    np.testing.assert_allclose(resumed_losses, ref_losses[4:], rtol=1e-6)
