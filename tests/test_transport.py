"""Pluggable Transport API: emulated / socket / shmem parity, measured
TransferRecords, failure propagation, the LSQ link fit, and the trace
recorder.

The acceptance surface of the transport redesign: the same model + cuts
+ scenario must produce identical outputs and sane metrics whether the
hops are modeled sleeps between threads or real TCP / shared-memory
channels between OS processes — and the measured records must drive the
closed adaptive loop to a migration.
"""
import time

import jax
import numpy as np
import pytest

from repro.core import Scenario, scenarios
from repro.core.autosplit import LinkEstimator
from repro.core.devices import DURESS, LOOPBACK, DeviceProfile, Link
from repro.models.cnn import zoo
from repro.runtime.adaptive import AdaptiveRuntime
from repro.runtime.edge import EdgePipeline
from repro.runtime.transport import (BATCH, PROBE, HopSpec, ShmemChannel,
                                     SocketChannel, TransferRecord,
                                     TransportError, get_transport,
                                     record_trace)


@pytest.fixture(scope="module")
def mobilenet():
    m = zoo.get("mobilenetv2")
    return m, m.init(jax.random.PRNGKey(0))


def _x(batch=2, hw=32):
    return jax.random.normal(jax.random.PRNGKey(1), (batch, hw, hw, 3))


# --------------------------------------------------------------------------- #
# Channel level: wire format, records, slot growth (in-process, cheap)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["socket", "shmem"])
def test_channel_roundtrip_and_records(name):
    chan = get_transport(name).open(HopSpec(index=0, link=LOOPBACK))
    try:
        x = np.arange(24, dtype=np.float32).reshape(2, 3, 4)
        chan.send(x, kind=BATCH)
        kind, y = chan.recv(timeout=5.0)
        assert kind == BATCH and np.array_equal(x, y)   # raw bytes: exact
        chan.send(kind=PROBE)
        kind, _ = chan.recv(timeout=5.0)
        assert kind == PROBE
        recs = chan.drain_records()
        assert len(recs) == 2
        assert recs[0].nbytes == x.nbytes and recs[0].elapsed_s > 0
        assert recs[1].nbytes == 0                      # header-only probe
        assert chan.drain_records() == []               # drained
        assert chan.total_bytes == x.nbytes             # lifetime counter
    finally:
        chan.close()


def test_channel_pickle_framing_roundtrip():
    hop = HopSpec(index=0, link=LOOPBACK, framing="pickle")
    chan = get_transport("socket").open(hop)
    try:
        x = np.ones((4, 5), dtype=np.float32)
        chan.send(x, kind=BATCH)
        _, y = chan.recv(timeout=5.0)
        assert np.array_equal(x, y)
        (rec,) = chan.drain_records()
        assert rec.nbytes > x.nbytes                    # pickle framing pays
    finally:
        chan.close()


def test_shmem_slot_growth():
    chan = get_transport("shmem").open(HopSpec(index=0, link=LOOPBACK))
    try:
        small = np.zeros(16, dtype=np.float32)
        big = np.zeros(1 << 18, dtype=np.float32)       # > initial 64 KiB slot
        for payload in (small, big, small, big):
            chan.send(payload, kind=BATCH)
            _, y = chan.recv(timeout=5.0)
            assert y.nbytes == payload.nbytes
    finally:
        chan.close()


def test_unknown_transport_rejected():
    with pytest.raises(KeyError, match="unknown transport"):
        get_transport("carrier-pigeon")


# --------------------------------------------------------------------------- #
# Scenario-level transport declarations
# --------------------------------------------------------------------------- #
def test_scenario_transports_declared_and_validated():
    scen = scenarios.get("pi_pi_gpu").with_transport("socket")
    assert scen.transports == ("socket", "socket")
    # preserved through link surgery and snapshots
    assert scen.with_link(0, DURESS).transports == ("socket", "socket")
    assert scen.at(0.0).transports == ("socket", "socket")
    with pytest.raises(ValueError, match="one transport per link"):
        Scenario("bad", scen.devices, scen.links, transports=("socket",))
    assert scenarios.get("local3_socket").transports == ("socket", "socket")
    assert scenarios.get("pi_pi_gpu_socket").n_stages == 3


def test_mixed_emulated_and_process_transports_rejected(mobilenet):
    m, params = mobilenet
    with pytest.raises(ValueError, match="mix"):
        EdgePipeline(m, params, (5, 12), scenarios.get("pi_pi_gpu"),
                     transport=("emulated", "socket"))


# --------------------------------------------------------------------------- #
# Transport parity: same model + cuts + scenario across all three backends
# --------------------------------------------------------------------------- #
def test_transport_parity(mobilenet):
    m, params = mobilenet
    scen = scenarios.get("pi_pi_gpu")
    x = _x()
    ref = np.asarray(m.apply(params, x))
    outs, results = {}, {}
    for name in ("emulated", "socket", "shmem"):
        with EdgePipeline(m, params, (5, 12), scen, transport=name) as pipe:
            assert pipe.transport == name
            pipe.warmup(x)
            y, lat, hops = pipe.run_one(x)
            assert lat > 0 and len(hops) == 2 and all(h > 0 for h in hops)
            outs[name] = np.asarray(y)
            res = pipe.measure(lambda: x, n_batches=4)
            results[name] = res
            assert res.transport == name
            assert res.partition == (5, 12)
            assert res.throughput > 0 and res.latency_s > 0
            assert len(res.stage_exe_s) == 3 and len(res.hop_net_s) == 2
            # per-worker CPU accounting (not one host-wide broadcast);
            # tiny stages can read 0 where the CPU clock is coarse, but
            # the readings must be per-stage, not one broadcast value
            assert len(res.cpu_pct) == 3 and all(c >= 0 for c in res.cpu_pct)
            assert len(set(res.cpu_pct)) > 1 and max(res.cpu_pct) > 0
            # raw framing moves exactly the activation bytes on every hop
            assert pipe.nets[0].total_bytes % (x.shape[0] * 4) == 0
    # identical outputs across modeled and measured hops
    assert np.allclose(outs["emulated"], ref, atol=1e-5)
    for name in ("socket", "shmem"):
        assert np.allclose(outs[name], outs["emulated"], rtol=0, atol=1e-6), \
            f"{name} diverged from emulated"
    # emulated is deterministic: a second thread-backed run is bit-identical
    pipe = EdgePipeline(m, params, (5, 12), scen)
    y2, _, _ = pipe.run_one(x)
    assert np.array_equal(outs["emulated"], np.asarray(y2))


def test_socket_pipeline_migrates_and_records(mobilenet):
    """A 3-stage pipeline across real OS processes: live RECONFIG keeps
    outputs correct, probes give nbytes=0 RTT samples, and every hop's
    TransferRecords are measured wall-clock."""
    m, params = mobilenet
    x = _x()
    ref = np.asarray(m.apply(params, x))
    with EdgePipeline(m, params, (5, 12), scenarios.get("pi_pi_gpu"),
                      transport="socket") as pipe:
        pipe.warmup(x)
        pipe.run_one(x)
        for net in pipe.nets:
            (rec,) = [r for r in net.drain_observations() if r.nbytes > 0]
            assert rec.elapsed_s > 0 and rec.nbytes > 0
        pipe.probe()
        for net in pipe.nets:
            probes = [r for r in net.drain_observations() if r.nbytes == 0]
            assert len(probes) == 1 and probes[0].elapsed_s > 0
        pipe.migrate((3, 17), cost_s=0.0)
        assert pipe.cuts == (3, 17)
        y, _, _ = pipe.run_one(x)
        assert np.allclose(ref, y, atol=1e-5)
        assert len(pipe.migrations) == 1


def test_linktrace_rejected_on_process_transports(mobilenet):
    """A measured channel cannot replay a schedule: a LinkTrace hop
    under socket/shmem must be rejected loudly, not silently ignored."""
    m, params = mobilenet
    with pytest.raises(ValueError, match="LinkTrace"):
        EdgePipeline(m, params, (5, 12),
                     scenarios.get("pi_pi_gpu_wan_ramp"), transport="socket")


@pytest.mark.parametrize("transport", ["socket", "shmem"])
def test_worker_process_death_raises_not_hangs(mobilenet, transport):
    """A worker process dying mid-stream must surface as TransportError
    within the liveness window, not hang the orchestrator — on the
    socket path (EOF + liveness) and the shmem path (no EOF: liveness
    polling and the bounded slot wait are all there is)."""
    m, params = mobilenet
    x = _x()
    pipe = EdgePipeline(m, params, (5, 12), scenarios.get("pi_pi_gpu"),
                        transport=transport)
    try:
        pipe.warmup(x)
        pipe._engine._procs[1].terminate()
        pipe._engine._procs[1].join(5.0)
        t0 = time.perf_counter()
        with pytest.raises(TransportError, match="died|closed|gone"):
            pipe.stream(x, n_batches=6)
        assert time.perf_counter() - t0 < 30.0
    finally:
        pipe.close()


def test_adaptive_loop_closes_over_measured_socket_costs(mobilenet):
    """Acceptance: nominal planning says every hop is under duress; the
    *measured* loopback TransferRecords say otherwise, and the closed
    loop migrates the cut vector on real worker processes."""
    m, params = mobilenet
    x = _x()
    scen = (scenarios.get("pi_pi_gpu").with_link(0, DURESS)
            .with_link(1, DURESS).with_transport("socket"))
    with AdaptiveRuntime(m, params, scen, graph=m.block_graph(input_hw=32),
                         batch=x.shape[0], policy="throughput",
                         check_every=2, migration_cost_s=0.01,
                         alpha=0.8) as rt:
        recs = rt.run(lambda: x, n_batches=10)
        assert len(recs) == 10
        assert any(r.migrated for r in recs)
        assert len(rt.pipe.migrations) >= 1
        # estimates moved off the duress prior toward the measured wire
        assert rt.estimators[0].rtt_s < DURESS.rtt_s / 2
        assert rt.estimators[0].bw_bytes_per_s > DURESS.bw_bytes_per_s
        # and outputs stay correct on the migrated process pipeline
        y, _, _ = rt.pipe.run_one(x)
        assert np.allclose(np.asarray(m.apply(params, x)), y, atol=1e-5)


# --------------------------------------------------------------------------- #
# LinkEstimator: joint (rtt, overhead, bw) least-squares fit
# --------------------------------------------------------------------------- #
def test_estimator_joint_fit_recovers_overhead_and_bw():
    truth = Link("truth", rtt_s=20e-3, bw_bytes_per_s=1e8,
                 per_msg_overhead_s=2e-3)
    est = LinkEstimator(rtt_s=1e-3, bw_bytes_per_s=1e9, alpha=0.5)
    naive = LinkEstimator(rtt_s=1e-3, bw_bytes_per_s=1e9, alpha=0.5,
                          min_fit_samples=10**9)   # EWMA fallback forever
    sizes = [1e4, 1e5, 1e6]
    for _ in range(15):
        est.observe(0, truth.rtt_s, is_rtt_probe=True)
        naive.observe(0, truth.rtt_s, is_rtt_probe=True)
        for n in sizes:
            est.observe(n, truth.transfer_time(n))
            naive.observe(n, truth.transfer_time(n))
    assert est.rtt_s == pytest.approx(truth.rtt_s, rel=0.05)
    assert est.bw_bytes_per_s == pytest.approx(truth.bw_bytes_per_s, rel=0.15)
    assert est.per_msg_overhead_s == pytest.approx(truth.per_msg_overhead_s,
                                                   rel=0.35)
    # the EWMA mis-attributes the fixed per-message cost of the small
    # transfers to bandwidth; the joint fit must be strictly closer
    assert (abs(est.bw_bytes_per_s - truth.bw_bytes_per_s)
            < abs(naive.bw_bytes_per_s - truth.bw_bytes_per_s))
    link = est.as_link()
    assert link.per_msg_overhead_s == pytest.approx(est.per_msg_overhead_s)


def test_estimator_single_size_falls_back_to_ewma():
    est = LinkEstimator(rtt_s=DURESS.rtt_s, bw_bytes_per_s=1e9, alpha=0.5)
    for _ in range(30):
        est.observe(1e6, DURESS.transfer_time(1e6))
    assert est.bw_bytes_per_s < 3 * DURESS.bw_bytes_per_s


# --------------------------------------------------------------------------- #
# Trace recorder: measured records → replayable LinkTrace
# --------------------------------------------------------------------------- #
def _synth_records(link: Link, t0: float, t1: float, n: int = 12):
    recs, sizes = [], [1e4, 1e5, 1e6]
    for i in range(n):
        t = t0 + (t1 - t0) * i / max(n - 1, 1)
        if i % 4 == 0:
            recs.append(TransferRecord(0, link.rtt_s / 2.0, t))
        else:
            nb = sizes[i % len(sizes)]
            recs.append(TransferRecord(int(nb), link.transfer_time(nb), t))
    return recs


def test_record_trace_recovers_two_phase_link():
    fast = Link("fast", rtt_s=2e-3, bw_bytes_per_s=1e8,
                per_msg_overhead_s=0.5e-3)
    slow = Link("slow", rtt_s=100e-3, bw_bytes_per_s=1e6,
                per_msg_overhead_s=0.5e-3)
    recs = _synth_records(fast, 0.0, 4.0) + _synth_records(slow, 5.0, 9.0)
    trace = record_trace(recs, name="measured", bucket_s=5.0)
    early, late = trace.at(1.0), trace.at(8.0)
    assert early.rtt_s == pytest.approx(fast.rtt_s, rel=0.1)
    assert early.bw_bytes_per_s == pytest.approx(fast.bw_bytes_per_s, rel=0.3)
    assert late.rtt_s == pytest.approx(slow.rtt_s, rel=0.1)
    assert late.bw_bytes_per_s == pytest.approx(slow.bw_bytes_per_s, rel=0.3)
    # replayable: a scenario can carry the recorded trace on a hop
    scen = scenarios.get("pi_to_gpu").with_link(0, trace)
    assert scen.time_varying and scen.at(8.0).links[0].rtt_s > early.rtt_s


def test_record_trace_from_real_channel():
    chan = get_transport("socket").open(HopSpec(index=0, link=LOOPBACK))
    try:
        for nb in (10_000, 200_000, 10_000, 200_000, 1_000_000):
            chan.send(np.zeros(nb // 4, dtype=np.float32), kind=BATCH)
            chan.recv(timeout=5.0)
        chan.send(kind=PROBE)
        chan.recv(timeout=5.0)
        trace = record_trace(chan, name="loopback_measured", bucket_s=60.0)
    finally:
        chan.close()
    snap = trace.at(0.0)
    assert snap.bw_bytes_per_s > 0 and snap.rtt_s >= 0
    assert trace.transfer_time(1e6) > 0


def test_record_trace_rejects_empty():
    with pytest.raises(ValueError, match="no records"):
        record_trace([])
