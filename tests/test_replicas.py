"""Replicated bottleneck stages (data-parallel fan-out inside the
pipeline): the solver's replica label cross-validated against exhaustive
sweeps, the migration-cost multiplier, the doorbell/multi-producer-ring
transport layer, and the fan-in ordering matrix on real worker
processes."""
import numpy as np
import pytest

from repro.core import scenarios
from repro.core.autosplit import AdaptiveSplitter
from repro.core.blocks import Block, BlockGraph
from repro.core.costmodel import evaluate_pipeline
from repro.core.devices import LAN_PI_GPU, DeviceProfile, Link
from repro.core.partitioner import (best_throughput, dp_front_kway,
                                    replicas_feasible, solve, sweep_kway,
                                    sweep_replicas)
from repro.core.scenarios import Scenario


# --------------------------------------------------------------------------- #
# Fixtures: a bottleneck-heavy toy chain
# --------------------------------------------------------------------------- #
def _graph():
    # front blocks are 10x heavier: the solver should staff them first
    blocks = tuple(Block(f"b{i}", flops=(1e9 if i < 4 else 1e8),
                         weight_bytes=1_000_000,
                         out_bytes=50_000 * (6 - i)) for i in range(6))
    return BlockGraph("toy", blocks, input_bytes=300_000, output_bytes=100)


def _chain(k=3):
    devs = tuple(DeviceProfile(f"d{i}", flops_per_s=1e9, mem_bytes=10**12,
                               idle_w=1.0, active_w=5.0) for i in range(k))
    link = Link("l0", rtt_s=1e-3, bw_bytes_per_s=1e8, energy_per_byte_j=1e-6)
    return devs, (link,) * (k - 1)


def _scenario(k=3, spares=()):
    devs, links = _chain(k)
    return Scenario("toy", devs, links, spare_devices=tuple(spares))


# --------------------------------------------------------------------------- #
# Cost model: the replica label
# --------------------------------------------------------------------------- #
def test_bottleneck_divides_by_replicas_latency_does_not():
    g = _graph()
    devs, links = _chain(3)
    base = evaluate_pipeline(g, (2, 4), devs, links, batch=2)
    rep = evaluate_pipeline(g, (2, 4), devs, links, batch=2,
                            replicas=(2, 1, 1))
    # stage 0 was the bottleneck: its cycle halves, others unchanged
    s0, r0 = base.stages[0], rep.stages[0]
    assert r0.replicas == 2
    cycle0 = (s0.compute_s + s0.send_s) / 2
    others = [(s.compute_s + s.send_s) for s in base.stages[1:]]
    # last-stage return IO stays serial; reconstruct it from the totals
    assert rep.bottleneck_s <= base.bottleneck_s
    assert rep.throughput >= base.throughput
    assert cycle0 <= rep.bottleneck_s + 1e-12
    assert max(others) <= rep.bottleneck_s * 2 + 1e-12
    # one batch still traverses exactly one replica
    assert rep.latency_s == pytest.approx(base.latency_s)


def test_replication_charges_extra_idle_energy():
    g = _graph()
    devs, links = _chain(3)
    base = evaluate_pipeline(g, (2, 4), devs, links, batch=2)
    rep = evaluate_pipeline(g, (2, 4), devs, links, batch=2,
                            replicas=(3, 1, 1))
    s0 = base.stages[0]
    extra = (3 - 1) * devs[0].idle_w * (s0.compute_s + s0.send_s) / 3
    assert rep.energy_j == pytest.approx(base.energy_j + extra)
    assert rep.replicas == (3, 1, 1)
    assert base.replicas == ()


def test_invalid_replica_vectors_raise():
    g = _graph()
    devs, links = _chain(3)
    with pytest.raises(ValueError):
        evaluate_pipeline(g, (2, 4), devs, links, replicas=(2, 1))
    with pytest.raises(ValueError):
        evaluate_pipeline(g, (2, 4), devs, links, replicas=(0, 1, 1))
    with pytest.raises(ValueError):
        solve(g, _scenario(), replicas="bogus")


# --------------------------------------------------------------------------- #
# Solver: replicated DP label vs exhaustive enumeration
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("reps", [None, (2, 1, 1), (1, 2, 1), (2, 2, 1),
                                  (1, 1, 3)])
def test_dp_front_matches_exhaustive_sweep(reps):
    """The monotone d-dimensional DP label must reproduce brute force's
    best points under any fixed replica vector."""
    g = _graph()
    devs, links = _chain(3)
    objectives = ("latency", "throughput", "energy")
    sweep = sweep_kway(g, devs, links, batch=2, replicas=reps)
    front = dp_front_kway(g, devs, links, batch=2, replicas=reps,
                          objectives=objectives)
    assert front, "empty DP front"
    for key in ("latency_s", "energy_j"):
        assert min(getattr(p, key) for p in front) == pytest.approx(
            min(getattr(p, key) for p in sweep))
    assert max(p.throughput for p in front) == pytest.approx(
        max(p.throughput for p in sweep))
    for p in front:
        assert p.replicas == (reps if reps is not None else ())


@pytest.mark.parametrize("n_spares", [1, 2])
def test_auto_replica_search_matches_exhaustive(n_spares):
    """Greedy ``solve(replicas='auto')`` must find the same best
    steady-state throughput as the exhaustive assignment sweep."""
    g = _graph()
    # staff spares that match the first two devices' profile names
    devs, links = _chain(3)
    scen = Scenario("toy", devs, links,
                    spare_devices=(devs[0],) * n_spares + (devs[1],))
    auto = solve(g, scen, batch=2, replicas="auto")
    exhaustive = sweep_replicas(g, scen, batch=2)
    got = best_throughput(auto)
    want = best_throughput(exhaustive)
    assert got.throughput == pytest.approx(want.throughput)
    assert got.replicas == want.replicas
    # replication must actually have been used, and used on the heavy
    # front stages
    assert any(r > 1 for r in got.replicas)
    # the unreplicated baseline stays in the pool for latency picks
    assert any(p.replicas in ((), (1,) * 3) for p in auto)


def test_fixed_replicas_flow_through_solve():
    g = _graph()
    scen = _scenario(3)
    pts = solve(g, scen, batch=2, replicas=(2, 1, 1))
    assert pts and all(p.replicas == (2, 1, 1) for p in pts)
    base = solve(g, scen, batch=2)
    assert (best_throughput(pts).throughput
            > best_throughput(base).throughput)


def test_replicas_feasible_counts_spares_by_name():
    devs, _ = _chain(3)
    spares = (devs[0], devs[0], devs[2])
    assert replicas_feasible((1, 1, 1), devs, spares)
    assert replicas_feasible((3, 1, 2), devs, spares)
    assert not replicas_feasible((4, 1, 1), devs, spares)
    assert not replicas_feasible((1, 2, 1), devs, spares)


def test_pi_cluster_scenarios_registered():
    for name, n_spares in (("pi_cluster4", 1), ("pi_cluster5", 2)):
        scen = scenarios.get(name)
        assert scen.name == name
        assert len(scen.spare_devices) == n_spares
        # spares replicate the Pi tier, not the GPU
        assert all(s.name == scen.devices[0].name
                   for s in scen.spare_devices)
        pts = solve(_graph(), scen, batch=2, replicas="auto")
        assert pts


# --------------------------------------------------------------------------- #
# Migration cost: weight bytes ship once per replica of the destination
# --------------------------------------------------------------------------- #
def test_migration_bytes_scale_with_destination_replicas():
    blocks = tuple(Block(f"b{i}", flops=1e7, weight_bytes=1_000_000,
                         out_bytes=50_000 * (6 - i)) for i in range(6))
    g = BlockGraph("toy", blocks, input_bytes=300_000, output_bytes=100)
    devs, links = _chain(2)
    scen = Scenario("toy2", devs[:2], links[:1])
    sp = AdaptiveSplitter(g, scen, batch=2)
    # moving the cut 2 -> 4 ships blocks 2 and 3 across hop 0 (r=1 pin)
    base = 2 * 1_000_000 * 1e-6
    assert sp.migration_energy_j((2,), (4,)) == pytest.approx(base)
    # destination stage replicated r=3: each crossed block ships 3 copies
    assert sp.migration_energy_j((2,), (4,), new_replicas=(3, 1)) \
        == pytest.approx(3 * base)
    # replication of an untouched stage costs nothing extra
    assert sp.migration_energy_j((2,), (4,), new_replicas=(1, 3)) \
        == pytest.approx(base)
    # time ships 3x the bytes in one bulk transfer per hop: the per-byte
    # term triples, the per-hop latency term is charged once
    oh = sp.migration_overhead_s
    assert sp.migration_time_s((2,), (4,)) \
        == pytest.approx(oh + links[0].transfer_time(2_000_000))
    assert sp.migration_time_s((2,), (4,), new_replicas=(3, 1)) \
        == pytest.approx(oh + links[0].transfer_time(3 * 2_000_000))


# --------------------------------------------------------------------------- #
# Doorbells
# --------------------------------------------------------------------------- #
def test_bell_pair_flavors_ring_and_wait():
    import os

    from repro.runtime.transport import _bell_pair
    flavors = ["socketpair", "auto"]
    if hasattr(os, "eventfd"):
        flavors.append("eventfd")
    for flavor in flavors:
        ring, wait = _bell_pair(flavor)
        ring.ring()
        ring.ring()                           # coalesced rings must not block
        wait.wait(0.5)
        wait.wait(0.01)                       # drained: times out quietly
        ring.close()
        wait.close()
        wait.close()                          # idempotent
    with pytest.raises(ValueError):
        _bell_pair("smoke-signals")


@pytest.mark.skipif(not hasattr(__import__("os"), "eventfd"),
                    reason="no eventfd on this platform")
def test_eventfd_pair_ends_close_independently():
    from repro.runtime.transport import _EventFdBell
    a, b = _EventFdBell.pair()
    b_dup = b                                 # same counter, own descriptor
    a.close()                                 # closing one end …
    b_dup.wait(0.01)                          # … must not break the other
    b_dup.close()


def test_shmem_hops_work_with_either_bell():
    from repro.runtime.transport import BATCH, HopSpec, ShmemChannel
    for bell in ("socketpair", "auto"):
        ch = ShmemChannel(HopSpec(index=0, depth=2, spin_us=0, bell=bell))
        x = np.arange(4096, dtype=np.float32)
        ch.send(x)
        kind, y = ch.recv(timeout=5)
        assert kind == BATCH
        np.testing.assert_array_equal(np.asarray(y).reshape(-1), x)
        ch.close()
        ch.reap()


# --------------------------------------------------------------------------- #
# Multi-producer shmem segment
# --------------------------------------------------------------------------- #
def test_shmem_open_fan_packs_lanes_into_one_segment():
    from repro.runtime.transport import BATCH, HopSpec, get_transport
    lanes = get_transport("shmem").open_fan(
        HopSpec(index=0, depth=4, spin_us=50), 3)
    try:
        assert len({c._ctl_name for c in lanes}) == 1
        assert all(c._n_lanes == 3 for c in lanes)
        for m, c in enumerate(lanes):         # per-lane SPSC rings stay
            c.send(np.full(2000, m, np.float32))      # independent
        for m, c in enumerate(lanes):
            kind, v = c.recv(timeout=5)
            assert kind == BATCH and float(np.asarray(v)[0]) == m
    finally:
        for c in lanes:
            c.close()
        lanes[0].reap()


def test_shmem_fan_reap_sweeps_every_lane():
    from multiprocessing import shared_memory

    from repro.runtime.transport import HopSpec, get_transport
    lanes = get_transport("shmem").open_fan(
        HopSpec(index=0, depth=2, spin_us=50), 2)
    # force a payload slot into lane 1's table, then reap via lane 0
    lanes[1].send(np.zeros(100_000, np.float32))
    lanes[1].recv(timeout=5)
    slot = lanes[1]._tab_name(0) or lanes[1]._tab_name(1)
    assert slot, "expected a named payload slot on lane 1"
    for c in lanes:
        c.close()
    lanes[0].reap()
    with pytest.raises(FileNotFoundError):
        shared_memory.SharedMemory(name=slot)


# --------------------------------------------------------------------------- #
# Fan wrappers: striping, token broadcast, merge ordering (in-process)
# --------------------------------------------------------------------------- #
def _queue_lanes(n):
    from repro.runtime.edge import _QueueChan
    return [_QueueChan() for _ in range(n)]


def test_fanout_stripes_batches_and_broadcasts_tokens():
    from repro.runtime.transport import (BATCH, RECONFIG, STOP,
                                         FanOutChannel)
    lanes = _queue_lanes(3)
    out = FanOutChannel(lanes)
    for i in range(7):
        out.send(i, kind=BATCH)
    out.send({"bounds": (0, 1)}, kind=RECONFIG)
    out.send(None, kind=STOP)
    per_lane = [[], [], []]
    for m, ln in enumerate(lanes):
        while True:
            try:
                per_lane[m].append(ln.recv(timeout=0.01))
            except Exception:
                break
    # batches striped round-robin …
    assert [k for k, _ in per_lane[0]][:3] == [BATCH] * 3
    assert [v for k, v in per_lane[0] if k == BATCH] == [0, 3, 6]
    assert [v for k, v in per_lane[1] if k == BATCH] == [1, 4]
    assert [v for k, v in per_lane[2] if k == BATCH] == [2, 5]
    # … tokens on every lane, in stream order
    for m in range(3):
        assert [k for k, _ in per_lane[m][-2:]] == [RECONFIG, STOP]


def test_fanin_merges_in_stripe_order_and_dedups_tokens():
    from repro.runtime.transport import (BATCH, STATS, STOP, FanInChannel,
                                         FanOutChannel)
    lanes = _queue_lanes(3)
    out, inn = FanOutChannel(lanes), FanInChannel(lanes)
    for i in range(5):
        out.send(i, kind=BATCH)
    out.send(None, kind=STATS)                # mid-stream broadcast token
    for i in range(5, 9):
        out.send(i, kind=BATCH)
    out.send(None, kind=STOP)
    got = []
    while True:
        kind, obj = inn.recv(timeout=1.0)
        got.append((kind, obj))
        if kind == STOP:
            break
    kinds = [k for k, _ in got]
    assert kinds.count(STATS) == 1            # returned exactly once
    assert kinds.count(STOP) == 1
    assert [v for k, v in got if k == BATCH] == list(range(9))


def test_fanin_timeout_leaves_merge_resumable():
    from repro.runtime.transport import (STATS, FanInChannel,
                                         TransportTimeout)
    lanes = _queue_lanes(2)
    inn = FanInChannel(lanes)
    lanes[0].send(None, kind=STATS)           # half a broadcast
    with pytest.raises(TransportTimeout):
        inn.recv(timeout=0.05)                # lane 1 still owes its copy
    lanes[1].send(None, kind=STATS)
    kind, _ = inn.recv(timeout=1.0)           # resumes, returns the token
    assert kind == STATS


# --------------------------------------------------------------------------- #
# Runtime: the fan-in integrity matrix
# --------------------------------------------------------------------------- #
jax = pytest.importorskip("jax")


def _tiny_model():
    from repro.models.cnn.layers import (Conv2D, Flatten, Linear, Pool,
                                         ReLU, Sequential)
    from repro.models.cnn.zoo import CNNModel
    blocks = [
        ("conv0", Sequential([Conv2D(3, 8, 3, 1, 1), ReLU()])),
        ("conv1", Sequential([Conv2D(8, 8, 3, 1, 1), ReLU()])),
        ("pool", Pool("max", 2, 2)),
        ("conv2", Sequential([Conv2D(8, 16, 3, 1, 1), ReLU()])),
        ("head", Sequential([Flatten(), Linear(16 * 16 * 16, 10)])),
    ]
    return CNNModel("tinycnn", blocks, input_hw=32)


@pytest.fixture(scope="module")
def tiny():
    m = _tiny_model()
    return m, m.init(jax.random.PRNGKey(0))


def _batches(n, batch=2, hw=32):
    return [jax.random.normal(jax.random.PRNGKey(100 + i), (batch, hw, hw, 3))
            for i in range(n)]


@pytest.fixture(scope="module")
def r1_reference(tiny):
    """The r=1 pipeline outputs everything else must be bit-equal to."""
    from repro.runtime.edge import EdgePipeline
    m, params = tiny
    xs = _batches(10)
    with EdgePipeline(m, params, (2, 3), [LAN_PI_GPU, LAN_PI_GPU]) as pipe:
        pipe.warmup(xs[0])
        with pipe.session() as s:
            for x in xs:
                s.submit(x)
            outs = [np.asarray(y) for y in s.drain()]
    return xs, outs


def test_pipeline_rejects_incoherent_replica_vectors(tiny):
    from repro.runtime.edge import EdgePipeline
    m, params = tiny
    with pytest.raises(ValueError):
        EdgePipeline(m, params, (2, 3), [LAN_PI_GPU, LAN_PI_GPU],
                     replicas=(2, 3, 1))      # 2->3 has no valid lane map
    with pytest.raises(ValueError):
        EdgePipeline(m, params, (2, 3), [LAN_PI_GPU, LAN_PI_GPU],
                     replicas=(1, 2))         # wrong length
    with pytest.raises(ValueError):
        EdgePipeline(m, params, (2, 3), [LAN_PI_GPU, LAN_PI_GPU],
                     replicas=(1, 0, 1))


@pytest.mark.parametrize("r", [2, 3])
@pytest.mark.parametrize("policy", ["drain", "drop"])
def test_emulated_replica_matrix(tiny, r1_reference, r, policy):
    _replica_matrix_case(tiny, r1_reference, "emulated", r, policy)


@pytest.mark.parametrize("transport", ["socket", "shmem"])
@pytest.mark.parametrize("r", [2, 3])
def test_process_replica_matrix(tiny, r1_reference, transport, r):
    """socket/shmem × drain/drop × r∈{2,3}: zero lost/dup/reordered
    results, bit-equal to the r=1 reference — both policies share one
    pipeline standup to keep the matrix affordable."""
    from repro.runtime.edge import EdgePipeline
    from repro.runtime.sanitizer import drain_violations
    m, params = tiny
    xs, refs = r1_reference
    drain_violations()                        # shed any stale reports
    with EdgePipeline(m, params, (2, 3), [LAN_PI_GPU, LAN_PI_GPU],
                      transport=transport, replicas=(1, r, 1),
                      sanitize=True) as pipe:
        pipe.warmup(xs[0])
        for policy in ("drain", "drop"):
            with pipe.session(inflight=4, policy=policy) as s:
                for x in xs[:4]:
                    s.submit(x)               # fill the replica lanes …
                s.migrate((2, 4))             # … re-cut mid-stream
                for x in xs[4:]:
                    s.submit(x)
                got = s.drain()
            assert len(got) == len(xs), \
                f"lost/duplicated under {transport}/r={r}/{policy}"
            for i, (ref, y) in enumerate(zip(refs, got)):
                assert np.allclose(ref, np.asarray(y), atol=1e-5), \
                    f"batch {i} wrong under {transport}/r={r}/{policy}"
            pipe.migrate((2, 3))              # restore for the next policy
    bad = drain_violations()
    assert bad == [], "\n".join(v.render() for v in bad)


def _replica_matrix_case(tiny, r1_reference, transport, r, policy):
    from repro.runtime.edge import EdgePipeline
    from repro.runtime.sanitizer import drain_violations
    m, params = tiny
    xs, refs = r1_reference
    drain_violations()                        # shed any stale reports
    with EdgePipeline(m, params, (2, 3), [LAN_PI_GPU, LAN_PI_GPU],
                      transport=transport, replicas=(1, r, 1),
                      sanitize=True) as pipe:
        pipe.warmup(xs[0])
        with pipe.session(inflight=4, policy=policy) as s:
            for x in xs[:4]:
                s.submit(x)
            s.migrate((2, 4))
            for x in xs[4:]:
                s.submit(x)
            got = s.drain()
    assert len(got) == len(xs)
    for i, (ref, y) in enumerate(zip(refs, got)):
        assert np.allclose(ref, np.asarray(y), atol=1e-5), \
            f"batch {i} wrong under {transport}/r={r}/{policy}"
    bad = drain_violations()
    assert bad == [], "\n".join(v.render() for v in bad)


def test_replicated_pipeline_is_bit_equal_without_migration(tiny,
                                                           r1_reference):
    """No recut in flight: replica fan-out/fan-in must be bit-exact, not
    merely close — same jitted stages, same cuts, different plumbing."""
    from repro.runtime.edge import EdgePipeline
    m, params = tiny
    xs, refs = r1_reference
    with EdgePipeline(m, params, (2, 3), [LAN_PI_GPU, LAN_PI_GPU],
                      transport="shmem", replicas=(2, 2, 1)) as pipe:
        pipe.warmup(xs[0])
        with pipe.session() as s:
            for x in xs:
                s.submit(x)
            got = s.drain()
            s.checkpoint(probe=False)         # STATS through the replicas
        stats = pipe.stage_stats()
    assert len(got) == len(refs)
    for ref, y in zip(refs, got):
        np.testing.assert_array_equal(ref, np.asarray(y))
    # every replica executed: the two logical stages split the batches
    assert stats[0].calls == len(xs)
    assert stats[1].calls == len(xs)
